"""Exact-seed replica of the Rust inverse-tier e2e tests.

The budgets asserted in rust/tests/native_e2e.rs were first sized with
numpy-default RNG streams (python/proto_two_head.py). This script goes
further: it ports the Rust `util::rng::Rng` (splitmix64 scramble +
xorshift64*), the f32-cast Glorot init, `QuadMesh::compute_boundary`
edge ordering, `sample_boundary` and `sample_interior` bit-for-bit, so
the two tests run here with the *exact* parameter init and sensor/
boundary data the Rust tests will see at their default seed 42. Only
floating-point summation order differs (blocked GEMMs vs numpy dots).

Run:  python3 python/proto_rust_seed_check.py
"""
import sys

import numpy as np

sys.path.insert(0, "python/compile")
from fem_py import assembly, mesh as pmesh  # noqa: E402

import proto_two_head as proto  # noqa: E402

M64 = (1 << 64) - 1


class RustRng:
    """Bit-exact port of rust util::rng::Rng (xorshift64*)."""

    def __init__(self, seed):
        z = (seed + 0x9E3779B97F4A7C15) & M64
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        self.state = ((z ^ (z >> 31)) | 1) & M64

    def next_u64(self):
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & M64
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & M64

    def uniform(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def uniform_in(self, lo, hi):
        return lo + (hi - lo) * self.uniform()

    def below(self, n):
        return int(self.uniform() * n) % max(n, 1)

    def glorot(self, n_in, n_out):
        lim = np.sqrt(6.0 / (n_in + n_out))
        return np.array(
            [np.float32(self.uniform_in(-lim, lim))
             for _ in range(n_in * n_out)],
            dtype=np.float64,
        ).reshape(n_in, n_out)


def rust_net(layers, seed, two_head):
    """TwoHeadNet with the exact Rust Mlp::glorot[_two_head] init."""
    rng = RustRng(seed)
    net = proto.TwoHeadNet(layers, seed=0, two_head=two_head)
    for i, (nin, nout) in enumerate(zip(layers[:-1], layers[1:])):
        net.params[i][0] = rng.glorot(nin, nout)
        net.params[i][1] = np.zeros(nout)
    if two_head:
        net.params[-1][0] = rng.glorot(layers[-2], 1)
        net.params[-1][1] = np.zeros(1)
    return net


def compute_boundary(points, cells):
    """Port of QuadMesh::compute_boundary (oriented, sorted by (a, b))."""
    count = {}
    for c in cells:
        for k in range(4):
            a, b = int(c[k]), int(c[(k + 1) % 4])
            key = (min(a, b), max(a, b))
            n, ab = count.get(key, (0, (a, b)))
            count[key] = (n + 1, ab)
    edges = sorted(ab for n, ab in count.values() if n == 1)
    return edges


def sample_boundary(points, edges, n):
    """Port of QuadMesh::sample_boundary (edge-list-order walk)."""
    lens = [np.hypot(*(points[b] - points[a])) for a, b in edges]
    total = sum(lens)
    out = []
    acc = 0.0
    ei = 0
    cur_len = lens[0]
    for i in range(n):
        target = total * i / n
        while acc + cur_len < target and ei + 1 < len(edges):
            acc += cur_len
            ei += 1
            cur_len = lens[ei]
        t = min(max((target - acc) / cur_len, 0.0), 1.0) \
            if cur_len > 0 else 0.0
        pa, pb = points[edges[ei][0]], points[edges[ei][1]]
        out.append(pa + t * (pb - pa))
    return np.array(out)


def bilinear_map(verts, xi, eta):
    x0, x1, x2, x3 = verts[:, 0]
    y0, y1, y2, y3 = verts[:, 1]
    xc = [(x0 + x1 + x2 + x3) / 4, (-x0 + x1 + x2 - x3) / 4,
          (-x0 - x1 + x2 + x3) / 4, (x0 - x1 + x2 - x3) / 4]
    yc = [(y0 + y1 + y2 + y3) / 4, (-y0 + y1 + y2 - y3) / 4,
          (-y0 - y1 + y2 + y3) / 4, (y0 - y1 + y2 - y3) / 4]
    return (xc[0] + xc[1] * xi + xc[2] * eta + xc[3] * xi * eta,
            yc[0] + yc[1] * xi + yc[2] * eta + yc[3] * xi * eta)


def sample_interior(points, cells, n, seed):
    """Port of QuadMesh::sample_interior (cell pick + ref point)."""
    rng = RustRng(seed)
    out = []
    for _ in range(n):
        e = rng.below(len(cells))
        xi = rng.uniform_in(-1.0, 1.0)
        eta = rng.uniform_in(-1.0, 1.0)
        out.append(bilinear_map(points[cells[e]], xi, eta))
    return np.array(out)


def eval_grid(nx, ny, x0, y0, x1, y1):
    out = []
    for iy in range(ny):
        for ix in range(nx):
            out.append([x0 + (x1 - x0) * ix / max(nx - 1, 1),
                        y0 + (y1 - y0) * iy / max(ny - 1, 1)])
    return np.array(out)


def run_inverse_const():
    print("== inverse_const_recovers_eps_to_paper_accuracy @ seed 42 ==")
    pts, cells = pmesh.rect_grid(2, 2, -1.0, -1.0, 1.0, 1.0)
    dom = assembly.assemble(pts, cells, 3, 10)

    def u_c(x):
        return 10.0 * np.sin(x) * np.tanh(x) * np.exp(-0.3 * x * x)

    def lap_u_c(x):
        h = 1e-4
        return (u_c(x + h) - 2 * u_c(x) + u_c(x - h)) / (h * h)

    x = dom.quad_xy[:, 0].reshape(dom.n_elem, dom.n_quad)
    fmat = np.einsum("ejq,eq->ej", dom.v, -0.3 * lap_u_c(x))
    edges = compute_boundary(pts, cells)
    bd = sample_boundary(pts, edges, 80)
    bd_u = u_c(bd[:, 0])
    sp = sample_interior(pts, cells, 20, 43)  # opts.seed + 1
    s_u = u_c(sp[:, 0])
    obj = proto.Objective(dom, fmat, bd, bd_u, sp, s_u, mode="const",
                          eps_const=2.0)
    net = rust_net([2, 16, 16, 1], 42, two_head=False)
    hit = {"t": None}

    def cb(t, loss, eps_c, _n):
        if hit["t"] is None and abs(eps_c - 0.3) < 1e-2:
            hit["t"] = t
        return abs(eps_c - 0.3) < 5e-3  # the test's early stop

    it, loss, eps_c = proto.adam_train(obj, net, 4000, 5e-3, eps0=2.0,
                                       callback=cb)
    ok = abs(eps_c - 0.3) < 1e-2
    print(f"  stopped at iter {it}, eps = {eps_c:.4f} "
          f"(first |eps-0.3|<1e-2 at {hit['t']}), PASS={ok}")
    assert ok


def run_inverse_space_smoke():
    print("== inverse_space_smoke_recovers_eps_field_2x @ seed 42 ==")
    pts, cells = pmesh.unit_square(2)
    dom = assembly.assemble(pts, cells, 3, 8)
    pi = np.pi

    def u_s(x, y):
        return np.sin(pi * x) * np.sin(pi * y)

    def forcing(x, y):
        ux = pi * np.cos(pi * x) * np.sin(pi * y)
        uy = pi * np.sin(pi * x) * np.cos(pi * y)
        lap = -2.0 * pi * pi * u_s(x, y)
        ex, ey = 0.5 * np.cos(x), -0.5 * np.sin(y)
        return -(ex * ux + ey * uy + proto.eps_star(x, y) * lap) + ux

    x = dom.quad_xy[:, 0].reshape(dom.n_elem, dom.n_quad)
    y = dom.quad_xy[:, 1].reshape(dom.n_elem, dom.n_quad)
    fmat = np.einsum("ejq,eq->ej", dom.v, forcing(x, y))
    edges = compute_boundary(pts, cells)
    bd = sample_boundary(pts, edges, 80)
    bd_u = u_s(bd[:, 0], bd[:, 1])
    sp = sample_interior(pts, cells, 60, 43)
    s_u = u_s(sp[:, 0], sp[:, 1])
    obj = proto.Objective(dom, fmat, bd, bd_u, sp, s_u, bx=1.0, by=0.0,
                          mode="space")
    net = rust_net([2, 16, 16, 1], 42, two_head=True)

    grid = eval_grid(30, 30, 0.02, 0.02, 0.98, 0.98)
    ref = proto.eps_star(grid[:, 0], grid[:, 1])

    def el2(n_):
        _, _, _, eps, _ = n_.forward(grid)
        return np.sqrt(((eps - ref) ** 2).mean())

    e0 = el2(net)
    proto.adam_train(obj, net, 2000, 5e-3)
    e1 = el2(net)
    u_pred, _, _, _, _ = net.forward(grid)
    u_ref = u_s(grid[:, 0], grid[:, 1])
    rel = np.sqrt(((u_pred - u_ref) ** 2).sum() / (u_ref ** 2).sum())
    ok = 2.0 * e1 <= e0 and rel < 0.2
    print(f"  ||eps-eps*|| {e0:.4f} -> {e1:.4f} (x{e0 / e1:.1f}), "
          f"u rel-L2 {rel:.4f}, PASS={ok}")
    assert ok


def sanity_rng():
    # spot-check the PRNG port: uniform() stays in [0,1), determinism
    a, b = RustRng(7), RustRng(7)
    seq = [a.uniform() for _ in range(1000)]
    assert seq == [b.uniform() for _ in range(1000)]
    assert all(0.0 <= v < 1.0 for v in seq)
    assert RustRng(1).next_u64() != RustRng(2).next_u64()
    print("RustRng port: deterministic, in-range")


if __name__ == "__main__":
    sanity_rng()
    run_inverse_const()
    run_inverse_space_smoke()
    print("both e2e budgets hold at the exact Rust seed-42 init")
