"""Reference second implementation of the version-1 checkpoint format
(rust/src/runtime/checkpoint.rs), used to validate the documented
layout offline: encodes a synthetic artifact per the spec in the Rust
module docs / README, decodes it back, and checks the FNV-1a test
vectors — any divergence between this file and the Rust reader means
the *documentation* drifted, which is exactly what it exists to catch
(no Rust toolchain in this container).

Run: python proto_checkpoint.py
"""

import json
import struct

MAGIC = b"FVPCHKPT"
FORMAT_VERSION = 1
FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK = (1 << 64) - 1

SECTION_NAMES = [
    "theta", "eps", "adam_m", "adam_v",
    "form_eps", "form_bx", "form_by", "form_c",
]


def fnv1a_64(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK
    return h


def hash_f64_bits(vals) -> int:
    return fnv1a_64(struct.pack(f"<{len(vals)}d", *vals))


def encode(ck: dict) -> bytes:
    """ck: problem, problem_label, loss_mode, loss_kind, cli (list of
    pairs), layers, two_head, step, theta, eps, adam_m, adam_v,
    form (dict coeff -> ("const", v) | ("table", [v...])),
    fingerprint, hyper."""
    coeffs = [ck["form"][k] for k in ("eps", "bx", "by", "c")]
    sections = [
        ["theta", len(ck["theta"])],
        ["eps", 1],
        ["adam_m", len(ck["adam_m"])],
        ["adam_v", len(ck["adam_v"])],
    ] + [
        [f"form_{k}", 1 if kind == "const" else len(v)]
        for k, (kind, v) in zip(("eps", "bx", "by", "c"), coeffs)
    ]
    fp = dict(ck["fingerprint"])
    fp["quad_hash"] = format(fp["quad_hash"], "016x")
    hyper = dict(ck["hyper"])
    hyper["seed"] = format(hyper["seed"], "x")
    meta = {
        "format": "fastvpinns-checkpoint",
        "version": FORMAT_VERSION,
        "problem": ck["problem"],
        "problem_label": ck["problem_label"],
        "loss_mode": ck["loss_mode"],
        "loss_kind": ck["loss_kind"],
        "cli": dict(ck["cli"]),
        "layers": ck["layers"],
        "two_head": ck["two_head"],
        "step": ck["step"],
        "best_metric": ck["best_metric"],
        "hyper": hyper,
        "fingerprint": fp,
        "form": {
            k: ({"kind": "const"} if kind == "const"
                else {"kind": "table", "len": len(v)})
            for k, (kind, v) in zip(("eps", "bx", "by", "c"), coeffs)
        },
        "sections": sections,
    }
    meta_b = json.dumps(meta, separators=(",", ":")).encode()
    payload = list(ck["theta"]) + [ck["eps"]] + list(ck["adam_m"]) \
        + list(ck["adam_v"])
    for kind, v in coeffs:
        payload += [v] if kind == "const" else list(v)
    body = (MAGIC + bytes([FORMAT_VERSION])
            + struct.pack("<I", len(meta_b)) + meta_b
            + struct.pack(f"<{len(payload)}d", *payload))
    return body + struct.pack("<Q", fnv1a_64(body))


def decode(b: bytes) -> dict:
    assert len(b) >= 8 + 1 + 4 + 8, "too short"
    assert b[:8] == MAGIC, "bad magic"
    assert b[8] == FORMAT_VERSION, f"unsupported version {b[8]}"
    body, stored = b[:-8], struct.unpack("<Q", b[-8:])[0]
    assert fnv1a_64(body) == stored, "checksum mismatch"
    (meta_len,) = struct.unpack("<I", b[9:13])
    meta = json.loads(b[13:13 + meta_len])
    assert [n for n, _ in meta["sections"]] == SECTION_NAMES
    total = sum(n for _, n in meta["sections"])
    payload = body[13 + meta_len:]
    assert len(payload) == 8 * total, "payload size mismatch"
    vals = list(struct.unpack(f"<{total}d", payload))
    out, off = {}, 0
    for name, n in meta["sections"]:
        out[name] = vals[off:off + n]
        off += n
    form = {}
    for k, sec in zip(("eps", "bx", "by", "c"),
                      ("form_eps", "form_bx", "form_by", "form_c")):
        spec = meta["form"][k]
        if spec["kind"] == "const":
            assert len(out[sec]) == 1
            form[k] = ("const", out[sec][0])
        else:
            assert spec["len"] == len(out[sec])
            form[k] = ("table", out[sec])
    # theta length validation
    layers, two_head = meta["layers"], meta["two_head"]
    want = sum(a * b + b for a, b in zip(layers, layers[1:]))
    if two_head:
        want += layers[-2] + 1
    assert len(out["theta"]) == want, "theta length mismatch"
    fp = dict(meta["fingerprint"])
    fp["quad_hash"] = int(fp["quad_hash"], 16)
    hyper = dict(meta["hyper"])
    hyper["seed"] = int(hyper["seed"], 16)
    return {
        "problem": meta["problem"],
        "problem_label": meta["problem_label"],
        "loss_mode": meta["loss_mode"],
        "loss_kind": meta["loss_kind"],
        "cli": sorted(meta["cli"].items()),
        "layers": layers,
        "two_head": two_head,
        "step": meta["step"],
        "best_metric": meta["best_metric"],
        "theta": out["theta"],
        "eps": out["eps"][0],
        "adam_m": out["adam_m"],
        "adam_v": out["adam_v"],
        "form": form,
        "fingerprint": fp,
        "hyper": hyper,
    }


def main():
    # FNV-1a standard vectors (same asserted in the Rust unit tests)
    assert fnv1a_64(b"") == 0xCBF29CE484222325
    assert fnv1a_64(b"a") == 0xAF63DC4C8601EC8C
    assert fnv1a_64(b"foobar") == 0x85944171F73967E8

    theta = [0.1 * i - 0.37 for i in range(2 * 3 + 3 + 3 * 1 + 1)]
    ck = {
        "problem": "helmholtz",
        "problem_label": "helmholtz_k6.283",
        "loss_mode": "forward",
        "loss_kind": "helmholtz",
        "cli": [("k-pi", "2"), ("n", "2")],
        "layers": [2, 3, 1],
        "two_head": False,
        "step": 1234,
        "best_metric": None,
        "theta": theta,
        "eps": 0.0,
        "adam_m": [0.25] * len(theta),
        "adam_v": [1e-9] * len(theta),
        "form": {
            "eps": ("const", 1.0),
            "bx": ("const", 0.0),
            "by": ("const", 0.0),
            "c": ("table", [-39.47, -39.47, 0.1 + 0.2]),
        },
        "fingerprint": {
            "ne": 4, "nt": 25, "nq": 100, "n_points": 9, "n_cells": 4,
            "bbox": [0.0, 0.0, 1.0, 1.0],
            "quad_hash": 0xDEADBEEF01234567,
        },
        "hyper": {"tau": 10.0, "gamma": 10.0,
                  "seed": (1 << 63) + 12345,  # beyond f64's 2^53
                  "eps_init": 2.0, "nb": 400, "ns": 0},
    }
    blob = encode(ck)
    back = decode(blob)
    assert back == ck, "round-trip mismatch"

    # corruption anywhere must break the checksum
    for i in (9, len(blob) // 2, len(blob) - 9):
        bad = bytearray(blob)
        bad[i] ^= 0x40
        try:
            decode(bytes(bad))
        except AssertionError:
            pass
        else:
            raise SystemExit(f"corruption at byte {i} not caught")

    # a version bump with a fixed-up checksum is a version error
    bad = bytearray(blob)
    bad[8] = FORMAT_VERSION + 1
    bad[-8:] = struct.pack("<Q", fnv1a_64(bytes(bad[:-8])))
    try:
        decode(bytes(bad))
    except AssertionError as e:
        assert "version" in str(e)

    print(f"proto_checkpoint OK: {len(blob)}-byte artifact, "
          f"round-trip + corruption + version checks passed")


if __name__ == "__main__":
    main()
