"""Exact-seed replay of the self-healing recovery protocol.

The chaos tier injects `grad.nan@500` into real CLI train runs and
asserts recovery. This script sizes those assertions offline, the
same way the e2e accuracy bars were sized (proto_rust_seed_check.py):
it replays the runs at the *exact* Rust init seeds (bit-ported RNG /
Glorot / boundary sampler) with the coordinator's recovery protocol
transliterated line by line —

- snapshot (theta copy + step) every `snapshot_every = 50` clean
  steps,
- at step 500 the gradient is NaN-poisoned *before* the Adam update
  (exactly like the failpoint: loss, m, v and theta all go NaN),
- the sentinel sees the non-finite loss and rolls back: restore the
  step-450 snapshot, **zero the Adam moments**, multiply the LR scale
  by `lr_backoff = 0.5`, rewind the step counter and replay,
- after `lr_restore_after = 500` consecutive clean steps since the
  rollback the LR scale is annealed back to 1.0 (the backoff is
  transient, not a permanent tax on the rest of the run),
- Adam bias correction keeps using the *global* step index (the Rust
  backend's `step` argument), so the post-reset transient is
  reproduced faithfully.

Only floating-point summation order differs from Rust (numpy dots vs
blocked GEMMs) — trajectories are chaotic over 1e4 iters, so this
validates the *basin*, not the bits. Measured families (rel-L2 at
the end of the default budget, across exact Rust init seeds):

- poisson_sin (constant lr 5e-3, 5000 iters): clean
  {42: 4.1e-2, 43: 2.3e-2, 44: 3.3e-2}; healed (permanent backoff)
  {42: 2.2e-2, 43: 5.1e-2, 44: 2.3e-2}; at 8000 iters
  {42: 5.4e-2, 43: 2.9e-2, 44: 1.6e-2}. The constant rate leaves an
  endgame wander floor of ~1.5e-2..5.4e-2 (clean AND healed draws
  are interleaved — the fault is not what moves the number), plus a
  chaotic saddle-escape time; poisson_sin can NOT robustly assert
  1e-2, so its chaos scenario uses a 1e-1 convergence-sanity bar
  (2x margin over the worst family draw, while a dead run sits at
  rel-L2 ~ 1 or NaN).
- helmholtz (ExpDecay 5e-3 x0.7/1500, 12000 iters; clean bar sized
  in proto_varform.py at 6.4e-3 / 7.8e-3 for seeds 42/1): healed
  with a *permanent* 0.5 backoff {42: 7.1e-3, 1: 1.02e-2} — seed 1
  is OVER the 1e-2 bar (0.8 backoff is no better: 9.5e-3 / 1.06e-2);
  healed with the backoff + anneal {42: 4.6e-3, 1: 6.9e-3,
  7: 6.5e-3} — back inside the clean family. The anneal is what
  makes "a healed run still meets the existing acceptance bar" a
  robust claim, and helmholtz is where the chaos tier asserts it.

Also checked: the lr-backoff bookkeeping (scale sequence 1.0, 0.5,
0.25, ... per recovery; budget exhaustion on the (max+1)-th event;
anneal restores the scale after exactly `lr_restore_after` clean
steps) and that a rollback restores the snapshot parameters
bit-for-bit.

Run:  python3 python/proto_selfheal.py      (~4 min)
"""
import sys
import time

import numpy as np

sys.path.insert(0, "python/compile")
from fem_py import assembly, mesh as pmesh  # noqa: E402

import proto_two_head as proto  # noqa: E402
import proto_varform as varform  # noqa: E402
from proto_rust_seed_check import (  # noqa: E402
    compute_boundary, eval_grid, rust_net, sample_boundary,
)

OMEGA = 2.0 * np.pi
SNAPSHOT_EVERY = 50
LR_BACKOFF = 0.5
LR_RESTORE_AFTER = 500
MAX_RECOVERIES = 3
FAIL_AT = 500


def u_exact(x, y):
    return np.sin(OMEGA * x) * np.sin(OMEGA * y)


def build_poisson():
    """poisson_sin at the CLI defaults: n=4, nt1d=5, nq1d=10, nb=400."""
    pts, cells = pmesh.unit_square(4)
    dom = assembly.assemble(pts, cells, 5, 10)
    x = dom.quad_xy[:, 0].reshape(dom.n_elem, dom.n_quad)
    y = dom.quad_xy[:, 1].reshape(dom.n_elem, dom.n_quad)
    # -lap u = f with u = sin(wx) sin(wy)  =>  f = 2 w^2 u
    fmat = np.einsum("ejq,eq->ej", dom.v,
                     2.0 * OMEGA * OMEGA * u_exact(x, y))
    edges = compute_boundary(pts, cells)
    bd = sample_boundary(pts, edges, 400)
    bd_u = u_exact(bd[:, 0], bd[:, 1])
    # forward problem: eps fixed at 1, no sensors (one dummy point at
    # gamma = 0 keeps the Objective's mean well-defined)
    sp = np.array([[0.5, 0.5]])
    s_u = u_exact(sp[:, 0], sp[:, 1])
    return proto.Objective(dom, fmat, bd, bd_u, sp, s_u, mode="const",
                           eps_const=1.0, tau=10.0, gamma=0.0)


def build_helmholtz():
    """helmholtz at the registry defaults: k=2pi on unit_square(2),
    nt1d=5, nq1d=10, nb=400 via the RustRng boundary-sampler port."""
    k = 2.0 * np.pi
    obj, u = varform.build_helmholtz(k, n=2, nt1d=5, nq1d=10, nb=400)
    pts, cells = pmesh.unit_square(2)
    edges = compute_boundary(pts, cells)
    bd = sample_boundary(pts, edges, 400)
    obj.bd_pts = bd
    obj.bd_u = u(bd[:, 0], bd[:, 1])
    return obj, u


def rel_l2(net, exact):
    """rel-L2 on the 100x100 grid the CLI --expect-rel-l2 gate uses."""
    grid = eval_grid(100, 100, 0.0, 0.0, 1.0, 1.0)
    u, _, _, _, _ = net.forward(grid)
    ref = exact(grid[:, 0], grid[:, 1])
    return np.sqrt(((u - ref) ** 2).sum() / (ref ** 2).sum())


def train_selfheal(obj, net, iters, lr_fn, fail_at=None,
                   lr_restore_after=LR_RESTORE_AFTER, log_every=2000):
    """The coordinator's run() loop, transliterated.

    `lr_fn(step)` is the base schedule (the recovery scale multiplies
    it). Returns (recoveries, lr_scale, restored_at) where recoveries
    is a list of (at_step, rollback_to, lr_scale_after) and
    restored_at lists the steps where the anneal fired.
    """
    theta = net.flat()
    m = np.zeros_like(theta)
    v = np.zeros_like(theta)
    b1, b2, ae = 0.9, 0.999, 1e-8
    lr_scale = 1.0
    snap = (theta.copy(), 0)  # run-start snapshot
    recoveries = []
    restored_at = []
    last_rollback = None
    step = 0
    while step < iters:
        step += 1
        loss, g, _ge, _parts = obj.loss_and_grad(net)
        if step == fail_at and not any(r[0] == fail_at
                                       for r in recoveries):
            # the grad.nan failpoint: poison before Adam; hit counters
            # persist across the replay so it fires exactly once
            g = np.full_like(g, np.nan)
            loss = np.nan
        # Adam with the global step index (the Rust backend signature)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        theta -= (lr_fn(step) * lr_scale) * (m / (1 - b1 ** step)) \
            / (np.sqrt(v / (1 - b2 ** step)) + ae)
        net.set_flat(theta)
        # divergence sentinel + rollback
        if not np.isfinite(loss):
            assert len(recoveries) < MAX_RECOVERIES, \
                "recovery budget exhausted"
            theta = snap[0].copy()
            m[:] = 0.0
            v[:] = 0.0
            lr_scale *= LR_BACKOFF
            recoveries.append((step, snap[1], lr_scale))
            print(f"    recovery[{len(recoveries)}/{MAX_RECOVERIES}]: "
                  f"step {step} -> rolled back to {snap[1]}, "
                  f"lr scale {lr_scale:.3e}")
            last_rollback = snap[1]
            step = snap[1]
            net.set_flat(theta)
            continue
        # backoff anneal: sustained health restores the full rate
        if last_rollback is not None and lr_restore_after > 0 \
                and lr_scale < 1.0 \
                and step - last_rollback >= lr_restore_after:
            lr_scale = 1.0
            last_rollback = None
            restored_at.append(step)
            print(f"    anneal: lr scale restored to 1.0 at step "
                  f"{step}")
        if step % SNAPSHOT_EVERY == 0:
            snap = (theta.copy(), step)
        if log_every and step % log_every == 0:
            print(f"    it {step:5d} loss {loss:.4e}")
    return recoveries, lr_scale, restored_at


def check_backoff_bookkeeping():
    """Scale sequence, budget exhaustion and anneal timing —
    protocol-only (tiny net, synthetic divergence)."""
    scale, events = 1.0, []
    for _ in range(MAX_RECOVERIES):
        scale *= LR_BACKOFF
        events.append(scale)
    assert events == [0.5, 0.25, 0.125]

    # the (max+1)-th divergence must raise, not loop forever
    class Sticky:
        def loss_and_grad(self, net):
            nan = np.full(net.flat().size, np.nan)
            return np.nan, nan, 0.0, None

    n = rust_net([2, 2, 1], 7, two_head=False)
    failed = False
    try:
        train_selfheal(Sticky(), n, 20, lambda _t: 1e-3, log_every=0)
    except AssertionError:
        failed = True
    assert failed, "sticky divergence did not exhaust the budget"

    # one transient fault: rollback restores the snapshot bit-for-bit
    # and the anneal fires after exactly lr_restore_after clean steps
    class Transient:
        def __init__(self):
            self.calls = 0
            self.seen = {}

        def loss_and_grad(self, net):
            self.calls += 1
            self.seen[self.calls] = net.flat().copy()
            if self.calls == 17:
                nan = np.full(net.flat().size, np.nan)
                return np.nan, nan, 0.0, None
            return 1.0, np.full(net.flat().size, 1e-6), 0.0, None

    n = rust_net([2, 2, 1], 7, two_head=False)
    tr = Transient()
    rec, scale, restored = train_selfheal(
        tr, n, 80, lambda _t: 1e-3, lr_restore_after=5, log_every=0)
    # fault at call 17 = step 17 -> the tiny run never reaches the
    # step-50 snapshot cadence, so the rollback target is step 0
    assert rec == [(17, 0, 0.5)], rec
    assert restored == [5], restored
    assert scale == 1.0
    # call 18 is replay step 1: the net entering it must be the
    # restored run-start snapshot, bit-for-bit what call 1 saw
    assert np.array_equal(tr.seen[18], tr.seen[1]), \
        "rollback did not restore the snapshot bit-for-bit"
    print("backoff bookkeeping: scale halves per recovery, budget "
          "trips on the 4th event, anneal restores after sustained "
          "health")


def run_poisson():
    print("== poisson_sin @ exact Rust seed 42 (constant lr 5e-3) ==")
    obj = build_poisson()

    def lr(_t):
        return 5e-3

    print("  control (unfaulted):")
    net = rust_net([2, 30, 30, 30, 1], 42, two_head=False)
    rec, scale, _ = train_selfheal(obj, net, 5000, lr, fail_at=None)
    r_clean = rel_l2(net, u_exact)
    assert rec == [] and scale == 1.0
    print(f"  control rel-L2 {r_clean:.3e}")

    print("  faulted (grad.nan@500 -> rollback to 450):")
    net = rust_net([2, 30, 30, 30, 1], 42, two_head=False)
    rec, scale, restored = train_selfheal(obj, net, 5000, lr,
                                          fail_at=FAIL_AT)
    r_healed = rel_l2(net, u_exact)
    assert len(rec) == 1 and rec[0][0] == FAIL_AT \
        and rec[0][1] == FAIL_AT - SNAPSHOT_EVERY
    assert restored == [FAIL_AT - SNAPSHOT_EVERY + LR_RESTORE_AFTER]
    assert scale == 1.0, "anneal must have restored the scale"
    print(f"  healed rel-L2 {r_healed:.3e} (control {r_clean:.3e})")
    # constant-LR wander floor is 1.5e-2..5.4e-2 across the measured
    # family (see module docstring) — the chaos-tier bar is the 1e-1
    # convergence-sanity check, asserted here with the same margin
    assert r_healed < 1e-1, \
        f"healed poisson missed the sanity bar: {r_healed:.3e}"
    assert r_clean < 1e-1
    print("  PASS: healed poisson_sin converges under the 1e-1 "
          "sanity bar")


def run_helmholtz():
    print("== helmholtz @ exact Rust seed 42 (ExpDecay, 12000 it) ==")
    obj, u = build_helmholtz()

    def lr(t):
        return 5e-3 * 0.7 ** ((t - 1) // 1500)

    print("  faulted (grad.nan@500 -> rollback to 450 + anneal):")
    net = rust_net([2, 30, 30, 30, 1], 42, two_head=False)
    rec, scale, restored = train_selfheal(obj, net, 12000, lr,
                                          fail_at=FAIL_AT,
                                          log_every=3000)
    r_healed = rel_l2(net, u)
    assert len(rec) == 1 and rec[0][0] == FAIL_AT \
        and rec[0][1] == FAIL_AT - SNAPSHOT_EVERY
    assert restored == [FAIL_AT - SNAPSHOT_EVERY + LR_RESTORE_AFTER]
    assert scale == 1.0
    print(f"  healed rel-L2 {r_healed:.3e} "
          f"(clean-run family 6.4e-3 / 7.8e-3)")
    assert r_healed < 1e-2, \
        f"healed helmholtz missed the acceptance bar: {r_healed:.3e}"
    print("  PASS: the healed helmholtz run still meets the existing "
          "rel-L2 < 1e-2 acceptance bar")


def main():
    t0 = time.time()
    check_backoff_bookkeeping()
    run_poisson()
    run_helmholtz()
    print(f"all self-healing checks passed ({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
