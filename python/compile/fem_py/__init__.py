"""Build-time FEM substrate (numpy) — the oracle used to validate both the
Pallas kernel inputs and the Rust runtime assembly (`repro dump-tensors`).

Mirrors `rust/src/fem/` module-for-module:
  jacobi      <-> fem/jacobi.rs
  quadrature  <-> fem/quadrature.rs
  transforms  <-> fem/bilinear.rs
  basis       <-> fem/jacobi.rs (test basis)
  assembly    <-> fem/assembly.rs
  mesh        <-> mesh/generators.rs (unit-square subset)
"""

from . import jacobi, quadrature, transforms, basis, assembly, mesh  # noqa: F401
