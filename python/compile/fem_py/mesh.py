"""Minimal quad meshes for build-time tests & cross-validation.

Node/cell numbering matches rust/src/mesh/generators.rs exactly:
- nodes row-major: id = iy * (nx+1) + ix, coordinates ascending;
- cells row-major: id = cy * nx + cx, corner order
  [bottom-left, bottom-right, top-right, top-left] (CCW).
"""

import numpy as np


def rect_grid(nx: int, ny: int, x0=0.0, y0=0.0, x1=1.0, y1=1.0):
    """Structured rectangle grid. Returns (points (NP,2), cells (NE,4))."""
    xs = np.linspace(x0, x1, nx + 1)
    ys = np.linspace(y0, y1, ny + 1)
    pts = np.empty(((nx + 1) * (ny + 1), 2))
    for iy in range(ny + 1):
        for ix in range(nx + 1):
            pts[iy * (nx + 1) + ix] = (xs[ix], ys[iy])
    cells = np.empty((nx * ny, 4), dtype=np.int64)
    for cy in range(ny):
        for cx in range(nx):
            bl = cy * (nx + 1) + cx
            br = bl + 1
            tl = bl + (nx + 1)
            tr = tl + 1
            cells[cy * nx + cx] = (bl, br, tr, tl)
    return pts, cells


def unit_square(n: int):
    """n x n grid on (0,1)^2."""
    return rect_grid(n, n)


def skewed_square(n: int, amp: float = 0.15):
    """Unit-square grid with interior nodes perturbed by an analytic
    (RNG-free, hence Rust-reproducible) displacement field — produces
    genuinely non-constant per-element Jacobians for tests.

    Must stay bit-for-bit identical to mesh::generators::skewed_square in
    Rust (same sin/cos arguments, same ordering)."""
    pts, cells = unit_square(n)
    h = 1.0 / n
    for i in range(pts.shape[0]):
        x, y = pts[i]
        interior = 1e-12 < x < 1 - 1e-12 and 1e-12 < y < 1 - 1e-12
        if interior:
            pts[i, 0] = x + amp * h * np.sin(9.0 * x + 5.0 * y)
            pts[i, 1] = y + amp * h * np.cos(7.0 * x - 4.0 * y)
    return pts, cells
