"""Jacobi / Legendre polynomial evaluation (numpy, float64).

The hp-VPINNs test basis (Kharazmi et al. 2021, and the FastVPINNs paper
SS4.5) is built from Legendre polynomials: the n-th test function is
``P_{n+1}(x) - P_{n-1}(x)``, which vanishes at x = +-1 so Dirichlet-zero
test spaces come for free on the reference element.

All evaluations use stable three-term recurrences; derivatives use the
derivative recurrence (never the (x^2-1) division form, which is singular
at the Lobatto endpoints).
"""

import numpy as np


def legendre(n: int, x: np.ndarray) -> np.ndarray:
    """P_n(x) by the Bonnet recurrence. x: any shape, returns same shape."""
    x = np.asarray(x, dtype=np.float64)
    if n == 0:
        return np.ones_like(x)
    if n == 1:
        return x.copy()
    p0 = np.ones_like(x)
    p1 = x.copy()
    for k in range(1, n):
        p0, p1 = p1, ((2 * k + 1) * x * p1 - k * p0) / (k + 1)
    return p1


def legendre_deriv(n: int, x: np.ndarray) -> np.ndarray:
    """P'_n(x) via P'_{k+1} = (2k+1) P_k + P'_{k-1} (stable at x = +-1)."""
    x = np.asarray(x, dtype=np.float64)
    if n == 0:
        return np.zeros_like(x)
    if n == 1:
        return np.ones_like(x)
    # iterate values and derivatives together
    p0 = np.ones_like(x)
    p1 = x.copy()
    d0 = np.zeros_like(x)
    d1 = np.ones_like(x)
    for k in range(1, n):
        p2 = ((2 * k + 1) * x * p1 - k * p0) / (k + 1)
        d2 = (2 * k + 1) * p1 + d0
        p0, p1 = p1, p2
        d0, d1 = d1, d2
    return d1


def legendre_all(n_max: int, x: np.ndarray) -> np.ndarray:
    """Stack [P_0..P_{n_max}] -> shape (n_max+1, *x.shape)."""
    x = np.asarray(x, dtype=np.float64)
    out = np.empty((n_max + 1,) + x.shape, dtype=np.float64)
    out[0] = 1.0
    if n_max >= 1:
        out[1] = x
    for k in range(1, n_max):
        out[k + 1] = ((2 * k + 1) * x * out[k] - k * out[k - 1]) / (k + 1)
    return out


def legendre_deriv_all(n_max: int, x: np.ndarray) -> np.ndarray:
    """Stack [P'_0..P'_{n_max}]."""
    x = np.asarray(x, dtype=np.float64)
    p = legendre_all(n_max, x)
    d = np.zeros_like(p)
    if n_max >= 1:
        d[1] = 1.0
    for k in range(1, n_max):
        d[k + 1] = (2 * k + 1) * p[k] + d[k - 1]
    return d


def jacobi(n: int, a: float, b: float, x: np.ndarray) -> np.ndarray:
    """General Jacobi polynomial P_n^{(a,b)}(x) by recurrence."""
    x = np.asarray(x, dtype=np.float64)
    if n == 0:
        return np.ones_like(x)
    p0 = np.ones_like(x)
    p1 = 0.5 * (a - b + (a + b + 2) * x)
    if n == 1:
        return p1
    for k in range(1, n):
        c = 2 * k + a + b
        a1 = 2 * (k + 1) * (k + a + b + 1) * c
        a2 = (c + 1) * (a * a - b * b)
        a3 = c * (c + 1) * (c + 2)
        a4 = 2 * (k + a) * (k + b) * (c + 2)
        p0, p1 = p1, ((a2 + a3 * x) * p1 - a4 * p0) / a1
    return p1


def jacobi_deriv(n: int, a: float, b: float, x: np.ndarray) -> np.ndarray:
    """d/dx P_n^{(a,b)} = (n+a+b+1)/2 * P_{n-1}^{(a+1,b+1)}."""
    x = np.asarray(x, dtype=np.float64)
    if n == 0:
        return np.zeros_like(x)
    return 0.5 * (n + a + b + 1) * jacobi(n - 1, a + 1, b + 1, x)
