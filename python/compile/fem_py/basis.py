"""The hp-VPINNs test-function basis on the reference element.

1D test function j (j = 1..n_test_1d):

    t_j(x) = P_{j+1}(x) - P_{j-1}(x)

(Legendre; vanishes at x = +-1). 2D test functions are tensor products

    v_{(a,b)}(xi, eta) = t_{a+1}(xi) * t_{b+1}(eta),  a, b = 0..n1d-1

flattened row-major: J = a * n1d + b. This flattening is the contract
shared with rust/src/fem/jacobi.rs.
"""

import numpy as np

from . import jacobi as jac


def test_fn_1d(n1d: int, x: np.ndarray) -> np.ndarray:
    """Values t_1..t_n1d at points x -> shape (n1d, len(x))."""
    x = np.asarray(x, dtype=np.float64)
    p = jac.legendre_all(n1d + 1, x)
    out = np.empty((n1d, x.shape[0]))
    for j in range(1, n1d + 1):
        out[j - 1] = p[j + 1] - p[j - 1]
    return out


def test_grad_1d(n1d: int, x: np.ndarray) -> np.ndarray:
    """Derivatives t'_1..t'_n1d at points x -> shape (n1d, len(x))."""
    x = np.asarray(x, dtype=np.float64)
    d = jac.legendre_deriv_all(n1d + 1, x)
    out = np.empty((n1d, x.shape[0]))
    for j in range(1, n1d + 1):
        out[j - 1] = d[j + 1] - d[j - 1]
    return out


def test_fn_2d(n1d: int, xi: np.ndarray, eta: np.ndarray):
    """Values, d/dxi and d/deta of all n1d^2 test functions at the given
    reference points.

    xi, eta: shape (NQ,). Returns (v, dxi, deta), each (n1d*n1d, NQ).
    """
    txi = test_fn_1d(n1d, xi)       # (n1d, NQ)
    teta = test_fn_1d(n1d, eta)
    dtxi = test_grad_1d(n1d, xi)
    dteta = test_grad_1d(n1d, eta)
    nq = xi.shape[0]
    nt = n1d * n1d
    v = np.empty((nt, nq))
    dxi = np.empty((nt, nq))
    deta = np.empty((nt, nq))
    for a in range(n1d):
        for b in range(n1d):
            j = a * n1d + b
            v[j] = txi[a] * teta[b]
            dxi[j] = dtxi[a] * teta[b]
            deta[j] = txi[a] * dteta[b]
    return v, dxi, deta
