"""Premultiplier tensor assembly — the FastVPINNs data layout (paper SS4.4).

For every element e, test function j and quadrature point q:

    G_x[e,j,q] = w_q * |J_e(q)| * dv_j/dx (x_{e,q})     (actual-domain grad)
    G_y[e,j,q] = w_q * |J_e(q)| * dv_j/dy (x_{e,q})
    V  [e,j,q] = w_q * |J_e(q)| *  v_j    (xi_q, eta_q)
    F  [e,j]   = sum_q w_q * |J_e(q)| * f(x_{e,q}) * v_j

so that, with NN gradients reshaped to (NE, NQ),

    residual[e,j] = sum_q G_x[e,j,q] u_x[e,q] + G_y[e,j,q] u_y[e,q] - F[e,j]
                  ~ int_{K_e} (grad u . grad v_j - f v_j) dK

The Jacobian is evaluated *pointwise* (bilinear map), which is what makes
skewed quads work. Quadrature-point stacking order is element-major:
row e*NQ+q of `quad_xy`. All shapes/orderings are the cross-layer contract
with rust/src/fem/assembly.rs — change both or neither.
"""

import numpy as np

from . import basis, quadrature
from .transforms import BilinearMap


class AssembledDomain:
    """Everything the training step needs, in float64 (cast later)."""

    def __init__(self, quad_xy, gx, gy, v, jdet, quad_ref):
        self.quad_xy = quad_xy  # (NE*NQ, 2)
        self.gx = gx            # (NE, NT, NQ)
        self.gy = gy            # (NE, NT, NQ)
        self.v = v              # (NE, NT, NQ)
        self.jdet = jdet        # (NE, NQ)
        self.quad_ref = quad_ref  # (xi, eta, w) on the reference element

    @property
    def n_elem(self):
        return self.gx.shape[0]

    @property
    def n_test(self):
        return self.gx.shape[1]

    @property
    def n_quad(self):
        return self.gx.shape[2]

    def force_matrix(self, f):
        """F[e,j] = sum_q w|J| f(x_q) v_j(q). `f(x, y)` vectorised."""
        ne, nt, nq = self.gx.shape
        x = self.quad_xy[:, 0].reshape(ne, nq)
        y = self.quad_xy[:, 1].reshape(ne, nq)
        fv = f(x, y)  # (NE, NQ)
        # V already contains w|J|, so F = sum_q V[e,j,q] * f[e,q]
        return np.einsum("ejq,eq->ej", self.v, fv)


def assemble(points, cells, n_test_1d: int, n_quad_1d: int,
             quad_kind: str = "gauss-legendre") -> AssembledDomain:
    """Assemble the FastVPINNs premultiplier tensors for a quad mesh."""
    points = np.asarray(points, dtype=np.float64)
    cells = np.asarray(cells, dtype=np.int64)
    ne = cells.shape[0]
    nt = n_test_1d * n_test_1d
    nq = n_quad_1d * n_quad_1d

    xi, eta, w = quadrature.tensor_rule_2d(n_quad_1d, quad_kind)
    v_ref, dxi_ref, deta_ref = basis.test_fn_2d(n_test_1d, xi, eta)

    quad_xy = np.empty((ne * nq, 2))
    gx = np.empty((ne, nt, nq))
    gy = np.empty((ne, nt, nq))
    vt = np.empty((ne, nt, nq))
    jdet = np.empty((ne, nq))

    for e in range(ne):
        bmap = BilinearMap(points[cells[e]])
        x, y = bmap.map(xi, eta)
        quad_xy[e * nq:(e + 1) * nq, 0] = x
        quad_xy[e * nq:(e + 1) * nq, 1] = y
        j11, j12, j21, j22, det = bmap.jacobian(xi, eta)
        adet = np.abs(det)
        jdet[e] = adet
        wj = w * adet  # (NQ,)
        # actual-domain gradients of every test function at every point
        #   dv/dx = ( j22 * dv/dxi - j21 * dv/deta) / det
        #   dv/dy = (-j12 * dv/dxi + j11 * dv/deta) / det
        dvx = (j22 * dxi_ref - j21 * deta_ref) / det   # (NT, NQ)
        dvy = (-j12 * dxi_ref + j11 * deta_ref) / det
        gx[e] = wj * dvx
        gy[e] = wj * dvy
        vt[e] = wj * v_ref

    return AssembledDomain(quad_xy, gx, gy, vt, jdet, (xi, eta, w))


def boundary_points_unit_square(n_per_side: int):
    """Uniformly spaced boundary samples on the unit square, matching
    rust mesh::QuadMesh::sample_boundary for the generated square meshes
    (corner handling: each side samples n points, corners not repeated)."""
    t = np.linspace(0.0, 1.0, n_per_side, endpoint=False)
    bottom = np.stack([t, np.zeros_like(t)], axis=1)
    right = np.stack([np.ones_like(t), t], axis=1)
    top = np.stack([1.0 - t, np.ones_like(t)], axis=1)
    left = np.stack([np.zeros_like(t), 1.0 - t], axis=1)
    return np.concatenate([bottom, right, top, left], axis=0)
