"""Gauss quadrature rules on [-1, 1] (numpy, float64).

- ``gauss_legendre(n)``: n-point Gauss-Legendre (exact to degree 2n-1),
  Newton iteration from the Chebyshev initial guess.
- ``gauss_lobatto(n)``: n-point Gauss-Lobatto-Legendre (endpoints included,
  exact to degree 2n-3) — the "Gauss-Jacobi-Lobatto" rule the paper uses.
- ``tensor_rule_2d``: tensor product on the reference square [-1,1]^2.
"""

import numpy as np

from . import jacobi as jac


def gauss_legendre(n: int):
    """Return (points, weights), each shape (n,), ascending points."""
    if n < 1:
        raise ValueError("need n >= 1 quadrature points")
    if n == 1:
        return np.zeros(1), np.full(1, 2.0)
    # Chebyshev initial guess, then Newton on P_n.
    k = np.arange(1, n + 1, dtype=np.float64)
    x = -np.cos(np.pi * (k - 0.25) / (n + 0.5))
    for _ in range(100):
        p = jac.legendre(n, x)
        dp = jac.legendre_deriv(n, x)
        dx = p / dp
        x -= dx
        if np.max(np.abs(dx)) < 1e-15:
            break
    dp = jac.legendre_deriv(n, x)
    w = 2.0 / ((1.0 - x * x) * dp * dp)
    return x, w


def gauss_lobatto(n: int):
    """Return (points, weights) of the n-point Gauss-Lobatto-Legendre rule.

    Interior nodes are the roots of P'_{n-1}; weights 2 / (n(n-1) P_{n-1}^2).
    """
    if n < 2:
        raise ValueError("Lobatto rules need n >= 2 points")
    if n == 2:
        return np.array([-1.0, 1.0]), np.array([1.0, 1.0])
    m = n - 1
    # initial guess: Chebyshev-Lobatto interior nodes
    x = -np.cos(np.pi * np.arange(1, m, dtype=np.float64) / m)
    for _ in range(100):
        # Newton on g(x) = P'_m(x); g' via the Legendre ODE:
        # (1-x^2) P''_m = 2x P'_m - m(m+1) P_m  =>
        # P''_m = (2x P'_m - m(m+1) P_m) / (1-x^2)
        p = jac.legendre(m, x)
        dp = jac.legendre_deriv(m, x)
        d2p = (2.0 * x * dp - m * (m + 1) * p) / (1.0 - x * x)
        dx = dp / d2p
        x -= dx
        if np.max(np.abs(dx)) < 1e-15:
            break
    nodes = np.concatenate(([-1.0], x, [1.0]))
    pm = jac.legendre(m, nodes)
    w = 2.0 / (m * (m + 1) * pm * pm)
    return nodes, w


def rule_1d(n: int, kind: str = "gauss-legendre"):
    if kind in ("gauss-legendre", "gl"):
        return gauss_legendre(n)
    if kind in ("gauss-lobatto", "lobatto", "gll"):
        return gauss_lobatto(n)
    raise ValueError(f"unknown quadrature kind: {kind}")


def tensor_rule_2d(n1d: int, kind: str = "gauss-legendre"):
    """Tensor-product rule on [-1,1]^2.

    Returns (xi, eta, w), each shape (n1d*n1d,). Ordering is row-major in
    (i, j) with xi varying slowest: q = i*n1d + j, xi_q = x[i], eta_q = x[j].
    This ordering is the contract shared with rust/src/fem/quadrature.rs.
    """
    x, w = rule_1d(n1d, kind)
    xi = np.repeat(x, n1d)
    eta = np.tile(x, n1d)
    ww = np.repeat(w, n1d) * np.tile(w, n1d)
    return xi, eta, ww
