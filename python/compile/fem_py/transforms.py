"""Bilinear reference->actual element transformation (paper Appendix A.1).

Vertices are given counter-clockwise, matching reference corners
(-1,-1), (1,-1), (1,1), (-1,1):

    x(xi, eta) = xc0 + xc1*xi + xc2*eta + xc3*xi*eta
    y(xi, eta) = yc0 + yc1*xi + yc2*eta + yc3*xi*eta

The Jacobian is *pointwise* (non-constant for skewed quads) — exactly the
property that breaks the original hp-VPINNs implementation and that the
FastVPINNs tensor assembly handles by baking |J(xi_q, eta_q)| into the
premultiplier tensors.
"""

import numpy as np


class BilinearMap:
    """Bilinear map for one quadrilateral. verts: (4,2) array, CCW."""

    def __init__(self, verts):
        v = np.asarray(verts, dtype=np.float64)
        if v.shape != (4, 2):
            raise ValueError("verts must be (4,2)")
        x0, x1, x2, x3 = v[:, 0]
        y0, y1, y2, y3 = v[:, 1]
        self.xc = np.array(
            [
                (x0 + x1 + x2 + x3) / 4.0,
                (-x0 + x1 + x2 - x3) / 4.0,
                (-x0 - x1 + x2 + x3) / 4.0,
                (x0 - x1 + x2 - x3) / 4.0,
            ]
        )
        self.yc = np.array(
            [
                (y0 + y1 + y2 + y3) / 4.0,
                (-y0 + y1 + y2 - y3) / 4.0,
                (-y0 - y1 + y2 + y3) / 4.0,
                (y0 - y1 + y2 - y3) / 4.0,
            ]
        )

    def map(self, xi, eta):
        """Reference (xi, eta) -> actual (x, y). Arrays broadcast."""
        xi = np.asarray(xi, dtype=np.float64)
        eta = np.asarray(eta, dtype=np.float64)
        xc, yc = self.xc, self.yc
        x = xc[0] + xc[1] * xi + xc[2] * eta + xc[3] * xi * eta
        y = yc[0] + yc[1] * xi + yc[2] * eta + yc[3] * xi * eta
        return x, y

    def jacobian(self, xi, eta):
        """Return (j11, j12, j21, j22, det) at (xi, eta).

        j11 = dx/dxi, j12 = dx/deta, j21 = dy/dxi, j22 = dy/deta.
        """
        xi = np.asarray(xi, dtype=np.float64)
        eta = np.asarray(eta, dtype=np.float64)
        xc, yc = self.xc, self.yc
        j11 = xc[1] + xc[3] * eta
        j12 = xc[2] + xc[3] * xi
        j21 = yc[1] + yc[3] * eta
        j22 = yc[2] + yc[3] * xi
        det = j11 * j22 - j12 * j21
        return j11, j12, j21, j22, det

    def grad_to_actual(self, dxi, deta, xi, eta):
        """Transform reference gradients (d/dxi, d/deta) to (d/dx, d/dy).

        [du/dx]   1  [ j22  -j21] [du/dxi ]
        [du/dy] = -  [-j12   j11] [du/deta]
                  D
        """
        j11, j12, j21, j22, det = self.jacobian(xi, eta)
        dx = (j22 * dxi - j21 * deta) / det
        dy = (-j12 * dxi + j11 * deta) / det
        return dx, dy

    def inverse_map(self, x, y, tol=1e-12, max_iter=50):
        """Actual (x, y) -> reference (xi, eta) by Newton iteration."""
        xi = np.zeros_like(np.asarray(x, dtype=np.float64))
        eta = np.zeros_like(xi)
        for _ in range(max_iter):
            fx, fy = self.map(xi, eta)
            rx, ry = fx - x, fy - y
            j11, j12, j21, j22, det = self.jacobian(xi, eta)
            dxi = (j22 * rx - j12 * ry) / det
            deta = (-j21 * rx + j11 * ry) / det
            xi -= dxi
            eta -= deta
            if np.max(np.abs(dxi)) < tol and np.max(np.abs(deta)) < tol:
                break
        return xi, eta
