"""AOT compiler: lower every spec'd train-step/predict fn to HLO text.

Interchange format is HLO *text* (never `.serialize()`): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact gets a JSON manifest (`<name>.json`) describing the exact
ordered input/output signature; the Rust runtime trusts only the
manifest, never positional conventions baked into code.

Usage:
    python -m compile.aot --all [--paper-scale] [--force] [--out-dir D]
    python -m compile.aot --name fv_poisson_ne4_nt5_nq20 [...]
    python -m compile.aot --list
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, specs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side can `to_tuple()` uniformly)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def n_param_arrays(spec: specs.Spec) -> int:
    n = 2 * (len(spec.layers) - 1)
    if spec.loss == "inverse_const":
        n += 1  # trainable eps scalar rides last
    return n


def train_data_inputs(spec: specs.Spec):
    """Ordered (name, shape) for the data segment of a train step."""
    ne, nt, nq, nb, ns = spec.ne, spec.nt, spec.nq, spec.nb, spec.ns
    quad = [("quad_xy", (ne * nq, 2)), ("gx", (ne, nt, nq)),
            ("gy", (ne, nt, nq))]
    vten = [("v", (ne, nt, nq))]
    force = [("f", (ne, nt))]
    bd = [("bd_xy", (nb, 2)), ("bd_u", (nb,))]
    sens = [("sensor_xy", (ns, 2)), ("sensor_u", (ns,))]
    tau = [("tau", ())]
    gamma = [("gamma", ())]
    if spec.loss in ("poisson", "hp_loop"):
        return quad + force + bd + tau
    if spec.loss == "cd":
        return quad + vten + force + bd + tau
    if spec.loss == "inverse_const":
        return quad + force + bd + sens + tau + gamma
    if spec.loss == "inverse_space":
        return quad + vten + force + bd + sens + tau + gamma
    if spec.loss == "pinn":
        return [("coll_xy", (spec.n_coll, 2)), ("f_vals", (spec.n_coll,)),
                ("bd_xy", (nb, 2)), ("bd_u", (nb,)), ("tau", ())]
    raise ValueError(f"unknown loss {spec.loss}")


def signature(spec: specs.Spec):
    """Full ordered (name, shape) input list + output names."""
    if spec.kind == "predict":
        ins = [(f"p{i}", s)
               for i, s in enumerate(model.param_shapes(spec.layers))]
        ins.append(("xy", (spec.n_eval, 2)))
        outs = ["u"] + (["eps"] if spec.heads == 2 else [])
        return ins, outs

    pshapes = list(model.param_shapes(spec.layers))
    if spec.loss == "inverse_const":
        pshapes.append(())  # eps
    ins = []
    for prefix in ("p", "m", "v"):
        ins += [(f"{prefix}{i}", s) for i, s in enumerate(pshapes)]
    ins += [("step", ()), ("lr", ())]
    ins += train_data_inputs(spec)

    outs = [f"p{i}" for i in range(len(pshapes))]
    outs += [f"m{i}" for i in range(len(pshapes))]
    outs += [f"v{i}" for i in range(len(pshapes))]
    outs += ["loss"]
    if spec.loss in ("inverse_const", "inverse_space"):
        outs += ["var_loss", "bd_loss", "sensor_loss"]
    else:
        outs += ["var_loss", "bd_loss"]
    return ins, outs


def build_fn(spec: specs.Spec):
    if spec.kind == "predict":
        return model.make_predict(2 * (len(spec.layers) - 1), spec.heads)
    return model.make_train_step(
        spec.loss, n_param_arrays(spec), kernel=spec.kernel,
        const_kwargs=spec.const)


def lower_spec(spec: specs.Spec) -> str:
    ins, _ = signature(spec)
    args = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in ins]
    fn = build_fn(spec)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def manifest(spec: specs.Spec) -> dict:
    ins, outs = signature(spec)
    return {
        "name": spec.name,
        "kind": spec.kind,
        "loss": spec.loss,
        "inputs": [
            {"name": n, "shape": list(s), "dtype": "f32"} for n, s in ins
        ],
        "outputs": outs,
        "config": {
            "layers": list(spec.layers),
            "ne": spec.ne, "nt1d": spec.nt1d, "nq1d": spec.nq1d,
            "nt": spec.nt, "nq": spec.nq,
            "nb": spec.nb, "ns": spec.ns, "n_coll": spec.n_coll,
            "n_eval": spec.n_eval, "kernel": spec.kernel,
            "heads": spec.heads, "const": spec.const,
            "paper_scale": spec.paper_scale, "note": spec.note,
            "param_order": model.PARAM_ORDER_DOC,
        },
    }


def emit(spec: specs.Spec, out_dir: str, force: bool = False) -> bool:
    hlo_path = os.path.join(out_dir, f"{spec.name}.hlo.txt")
    man_path = os.path.join(out_dir, f"{spec.name}.json")
    if not force and os.path.exists(hlo_path) and os.path.exists(man_path):
        return False
    t0 = time.time()
    text = lower_spec(spec)
    with open(hlo_path + ".tmp", "w") as f:
        f.write(text)
    os.replace(hlo_path + ".tmp", hlo_path)
    with open(man_path, "w") as f:
        json.dump(manifest(spec), f, indent=1)
    print(f"  {spec.name}: {len(text)//1024} KiB in {time.time()-t0:.1f}s",
          flush=True)
    return True


def write_index(all_specs, out_dir):
    idx = {
        "artifacts": [s.name for s in all_specs],
        "format": "hlo-text",
        "generator": "python -m compile.aot",
    }
    with open(os.path.join(out_dir, "index.json"), "w") as f:
        json.dump(idx, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--name", action="append", default=[])
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args(argv)

    all_specs = specs.build_specs(paper_scale=args.paper_scale)
    if args.list:
        for s in all_specs:
            print(f"{s.name:42s} {s.kind:8s} {s.loss:14s} ne={s.ne:<6d} "
                  f"nt={s.nt:<4d} nq={s.nq:<5d} kernel={s.kernel}")
        return 0

    chosen = all_specs
    if args.name:
        byname = {s.name: s for s in all_specs}
        missing = [n for n in args.name if n not in byname]
        if missing:
            print(f"unknown spec(s): {missing}", file=sys.stderr)
            return 1
        chosen = [byname[n] for n in args.name]
    elif not args.all:
        ap.error("pass --all, --name or --list")

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    n_new = 0
    for s in chosen:
        n_new += emit(s, out_dir, force=args.force)
    write_index(all_specs, out_dir)
    print(f"artifacts: {n_new} lowered, {len(chosen)-n_new} cached "
          f"({time.time()-t0:.0f}s) -> {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
