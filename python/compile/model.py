"""L2: the FastVPINNs compute graph in JAX (build-time only).

Everything here exists to be `jax.jit(...).lower(...)`-ed by aot.py into
HLO text that the Rust coordinator executes via PJRT. Nothing in this
module runs on the request path.

Contents
--------
- MLP (tanh hidden layers, linear head) + flat-parameter conventions
  shared with the Rust side (see PARAM_ORDER_DOC),
- batched value+gradient evaluation at quadrature points,
- the FastVPINNs variational losses (Poisson / convection-diffusion /
  inverse-constant-eps / inverse-space-eps) built on the L1 kernel
  (Pallas) or its einsum oracle,
- the PINN collocation baseline (forward-over-reverse Laplacian),
- the loop-based hp-VPINNs baseline (lax.scan over elements — reproduces
  Algorithm 1's O(N_elem) step cost),
- a hand-rolled Adam (optax-free) whose state rides along the step
  signature so the whole optimizer lives inside the artifact.

Loss conventions follow the paper exactly:
  variational_loss = sum_e mean_j residual[e,j]^2          (Alg. 2/3)
  dirichlet_loss   = mean_d (u(x_d) - g(x_d))^2
  total            = variational + tau * dirichlet [+ gamma * sensor]
"""

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels import vpinn_residual as kpallas

PARAM_ORDER_DOC = (
    "params are a flat list [W1, b1, W2, b2, ..., Wh, bh, Wout, bout]; "
    "W: (n_in, n_out) f32, b: (n_out,) f32. Adam m/v mirror this order."
)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def param_shapes(layers):
    """layers e.g. [2, 30, 30, 30, 1] -> list of array shapes (flat)."""
    shapes = []
    for n_in, n_out in zip(layers[:-1], layers[1:]):
        shapes.append((n_in, n_out))
        shapes.append((n_out,))
    return shapes


def mlp_apply(params, x):
    """x: (..., n_in) -> (..., n_out). tanh hidden layers, linear head."""
    n_layers = len(params) // 2
    h = x
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        h = h @ w + b
        if i < n_layers - 1:
            h = jnp.tanh(h)
    return h


def scalar_u(params, xy, head=0):
    """u at a single point xy: (2,) -> scalar (selects output head)."""
    return mlp_apply(params, xy[None, :])[0, head]


def u_and_grad(params, xy, head=0):
    """Batched value and gradient. xy: (N,2) -> u (N,), du (N,2)."""
    def one(p):
        return jax.value_and_grad(lambda q: scalar_u(params, q, head))(p)
    u, du = jax.vmap(one)(xy)
    return u, du


def u_grad_laplacian(params, xy):
    """Batched (u, grad u, laplacian u) for the PINN baseline.

    Laplacian via forward-over-reverse: jvp of grad along each axis.
    """
    def gradf(q):
        return jax.grad(lambda z: scalar_u(params, z))(q)

    def one(q):
        u = scalar_u(params, q)
        g = gradf(q)
        _, hxx = jax.jvp(gradf, (q,), (jnp.array([1.0, 0.0], q.dtype),))
        _, hyy = jax.jvp(gradf, (q,), (jnp.array([0.0, 1.0], q.dtype),))
        return u, g, hxx[0] + hyy[1]

    return jax.vmap(one)(xy)


# --------------------------------------------------------------------------
# Adam (hand-rolled; state is part of the artifact signature)
# --------------------------------------------------------------------------

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def adam_update(params, grads, m, v, step, lr):
    """One Adam step. step is the 1-based iteration count as f32."""
    new_p, new_m, new_v = [], [], []
    b1t = ADAM_B1 ** step
    b2t = ADAM_B2 ** step
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        mhat = mi / (1.0 - b1t)
        vhat = vi / (1.0 - b2t)
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


# --------------------------------------------------------------------------
# Residual dispatch: Pallas kernel vs einsum oracle
# --------------------------------------------------------------------------

def residual_poisson(gx, gy, ux, uy, f, kernel="pallas"):
    if kernel == "pallas":
        return kpallas.vpinn_residual(gx, gy, ux, uy, f)
    return kref.vpinn_residual_ref(gx, gy, ux, uy, f)


def residual_cd(gx, gy, v, ux, uy, f, eps, bx, by, kernel="pallas"):
    if kernel == "pallas":
        return kpallas.vpinn_residual_cd(gx, gy, v, ux, uy, f, eps, bx, by)
    return kref.vpinn_residual_cd_ref(gx, gy, v, ux, uy, f, eps, bx, by)


def residual_space_eps(gx, gy, v, ux, uy, eps_q, f, bx, by, kernel="pallas"):
    if kernel == "pallas":
        return kpallas.vpinn_residual_space_eps(
            gx, gy, v, ux, uy, eps_q, f, bx, by)
    return kref.vpinn_residual_space_eps_ref(
        gx, gy, v, ux, uy, eps_q, f, bx, by)


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------

def _variational(res):
    """sum over elements of mean over test functions of res^2."""
    return jnp.sum(jnp.mean(res * res, axis=1))


def _dirichlet(params, bd_xy, bd_u, head=0):
    pred = mlp_apply(params, bd_xy)[:, head]
    d = pred - bd_u
    return jnp.mean(d * d)


def loss_fastvpinn_poisson(params, quad_xy, gx, gy, f, bd_xy, bd_u, tau,
                           kernel="pallas"):
    ne, nt, nq = gx.shape
    _, du = u_and_grad(params, quad_xy)
    ux = du[:, 0].reshape(ne, nq)
    uy = du[:, 1].reshape(ne, nq)
    res = residual_poisson(gx, gy, ux, uy, f, kernel)
    lv = _variational(res)
    lb = _dirichlet(params, bd_xy, bd_u)
    return lv + tau * lb, (lv, lb)


def loss_fastvpinn_cd(params, quad_xy, gx, gy, v, f, bd_xy, bd_u, tau,
                      eps, bx, by, kernel="pallas"):
    """Forward convection-diffusion (constant eps, b) — the gear problem."""
    ne, nt, nq = gx.shape
    _, du = u_and_grad(params, quad_xy)
    ux = du[:, 0].reshape(ne, nq)
    uy = du[:, 1].reshape(ne, nq)
    res = residual_cd(gx, gy, v, ux, uy, f, eps, bx, by, kernel)
    lv = _variational(res)
    lb = _dirichlet(params, bd_xy, bd_u)
    return lv + tau * lb, (lv, lb)


def loss_inverse_const(params, eps, quad_xy, gx, gy, f, bd_xy, bd_u,
                       sensor_xy, sensor_u, tau, gamma, kernel="pallas"):
    """Inverse problem with a single trainable diffusion scalar eps.

    res[e,j] = eps * (Gx.ux + Gy.uy) - F, plus a sensor-data loss.
    """
    ne, nt, nq = gx.shape
    _, du = u_and_grad(params, quad_xy)
    ux = du[:, 0].reshape(ne, nq)
    uy = du[:, 1].reshape(ne, nq)
    rx = residual_poisson(gx, gy, ux, uy, jnp.zeros_like(f), kernel)
    res = eps * rx - f
    lv = _variational(res)
    lb = _dirichlet(params, bd_xy, bd_u)
    sens = mlp_apply(params, sensor_xy)[:, 0] - sensor_u
    ls = jnp.mean(sens * sens)
    return lv + tau * lb + gamma * ls, (lv, lb, ls)


def loss_inverse_space(params, quad_xy, gx, gy, v, f, bd_xy, bd_u,
                       sensor_xy, sensor_u, tau, gamma, bx, by,
                       kernel="pallas"):
    """Space-dependent eps(x, y): the network has two output heads,
    head 0 = u, head 1 = eps. Sensor data supervises u (paper SS4.7.2)."""
    ne, nt, nq = gx.shape
    _, du = u_and_grad(params, quad_xy, head=0)
    ux = du[:, 0].reshape(ne, nq)
    uy = du[:, 1].reshape(ne, nq)
    eps_q = mlp_apply(params, quad_xy)[:, 1].reshape(ne, nq)
    res = residual_space_eps(gx, gy, v, ux, uy, eps_q, f, bx, by, kernel)
    lv = _variational(res)
    lb = _dirichlet(params, bd_xy, bd_u, head=0)
    sens = mlp_apply(params, sensor_xy)[:, 0] - sensor_u
    ls = jnp.mean(sens * sens)
    return lv + tau * lb + gamma * ls, (lv, lb, ls)


def loss_pinn(params, coll_xy, f_vals, bd_xy, bd_u, tau, eps, bx, by):
    """PINN collocation baseline: -eps*lap(u) + b.grad(u) - f at points."""
    _, g, lap = u_grad_laplacian(params, coll_xy)
    res = -eps * lap + bx * g[:, 0] + by * g[:, 1] - f_vals
    lp = jnp.mean(res * res)
    lb = _dirichlet(params, bd_xy, bd_u)
    return lp + tau * lb, (lp, lb)


def loss_hp_loop(params, quad_xy, gx, gy, f, bd_xy, bd_u, tau):
    """Loop-based hp-VPINNs baseline (paper Algorithm 1 cost model).

    lax.scan over elements: each scan step does its own NN forward +
    gradient over one element's NQ quadrature points and a (NT,NQ)@(NQ,)
    matvec — the per-element sequential structure whose O(N_elem) step
    cost Figs. 2/10b document. Differentiable (scan), unlike fori_loop.
    """
    ne, nt, nq = gx.shape
    pts = quad_xy.reshape(ne, nq, 2)

    def body(carry, xs):
        pt_e, gx_e, gy_e, f_e = xs
        _, du = u_and_grad(params, pt_e)
        rx = gx_e @ du[:, 0]
        ry = gy_e @ du[:, 1]
        res = rx + ry - f_e
        return carry + jnp.mean(res * res), None

    lv, _ = jax.lax.scan(body, jnp.float32(0.0), (pts, gx, gy, f))
    lb = _dirichlet(params, bd_xy, bd_u)
    return lv + tau * lb, (lv, lb)


# --------------------------------------------------------------------------
# Train-step / predict builders (lowered by aot.py)
# --------------------------------------------------------------------------

def _split_state(state, n_arr):
    params = list(state[:n_arr])
    m = list(state[n_arr:2 * n_arr])
    v = list(state[2 * n_arr:3 * n_arr])
    return params, m, v


def make_train_step(loss_kind, n_param_arrays, kernel="pallas",
                    const_kwargs=None):
    """Return step(params.., m.., v.., step, lr, *data) -> flat outputs.

    loss_kind in {poisson, cd, inverse_const, inverse_space, pinn, hp_loop}.
    const_kwargs are baked (static) values like eps/bx/by for forward CD.
    For inverse_const the trainable eps scalar is the LAST params entry
    (n_param_arrays includes it).
    """
    ck = dict(const_kwargs or {})

    def step_fn(*args):
        n = n_param_arrays
        state, rest = list(args[:3 * n]), list(args[3 * n:])
        params, m, v = _split_state(state, n)
        step, lr = rest[0], rest[1]
        data = rest[2:]

        if loss_kind == "poisson":
            quad_xy, gx, gy, f, bd_xy, bd_u, tau = data

            def lf(p):
                return loss_fastvpinn_poisson(
                    p, quad_xy, gx, gy, f, bd_xy, bd_u, tau, kernel)
        elif loss_kind == "cd":
            quad_xy, gx, gy, vt, f, bd_xy, bd_u, tau = data

            def lf(p):
                return loss_fastvpinn_cd(
                    p, quad_xy, gx, gy, vt, f, bd_xy, bd_u, tau,
                    ck["eps"], ck["bx"], ck["by"], kernel)
        elif loss_kind == "inverse_const":
            quad_xy, gx, gy, f, bd_xy, bd_u, sensor_xy, sensor_u, tau, \
                gamma = data

            def lf(p):
                net, eps = p[:-1], p[-1]
                return loss_inverse_const(
                    net, eps, quad_xy, gx, gy, f, bd_xy, bd_u,
                    sensor_xy, sensor_u, tau, gamma, kernel)
        elif loss_kind == "inverse_space":
            quad_xy, gx, gy, vt, f, bd_xy, bd_u, sensor_xy, sensor_u, \
                tau, gamma = data

            def lf(p):
                return loss_inverse_space(
                    p, quad_xy, gx, gy, vt, f, bd_xy, bd_u, sensor_xy,
                    sensor_u, tau, gamma, ck["bx"], ck["by"], kernel)
        elif loss_kind == "pinn":
            coll_xy, f_vals, bd_xy, bd_u, tau = data

            def lf(p):
                return loss_pinn(p, coll_xy, f_vals, bd_xy, bd_u, tau,
                                 ck.get("eps", 1.0), ck.get("bx", 0.0),
                                 ck.get("by", 0.0))
        elif loss_kind == "hp_loop":
            quad_xy, gx, gy, f, bd_xy, bd_u, tau = data

            def lf(p):
                return loss_hp_loop(p, quad_xy, gx, gy, f, bd_xy, bd_u, tau)
        else:
            raise ValueError(f"unknown loss kind {loss_kind}")

        (total, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (total,) + \
            tuple(aux)

    return step_fn


def make_predict(n_param_arrays, n_heads=1):
    """predict(params.., xy) -> (u,) or (u, eps)."""

    def predict_fn(*args):
        params = list(args[:n_param_arrays])
        xy = args[n_param_arrays]
        out = mlp_apply(params, xy)
        return tuple(out[:, h] for h in range(n_heads))

    return predict_fn


def make_predict_with_grad(n_param_arrays):
    """predict(params.., xy) -> (u, ux, uy) — for flux/VTK output."""

    def predict_fn(*args):
        params = list(args[:n_param_arrays])
        xy = args[n_param_arrays]
        u, du = u_and_grad(params, xy)
        return u, du[:, 0], du[:, 1]

    return predict_fn
