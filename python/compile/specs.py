"""Artifact specs: every HLO executable the experiment suite needs.

One spec = one statically-shaped train-step or predict executable. The
Rust side discovers artifacts through their JSON manifests; names here
are the cross-layer contract (rust/src/runtime/manifest.rs).

CI scale vs paper scale: shapes that would make a CPU run impractical
(the 14k-element gear, 80x80-per-element quadrature) have CI-scale
defaults; `aot.py --paper-scale` emits the paper-faithful set on top.
Where a shape differs from the paper it is recorded in the manifest
(`config.paper_scale` / `config.note`) and in EXPERIMENTS.md.
"""

from dataclasses import dataclass, field
from typing import Optional

ARCH_STD = (2, 30, 30, 30, 1)    # paper: 3 hidden layers x 30 neurons
ARCH_GEAR = (2, 50, 50, 50, 1)   # paper SS4.6.4: 3 x 50
ARCH_INV2 = (2, 30, 30, 30, 2)   # two heads: u and eps(x,y)

# Fixed boundary-sample counts (static shapes; Rust samples exactly these)
NB_SQUARE = 1000   # paper SS4.6.3: 1000 Dirichlet points
NB_GEAR_CI = 1536
NB_GEAR_PAPER = 6096
NB_DISK = 512

# gear mesh: outline_points x layers (see rust mesh::generators::gear)
GEAR_CI = dict(ne=1760, nb=NB_GEAR_CI)        # 220 x 8
GEAR_PAPER = dict(ne=14080, nb=NB_GEAR_PAPER)  # 880 x 16 (~ paper's 14192)


@dataclass(frozen=True)
class Spec:
    name: str
    kind: str                  # "train" | "predict"
    loss: str = ""             # train: poisson|cd|inverse_const|...
    layers: tuple = ARCH_STD
    ne: int = 0                # elements
    nt1d: int = 0              # test fns per direction
    nq1d: int = 0              # quad points per direction
    nb: int = 0                # boundary samples
    ns: int = 0                # sensor points (inverse)
    n_coll: int = 0            # collocation points (pinn)
    n_eval: int = 0            # predict points (padded)
    kernel: str = "pallas"     # pallas | einsum
    heads: int = 1
    const: dict = field(default_factory=dict)  # baked eps/bx/by
    paper_scale: bool = False
    note: str = ""

    @property
    def nt(self):
        return self.nt1d * self.nt1d

    @property
    def nq(self):
        return self.nq1d * self.nq1d


# Above this G-tensor size the Pallas interpret path's grid loop (an XLA
# while + dynamic-slice over the full tensor) dominates CPU step time;
# those artifacts use the mathematically identical einsum lowering
# (equality is pytest-enforced). On a real TPU the Pallas kernel is the
# right choice at every size — see EXPERIMENTS.md SSPerf L1.
PALLAS_CPU_MAX_WORDS = 2_000_000


def _fv(name, ne, nt1d, nq1d, nb=NB_SQUARE, kernel=None, loss="poisson",
        layers=ARCH_STD, ns=0, heads=1, const=None, paper_scale=False,
        note=""):
    if kernel is None:
        words = ne * nt1d * nt1d * nq1d * nq1d
        kernel = "pallas" if words <= PALLAS_CPU_MAX_WORDS else "einsum"
    return Spec(name=name, kind="train", loss=loss, layers=layers, ne=ne,
                nt1d=nt1d, nq1d=nq1d, nb=nb, ns=ns, heads=heads,
                kernel=kernel, const=const or {}, paper_scale=paper_scale,
                note=note)


def build_specs(paper_scale: bool = False):
    """Return the deduplicated spec list (CI set; += paper set if asked)."""
    specs = {}

    def add(s: Spec):
        specs.setdefault(s.name, s)

    # ---- quickstart + fig08 (accuracy, omega=2pi) --------------------
    # paper: 2x2 elements, 40x40 quad, 15 test fns per direction
    add(_fv("fv_poisson_ne4_nt15_nq40", 4, 15, 40,
            note="fig08 accuracy, omega=2pi"))
    # CI-friendly quickstart shape
    add(_fv("fv_poisson_ne4_nt5_nq20", 4, 5, 20, note="quickstart"))

    # ---- fig09 / fig17: h-refinement (omega=4pi) ---------------------
    # paper uses 80x80 quad per element; CI uses 20x20 (recorded).
    for ne in (1, 16, 64):
        add(_fv(f"fv_poisson_ne{ne}_nt5_nq20", ne, 5, 20,
                note="fig09 h-refinement (CI quad 20x20; paper 80x80)"))
        if paper_scale:
            add(_fv(f"fv_poisson_ne{ne}_nt5_nq80", ne, 5, 80,
                    paper_scale=True, note="fig09 h-refinement"))

    # ---- fig09 / fig18: p-refinement on one element ------------------
    for nt in (5, 10, 15, 20):
        add(_fv(f"fv_poisson_ne1_nt{nt}_nq30", 1, nt, 30,
                note="fig09 p-refinement (CI quad 30x30; paper 80x80)"))

    # ---- fig11: frequency sweep, total quad fixed at 6400 ------------
    add(_fv("fv_poisson_ne4_nt5_nq40", 4, 5, 40, note="fig11 omega=2pi"))
    add(_fv("fv_poisson_ne16_nt5_nq20", 16, 5, 20, note="fig11 omega=4pi"))
    add(_fv("fv_poisson_ne64_nt5_nq10", 64, 5, 10, note="fig11 omega=8pi"))

    # ---- fig10a/10b + fig02: efficiency sweeps -----------------------
    # (a) 25 quad/elem, 25 test fns, residual points = 25 * ne
    for ne in (16, 64, 256, 400, 1024):
        add(_fv(f"fv_poisson_ne{ne}_nt5_nq5", ne, 5, 5, note="fig10a"))
        add(_fv(f"hp_poisson_ne{ne}_nt5_nq5", ne, 5, 5, loss="hp_loop",
                note="fig10a / fig02a baseline"))
    # (b) total quad fixed at 6400, vary element count
    for ne, nq in ((1, 80), (4, 40), (16, 20), (64, 10), (256, 5), (400, 4)):
        add(_fv(f"fv_poisson_ne{ne}_nt5_nq{nq}", ne, 5, nq, note="fig10b"))
        add(_fv(f"hp_poisson_ne{ne}_nt5_nq{nq}", ne, 5, nq, loss="hp_loop",
                note="fig10b / fig02b baseline"))

    # PINN baselines across residual-point counts (artifact reusable for
    # any omega: forcing values are runtime inputs)
    for nc in (400, 1600, 6400, 10000, 25600):
        add(Spec(name=f"pinn_poisson_nc{nc}", kind="train", loss="pinn",
                 layers=ARCH_STD, n_coll=nc, nb=NB_SQUARE,
                 const={"eps": 1.0, "bx": 0.0, "by": 0.0},
                 note="fig08/10/11 PINN baseline"))

    # ---- fig12: gear convection-diffusion ----------------------------
    g = GEAR_PAPER if paper_scale else GEAR_CI
    add(_fv("fv_cd_gear", g["ne"], 4, 5, nb=g["nb"], loss="cd",
            layers=ARCH_GEAR, kernel="einsum",
            const={"eps": 1.0, "bx": 0.1, "by": 0.0},
            paper_scale=paper_scale,
            note="fig12 gear (einsum kernel: 14k-elem pallas-interpret "
                 "grid loop is impractical on CPU; equality tested)"))

    # ---- fig14: inverse, constant eps --------------------------------
    add(_fv("fv_inverse_const_ne4_nt5_nq40", 4, 5, 40, nb=400, ns=50,
            loss="inverse_const", note="fig14; eps appended to params"))

    # ---- fig15: inverse, space-dependent eps on 1024-cell disk -------
    add(_fv("fv_inverse_space_disk1024", 1024, 4, 5, nb=NB_DISK, ns=500,
            loss="inverse_space", layers=ARCH_INV2, heads=2,
            kernel="einsum", const={"bx": 1.0, "by": 0.0},
            note="fig15 disk inverse"))

    # ---- fig16: hyperparameter timing sweeps -------------------------
    for nt in (5, 10, 20):
        for nq in (10, 20, 40):
            add(_fv(f"fv_poisson_ne1_nt{nt}_nq{nq}", 1, nt, nq,
                    note="fig16a"))
    for nt in (5, 10, 20):
        for ne in (4, 64, 400):
            add(_fv(f"fv_poisson_ne{ne}_nt{nt}_nq10", ne, nt, 10,
                    note="fig16b"))
    for nq in (5, 10, 20):
        for ne in (4, 64, 400):
            add(_fv(f"fv_poisson_ne{ne}_nt10_nq{nq}", ne, 10, nq,
                    note="fig16c"))

    # ---- predict executables ------------------------------------------
    for name, layers, heads, n_eval in (
        ("predict_std_16k", ARCH_STD, 1, 16384),
        ("predict_gear_16k", ARCH_GEAR, 1, 16384),
        ("predict_inv2_16k", ARCH_INV2, 2, 16384),
        # table1 prediction-time ladder
        ("predict_std_65k", ARCH_STD, 1, 65536),
        ("predict_std_262k", ARCH_STD, 1, 262144),
        ("predict_std_1m", ARCH_STD, 1, 1048576),
    ):
        add(Spec(name=name, kind="predict", layers=layers, heads=heads,
                 n_eval=n_eval, note="table1/eval" ))

    return list(specs.values())


def spec_by_name(name: str, paper_scale: bool = True) -> Optional[Spec]:
    for s in build_specs(paper_scale=paper_scale):
        if s.name == name:
            return s
    return None
