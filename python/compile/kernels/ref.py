"""Pure-jnp oracle for the FastVPINNs residual contraction (L1 kernel).

This is the paper's Algorithm 3 written as one einsum per direction:

    residual[e, j] = sum_q Gx[e,j,q] * ux[e,q]
                   + sum_q Gy[e,j,q] * uy[e,q]  -  F[e,j]

and its convection / variable-diffusion generalisation. The Pallas kernel
in vpinn_residual.py must match these (tests use fp32 allclose; the
contraction order within a block may differ).
"""

import jax.numpy as jnp


def vpinn_residual_ref(gx, gy, ux, uy, f):
    """Poisson residual. gx,gy: (NE,NT,NQ); ux,uy: (NE,NQ); f: (NE,NT)."""
    rx = jnp.einsum("ejq,eq->ej", gx, ux)
    ry = jnp.einsum("ejq,eq->ej", gy, uy)
    return rx + ry - f


def vpinn_residual_cd_ref(gx, gy, v, ux, uy, f, eps, bx, by):
    """Constant-coefficient convection-diffusion residual:

        res[e,j] = eps * (Gx.ux + Gy.uy)[e,j]
                 + (V . (bx*ux + by*uy))[e,j] - F[e,j]
    """
    rx = jnp.einsum("ejq,eq->ej", gx, ux)
    ry = jnp.einsum("ejq,eq->ej", gy, uy)
    conv = jnp.einsum("ejq,eq->ej", v, bx * ux + by * uy)
    return eps * (rx + ry) + conv - f


def vpinn_residual_space_eps_ref(gx, gy, v, ux, uy, eps_q, f, bx, by):
    """Space-dependent diffusion residual (paper SS4.7.2):

        res[e,j] = Gx.(eps_q*ux) + Gy.(eps_q*uy) + V.(b . grad u) - F

    eps_q: (NE, NQ) — diffusion parameter at the quadrature points
    (second NN output head in the inverse problem).
    """
    rx = jnp.einsum("ejq,eq->ej", gx, eps_q * ux)
    ry = jnp.einsum("ejq,eq->ej", gy, eps_q * uy)
    conv = jnp.einsum("ejq,eq->ej", v, bx * ux + by * uy)
    return rx + ry + conv - f
