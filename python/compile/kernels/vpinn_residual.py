"""L1 Pallas kernels: the FastVPINNs batched residual contraction.

Forward kernel (paper Algorithm 3), for a block of BE elements at a time:

    res[e, j] = sum_q Gx[e,j,q]*ux[e,q] + sum_q Gy[e,j,q]*uy[e,q] - F[e,j]

i.e. two batched GEMVs (batch dim = element, contracting dim = quadrature
point) fused with the force-matrix subtraction. Convection and
space-dependent-diffusion variants add the V-tensor term and the eps_q
scaling *inside* the same block, so G/V tiles are read from HBM exactly
once per step.

Backward kernel: `pallas_call` has no built-in reverse-mode rule, so each
variant carries a `jax.custom_vjp` whose cotangent needs the *transposed*
contraction

    dux[e, q] = sum_j G[e,j,q] * dres[e,j]

which is the second Pallas kernel here (`_contract_t`). G/V are
step-invariant premultiplier tensors — their cotangents are returned as
symbolic zeros and DCE'd by XLA.

TPU mapping (see DESIGN.md SSHardware-Adaptation): the element dimension is
gridded; per-block VMEM working set is

    BE * NQ * 4B * (n_tensors*NT + n_vecs) + BE*NT*4B

and BE is chosen as the largest divisor of NE that keeps this under
~4 MiB. The contraction (dot_general over q) is the MXU-shaped op; the
paper's own Fig. 16 shows N_quad dominates step cost, which is exactly the
contracting dimension here.

CPU PJRT cannot run Mosaic custom-calls, so `interpret=True` is mandatory
in this environment; correctness versus kernels/ref.py is enforced by
python/tests/test_kernel.py (hypothesis shape sweeps, fwd + grad).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# ~4 MiB of f32s
_VMEM_BUDGET_WORDS = 1 << 20

# forward contraction: batch e, contract q of (BE,NT,NQ) x (BE,NQ)
_DN_FWD = (((2,), (1,)), ((0,), (0,)))
# transposed contraction: batch e, contract j of (BE,NT,NQ) x (BE,NT)
_DN_BWD = (((1,), (1,)), ((0,), (0,)))


def pick_block_elems(ne: int, nt: int, nq: int, n_tensors: int = 2,
                     n_vecs: int = 2) -> int:
    """Largest divisor of NE whose per-block working set fits the VMEM
    budget. Always >= 1."""
    per_elem = nq * (n_tensors * nt + n_vecs) + nt
    cap = max(1, _VMEM_BUDGET_WORDS // max(per_elem, 1))
    best = 1
    d = 1
    while d * d <= ne:
        if ne % d == 0:
            for cand in (d, ne // d):
                if cand <= cap and cand > best:
                    best = cand
        d += 1
    return best


def _t3_spec(be, nt, nq):
    return pl.BlockSpec((be, nt, nq), lambda i: (i, 0, 0))


def _m2_spec(be, n):
    return pl.BlockSpec((be, n), lambda i: (i, 0))


# --------------------------------------------------------------------------
# Transposed contraction kernel (shared backward primitive)
# --------------------------------------------------------------------------

def _contract_t_kernel(g_ref, r_ref, o_ref):
    g = g_ref[...]            # (BE, NT, NQ)
    r = r_ref[...]            # (BE, NT)
    o_ref[...] = jax.lax.dot_general(
        g, r, _DN_BWD, preferred_element_type=jnp.float32)  # (BE, NQ)


def contract_t(g, r, *, interpret=True, block_elems=None):
    """dux[e,q] = sum_j g[e,j,q] * r[e,j]. g: (NE,NT,NQ), r: (NE,NT)."""
    ne, nt, nq = g.shape
    be = block_elems or pick_block_elems(ne, nt, nq, n_tensors=1, n_vecs=1)
    return pl.pallas_call(
        _contract_t_kernel,
        grid=(ne // be,),
        in_specs=[_t3_spec(be, nt, nq), _m2_spec(be, nt)],
        out_specs=_m2_spec(be, nq),
        out_shape=jax.ShapeDtypeStruct((ne, nq), jnp.float32),
        interpret=interpret,
    )(g, r)


# --------------------------------------------------------------------------
# Forward kernels
# --------------------------------------------------------------------------

def _poisson_kernel(gx_ref, gy_ref, ux_ref, uy_ref, f_ref, o_ref):
    rx = jax.lax.dot_general(gx_ref[...], ux_ref[...], _DN_FWD,
                             preferred_element_type=jnp.float32)
    ry = jax.lax.dot_general(gy_ref[...], uy_ref[...], _DN_FWD,
                             preferred_element_type=jnp.float32)
    o_ref[...] = rx + ry - f_ref[...]


def _poisson_fwd_raw(gx, gy, ux, uy, f, interpret=True, block_elems=None):
    ne, nt, nq = gx.shape
    be = block_elems or pick_block_elems(ne, nt, nq)
    return pl.pallas_call(
        _poisson_kernel,
        grid=(ne // be,),
        in_specs=[_t3_spec(be, nt, nq), _t3_spec(be, nt, nq),
                  _m2_spec(be, nq), _m2_spec(be, nq), _m2_spec(be, nt)],
        out_specs=_m2_spec(be, nt),
        out_shape=jax.ShapeDtypeStruct((ne, nt), jnp.float32),
        interpret=interpret,
    )(gx, gy, ux, uy, f)


@jax.custom_vjp
def vpinn_residual(gx, gy, ux, uy, f):
    """Poisson residual, Pallas. Shapes as in ref.vpinn_residual_ref."""
    return _poisson_fwd_raw(gx, gy, ux, uy, f)


def _poisson_vjp_fwd(gx, gy, ux, uy, f):
    return _poisson_fwd_raw(gx, gy, ux, uy, f), (gx, gy)


def _poisson_vjp_bwd(saved, dres):
    gx, gy = saved
    dux = contract_t(gx, dres)
    duy = contract_t(gy, dres)
    zeros = jnp.zeros_like(gx)
    return zeros, jnp.zeros_like(gy), dux, duy, -dres


vpinn_residual.defvjp(_poisson_vjp_fwd, _poisson_vjp_bwd)


def _make_cd_kernel(eps, bx, by):
    def kern(gx_ref, gy_ref, v_ref, ux_ref, uy_ref, f_ref, o_ref):
        ux = ux_ref[...]
        uy = uy_ref[...]
        rx = jax.lax.dot_general(gx_ref[...], ux, _DN_FWD,
                                 preferred_element_type=jnp.float32)
        ry = jax.lax.dot_general(gy_ref[...], uy, _DN_FWD,
                                 preferred_element_type=jnp.float32)
        conv = jax.lax.dot_general(v_ref[...], bx * ux + by * uy, _DN_FWD,
                                   preferred_element_type=jnp.float32)
        o_ref[...] = eps * (rx + ry) + conv - f_ref[...]
    return kern


def _cd_fwd_raw(gx, gy, v, ux, uy, f, eps, bx, by, interpret=True,
                block_elems=None):
    ne, nt, nq = gx.shape
    be = block_elems or pick_block_elems(ne, nt, nq, n_tensors=3)
    return pl.pallas_call(
        _make_cd_kernel(eps, bx, by),
        grid=(ne // be,),
        in_specs=[_t3_spec(be, nt, nq)] * 3 +
                 [_m2_spec(be, nq), _m2_spec(be, nq), _m2_spec(be, nt)],
        out_specs=_m2_spec(be, nt),
        out_shape=jax.ShapeDtypeStruct((ne, nt), jnp.float32),
        interpret=interpret,
    )(gx, gy, v, ux, uy, f)


def make_vpinn_residual_cd(eps, bx, by):
    """Constant-coefficient CD residual with (eps, bx, by) baked static."""

    @jax.custom_vjp
    def residual(gx, gy, v, ux, uy, f):
        return _cd_fwd_raw(gx, gy, v, ux, uy, f, eps, bx, by)

    def fwd(gx, gy, v, ux, uy, f):
        return residual(gx, gy, v, ux, uy, f), (gx, gy, v)

    def bwd(saved, dres):
        gx, gy, v = saved
        gxr = contract_t(gx, dres)
        gyr = contract_t(gy, dres)
        vr = contract_t(v, dres)
        dux = eps * gxr + bx * vr
        duy = eps * gyr + by * vr
        z = jnp.zeros_like(gx)
        return z, jnp.zeros_like(gy), jnp.zeros_like(v), dux, duy, -dres

    residual.defvjp(fwd, bwd)
    return residual


def vpinn_residual_cd(gx, gy, v, ux, uy, f, eps, bx, by):
    """Convenience wrapper: eps/bx/by must be static python floats."""
    return make_vpinn_residual_cd(float(eps), float(bx), float(by))(
        gx, gy, v, ux, uy, f)


def _make_space_eps_kernel(bx, by):
    def kern(gx_ref, gy_ref, v_ref, ux_ref, uy_ref, eps_ref, f_ref, o_ref):
        eps_q = eps_ref[...]
        ux = ux_ref[...]
        uy = uy_ref[...]
        rx = jax.lax.dot_general(gx_ref[...], eps_q * ux, _DN_FWD,
                                 preferred_element_type=jnp.float32)
        ry = jax.lax.dot_general(gy_ref[...], eps_q * uy, _DN_FWD,
                                 preferred_element_type=jnp.float32)
        conv = jax.lax.dot_general(v_ref[...], bx * ux + by * uy, _DN_FWD,
                                   preferred_element_type=jnp.float32)
        o_ref[...] = rx + ry + conv - f_ref[...]
    return kern


def _space_fwd_raw(gx, gy, v, ux, uy, eps_q, f, bx, by, interpret=True,
                   block_elems=None):
    ne, nt, nq = gx.shape
    be = block_elems or pick_block_elems(ne, nt, nq, n_tensors=3, n_vecs=3)
    return pl.pallas_call(
        _make_space_eps_kernel(bx, by),
        grid=(ne // be,),
        in_specs=[_t3_spec(be, nt, nq)] * 3 +
                 [_m2_spec(be, nq)] * 3 + [_m2_spec(be, nt)],
        out_specs=_m2_spec(be, nt),
        out_shape=jax.ShapeDtypeStruct((ne, nt), jnp.float32),
        interpret=interpret,
    )(gx, gy, v, ux, uy, eps_q, f)


def make_vpinn_residual_space_eps(bx, by):
    """Space-dependent-diffusion residual with (bx, by) baked static.

    Differentiable in ux, uy AND eps_q (the second network head)."""

    @jax.custom_vjp
    def residual(gx, gy, v, ux, uy, eps_q, f):
        return _space_fwd_raw(gx, gy, v, ux, uy, eps_q, f, bx, by)

    def fwd(gx, gy, v, ux, uy, eps_q, f):
        return residual(gx, gy, v, ux, uy, eps_q, f), \
            (gx, gy, v, ux, uy, eps_q)

    def bwd(saved, dres):
        gx, gy, v, ux, uy, eps_q = saved
        gxr = contract_t(gx, dres)
        gyr = contract_t(gy, dres)
        vr = contract_t(v, dres)
        dux = eps_q * gxr + bx * vr
        duy = eps_q * gyr + by * vr
        deps = ux * gxr + uy * gyr
        z = jnp.zeros_like(gx)
        return z, jnp.zeros_like(gy), jnp.zeros_like(v), dux, duy, deps, \
            -dres

    residual.defvjp(fwd, bwd)
    return residual


def vpinn_residual_space_eps(gx, gy, v, ux, uy, eps_q, f, bx, by):
    """Convenience wrapper: bx/by must be static python floats."""
    return make_vpinn_residual_space_eps(float(bx), float(by))(
        gx, gy, v, ux, uy, eps_q, f)


def vmem_footprint_bytes(ne, nt, nq, n_tensors=2, n_vecs=2,
                         block_elems=None):
    """Analytic VMEM model used by DESIGN.md SSPerf (bytes per block)."""
    be = block_elems or pick_block_elems(ne, nt, nq, n_tensors, n_vecs)
    words = be * nq * (n_tensors * nt + n_vecs) + be * nt
    return 4 * words, be
