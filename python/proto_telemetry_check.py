"""Reference second implementation of the version-1 telemetry JSONL
schema (rust/src/telemetry/mod.rs), used as a strict producer-
conformance validator: CI runs it against the stream a real
`train --metrics-out` run wrote. Unlike `repro report` (a tolerant
reader that must ignore unknown tags), this checker rejects anything
the documented producer does not emit — any divergence between this
file and the Rust writer means the *documentation* drifted, which is
exactly what it exists to catch (no Rust toolchain in this container).

Run: python proto_telemetry_check.py STREAM.jsonl [MORE.jsonl ...]
     python proto_telemetry_check.py            (built-in self-test)
"""

import json
import math
import sys

SCHEMA_VERSION = 1

# tag -> {field: type-spec}; every line also carries "v", "ev" and
# (except flush) "t_ms". Type specs: "int", "num" (finite float),
# "num?" (finite float or null), "str", "bool".
TAGS = {
    "step": {
        "step": "int", "wall_ms": "num",
        "assign_ms": "num?", "step_ms": "num?",
        "reduce_ms": "num?", "sync_ms": "num?",
        "loss": "num?", "grad_norm": "num?", "lr": "num",
    },
    "recovery": {
        "at_step": "int", "rollback_to": "int",
        "reason": "str", "lr_scale": "num",
    },
    "checkpoint": {
        "step": "int", "path": "str", "bytes": "int", "write_ms": "num",
    },
    "kernel": {"kernel": "str", "degraded": "bool", "reason": "str"},
    "queue": {"queued": "int", "hwm": "int"},
    "batch": {"len": "int", "max": "int"},
    "flush": {"dropped": "int"},
}
PHASES = ("assign_ms", "step_ms", "reduce_ms", "sync_ms")


def _is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def _check_type(field, v, spec):
    if spec == "num?" and v is None:
        return
    if spec in ("num", "num?"):
        assert _is_num(v), f"{field}: finite number expected, got {v!r}"
    elif spec == "int":
        assert _is_num(v) and float(v).is_integer() and v >= 0, \
            f"{field}: non-negative integer expected, got {v!r}"
    elif spec == "str":
        assert isinstance(v, str) and v, \
            f"{field}: non-empty string expected, got {v!r}"
    elif spec == "bool":
        assert isinstance(v, bool), f"{field}: bool expected, got {v!r}"


def check_stream(lines):
    """Validate one stream (iterable of raw lines). Returns a
    tag -> count dict; raises AssertionError with a line-numbered
    message on the first violation."""
    counts = {}
    last_t = -1.0
    next_step = None  # expected id of the next step event
    saw_flush_at = None
    n = 0
    for n, raw in enumerate(lines, start=1):
        raw = raw.strip()
        if not raw:
            continue
        try:
            ev = json.loads(raw)
        except ValueError as e:
            raise AssertionError(f"line {n}: unparseable JSON ({e})")
        try:
            assert isinstance(ev, dict), "line is not an object"
            assert ev.get("v") == SCHEMA_VERSION, \
                f"unknown schema version {ev.get('v')!r}"
            tag = ev.get("ev")
            assert tag in TAGS, f"unknown event tag {tag!r}"
            assert saw_flush_at is None, \
                f"event after the flush line (line {saw_flush_at})"
            fields = TAGS[tag]
            want = {"v", "ev"} | set(fields)
            if tag != "flush":
                want.add("t_ms")
                t = ev.get("t_ms")
                assert _is_num(t) and t >= 0.0, f"bad t_ms {t!r}"
                assert t >= last_t, \
                    f"t_ms went backwards ({t} < {last_t})"
                last_t = t
            assert set(ev) == want, \
                f"field set mismatch: got {sorted(ev)}, " \
                f"want {sorted(want)}"
            for field, spec in fields.items():
                _check_type(field, ev[field], spec)
            if tag == "step":
                nulls = [ev[p] is None for p in PHASES]
                assert all(nulls) or not any(nulls), \
                    "phase fields must be all-null or all-present"
                if not any(nulls):
                    s = sum(ev[p] for p in PHASES)
                    w = ev["wall_ms"]
                    assert s <= w * (1.0 + 1e-9) + 1e-6, \
                        f"phase sum {s} ms exceeds step wall {w} ms"
                if next_step is not None:
                    assert ev["step"] == next_step, \
                        f"step id {ev['step']} is not contiguous " \
                        f"(expected {next_step})"
                next_step = ev["step"] + 1
            elif tag == "recovery":
                assert ev["rollback_to"] < ev["at_step"], \
                    "rollback_to must precede at_step"
                # training resumes from the rollback point
                next_step = ev["rollback_to"] + 1
            elif tag == "batch":
                assert 1 <= ev["len"] <= ev["max"], \
                    f"batch len {ev['len']} outside [1, {ev['max']}]"
            elif tag == "flush":
                saw_flush_at = n
            counts[tag] = counts.get(tag, 0) + 1
        except AssertionError as e:
            raise AssertionError(f"line {n}: {e}")
    assert n > 0 and counts, "empty stream"
    if saw_flush_at is None:
        print("  warning: no flush line — the producer did not shut "
              "down cleanly (killed run?)", file=sys.stderr)
    return counts


def _self_test():
    good = [
        '{"v":1,"ev":"kernel","t_ms":0.01,"kernel":"avx2",'
        '"degraded":false,"reason":"arm"}',
        '{"v":1,"ev":"step","t_ms":1.5,"step":1,"wall_ms":2.0,'
        '"assign_ms":0.1,"step_ms":1.2,"reduce_ms":0.3,"sync_ms":0.2,'
        '"loss":0.5,"grad_norm":1.25,"lr":0.01}',
        '{"v":1,"ev":"step","t_ms":3.0,"step":2,"wall_ms":2.0,'
        '"assign_ms":null,"step_ms":null,"reduce_ms":null,'
        '"sync_ms":null,"loss":null,"grad_norm":null,"lr":0.01}',
        '{"v":1,"ev":"recovery","t_ms":3.5,"at_step":2,'
        '"rollback_to":1,"reason":"nan_grad","lr_scale":0.5}',
        '{"v":1,"ev":"step","t_ms":4.0,"step":2,"wall_ms":1.0,'
        '"assign_ms":0.1,"step_ms":0.5,"reduce_ms":0.2,"sync_ms":0.1,'
        '"loss":0.4,"grad_norm":1.0,"lr":0.005}',
        '{"v":1,"ev":"checkpoint","t_ms":5.0,"step":2,'
        '"path":"ring/a.ckpt","bytes":4096,"write_ms":0.8}',
        '{"v":1,"ev":"queue","t_ms":6.0,"queued":3,"hwm":7}',
        '{"v":1,"ev":"batch","t_ms":6.1,"len":3,"max":8}',
        '{"v":1,"ev":"flush","dropped":0}',
    ]
    counts = check_stream(good)
    assert counts == {"kernel": 1, "step": 3, "recovery": 1,
                      "checkpoint": 1, "queue": 1, "batch": 1,
                      "flush": 1}, counts

    bad_cases = [
        # wrong schema version
        ['{"v":2,"ev":"flush","dropped":0}'],
        # unknown tag
        ['{"v":1,"ev":"mystery","t_ms":1.0}'],
        # missing required field (no lr)
        ['{"v":1,"ev":"step","t_ms":1.0,"step":1,"wall_ms":1.0,'
         '"assign_ms":null,"step_ms":null,"reduce_ms":null,'
         '"sync_ms":null,"loss":0.5,"grad_norm":1.0}'],
        # unexpected extra field
        ['{"v":1,"ev":"flush","dropped":0,"extra":1}'],
        # NaN-as-string instead of null
        ['{"v":1,"ev":"step","t_ms":1.0,"step":1,"wall_ms":1.0,'
         '"assign_ms":null,"step_ms":null,"reduce_ms":null,'
         '"sync_ms":null,"loss":"NaN","grad_norm":null,"lr":0.01}'],
        # mixed null / non-null phase fields
        ['{"v":1,"ev":"step","t_ms":1.0,"step":1,"wall_ms":1.0,'
         '"assign_ms":0.1,"step_ms":null,"reduce_ms":null,'
         '"sync_ms":null,"loss":0.5,"grad_norm":1.0,"lr":0.01}'],
        # phase sum exceeds the step wall
        ['{"v":1,"ev":"step","t_ms":1.0,"step":1,"wall_ms":1.0,'
         '"assign_ms":0.5,"step_ms":0.5,"reduce_ms":0.5,'
         '"sync_ms":0.5,"loss":0.5,"grad_norm":1.0,"lr":0.01}'],
        # non-contiguous step ids without a recovery in between
        [good[1], good[1].replace('"step":1', '"step":3')
                         .replace('"t_ms":1.5', '"t_ms":2.5')],
        # t_ms goes backwards
        [good[1], good[2].replace('"t_ms":3.0', '"t_ms":1.0')],
        # an event after the flush line
        ['{"v":1,"ev":"flush","dropped":0}', good[0]],
        # torn (truncated) line
        [good[1][: len(good[1]) // 2]],
    ]
    for i, case in enumerate(bad_cases):
        try:
            check_stream(case)
        except AssertionError:
            pass
        else:
            raise SystemExit(f"self-test: bad case {i} not caught")
    print("proto_telemetry_check OK: self-test passed "
          f"({len(good)}-line stream accepted, "
          f"{len(bad_cases)} malformed streams rejected)")


def main(argv):
    if not argv:
        _self_test()
        return
    for path in argv:
        try:
            with open(path) as fh:
                counts = check_stream(fh)
        except AssertionError as e:
            raise SystemExit(f"proto_telemetry_check FAIL: {path}: {e}")
        total = sum(counts.values())
        detail = ", ".join(f"{k} x{v}" for k, v in sorted(counts.items()))
        print(f"proto_telemetry_check OK: {path}: {total} events "
              f"({detail})")


if __name__ == "__main__":
    main(sys.argv[1:])
