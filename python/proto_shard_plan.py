"""Prototype of the coordinator plane's shard plan + tree reduce.

Transliterates `coordinator/shard.rs` (greedy cost-aware shard plan,
`n_pairs`/`pair` tree-reduce schedule) into pure python and checks the
two claims the Rust side's determinism argument rests on:

1. The shard plan is a pure function of `(ne, nq, block_elems)` —
   never of the worker count — and always produces contiguous,
   block-aligned shards, none of which exceeds the ideal mean weight
   by a full block's weight (the greedy can overshoot its running
   target by at most one block minus one point).
2. The pairwise tree reduce has a *fixed* structure per shard count:
   every level's pairs are disjoint, every shard folds into index 0
   exactly once, and — the load-bearing part — the floating-point
   result is bit-identical no matter which worker executes which pair
   or in what order pairs within a level complete, because the
   *pairing* (who adds with whom, and in which argument position) is
   a function of (n_shards, stride, k) alone.

Run: python3 python/proto_shard_plan.py  (pure python, no numpy
needed; uses `struct` for bit-level f64 comparison).
"""

import random
import struct

MAX_SHARDS = 64


# ---- shard.rs transliteration ------------------------------------------


def build_plan(ne, nq, block_elems):
    """Greedy cost-aware plan: element-block granularity, weights in
    quadrature points, front-loaded remainders (shard.rs ShardPlan)."""
    be = max(block_elems, 1)
    n_blocks = (ne + be - 1) // be
    n_shards = min(n_blocks, MAX_SHARDS)
    if n_shards == 0:
        return []
    weight_of = lambda b: (min((b + 1) * be, ne) - b * be) * nq
    remaining = sum(weight_of(b) for b in range(n_blocks))
    shards, b = [], 0
    for s in range(n_shards):
        left = n_shards - s
        target = (remaining + left - 1) // left  # div_ceil
        max_b = n_blocks - (left - 1)
        lo, w = b, 0
        while b < max_b and w < target:
            w += weight_of(b)
            b += 1
        shards.append((lo * be, min(b * be, ne), w))
        remaining -= w
    return shards


def n_pairs(n, stride):
    """Pairs at one reduce level (shard.rs::n_pairs)."""
    if n <= stride:
        return 0
    return (n - 1 - stride) // (2 * stride) + 1


def pair(stride, k):
    """k-th pair at a level: (dst, src) shard indices."""
    return (2 * stride * k, 2 * stride * k + stride)


# ---- claim 1: plan invariants ------------------------------------------


def check_plan_invariants():
    cases = 0
    for ne in [0, 1, 2, 3, 5, 9, 64, 65, 100, 1000, 4096, 100_000]:
        for be in [1, 2, 7, 28, 256]:
            for nq in [1, 9, 100]:
                shards = build_plan(ne, nq, be)
                n_blocks = (ne + be - 1) // be
                assert len(shards) == min(n_blocks, MAX_SHARDS), (
                    ne, be, nq)
                # contiguous cover, block-aligned interior bounds
                pos = 0
                for lo, hi, w in shards:
                    assert lo == pos and hi > lo, (ne, be, nq, shards)
                    assert lo % be == 0, (ne, be, nq, shards)
                    pos = hi
                if shards:
                    assert pos == ne
                # weights: exact cover + bounded imbalance. The greedy
                # stops a shard once it reaches its running target, so
                # no shard exceeds the ideal mean by more than one
                # block's weight minus one point (min-side imbalance is
                # unbounded by design: the tail shard takes what's
                # left).
                assert sum(w for _, _, w in shards) == ne * nq
                if shards:
                    ideal = -(-(ne * nq) // len(shards))  # div_ceil
                    assert max(w for _, _, w in shards) \
                        <= ideal + be * nq - 1, (ne, be, nq, shards)
                cases += 1
    # the ragged-tail fixture the Rust unit test pins: ne=9, be=2,
    # nq=4 -> 5 blocks over 5 shards, weights front-loaded 8,8,8,8,4
    assert [w for _, _, w in build_plan(9, 4, 2)] == [8, 8, 8, 8, 4]
    print(f"plan invariants hold over {cases} (ne, be, nq) shapes")


# ---- claim 2: tree reduce is schedule-independent ----------------------


def levels(n):
    """The full reduce schedule for n shards: list of per-level pair
    lists, exactly as the Reduce phase walks them."""
    out, stride = [], 1
    while stride < n:
        out.append([pair(stride, k) for k in range(n_pairs(n, stride))])
        stride *= 2
    return out


def check_tree_structure():
    for n in range(1, 200):
        seen = set()
        for lvl in levels(n):
            touched = set()
            for dst, src in lvl:
                # pairs within a level are disjoint (workers may run
                # them concurrently and in any order)
                assert dst not in touched and src not in touched, (
                    n, lvl)
                touched |= {dst, src}
                assert src < n and dst < n
                assert src not in seen, (n, src)
                seen.add(src)  # src is consumed exactly once
        # every shard except the root folded in exactly once
        assert seen == set(range(1, n)), n
    print("tree structure: every shard folds into the root exactly "
          "once, disjoint within levels, for n in 1..=199")


def f64_bits(x):
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def tree_reduce(vals, order_rng=None):
    """Run the schedule; optionally shuffle pair completion order
    within each level (simulating arbitrary worker interleaving)."""
    v = list(vals)
    for lvl in levels(len(v)):
        lvl = list(lvl)
        if order_rng is not None:
            order_rng.shuffle(lvl)
        for dst, src in lvl:
            v[dst] = v[dst] + v[src]
    return v[0] if v else 0.0


def check_bitwise_schedule_independence():
    rng = random.Random(29)
    for n in [1, 2, 3, 5, 17, 33, 64]:
        # adversarial magnitudes: fp addition here is NOT associative,
        # so only a fixed pairing structure keeps the bits stable
        vals = [rng.uniform(-1, 1) * 10.0 ** rng.randint(-12, 12)
                for _ in range(n)]
        ref = tree_reduce(vals)
        for trial in range(50):
            got = tree_reduce(vals, order_rng=random.Random(trial))
            assert f64_bits(got) == f64_bits(ref), (n, trial)
        # and a *sequential* left fold generally disagrees in the last
        # bits (sanity: the test above is not vacuous)
    print("tree reduce: bit-identical under 50 shuffled worker "
          "interleavings per shard count (n in {1,2,3,5,17,33,64})")


def check_worker_count_independence():
    """The claim end to end: simulate the Step phase's atomic-cursor
    claiming with w workers writing per-shard partials, then the fixed
    tree reduce — the final f64 bits must not depend on w."""
    rng = random.Random(7)
    for ne, be, nq in [(9, 2, 4), (64, 7, 9), (4096, 28, 25)]:
        shards = build_plan(ne, nq, be)
        # per-element contributions (what element_range accumulates)
        elem = [rng.uniform(-1, 1) * 10.0 ** rng.randint(-8, 8)
                for _ in range(ne)]
        results = []
        for w in [1, 2, 3, 8]:
            # shard partials are per-shard regardless of which worker
            # claims the shard: accumulation order inside a shard is
            # lo..hi, always
            partials = []
            for lo, hi, _ in shards:
                acc = 0.0
                for e in range(lo, hi):
                    acc += elem[e]
                partials.append(acc)
            # (worker count w only changes *who* computes a shard —
            # claiming via cursor — never the per-shard fold above or
            # the tree below)
            results.append(tree_reduce(partials,
                                       order_rng=random.Random(w)))
        bits = {f64_bits(r) for r in results}
        assert len(bits) == 1, (ne, be, nq, results)
    print("end-to-end: cursor-claimed shards + fixed tree reduce give "
          "identical bits for 1/2/3/8 workers")


if __name__ == "__main__":
    check_plan_invariants()
    check_tree_structure()
    check_bitwise_schedule_independence()
    check_worker_count_independence()
    print("proto_shard_plan: all checks passed")
