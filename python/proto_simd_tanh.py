"""Offline validation of the SIMD kernels in rust/src/linalg/simd.rs.

No Rust toolchain runs in the authoring container, so (following
proto_two_head.py / proto_varform.py) the numerically risky pieces of
the AVX2 path ship with this transliteration, executed offline:

1. `tanh_accurate`   -- the f64 vector tanh used by the training
   epilogue: blend of an odd Taylor branch (|x| < 0.125) and an
   exp-based branch tanh = (E-1)/(E+1), E = e^{2|x|} via Cody-Waite
   range reduction + degree-13 Taylor exp + 2^k bit reconstruction.
   Claim under test: max relative error vs the libm tanh is
   "1e-15-class" (a few ulp) over the whole line.
2. `tanh_fast_f32`   -- the f32 inference variant (same structure,
   degree-7 exp polynomial). Claim: rel err well under the 1e-5
   budget of the f32 serve path.
3. f32-compute / f64-accumulate GEMM -- products and 16-deep partial
   sums in f32 (FMA), chunk sums accumulated in f64. Claim: a
   30x30-weight MLP layer stays within ~1e-6 of the f64 result, so
   the end-to-end f32 serve path clears max rel-err < 1e-5.

Every operation below mirrors the Rust/AVX2 instruction sequence
(same polynomial orders, same Horner order, same magic-number
round-to-nearest) so the measured bounds transfer.
"""

import numpy as np

# --- constants shared with rust/src/linalg/simd.rs -------------------
LOG2E = 1.4426950408889634
LN2_HI = 6.93147180369123816490e-01  # 0x3FE62E42FEE00000
LN2_LO = 1.90821492927058770002e-10  # 0x3DEA39EF35793C76
MAGIC = 1.5 * 2.0**52  # round-to-nearest-even bias trick

# tanh odd Taylor coefficients (x + x^3*c3 + ... + x^13*c13)
TANH_C = [
    -1.0 / 3.0,
    2.0 / 15.0,
    -17.0 / 315.0,
    62.0 / 2835.0,
    -1382.0 / 155925.0,
    21844.0 / 6081075.0,
]

# exp Taylor 1/i! for i = 0..13 (Horner from the top)
import math
EXP_C = [1.0 / math.factorial(i) for i in range(14)]


def exp_reduced(y):
    """e^y for y in [0, ~40] via 2^k * P(r), mirroring the AVX2 ops."""
    y = np.asarray(y, dtype=np.float64)
    kd = (y * LOG2E + MAGIC) - MAGIC  # rint via magic number
    r = (y - kd * LN2_HI) - kd * LN2_LO
    # Horner, degree 13, top-down — same order as the Rust kernel
    q = np.full_like(r, EXP_C[13])
    for i in range(12, -1, -1):
        q = q * r + EXP_C[i]
    k = kd.astype(np.int64)
    scale = ((k + 1023) << 52).view(np.float64)
    return q * scale


def tanh_accurate(x):
    """f64 vector tanh: blend(small Taylor, (E-1)/(E+1))."""
    x = np.asarray(x, dtype=np.float64)
    ax = np.abs(x)
    # small branch: x + x*(x2*p)
    x2 = x * x
    p = np.full_like(x, TANH_C[5])
    for c in TANH_C[4::-1]:
        p = p * x2 + c
    small = x + x * (x2 * p)
    # exp branch
    y = np.minimum(2.0 * ax, 40.0)
    e = exp_reduced(y)
    big = np.copysign((e - 1.0) / (e + 1.0), x)
    return np.where(ax < 0.125, small, big)


# --- f32 variant -----------------------------------------------------
LOG2E_F = np.float32(LOG2E)
LN2_HI_F = np.float32(0.6933594)   # 0x3F318000 (exact in 11 bits)
LN2_LO_F = np.float32(-2.1219444e-4)  # ln2 - LN2_HI_F
MAGIC_F = np.float32(1.5 * 2.0**23)
TANH_CF = [np.float32(c) for c in TANH_C[:3]]
EXP_CF = [np.float32(1.0 / math.factorial(i)) for i in range(8)]


def tanh_fast_f32(x):
    """f32 inference tanh, degree-7 exp polynomial."""
    x = np.asarray(x, dtype=np.float32)
    ax = np.abs(x)
    x2 = x * x
    p = np.full_like(x, TANH_CF[2])
    for c in TANH_CF[1::-1]:
        p = p * x2 + c
    small = x + x * (x2 * p)
    y = np.minimum(np.float32(2.0) * ax, np.float32(18.0))
    kd = (y * LOG2E_F + MAGIC_F) - MAGIC_F
    r = (y - kd * LN2_HI_F) - kd * LN2_LO_F
    q = np.full_like(r, EXP_CF[7])
    for i in range(6, -1, -1):
        q = q * r + EXP_CF[i]
    k = kd.astype(np.int32)
    scale = ((k + 127) << 23).view(np.float32)
    e = q * scale
    big = np.copysign((e - np.float32(1.0)) / (e + np.float32(1.0)), x)
    return np.where(ax < np.float32(0.125), small, big).astype(np.float32)


def rel_err(approx, exact):
    exact = np.asarray(exact, dtype=np.float64)
    denom = np.maximum(np.abs(exact), 1e-300)
    return np.abs(np.asarray(approx, dtype=np.float64) - exact) / denom


def check_tanh_f64():
    rng = np.random.default_rng(7)
    xs = np.concatenate([
        np.linspace(-25.0, 25.0, 2_000_001),
        rng.uniform(-1.0, 1.0, 500_000),
        rng.uniform(-0.2, 0.2, 500_000),  # dense around the blend seam
        np.array([0.0, 0.125, -0.125, 19.0, -19.0, 1e-30, -1e-30,
                  700.0, -700.0, 1e308]),
    ])
    got = tanh_accurate(xs)
    want = np.tanh(xs)
    re = rel_err(got, want)
    print(f"f64 tanh_accurate: max rel err {re.max():.3e} "
          f"(n={xs.size})")
    assert re.max() < 5e-15, "not 1e-15-class"
    # seam continuity: both branches agree to ~1 ulp at the boundary
    seam = np.linspace(0.1249, 0.1251, 10001)
    re_seam = rel_err(tanh_accurate(seam), np.tanh(seam))
    print(f"  seam [0.1249,0.1251]: max rel err {re_seam.max():.3e}")
    assert re_seam.max() < 5e-15


def check_tanh_f32():
    rng = np.random.default_rng(11)
    xs = np.concatenate([
        np.linspace(-12.0, 12.0, 1_000_001),
        rng.uniform(-1.5, 1.5, 500_000),
    ]).astype(np.float32)
    got = tanh_fast_f32(xs).astype(np.float64)
    want = np.tanh(xs.astype(np.float64))
    # absolute-or-relative: tanh saturates at +-1
    err = np.abs(got - want) / np.maximum(np.abs(want), 1e-6)
    print(f"f32 tanh_fast: max rel err {err.max():.3e} (n={xs.size})")
    assert err.max() < 2e-6, "f32 tanh outside budget"


def gemm_f32_acc64(a32, w32, kblk=16):
    """z[p,o] = sum_i a[p,i] w[o,i]: f32 FMA products, f32 partial sums
    within kblk-deep chunks, chunk totals accumulated in f64 — the
    mixed-precision inference kernel's reduction scheme."""
    m, k = a32.shape
    o = w32.shape[0]
    z = np.zeros((m, o), dtype=np.float64)
    for c0 in range(0, k, kblk):
        c1 = min(c0 + kblk, k)
        part = np.zeros((m, o), dtype=np.float32)
        for i in range(c0, c1):
            # np float32 * float32 -> float32 rounds once per op like
            # mul+add; hardware FMA rounds once per fma (tighter), so
            # this measured bound is conservative for the Rust kernel.
            part += a32[:, i:i + 1] * w32[:, i].T[None, :]
        z += part.astype(np.float64)
    return z


def check_f32_serve_path():
    """End-to-end [2,30,30,30,1] forward in the mixed-precision scheme
    vs the f64 reference: the --precision f32 rel-err budget."""
    rng = np.random.default_rng(42)
    layers = [2, 30, 30, 30, 1]
    glorot = [rng.uniform(-1, 1, (o, i)) * np.sqrt(6.0 / (i + o))
              for i, o in zip(layers[:-1], layers[1:])]
    biases = [rng.uniform(-0.1, 0.1, o) for o in layers[1:]]
    pts = rng.uniform(0.0, 1.0, (4096, 2))

    # f64 reference (libm tanh)
    a = pts.copy()
    for l, (w, b) in enumerate(zip(glorot, biases)):
        z = a @ w.T + b
        a = np.tanh(z) if l < len(glorot) - 1 else z
    u64 = a[:, 0]

    # f32 serve path: weights/bias packed to f32 once, activations f32,
    # mixed-precision GEMM, fast f32 tanh
    a32 = pts.astype(np.float32)
    for l, (w, b) in enumerate(zip(glorot, biases)):
        z = gemm_f32_acc64(a32, w.astype(np.float32))
        z = (z + b).astype(np.float32)
        a32 = tanh_fast_f32(z) if l < len(glorot) - 1 else z
    u32 = a32[:, 0].astype(np.float64)

    scale = np.abs(u64).max()
    re = np.abs(u32 - u64) / max(scale, 1e-12)
    print(f"f32 serve path: max rel err {re.max():.3e} vs f64 "
          f"(scale {scale:.3e}, 4096 points)")
    assert re.max() < 1e-5, "f32 inference path outside 1e-5 budget"


if __name__ == "__main__":
    check_tanh_f64()
    check_tanh_f32()
    check_f32_serve_path()
    print("all SIMD-kernel prototype checks passed")
