"""Numpy prototype of the native two-head InverseSpace train step.

This is the validation harness for rust/src/runtime/backend/native.rs
NativeLoss::InverseSpace (no rust toolchain in the dev container): it
transliterates the planned hand-written adjoints exactly, checks every
parameter gradient against complex-step differentiation (machine
precision, the numpy analogue of the Rust Dual2 checks), and sizes the
iteration budgets asserted by tests/native_e2e.rs.

Run:  python3 python/proto_two_head.py
"""
import sys
import time
import numpy as np

sys.path.insert(0, "python/compile")
from fem_py import mesh as pmesh, assembly  # noqa: E402


# ---------------------------------------------------------------------
# stable softplus / sigmoid (complex-safe variants for the reference)
# ---------------------------------------------------------------------
def softplus(z):
    return np.where(z > 30.0, z, np.log1p(np.exp(np.minimum(z, 30.0))))


def sigmoid(z):
    out = np.empty_like(z)
    pos = z >= 0
    out[pos] = 1.0 / (1.0 + np.exp(-z[pos]))
    ez = np.exp(z[~pos])
    out[~pos] = ez / (1.0 + ez)
    return out


def softplus_c(z):  # complex-step-safe (moderate |z| only)
    return np.log1p(np.exp(z))


# ---------------------------------------------------------------------
# Two-head MLP: trunk hidden layers -> u head (with spatial tangents)
#                                   -> eps head (value, softplus)
# ---------------------------------------------------------------------
class TwoHeadNet:
    def __init__(self, layers, seed=0, two_head=True):
        # layers like [2, h1, ..., 1]; eps head is (h_last -> 1) extra
        rng = np.random.default_rng(seed)
        self.layers = layers
        self.two_head = two_head
        self.params = []  # (W, b) per stage; eps head appended last
        for nin, nout in zip(layers[:-1], layers[1:]):
            lim = np.sqrt(6.0 / (nin + nout))
            self.params.append([rng.uniform(-lim, lim, (nin, nout)),
                                np.zeros(nout)])
        if two_head:
            nin = layers[-2]
            lim = np.sqrt(6.0 / (nin + 1))
            self.params.append([rng.uniform(-lim, lim, (nin, 1)),
                                np.zeros(1)])

    def flat(self):
        return np.concatenate([np.concatenate([w.ravel(), b])
                               for w, b in self.params])

    def set_flat(self, theta):
        o = 0
        for wb in self.params:
            w, b = wb
            wb[0] = theta[o:o + w.size].reshape(w.shape)
            o += w.size
            wb[1] = theta[o:o + b.size]
            o += b.size
        assert o == theta.size

    def n_stages(self):
        return len(self.layers) - 1

    def forward(self, pts):
        """pts (N,2) -> u, ux, uy, eps, tape.

        tape: per hidden layer (a, ax, ay, zx, zy); plus trunk output
        activation (a_last) and eps pre-activation z_eps.
        """
        n = pts.shape[0]
        cplx = pts.dtype == np.complex128 or self.params[0][0].dtype == np.complex128
        dt = np.complex128 if cplx else np.float64
        a = pts.astype(dt)
        ax = np.zeros((n, 2), dt)
        ay = np.zeros((n, 2), dt)
        ax[:, 0] = 1.0
        ay[:, 1] = 1.0
        tape = []
        last = self.n_stages() - 1
        for l in range(last):
            w, b = self.params[l]
            z = a @ w + b
            zx = ax @ w
            zy = ay @ w
            t = np.tanh(z)
            s = 1.0 - t * t
            tape.append((t, s * zx, s * zy, zx, zy))
            a, ax, ay = t, s * zx, s * zy
        wu, bu = self.params[last]
        u = (a @ wu + bu)[:, 0]
        ux = (ax @ wu)[:, 0]
        uy = (ay @ wu)[:, 0]
        eps = None
        z_eps = None
        if self.two_head:
            we, be = self.params[-1]
            z_eps = (a @ we + be)[:, 0]
            eps = (softplus_c(z_eps) if cplx else softplus(z_eps))
        return u, ux, uy, eps, (tape, a, ax, ay, z_eps)

    def backward(self, pts, cache, gu, gx_, gy_, ge, grads):
        """Accumulate parameter grads for seeds (gu,gx_,gy_,ge)."""
        tape, a_last, ax_last, ay_last, z_eps = cache
        last = self.n_stages() - 1
        ga = gu[:, None].copy()
        gax = gx_[:, None].copy()
        gay = gy_[:, None].copy()
        # eps head adjoint
        gez = None
        if self.two_head and ge is not None:
            gez = (ge * sigmoid(z_eps))[:, None]
            gw_e, gb_e = grads[-1]
            gw_e += a_last.T @ gez
            gb_e += gez.sum(axis=0)
        for l in range(last, -1, -1):
            w, _ = self.params[l]
            gw, gb = grads[l]
            a_in = pts if l == 0 else tape[l - 1][0]
            gb += ga.sum(axis=0)
            if l == 0:
                gw += a_in.T @ ga
                gw[0] += gax.sum(axis=0)
                gw[1] += gay.sum(axis=0)
            else:
                ax_in, ay_in = tape[l - 1][1], tape[l - 1][2]
                gw += a_in.T @ ga + ax_in.T @ gax + ay_in.T @ gay
            if l == 0:
                break
            gb_v = ga @ w.T
            if l == last and gez is not None:
                gb_v = gb_v + gez @ self.params[-1][0].T
            gbx = gax @ w.T
            gby = gay @ w.T
            a, _, _, zx, zy = tape[l - 1]
            s = 1.0 - a * a
            ds = -2.0 * a * s
            ga = gb_v * s + (gbx * zx + gby * zy) * ds
            gax = gbx * s
            gay = gby * s


# ---------------------------------------------------------------------
# The InverseSpace objective (and InverseConst for budget sizing)
# ---------------------------------------------------------------------
class Objective:
    """loss = var + tau*bd + gamma*sensor over an AssembledDomain."""

    def __init__(self, dom, fmat, bd_pts, bd_u, s_pts, s_u,
                 bx=0.0, by=0.0, tau=10.0, gamma=10.0, mode="space",
                 eps_const=None):
        self.dom, self.fmat = dom, fmat
        self.bd_pts, self.bd_u = bd_pts, bd_u
        self.s_pts, self.s_u = s_pts, s_u
        self.bx, self.by, self.tau, self.gamma = bx, by, tau, gamma
        self.mode = mode          # "space" | "const"
        self.eps_const = eps_const  # trainable scalar (const mode)

    def loss(self, net, eps_const=None):
        """Pure forward loss (complex-safe) for gradchecking."""
        dom = self.dom
        ne, nt, nq = dom.n_elem, dom.n_test, dom.n_quad
        u, ux, uy, eps, _ = net.forward(dom.quad_xy)
        ux = ux.reshape(ne, nq)
        uy = uy.reshape(ne, nq)
        if self.mode == "space":
            exq = eps.reshape(ne, nq) * ux
            eyq = eps.reshape(ne, nq) * uy
        else:
            ec = self.eps_const if eps_const is None else eps_const
            exq, eyq = ec * ux, ec * uy
        r = (np.einsum("ejq,eq->ej", dom.gx, exq)
             + np.einsum("ejq,eq->ej", dom.gy, eyq)
             - self.fmat)
        if self.bx != 0.0 or self.by != 0.0:
            dq = self.bx * ux + self.by * uy
            r = r + np.einsum("ejq,eq->ej", dom.v, dq)
        var = (r * r).sum() / (ne * nt)
        ub, _, _, _, _ = net.forward(self.bd_pts)
        bd = ((ub - self.bd_u) ** 2).sum() / len(self.bd_u)
        us, _, _, _, _ = net.forward(self.s_pts)
        sens = ((us - self.s_u) ** 2).sum() / len(self.s_u)
        return var + self.tau * bd + self.gamma * sens

    def loss_and_grad(self, net):
        """Hand-written adjoints — the Rust transliteration."""
        dom = self.dom
        ne, nt, nq = dom.n_elem, dom.n_test, dom.n_quad
        cr = 2.0 / (ne * nt)
        grads = [[np.zeros_like(w), np.zeros_like(b)]
                 for w, b in net.params]
        u, ux, uy, eps, cache = net.forward(dom.quad_xy)
        uxe = ux.reshape(ne, nq)
        uye = uy.reshape(ne, nq)
        if self.mode == "space":
            epse = eps.reshape(ne, nq)
            exq, eyq = epse * uxe, epse * uye
        else:
            exq, eyq = self.eps_const * uxe, self.eps_const * uye
        cv = (np.einsum("ejq,eq->ej", dom.gx, exq)
              + np.einsum("ejq,eq->ej", dom.gy, eyq))
        r = cv - self.fmat
        conv = self.bx != 0.0 or self.by != 0.0
        if conv:
            dq = self.bx * uxe + self.by * uye
            r = r + np.einsum("ejq,eq->ej", dom.v, dq)
        var = (r * r).sum() / (ne * nt)
        # seeds
        tgx = cr * np.einsum("ejq,ej->eq", dom.gx, r)
        tgy = cr * np.einsum("ejq,ej->eq", dom.gy, r)
        if self.mode == "space":
            ge = (tgx * uxe + tgy * uye).ravel()
            sx = epse * tgx
            sy = epse * tgy
            geps_const = 0.0
        else:
            ge = None
            sx = self.eps_const * tgx
            sy = self.eps_const * tgy
            # dL/deps_const = cr * sum_ej r * c  with c = Gx ux + Gy uy
            c_pre = (np.einsum("ejq,eq->ej", dom.gx, uxe)
                     + np.einsum("ejq,eq->ej", dom.gy, uye))
            geps_const = cr * (r * c_pre).sum()
        if conv:
            tv = cr * np.einsum("ejq,ej->eq", dom.v, r)
            sx = sx + self.bx * tv
            sy = sy + self.by * tv
        net.backward(dom.quad_xy, cache, np.zeros(ne * nq),
                     sx.ravel(), sy.ravel(), ge, grads)
        # boundary
        ub, _, _, _, cb = net.forward(self.bd_pts)
        nb = len(self.bd_u)
        d = ub - self.bd_u
        bd = (d * d).sum() / nb
        net.backward(self.bd_pts, cb, 2.0 * self.tau / nb * d,
                     np.zeros(nb), np.zeros(nb),
                     np.zeros(nb) if net.two_head else None, grads)
        # sensors
        us, _, _, _, cs = net.forward(self.s_pts)
        ns = len(self.s_u)
        d = us - self.s_u
        sens = (d * d).sum() / ns
        net.backward(self.s_pts, cs, 2.0 * self.gamma / ns * d,
                     np.zeros(ns), np.zeros(ns),
                     np.zeros(ns) if net.two_head else None, grads)
        total = var + self.tau * bd + self.gamma * sens
        flat = np.concatenate([np.concatenate([gw.ravel(), gb])
                               for gw, gb in grads])
        return total, flat, geps_const, (var, bd, sens)


def complex_step_grad(obj, net, eps_const=None):
    theta0 = net.flat()
    g = np.zeros_like(theta0)
    h = 1e-30
    for k in range(theta0.size):
        th = theta0.astype(np.complex128)
        th[k] += 1j * h
        net.set_flat(th)
        g[k] = obj.loss(net).imag / h
    net.set_flat(theta0)
    if eps_const is not None:
        l = obj.loss(net, eps_const=eps_const + 1j * h)
        return g, l.imag / h
    return g, None


def adam_train(obj, net, iters, lr, eps0=None, log_every=0,
               callback=None):
    theta = net.flat()
    has_eps = obj.mode == "const"
    n = theta.size + (1 if has_eps else 0)
    m = np.zeros(n)
    v = np.zeros(n)
    b1, b2, ae = 0.9, 0.999, 1e-8
    eps_c = eps0
    for t in range(1, iters + 1):
        if has_eps:
            obj.eps_const = eps_c
        loss, g, ge, parts = obj.loss_and_grad(net)
        if has_eps:
            g = np.append(g, ge)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd = lr * (m / (1 - b1 ** t)) / (np.sqrt(v / (1 - b2 ** t)) + ae)
        theta -= upd[:theta.size]
        net.set_flat(theta)
        if has_eps:
            eps_c -= upd[-1]
        if log_every and (t % log_every == 0 or t == 1):
            extra = f" eps={eps_c:.4f}" if has_eps else ""
            print(f"    it {t:5d} loss {loss:.4e} "
                  f"(var {parts[0]:.3e} bd {parts[1]:.3e} "
                  f"sens {parts[2]:.3e}){extra}")
        if callback and callback(t, loss, eps_c, net):
            return t, loss, eps_c
    return iters, loss, eps_c


# ---------------------------------------------------------------------
# Problems
# ---------------------------------------------------------------------
def eps_star(x, y):
    return 0.5 * (np.sin(x) + np.cos(y))


def u_star(x, y):
    return np.sin(np.pi * x) * np.sin(np.pi * y)


def forcing_space(x, y):
    """f = -div(eps* grad u*) + b . grad u* with b=(1,0), via FD."""
    h = 1e-5

    def flux_div(x, y):
        # d/dx(eps ux) + d/dy(eps uy) with central differences on the
        # analytic pieces (accuracy ~1e-9, plenty for training targets)
        def epsux(x, y):
            return eps_star(x, y) * np.pi * np.cos(np.pi * x) \
                * np.sin(np.pi * y)

        def epsuy(x, y):
            return eps_star(x, y) * np.pi * np.sin(np.pi * x) \
                * np.cos(np.pi * y)
        return ((epsux(x + h, y) - epsux(x - h, y)) / (2 * h)
                + (epsuy(x, y + h) - epsuy(x, y - h)) / (2 * h))

    ux = np.pi * np.cos(np.pi * x) * np.sin(np.pi * y)
    return -flux_div(x, y) + 1.0 * ux


def boundary_square(nb, x0=0.0, y0=0.0, x1=1.0, y1=1.0):
    per = nb // 4
    t = np.linspace(0, 1, per, endpoint=False)
    pts = np.concatenate([
        np.stack([x0 + (x1 - x0) * t, np.full(per, y0)], 1),
        np.stack([np.full(per, x1), y0 + (y1 - y0) * t], 1),
        np.stack([x1 - (x1 - x0) * t, np.full(per, y1)], 1),
        np.stack([np.full(per, x0), y1 - (y1 - y0) * t], 1),
    ])
    return pts


def build_space_objective(n=2, nt1d=3, nq1d=8, nb=80, ns=40, seed=5):
    pts, cells = pmesh.unit_square(n)
    dom = assembly.assemble(pts, cells, nt1d, nq1d)
    x = dom.quad_xy[:, 0].reshape(dom.n_elem, dom.n_quad)
    y = dom.quad_xy[:, 1].reshape(dom.n_elem, dom.n_quad)
    fmat = np.einsum("ejq,eq->ej", dom.v, forcing_space(x, y))
    bd = boundary_square(nb)
    bd_u = u_star(bd[:, 0], bd[:, 1])
    rng = np.random.default_rng(seed)
    sp = rng.uniform(0.02, 0.98, (ns, 2))
    s_u = u_star(sp[:, 0], sp[:, 1])
    return Objective(dom, fmat, bd, bd_u, sp, s_u, bx=1.0, by=0.0,
                     mode="space")


def eps_l2(net, grid_n=30):
    g = np.linspace(0.02, 0.98, grid_n)
    X, Y = np.meshgrid(g, g)
    p = np.stack([X.ravel(), Y.ravel()], 1)
    _, _, _, eps, _ = net.forward(p)
    ref = eps_star(p[:, 0], p[:, 1])
    return np.sqrt(((eps - ref) ** 2).mean())


# ---------------------------------------------------------------------
def main():
    print("== gradchecks: hand adjoints vs complex step ==")
    for layers, conv in [([2, 4, 1], (1.0, 0.0)),
                         ([2, 4, 1], (0.0, 0.0)),
                         ([2, 1, 1], (0.3, -0.2)),
                         ([2, 5, 3, 1], (1.0, 0.5)),
                         ([2, 1], (1.0, 0.0))]:
        obj = build_space_objective(n=1, nt1d=2, nq1d=3, nb=8, ns=4)
        obj.bx, obj.by = conv
        net = TwoHeadNet(layers, seed=3)
        _, g, _, _ = obj.loss_and_grad(net)
        gref, _ = complex_step_grad(obj, net)
        rel = np.abs(g - gref) / (1.0 + np.maximum(np.abs(g),
                                                   np.abs(gref)))
        print(f"  space {layers} b={conv}: max rel err {rel.max():.2e}")
        assert rel.max() < 1e-12, (layers, rel.max())

    # const-eps variant through the same harness (sanity of geps)
    obj = build_space_objective(n=1, nt1d=2, nq1d=3, nb=8, ns=4)
    obj.mode = "const"
    obj.eps_const = 0.7
    obj.bx = obj.by = 0.0
    net = TwoHeadNet([2, 4, 1], seed=3, two_head=False)
    _, g, ge, _ = obj.loss_and_grad(net)
    gref, geref = complex_step_grad(obj, net, eps_const=0.7)
    rel = np.abs(g - gref) / (1.0 + np.maximum(np.abs(g), np.abs(gref)))
    print(f"  const [2,4,1]: max rel {rel.max():.2e}, "
          f"geps {ge:.6e} vs {geref:.6e}")
    assert rel.max() < 1e-12 and abs(ge - geref) < 1e-10 * (1 + abs(ge))

    print("== inverse_const budget (rust e2e hyperparams) ==")
    # rect_grid(2,2,-1..1), nt=3, nq=10, net [2,16,16,1], nb=80, ns=20,
    # lr 5e-3, eps_init 2.0, target 0.3 within 1e-2
    pts, cells = pmesh.rect_grid(2, 2, -1.0, -1.0, 1.0, 1.0)
    dom = assembly.assemble(pts, cells, 3, 10)

    def u_c(x):
        return 10.0 * np.sin(x) * np.tanh(x) * np.exp(-0.3 * x * x)

    def lap_u_c(x):
        h = 1e-4
        return (u_c(x + h) - 2 * u_c(x) + u_c(x - h)) / (h * h)

    x = dom.quad_xy[:, 0].reshape(dom.n_elem, dom.n_quad)
    fmat = np.einsum("ejq,eq->ej", dom.v, -0.3 * lap_u_c(x))
    bd = boundary_square(80, -1.0, -1.0, 1.0, 1.0)
    bd_u = u_c(bd[:, 0])
    for seed in [1, 2, 3]:
        rng = np.random.default_rng(seed)
        sp = rng.uniform(-0.95, 0.95, (20, 2))
        s_u = u_c(sp[:, 0])
        objc = Objective(dom, fmat, bd, bd_u, sp, s_u, mode="const",
                         eps_const=2.0)
        net = TwoHeadNet([2, 16, 16, 1], seed=seed, two_head=False)
        hit = {"t": None}

        def cb(t, loss, eps_c, _n):
            if hit["t"] is None and abs(eps_c - 0.3) < 1e-2:
                hit["t"] = t
            return False

        t0 = time.time()
        it, loss, eps_c = adam_train(objc, net, 4000, 5e-3, eps0=2.0,
                                     callback=cb)
        print(f"  seed {seed}: eps={eps_c:.4f} after {it} iters "
              f"(first |eps-0.3|<1e-2 at {hit['t']}), "
              f"{time.time()-t0:.1f}s")

    print("== inverse_space smoke budget (unit_square(2)) ==")
    for seed in [1, 2, 3]:
        obj = build_space_objective(n=2, nt1d=3, nq1d=8, nb=80, ns=60,
                                    seed=seed)
        net = TwoHeadNet([2, 16, 16, 1], seed=seed)
        e0 = eps_l2(net)
        t0 = time.time()
        marks = {}

        def cb(t, loss, _e, n):
            if t in (300, 600, 1000, 1500, 2000):
                marks[t] = eps_l2(n)
            return False

        adam_train(obj, net, 2000, 5e-3, callback=cb)
        e1 = eps_l2(net)
        print(f"  seed {seed}: ||eps-eps*|| {e0:.4f} -> {e1:.4f} "
              f"(x{e0/e1:.1f}), marks "
              + " ".join(f"{k}:{v:.4f}(x{e0/v:.1f})"
                         for k, v in sorted(marks.items()))
              + f", {time.time()-t0:.1f}s")

    print("== fig15-scale stability probe (8x8 square, nt1d=4 nq1d=5) ==")
    obj = build_space_objective(n=8, nt1d=4, nq1d=5, nb=200, ns=200,
                                seed=7)
    net = TwoHeadNet([2, 30, 30, 30, 1], seed=7)
    e0 = eps_l2(net)
    t0 = time.time()
    adam_train(obj, net, 800, 2e-3, log_every=200)
    print(f"  ||eps-eps*|| {e0:.4f} -> {eps_l2(net):.4f}, "
          f"{time.time()-t0:.1f}s for 800 iters")




# ---------------------------------------------------------------------
# disk_1024 stability probe (port of mesh::generators::disk)
# ---------------------------------------------------------------------
def disk_mesh(n=16, m=12, r=1.0):
    s = 0.5 * r
    pts = []
    index = {}

    def add(x, y):
        key = (round(x, 12), round(y, 12))
        if key not in index:
            index[key] = len(pts)
            pts.append([x, y])
        return index[key]

    cells = []
    grid = [[add(-s + 2 * s * ix / n, -s + 2 * s * iy / n)
             for ix in range(n + 1)] for iy in range(n + 1)]
    for iy in range(n):
        for ix in range(n):
            cells.append([grid[iy][ix], grid[iy][ix + 1],
                          grid[iy + 1][ix + 1], grid[iy + 1][ix]])
    for side in range(4):
        blk = [[0] * (n + 1) for _ in range(m + 1)]
        for iv in range(m + 1):
            v = iv / m
            for it in range(n + 1):
                t = it / n
                sx, sy = [(-s + 2 * s * t, -s), (s, -s + 2 * s * t),
                          (s - 2 * s * t, s), (-s, s - 2 * s * t)][side]
                a0 = [-0.75, -0.25, 0.25, 0.75][side] * np.pi
                ang = a0 + t * 0.5 * np.pi
                axp, ayp = r * np.cos(ang), r * np.sin(ang)
                blk[iv][it] = add(sx + v * (axp - sx), sy + v * (ayp - sy))
        for iv in range(m):
            for it in range(n):
                cells.append([blk[iv][it], blk[iv][it + 1],
                              blk[iv + 1][it + 1], blk[iv + 1][it]])
    pts = np.array(pts)
    cells = np.array(cells)
    # fix orientation (shoelace)
    for c in cells:
        p = pts[c]
        a2 = ((p[0, 0] * p[1, 1] - p[1, 0] * p[0, 1])
              + (p[1, 0] * p[2, 1] - p[2, 0] * p[1, 1])
              + (p[2, 0] * p[3, 1] - p[3, 0] * p[2, 1])
              + (p[3, 0] * p[0, 1] - p[0, 0] * p[3, 1]))
        if a2 < 0:
            c[1], c[3] = c[3], c[1]
    return pts, cells


def probe_disk():
    print("== disk_1024 two-head stability probe (manufactured) ==")
    pts, cells = disk_mesh()
    print(f"  disk mesh: {len(cells)} cells, {len(pts)} points")
    dom = assembly.assemble(pts, cells, 4, 5)

    def u_d(x, y):
        return 2.5 * (1.0 - x * x - y * y)

    # f = -div(eps* grad u) + u_x, u = 2.5(1-x^2-y^2):
    # ux=-5x, uy=-5y, lap=-10; epsx=0.5cos x, epsy=-0.5 sin y
    def forcing_d(x, y):
        ex, ey = 0.5 * np.cos(x), -0.5 * np.sin(y)
        return -(ex * (-5 * x) + ey * (-5 * y)
                 + eps_star(x, y) * (-10.0)) + (-5 * x)

    x = dom.quad_xy[:, 0].reshape(dom.n_elem, dom.n_quad)
    y = dom.quad_xy[:, 1].reshape(dom.n_elem, dom.n_quad)
    fmat = np.einsum("ejq,eq->ej", dom.v, forcing_d(x, y))
    th = np.linspace(0, 2 * np.pi, 400, endpoint=False)
    bd = np.stack([np.cos(th), np.sin(th)], 1)
    bd_u = np.zeros(400)
    rng = np.random.default_rng(11)
    rr = np.sqrt(rng.uniform(0, 0.9, 400))
    ta = rng.uniform(0, 2 * np.pi, 400)
    sp = np.stack([rr * np.cos(ta), rr * np.sin(ta)], 1)
    s_u = u_d(sp[:, 0], sp[:, 1])
    obj = Objective(dom, fmat, bd, bd_u, sp, s_u, bx=1.0, by=0.0,
                    mode="space")
    net = TwoHeadNet([2, 30, 30, 30, 1], seed=4)

    def el2(n_):
        g = np.linspace(-0.7, 0.7, 25)
        X, Y = np.meshgrid(g, g)
        p = np.stack([X.ravel(), Y.ravel()], 1)
        _, _, _, eps, _ = n_.forward(p)
        return np.sqrt(((eps - eps_star(p[:, 0], p[:, 1])) ** 2).mean())

    e0 = el2(net)
    t0 = time.time()
    adam_train(obj, net, 600, 2e-3, log_every=150)
    print(f"  ||eps-eps*|| {e0:.4f} -> {el2(net):.4f}, "
          f"{time.time()-t0:.1f}s for 600 iters")



if __name__ == "__main__":
    main()
    probe_disk()
