"""L2 model tests: losses, Adam, train-step builders, baselines."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.fem_py import assembly, mesh

jax.config.update("jax_platform_name", "cpu")


def make_params(layers, seed=0, scale=0.5):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(0, scale, s), jnp.float32)
            for s in model.param_shapes(layers)]


def zeros_like_params(params):
    return [jnp.zeros_like(p) for p in params]


@pytest.fixture(scope="module")
def poisson_data():
    pts, cells = mesh.unit_square(2)
    dom = assembly.assemble(pts, cells, 5, 10)
    om = 2 * math.pi
    f = dom.force_matrix(
        lambda x, y: 2 * om * om * np.sin(om * x) * np.sin(om * y))
    bd = assembly.boundary_points_unit_square(50)
    return {
        "quad_xy": jnp.asarray(dom.quad_xy, jnp.float32),
        "gx": jnp.asarray(dom.gx, jnp.float32),
        "gy": jnp.asarray(dom.gy, jnp.float32),
        "f": jnp.asarray(f, jnp.float32),
        "bd_xy": jnp.asarray(bd, jnp.float32),
        "bd_u": jnp.zeros(200, jnp.float32),
        "shape": dom.gx.shape,
    }


class TestMLP:
    def test_shapes(self):
        p = make_params((2, 30, 30, 30, 1))
        assert len(p) == 8
        x = jnp.zeros((17, 2))
        assert model.mlp_apply(p, x).shape == (17, 1)

    def test_two_heads(self):
        p = make_params((2, 8, 2))
        assert model.mlp_apply(p, jnp.zeros((5, 2))).shape == (5, 2)

    def test_grad_matches_fd(self):
        p = make_params((2, 16, 1), seed=4)
        xy = jnp.asarray(np.random.default_rng(1).uniform(0, 1, (10, 2)),
                         jnp.float32)
        u, du = model.u_and_grad(p, xy)
        h = 1e-3
        for axis in (0, 1):
            delta = np.zeros((1, 2), np.float32)
            delta[0, axis] = h
            up = model.mlp_apply(p, xy + delta)[:, 0]
            um = model.mlp_apply(p, xy - delta)[:, 0]
            fd = (up - um) / (2 * h)
            np.testing.assert_allclose(du[:, axis], fd, rtol=2e-2,
                                       atol=2e-3)

    def test_laplacian_matches_hessian_trace(self):
        p = make_params((2, 12, 1), seed=5)
        xy = jnp.asarray([[0.3, 0.4], [0.7, 0.1], [0.5, 0.9]], jnp.float32)
        _, _, lap = model.u_grad_laplacian(p, xy)

        def u_scalar(q):
            return model.scalar_u(p, q)

        for i in range(xy.shape[0]):
            hess = jax.hessian(u_scalar)(xy[i])
            assert float(lap[i]) == pytest.approx(
                float(jnp.trace(hess)), rel=1e-4, abs=1e-5)


class TestAdam:
    def test_moves_toward_minimum(self):
        # minimize (p-3)^2 with Adam
        p = [jnp.asarray(0.0)]
        m = [jnp.asarray(0.0)]
        v = [jnp.asarray(0.0)]
        for t in range(1, 3001):
            g = [2 * (p[0] - 3.0)]
            p, m, v = model.adam_update(p, g, m, v, float(t), 0.05)
        assert float(p[0]) == pytest.approx(3.0, abs=1e-3)

    def test_bias_correction_first_step(self):
        # after one step from zero state, |delta| ~ lr regardless of g scale
        for gval in (1e-4, 1.0, 1e4):
            p, m, v = model.adam_update(
                [jnp.asarray(0.0)], [jnp.asarray(gval)],
                [jnp.asarray(0.0)], [jnp.asarray(0.0)], 1.0, 0.01)
            assert abs(float(p[0])) == pytest.approx(0.01, rel=1e-3)


class TestLossesDecrease:
    def test_fastvpinn_poisson(self, poisson_data):
        d = poisson_data
        params = make_params((2, 30, 30, 30, 1))
        m = zeros_like_params(params)
        v = zeros_like_params(params)
        step = jax.jit(model.make_train_step("poisson", len(params)))
        state = params + m + v
        losses = []
        for i in range(1, 121):
            out = step(*(state + [jnp.float32(i), jnp.float32(1e-3),
                                  d["quad_xy"], d["gx"], d["gy"], d["f"],
                                  d["bd_xy"], d["bd_u"], jnp.float32(10.)]))
            state = list(out[:3 * len(params)])
            losses.append(float(out[3 * len(params)]))
        assert losses[-1] < 0.5 * losses[0]

    def test_pinn(self):
        om = 2 * math.pi
        rng = np.random.default_rng(0)
        coll = jnp.asarray(rng.uniform(0, 1, (400, 2)), jnp.float32)
        fv = jnp.asarray(
            2 * om * om * np.sin(om * coll[:, 0]) * np.sin(om * coll[:, 1]),
            jnp.float32)
        bd = jnp.asarray(assembly.boundary_points_unit_square(25),
                         jnp.float32)
        bdu = jnp.zeros(100, jnp.float32)
        params = make_params((2, 20, 20, 1))
        m = zeros_like_params(params)
        v = zeros_like_params(params)
        step = jax.jit(model.make_train_step(
            "pinn", len(params),
            const_kwargs={"eps": 1.0, "bx": 0.0, "by": 0.0}))
        state = params + m + v
        losses = []
        for i in range(1, 101):
            out = step(*(state + [jnp.float32(i), jnp.float32(1e-3), coll,
                                  fv, bd, bdu, jnp.float32(10.0)]))
            state = list(out[:3 * len(params)])
            losses.append(float(out[3 * len(params)]))
        assert losses[-1] < losses[0]

    def test_inverse_const_eps_converges_direction(self, poisson_data):
        """eps should move from init toward eps_actual given consistent
        forcing: f = eps_actual * (stiffness action of u_exact)."""
        d = poisson_data
        params = make_params((2, 20, 20, 1), seed=2)
        params.append(jnp.asarray(2.0, jnp.float32))  # eps init
        m = zeros_like_params(params)
        v = zeros_like_params(params)
        # sensors on the exact solution u = sin(2pi x) sin(2pi y)
        rng = np.random.default_rng(3)
        sxy = jnp.asarray(rng.uniform(0, 1, (50, 2)), jnp.float32)
        om = 2 * math.pi
        su = jnp.asarray(np.sin(om * sxy[:, 0]) * np.sin(om * sxy[:, 1]),
                         jnp.float32)
        eps_actual = 0.3
        f_eps = jnp.asarray(eps_actual * np.asarray(d["f"]), jnp.float32)
        step = jax.jit(model.make_train_step("inverse_const", len(params)))
        state = params + m + v
        eps_hist = [2.0]
        losses = []
        for i in range(1, 1201):
            out = step(*(state + [jnp.float32(i), jnp.float32(5e-3),
                                  d["quad_xy"], d["gx"], d["gy"], f_eps,
                                  d["bd_xy"], d["bd_u"], sxy, su,
                                  jnp.float32(10.0), jnp.float32(10.0)]))
            state = list(out[:3 * len(params)])
            eps_hist.append(float(state[len(params) - 1]))
            losses.append(float(out[3 * len(params)]))
        # eps transiently overshoots, then descends toward 0.3 (paper
        # needed ~9k epochs for 1e-5; here we assert clear progress)
        assert abs(eps_hist[-1] - eps_actual) < abs(eps_hist[0] - eps_actual)
        assert losses[-1] < 0.05 * losses[0]


class TestBaselineEquivalence:
    def test_hp_loop_matches_fastvpinn_loss(self, poisson_data):
        """The loop-based baseline and the tensorised loss compute the SAME
        mathematical quantity — only the schedule differs (paper SS4).
        Verify the variational losses agree at identical parameters."""
        d = poisson_data
        params = make_params((2, 30, 30, 30, 1), seed=7)
        lv_fast, _ = model.loss_fastvpinn_poisson(
            params, d["quad_xy"], d["gx"], d["gy"], d["f"],
            d["bd_xy"], d["bd_u"], jnp.float32(10.0), kernel="einsum")
        lv_loop, _ = model.loss_hp_loop(
            params, d["quad_xy"], d["gx"], d["gy"], d["f"],
            d["bd_xy"], d["bd_u"], jnp.float32(10.0))
        assert float(lv_fast) == pytest.approx(float(lv_loop), rel=1e-4)

    def test_pallas_einsum_step_identical(self, poisson_data):
        d = poisson_data
        params = make_params((2, 30, 30, 30, 1), seed=8)
        m = zeros_like_params(params)
        v = zeros_like_params(params)
        args = params + m + v + [
            jnp.float32(1), jnp.float32(1e-3), d["quad_xy"], d["gx"],
            d["gy"], d["f"], d["bd_xy"], d["bd_u"], jnp.float32(10.0)]
        out_p = jax.jit(model.make_train_step(
            "poisson", len(params), kernel="pallas"))(*args)
        out_e = jax.jit(model.make_train_step(
            "poisson", len(params), kernel="einsum"))(*args)
        for a, b in zip(out_p, out_e):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


class TestInverseSpace:
    def test_two_head_loss_runs_and_decreases(self):
        pts, cells = mesh.unit_square(2)
        dom = assembly.assemble(pts, cells, 3, 6)
        ne, nt, nq = dom.gx.shape
        f = dom.force_matrix(lambda x, y: 10.0 + 0 * x)
        params = make_params((2, 16, 16, 2), seed=9)
        m = zeros_like_params(params)
        v = zeros_like_params(params)
        rng = np.random.default_rng(5)
        sxy = jnp.asarray(rng.uniform(0, 1, (30, 2)), jnp.float32)
        su = jnp.zeros(30, jnp.float32)
        bd = jnp.asarray(assembly.boundary_points_unit_square(25),
                         jnp.float32)
        step = jax.jit(model.make_train_step(
            "inverse_space", len(params),
            const_kwargs={"bx": 1.0, "by": 0.0}))
        state = params + m + v
        losses = []
        for i in range(1, 61):
            out = step(*(state + [
                jnp.float32(i), jnp.float32(1e-3),
                jnp.asarray(dom.quad_xy, jnp.float32),
                jnp.asarray(dom.gx, jnp.float32),
                jnp.asarray(dom.gy, jnp.float32),
                jnp.asarray(dom.v, jnp.float32),
                jnp.asarray(f, jnp.float32), bd,
                jnp.zeros(100, jnp.float32), sxy, su,
                jnp.float32(10.0), jnp.float32(10.0)]))
            state = list(out[:3 * len(params)])
            losses.append(float(out[3 * len(params)]))
        assert losses[-1] < losses[0]
        assert np.isfinite(losses).all()


class TestPredict:
    def test_predict_heads(self):
        params = make_params((2, 8, 2), seed=11)
        fn = model.make_predict(len(params), n_heads=2)
        xy = jnp.zeros((7, 2), jnp.float32)
        u, eps = fn(*params, xy)
        assert u.shape == (7,) and eps.shape == (7,)

    def test_predict_with_grad(self):
        params = make_params((2, 8, 1), seed=12)
        fn = model.make_predict_with_grad(len(params))
        xy = jnp.asarray([[0.1, 0.2], [0.3, 0.4]], jnp.float32)
        u, ux, uy = fn(*params, xy)
        _, du = model.u_and_grad(params, xy)
        np.testing.assert_allclose(ux, du[:, 0], rtol=1e-6)
        np.testing.assert_allclose(uy, du[:, 1], rtol=1e-6)
