"""Rust <-> Python assembly cross-validation.

The Rust CLI `repro dump-tensors --mesh <kind> --n <n> --nt <nt> --nq <nq>
--out artifacts/crosscheck/<tag>` writes the premultiplier tensors it
assembled (gx, gy, v, f, quad_xy, jdet) as .npy files. This test
re-assembles the same domain with fem_py and compares element-wise.

Run `make crosscheck` to produce the dumps; tests skip when absent.
"""

import os

import numpy as np
import pytest

from compile.fem_py import assembly, mesh

CROSS_DIR = os.path.join(os.path.dirname(__file__), "..", "..",
                         "artifacts", "crosscheck")

CASES = [
    # tag, mesh builder, nt1d, nq1d
    ("square4_nt3_nq5", lambda: mesh.unit_square(4), 3, 5),
    ("skewed4_nt3_nq5", lambda: mesh.skewed_square(4), 3, 5),
    ("square2_nt5_nq10", lambda: mesh.unit_square(2), 5, 10),
]


def load_dump(tag):
    d = os.path.join(CROSS_DIR, tag)
    if not os.path.isdir(d):
        pytest.skip(f"no rust dump at {d} (run `make crosscheck`)")
    out = {}
    for name in ("quad_xy", "gx", "gy", "v", "f", "jdet"):
        path = os.path.join(d, f"{name}.npy")
        assert os.path.exists(path), f"missing {path}"
        out[name] = np.load(path)
    return out


@pytest.mark.parametrize("tag,builder,nt,nq", CASES)
def test_assembly_matches_rust(tag, builder, nt, nq):
    dump = load_dump(tag)
    pts, cells = builder()
    dom = assembly.assemble(pts, cells, nt, nq)
    f = dom.force_matrix(lambda x, y: np.sin(x) * np.cos(y) + 2.0 * x * y)

    np.testing.assert_allclose(dump["quad_xy"], dom.quad_xy, rtol=1e-6,
                               atol=1e-9, err_msg=f"{tag}: quad_xy")
    np.testing.assert_allclose(dump["jdet"], dom.jdet, rtol=1e-6,
                               atol=1e-12, err_msg=f"{tag}: jdet")
    np.testing.assert_allclose(dump["gx"], dom.gx, rtol=1e-5, atol=1e-7,
                               err_msg=f"{tag}: gx")
    np.testing.assert_allclose(dump["gy"], dom.gy, rtol=1e-5, atol=1e-7,
                               err_msg=f"{tag}: gy")
    np.testing.assert_allclose(dump["v"], dom.v, rtol=1e-5, atol=1e-7,
                               err_msg=f"{tag}: v")
    np.testing.assert_allclose(dump["f"], f, rtol=1e-5, atol=1e-7,
                               err_msg=f"{tag}: force matrix")
