"""Quadrature rule tests (fem_py.quadrature)."""

import numpy as np
import pytest

from compile.fem_py import quadrature as quad


def poly_integral(c):
    """Exact integral over [-1,1] of sum_i c[i] x^i."""
    return sum(ci * ((1 - (-1) ** (i + 1)) / (i + 1))
               for i, ci in enumerate(c))


class TestGaussLegendre:
    @pytest.mark.parametrize("n", range(1, 16))
    def test_weights_sum_to_two(self, n):
        _, w = quad.gauss_legendre(n)
        assert np.sum(w) == pytest.approx(2.0, abs=1e-13)

    @pytest.mark.parametrize("n", range(1, 13))
    def test_exact_to_degree_2n_minus_1(self, n):
        x, w = quad.gauss_legendre(n)
        rng = np.random.default_rng(n)
        c = rng.normal(size=2 * n)  # degree 2n-1
        vals = np.polyval(c[::-1], x)
        assert np.dot(w, vals) == pytest.approx(poly_integral(c), rel=1e-11,
                                                abs=1e-11)

    @pytest.mark.parametrize("n", [3, 5, 8])
    def test_not_exact_beyond(self, n):
        x, w = quad.gauss_legendre(n)
        # x^{2n} is not integrated exactly
        approx = np.dot(w, x ** (2 * n))
        exact = 2.0 / (2 * n + 1)
        assert abs(approx - exact) > 1e-10

    def test_points_sorted_symmetric(self):
        x, _ = quad.gauss_legendre(9)
        assert np.all(np.diff(x) > 0)
        np.testing.assert_allclose(x, -x[::-1], atol=1e-14)


class TestGaussLobatto:
    @pytest.mark.parametrize("n", range(2, 14))
    def test_weights_sum_to_two(self, n):
        _, w = quad.gauss_lobatto(n)
        assert np.sum(w) == pytest.approx(2.0, abs=1e-12)

    @pytest.mark.parametrize("n", range(2, 12))
    def test_includes_endpoints(self, n):
        x, _ = quad.gauss_lobatto(n)
        assert x[0] == pytest.approx(-1.0)
        assert x[-1] == pytest.approx(1.0)

    @pytest.mark.parametrize("n", range(2, 12))
    def test_exact_to_degree_2n_minus_3(self, n):
        x, w = quad.gauss_lobatto(n)
        rng = np.random.default_rng(100 + n)
        c = rng.normal(size=2 * n - 2)  # degree 2n-3
        vals = np.polyval(c[::-1], x)
        assert np.dot(w, vals) == pytest.approx(poly_integral(c), rel=1e-10,
                                                abs=1e-10)

    def test_known_5_point(self):
        x, w = quad.gauss_lobatto(5)
        np.testing.assert_allclose(
            x, [-1.0, -np.sqrt(3 / 7), 0.0, np.sqrt(3 / 7), 1.0],
            atol=1e-13)
        np.testing.assert_allclose(
            w, [0.1, 49 / 90, 32 / 45, 49 / 90, 0.1], atol=1e-13)


class TestTensorRule:
    def test_ordering_contract(self):
        # q = i*n + j with xi from index i, eta from index j
        x, _ = quad.gauss_legendre(3)
        xi, eta, _ = quad.tensor_rule_2d(3)
        for i in range(3):
            for j in range(3):
                q = i * 3 + j
                assert xi[q] == pytest.approx(x[i])
                assert eta[q] == pytest.approx(x[j])

    @pytest.mark.parametrize("n", [2, 4, 7])
    def test_integrates_monomials(self, n):
        xi, eta, w = quad.tensor_rule_2d(n)
        for p in range(0, 2 * n - 1, 2):
            for q in range(0, 2 * n - 1, 2):
                got = np.dot(w, xi**p * eta**q)
                exact = (2.0 / (p + 1)) * (2.0 / (q + 1))
                assert got == pytest.approx(exact, rel=1e-11)

    def test_total_weight_is_area(self):
        _, _, w = quad.tensor_rule_2d(6)
        assert np.sum(w) == pytest.approx(4.0)

    def test_lobatto_kind(self):
        xi, eta, w = quad.tensor_rule_2d(4, "gauss-lobatto")
        assert xi.min() == pytest.approx(-1.0)
        assert np.sum(w) == pytest.approx(4.0)

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError):
            quad.rule_1d(4, "monte-carlo")
