"""Premultiplier tensor assembly tests (fem_py.assembly)."""

import numpy as np
import pytest

from compile.fem_py import assembly, basis, mesh, quadrature


class TestShapesAndLayout:
    def test_shapes(self):
        pts, cells = mesh.unit_square(3)
        dom = assembly.assemble(pts, cells, 4, 6)
        assert dom.gx.shape == (9, 16, 36)
        assert dom.gy.shape == (9, 16, 36)
        assert dom.v.shape == (9, 16, 36)
        assert dom.quad_xy.shape == (9 * 36, 2)
        assert dom.jdet.shape == (9, 36)

    def test_quad_points_inside_elements(self):
        pts, cells = mesh.unit_square(2)
        dom = assembly.assemble(pts, cells, 3, 4)
        nq = 16
        # element 0 is [0,.5]^2 under the row-major cell ordering
        e0 = dom.quad_xy[:nq]
        assert np.all(e0 >= 0) and np.all(e0 <= 0.5 + 1e-12)
        # last element is [.5,1]^2
        e3 = dom.quad_xy[3 * nq:]
        assert np.all(e3 >= 0.5 - 1e-12) and np.all(e3 <= 1)


class TestIntegralCorrectness:
    def test_v_tensor_integrates_constants(self):
        """sum_q V[e,j,q] * 1 = int_K v_j dK; check against 1D exact
        integrals: int_-1^1 (P_{j+1}-P_{j-1}) dx = 0 for all j >= 1
        except none — the integral vanishes unless j-1 == 0 where
        int P_0 = 2 and int P_2 = 0, giving -2 * (h/2) scaling... compute
        directly from high-order quadrature instead."""
        pts, cells = mesh.unit_square(1)
        dom = assembly.assemble(pts, cells, 3, 20)
        got = dom.v.sum(axis=2)[0]  # (NT,)
        # reference: dense tensor quadrature at much higher order
        x, w = quadrature.gauss_legendre(60)
        t = basis.test_fn_1d(3, x)
        int_1d = t @ w  # integrals of each 1D test fn over [-1,1]
        jac = 0.25  # (h/2)^2, h=1
        expect = np.array([int_1d[a] * int_1d[b]
                           for a in range(3) for b in range(3)]) * jac
        np.testing.assert_allclose(got, expect, atol=1e-12)

    def test_stiffness_diagonal_positive(self):
        """sum_q Gx[e,j,q]*dvdx_j + Gy... = int |grad v_j|^2 > 0.
        Reconstruct grad v_j at quad points from the tensors themselves:
        G contains w|J| dv/dx, so  int |grad v|^2 = sum_q G*(dv/dx).
        Use a fresh assembly evaluation for dv/dx via chain rule on the
        unit element where dv/dx = 2 * dv/dxi."""
        pts, cells = mesh.unit_square(1)
        n1d = 3
        dom = assembly.assemble(pts, cells, n1d, 25)
        xi, eta, _ = dom.quad_ref
        _, dxi, deta = basis.test_fn_2d(n1d, xi, eta)
        dvdx = 2.0 * dxi   # h=1 so dxi/dx = 2
        dvdy = 2.0 * deta
        for j in range(n1d * n1d):
            val = np.dot(dom.gx[0, j], dvdx[j]) + np.dot(
                dom.gy[0, j], dvdy[j])
            assert val > 0

    def test_residual_of_exact_solution_vanishes(self):
        """With u = exact Poisson solution and f = -lap u, the element
        residual int (grad u . grad v - f v) dK -> 0 because v vanishes
        on each element boundary (integration by parts)."""
        om = 2 * np.pi
        pts, cells = mesh.unit_square(2)
        dom = assembly.assemble(pts, cells, 4, 30)
        ne, nt, nq = dom.gx.shape
        f = dom.force_matrix(
            lambda x, y: 2 * om * om * np.sin(om * x) * np.sin(om * y))
        x = dom.quad_xy[:, 0].reshape(ne, nq)
        y = dom.quad_xy[:, 1].reshape(ne, nq)
        ux = om * np.cos(om * x) * np.sin(om * y)
        uy = om * np.sin(om * x) * np.cos(om * y)
        res = (np.einsum("ejq,eq->ej", dom.gx, ux)
               + np.einsum("ejq,eq->ej", dom.gy, uy) - f)
        assert np.abs(res).max() < 1e-8

    def test_skewed_mesh_residual_vanishes(self):
        """Same Galerkin-orthogonality property must hold on skewed quads
        (pointwise Jacobians) — this is the complex-geometry claim."""
        om = np.pi
        pts, cells = mesh.skewed_square(3, amp=0.25)
        dom = assembly.assemble(pts, cells, 3, 40)
        ne, nt, nq = dom.gx.shape
        f = dom.force_matrix(
            lambda x, y: 2 * om * om * np.sin(om * x) * np.sin(om * y))
        x = dom.quad_xy[:, 0].reshape(ne, nq)
        y = dom.quad_xy[:, 1].reshape(ne, nq)
        ux = om * np.cos(om * x) * np.sin(om * y)
        uy = om * np.sin(om * x) * np.cos(om * y)
        res = (np.einsum("ejq,eq->ej", dom.gx, ux)
               + np.einsum("ejq,eq->ej", dom.gy, uy) - f)
        assert np.abs(res).max() < 1e-6

    def test_jdet_integrates_area(self):
        pts, cells = mesh.skewed_square(4, amp=0.3)
        dom = assembly.assemble(pts, cells, 2, 10)
        _, _, w = dom.quad_ref
        total_area = np.sum(dom.jdet @ w)
        assert total_area == pytest.approx(1.0, rel=1e-10)

    def test_force_matrix_linear_in_f(self):
        pts, cells = mesh.unit_square(2)
        dom = assembly.assemble(pts, cells, 3, 8)
        f1 = dom.force_matrix(lambda x, y: x)
        f2 = dom.force_matrix(lambda x, y: 2 * x)
        np.testing.assert_allclose(f2, 2 * f1, atol=1e-14)


class TestQuadKinds:
    def test_lobatto_vs_legendre_agree_on_smooth(self):
        pts, cells = mesh.unit_square(2)
        d1 = assembly.assemble(pts, cells, 3, 12, "gauss-legendre")
        d2 = assembly.assemble(pts, cells, 3, 12, "gauss-lobatto")
        f1 = d1.force_matrix(lambda x, y: np.sin(x) * y)
        f2 = d2.force_matrix(lambda x, y: np.sin(x) * y)
        np.testing.assert_allclose(f1, f2, atol=1e-8)
