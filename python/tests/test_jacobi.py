"""Legendre/Jacobi polynomial substrate tests (fem_py.jacobi, fem_py.basis)."""

import numpy as np
import pytest

from compile.fem_py import basis, jacobi


XS = np.linspace(-1.0, 1.0, 41)


class TestLegendre:
    def test_p0_p1(self):
        np.testing.assert_allclose(jacobi.legendre(0, XS), 1.0)
        np.testing.assert_allclose(jacobi.legendre(1, XS), XS)

    def test_closed_forms(self):
        np.testing.assert_allclose(
            jacobi.legendre(2, XS), 0.5 * (3 * XS**2 - 1), atol=1e-14)
        np.testing.assert_allclose(
            jacobi.legendre(3, XS), 0.5 * (5 * XS**3 - 3 * XS), atol=1e-14)
        np.testing.assert_allclose(
            jacobi.legendre(4, XS),
            (35 * XS**4 - 30 * XS**2 + 3) / 8.0, atol=1e-13)

    def test_endpoint_values(self):
        # P_n(1) = 1, P_n(-1) = (-1)^n
        for n in range(12):
            assert jacobi.legendre(n, np.array([1.0]))[0] == pytest.approx(1)
            assert jacobi.legendre(n, np.array([-1.0]))[0] == pytest.approx(
                (-1.0) ** n)

    def test_orthogonality(self):
        # int_-1^1 P_m P_n = 2/(2n+1) delta_mn via dense trapezoid
        x = np.linspace(-1, 1, 20001)
        for m in range(6):
            for n in range(6):
                integral = np.trapezoid(
                    jacobi.legendre(m, x) * jacobi.legendre(n, x), x)
                expected = 2.0 / (2 * n + 1) if m == n else 0.0
                assert integral == pytest.approx(expected, abs=5e-7)

    def test_deriv_matches_finite_difference(self):
        h = 1e-6
        x = np.linspace(-0.95, 0.95, 21)
        for n in range(1, 10):
            fd = (jacobi.legendre(n, x + h) - jacobi.legendre(n, x - h)) / (
                2 * h)
            np.testing.assert_allclose(
                jacobi.legendre_deriv(n, x), fd, rtol=1e-6, atol=1e-6)

    def test_deriv_at_endpoints(self):
        # P'_n(1) = n(n+1)/2 — the recurrence must be stable at +-1
        for n in range(1, 12):
            d = jacobi.legendre_deriv(n, np.array([1.0]))[0]
            assert d == pytest.approx(n * (n + 1) / 2.0)

    def test_all_variants_match_scalar(self):
        p = jacobi.legendre_all(8, XS)
        d = jacobi.legendre_deriv_all(8, XS)
        for n in range(9):
            np.testing.assert_allclose(p[n], jacobi.legendre(n, XS),
                                       atol=1e-14)
            np.testing.assert_allclose(d[n], jacobi.legendre_deriv(n, XS),
                                       atol=1e-12)


class TestJacobiGeneral:
    def test_reduces_to_legendre(self):
        for n in range(8):
            np.testing.assert_allclose(
                jacobi.jacobi(n, 0.0, 0.0, XS), jacobi.legendre(n, XS),
                atol=1e-13)

    def test_deriv_consistency(self):
        h = 1e-6
        x = np.linspace(-0.9, 0.9, 13)
        for n in range(1, 7):
            fd = (jacobi.jacobi(n, 1.0, 1.0, x + h)
                  - jacobi.jacobi(n, 1.0, 1.0, x - h)) / (2 * h)
            np.testing.assert_allclose(
                jacobi.jacobi_deriv(n, 1.0, 1.0, x), fd, rtol=1e-6,
                atol=1e-6)


class TestTestBasis:
    def test_vanishes_at_endpoints(self):
        ends = np.array([-1.0, 1.0])
        t = basis.test_fn_1d(10, ends)
        np.testing.assert_allclose(t, 0.0, atol=1e-12)

    def test_matches_definition(self):
        t = basis.test_fn_1d(6, XS)
        for j in range(1, 7):
            expect = jacobi.legendre(j + 1, XS) - jacobi.legendre(j - 1, XS)
            np.testing.assert_allclose(t[j - 1], expect, atol=1e-13)

    def test_grad_finite_difference(self):
        h = 1e-6
        x = np.linspace(-0.99, 0.99, 17)
        g = basis.test_grad_1d(6, x)
        tp = basis.test_fn_1d(6, x + h)
        tm = basis.test_fn_1d(6, x - h)
        np.testing.assert_allclose(g, (tp - tm) / (2 * h), rtol=1e-5,
                                   atol=1e-5)

    def test_2d_tensor_structure(self):
        xi = np.array([-0.3, 0.1, 0.8])
        eta = np.array([0.5, -0.7, 0.2])
        v, dxi, deta = basis.test_fn_2d(3, xi, eta)
        assert v.shape == (9, 3)
        t_xi = basis.test_fn_1d(3, xi)
        t_eta = basis.test_fn_1d(3, eta)
        for a in range(3):
            for b in range(3):
                np.testing.assert_allclose(
                    v[a * 3 + b], t_xi[a] * t_eta[b], atol=1e-14)
