"""Bilinear transformation tests (fem_py.transforms)."""

import numpy as np
import pytest

from compile.fem_py.transforms import BilinearMap

UNIT = np.array([[0.0, 0.0], [1.0, 0.0], [1.0, 1.0], [0.0, 1.0]])
SKEWED = np.array([[0.0, 0.0], [2.0, 0.3], [1.7, 1.9], [-0.2, 1.2]])


class TestAffineCase:
    def test_corners(self):
        bm = BilinearMap(UNIT)
        ref = np.array([[-1, -1], [1, -1], [1, 1], [-1, 1]], dtype=float)
        x, y = bm.map(ref[:, 0], ref[:, 1])
        np.testing.assert_allclose(np.stack([x, y], 1), UNIT, atol=1e-14)

    def test_center(self):
        bm = BilinearMap(UNIT)
        x, y = bm.map(0.0, 0.0)
        assert (x, y) == (pytest.approx(0.5), pytest.approx(0.5))

    def test_constant_jacobian(self):
        bm = BilinearMap(UNIT)
        xi = np.linspace(-1, 1, 7)
        _, _, _, _, det = bm.jacobian(xi, xi[::-1])
        np.testing.assert_allclose(det, 0.25, atol=1e-15)  # (h/2)^2

    def test_area_from_jacobian(self):
        # rectangle 3 x 0.5 -> det = 3/2 * 1/4 = 0.375 everywhere
        rect = np.array([[1, 1], [4, 1], [4, 1.5], [1, 1.5]], dtype=float)
        bm = BilinearMap(rect)
        _, _, _, _, det = bm.jacobian(np.array([0.3]), np.array([-0.8]))
        assert det[0] == pytest.approx(3 * 0.5 / 4)


class TestSkewedCase:
    def test_corners(self):
        bm = BilinearMap(SKEWED)
        ref = np.array([[-1, -1], [1, -1], [1, 1], [-1, 1]], dtype=float)
        x, y = bm.map(ref[:, 0], ref[:, 1])
        np.testing.assert_allclose(np.stack([x, y], 1), SKEWED, atol=1e-14)

    def test_jacobian_varies(self):
        bm = BilinearMap(SKEWED)
        _, _, _, _, d1 = bm.jacobian(np.array([-0.9]), np.array([-0.9]))
        _, _, _, _, d2 = bm.jacobian(np.array([0.9]), np.array([0.9]))
        assert abs(d1[0] - d2[0]) > 1e-3  # genuinely non-constant

    def test_jacobian_finite_difference(self):
        bm = BilinearMap(SKEWED)
        h = 1e-7
        xi, eta = np.array([0.37]), np.array([-0.21])
        j11, j12, j21, j22, _ = bm.jacobian(xi, eta)
        xp, yp = bm.map(xi + h, eta)
        xm, ym = bm.map(xi - h, eta)
        assert j11[0] == pytest.approx((xp - xm)[0] / (2 * h), rel=1e-6)
        assert j21[0] == pytest.approx((yp - ym)[0] / (2 * h), rel=1e-6)
        xp, yp = bm.map(xi, eta + h)
        xm, ym = bm.map(xi, eta - h)
        assert j12[0] == pytest.approx((xp - xm)[0] / (2 * h), rel=1e-6)
        assert j22[0] == pytest.approx((yp - ym)[0] / (2 * h), rel=1e-6)

    def test_inverse_roundtrip(self):
        bm = BilinearMap(SKEWED)
        rng = np.random.default_rng(3)
        xi = rng.uniform(-0.95, 0.95, 50)
        eta = rng.uniform(-0.95, 0.95, 50)
        x, y = bm.map(xi, eta)
        xi2, eta2 = bm.inverse_map(x, y)
        np.testing.assert_allclose(xi2, xi, atol=1e-10)
        np.testing.assert_allclose(eta2, eta, atol=1e-10)

    def test_grad_transform_chain_rule(self):
        """For u(x,y) = x^2 + 3xy, the transformed reference gradient must
        reproduce the analytic actual gradient at mapped points."""
        bm = BilinearMap(SKEWED)
        xi = np.linspace(-0.8, 0.8, 9)
        eta = np.linspace(0.8, -0.8, 9)
        h = 1e-7

        def u_of_ref(a, b):
            x, y = bm.map(a, b)
            return x * x + 3 * x * y

        dxi = (u_of_ref(xi + h, eta) - u_of_ref(xi - h, eta)) / (2 * h)
        deta = (u_of_ref(xi, eta + h) - u_of_ref(xi, eta - h)) / (2 * h)
        gx, gy = bm.grad_to_actual(dxi, deta, xi, eta)
        x, y = bm.map(xi, eta)
        np.testing.assert_allclose(gx, 2 * x + 3 * y, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(gy, 3 * x, rtol=1e-5, atol=1e-5)


class TestValidation:
    def test_bad_shape_raises(self):
        with pytest.raises(ValueError):
            BilinearMap(np.zeros((3, 2)))
