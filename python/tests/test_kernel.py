"""L1 Pallas kernel vs einsum oracle — the core correctness signal.

Hypothesis sweeps shapes; every variant is checked in both the forward
pass and reverse-mode gradients (the custom_vjp backward kernel).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref as kref
from compile.kernels import vpinn_residual as kp

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


shape_st = st.tuples(
    st.integers(min_value=1, max_value=12),   # NE
    st.integers(min_value=1, max_value=24),   # NT
    st.integers(min_value=1, max_value=40),   # NQ
)


class TestPickBlockElems:
    def test_divides(self):
        for ne in (1, 2, 7, 12, 36, 1024, 1760, 14080):
            be = kp.pick_block_elems(ne, 25, 400)
            assert ne % be == 0
            assert be >= 1

    def test_respects_vmem_budget(self):
        bytes_, be = kp.vmem_footprint_bytes(14080, 16, 25)
        assert bytes_ <= 4 * (1 << 20) or be == 1

    def test_prime_ne(self):
        assert kp.pick_block_elems(887, 25, 25) in (1, 887)


class TestPoissonForward:
    @settings(max_examples=25, deadline=None)
    @given(shape_st)
    def test_matches_ref(self, shape):
        ne, nt, nq = shape
        gx, gy = rand((ne, nt, nq), 0), rand((ne, nt, nq), 1)
        ux, uy = rand((ne, nq), 2), rand((ne, nq), 3)
        f = rand((ne, nt), 4)
        got = kp.vpinn_residual(gx, gy, ux, uy, f)
        want = kref.vpinn_residual_ref(gx, gy, ux, uy, f)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_explicit_tiny(self):
        # NE=1, NT=1, NQ=2 by hand
        gx = jnp.array([[[1.0, 2.0]]])
        gy = jnp.array([[[0.5, -1.0]]])
        ux = jnp.array([[3.0, 4.0]])
        uy = jnp.array([[2.0, 2.0]])
        f = jnp.array([[1.0]])
        # 1*3+2*4 + 0.5*2-1*2 - 1 = 11 - 1 - 1 = 9
        got = kp.vpinn_residual(gx, gy, ux, uy, f)
        assert float(got[0, 0]) == pytest.approx(9.0, rel=1e-6)

    def test_block_elems_override(self):
        gx, gy = rand((8, 4, 9), 5), rand((8, 4, 9), 6)
        ux, uy = rand((8, 9), 7), rand((8, 9), 8)
        f = rand((8, 4), 9)
        for be in (1, 2, 4, 8):
            got = kp._poisson_fwd_raw(gx, gy, ux, uy, f, block_elems=be)
            want = kref.vpinn_residual_ref(gx, gy, ux, uy, f)
            np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestPoissonGrad:
    @settings(max_examples=10, deadline=None)
    @given(shape_st)
    def test_grad_matches_ref(self, shape):
        ne, nt, nq = shape
        gx, gy = rand((ne, nt, nq), 10), rand((ne, nt, nq), 11)
        ux, uy = rand((ne, nq), 12), rand((ne, nq), 13)
        f = rand((ne, nt), 14)

        def loss_p(ux, uy):
            r = kp.vpinn_residual(gx, gy, ux, uy, f)
            return jnp.sum(r * r)

        def loss_r(ux, uy):
            r = kref.vpinn_residual_ref(gx, gy, ux, uy, f)
            return jnp.sum(r * r)

        gp = jax.grad(loss_p, argnums=(0, 1))(ux, uy)
        gr = jax.grad(loss_r, argnums=(0, 1))(ux, uy)
        np.testing.assert_allclose(gp[0], gr[0], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gp[1], gr[1], rtol=1e-4, atol=1e-4)

    def test_grad_wrt_f(self):
        gx, gy = rand((3, 5, 7), 20), rand((3, 5, 7), 21)
        ux, uy = rand((3, 7), 22), rand((3, 7), 23)
        f = rand((3, 5), 24)

        def lp(f):
            r = kp.vpinn_residual(gx, gy, ux, uy, f)
            return jnp.sum(r * r)

        def lr(f):
            r = kref.vpinn_residual_ref(gx, gy, ux, uy, f)
            return jnp.sum(r * r)

        np.testing.assert_allclose(jax.grad(lp)(f), jax.grad(lr)(f),
                                   rtol=1e-4, atol=1e-4)


class TestContractT:
    @settings(max_examples=15, deadline=None)
    @given(shape_st)
    def test_matches_einsum(self, shape):
        ne, nt, nq = shape
        g = rand((ne, nt, nq), 30)
        r = rand((ne, nt), 31)
        got = kp.contract_t(g, r)
        want = jnp.einsum("ejq,ej->eq", g, r)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestCdVariant:
    @settings(max_examples=15, deadline=None)
    @given(shape_st,
           st.floats(min_value=0.01, max_value=5.0),
           st.floats(min_value=-2.0, max_value=2.0),
           st.floats(min_value=-2.0, max_value=2.0))
    def test_matches_ref(self, shape, eps, bx, by):
        ne, nt, nq = shape
        gx, gy, v = (rand((ne, nt, nq), s) for s in (40, 41, 42))
        ux, uy = rand((ne, nq), 43), rand((ne, nq), 44)
        f = rand((ne, nt), 45)
        got = kp.vpinn_residual_cd(gx, gy, v, ux, uy, f, eps, bx, by)
        want = kref.vpinn_residual_cd_ref(gx, gy, v, ux, uy, f, eps, bx, by)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_grad(self):
        gx, gy, v = (rand((4, 6, 10), s) for s in (50, 51, 52))
        ux, uy = rand((4, 10), 53), rand((4, 10), 54)
        f = rand((4, 6), 55)

        def lp(ux, uy):
            r = kp.vpinn_residual_cd(gx, gy, v, ux, uy, f, 0.7, 1.2, -0.4)
            return jnp.sum(r * r)

        def lr(ux, uy):
            r = kref.vpinn_residual_cd_ref(
                gx, gy, v, ux, uy, f, 0.7, 1.2, -0.4)
            return jnp.sum(r * r)

        gp = jax.grad(lp, argnums=(0, 1))(ux, uy)
        gr = jax.grad(lr, argnums=(0, 1))(ux, uy)
        np.testing.assert_allclose(gp[0], gr[0], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gp[1], gr[1], rtol=1e-4, atol=1e-4)


class TestSpaceEpsVariant:
    @settings(max_examples=15, deadline=None)
    @given(shape_st)
    def test_matches_ref(self, shape):
        ne, nt, nq = shape
        gx, gy, v = (rand((ne, nt, nq), s) for s in (60, 61, 62))
        ux, uy = rand((ne, nq), 63), rand((ne, nq), 64)
        eps_q = rand((ne, nq), 65)
        f = rand((ne, nt), 66)
        got = kp.vpinn_residual_space_eps(
            gx, gy, v, ux, uy, eps_q, f, 1.0, 0.0)
        want = kref.vpinn_residual_space_eps_ref(
            gx, gy, v, ux, uy, eps_q, f, 1.0, 0.0)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_grad_including_eps(self):
        gx, gy, v = (rand((4, 6, 10), s) for s in (70, 71, 72))
        ux, uy = rand((4, 10), 73), rand((4, 10), 74)
        eps_q = rand((4, 10), 75)
        f = rand((4, 6), 76)

        def lp(ux, uy, eps_q):
            r = kp.vpinn_residual_space_eps(
                gx, gy, v, ux, uy, eps_q, f, 1.0, 0.0)
            return jnp.sum(r * r)

        def lr(ux, uy, eps_q):
            r = kref.vpinn_residual_space_eps_ref(
                gx, gy, v, ux, uy, eps_q, f, 1.0, 0.0)
            return jnp.sum(r * r)

        gp = jax.grad(lp, argnums=(0, 1, 2))(ux, uy, eps_q)
        gr = jax.grad(lr, argnums=(0, 1, 2))(ux, uy, eps_q)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


class TestUnderJit:
    def test_jit_compiles_and_matches(self):
        gx, gy = rand((6, 9, 16), 80), rand((6, 9, 16), 81)
        ux, uy = rand((6, 16), 82), rand((6, 16), 83)
        f = rand((6, 9), 84)
        got = jax.jit(kp.vpinn_residual)(gx, gy, ux, uy, f)
        want = kref.vpinn_residual_ref(gx, gy, ux, uy, f)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
