"""AOT pipeline tests: specs, signatures, manifests, HLO emission."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, specs

jax.config.update("jax_platform_name", "cpu")


class TestSpecs:
    def test_names_unique(self):
        names = [s.name for s in specs.build_specs(paper_scale=True)]
        assert len(names) == len(set(names))

    def test_ci_subset_of_paper(self):
        ci = {s.name for s in specs.build_specs(paper_scale=False)}
        paper = {s.name for s in specs.build_specs(paper_scale=True)}
        # paper-scale adds artifacts; must not *remove* shared CI ones
        # except those whose config legitimately changes (gear)
        assert len(ci - paper) <= 1  # fv_cd_gear changes shape

    def test_fig11_total_quad_constant(self):
        for name in ("fv_poisson_ne4_nt5_nq40", "fv_poisson_ne16_nt5_nq20",
                     "fv_poisson_ne64_nt5_nq10"):
            s = specs.spec_by_name(name)
            assert s is not None
            assert s.ne * s.nq == 6400

    def test_spec_by_name_missing(self):
        assert specs.spec_by_name("nope") is None


class TestSignature:
    def test_poisson_signature_order(self):
        s = specs.spec_by_name("fv_poisson_ne4_nt5_nq20")
        ins, outs = aot.signature(s)
        names = [n for n, _ in ins]
        # 8 params + 8 m + 8 v + step + lr + 7 data
        assert names[:8] == [f"p{i}" for i in range(8)]
        assert names[8:16] == [f"m{i}" for i in range(8)]
        assert names[16:24] == [f"v{i}" for i in range(8)]
        assert names[24:26] == ["step", "lr"]
        assert names[26:] == ["quad_xy", "gx", "gy", "f", "bd_xy", "bd_u",
                              "tau"]
        assert outs[-3:] == ["loss", "var_loss", "bd_loss"]

    def test_inverse_const_has_eps_param(self):
        s = specs.spec_by_name("fv_inverse_const_ne4_nt5_nq40")
        ins, outs = aot.signature(s)
        names = [n for n, _ in ins]
        # 9 param slots (8 arrays + eps scalar)
        assert "p8" in names and "m8" in names and "v8" in names
        shp = dict(ins)
        assert shp["p8"] == ()
        assert "sensor_xy" in names and "gamma" in names
        assert outs[-1] == "sensor_loss"

    def test_shapes_match_spec(self):
        s = specs.spec_by_name("fv_poisson_ne16_nt5_nq20")
        shp = dict(aot.signature(s)[0])
        assert tuple(shp["gx"]) == (16, 25, 400)
        assert tuple(shp["quad_xy"]) == (16 * 400, 2)
        assert tuple(shp["bd_xy"]) == (s.nb, 2)

    def test_predict_signature(self):
        s = specs.spec_by_name("predict_inv2_16k")
        ins, outs = aot.signature(s)
        assert ins[-1][0] == "xy"
        assert outs == ["u", "eps"]


class TestManifest:
    def test_manifest_roundtrip(self):
        s = specs.spec_by_name("fv_poisson_ne4_nt5_nq20")
        man = aot.manifest(s)
        text = json.dumps(man)
        back = json.loads(text)
        assert back["name"] == s.name
        assert back["config"]["ne"] == 4
        assert back["config"]["kernel"] == "pallas"
        assert len(back["inputs"]) == len(aot.signature(s)[0])


class TestLowering:
    def test_tiny_spec_lowers_to_hlo_text(self):
        s = specs.Spec(name="tmp_test", kind="train", loss="poisson",
                       layers=(2, 4, 1), ne=1, nt1d=2, nq1d=3, nb=8)
        text = aot.lower_spec(s)
        assert text.startswith("HloModule")
        assert "custom-call" not in text
        # parameter count must match signature
        n_in = len(aot.signature(s)[0])
        assert f"parameter({n_in - 1})" in text

    def test_lowered_step_executes_like_python(self):
        """The lowered fn and the python fn must agree numerically."""
        s = specs.Spec(name="tmp_exec", kind="train", loss="poisson",
                       layers=(2, 4, 1), ne=1, nt1d=2, nq1d=3, nb=8)
        ins, _ = aot.signature(s)
        rng = np.random.default_rng(0)
        args = [jnp.asarray(rng.normal(0, 0.3, shape), jnp.float32)
                for _, shape in ins]
        fn = aot.build_fn(s)
        out_py = fn(*args)
        out_jit = jax.jit(fn)(*args)
        for a, b in zip(out_py, out_jit):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_predict_lowering(self):
        s = specs.Spec(name="tmp_pred", kind="predict", layers=(2, 4, 1),
                       n_eval=16)
        text = aot.lower_spec(s)
        assert text.startswith("HloModule")


class TestParamArrayCount:
    def test_counts(self):
        s = specs.spec_by_name("fv_poisson_ne4_nt5_nq20")
        assert aot.n_param_arrays(s) == 8
        s = specs.spec_by_name("fv_inverse_const_ne4_nt5_nq40")
        assert aot.n_param_arrays(s) == 9
        s = specs.spec_by_name("fv_cd_gear")
        assert aot.n_param_arrays(s) == 8


class TestKernelAutoSelect:
    def test_small_tensors_use_pallas(self):
        s = specs.spec_by_name("fv_poisson_ne4_nt5_nq20")
        assert s.kernel == "pallas"

    def test_large_tensors_fall_back_to_einsum(self):
        # 400 * 400 * 100 = 16M words > PALLAS_CPU_MAX_WORDS
        s = specs.spec_by_name("fv_poisson_ne400_nt20_nq10")
        assert s.kernel == "einsum"

    def test_threshold_boundary(self):
        assert specs.PALLAS_CPU_MAX_WORDS == 2_000_000
        # fig08 artifact sits just under the threshold: stays pallas
        s = specs.spec_by_name("fv_poisson_ne4_nt15_nq40")
        assert s.ne * s.nt * s.nq <= specs.PALLAS_CPU_MAX_WORDS
        assert s.kernel == "pallas"
