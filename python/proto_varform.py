"""Numpy prototype of the generalized variational-form native step.

Validation harness for the VariationalForm refactor in
rust/src/runtime/backend/{form.rs,native.rs} (no rust toolchain in the
dev container). It transliterates the generalized residual

    r[e,j] = sum_q eps_q (Gx ux + Gy uy) + sum_q V (b_q . grad u + c_q u) - F

and its hand-written adjoints — per-point eps/b/c tables, constant fast
path, the reaction seed (seed_u = c_q V^T r), the trainable-scalar mode
and the two-head eps-field mode — and checks every parameter gradient
against complex-step differentiation at machine precision. It then
sizes the budgets asserted by the new helmholtz/cd_var e2e tests and
the `train --problem helmholtz` acceptance run.

Run:  python3 python/proto_varform.py          # gradchecks + e2e budgets
      python3 python/proto_varform.py --accept # + CLI-scale acceptance
"""
import sys
import time
import numpy as np

sys.path.insert(0, "python/compile")
from fem_py import mesh as pmesh, assembly  # noqa: E402

from proto_two_head import (  # noqa: E402
    TwoHeadNet, boundary_square, complex_step_grad, sigmoid,
)


# ---------------------------------------------------------------------
# Generalized objective: eps/b/c as per-point tables or constants
# ---------------------------------------------------------------------
class FormObjective:
    """loss = var + tau*bd + gamma*sensor with a VariationalForm.

    eps/bx/by/c are each either a float (constant fast path) or an
    (ne*nq,) table. mode: "forward" | "const" | "space".
    """

    def __init__(self, dom, fmat, bd_pts, bd_u, s_pts, s_u,
                 eps=1.0, bx=0.0, by=0.0, c=0.0,
                 tau=10.0, gamma=10.0, mode="forward", eps_const=None):
        self.dom, self.fmat = dom, fmat
        self.bd_pts, self.bd_u = bd_pts, bd_u
        self.s_pts, self.s_u = s_pts, s_u
        self.eps, self.bx, self.by, self.c = eps, bx, by, c
        self.tau, self.gamma = tau, gamma
        self.mode = mode
        self.eps_const = eps_const

    def _tab(self, v):
        """Coefficient as an (ne, nq) array regardless of class."""
        ne, nq = self.dom.n_elem, self.dom.n_quad
        if np.isscalar(v):
            return np.full((ne, nq), v)
        return np.asarray(v).reshape(ne, nq)

    def _conv_reac(self):
        conv = (not np.isscalar(self.bx) or self.bx != 0.0
                or not np.isscalar(self.by) or self.by != 0.0)
        reac = not np.isscalar(self.c) or self.c != 0.0
        return conv, reac

    def loss(self, net, eps_const=None):
        """Pure forward loss (complex-safe) for gradchecking."""
        dom = self.dom
        ne, nt, nq = dom.n_elem, dom.n_test, dom.n_quad
        u, ux, uy, eps_h, _ = net.forward(dom.quad_xy)
        ue = u.reshape(ne, nq)
        uxe = ux.reshape(ne, nq)
        uye = uy.reshape(ne, nq)
        if self.mode == "space":
            epse = eps_h.reshape(ne, nq)
        elif self.mode == "const":
            ec = self.eps_const if eps_const is None else eps_const
            epse = np.full((ne, nq), ec)
        else:
            epse = self._tab(self.eps)
        r = (np.einsum("ejq,eq->ej", dom.gx, epse * uxe)
             + np.einsum("ejq,eq->ej", dom.gy, epse * uye)
             - self.fmat)
        conv, reac = self._conv_reac()
        if conv or reac:
            vq = 0.0
            if conv:
                vq = self._tab(self.bx) * uxe + self._tab(self.by) * uye
            if reac:
                vq = vq + self._tab(self.c) * ue
            r = r + np.einsum("ejq,eq->ej", dom.v, vq)
        var = (r * r).sum() / (ne * nt)
        ub, _, _, _, _ = net.forward(self.bd_pts)
        bd = ((ub - self.bd_u) ** 2).sum() / len(self.bd_u)
        total = var + self.tau * bd
        if len(self.s_u):
            us, _, _, _, _ = net.forward(self.s_pts)
            total = total + self.gamma * (
                (us - self.s_u) ** 2).sum() / len(self.s_u)
        return total

    def loss_and_grad(self, net):
        """Hand-written adjoints — the Rust transliteration."""
        dom = self.dom
        ne, nt, nq = dom.n_elem, dom.n_test, dom.n_quad
        cr = 2.0 / (ne * nt)
        grads = [[np.zeros_like(w), np.zeros_like(b)]
                 for w, b in net.params]
        u, ux, uy, eps_h, cache = net.forward(dom.quad_xy)
        ue = u.reshape(ne, nq)
        uxe = ux.reshape(ne, nq)
        uye = uy.reshape(ne, nq)
        space = self.mode == "space"
        if space:
            epse = eps_h.reshape(ne, nq)
        elif self.mode == "const":
            epse = np.full((ne, nq), self.eps_const)
        else:
            epse = self._tab(self.eps)
        cv_pre = (np.einsum("ejq,eq->ej", dom.gx, uxe)
                  + np.einsum("ejq,eq->ej", dom.gy, uye))
        r = (np.einsum("ejq,eq->ej", dom.gx, epse * uxe)
             + np.einsum("ejq,eq->ej", dom.gy, epse * uye)
             - self.fmat)
        conv, reac = self._conv_reac()
        if conv or reac:
            vq = 0.0
            if conv:
                vq = self._tab(self.bx) * uxe + self._tab(self.by) * uye
            if reac:
                vq = vq + self._tab(self.c) * ue
            r = r + np.einsum("ejq,eq->ej", dom.v, vq)
        var = (r * r).sum() / (ne * nt)
        # seeds (the Rust block_seeds transliteration)
        tgx = cr * np.einsum("ejq,ej->eq", dom.gx, r)
        tgy = cr * np.einsum("ejq,ej->eq", dom.gy, r)
        ge = (tgx * uxe + tgy * uye).ravel() if space else None
        sx = epse * tgx
        sy = epse * tgy
        su = np.zeros((ne, nq))
        geps_const = 0.0
        if self.mode == "const":
            geps_const = cr * (r * cv_pre).sum()
        if conv or reac:
            tv = cr * np.einsum("ejq,ej->eq", dom.v, r)
            if conv:
                sx = sx + self._tab(self.bx) * tv
                sy = sy + self._tab(self.by) * tv
            if reac:
                su = self._tab(self.c) * tv
        net.backward(dom.quad_xy, cache, su.ravel(), sx.ravel(),
                     sy.ravel(), ge, grads)
        # boundary
        ub, _, _, _, cb = net.forward(self.bd_pts)
        nb = len(self.bd_u)
        d = ub - self.bd_u
        bd = (d * d).sum() / nb
        net.backward(self.bd_pts, cb, 2.0 * self.tau / nb * d,
                     np.zeros(nb), np.zeros(nb),
                     np.zeros(nb) if net.two_head else None, grads)
        total = var + self.tau * bd
        sens = 0.0
        if len(self.s_u):
            us, _, _, _, cs = net.forward(self.s_pts)
            ns = len(self.s_u)
            d = us - self.s_u
            sens = (d * d).sum() / ns
            net.backward(self.s_pts, cs, 2.0 * self.gamma / ns * d,
                         np.zeros(ns), np.zeros(ns),
                         np.zeros(ns) if net.two_head else None, grads)
            total = total + self.gamma * sens
        flat = np.concatenate([np.concatenate([gw.ravel(), gb])
                               for gw, gb in grads])
        return total, flat, geps_const, (var, bd, sens)


# ---------------------------------------------------------------------
# Problems
# ---------------------------------------------------------------------
def helmholtz_exact(k):
    return lambda x, y: np.sin(k * x) * np.sin(k * y)


def build_helmholtz(k, n=2, nt1d=3, nq1d=8, nb=80):
    pts, cells = pmesh.unit_square(n)
    dom = assembly.assemble(pts, cells, nt1d, nq1d)
    u = helmholtz_exact(k)
    x = dom.quad_xy[:, 0].reshape(dom.n_elem, dom.n_quad)
    y = dom.quad_xy[:, 1].reshape(dom.n_elem, dom.n_quad)
    # f = -lap u - k^2 u = (2k^2 - k^2) u = k^2 u
    fmat = np.einsum("ejq,eq->ej", dom.v, k * k * u(x, y))
    bd = boundary_square(nb)
    bd_u = u(bd[:, 0], bd[:, 1])
    return FormObjective(dom, fmat, bd, bd_u, np.zeros((0, 2)),
                         np.zeros(0), eps=1.0, c=-k * k), u


def cd_var_b(x, y, omr=2.0):
    return omr * (y - 0.5), omr * (0.5 - x)


def build_cd_var(n=2, nt1d=3, nq1d=8, nb=80):
    pts, cells = pmesh.unit_square(n)
    dom = assembly.assemble(pts, cells, nt1d, nq1d)

    def u(x, y):
        return np.sin(np.pi * x) * np.sin(np.pi * y)

    x = dom.quad_xy[:, 0].reshape(dom.n_elem, dom.n_quad)
    y = dom.quad_xy[:, 1].reshape(dom.n_elem, dom.n_quad)
    bx, by = cd_var_b(x, y)
    ux = np.pi * np.cos(np.pi * x) * np.sin(np.pi * y)
    uy = np.pi * np.sin(np.pi * x) * np.cos(np.pi * y)
    lap = -2.0 * np.pi * np.pi * u(x, y)
    f = -lap + bx * ux + by * uy
    fmat = np.einsum("ejq,eq->ej", dom.v, f)
    bxq, byq = cd_var_b(dom.quad_xy[:, 0], dom.quad_xy[:, 1])
    bd = boundary_square(nb)
    bd_u = u(bd[:, 0], bd[:, 1])
    return FormObjective(dom, fmat, bd, bd_u, np.zeros((0, 2)),
                         np.zeros(0), eps=1.0, bx=bxq, by=byq), u


def rel_l2(net, exact, grid_n=50, lo=0.0, hi=1.0):
    g = np.linspace(lo, hi, grid_n)
    X, Y = np.meshgrid(g, g)
    p = np.stack([X.ravel(), Y.ravel()], 1)
    u, _, _, _, _ = net.forward(p)
    ref = exact(p[:, 0], p[:, 1])
    return np.sqrt(((u - ref) ** 2).sum() / (ref ** 2).sum())


# ---------------------------------------------------------------------
def gradchecks():
    print("== gradchecks: generalized adjoints vs complex step ==")
    pts, cells = pmesh.unit_square(1)
    dom = assembly.assemble(pts, cells, 2, 3)
    ne, nq = dom.n_elem, dom.n_quad
    rng = np.random.default_rng(0)
    xq, yq = dom.quad_xy[:, 0], dom.quad_xy[:, 1]
    fmat = np.einsum("ejq,eq->ej",
                     dom.v, (np.sin(xq) * np.cos(yq) + 0.5)
                     .reshape(ne, nq))
    bd = boundary_square(8)
    bd_u = np.sin(1.3 * bd[:, 0]) * np.cos(0.7 * bd[:, 1])
    sp = rng.uniform(0.05, 0.95, (4, 2))
    s_u = np.sin(1.3 * sp[:, 0]) * np.cos(0.7 * sp[:, 1])
    nope = (np.zeros((0, 2)), np.zeros(0))

    # coefficient tables mirroring the Rust TestProblem fields
    eps_tab = 0.9 * (1.0 + 0.3 * np.sin(xq + yq))
    bx_tab = 0.3 + 0.2 * np.cos(yq)
    by_tab = -0.2 + 0.3 * np.sin(xq)
    c_tab = -1.5 + 0.2 * np.cos(xq * yq)

    cases = [
        ("poisson const", dict(eps=1.0), "forward", False, False),
        ("cd const", dict(eps=0.7, bx=0.3, by=-0.2), "forward",
         False, False),
        ("helmholtz c=-6.25", dict(eps=1.0, c=-6.25), "forward",
         False, False),
        ("var b", dict(eps=0.8, bx=bx_tab, by=by_tab), "forward",
         False, False),
        ("var eps", dict(eps=eps_tab), "forward", False, False),
        ("all var + reac",
         dict(eps=eps_tab, bx=bx_tab, by=by_tab, c=c_tab), "forward",
         False, False),
        ("inv_const + conv + reac", dict(eps=1.0, bx=0.2, by=-0.1,
                                         c=-0.8), "const", False, True),
        ("two-head + conv", dict(eps=1.0, bx=1.0), "space", True, True),
        ("two-head + reac + var b",
         dict(eps=1.0, bx=0.5 + 0.2 * np.cos(yq),
              by=-0.4 + 0.3 * np.sin(xq), c=-1.1 + 0.2 *
              np.cos(xq * yq)), "space", True, True),
    ]
    for label, coeffs, mode, two_head, sensors in cases:
        spts, svals = (sp, s_u) if sensors else nope
        obj = FormObjective(dom, fmat, bd, bd_u, spts, svals,
                            mode=mode, **coeffs)
        if mode == "const":
            obj.eps_const = 0.7
        net = TwoHeadNet([2, 4, 1], seed=3, two_head=two_head)
        _, g, ge, _ = obj.loss_and_grad(net)
        if mode == "const":
            gref, geref = complex_step_grad(obj, net, eps_const=0.7)
            assert abs(ge - geref) < 1e-10 * (1 + abs(ge)), label
        else:
            gref, _ = complex_step_grad(obj, net)
        rel = np.abs(g - gref) / (1.0 + np.maximum(np.abs(g),
                                                   np.abs(gref)))
        print(f"  {label:<28} max rel err {rel.max():.2e}")
        assert rel.max() < 1e-12, (label, rel.max())


def adam_sched(obj, net, iters, lr_fn):
    """Adam with a per-step lr schedule (the Rust LrSchedule analogue)."""
    theta = net.flat()
    m = np.zeros(theta.size)
    v = np.zeros(theta.size)
    b1, b2, ae = 0.9, 0.999, 1e-8
    for t in range(1, iters + 1):
        _, g, _, _ = obj.loss_and_grad(net)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        theta -= lr_fn(t - 1) * (m / (1 - b1 ** t)) / (
            np.sqrt(v / (1 - b2 ** t)) + ae)
        net.set_flat(theta)


def e2e_budgets():
    # the exact Rust release-tier config: unit_square(2), nt=3, nq=8,
    # net [2,16,16,1], nb=80, ExpDecay(1e-2, x0.5 every 500), 3000
    # iters -> the tests assert rel-L2 < 5e-2 (measured here:
    # helmholtz 0.8-2.6e-2, cd_var 0.8-1.3e-2 across seeds)
    lr_fn = lambda s: 1e-2 * 0.5 ** (s // 500)  # noqa: E731
    print("== helmholtz e2e budget (rust native_e2e hyperparams) ==")
    k = np.pi
    for seed in [1, 2, 3]:
        obj, u = build_helmholtz(k)
        net = TwoHeadNet([2, 16, 16, 1], seed=seed, two_head=False)
        t0 = time.time()
        adam_sched(obj, net, 3000, lr_fn)
        print(f"  seed {seed}: rel-L2 {rel_l2(net, u):.2e}, "
              f"{time.time()-t0:.1f}s")

    print("== cd_var e2e budget ==")
    for seed in [1, 2, 3]:
        obj, u = build_cd_var()
        net = TwoHeadNet([2, 16, 16, 1], seed=seed, two_head=False)
        t0 = time.time()
        adam_sched(obj, net, 3000, lr_fn)
        print(f"  seed {seed}: rel-L2 {rel_l2(net, u):.2e}, "
              f"{time.time()-t0:.1f}s")


def acceptance():
    """Exact-seed replica of `train --problem helmholtz` (registry
    defaults): k = 2pi on unit_square(2) — the coarse mesh keeps the
    per-element forcing projections (and with them the variational
    signal) strong against the boundary penalty; on the 4x4 mesh the
    run collapses into the u ~ 0 boundary-satisfying saddle and the
    (k^2-weak) forcing cannot pull it out within the budget (observed
    rel-L2 ~ 1 after 5000 iters for k = pi AND k = 2pi, while plain
    Poisson at omega = 2pi escapes the same saddle at ~2500 iters
    because its forcing is 2x stronger). nt=5, nq=10, net
    [2,30,30,30,1], nb=400 via the RustRng boundary-sampler port,
    Mlp::glorot seed-42 init via the RustRng port, 12000 iters with
    ExpDecay(5e-3, x0.7 every 1500) — the tight lr tail damps the
    late rel-L2 wander a constant rate shows near the accuracy floor.

    Measured at 12000 iters: rel-L2 6.4e-3 (Rust init seed 42),
    7.8e-3 (seed 1), 3.6e-3/7.6e-3 (seeds 7/123 on the gentler 0.7/2000
    tail) — the `cargo run --release -- train --problem helmholtz`
    acceptance bar (< 1e-2) holds with margin.
    """
    import proto_rust_seed_check as rsc
    from fem_py import mesh as pmesh

    print("== CLI acceptance: train --problem helmholtz defaults ==")
    k = 2.0 * np.pi
    obj, u = build_helmholtz(k, n=2, nt1d=5, nq1d=10, nb=400)
    pts, cells = pmesh.unit_square(2)
    edges = rsc.compute_boundary(pts, cells)
    bd = rsc.sample_boundary(pts, edges, 400)
    obj.bd_pts = bd
    obj.bd_u = u(bd[:, 0], bd[:, 1])
    net = rsc.rust_net([2, 30, 30, 30, 1], 42, False)
    theta = net.flat()
    m = np.zeros(theta.size)
    v = np.zeros(theta.size)
    b1, b2, ae = 0.9, 0.999, 1e-8
    t0 = time.time()
    marks = {}
    for t in range(1, 12001):
        _, g, _, _ = obj.loss_and_grad(net)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        lr = 5e-3 * 0.7 ** ((t - 1) // 1500)
        theta -= lr * (m / (1 - b1 ** t)) / (np.sqrt(v / (1 - b2 ** t))
                                             + ae)
        net.set_flat(theta)
        if t % 3000 == 0:
            marks[t] = rel_l2(net, u)
    print("  rel-L2 "
          + " ".join(f"{t}:{v_:.2e}" for t, v_ in sorted(marks.items()))
          + f", {time.time()-t0:.1f}s")
    assert marks[12000] < 1e-2, "acceptance bar rel-L2 < 1e-2 violated"


if __name__ == "__main__":
    gradchecks()
    e2e_budgets()
    if "--accept" in sys.argv:
        acceptance()
