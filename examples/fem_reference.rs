//! The classical FEM substrate on its own: solve the omega = pi Poisson
//! problem on a sequence of refined meshes and verify O(h^2)
//! convergence, then export a VTK field. This is the "ParMooN stand-in"
//! used as reference for the gear and disk experiments — no artifacts
//! or PJRT involved.
//!
//!     cargo run --release --example fem_reference

use fastvpinns::fem_solver::{self, FemProblem};
use fastvpinns::mesh::{generators, vtk};

fn main() -> anyhow::Result<()> {
    let om = std::f64::consts::PI;
    let exact = move |x: f64, y: f64| (om * x).sin() * (om * y).sin();
    let f = move |x: f64, y: f64| {
        2.0 * om * om * (om * x).sin() * (om * y).sin()
    };

    println!("{:>6} {:>10} {:>12} {:>8}", "n", "DOFs", "L2 error",
             "rate");
    let mut last_err: Option<f64> = None;
    for n in [8usize, 16, 32, 64] {
        let mesh = generators::unit_square(n);
        let sol = fem_solver::solve(&mesh, &FemProblem {
            eps: &|_, _| 1.0,
            b: None,
            c: None,
            f: &f,
            g: &|_, _| 0.0,
        }, 3)?;
        let err = {
            let mut acc = 0.0;
            for (i, p) in mesh.points.iter().enumerate() {
                let d = sol.u[i] - exact(p[0], p[1]);
                acc += d * d;
            }
            (acc / mesh.n_points() as f64).sqrt()
        };
        let rate = last_err
            .map(|e| (e / err).log2())
            .map(|r| format!("{r:.2}"))
            .unwrap_or_else(|| "-".into());
        println!("{n:>6} {:>10} {err:>12.3e} {rate:>8}", mesh.n_points());
        last_err = Some(err);

        if n == 64 {
            let field: Vec<f64> = sol.u.clone();
            vtk::write_point_fields(&mesh, &[("u", &field)],
                                    "results/fem_reference.vtk")
                .or_else(|_| {
                    std::fs::create_dir_all("results")?;
                    vtk::write_point_fields(&mesh, &[("u", &field)],
                                            "results/fem_reference.vtk")
                })?;
            println!("field -> results/fem_reference.vtk");
        }
    }
    // second-order convergence check (rate ~2 between last meshes)
    println!("fem_reference OK");
    Ok(())
}
