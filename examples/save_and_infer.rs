//! Train once, query many: the amortized-inference story on the gear
//! geometry. Trains the convection-diffusion problem on the 1760-cell
//! spur gear, exports a versioned checkpoint, then serves two query
//! workloads from the artifact alone — the mesh nodes (VTK output for
//! ParaView) and a dense uniform grid (streamed CSV) — through the
//! batched blocked-GEMM inference path, verifying the reloaded model
//! reproduces the trainer's predictions bit-for-bit.
//!
//!     cargo run --release --example save_and_infer
//!
//! Flags via env: SAVE_ITERS (default 400).

use std::time::Instant;

use fastvpinns::coordinator::metrics::eval_grid;
use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::mesh::{generators, vtk};
use fastvpinns::problems::GearCd;
use fastvpinns::runtime::backend::native::{
    NativeBackend, NativeConfig, NativeLoss,
};
use fastvpinns::runtime::backend::BackendOpts;
use fastvpinns::runtime::infer::InferenceSession;
use fastvpinns::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("SAVE_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let dir = std::path::PathBuf::from("results/save_and_infer");
    std::fs::create_dir_all(&dir)?;

    // 1. train once (the expensive part)
    let problem = GearCd;
    let mesh = generators::gear_ci();
    let domain = assembly::assemble(&mesh, 4, 5, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&domain),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters,
        lr: LrSchedule::Constant(5e-3),
        log_every: 50,
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 30, 30, 30, 1],
        loss: NativeLoss::Forward,
        nb: 400,
        ns: 0,
    };
    let backend = NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg))?;
    let mut trainer = Trainer::new(Box::new(backend), &cfg);
    let report = trainer.run()?;
    println!("trained {} iters on {} gear cells: loss {:.3e}, \
              {:.2} ms/step median",
             report.steps, mesh.n_cells(), report.final_loss,
             report.median_step_ms);

    // 2. persist the model (registry id so `repro infer --quad` /
    //    `repro train --resume` can rebuild the setup)
    let ckpt_path = dir.join("gear.ckpt");
    let mut ck = trainer.checkpoint()?;
    ck.problem = "cd_gear".into();
    ck.write(&ckpt_path)?;
    println!("checkpoint -> {} ({} bytes)", ckpt_path.display(),
             std::fs::metadata(&ckpt_path)?.len());

    // 3. serve from the artifact alone — no mesh assembly, no trainer
    let mut sess = InferenceSession::open(&ckpt_path)?;

    // query workload A: the mesh nodes, written as VTK for ParaView
    let (u_nodes, _) = sess.eval(&mesh.points);
    let u_f64: Vec<f64> = u_nodes.iter().map(|&v| v as f64).collect();
    let vtk_path = dir.join("gear_u.vtk");
    vtk::write_point_fields(&mesh, &[("u", &u_f64)], &vtk_path)?;
    println!("mesh-node field -> {}", vtk_path.display());

    // the reloaded model must reproduce the live trainer bit-for-bit
    assert_eq!(u_nodes, trainer.predict(&mesh.points)?,
               "checkpointed predictions must be bit-identical");

    // query workload B: a dense grid over the gear bbox, streamed to
    // CSV in batches — the serve-many half of train-once/query-many
    let (lo, hi) = mesh.bbox();
    let grid = eval_grid(200, 200, lo[0], lo[1], hi[0], hi[1]);
    let csv_path = dir.join("gear_grid.csv");
    let mut w = CsvWriter::create(&csv_path, &["x", "y", "u"])?;
    let t0 = Instant::now();
    for chunk in grid.chunks(4096) {
        let u = sess.eval_u(chunk);
        for (p, &v) in chunk.iter().zip(&u) {
            w.row_f64(&[p[0], p[1], v as f64])?;
        }
    }
    w.flush()?;
    let secs = t0.elapsed().as_secs_f64();
    println!("grid queries -> {}: {} points in {:.3}s \
              ({:.0} points/s)",
             csv_path.display(), grid.len(), secs,
             grid.len() as f64 / secs.max(1e-12));
    println!("save_and_infer OK");
    Ok(())
}
