//! Complex-geometry forward problem (paper SS4.6.4 / Fig. 12, CI scale):
//! convection-diffusion on a spur-gear mesh with strongly skewed quads —
//! the workload loop-based hp-VPINNs cannot handle. Runs fully natively:
//! FEM reference + pure-Rust FastVPINNs training, no artifacts.
//!
//!     cargo run --release --example gear_forward
//!
//! Flags via env: GEAR_ITERS (default 800).

use fastvpinns::coordinator::metrics::ErrorNorms;
use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::fem_solver;
use fastvpinns::mesh::{generators, quality};
use fastvpinns::problems::GearCd;
use fastvpinns::runtime::backend::native::{
    NativeBackend, NativeConfig, NativeLoss,
};
use fastvpinns::runtime::backend::BackendOpts;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("GEAR_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(800);
    let problem = GearCd;

    // 1. the gear mesh: 1760 skewed quads (paper-scale: 14,080)
    let mesh = generators::gear_ci();
    let q = quality::report(&mesh);
    println!("gear mesh: {} cells, min |J| {:.2e}, worst in-cell \
              Jacobian ratio {:.2}", q.n_cells, q.min_jac, q.worst_ratio);

    // 2. FEM reference (our ParMooN stand-in), driven by the same
    //    Problem trait object as the training run
    let fem = fem_solver::solve_problem(&mesh, &problem, 3)?;
    println!("FEM reference: {} iterations, {:.2}s",
             fem.solve_iterations, fem.solve_seconds);

    // 3. FastVPINNs: pointwise-Jacobian tensors handle the skewed quads;
    //    the native backend optimizes the cd loss with the paper's 3x50
    //    net — no artifacts involved
    let domain = assembly::assemble(&mesh, 4, 5, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&domain),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters,
        lr: LrSchedule::ExpDecay { lr0: 5e-3, factor: 0.99, every: 1000 },
        log_every: 50,
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 50, 50, 50, 1],
        loss: NativeLoss::Forward,
        nb: 400,
        ns: 0,
    };
    let backend = NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg))?;
    let mut trainer = Trainer::new(Box::new(backend), &cfg);
    let report = trainer.run()?;
    println!("FastVPINNs: {} iters, loss {:.3e}, {:.2} ms/iter median",
             report.steps, report.final_loss, report.median_step_ms);

    // 4. compare against FEM at the mesh nodes
    let pred = trainer.predict(&mesh.points)?;
    let err = ErrorNorms::compute_f32(&pred, fem.nodal());
    println!("vs FEM: MAE {:.3e}, rel-L2 {:.3e}", err.mae, err.rel_l2);
    println!("gear_forward OK");
    Ok(())
}
