//! Inverse problem (paper SS4.7.1 / Fig. 14, CI scale): recover the
//! unknown constant diffusion coefficient eps = 0.3 from 50 sensor
//! observations, starting from eps = 2.0. With the native backend the
//! trainable eps is an extra scalar parameter with an analytic
//! d(loss)/d(eps) — no artifacts, no Python.
//!
//!     cargo run --release --example inverse_diffusion
//!
//! Env: INV_ITERS (default 4000).

use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::mesh::generators;
use fastvpinns::problems::InverseConstPoisson;
use fastvpinns::runtime::backend::native::{
    NativeBackend, NativeConfig, NativeLoss,
};
use fastvpinns::runtime::backend::BackendOpts;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("INV_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4000);
    let problem = InverseConstPoisson::new();

    // (-1,1)^2, 2x2 elements, 40x40 quadrature per element (paper shape)
    let mesh = generators::rect_grid(2, 2, -1.0, -1.0, 1.0, 1.0);
    let domain = assembly::assemble(&mesh, 5, 40, QuadKind::GaussLegendre);

    let src = DataSource { mesh: &mesh, domain: Some(&domain),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters,
        lr: LrSchedule::Constant(2e-3),
        eps_init: 2.0,
        eps_converge: Some((problem.eps_actual, 1e-3)),
        log_every: 100,
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 30, 30, 30, 1],
        loss: NativeLoss::InverseConst,
        nb: 400,
        ns: 50,
    };
    let backend = NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg))?;
    let mut trainer = Trainer::new(Box::new(backend), &cfg);

    println!("recovering eps (actual {}, init {})...",
             problem.eps_actual, cfg.eps_init);
    let report = trainer.run()?;
    let eps = report.eps_final.unwrap();
    println!(
        "eps = {eps:.5} after {} epochs ({:.2} ms/epoch median, \
         total {:.1}s){}",
        report.steps, report.median_step_ms, report.total_seconds,
        if report.converged_early { " [converged early]" } else { "" }
    );
    assert!(
        (eps - problem.eps_actual).abs() < 0.5,
        "eps did not move toward the target: {eps}"
    );
    println!("inverse_diffusion OK");
    Ok(())
}
