//! Quickstart: the end-to-end FastVPINNs pipeline in ~50 lines —
//! no artifacts, no Python, no XLA.
//!
//! Solves the Poisson problem `-lap u = -2 w^2 sin(wx) sin(wy)` with
//! omega = 2*pi on the unit square: mesh -> tensor assembly (Rust) ->
//! native train step (pure Rust backprop + Adam) -> error vs the exact
//! solution. (Build with `--features xla` and `make artifacts` to run
//! the same pipeline through AOT/PJRT instead.)
//!
//!     cargo run --release --example quickstart

use fastvpinns::coordinator::metrics::eval_grid;
use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::mesh::generators;
use fastvpinns::problems::{PoissonSin, Problem};
use fastvpinns::runtime::backend::native::{NativeBackend, NativeConfig};
use fastvpinns::runtime::backend::BackendOpts;

fn main() -> anyhow::Result<()> {
    let omega = 2.0 * std::f64::consts::PI;
    let problem = PoissonSin::new(omega);

    // 1. mesh the unit square 4x4 and assemble the FastVPINNs tensors
    //    (5^2 test functions, 10^2 quadrature points per element)
    let mesh = generators::unit_square(4);
    let domain = assembly::assemble(&mesh, 5, 10, QuadKind::GaussLegendre);
    println!("assembled: {} elements x {} tests x {} quad points",
             domain.ne, domain.nt, domain.nq);

    // 2. build the native backend and train
    let src = DataSource { mesh: &mesh, domain: Some(&domain),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters: 5000,
        lr: LrSchedule::Constant(5e-3),
        log_every: 100,
        ..TrainConfig::default()
    };
    let backend = NativeBackend::new(&NativeConfig::forward_std(), &src,
                                     &BackendOpts::from(&cfg))?;
    let mut trainer = Trainer::new(Box::new(backend), &cfg);
    let report = trainer.run()?;
    println!("trained {} steps: loss {:.3e} ({:.2} ms/step median)",
             report.steps, report.final_loss, report.median_step_ms);

    // 3. evaluate against the exact solution on the paper's 100x100 grid
    let grid = eval_grid(100, 100, 0.0, 0.0, 1.0, 1.0);
    let exact: Vec<f64> = grid.iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap())
        .collect();
    let err = trainer.evaluate(&grid, &exact)?;
    println!("errors vs exact: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
             err.mae, err.rel_l2, err.linf);

    // end-to-end sanity: the network must have actually learned the field
    assert!(err.mae < 0.1, "quickstart did not converge (MAE {})",
            err.mae);
    println!("quickstart OK");
    Ok(())
}
