//! Quickstart: the end-to-end FastVPINNs pipeline in ~50 lines.
//!
//! Solves the Poisson problem `-lap u = -2 w^2 sin(wx) sin(wy)` with
//! omega = 2*pi on the unit square: mesh -> tensor assembly (Rust) ->
//! AOT train-step execution (PJRT) -> error vs the exact solution.
//!
//!     make artifacts && cargo run --release --example quickstart

use fastvpinns::coordinator::metrics::eval_grid;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::mesh::generators;
use fastvpinns::problems::{PoissonSin, Problem};
use fastvpinns::runtime::engine::Engine;

fn main() -> anyhow::Result<()> {
    let omega = 2.0 * std::f64::consts::PI;
    let problem = PoissonSin::new(omega);

    // 1. mesh the unit square 2x2 and assemble the FastVPINNs tensors
    //    (5^2 test functions, 20^2 quadrature points per element)
    let mesh = generators::unit_square(2);
    let domain = assembly::assemble(&mesh, 5, 20, QuadKind::GaussLegendre);
    println!("assembled: {} elements x {} tests x {} quad points",
             domain.ne, domain.nt, domain.nq);

    // 2. load the matching AOT artifact and train
    let engine = Engine::new("artifacts")?;
    let src = DataSource { mesh: &mesh, domain: Some(&domain),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig { iters: 3000, log_every: 100,
                            ..TrainConfig::default() };
    let mut trainer = Trainer::new(&engine, "fv_poisson_ne4_nt5_nq20",
                                   &src, &cfg)?;
    let report = trainer.run()?;
    println!("trained {} steps: loss {:.3e} ({:.2} ms/step median)",
             report.steps, report.final_loss, report.median_step_ms);

    // 3. evaluate against the exact solution on the paper's 100x100 grid
    let grid = eval_grid(100, 100, 0.0, 0.0, 1.0, 1.0);
    let exact: Vec<f64> = grid.iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap())
        .collect();
    let err = trainer.evaluate("predict_std_16k", &grid, &exact)?;
    println!("errors vs exact: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
             err.mae, err.rel_l2, err.linf);

    // end-to-end sanity: the network must have actually learned the field
    assert!(err.mae < 0.1, "quickstart did not converge (MAE {})",
            err.mae);
    println!("quickstart OK");
    Ok(())
}
