//! `repro` — the FastVPINNs L3 coordinator CLI.
//!
//! Subcommands:
//!   train [--backend native|xla] ...  train a problem (native: pure
//!                                     Rust, no artifacts; xla: AOT);
//!                                     --checkpoint persists the model,
//!                                     --resume warm-restarts one
//!   infer --ckpt out.ckpt ...      load a checkpoint and serve batched
//!                                  predictions over a query point cloud
//!                                  (CSV/VTK output)
//!   serve --registry DIR ...       long-running multi-model inference
//!                                  server: length-prefixed JSON over
//!                                  TCP, micro-batched onto the blocked
//!                                  eval path, LRU model cache, graceful
//!                                  SIGTERM drain
//!   serve-probe --addr H:P ...     one-shot client against a running
//!                                  serve instance (ping/stats/models/
//!                                  eval/shutdown)
//!   bench [--quick] ...            time the native train-step hot path
//!                                  + inference throughput and write
//!                                  BENCH_native_step.json
//!   artifacts                      list available AOT artifacts (xla)
//!   experiment <id|all> ...        regenerate a paper table/figure
//!   fem-solve --mesh <kind> ...    run the classical FEM reference solver
//!   mesh --kind <kind> ...         generate/inspect/export meshes
//!   dump-tensors                   write assembly dumps for pytest
//!                                  cross-validation (`make crosscheck`)

// Every code path here is CLI-reachable: a panic is a crash report to
// the user's terminal, so failures must travel as errors instead.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use anyhow::{bail, Context as _, Result};

use fastvpinns::coordinator::metrics::eval_grid;
use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::experiments;
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::fem_solver::{self, FemProblem};
use fastvpinns::mesh::{generators, gmsh, quality, QuadMesh};
use fastvpinns::problems::{self, Problem};
use fastvpinns::runtime::backend::native::{
    NativeBackend, NativeConfig, NativeLoss,
};
use fastvpinns::runtime::backend::{check_backend_name, BackendOpts};
use fastvpinns::util::cli::Args;
use fastvpinns::util::npy;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    // chaos-tier fault injection (no-op unless REPRO_FAILPOINTS is
    // set; `repro train --failpoints` arms more below)
    if let Err(e) = fastvpinns::runtime::failpoint::arm_from_env() {
        eprintln!("argument error: {e:#}");
        std::process::exit(2);
    }
    let res = dispatch(&args);
    // flush + fsync the telemetry stream on every exit path (clean
    // finish, error, serve drain) — a no-op unless --metrics-out armed
    // it
    fastvpinns::telemetry::shutdown();
    if let Err(e) = res {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "artifacts" => cmd_artifacts(args),
        "train" => cmd_train(args),
        "infer" => cmd_infer(args),
        "serve" => cmd_serve(args),
        "serve-probe" => cmd_serve_probe(args),
        "bench" => cmd_bench(args),
        "experiment" => {
            if args.positional.is_empty() {
                bail!("usage: repro experiment <id|all> (ids: {:?})",
                      experiments::ALL);
            }
            for id in &args.positional {
                experiments::run(id, args)?;
            }
            Ok(())
        }
        "fem-solve" => cmd_fem_solve(args),
        "report" => cmd_report(args),
        "mesh" => cmd_mesh(args),
        "dump-tensors" => cmd_dump_tensors(args),
        "" | "help" | "--help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{}", usage()),
    }
}

/// The CLI help text. The `--problem` list is derived from the single
/// problem registry (`problems::registry`), so it cannot drift from
/// the set `repro train` actually dispatches on.
fn usage() -> String {
    format!(
        "\
repro — FastVPINNs coordinator
  repro train [--backend native|xla]
              [--problem {problems}]
              [--omega-pi K] [--k-pi K] [--n N] [--nt1d N] [--nq1d N]
              [--layers 2,30,30,30,1] [--iters N] [--lr F] [--tau F]
              [--seed N] [--ns N] [--nb N] [--log-every N]
              [--workers N]   (pool size; FASTVPINNS_THREADS is an alias)
              [--expect-rel-l2 F] [--history F.csv]
              [--checkpoint F.ckpt [--checkpoint-every N]]
              [--resume F.ckpt]
              [--snapshot-every N] [--max-recoveries N]
              [--lr-backoff F] [--lr-restore-after N]
              [--grad-limit F] [--watchdog-ms N]
              [--failpoints SPEC]   (chaos testing; also REPRO_FAILPOINTS)
              [--metrics-out F.jsonl]   (structured telemetry stream)
              (xla backend: --artifact NAME [--artifacts DIR])
  repro infer --ckpt F.ckpt [--points F.csv | --grid N | --quad]
              [--out pred.csv|pred.vtk] [--batch N]
              [--precision f64|f32]
  repro serve --registry DIR [--addr HOST:PORT] [--cache N]
              [--workers N] [--max-batch N] [--max-wait-ms N]
              [--queue-depth N] [--drain-timeout-s N]
              [--metrics-out F.jsonl]
  repro serve-probe --addr HOST:PORT
              [--op ping|stats|models|eval|shutdown]
              [--model NAME] [--grid N] [--points F.csv]
              [--precision f64|f32] [--clients N] [--repeat N]
  repro bench [--backend native] [--quick] [--iters N] [--warmup N]
              [--nt1d N] [--nq1d N] [--out BENCH_native_step.json]
              [--no-serve]
  repro artifacts [--artifacts DIR]              (requires --features xla)
  repro experiment <{experiments}|all>
              [--backend native|xla] [--iters N] [--paper-scale]
  repro report F.jsonl [MORE.jsonl ...]   summarize a telemetry stream
  repro fem-solve --mesh <square|disk|gear> [--n N] [--omega-pi K]
  repro mesh --kind <square|skewed|disk|gear|annulus> [--n N] [--out F.msh]
  repro dump-tensors [--out DIR]

problems (from the registry):
{summaries}",
        problems = problems::registry::name_list(),
        experiments = experiments::ALL.join("|"),
        summaries = problems::registry::REGISTRY
            .iter()
            .map(|e| format!("  {:<14} {}", e.name, e.summary))
            .collect::<Vec<_>>()
            .join("\n"),
    )
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts(_args: &Args) -> Result<()> {
    bail!("the artifacts subcommand needs the xla runtime — rebuild \
           with `cargo build --features xla`")
}

#[cfg(feature = "xla")]
fn cmd_artifacts(args: &Args) -> Result<()> {
    use fastvpinns::runtime::engine::Engine;
    let engine = Engine::new(args.str_or("artifacts", "artifacts"))?;
    let names = engine.list()?;
    if names.is_empty() {
        println!("no artifacts found — run `make artifacts`");
        return Ok(());
    }
    println!("{} artifacts under {} (platform: {}):", names.len(),
             engine.artifact_dir().display(), engine.platform());
    for n in names {
        let art = engine.load(&n);
        match art {
            Ok(a) => {
                let c = &a.manifest.config;
                println!(
                    "  {n:<42} {:<8} ne={:<6} nt={:<4} nq={:<5} \
                     kernel={} ({:.2}s compile)",
                    a.manifest.kind, c.ne, c.nt, c.nq, c.kernel,
                    a.compile_seconds
                );
            }
            Err(e) => println!("  {n:<42} FAILED: {e}"),
        }
    }
    Ok(())
}

/// Evaluate a problem's exact solution over a point set, failing as an
/// error (not a panic) when it is undefined anywhere on the set.
fn exact_on_grid(problem: &dyn Problem, grid: &[[f64; 2]])
    -> Result<Vec<f64>> {
    grid.iter()
        .map(|p| problem.exact(p[0], p[1]))
        .collect::<Option<Vec<f64>>>()
        .ok_or_else(|| anyhow::anyhow!(
            "problem '{}' has no exact solution on the evaluation grid",
            problem.name()))
}

/// Parse `--layers 2,30,30,30,1`.
fn parse_layers(spec: &str) -> Result<Vec<usize>> {
    let layers: Vec<usize> = spec
        .split(',')
        .map(|t| t.trim().parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|_| anyhow::anyhow!("--layers expects e.g. 2,30,30,30,1"))?;
    Ok(layers)
}

/// Time the native train-step hot path across grid sizes and write a
/// JSON perf record — the tracked datapoint CI uploads on every PR.
fn cmd_bench(args: &Args) -> Result<()> {
    use fastvpinns::experiments::common::{
        native_forward_step_case, native_infer_case,
        native_inverse_space_step_case, native_probe_loss,
        native_probe_loss_workers, native_step_case,
        native_step_case_telemetry, native_step_case_workers,
        StepBenchCase, STD_LAYERS,
    };
    use fastvpinns::linalg::simd;
    use fastvpinns::runtime::infer::Precision;
    use fastvpinns::util::json::Json;

    let backend = args.str_or("backend", "native");
    check_backend_name(&backend)?;
    if backend != "native" {
        bail!("repro bench currently times the native backend only");
    }
    let quick = args.has("quick");
    let (ks, pde_ks, iters_default, warmup_default): (&[usize], &[usize],
                                                      usize, usize) =
        if quick {
            (&[4, 8, 16], &[4, 16], 5, 2)
        } else {
            (&[4, 8, 16, 32, 64], &[4, 16, 64], 15, 3)
        };
    let iters = args.usize_or("iters", iters_default)?.max(1);
    let warmup = args.usize_or("warmup", warmup_default)?;
    let nt1d = args.usize_or("nt1d", 5)?;
    let nq1d = args.usize_or("nq1d", 5)?;
    let out_path = args.str_or("out", "BENCH_native_step.json");
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!(
        "bench: native train step, net {STD_LAYERS:?}, nt={nt1d}^2, \
         nq={nq1d}^2, {iters} iters (+{warmup} warmup), {threads} threads"
    );
    let mut cases = Vec::new();
    let mut push_case = |case: &StepBenchCase| {
        let s = &case.summary;
        println!(
            "  {:<14} {:<17} ne={:<6} ({:>8} quad pts)  median {:>9.3} \
             ms/step  p90 {:>9.3} ms",
            case.loss, case.pde, case.ne, case.n_quad, s.median, s.p90
        );
        cases.push(Json::obj(vec![
            ("loss", Json::str(case.loss)),
            ("pde", Json::str(case.pde)),
            ("ne", Json::num(case.ne as f64)),
            ("n_quad", Json::num(case.n_quad as f64)),
            ("dof", Json::num(case.dof as f64)),
            // effective persistent-pool workers (clamped to ne), not
            // machine cores — the thread-scaling sweep varies this
            ("workers", Json::num(case.workers as f64)),
            // kernel the case actually ran on (the forced-scalar
            // parity case records "scalar_4x8" here)
            ("kernel", Json::str(case.kernel)),
            ("median_ms", Json::num(s.median)),
            ("p90_ms", Json::num(s.p90)),
            ("min_ms", Json::num(s.min)),
            ("mean_ms", Json::num(s.mean)),
        ]));
    };
    for &k in ks {
        push_case(&native_step_case(k, nt1d, nq1d, iters, warmup)?);
    }
    // persistent-pool thread scaling: the sweep's largest grid
    // re-timed with the pool pinned to 1, 2 and all workers — the
    // tracked scaling rows. The shard plan and the fixed-order tree
    // reduce are worker-count-independent, so these rows differ only
    // in wall-clock; the probe below checks the losses stay
    // bit-identical.
    let k_max = ks.iter().copied().max().unwrap_or(4);
    let mut sweep_counts = vec![1usize, 2, threads];
    sweep_counts.sort_unstable();
    sweep_counts.dedup();
    let mut scaling: Vec<(usize, f64)> = Vec::new();
    for &w in &sweep_counts {
        let c = native_step_case_workers(k_max, nt1d, nq1d, iters,
                                         warmup, w)?;
        scaling.push((c.workers, c.summary.median));
        push_case(&c);
    }
    for pair in scaling.windows(2) {
        let ((w0, m0), (w1, m1)) = (pair[0], pair[1]);
        println!(
            "  worker scaling: {w0} -> {w1} workers, median {m0:.3} -> \
             {m1:.3} ms/step ({:.2}x) at ne={}",
            m0 / m1.max(1e-9), k_max * k_max
        );
        if w1 > w0 && m1 > m0 * 1.15 {
            // soft gate: shared runners are too noisy for a hard
            // monotonicity bail, but a real scaling regression shows
            // up in the uploaded JSON rows either way
            println!(
                "  WARNING: adding workers ({w0} -> {w1}) slowed the \
                 step down by {:.1}% at ne={}",
                (m1 / m0 - 1.0) * 100.0, k_max * k_max
            );
        }
    }
    // worker-count determinism guard: a short training run repeated at
    // each sweep count must land on bit-identical losses (shard plan +
    // reduction order never depend on the worker count)
    let probe_ref = native_probe_loss_workers(8, nt1d, nq1d, 5, Some(1))?;
    for &w in &sweep_counts[1..] {
        let probe =
            native_probe_loss_workers(8, nt1d, nq1d, 5, Some(w))?;
        if probe.to_bits() != probe_ref.to_bits() {
            bail!(
                "persistent pool broke worker-count determinism: \
                 probe loss {probe} with {w} workers vs {probe_ref} \
                 with 1 worker (must be bit-identical)"
            );
        }
    }
    println!(
        "  worker determinism: probe losses bit-identical across \
         workers {sweep_counts:?}"
    );
    // the generalized-form PDE cases on a subset of grids: Helmholtz
    // (reaction term) and the rotating variable-convection field
    for &k in pde_ks {
        push_case(&native_forward_step_case("helmholtz", k, nt1d, nq1d,
                                            iters, warmup)?);
        push_case(&native_forward_step_case("cd_var", k, nt1d, nq1d,
                                            iters, warmup)?);
    }
    // the two-head inverse-space step on the same grids: tracks the
    // eps head's cost on the blocked tensor path
    for &k in pde_ks {
        push_case(&native_inverse_space_step_case(k, nt1d, nq1d, iters,
                                                  warmup)?);
    }
    // hoisting regression probe: the same constant-coefficient Poisson
    // problem once on the scalar fast path and once forced through the
    // generalized per-point eps table path, measured back to back. The
    // coefficient tables are precomputed at backend construction; if
    // they were re-evaluated per step the table case would blow far
    // past this bound. A fixed ne=256 grid with >= 20 timed iters
    // keeps the medians stable enough for the 5% gate even on noisy
    // shared runners (and avoids re-timing the ne=4096 case in full
    // mode just for the ratio).
    let k_ref = 16;
    let (h_iters, h_warmup) = (iters.max(20), warmup.max(3));
    let mut base = native_step_case(k_ref, nt1d, nq1d, h_iters, h_warmup)?;
    let mut tab = native_forward_step_case("poisson_tab", k_ref, nt1d,
                                           nq1d, h_iters, h_warmup)?;
    let mut ratio = tab.summary.median / base.summary.median;
    if ratio > 1.05 {
        // one retry with min-of-medians before failing: a shared
        // runner's noisy neighbor between the back-to-back runs can
        // breach 5% without any real regression, but a table path
        // that re-evaluated coefficients per step would miss by far
        // more than two retries can hide
        let base2 =
            native_step_case(k_ref, nt1d, nq1d, h_iters, h_warmup)?;
        let tab2 = native_forward_step_case("poisson_tab", k_ref, nt1d,
                                            nq1d, h_iters, h_warmup)?;
        if base2.summary.median < base.summary.median {
            base = base2;
        }
        if tab2.summary.median < tab.summary.median {
            tab = tab2;
        }
        ratio = tab.summary.median / base.summary.median;
    }
    push_case(&tab);
    println!(
        "  hoisting check: poisson_tab / poisson median ratio {ratio:.3} \
         at ne={}",
        k_ref * k_ref
    );
    if ratio > 1.05 {
        bail!(
            "generalized coefficient-table path regressed the \
             constant-coefficient poisson step by {:.1}% (> 5%): the \
             tables must be hoisted, not recomputed per step \
             (poisson {:.3} ms vs poisson_tab {:.3} ms at ne={})",
            (ratio - 1.0) * 100.0, base.summary.median,
            tab.summary.median, k_ref * k_ref
        );
    }
    // simd-vs-scalar parity guard (the hoisting guard's sibling): the
    // same case re-timed on the forced scalar kernel, plus a
    // short-training numeric probe on both kernels. The f64 GEMM/GEMV
    // kernels are bit-identical and the vector tanh is 1e-15-class, so
    // any probe-loss drift past 1e-6 relative means a broken kernel —
    // and a SIMD median behind the scalar one means the dispatch is
    // selecting a kernel that loses to its own fallback.
    if simd::simd_available() {
        let loss_simd = native_probe_loss(8, nt1d, nq1d, 5)?;
        simd::set_force_scalar(true);
        let scalar_res = (|| -> Result<(StepBenchCase, f64)> {
            let c = native_step_case(k_ref, nt1d, nq1d, h_iters,
                                     h_warmup)?;
            let l = native_probe_loss(8, nt1d, nq1d, 5)?;
            Ok((c, l))
        })();
        simd::set_force_scalar(false);
        let (mut scalar_case, loss_scalar) = scalar_res?;
        let mut simd_median = base.summary.median;
        let mut sratio = simd_median / scalar_case.summary.median;
        if sratio > 1.0 {
            // same retry policy as the hoisting guard: min-of-medians
            // over one re-measurement absorbs noisy-neighbor spikes; a
            // genuinely slower SIMD kernel stays slower
            let b2 =
                native_step_case(k_ref, nt1d, nq1d, h_iters, h_warmup)?;
            simd_median = simd_median.min(b2.summary.median);
            simd::set_force_scalar(true);
            let s2 = native_step_case(k_ref, nt1d, nq1d, h_iters,
                                      h_warmup);
            simd::set_force_scalar(false);
            let s2 = s2?;
            if s2.summary.median < scalar_case.summary.median {
                scalar_case = s2;
            }
            sratio = simd_median / scalar_case.summary.median;
        }
        push_case(&scalar_case);
        let drift =
            (loss_simd - loss_scalar).abs() / (1.0 + loss_scalar.abs());
        println!(
            "  simd parity: {} / scalar median ratio {sratio:.3} at \
             ne={}, probe-loss drift {drift:.2e}",
            simd::kernel_name(), k_ref * k_ref
        );
        if drift > 1e-6 {
            bail!(
                "simd kernel diverges numerically from the scalar \
                 ground truth: probe losses {loss_simd} vs \
                 {loss_scalar} (rel drift {drift:.2e} > 1e-6)"
            );
        }
        if sratio > 1.02 {
            bail!(
                "simd kernel ({}) is {:.1}% slower than the scalar \
                 fallback it replaces at ne={} ({:.3} ms vs {:.3} ms): \
                 the dispatch should not select a losing kernel",
                simd::kernel_name(), (sratio - 1.0) * 100.0,
                k_ref * k_ref, simd_median, scalar_case.summary.median
            );
        }
    } else {
        println!(
            "  simd parity: skipped (kernel {} — no AVX2 or \
             REPRO_FORCE_SCALAR set)",
            simd::kernel_name()
        );
    }
    // telemetry overhead guard: the sweep's largest grid re-timed with
    // the recorder disarmed and armed (writing to a throwaway stream).
    // The armed run pays the per-phase clock + one StepStats emit per
    // step; the zero-overhead contract caps that at 2% of the median
    // step. Same min-of-medians one-retry policy as the hoisting and
    // simd guards.
    {
        let metrics_tmp = std::env::temp_dir().join(format!(
            "fastvpinns_bench_metrics_{}.jsonl",
            std::process::id()
        ));
        let run_pair = |tmp: &std::path::Path|
            -> Result<(StepBenchCase, StepBenchCase)> {
            let off = native_step_case_telemetry(
                k_max, nt1d, nq1d, iters, warmup, "telemetry_off",
            )?;
            fastvpinns::telemetry::arm(tmp)
                .context("arm bench telemetry stream")?;
            let on_res = native_step_case_telemetry(
                k_max, nt1d, nq1d, iters, warmup, "telemetry_on",
            );
            fastvpinns::telemetry::shutdown();
            let _ = std::fs::remove_file(tmp);
            Ok((off, on_res?))
        };
        let (mut off, mut on) = run_pair(&metrics_tmp)?;
        let mut tratio = on.summary.median / off.summary.median;
        if tratio > 1.02 {
            let (off2, on2) = run_pair(&metrics_tmp)?;
            if off2.summary.median < off.summary.median {
                off = off2;
            }
            if on2.summary.median < on.summary.median {
                on = on2;
            }
            tratio = on.summary.median / off.summary.median;
        }
        push_case(&off);
        push_case(&on);
        println!(
            "  telemetry overhead: armed / disarmed median ratio \
             {tratio:.3} at ne={}",
            k_max * k_max
        );
        if tratio > 1.02 {
            bail!(
                "telemetry recorder adds {:.1}% to the median step at \
                 ne={} ({:.3} ms armed vs {:.3} ms disarmed): the \
                 armed hot path must stay within the 2% zero-overhead \
                 budget",
                (tratio - 1.0) * 100.0, k_max * k_max,
                on.summary.median, off.summary.median
            );
        }
    }
    // inference throughput: repeated passes over a 4096-point query
    // cloud through the blocked prediction path, at serving batch
    // sizes and both precisions — the amortized-inference datapoints
    // `repro infer` serves (`--precision f32` is the mixed-precision
    // path)
    for &precision in &[Precision::F64, Precision::F32] {
        for &batch in &[1usize, 256, 4096] {
            let c =
                native_infer_case(batch, 4096, iters, warmup, precision)?;
            println!(
                "  {:<14} {:<17} batch={:<6} ({:>7} points)   median \
                 {:>9.3} ms/pass  {:>12.0} points/s  [{}]",
                "infer", "mlp_predict", c.batch, c.n_points,
                c.summary.median, c.points_per_sec, c.precision
            );
            cases.push(Json::obj(vec![
                ("loss", Json::str("infer")),
                ("pde", Json::str("mlp_predict")),
                ("batch", Json::num(c.batch as f64)),
                ("n_points", Json::num(c.n_points as f64)),
                ("kernel", Json::str(c.kernel)),
                ("precision", Json::str(c.precision)),
                ("median_ms", Json::num(c.summary.median)),
                ("p90_ms", Json::num(c.summary.p90)),
                ("min_ms", Json::num(c.summary.min)),
                ("mean_ms", Json::num(c.summary.mean)),
                ("points_per_sec", Json::num(c.points_per_sec)),
            ]));
        }
    }
    // serve throughput: a fresh in-process server per case (so the
    // latency percentiles and batch-fill are per-case, not
    // cumulative), hammered over real TCP at two client
    // concurrencies and both precisions — the `repro serve`
    // datapoints: aggregate points/sec, server-side p50/p99, and how
    // full the coalesced micro-batches ran
    if !args.has("no-serve") {
        use fastvpinns::serve::bench::{
            prepare_bench_registry, serve_bench_case,
        };
        let reg = std::env::temp_dir().join(format!(
            "fastvpinns_serve_bench_{}",
            std::process::id()
        ));
        prepare_bench_registry(&reg, STD_LAYERS)?;
        let reqs_per_client = if quick { 8 } else { 24 };
        // run the sweep through a named closure so the temp registry
        // is removed on success and failure alike
        let mut sweep = || -> Result<()> {
            for &precision in &[Precision::F64, Precision::F32] {
                for &clients in &[1usize, 4] {
                    let c = serve_bench_case(
                        &reg, clients, 4096, reqs_per_client, precision,
                    )?;
                    println!(
                        "  {:<14} {:<17} clients={:<4} ({:>7} points) \
                         p50 {:>9.3} ms  p99 {:>9.3} ms {:>12.0} \
                         points/s  fill {:.2} [{}]",
                        "serve", "tcp_eval", c.clients,
                        c.points_per_req, c.p50_ms, c.p99_ms,
                        c.points_per_sec, c.batch_fill, c.precision
                    );
                    cases.push(Json::obj(vec![
                        ("loss", Json::str("serve")),
                        ("pde", Json::str("tcp_eval")),
                        ("clients", Json::num(c.clients as f64)),
                        (
                            "points_per_req",
                            Json::num(c.points_per_req as f64),
                        ),
                        ("requests", Json::num(c.requests as f64)),
                        (
                            "precision",
                            Json::str(c.precision.to_string()),
                        ),
                        ("p50_ms", Json::num(c.p50_ms)),
                        ("p99_ms", Json::num(c.p99_ms)),
                        (
                            "points_per_sec",
                            Json::num(c.points_per_sec),
                        ),
                        ("batch_fill", Json::num(c.batch_fill)),
                        ("max_batch", Json::num(c.max_batch as f64)),
                    ]));
                }
            }
            Ok(())
        };
        let serve_res = sweep();
        let _ = std::fs::remove_dir_all(&reg);
        serve_res?;
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("native_step")),
        ("backend", Json::str("native")),
        ("layers",
         Json::Arr(STD_LAYERS.iter().map(|&w| Json::num(w as f64))
             .collect())),
        ("nt1d", Json::num(nt1d as f64)),
        ("nq1d", Json::num(nq1d as f64)),
        ("iters", Json::num(iters as f64)),
        ("warmup", Json::num(warmup as f64)),
        ("threads", Json::num(threads as f64)),
        ("quick", Json::Bool(quick)),
        // CPU feature probe + the kernel the run selected: makes perf
        // records comparable across machines and CI legs
        ("kernel", Json::str(simd::kernel_name())),
        ("cpu_avx2", Json::Bool(simd::cpu_avx2())),
        ("cpu_fma", Json::Bool(simd::cpu_fma())),
        ("cases", Json::Arr(cases)),
    ]);
    std::fs::write(&out_path, format!("{doc}\n"))?;
    println!("bench record -> {out_path}");
    Ok(())
}

/// `repro serve`: run the micro-batching inference server until
/// SIGTERM/SIGINT or a client `shutdown` op, then drain gracefully.
fn cmd_serve(args: &Args) -> Result<()> {
    use fastvpinns::serve::{BatchPolicy, ServeConfig, Server};
    use std::time::Duration;

    let registry = args.req_str("registry")?;
    let mut config =
        ServeConfig::new(args.str_or("addr", "127.0.0.1:7077"), registry);
    config.cache_capacity = args.usize_or("cache", 4)?.max(1);
    config.workers_per_model = args.usize_or("workers", 2)?.max(1);
    config.policy = BatchPolicy {
        max_batch: args.usize_or("max-batch", 8)?.max(1),
        max_wait: Duration::from_millis(
            args.usize_or("max-wait-ms", 2)? as u64,
        ),
        queue_depth: args.usize_or("queue-depth", 64)?.max(1),
    };
    config.drain_timeout = Duration::from_secs(
        args.usize_or("drain-timeout-s", 10)? as u64,
    );
    if let Some(path) = args.flag("metrics-out") {
        fastvpinns::telemetry::arm(path)
            .context("open --metrics-out")?;
    }
    Server::new(config)?.run()
}

/// `repro serve-probe`: a one-shot client for scripting against a
/// running serve instance — CI smoke tests and shell pipelines.
fn cmd_serve_probe(args: &Args) -> Result<()> {
    use fastvpinns::runtime::infer::{read_points_csv, Precision};
    use fastvpinns::serve::ServeClient;

    let addr = args.req_str("addr")?;
    let op = args.str_or("op", "ping");
    match op.as_str() {
        "ping" => {
            ServeClient::connect(&*addr)?.ping()?;
            println!("pong");
            Ok(())
        }
        "stats" => {
            let stats = ServeClient::connect(&*addr)?.stats()?;
            println!("{stats}");
            Ok(())
        }
        "models" => {
            let models = ServeClient::connect(&*addr)?.models()?;
            for m in models {
                println!("{m}");
            }
            Ok(())
        }
        "shutdown" => {
            ServeClient::connect(&*addr)?.shutdown_server()?;
            println!("server draining");
            Ok(())
        }
        "eval" => {
            let model = args.req_str("model")?;
            let precision: Precision =
                args.str_or("precision", "f64").parse()?;
            let pts: Vec<[f64; 2]> =
                if let Some(f) = args.flag("points") {
                    read_points_csv(f)?
                } else {
                    let n = args.usize_or("grid", 32)?.max(2);
                    eval_grid(n, n, 0.0, 0.0, 1.0, 1.0)
                };
            anyhow::ensure!(!pts.is_empty(), "empty query point cloud");
            let clients = args.usize_or("clients", 1)?.max(1);
            let repeat = args.usize_or("repeat", 1)?.max(1);
            let t0 = std::time::Instant::now();
            let joins: Vec<_> = (0..clients)
                .map(|_| {
                    let addr = addr.clone();
                    let model = model.clone();
                    let pts = pts.clone();
                    std::thread::spawn(move || -> Result<(f32, f32)> {
                        let mut c = ServeClient::connect(&*addr)?;
                        let mut first = 0.0f32;
                        let mut last = 0.0f32;
                        for _ in 0..repeat {
                            let (u, _) = c.eval(
                                &model,
                                &pts,
                                Some(precision),
                            )?;
                            first = *u.first().unwrap_or(&f32::NAN);
                            last = *u.last().unwrap_or(&f32::NAN);
                        }
                        Ok((first, last))
                    })
                })
                .collect();
            let mut outputs = Vec::new();
            for j in joins {
                outputs.push(j.join().map_err(|_| {
                    anyhow::anyhow!("probe client panicked")
                })??);
            }
            let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
            let total_pts = clients * repeat * pts.len();
            // every client asked the same query: answers must agree
            for w in outputs.windows(2) {
                anyhow::ensure!(
                    w[0] == w[1],
                    "clients disagree: {:?} vs {:?}",
                    w[0],
                    w[1]
                );
            }
            println!(
                "eval ok: {} points x {repeat} x {clients} clients in \
                 {elapsed:.3}s ({:.0} points/s), u[0]={} u[-1]={}",
                pts.len(),
                total_pts as f64 / elapsed,
                outputs[0].0,
                outputs[0].1,
            );
            Ok(())
        }
        other => bail!(
            "unknown --op '{other}' \
             (expected ping|stats|models|eval|shutdown)"
        ),
    }
}

/// `repro report`: summarize one or more `--metrics-out` telemetry
/// streams — event counts, per-phase step breakdown, recovery
/// timeline, and step-time percentiles. Multiple files are combined
/// through [`Summary::merge`], so a sharded CI run's streams can be
/// reported as one. Every line is schema-validated on the way through;
/// a torn *final* line (a run killed mid-write) is skipped with a
/// warning, a malformed interior line is an error.
fn cmd_report(args: &Args) -> Result<()> {
    use fastvpinns::telemetry::SCHEMA_VERSION;
    use fastvpinns::util::json::Json;
    use fastvpinns::util::stats::Summary;

    if args.positional.is_empty() {
        bail!("usage: repro report FILE.jsonl [MORE.jsonl ...]");
    }

    const PHASES: [&str; 4] =
        ["assign_ms", "step_ms", "reduce_ms", "sync_ms"];
    let mut merged = Summary::from(&[]);
    let mut counts: Vec<(String, usize)> = Vec::new();
    let mut phase_tot = [0.0f64; 4];
    let mut phase_steps = 0usize;
    let mut wall_tot = 0.0f64;
    let mut recoveries: Vec<String> = Vec::new();
    let mut checkpoints = 0usize;
    let mut ckpt_bytes = 0u64;
    let mut ckpt_ms: Vec<f64> = Vec::new();
    let mut kernel_lines: Vec<String> = Vec::new();
    let mut queue_hwm = 0usize;
    let mut batch_len = 0u64;
    let mut batch_cap = 0u64;
    let mut dropped = 0usize;

    for path in &args.positional {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {path}"))?;
        let lines: Vec<&str> =
            text.lines().filter(|l| !l.trim().is_empty()).collect();
        let mut walls: Vec<f64> = Vec::new();
        let mut handle = |ev: &Json| -> Result<()> {
            let v = ev.req("v")?.as_usize()?;
            anyhow::ensure!(
                v as u32 == SCHEMA_VERSION,
                "schema version {v} (this build reads v{SCHEMA_VERSION})"
            );
            let tag = ev.req("ev")?.as_str()?;
            if tag != "flush" {
                // every event except the shutdown marker is stamped
                ev.req("t_ms")?.as_f64()?;
            }
            match counts.iter_mut().find(|(t, _)| t == tag) {
                Some((_, c)) => *c += 1,
                None => counts.push((tag.to_string(), 1)),
            }
            match tag {
                "step" => {
                    let wall = ev.req("wall_ms")?.as_f64()?;
                    walls.push(wall);
                    wall_tot += wall;
                    // the four phase fields are all-numbers or all-null
                    // (null: the backend has no phase clock, or the
                    // step never reached the hot path)
                    let mut ph = [0.0f64; 4];
                    let mut have = true;
                    for (i, k) in PHASES.iter().enumerate() {
                        match ev.req(k)?.as_f64() {
                            Ok(x) => ph[i] = x,
                            Err(_) => {
                                have = false;
                                break;
                            }
                        }
                    }
                    if have {
                        for (t, p) in phase_tot.iter_mut().zip(ph) {
                            *t += p;
                        }
                        phase_steps += 1;
                    }
                }
                "recovery" => recoveries.push(format!(
                    "t={:.1} ms: step {} rolled back to {} ({}), lr \
                     scale {:.3e}",
                    ev.req("t_ms")?.as_f64()?,
                    ev.req("at_step")?.as_usize()?,
                    ev.req("rollback_to")?.as_usize()?,
                    ev.req("reason")?.as_str()?,
                    ev.req("lr_scale")?.as_f64()?,
                )),
                "checkpoint" => {
                    checkpoints += 1;
                    ckpt_bytes += ev.req("bytes")?.as_usize()? as u64;
                    ckpt_ms.push(ev.req("write_ms")?.as_f64()?);
                }
                "kernel" => kernel_lines.push(format!(
                    "{} degraded={} ({})",
                    ev.req("kernel")?.as_str()?,
                    ev.req("degraded")?.as_bool()?,
                    ev.req("reason")?.as_str()?,
                )),
                "queue" => {
                    queue_hwm =
                        queue_hwm.max(ev.req("hwm")?.as_usize()?);
                    ev.req("queued")?.as_usize()?;
                }
                "batch" => {
                    batch_len += ev.req("len")?.as_usize()? as u64;
                    batch_cap += ev.req("max")?.as_usize()? as u64;
                }
                "flush" => {
                    dropped += ev.req("dropped")?.as_usize()?;
                }
                // same-version unknown tags are counted but otherwise
                // ignored (the schema rule: new tags don't bump v, so
                // a reader must tolerate them)
                _ => {}
            }
            Ok(())
        };
        let n_lines = lines.len();
        for (i, line) in lines.iter().enumerate() {
            let parsed = Json::parse(line);
            let ev = match parsed {
                Ok(j) => j,
                Err(e) if i + 1 == n_lines => {
                    // a run killed mid-write may leave a torn final
                    // line; everything before it is intact and still
                    // worth reporting
                    eprintln!(
                        "warning: {path}: skipping torn final line \
                         ({e})"
                    );
                    continue;
                }
                Err(e) => {
                    return Err(e.context(format!(
                        "{path}:{}: malformed event line",
                        i + 1
                    )))
                }
            };
            handle(&ev)
                .with_context(|| format!("{path}:{}", i + 1))?;
        }
        drop(handle);
        let s = Summary::from(&walls);
        println!(
            "{path}: {n_lines} event(s), {} step(s)",
            s.n + s.dropped
        );
        merged = merged.merge(&s);
    }

    println!(
        "telemetry report ({} file(s), schema v{SCHEMA_VERSION})",
        args.positional.len()
    );
    for (tag, c) in &counts {
        println!("  {tag:<11} {c:>8} event(s)");
    }
    if merged.n > 0 {
        println!(
            "step wall time: n {}  median {:.3} ms  p90 {:.3} ms  p99 \
             {:.3} ms  max {:.3} ms  mean {:.3} ms",
            merged.n, merged.median, merged.p90, merged.p99,
            merged.max, merged.mean
        );
    }
    if phase_steps > 0 {
        let accounted: f64 = phase_tot.iter().sum();
        println!(
            "phase breakdown over {phase_steps} step(s) with timings \
             ({:.1}% of step wall accounted):",
            if wall_tot > 0.0 {
                accounted / wall_tot * 100.0
            } else {
                0.0
            }
        );
        for (name, ms) in ["assign", "step", "reduce", "sync"]
            .iter()
            .zip(phase_tot)
        {
            println!(
                "  {name:<7} {ms:>10.1} ms  ({:>5.1}%)",
                if accounted > 0.0 { ms / accounted * 100.0 } else { 0.0 }
            );
        }
    }
    if !recoveries.is_empty() {
        println!("recovery timeline ({}):", recoveries.len());
        for r in &recoveries {
            println!("  {r}");
        }
    }
    if checkpoints > 0 {
        println!(
            "checkpoints: {checkpoints} write(s), {ckpt_bytes} bytes, \
             median {:.3} ms",
            Summary::from(&ckpt_ms).median
        );
    }
    for k in &kernel_lines {
        println!("kernel: {k}");
    }
    if batch_cap > 0 {
        println!(
            "serve: queue hwm {queue_hwm}, mean batch fill {:.2}",
            batch_len as f64 / batch_cap as f64
        );
    }
    if dropped > 0 {
        println!(
            "WARNING: {dropped} event(s) dropped at the recorder \
             (channel full)"
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let backend = args.str_or("backend", "native");
    check_backend_name(&backend)?;
    if backend != "native"
        && (args.has("checkpoint") || args.has("resume")
            || args.has("checkpoint-every"))
    {
        // fail loudly rather than train-and-discard: the xla artifact
        // executor keeps its state on device and does not implement
        // Backend::export_checkpoint
        bail!(
            "--checkpoint/--resume are only supported on the native \
             backend ('{backend}' does not persist state)"
        );
    }
    match backend.as_str() {
        "native" => cmd_train_native(args),
        "xla" => cmd_train_xla(args),
        _ => unreachable!(),
    }
}

/// Flags worth persisting into a checkpoint: everything that shapes
/// the problem/mesh/network, minus per-run control flags (the resumed
/// run picks its own iteration budget, output paths and gates).
fn persistable_flags(args: &Args) -> Vec<(String, String)> {
    const CONTROL: &[&str] = &[
        "backend", "resume", "checkpoint", "checkpoint-every", "history",
        "expect-rel-l2", "iters", "log-every", "failpoints",
        "snapshot-every", "max-recoveries", "lr-backoff",
        "lr-restore-after", "grad-limit", "watchdog-ms", "workers",
        "metrics-out",
    ];
    args.flag_pairs()
        .into_iter()
        .filter(|(k, _)| !CONTROL.contains(&k.as_str()))
        .collect()
}

/// Pure-Rust training: no artifacts, no Python, no XLA. The problem
/// family is looked up in the single registry (`problems::registry`),
/// which also owns the USAGE list — mesh, loss mode and sensor counts
/// all come from the entry; the PDE coefficients come from the problem
/// itself via its variational form.
///
/// `--checkpoint F.ckpt` persists the model (periodically with
/// `--checkpoint-every N`, always at the end; best-by-validation at
/// `F.ckpt.best` when the problem has an exact solution).
/// `--resume F.ckpt` warm-restarts: the artifact's stored flags
/// rebuild the identical setup, its Adam state, step count and best
/// metric are restored, and training continues the original loss
/// trajectory for `--iters` further steps. Run-control flags
/// (`--iters`, `--lr`, `--log-every`, output paths, gates) may be
/// given anew; trained state (`--tau`, `--seed`, `--layers`, the
/// problem and its mesh shape, ...) cannot be overridden and is
/// rejected loudly.
fn cmd_train_native(args: &Args) -> Result<()> {
    use fastvpinns::coordinator::trainer::{
        CheckpointPolicy, RecoveryPolicy,
    };
    use fastvpinns::runtime::checkpoint::{hash_f32_bits, Checkpoint};
    use fastvpinns::runtime::failpoint;

    if let Some(spec) = args.flag("failpoints") {
        failpoint::arm_from_spec(spec).context("parse --failpoints")?;
    }
    if let Some(path) = args.flag("metrics-out") {
        fastvpinns::telemetry::arm(path)
            .context("open --metrics-out")?;
    }
    // --resume goes through the generation ring: a run killed mid-save
    // leaves a torn primary, and the previous generation(s) at
    // <path>.g0/.g1 are the crash-safety net
    let resume: Option<Checkpoint> = match args.flag("resume") {
        Some(p) => {
            let primary = std::path::Path::new(p);
            let (ck, loaded_from) = Checkpoint::read_salvage(primary)?;
            if loaded_from != primary {
                eprintln!(
                    "warning: {p} was unreadable; salvaged {} \
                     (step {})",
                    loaded_from.display(), ck.step
                );
            }
            Some(ck)
        }
        None => None,
    };
    // effective args: the checkpoint's persisted invocation underneath
    // anything given now
    let eff: Args = match &resume {
        Some(ck) => {
            anyhow::ensure!(
                !ck.problem.is_empty(),
                "checkpoint has no registry problem id (it was \
                 exported outside `repro train --checkpoint`); rebuild \
                 the setup in code via NativeBackend::from_checkpoint \
                 instead"
            );
            // the trained hyper-parameters and network shape are
            // restored from the artifact — overriding them now would
            // silently train a different objective, so reject instead
            for k in ["tau", "gamma", "nb", "ns", "seed", "layers",
                      "problem"] {
                anyhow::ensure!(
                    !args.has(k),
                    "--{k} cannot be overridden on --resume (it is \
                     part of the trained state restored from the \
                     artifact); retrain from scratch to change it"
                );
            }
            let mut a = args.with_defaults(&ck.cli);
            a.set("problem", &ck.problem);
            a
        }
        None => args.clone(),
    };
    let problem_name = eff.str_or("problem", "poisson_sin");
    let entry = problems::registry::lookup(&problem_name)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown --problem '{problem_name}' (known: {})",
            problems::registry::name_list()
        ))?;
    let setup = (entry.build)(&eff)?;
    let iters = eff.usize_or("iters", setup.iters)?;
    // --lr overrides the registry's per-problem schedule with a
    // constant rate
    let lr = match eff.flag("lr") {
        Some(v) => LrSchedule::Constant(v.parse().map_err(
            |_| anyhow::anyhow!("--lr expects a number, got {v}"))?),
        None => setup.lr,
    };
    // --workers: persistent-pool size. Takes precedence over the
    // FASTVPINNS_THREADS env alias (checked by the backend when this
    // is None); zero and garbage are rejected here with the same
    // wording the backend uses for the env variable.
    let workers = match eff.flag("workers") {
        Some(v) => {
            let n: usize = v.parse().map_err(|_| anyhow::anyhow!(
                "--workers must be a positive integer, got '{v}'"))?;
            anyhow::ensure!(
                n > 0, "--workers must be a positive integer, got 0");
            Some(n)
        }
        None => None,
    };
    let cfg = TrainConfig {
        iters,
        lr,
        tau: eff.f64_or("tau", 10.0)?,
        seed: eff.usize_or("seed", 42)? as u64,
        log_every: eff.usize_or("log-every", 100)?,
        workers,
        ..TrainConfig::default()
    };
    // on resume the network shape is the artifact's, not --layers
    let layers = match &resume {
        Some(ck) => ck.layers.clone(),
        None => parse_layers(&eff.str_or("layers", "2,30,30,30,1"))?,
    };
    let nt1d = eff.usize_or("nt1d", 5)?;
    let nq1d = eff.usize_or("nq1d", 10)?;
    let (mesh, problem) = (setup.mesh, setup.problem);

    println!(
        "training {problem_name} [native backend]: {} cells, nt={}^2, \
         nq={}^2, net {:?}, {} iters",
        mesh.n_cells(), nt1d, nq1d, layers, cfg.iters
    );
    let dom = assembly::assemble(&mesh, nt1d, nq1d, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &*problem, sensor_values: None };
    let native = match &resume {
        Some(ck) => {
            // the worker count is run-control, not trained state:
            // from_checkpoint resolves the env/machine default, and an
            // explicit --workers re-sizes the pool afterwards
            let mut b = NativeBackend::from_checkpoint(ck, &src)?;
            if let Some(w) = cfg.workers {
                b.set_workers(w)?;
            }
            b
        }
        None => {
            let ncfg = NativeConfig {
                layers,
                loss: setup.loss,
                nb: eff.usize_or("nb", 400)?,
                ns: setup.ns,
            };
            NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg))?
        }
    };
    let mut trainer = Trainer::new(Box::new(native), &cfg);
    {
        // self-healing knobs (defaults in RecoveryPolicy):
        // --snapshot-every 0 turns healing off entirely
        let d = RecoveryPolicy::default();
        trainer.set_recovery_policy(RecoveryPolicy {
            snapshot_every: eff.usize_or("snapshot-every",
                                         d.snapshot_every)?,
            max_recoveries: eff.usize_or("max-recoveries",
                                         d.max_recoveries)?,
            lr_backoff: eff.f64_or("lr-backoff", d.lr_backoff)?,
            lr_restore_after: eff.usize_or("lr-restore-after",
                                           d.lr_restore_after)?,
            grad_norm_limit: eff.f64_or("grad-limit",
                                        d.grad_norm_limit)?,
            watchdog_ms: eff.usize_or("watchdog-ms",
                                      d.watchdog_ms as usize)?
                as u64,
        });
    }
    if let Some(ck) = &resume {
        trainer.resume_from_step(ck.step);
        if let Some(best) = ck.best_metric {
            // continue best-model tracking instead of letting the
            // first resumed save clobber <path>.best with a worse
            // model
            trainer.resume_best_metric(best);
        }
        println!(
            "resumed from step {} of '{}' ({} further iters)",
            ck.step, ck.problem, cfg.iters
        );
    }

    // evaluation grid (the paper's 100x100) — also the validation set
    // for best-model tracking when the solution is analytic
    let (lo, hi) = mesh.bbox();
    let grid = eval_grid(100, 100, lo[0], lo[1], hi[0], hi[1]);
    let exact_known = problem.exact(grid[0][0], grid[0][1]).is_some();

    // --checkpoint enables persistence; a bare --resume keeps saving
    // to the artifact it restarted from
    let ckpt_path: Option<String> = args
        .flag("checkpoint")
        .or_else(|| args.flag("resume"))
        .map(|s| s.to_string());
    if let Some(path) = &ckpt_path {
        trainer.set_checkpoint_policy(CheckpointPolicy {
            path: path.into(),
            every: eff.usize_or("checkpoint-every", 0)?,
            problem: problem_name.clone(),
            cli: persistable_flags(&eff),
        });
        if exact_known {
            let exact = exact_on_grid(&*problem, &grid)?;
            trainer.set_validation(grid.clone(), exact);
        }
    }

    let report = trainer.run()?;
    println!(
        "done: loss {:.4e} (var {:.4e}, bd {:.4e}), median {:.3} ms/step, \
         total {:.1}s",
        report.final_loss, report.final_var_loss, report.final_bd_loss,
        report.median_step_ms, report.total_seconds
    );
    if !report.recoveries.is_empty() {
        println!("recoveries: {} (final lr scale {:.3e})",
                 report.recoveries.len(), trainer.lr_scale());
        for ev in &report.recoveries {
            println!(
                "  step {} -> rolled back to {} ({}), lr scale {:.3e}",
                ev.at_step, ev.rollback_to, ev.reason, ev.lr_scale
            );
        }
    }
    if report.stalls > 0 {
        println!("watchdog: {} stalled step(s) flagged", report.stalls);
    }
    if let Some(eps) = report.eps_final {
        println!("trainable eps -> {eps:.5}");
    }

    // error vs exact on the paper's 100x100 grid (when analytic)
    let mut rel_l2_measured: Option<f64> = None;
    if setup.loss == NativeLoss::InverseSpace {
        // both heads in one trunk pass: u vs exact + the recovered
        // diffusion field vs the registered ground truth
        use fastvpinns::coordinator::metrics::ErrorNorms;
        let heads = trainer.predict_heads(&grid)?;
        anyhow::ensure!(heads.len() >= 2, "two-head network expected");
        if exact_known {
            let exact = exact_on_grid(&*problem, &grid)?;
            let err = ErrorNorms::compute_f32(&heads[0], &exact)?;
            println!("errors: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                     err.mae, err.rel_l2, err.linf);
            rel_l2_measured = Some(err.rel_l2);
        }
        if let Some(eps_star) = setup.eps_star {
            let eps_pred: Vec<f64> =
                heads[1].iter().map(|&v| v as f64).collect();
            let eps_exact: Vec<f64> =
                grid.iter().map(|p| eps_star(p[0], p[1])).collect();
            let err = ErrorNorms::compute(&eps_pred, &eps_exact)?;
            println!("eps field: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                     err.mae, err.rel_l2, err.linf);
        }
    } else if exact_known {
        let exact = exact_on_grid(&*problem, &grid)?;
        let err = trainer.evaluate(&grid, &exact)?;
        println!("errors: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                 err.mae, err.rel_l2, err.linf);
        rel_l2_measured = Some(err.rel_l2);
    }
    // history first: it is the diagnostic needed most when the
    // --expect-rel-l2 gate below fails the run
    if let Some(out) = args.flag("history") {
        trainer.history.to_csv(out)?;
        println!("history -> {out}");
    }
    if let Some(path) = &ckpt_path {
        // quadrature-point prediction hash: `repro infer --ckpt <path>
        // --quad` recomputes this from the written artifact, so
        // bit-for-bit reproduction is a string comparison away
        let qpts: Vec<[f64; 2]> =
            dom.quad_xy.chunks(2).map(|c| [c[0], c[1]]).collect();
        let uq = trainer.predict(&qpts)?;
        println!(
            "checkpoint -> {path} (step {}); quad-point u hash \
             {:016x} over {} points",
            report.steps, hash_f32_bits(&uq), uq.len()
        );
        if let Some(best) = report.best_metric {
            println!(
                "best model -> {path}.best ({} {best:.3e})",
                if exact_known { "validation rel-L2" } else { "loss" }
            );
        }
    }
    // --expect-rel-l2 F turns the printed error into an enforced gate
    // (nonzero exit on miss) — what the CI acceptance step runs
    if args.has("expect-rel-l2") {
        let bar = args.f64_or("expect-rel-l2", 1e-2)?;
        let got = rel_l2_measured.ok_or_else(|| anyhow::anyhow!(
            "--expect-rel-l2 needs a problem with an exact solution \
             ('{}' has none)", problem.name()))?;
        anyhow::ensure!(
            got < bar,
            "rel-L2 {got:.3e} failed the --expect-rel-l2 {bar:.1e} bar"
        );
        println!("rel-L2 {got:.3e} within the {bar:.1e} bar");
    }
    Ok(())
}

/// AOT/PJRT training (requires --features xla + `make artifacts`).
#[cfg(not(feature = "xla"))]
fn cmd_train_xla(_args: &Args) -> Result<()> {
    unreachable!("check_backend_name rejects xla without the feature")
}

#[cfg(feature = "xla")]
fn cmd_train_xla(args: &Args) -> Result<()> {
    {
        use fastvpinns::runtime::backend::xla::XlaBackend;
        use fastvpinns::runtime::engine::Engine;

        let engine = Engine::new(args.str_or("artifacts", "artifacts"))?;
        let name = args.req_str("artifact")?;
        let art = engine.load(&name)?;
        let c = art.manifest.config.clone();
        let omega = args.f64_or("omega-pi", 2.0)? * std::f64::consts::PI;
        let problem = problems::PoissonSin::new(omega);

        let k = (c.ne as f64).sqrt().round() as usize;
        if k * k != c.ne && art.manifest.loss != "pinn" {
            bail!("artifact ne={} is not a square grid; use the \
                   experiment drivers for mesh-specific runs", c.ne);
        }
        let mesh = generators::unit_square(k.max(1));
        let dom;
        let domain = if art.manifest.loss == "pinn" {
            None
        } else {
            dom = assembly::assemble(&mesh, c.nt1d, c.nq1d,
                                     QuadKind::GaussLegendre);
            Some(&dom)
        };
        let src = DataSource { mesh: &mesh, domain, problem: &problem,
                               sensor_values: None };
        let cfg = TrainConfig {
            iters: args.usize_or("iters", 2000)?,
            lr: LrSchedule::Constant(args.f64_or("lr", 1e-3)?),
            tau: args.f64_or("tau", 10.0)?,
            seed: args.usize_or("seed", 42)? as u64,
            log_every: args.usize_or("log-every", 100)?,
            ..TrainConfig::default()
        };
        let backend = XlaBackend::new(&engine, &name,
                                      Some("predict_std_16k"), &src,
                                      &BackendOpts::from(&cfg))?;
        let mut trainer = Trainer::new(Box::new(backend), &cfg);
        println!("training {name} (omega = {:.2}pi, {} iters)...",
                 omega / std::f64::consts::PI, cfg.iters);
        let report = trainer.run()?;
        println!(
            "done: loss {:.4e} (var {:.4e}, bd {:.4e}), median {:.3} \
             ms/step, total {:.1}s",
            report.final_loss, report.final_var_loss, report.final_bd_loss,
            report.median_step_ms, report.total_seconds
        );
        // error vs exact on the paper's 100x100 grid
        let grid = eval_grid(100, 100, 0.0, 0.0, 1.0, 1.0);
        let exact = exact_on_grid(&problem, &grid)?;
        if let Ok(err) = trainer.evaluate(&grid, &exact) {
            println!("errors: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                     err.mae, err.rel_l2, err.linf);
        }
        if let Some(out) = args.flag("history") {
            trainer.history.to_csv(out)?;
            println!("history -> {out}");
        }
        Ok(())
    }
}

/// Rebuild the training quadrature points of a CLI-written checkpoint
/// from its persisted registry id + flags, verifying the result
/// against the artifact's domain fingerprint.
fn quad_points_for(
    ck: &fastvpinns::runtime::checkpoint::Checkpoint,
) -> Result<Vec<[f64; 2]>> {
    use fastvpinns::runtime::checkpoint::hash_f64_bits;
    anyhow::ensure!(
        !ck.problem.is_empty(),
        "--quad needs a checkpoint written by `repro train \
         --checkpoint` (it stores the problem id and flags); this one \
         was exported manually"
    );
    let entry = problems::registry::lookup(&ck.problem).ok_or_else(
        || anyhow::anyhow!(
            "checkpoint problem '{}' is not in the registry (known: {})",
            ck.problem, problems::registry::name_list()
        ),
    )?;
    let mut a = Args::default();
    for (k, v) in &ck.cli {
        a.set(k, v);
    }
    let setup = (entry.build)(&a)?;
    let nt1d = a.usize_or("nt1d", 5)?;
    let nq1d = a.usize_or("nq1d", 10)?;
    let dom = assembly::assemble(&setup.mesh, nt1d, nq1d,
                                 QuadKind::GaussLegendre);
    anyhow::ensure!(
        hash_f64_bits(&dom.quad_xy) == ck.fingerprint.quad_hash,
        "rebuilt quadrature points do not match the checkpoint's \
         domain fingerprint — the mesh generator or assembly changed \
         since the artifact was written"
    );
    Ok(dom.quad_xy.chunks(2).map(|c| [c[0], c[1]]).collect())
}

/// Batched inference from a checkpoint: evaluate u (and the eps field
/// for two-head inverse models) over a query point cloud — a CSV
/// file, a uniform grid over the training bbox, or the training
/// quadrature points — through the blocked-GEMM forward path,
/// streaming CSV (or writing VTK) output.
fn cmd_infer(args: &Args) -> Result<()> {
    use fastvpinns::runtime::checkpoint::{hash_f32_bits, Checkpoint};
    use fastvpinns::runtime::infer::{
        read_points_csv, InferenceSession, Precision,
    };
    use fastvpinns::util::csv::CsvWriter;

    let path = args.req_str("ckpt")?;
    let ck = Checkpoint::read(&path)?;
    let mut sess = InferenceSession::from_checkpoint(&ck)?;
    let precision: Precision =
        args.str_or("precision", "f64").parse()?;
    sess.set_precision(precision);
    println!(
        "loaded {path}: problem '{}' ({}), loss {}, net {:?}{}, step \
         {}, serving {precision}",
        if ck.problem.is_empty() {
            "<manual export>"
        } else {
            ck.problem.as_str()
        },
        ck.problem_label, ck.loss_kind, ck.layers,
        if ck.two_head { " + eps head" } else { "" }, ck.step
    );
    if precision == Precision::F32 {
        println!(
            "note: --precision f32 serves the mixed-precision path \
             (rel err < 1e-5 vs f64); the u hash below will differ \
             from the exporting trainer's"
        );
    }

    let pts: Vec<[f64; 2]> = if let Some(f) = args.flag("points") {
        read_points_csv(f)?
    } else if args.has("quad") {
        quad_points_for(&ck)?
    } else {
        let n = args.usize_or("grid", 100)?.max(2);
        let [x0, y0, x1, y1] = ck.fingerprint.bbox;
        eval_grid(n, n, x0, y0, x1, y1)
    };
    anyhow::ensure!(!pts.is_empty(), "empty query point cloud");
    let batch = args.usize_or("batch", 4096)?.max(1);

    // evaluate batch-by-batch, streaming CSV rows as they are computed
    let out = args.flag("out").map(|s| s.to_string());
    let mut csv = match &out {
        Some(p) if p.ends_with(".csv") => Some(CsvWriter::create(
            p,
            if sess.two_head() { &["x", "y", "u", "eps"][..] }
            else { &["x", "y", "u"][..] },
        )?),
        Some(p) if p.ends_with(".vtk") => None,
        Some(p) => bail!(
            "--out '{p}': unknown extension (expected .csv or .vtk)"),
        None => None,
    };
    let mut u = Vec::with_capacity(pts.len());
    let mut eps: Option<Vec<f32>> = sess
        .two_head()
        .then(|| Vec::with_capacity(pts.len()));
    let t0 = std::time::Instant::now();
    for chunk in pts.chunks(batch) {
        let (cu, ce) = sess.eval(chunk);
        if let Some(w) = csv.as_mut() {
            for (i, p) in chunk.iter().enumerate() {
                match &ce {
                    Some(e) => w.row_f64(&[p[0], p[1], cu[i] as f64,
                                           e[i] as f64])?,
                    None => w.row_f64(&[p[0], p[1], cu[i] as f64])?,
                }
            }
        }
        if let (Some(all), Some(e)) = (eps.as_mut(), ce) {
            all.extend_from_slice(&e);
        }
        u.extend_from_slice(&cu);
    }
    let secs = t0.elapsed().as_secs_f64();
    if let Some(w) = csv.as_mut() {
        w.flush()?;
    }
    if let Some(p) = &out {
        if p.ends_with(".vtk") {
            let uf: Vec<f64> = u.iter().map(|&v| v as f64).collect();
            let ef: Option<Vec<f64>> = eps
                .as_ref()
                .map(|e| e.iter().map(|&v| v as f64).collect());
            let mut fields: Vec<(&str, &[f64])> =
                vec![("u", uf.as_slice())];
            if let Some(ef) = &ef {
                fields.push(("eps", ef.as_slice()));
            }
            fastvpinns::mesh::vtk::write_point_cloud(&pts, &fields, p)?;
        }
        println!("predictions -> {p}");
    }

    let (umin, umax) = u.iter().fold(
        (f64::MAX, f64::MIN),
        |(lo, hi), &v| (lo.min(v as f64), hi.max(v as f64)),
    );
    println!(
        "{} points in {:.3}s (batch {batch}): {:.0} points/s, u in \
         [{umin:.4}, {umax:.4}]",
        u.len(), secs, u.len() as f64 / secs.max(1e-12)
    );
    if let Some(e) = &eps {
        let (emin, emax) = e.iter().fold(
            (f64::MAX, f64::MIN),
            |(lo, hi), &v| (lo.min(v as f64), hi.max(v as f64)),
        );
        println!("eps field in [{emin:.4}, {emax:.4}]");
    }
    // with --quad this reproduces the hash `repro train --checkpoint`
    // printed — bit-for-bit agreement with the exporting trainer
    println!("u hash {:016x} over {} points", hash_f32_bits(&u),
             u.len());
    Ok(())
}

fn build_mesh(kind: &str, n: usize) -> Result<QuadMesh> {
    Ok(match kind {
        "square" => generators::unit_square(n.max(1)),
        "skewed" => generators::skewed_square(n.max(1), 0.25),
        "disk" => generators::disk_1024(),
        "gear" => generators::gear_ci(),
        "gear-paper" => generators::gear_paper(),
        "annulus" => generators::annulus(n.max(8), (n / 4).max(2), 0.0,
                                         0.0, 0.5, 1.0),
        other => bail!("unknown mesh kind '{other}'"),
    })
}

fn cmd_fem_solve(args: &Args) -> Result<()> {
    let kind = args.str_or("mesh", "square");
    let n = args.usize_or("n", 32)?;
    let mesh = build_mesh(&kind, n)?;
    let omega = args.f64_or("omega-pi", 1.0)? * std::f64::consts::PI;
    println!("FEM solve on {kind} mesh: {} cells, {} DOFs",
             mesh.n_cells(), mesh.n_points());
    let t0 = std::time::Instant::now();
    let sol = match kind.as_str() {
        "gear" | "gear-paper" => {
            // the Problem-driven entry point: coefficients (incl. the
            // gear's convection) come from the trait
            fem_solver::solve_problem(&mesh, &problems::GearCd, 3)?
        }
        _ => {
            let f = move |x: f64, y: f64| {
                2.0 * omega * omega * (omega * x).sin() * (omega * y).sin()
            };
            fem_solver::solve(&mesh, &FemProblem {
                eps: &|_, _| 1.0,
                b: None,
                c: None,
                f: &f,
                g: &|_, _| 0.0,
            }, 3)?
        }
    };
    println!("solved in {:.3}s ({} linear iterations)",
             t0.elapsed().as_secs_f64(), sol.solve_iterations);
    let mx = sol.u.iter().cloned().fold(f64::MIN, f64::max);
    let mn = sol.u.iter().cloned().fold(f64::MAX, f64::min);
    println!("u in [{mn:.4}, {mx:.4}]");
    if let Some(out) = args.flag("out") {
        fastvpinns::mesh::vtk::write_point_fields(&mesh, &[("u", &sol.u)],
                                                  out)?;
        println!("field -> {out}");
    }
    Ok(())
}

fn cmd_mesh(args: &Args) -> Result<()> {
    let kind = args.str_or("kind", "square");
    let n = args.usize_or("n", 8)?;
    let mesh = build_mesh(&kind, n)?;
    let r = quality::report(&mesh);
    println!("{kind}: {} cells, {} points", r.n_cells, r.n_points);
    println!("  valid: {} (min |J| {:.3e})", r.all_valid, r.min_jac);
    println!("  worst in-cell Jacobian ratio: {:.3}", r.worst_ratio);
    println!("  max aspect ratio: {:.2}", r.max_aspect);
    println!("  area: {:.6}", r.area);
    println!("  boundary edges: {}", mesh.boundary.len());
    if let Some(out) = args.flag("out") {
        gmsh::write(&mesh, out)?;
        println!("mesh -> {out}");
    }
    Ok(())
}

/// Cross-validation dumps consumed by python/tests/test_cross_validation.py
/// — the case list must stay in sync with CASES there.
fn cmd_dump_tensors(args: &Args) -> Result<()> {
    let base = args.str_or("out", "artifacts/crosscheck");
    let cases: [(&str, QuadMesh, usize, usize); 3] = [
        ("square4_nt3_nq5", generators::unit_square(4), 3, 5),
        ("skewed4_nt3_nq5", generators::skewed_square(4, 0.15), 3, 5),
        ("square2_nt5_nq10", generators::unit_square(2), 5, 10),
    ];
    for (tag, mesh, nt, nq) in cases {
        let dir = std::path::PathBuf::from(&base).join(tag);
        std::fs::create_dir_all(&dir)?;
        let d = assembly::assemble(&mesh, nt, nq, QuadKind::GaussLegendre);
        let f = d.force_matrix(|x, y| x.sin() * y.cos() + 2.0 * x * y);
        npy::write_f64(dir.join("quad_xy.npy"), &d.quad_xy,
                       &[d.ne * d.nq, 2])?;
        npy::write_f64(dir.join("gx.npy"), &d.gx, &[d.ne, d.nt, d.nq])?;
        npy::write_f64(dir.join("gy.npy"), &d.gy, &[d.ne, d.nt, d.nq])?;
        npy::write_f64(dir.join("v.npy"), &d.v, &[d.ne, d.nt, d.nq])?;
        npy::write_f64(dir.join("f.npy"), &f, &[d.ne, d.nt])?;
        npy::write_f64(dir.join("jdet.npy"), &d.jdet, &[d.ne, d.nq])?;
        println!("dumped {tag} -> {}", dir.display());
    }
    println!("now run: cd python && pytest tests/test_cross_validation.py");
    Ok(())
}
