//! `repro` — the FastVPINNs L3 coordinator CLI.
//!
//! Subcommands:
//!   train [--backend native|xla] ...  train a problem (native: pure
//!                                     Rust, no artifacts; xla: AOT)
//!   bench [--quick] ...            time the native train-step hot path
//!                                  and write BENCH_native_step.json
//!   artifacts                      list available AOT artifacts (xla)
//!   experiment <id|all> ...        regenerate a paper table/figure
//!   fem-solve --mesh <kind> ...    run the classical FEM reference solver
//!   mesh --kind <kind> ...         generate/inspect/export meshes
//!   dump-tensors                   write assembly dumps for pytest
//!                                  cross-validation (`make crosscheck`)

use anyhow::{bail, Result};

use fastvpinns::coordinator::metrics::eval_grid;
use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::experiments;
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::fem_solver::{self, FemProblem};
use fastvpinns::mesh::{generators, gmsh, quality, QuadMesh};
use fastvpinns::problems::{self, Problem};
use fastvpinns::runtime::backend::native::{
    NativeBackend, NativeConfig, NativeLoss,
};
use fastvpinns::runtime::backend::{check_backend_name, BackendOpts};
use fastvpinns::util::cli::Args;
use fastvpinns::util::npy;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "artifacts" => cmd_artifacts(args),
        "train" => cmd_train(args),
        "bench" => cmd_bench(args),
        "experiment" => {
            if args.positional.is_empty() {
                bail!("usage: repro experiment <id|all> (ids: {:?})",
                      experiments::ALL);
            }
            for id in &args.positional {
                experiments::run(id, args)?;
            }
            Ok(())
        }
        "fem-solve" => cmd_fem_solve(args),
        "mesh" => cmd_mesh(args),
        "dump-tensors" => cmd_dump_tensors(args),
        "" | "help" | "--help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{}", usage()),
    }
}

/// The CLI help text. The `--problem` list is derived from the single
/// problem registry (`problems::registry`), so it cannot drift from
/// the set `repro train` actually dispatches on.
fn usage() -> String {
    format!(
        "\
repro — FastVPINNs coordinator
  repro train [--backend native|xla]
              [--problem {problems}]
              [--omega-pi K] [--k-pi K] [--n N] [--nt1d N] [--nq1d N]
              [--layers 2,30,30,30,1] [--iters N] [--lr F] [--tau F]
              [--seed N] [--ns N] [--expect-rel-l2 F] [--history F.csv]
              (xla backend: --artifact NAME [--artifacts DIR])
  repro bench [--backend native] [--quick] [--iters N] [--warmup N]
              [--nt1d N] [--nq1d N] [--out BENCH_native_step.json]
  repro artifacts [--artifacts DIR]              (requires --features xla)
  repro experiment <{experiments}|all>
              [--backend native|xla] [--iters N] [--paper-scale]
  repro fem-solve --mesh <square|disk|gear> [--n N] [--omega-pi K]
  repro mesh --kind <square|skewed|disk|gear|annulus> [--n N] [--out F.msh]
  repro dump-tensors [--out DIR]

problems (from the registry):
{summaries}",
        problems = problems::registry::name_list(),
        experiments = experiments::ALL.join("|"),
        summaries = problems::registry::REGISTRY
            .iter()
            .map(|e| format!("  {:<14} {}", e.name, e.summary))
            .collect::<Vec<_>>()
            .join("\n"),
    )
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts(_args: &Args) -> Result<()> {
    bail!("the artifacts subcommand needs the xla runtime — rebuild \
           with `cargo build --features xla`")
}

#[cfg(feature = "xla")]
fn cmd_artifacts(args: &Args) -> Result<()> {
    use fastvpinns::runtime::engine::Engine;
    let engine = Engine::new(args.str_or("artifacts", "artifacts"))?;
    let names = engine.list()?;
    if names.is_empty() {
        println!("no artifacts found — run `make artifacts`");
        return Ok(());
    }
    println!("{} artifacts under {} (platform: {}):", names.len(),
             engine.artifact_dir().display(), engine.platform());
    for n in names {
        let art = engine.load(&n);
        match art {
            Ok(a) => {
                let c = &a.manifest.config;
                println!(
                    "  {n:<42} {:<8} ne={:<6} nt={:<4} nq={:<5} \
                     kernel={} ({:.2}s compile)",
                    a.manifest.kind, c.ne, c.nt, c.nq, c.kernel,
                    a.compile_seconds
                );
            }
            Err(e) => println!("  {n:<42} FAILED: {e}"),
        }
    }
    Ok(())
}

/// Parse `--layers 2,30,30,30,1`.
fn parse_layers(spec: &str) -> Result<Vec<usize>> {
    let layers: Vec<usize> = spec
        .split(',')
        .map(|t| t.trim().parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|_| anyhow::anyhow!("--layers expects e.g. 2,30,30,30,1"))?;
    Ok(layers)
}

/// Time the native train-step hot path across grid sizes and write a
/// JSON perf record — the tracked datapoint CI uploads on every PR.
fn cmd_bench(args: &Args) -> Result<()> {
    use fastvpinns::experiments::common::{
        native_forward_step_case, native_inverse_space_step_case,
        native_step_case, StepBenchCase, STD_LAYERS,
    };
    use fastvpinns::util::json::Json;

    let backend = args.str_or("backend", "native");
    check_backend_name(&backend)?;
    if backend != "native" {
        bail!("repro bench currently times the native backend only");
    }
    let quick = args.has("quick");
    let (ks, pde_ks, iters_default, warmup_default): (&[usize], &[usize],
                                                      usize, usize) =
        if quick {
            (&[4, 8, 16], &[4, 16], 5, 2)
        } else {
            (&[4, 8, 16, 32, 64], &[4, 16, 64], 15, 3)
        };
    let iters = args.usize_or("iters", iters_default)?.max(1);
    let warmup = args.usize_or("warmup", warmup_default)?;
    let nt1d = args.usize_or("nt1d", 5)?;
    let nq1d = args.usize_or("nq1d", 5)?;
    let out_path = args.str_or("out", "BENCH_native_step.json");
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!(
        "bench: native train step, net {STD_LAYERS:?}, nt={nt1d}^2, \
         nq={nq1d}^2, {iters} iters (+{warmup} warmup), {threads} threads"
    );
    let mut cases = Vec::new();
    let mut push_case = |case: &StepBenchCase| {
        let s = &case.summary;
        println!(
            "  {:<14} {:<17} ne={:<6} ({:>8} quad pts)  median {:>9.3} \
             ms/step  p90 {:>9.3} ms",
            case.loss, case.pde, case.ne, case.n_quad, s.median, s.p90
        );
        cases.push(Json::obj(vec![
            ("loss", Json::str(case.loss)),
            ("pde", Json::str(case.pde)),
            ("ne", Json::num(case.ne as f64)),
            ("n_quad", Json::num(case.n_quad as f64)),
            ("dof", Json::num(case.dof as f64)),
            // effective worker count (clamped to ne), not machine cores
            ("threads", Json::num(case.threads as f64)),
            ("median_ms", Json::num(s.median)),
            ("p90_ms", Json::num(s.p90)),
            ("min_ms", Json::num(s.min)),
            ("mean_ms", Json::num(s.mean)),
        ]));
    };
    for &k in ks {
        push_case(&native_step_case(k, nt1d, nq1d, iters, warmup)?);
    }
    // the generalized-form PDE cases on a subset of grids: Helmholtz
    // (reaction term) and the rotating variable-convection field
    for &k in pde_ks {
        push_case(&native_forward_step_case("helmholtz", k, nt1d, nq1d,
                                            iters, warmup)?);
        push_case(&native_forward_step_case("cd_var", k, nt1d, nq1d,
                                            iters, warmup)?);
    }
    // the two-head inverse-space step on the same grids: tracks the
    // eps head's cost on the blocked tensor path
    for &k in pde_ks {
        push_case(&native_inverse_space_step_case(k, nt1d, nq1d, iters,
                                                  warmup)?);
    }
    // hoisting regression probe: the same constant-coefficient Poisson
    // problem once on the scalar fast path and once forced through the
    // generalized per-point eps table path, measured back to back. The
    // coefficient tables are precomputed at backend construction; if
    // they were re-evaluated per step the table case would blow far
    // past this bound. A fixed ne=256 grid with >= 20 timed iters
    // keeps the medians stable enough for the 5% gate even on noisy
    // shared runners (and avoids re-timing the ne=4096 case in full
    // mode just for the ratio).
    let k_ref = 16;
    let (h_iters, h_warmup) = (iters.max(20), warmup.max(3));
    let mut base = native_step_case(k_ref, nt1d, nq1d, h_iters, h_warmup)?;
    let mut tab = native_forward_step_case("poisson_tab", k_ref, nt1d,
                                           nq1d, h_iters, h_warmup)?;
    let mut ratio = tab.summary.median / base.summary.median;
    if ratio > 1.05 {
        // one retry with min-of-medians before failing: a shared
        // runner's noisy neighbor between the back-to-back runs can
        // breach 5% without any real regression, but a table path
        // that re-evaluated coefficients per step would miss by far
        // more than two retries can hide
        let base2 =
            native_step_case(k_ref, nt1d, nq1d, h_iters, h_warmup)?;
        let tab2 = native_forward_step_case("poisson_tab", k_ref, nt1d,
                                            nq1d, h_iters, h_warmup)?;
        if base2.summary.median < base.summary.median {
            base = base2;
        }
        if tab2.summary.median < tab.summary.median {
            tab = tab2;
        }
        ratio = tab.summary.median / base.summary.median;
    }
    push_case(&tab);
    println!(
        "  hoisting check: poisson_tab / poisson median ratio {ratio:.3} \
         at ne={}",
        k_ref * k_ref
    );
    if ratio > 1.05 {
        bail!(
            "generalized coefficient-table path regressed the \
             constant-coefficient poisson step by {:.1}% (> 5%): the \
             tables must be hoisted, not recomputed per step \
             (poisson {:.3} ms vs poisson_tab {:.3} ms at ne={})",
            (ratio - 1.0) * 100.0, base.summary.median,
            tab.summary.median, k_ref * k_ref
        );
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("native_step")),
        ("backend", Json::str("native")),
        ("layers",
         Json::Arr(STD_LAYERS.iter().map(|&w| Json::num(w as f64))
             .collect())),
        ("nt1d", Json::num(nt1d as f64)),
        ("nq1d", Json::num(nq1d as f64)),
        ("iters", Json::num(iters as f64)),
        ("warmup", Json::num(warmup as f64)),
        ("threads", Json::num(threads as f64)),
        ("quick", Json::Bool(quick)),
        ("cases", Json::Arr(cases)),
    ]);
    std::fs::write(&out_path, format!("{doc}\n"))?;
    println!("bench record -> {out_path}");
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let backend = args.str_or("backend", "native");
    check_backend_name(&backend)?;
    match backend.as_str() {
        "native" => cmd_train_native(args),
        "xla" => cmd_train_xla(args),
        _ => unreachable!(),
    }
}

/// Pure-Rust training: no artifacts, no Python, no XLA. The problem
/// family is looked up in the single registry (`problems::registry`),
/// which also owns the USAGE list — mesh, loss mode and sensor counts
/// all come from the entry; the PDE coefficients come from the problem
/// itself via its variational form.
fn cmd_train_native(args: &Args) -> Result<()> {
    let problem_name = args.str_or("problem", "poisson_sin");
    let entry = problems::registry::lookup(&problem_name)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown --problem '{problem_name}' (known: {})",
            problems::registry::name_list()
        ))?;
    let setup = (entry.build)(args)?;
    let iters = args.usize_or("iters", setup.iters)?;
    // --lr overrides the registry's per-problem schedule with a
    // constant rate
    let lr = match args.flag("lr") {
        Some(v) => LrSchedule::Constant(v.parse().map_err(
            |_| anyhow::anyhow!("--lr expects a number, got {v}"))?),
        None => setup.lr,
    };
    let cfg = TrainConfig {
        iters,
        lr,
        tau: args.f64_or("tau", 10.0)?,
        seed: args.usize_or("seed", 42)? as u64,
        log_every: args.usize_or("log-every", 100)?,
        ..TrainConfig::default()
    };
    let layers = parse_layers(&args.str_or("layers", "2,30,30,30,1"))?;
    let nt1d = args.usize_or("nt1d", 5)?;
    let nq1d = args.usize_or("nq1d", 10)?;
    let (mesh, problem) = (setup.mesh, setup.problem);

    println!(
        "training {problem_name} [native backend]: {} cells, nt={}^2, \
         nq={}^2, net {:?}, {} iters",
        mesh.n_cells(), nt1d, nq1d, layers, cfg.iters
    );
    let dom = assembly::assemble(&mesh, nt1d, nq1d, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &*problem, sensor_values: None };
    let ncfg = NativeConfig {
        layers,
        loss: setup.loss,
        nb: args.usize_or("nb", 400)?,
        ns: setup.ns,
    };
    let native = NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg))?;
    let mut trainer = Trainer::new(Box::new(native), &cfg);
    let report = trainer.run()?;
    println!(
        "done: loss {:.4e} (var {:.4e}, bd {:.4e}), median {:.3} ms/step, \
         total {:.1}s",
        report.final_loss, report.final_var_loss, report.final_bd_loss,
        report.median_step_ms, report.total_seconds
    );
    if let Some(eps) = report.eps_final {
        println!("trainable eps -> {eps:.5}");
    }

    // error vs exact on the paper's 100x100 grid (when analytic)
    let (lo, hi) = mesh.bbox();
    let grid = eval_grid(100, 100, lo[0], lo[1], hi[0], hi[1]);
    let exact_known = problem.exact(grid[0][0], grid[0][1]).is_some();
    let mut rel_l2_measured: Option<f64> = None;
    if setup.loss == NativeLoss::InverseSpace {
        // both heads in one trunk pass: u vs exact + the recovered
        // diffusion field vs the registered ground truth
        use fastvpinns::coordinator::metrics::ErrorNorms;
        let heads = trainer.predict_heads(&grid)?;
        anyhow::ensure!(heads.len() >= 2, "two-head network expected");
        if exact_known {
            let exact: Vec<f64> = grid
                .iter()
                .map(|p| problem.exact(p[0], p[1]).unwrap())
                .collect();
            let err = ErrorNorms::compute_f32(&heads[0], &exact);
            println!("errors: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                     err.mae, err.rel_l2, err.linf);
            rel_l2_measured = Some(err.rel_l2);
        }
        if let Some(eps_star) = setup.eps_star {
            let eps_pred: Vec<f64> =
                heads[1].iter().map(|&v| v as f64).collect();
            let eps_exact: Vec<f64> =
                grid.iter().map(|p| eps_star(p[0], p[1])).collect();
            let err = ErrorNorms::compute(&eps_pred, &eps_exact);
            println!("eps field: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                     err.mae, err.rel_l2, err.linf);
        }
    } else if exact_known {
        let exact: Vec<f64> = grid
            .iter()
            .map(|p| problem.exact(p[0], p[1]).unwrap())
            .collect();
        let err = trainer.evaluate(&grid, &exact)?;
        println!("errors: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                 err.mae, err.rel_l2, err.linf);
        rel_l2_measured = Some(err.rel_l2);
    }
    // history first: it is the diagnostic needed most when the
    // --expect-rel-l2 gate below fails the run
    if let Some(out) = args.flag("history") {
        trainer.history.to_csv(out)?;
        println!("history -> {out}");
    }
    // --expect-rel-l2 F turns the printed error into an enforced gate
    // (nonzero exit on miss) — what the CI acceptance step runs
    if args.has("expect-rel-l2") {
        let bar = args.f64_or("expect-rel-l2", 1e-2)?;
        let got = rel_l2_measured.ok_or_else(|| anyhow::anyhow!(
            "--expect-rel-l2 needs a problem with an exact solution \
             ('{}' has none)", problem.name()))?;
        anyhow::ensure!(
            got < bar,
            "rel-L2 {got:.3e} failed the --expect-rel-l2 {bar:.1e} bar"
        );
        println!("rel-L2 {got:.3e} within the {bar:.1e} bar");
    }
    Ok(())
}

/// AOT/PJRT training (requires --features xla + `make artifacts`).
#[cfg(not(feature = "xla"))]
fn cmd_train_xla(_args: &Args) -> Result<()> {
    unreachable!("check_backend_name rejects xla without the feature")
}

#[cfg(feature = "xla")]
fn cmd_train_xla(args: &Args) -> Result<()> {
    {
        use fastvpinns::runtime::backend::xla::XlaBackend;
        use fastvpinns::runtime::engine::Engine;

        let engine = Engine::new(args.str_or("artifacts", "artifacts"))?;
        let name = args.req_str("artifact")?;
        let art = engine.load(&name)?;
        let c = art.manifest.config.clone();
        let omega = args.f64_or("omega-pi", 2.0)? * std::f64::consts::PI;
        let problem = problems::PoissonSin::new(omega);

        let k = (c.ne as f64).sqrt().round() as usize;
        if k * k != c.ne && art.manifest.loss != "pinn" {
            bail!("artifact ne={} is not a square grid; use the \
                   experiment drivers for mesh-specific runs", c.ne);
        }
        let mesh = generators::unit_square(k.max(1));
        let dom;
        let domain = if art.manifest.loss == "pinn" {
            None
        } else {
            dom = assembly::assemble(&mesh, c.nt1d, c.nq1d,
                                     QuadKind::GaussLegendre);
            Some(&dom)
        };
        let src = DataSource { mesh: &mesh, domain, problem: &problem,
                               sensor_values: None };
        let cfg = TrainConfig {
            iters: args.usize_or("iters", 2000)?,
            lr: LrSchedule::Constant(args.f64_or("lr", 1e-3)?),
            tau: args.f64_or("tau", 10.0)?,
            seed: args.usize_or("seed", 42)? as u64,
            log_every: args.usize_or("log-every", 100)?,
            ..TrainConfig::default()
        };
        let backend = XlaBackend::new(&engine, &name,
                                      Some("predict_std_16k"), &src,
                                      &BackendOpts::from(&cfg))?;
        let mut trainer = Trainer::new(Box::new(backend), &cfg);
        println!("training {name} (omega = {:.2}pi, {} iters)...",
                 omega / std::f64::consts::PI, cfg.iters);
        let report = trainer.run()?;
        println!(
            "done: loss {:.4e} (var {:.4e}, bd {:.4e}), median {:.3} \
             ms/step, total {:.1}s",
            report.final_loss, report.final_var_loss, report.final_bd_loss,
            report.median_step_ms, report.total_seconds
        );
        // error vs exact on the paper's 100x100 grid
        let grid = eval_grid(100, 100, 0.0, 0.0, 1.0, 1.0);
        let exact: Vec<f64> = grid
            .iter()
            .map(|p| problem.exact(p[0], p[1]).unwrap())
            .collect();
        if let Ok(err) = trainer.evaluate(&grid, &exact) {
            println!("errors: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                     err.mae, err.rel_l2, err.linf);
        }
        if let Some(out) = args.flag("history") {
            trainer.history.to_csv(out)?;
            println!("history -> {out}");
        }
        Ok(())
    }
}

fn build_mesh(kind: &str, n: usize) -> Result<QuadMesh> {
    Ok(match kind {
        "square" => generators::unit_square(n.max(1)),
        "skewed" => generators::skewed_square(n.max(1), 0.25),
        "disk" => generators::disk_1024(),
        "gear" => generators::gear_ci(),
        "gear-paper" => generators::gear_paper(),
        "annulus" => generators::annulus(n.max(8), (n / 4).max(2), 0.0,
                                         0.0, 0.5, 1.0),
        other => bail!("unknown mesh kind '{other}'"),
    })
}

fn cmd_fem_solve(args: &Args) -> Result<()> {
    let kind = args.str_or("mesh", "square");
    let n = args.usize_or("n", 32)?;
    let mesh = build_mesh(&kind, n)?;
    let omega = args.f64_or("omega-pi", 1.0)? * std::f64::consts::PI;
    println!("FEM solve on {kind} mesh: {} cells, {} DOFs",
             mesh.n_cells(), mesh.n_points());
    let t0 = std::time::Instant::now();
    let sol = match kind.as_str() {
        "gear" | "gear-paper" => {
            // the Problem-driven entry point: coefficients (incl. the
            // gear's convection) come from the trait
            fem_solver::solve_problem(&mesh, &problems::GearCd, 3)?
        }
        _ => {
            let f = move |x: f64, y: f64| {
                2.0 * omega * omega * (omega * x).sin() * (omega * y).sin()
            };
            fem_solver::solve(&mesh, &FemProblem {
                eps: &|_, _| 1.0,
                b: None,
                c: None,
                f: &f,
                g: &|_, _| 0.0,
            }, 3)?
        }
    };
    println!("solved in {:.3}s ({} linear iterations)",
             t0.elapsed().as_secs_f64(), sol.solve_iterations);
    let mx = sol.u.iter().cloned().fold(f64::MIN, f64::max);
    let mn = sol.u.iter().cloned().fold(f64::MAX, f64::min);
    println!("u in [{mn:.4}, {mx:.4}]");
    if let Some(out) = args.flag("out") {
        fastvpinns::mesh::vtk::write_point_fields(&mesh, &[("u", &sol.u)],
                                                  out)?;
        println!("field -> {out}");
    }
    Ok(())
}

fn cmd_mesh(args: &Args) -> Result<()> {
    let kind = args.str_or("kind", "square");
    let n = args.usize_or("n", 8)?;
    let mesh = build_mesh(&kind, n)?;
    let r = quality::report(&mesh);
    println!("{kind}: {} cells, {} points", r.n_cells, r.n_points);
    println!("  valid: {} (min |J| {:.3e})", r.all_valid, r.min_jac);
    println!("  worst in-cell Jacobian ratio: {:.3}", r.worst_ratio);
    println!("  max aspect ratio: {:.2}", r.max_aspect);
    println!("  area: {:.6}", r.area);
    println!("  boundary edges: {}", mesh.boundary.len());
    if let Some(out) = args.flag("out") {
        gmsh::write(&mesh, out)?;
        println!("mesh -> {out}");
    }
    Ok(())
}

/// Cross-validation dumps consumed by python/tests/test_cross_validation.py
/// — the case list must stay in sync with CASES there.
fn cmd_dump_tensors(args: &Args) -> Result<()> {
    let base = args.str_or("out", "artifacts/crosscheck");
    let cases: [(&str, QuadMesh, usize, usize); 3] = [
        ("square4_nt3_nq5", generators::unit_square(4), 3, 5),
        ("skewed4_nt3_nq5", generators::skewed_square(4, 0.15), 3, 5),
        ("square2_nt5_nq10", generators::unit_square(2), 5, 10),
    ];
    for (tag, mesh, nt, nq) in cases {
        let dir = std::path::PathBuf::from(&base).join(tag);
        std::fs::create_dir_all(&dir)?;
        let d = assembly::assemble(&mesh, nt, nq, QuadKind::GaussLegendre);
        let f = d.force_matrix(|x, y| x.sin() * y.cos() + 2.0 * x * y);
        npy::write_f64(dir.join("quad_xy.npy"), &d.quad_xy,
                       &[d.ne * d.nq, 2])?;
        npy::write_f64(dir.join("gx.npy"), &d.gx, &[d.ne, d.nt, d.nq])?;
        npy::write_f64(dir.join("gy.npy"), &d.gy, &[d.ne, d.nt, d.nq])?;
        npy::write_f64(dir.join("v.npy"), &d.v, &[d.ne, d.nt, d.nq])?;
        npy::write_f64(dir.join("f.npy"), &f, &[d.ne, d.nt])?;
        npy::write_f64(dir.join("jdet.npy"), &d.jdet, &[d.ne, d.nq])?;
        println!("dumped {tag} -> {}", dir.display());
    }
    println!("now run: cd python && pytest tests/test_cross_validation.py");
    Ok(())
}
