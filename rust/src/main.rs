//! `repro` — the FastVPINNs L3 coordinator CLI.
//!
//! Subcommands:
//!   train [--backend native|xla] ...  train a problem (native: pure
//!                                     Rust, no artifacts; xla: AOT)
//!   bench [--quick] ...            time the native train-step hot path
//!                                  and write BENCH_native_step.json
//!   artifacts                      list available AOT artifacts (xla)
//!   experiment <id|all> ...        regenerate a paper table/figure
//!   fem-solve --mesh <kind> ...    run the classical FEM reference solver
//!   mesh --kind <kind> ...         generate/inspect/export meshes
//!   dump-tensors                   write assembly dumps for pytest
//!                                  cross-validation (`make crosscheck`)

use anyhow::{bail, Result};

use fastvpinns::coordinator::metrics::eval_grid;
use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::experiments;
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::fem_solver::{self, FemProblem};
use fastvpinns::mesh::{generators, gmsh, quality, QuadMesh};
use fastvpinns::problems::{self, Problem};
use fastvpinns::runtime::backend::native::{
    NativeBackend, NativeConfig, NativeLoss,
};
use fastvpinns::runtime::backend::{check_backend_name, BackendOpts};
use fastvpinns::util::cli::Args;
use fastvpinns::util::npy;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "artifacts" => cmd_artifacts(args),
        "train" => cmd_train(args),
        "bench" => cmd_bench(args),
        "experiment" => {
            if args.positional.is_empty() {
                bail!("usage: repro experiment <id|all> (ids: {:?})",
                      experiments::ALL);
            }
            for id in &args.positional {
                experiments::run(id, args)?;
            }
            Ok(())
        }
        "fem-solve" => cmd_fem_solve(args),
        "mesh" => cmd_mesh(args),
        "dump-tensors" => cmd_dump_tensors(args),
        "" | "help" | "--help" => {
            println!("{}", USAGE);
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

const USAGE: &str = "\
repro — FastVPINNs coordinator
  repro train [--backend native|xla] [--problem poisson_sin|cd_gear|
              inverse_const|inverse_space] [--omega-pi K] [--n N]
              [--nt1d N] [--nq1d N] [--layers 2,30,30,30,1] [--iters N]
              [--lr F] [--tau F] [--seed N] [--ns N] [--history F.csv]
              (xla backend: --artifact NAME [--artifacts DIR])
  repro bench [--backend native] [--quick] [--iters N] [--warmup N]
              [--nt1d N] [--nq1d N] [--out BENCH_native_step.json]
  repro artifacts [--artifacts DIR]              (requires --features xla)
  repro experiment <fig02|fig08|fig09|fig10|fig11|fig12|fig14|fig15|
                    fig16|table1|all> [--backend native|xla] [--iters N]
                    [--paper-scale]
  repro fem-solve --mesh <square|disk|gear> [--n N] [--omega-pi K]
  repro mesh --kind <square|skewed|disk|gear|annulus> [--n N] [--out F.msh]
  repro dump-tensors [--out DIR]";

#[cfg(not(feature = "xla"))]
fn cmd_artifacts(_args: &Args) -> Result<()> {
    bail!("the artifacts subcommand needs the xla runtime — rebuild \
           with `cargo build --features xla`")
}

#[cfg(feature = "xla")]
fn cmd_artifacts(args: &Args) -> Result<()> {
    use fastvpinns::runtime::engine::Engine;
    let engine = Engine::new(args.str_or("artifacts", "artifacts"))?;
    let names = engine.list()?;
    if names.is_empty() {
        println!("no artifacts found — run `make artifacts`");
        return Ok(());
    }
    println!("{} artifacts under {} (platform: {}):", names.len(),
             engine.artifact_dir().display(), engine.platform());
    for n in names {
        let art = engine.load(&n);
        match art {
            Ok(a) => {
                let c = &a.manifest.config;
                println!(
                    "  {n:<42} {:<8} ne={:<6} nt={:<4} nq={:<5} \
                     kernel={} ({:.2}s compile)",
                    a.manifest.kind, c.ne, c.nt, c.nq, c.kernel,
                    a.compile_seconds
                );
            }
            Err(e) => println!("  {n:<42} FAILED: {e}"),
        }
    }
    Ok(())
}

/// Parse `--layers 2,30,30,30,1`.
fn parse_layers(spec: &str) -> Result<Vec<usize>> {
    let layers: Vec<usize> = spec
        .split(',')
        .map(|t| t.trim().parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|_| anyhow::anyhow!("--layers expects e.g. 2,30,30,30,1"))?;
    Ok(layers)
}

/// Time the native train-step hot path across grid sizes and write a
/// JSON perf record — the tracked datapoint CI uploads on every PR.
fn cmd_bench(args: &Args) -> Result<()> {
    use fastvpinns::experiments::common::{
        native_inverse_space_step_case, native_step_case, StepBenchCase,
        STD_LAYERS,
    };
    use fastvpinns::util::json::Json;

    let backend = args.str_or("backend", "native");
    check_backend_name(&backend)?;
    if backend != "native" {
        bail!("repro bench currently times the native backend only");
    }
    let quick = args.has("quick");
    let (ks, inv_ks, iters_default, warmup_default): (&[usize], &[usize],
                                                      usize, usize) =
        if quick {
            (&[4, 8, 16], &[4, 16], 5, 2)
        } else {
            (&[4, 8, 16, 32, 64], &[4, 16, 64], 15, 3)
        };
    let iters = args.usize_or("iters", iters_default)?.max(1);
    let warmup = args.usize_or("warmup", warmup_default)?;
    let nt1d = args.usize_or("nt1d", 5)?;
    let nq1d = args.usize_or("nq1d", 5)?;
    let out_path = args.str_or("out", "BENCH_native_step.json");
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!(
        "bench: native train step, net {STD_LAYERS:?}, nt={nt1d}^2, \
         nq={nq1d}^2, {iters} iters (+{warmup} warmup), {threads} threads"
    );
    let mut cases = Vec::new();
    let mut push_case = |case: StepBenchCase| {
        let s = &case.summary;
        println!(
            "  {:<14} ne={:<6} ({:>8} quad pts)  median {:>9.3} \
             ms/step  p90 {:>9.3} ms",
            case.loss, case.ne, case.n_quad, s.median, s.p90
        );
        cases.push(Json::obj(vec![
            ("loss", Json::str(case.loss)),
            ("ne", Json::num(case.ne as f64)),
            ("n_quad", Json::num(case.n_quad as f64)),
            ("dof", Json::num(case.dof as f64)),
            // effective worker count (clamped to ne), not machine cores
            ("threads", Json::num(case.threads as f64)),
            ("median_ms", Json::num(s.median)),
            ("p90_ms", Json::num(s.p90)),
            ("min_ms", Json::num(s.min)),
            ("mean_ms", Json::num(s.mean)),
        ]));
    };
    for &k in ks {
        push_case(native_step_case(k, nt1d, nq1d, iters, warmup)?);
    }
    // the two-head inverse-space step on the same grids: tracks the
    // eps head's cost on the blocked tensor path
    for &k in inv_ks {
        push_case(native_inverse_space_step_case(k, nt1d, nq1d, iters,
                                                 warmup)?);
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("native_step")),
        ("backend", Json::str("native")),
        ("layers",
         Json::Arr(STD_LAYERS.iter().map(|&w| Json::num(w as f64))
             .collect())),
        ("nt1d", Json::num(nt1d as f64)),
        ("nq1d", Json::num(nq1d as f64)),
        ("iters", Json::num(iters as f64)),
        ("warmup", Json::num(warmup as f64)),
        ("threads", Json::num(threads as f64)),
        ("quick", Json::Bool(quick)),
        ("cases", Json::Arr(cases)),
    ]);
    std::fs::write(&out_path, format!("{doc}\n"))?;
    println!("bench record -> {out_path}");
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let backend = args.str_or("backend", "native");
    check_backend_name(&backend)?;
    match backend.as_str() {
        "native" => cmd_train_native(args),
        "xla" => cmd_train_xla(args),
        _ => unreachable!(),
    }
}

/// Pure-Rust training: no artifacts, no Python, no XLA.
fn cmd_train_native(args: &Args) -> Result<()> {
    let problem_name = args.str_or("problem", "poisson_sin");
    let iters = args.usize_or("iters", 5000)?;
    let cfg = TrainConfig {
        iters,
        lr: LrSchedule::Constant(args.f64_or("lr", 5e-3)?),
        tau: args.f64_or("tau", 10.0)?,
        seed: args.usize_or("seed", 42)? as u64,
        log_every: args.usize_or("log-every", 100)?,
        ..TrainConfig::default()
    };
    let layers = parse_layers(&args.str_or("layers", "2,30,30,30,1"))?;
    let nt1d = args.usize_or("nt1d", 5)?;
    let nq1d = args.usize_or("nq1d", 10)?;

    // problem + mesh + loss per problem family
    let omega = args.f64_or("omega-pi", 2.0)? * std::f64::consts::PI;
    let (mesh, problem, loss, ns): (QuadMesh, Box<dyn Problem>, NativeLoss,
                                    usize) = match problem_name.as_str() {
        "poisson_sin" => {
            let n = args.usize_or("n", 4)?;
            (generators::unit_square(n.max(1)),
             Box::new(problems::PoissonSin::new(omega)),
             NativeLoss::Forward { eps: 1.0, bx: 0.0, by: 0.0 }, 0)
        }
        "cd_gear" => {
            let p = problems::GearCd;
            let (bx, by) = p.b();
            (generators::gear_ci(), Box::new(p),
             NativeLoss::Forward { eps: 1.0, bx, by }, 0)
        }
        "inverse_const" => {
            (generators::rect_grid(2, 2, -1.0, -1.0, 1.0, 1.0),
             Box::new(problems::InverseConstPoisson::new()),
             NativeLoss::InverseConst, args.usize_or("ns", 50)?)
        }
        "inverse_space" => {
            // two-head net: u + softplus'd eps field, sensors from the
            // manufactured exact solution
            let n = args.usize_or("n", 2)?;
            let p = problems::InverseSpaceSin;
            let (bx, by) = p.b();
            (generators::unit_square(n.max(1)), Box::new(p),
             NativeLoss::InverseSpace { bx, by },
             args.usize_or("ns", 200)?)
        }
        other => bail!("unknown --problem '{other}' (known: poisson_sin, \
                        cd_gear, inverse_const, inverse_space)"),
    };

    println!(
        "training {problem_name} [native backend]: {} cells, nt={}^2, \
         nq={}^2, net {:?}, {} iters",
        mesh.n_cells(), nt1d, nq1d, layers, cfg.iters
    );
    let dom = assembly::assemble(&mesh, nt1d, nq1d, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &*problem, sensor_values: None };
    let ncfg = NativeConfig {
        layers,
        loss,
        nb: args.usize_or("nb", 400)?,
        ns,
    };
    let native = NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg))?;
    let mut trainer = Trainer::new(Box::new(native), &cfg);
    let report = trainer.run()?;
    println!(
        "done: loss {:.4e} (var {:.4e}, bd {:.4e}), median {:.3} ms/step, \
         total {:.1}s",
        report.final_loss, report.final_var_loss, report.final_bd_loss,
        report.median_step_ms, report.total_seconds
    );
    if let Some(eps) = report.eps_final {
        println!("trainable eps -> {eps:.5}");
    }

    // error vs exact on the paper's 100x100 grid (when analytic)
    let (lo, hi) = mesh.bbox();
    let grid = eval_grid(100, 100, lo[0], lo[1], hi[0], hi[1]);
    let exact_known = problem.exact(grid[0][0], grid[0][1]).is_some();
    if problem_name == "inverse_space" {
        // both heads in one trunk pass: u vs exact + the recovered
        // diffusion field vs the manufactured truth
        use fastvpinns::coordinator::metrics::ErrorNorms;
        let heads = trainer.predict_heads(&grid)?;
        anyhow::ensure!(heads.len() >= 2, "two-head network expected");
        if exact_known {
            let exact: Vec<f64> = grid
                .iter()
                .map(|p| problem.exact(p[0], p[1]).unwrap())
                .collect();
            let err = ErrorNorms::compute_f32(&heads[0], &exact);
            println!("errors: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                     err.mae, err.rel_l2, err.linf);
        }
        let eps_pred: Vec<f64> =
            heads[1].iter().map(|&v| v as f64).collect();
        let eps_exact: Vec<f64> = grid
            .iter()
            .map(|p| problems::InverseSpaceSin::eps_actual(p[0], p[1]))
            .collect();
        let err = ErrorNorms::compute(&eps_pred, &eps_exact);
        println!("eps field: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                 err.mae, err.rel_l2, err.linf);
    } else if exact_known {
        let exact: Vec<f64> = grid
            .iter()
            .map(|p| problem.exact(p[0], p[1]).unwrap())
            .collect();
        let err = trainer.evaluate(&grid, &exact)?;
        println!("errors: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                 err.mae, err.rel_l2, err.linf);
    }
    if let Some(out) = args.flag("history") {
        trainer.history.to_csv(out)?;
        println!("history -> {out}");
    }
    Ok(())
}

/// AOT/PJRT training (requires --features xla + `make artifacts`).
#[cfg(not(feature = "xla"))]
fn cmd_train_xla(_args: &Args) -> Result<()> {
    unreachable!("check_backend_name rejects xla without the feature")
}

#[cfg(feature = "xla")]
fn cmd_train_xla(args: &Args) -> Result<()> {
    {
        use fastvpinns::runtime::backend::xla::XlaBackend;
        use fastvpinns::runtime::engine::Engine;

        let engine = Engine::new(args.str_or("artifacts", "artifacts"))?;
        let name = args.req_str("artifact")?;
        let art = engine.load(&name)?;
        let c = art.manifest.config.clone();
        let omega = args.f64_or("omega-pi", 2.0)? * std::f64::consts::PI;
        let problem = problems::PoissonSin::new(omega);

        let k = (c.ne as f64).sqrt().round() as usize;
        if k * k != c.ne && art.manifest.loss != "pinn" {
            bail!("artifact ne={} is not a square grid; use the \
                   experiment drivers for mesh-specific runs", c.ne);
        }
        let mesh = generators::unit_square(k.max(1));
        let dom;
        let domain = if art.manifest.loss == "pinn" {
            None
        } else {
            dom = assembly::assemble(&mesh, c.nt1d, c.nq1d,
                                     QuadKind::GaussLegendre);
            Some(&dom)
        };
        let src = DataSource { mesh: &mesh, domain, problem: &problem,
                               sensor_values: None };
        let cfg = TrainConfig {
            iters: args.usize_or("iters", 2000)?,
            lr: LrSchedule::Constant(args.f64_or("lr", 1e-3)?),
            tau: args.f64_or("tau", 10.0)?,
            seed: args.usize_or("seed", 42)? as u64,
            log_every: args.usize_or("log-every", 100)?,
            ..TrainConfig::default()
        };
        let backend = XlaBackend::new(&engine, &name,
                                      Some("predict_std_16k"), &src,
                                      &BackendOpts::from(&cfg))?;
        let mut trainer = Trainer::new(Box::new(backend), &cfg);
        println!("training {name} (omega = {:.2}pi, {} iters)...",
                 omega / std::f64::consts::PI, cfg.iters);
        let report = trainer.run()?;
        println!(
            "done: loss {:.4e} (var {:.4e}, bd {:.4e}), median {:.3} \
             ms/step, total {:.1}s",
            report.final_loss, report.final_var_loss, report.final_bd_loss,
            report.median_step_ms, report.total_seconds
        );
        // error vs exact on the paper's 100x100 grid
        let grid = eval_grid(100, 100, 0.0, 0.0, 1.0, 1.0);
        let exact: Vec<f64> = grid
            .iter()
            .map(|p| problem.exact(p[0], p[1]).unwrap())
            .collect();
        if let Ok(err) = trainer.evaluate(&grid, &exact) {
            println!("errors: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
                     err.mae, err.rel_l2, err.linf);
        }
        if let Some(out) = args.flag("history") {
            trainer.history.to_csv(out)?;
            println!("history -> {out}");
        }
        Ok(())
    }
}

fn build_mesh(kind: &str, n: usize) -> Result<QuadMesh> {
    Ok(match kind {
        "square" => generators::unit_square(n.max(1)),
        "skewed" => generators::skewed_square(n.max(1), 0.25),
        "disk" => generators::disk_1024(),
        "gear" => generators::gear_ci(),
        "gear-paper" => generators::gear_paper(),
        "annulus" => generators::annulus(n.max(8), (n / 4).max(2), 0.0,
                                         0.0, 0.5, 1.0),
        other => bail!("unknown mesh kind '{other}'"),
    })
}

fn cmd_fem_solve(args: &Args) -> Result<()> {
    let kind = args.str_or("mesh", "square");
    let n = args.usize_or("n", 32)?;
    let mesh = build_mesh(&kind, n)?;
    let omega = args.f64_or("omega-pi", 1.0)? * std::f64::consts::PI;
    println!("FEM solve on {kind} mesh: {} cells, {} DOFs",
             mesh.n_cells(), mesh.n_points());
    let t0 = std::time::Instant::now();
    let sol = match kind.as_str() {
        "gear" | "gear-paper" => {
            let p = problems::GearCd;
            fem_solver::solve(&mesh, &FemProblem {
                eps: &|_, _| 1.0,
                b: p.b(),
                f: &|x, y| p.forcing(x, y),
                g: &|x, y| p.boundary(x, y),
            }, 3)?
        }
        _ => {
            let f = move |x: f64, y: f64| {
                2.0 * omega * omega * (omega * x).sin() * (omega * y).sin()
            };
            fem_solver::solve(&mesh, &FemProblem {
                eps: &|_, _| 1.0,
                b: (0.0, 0.0),
                f: &f,
                g: &|_, _| 0.0,
            }, 3)?
        }
    };
    println!("solved in {:.3}s ({} linear iterations)",
             t0.elapsed().as_secs_f64(), sol.solve_iterations);
    let mx = sol.u.iter().cloned().fold(f64::MIN, f64::max);
    let mn = sol.u.iter().cloned().fold(f64::MAX, f64::min);
    println!("u in [{mn:.4}, {mx:.4}]");
    if let Some(out) = args.flag("out") {
        fastvpinns::mesh::vtk::write_point_fields(&mesh, &[("u", &sol.u)],
                                                  out)?;
        println!("field -> {out}");
    }
    Ok(())
}

fn cmd_mesh(args: &Args) -> Result<()> {
    let kind = args.str_or("kind", "square");
    let n = args.usize_or("n", 8)?;
    let mesh = build_mesh(&kind, n)?;
    let r = quality::report(&mesh);
    println!("{kind}: {} cells, {} points", r.n_cells, r.n_points);
    println!("  valid: {} (min |J| {:.3e})", r.all_valid, r.min_jac);
    println!("  worst in-cell Jacobian ratio: {:.3}", r.worst_ratio);
    println!("  max aspect ratio: {:.2}", r.max_aspect);
    println!("  area: {:.6}", r.area);
    println!("  boundary edges: {}", mesh.boundary.len());
    if let Some(out) = args.flag("out") {
        gmsh::write(&mesh, out)?;
        println!("mesh -> {out}");
    }
    Ok(())
}

/// Cross-validation dumps consumed by python/tests/test_cross_validation.py
/// — the case list must stay in sync with CASES there.
fn cmd_dump_tensors(args: &Args) -> Result<()> {
    let base = args.str_or("out", "artifacts/crosscheck");
    let cases: [(&str, QuadMesh, usize, usize); 3] = [
        ("square4_nt3_nq5", generators::unit_square(4), 3, 5),
        ("skewed4_nt3_nq5", generators::skewed_square(4, 0.15), 3, 5),
        ("square2_nt5_nq10", generators::unit_square(2), 5, 10),
    ];
    for (tag, mesh, nt, nq) in cases {
        let dir = std::path::PathBuf::from(&base).join(tag);
        std::fs::create_dir_all(&dir)?;
        let d = assembly::assemble(&mesh, nt, nq, QuadKind::GaussLegendre);
        let f = d.force_matrix(|x, y| x.sin() * y.cos() + 2.0 * x * y);
        npy::write_f64(dir.join("quad_xy.npy"), &d.quad_xy,
                       &[d.ne * d.nq, 2])?;
        npy::write_f64(dir.join("gx.npy"), &d.gx, &[d.ne, d.nt, d.nq])?;
        npy::write_f64(dir.join("gy.npy"), &d.gy, &[d.ne, d.nt, d.nq])?;
        npy::write_f64(dir.join("v.npy"), &d.v, &[d.ne, d.nt, d.nq])?;
        npy::write_f64(dir.join("f.npy"), &f, &[d.ne, d.nt])?;
        npy::write_f64(dir.join("jdet.npy"), &d.jdet, &[d.ne, d.nq])?;
        println!("dumped {tag} -> {}", dir.display());
    }
    println!("now run: cd python && pytest tests/test_cross_validation.py");
    Ok(())
}
