//! Sparse linear algebra for the classical FEM reference solver:
//! CSR matrices and a Jacobi-preconditioned conjugate-gradient solver.

pub mod bicgstab;
pub mod cg;
pub mod csr;

pub use bicgstab::bicgstab_solve;
pub use cg::{cg_solve, CgOptions, CgResult};
pub use csr::{CsrMatrix, Triplets};
