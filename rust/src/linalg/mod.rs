//! Linear algebra kernels: sparse CSR + iterative solvers for the
//! classical FEM reference, and the cache-blocked dense micro-GEMM the
//! tensorized native training hot path runs on.

pub mod bicgstab;
pub mod cg;
pub mod csr;
pub mod gemm;
pub mod simd;

pub use bicgstab::bicgstab_solve;
pub use cg::{cg_solve, CgOptions, CgResult};
pub use csr::{CsrMatrix, Triplets};
pub use gemm::{gemm, gemv, GemmBufs};
pub use simd::{
    cpu_avx2, cpu_fma, kernel_name, set_force_scalar, simd_available,
    Kernel,
};
