//! Compressed-sparse-row matrix with triplet (COO) assembly.

use anyhow::{ensure, Result};

/// Triplet accumulator: duplicates are summed on conversion (standard FEM
/// assembly pattern).
#[derive(Debug, Clone, Default)]
pub struct Triplets {
    /// Row count.
    pub n_rows: usize,
    /// Column count.
    pub n_cols: usize,
    entries: Vec<(usize, usize, f64)>,
}

impl Triplets {
    /// Empty accumulator for an `n_rows x n_cols` matrix.
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        Triplets { n_rows, n_cols, entries: Vec::new() }
    }

    /// Add `v` at (i, j); duplicates are summed by [`Triplets::to_csr`].
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.n_rows && j < self.n_cols);
        if v != 0.0 {
            self.entries.push((i, j, v));
        }
    }

    /// Sort, merge duplicates and compress to CSR.
    pub fn to_csr(mut self) -> CsrMatrix {
        self.entries.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut row_ptr = vec![0usize; self.n_rows + 1];
        let mut cols: Vec<usize> = Vec::with_capacity(self.entries.len());
        let mut vals: Vec<f64> = Vec::with_capacity(self.entries.len());
        let mut last: Option<(usize, usize)> = None;
        for &(i, j, v) in &self.entries {
            if last == Some((i, j)) {
                // duplicate entry in the same (row, col): accumulate
                *vals.last_mut().unwrap() += v;
            } else {
                cols.push(j);
                vals.push(v);
                last = Some((i, j));
            }
            row_ptr[i + 1] = cols.len();
        }
        // prefix-fill rows with no entries
        for i in 1..=self.n_rows {
            if row_ptr[i] < row_ptr[i - 1] {
                row_ptr[i] = row_ptr[i - 1];
            }
        }
        CsrMatrix { n_rows: self.n_rows, n_cols: self.n_cols, row_ptr,
                    cols, vals }
    }
}

/// CSR sparse matrix.
#[derive(Debug, Clone)]
pub struct CsrMatrix {
    /// Row count.
    pub n_rows: usize,
    /// Column count.
    pub n_cols: usize,
    /// Start offset of each row in `cols`/`vals` (len `n_rows + 1`).
    pub row_ptr: Vec<usize>,
    /// Column index per stored entry.
    pub cols: Vec<usize>,
    /// Value per stored entry.
    pub vals: Vec<f64>,
}

impl CsrMatrix {
    /// Stored (structurally nonzero) entry count.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n_cols);
        debug_assert_eq!(y.len(), self.n_rows);
        for i in 0..self.n_rows {
            let mut acc = 0.0;
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += self.vals[k] * x[self.cols[k]];
            }
            y[i] = acc;
        }
    }

    /// [`CsrMatrix::matvec`] into a fresh vector.
    pub fn matvec_alloc(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.matvec(x, &mut y);
        y
    }

    /// Diagonal entries (0 where structurally absent).
    pub fn diagonal(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.n_rows];
        for i in 0..self.n_rows {
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                if self.cols[k] == i {
                    d[i] = self.vals[k];
                }
            }
        }
        d
    }

    /// Value at (i, j), 0.0 if not stored.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        for k in self.row_ptr[i]..self.row_ptr[i + 1] {
            if self.cols[k] == j {
                return self.vals[k];
            }
        }
        0.0
    }

    /// Symmetry check (for tests): max |A - A^T| entry.
    pub fn asymmetry(&self) -> Result<f64> {
        ensure!(self.n_rows == self.n_cols, "not square");
        let mut mx: f64 = 0.0;
        for i in 0..self.n_rows {
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                let j = self.cols[k];
                mx = mx.max((self.vals[k] - self.get(j, i)).abs());
            }
        }
        Ok(mx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assembles_and_multiplies() {
        let mut t = Triplets::new(3, 3);
        t.push(0, 0, 2.0);
        t.push(1, 1, 3.0);
        t.push(2, 2, 4.0);
        t.push(0, 1, 1.0);
        let a = t.to_csr();
        assert_eq!(a.nnz(), 4);
        let y = a.matvec_alloc(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![4.0, 6.0, 12.0]);
    }

    #[test]
    fn duplicates_summed() {
        let mut t = Triplets::new(2, 2);
        t.push(0, 0, 1.0);
        t.push(0, 0, 2.5);
        t.push(1, 0, 1.0);
        t.push(1, 0, -1.0); // cancels but both nonzero pushes
        let a = t.to_csr();
        assert_eq!(a.get(0, 0), 3.5);
        assert_eq!(a.get(1, 0), 0.0);
    }

    #[test]
    fn empty_rows() {
        let mut t = Triplets::new(4, 4);
        t.push(3, 3, 1.0);
        let a = t.to_csr();
        assert_eq!(a.row_ptr, vec![0, 0, 0, 0, 1]);
        let y = a.matvec_alloc(&[1.0, 1.0, 1.0, 2.0]);
        assert_eq!(y, vec![0.0, 0.0, 0.0, 2.0]);
    }

    #[test]
    fn diagonal_extraction() {
        let mut t = Triplets::new(3, 3);
        t.push(0, 0, 5.0);
        t.push(1, 2, 7.0);
        t.push(2, 2, 9.0);
        let a = t.to_csr();
        assert_eq!(a.diagonal(), vec![5.0, 0.0, 9.0]);
    }

    #[test]
    fn symmetry_metric() {
        let mut t = Triplets::new(2, 2);
        t.push(0, 1, 1.0);
        t.push(1, 0, 1.0);
        t.push(0, 0, 2.0);
        t.push(1, 1, 2.0);
        let a = t.to_csr();
        assert!(a.asymmetry().unwrap() < 1e-15);
    }

    #[test]
    fn zero_entries_skipped() {
        let mut t = Triplets::new(2, 2);
        t.push(0, 0, 0.0);
        t.push(1, 1, 1.0);
        assert_eq!(t.to_csr().nnz(), 1);
    }
}
