//! BiCGStab for the nonsymmetric systems produced by convection terms
//! (Jacobi-preconditioned).

use super::csr::CsrMatrix;
use super::cg::{CgOptions, CgResult};

/// Solve A x = b (A possibly nonsymmetric) with Jacobi-preconditioned
/// BiCGStab. Reuses CgOptions/CgResult.
pub fn bicgstab_solve(a: &CsrMatrix, b: &[f64], opts: CgOptions)
    -> CgResult {
    let n = b.len();
    assert_eq!(a.n_rows, n);
    let diag = a.diagonal();
    let minv: Vec<f64> = diag
        .iter()
        .map(|&d| if d.abs() > 1e-300 { 1.0 / d } else { 1.0 })
        .collect();

    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let r0 = r.clone();
    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut v = vec![0.0; n];
    let mut p = vec![0.0; n];
    let mut phat = vec![0.0; n];
    let mut shat = vec![0.0; n];
    let mut t = vec![0.0; n];
    let b_norm = norm(b).max(1e-300);

    for it in 0..opts.max_iter {
        let r_norm = norm(&r);
        if r_norm <= opts.rtol * b_norm || r_norm <= opts.atol {
            return CgResult { x, iterations: it, residual_norm: r_norm,
                              converged: true };
        }
        let rho_new = dot(&r0, &r);
        if rho_new.abs() < 1e-300 {
            return CgResult { x, iterations: it, residual_norm: r_norm,
                              converged: false };
        }
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        for i in 0..n {
            phat[i] = p[i] * minv[i];
        }
        a.matvec(&phat, &mut v);
        alpha = rho / dot(&r0, &v);
        let s: Vec<f64> = (0..n).map(|i| r[i] - alpha * v[i]).collect();
        if norm(&s) <= opts.atol {
            for i in 0..n {
                x[i] += alpha * phat[i];
            }
            return CgResult { x, iterations: it, residual_norm: norm(&s),
                              converged: true };
        }
        for i in 0..n {
            shat[i] = s[i] * minv[i];
        }
        a.matvec(&shat, &mut t);
        let tt = dot(&t, &t);
        omega = if tt > 0.0 { dot(&t, &s) / tt } else { 0.0 };
        for i in 0..n {
            x[i] += alpha * phat[i] + omega * shat[i];
            r[i] = s[i] - omega * t[i];
        }
        if omega.abs() < 1e-300 {
            return CgResult { x, iterations: it, residual_norm: norm(&r),
                              converged: false };
        }
    }
    let r_norm = norm(&r);
    CgResult { x, iterations: opts.max_iter, residual_norm: r_norm,
               converged: r_norm <= opts.rtol * b_norm }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::csr::Triplets;

    #[test]
    fn solves_nonsymmetric() {
        // upwind-ish convection-diffusion 1D: -u'' + 10 u' on 40 nodes
        let n = 40;
        let mut tr = Triplets::new(n, n);
        let h = 1.0 / (n as f64 + 1.0);
        for i in 0..n {
            tr.push(i, i, 2.0 / (h * h) + 10.0 / h);
            if i > 0 {
                tr.push(i, i - 1, -1.0 / (h * h) - 10.0 / h);
            }
            if i + 1 < n {
                tr.push(i, i + 1, -1.0 / (h * h));
            }
        }
        let a = tr.to_csr();
        assert!(a.asymmetry().unwrap() > 1.0); // genuinely nonsymmetric
        let want: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.3).cos())
            .collect();
        let b = a.matvec_alloc(&want);
        let r = bicgstab_solve(&a, &b, CgOptions::default());
        assert!(r.converged, "residual {}", r.residual_norm);
        for (g, w) in r.x.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
    }

    #[test]
    fn solves_spd_too() {
        let mut tr = Triplets::new(3, 3);
        for i in 0..3 {
            tr.push(i, i, 4.0);
        }
        tr.push(0, 1, 1.0);
        tr.push(1, 0, 1.0);
        let a = tr.to_csr();
        let b = a.matvec_alloc(&[1.0, -2.0, 0.5]);
        let r = bicgstab_solve(&a, &b, CgOptions::default());
        assert!(r.converged);
        assert!((r.x[1] + 2.0).abs() < 1e-8);
    }
}
