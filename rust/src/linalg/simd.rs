//! Runtime-dispatched SIMD kernels for the dense hot path: an AVX2
//! `f64` GEMM/GEMV microkernel family, vectorized `tanh` epilogues,
//! and the f32-compute / f64-accumulate inference GEMM.
//!
//! Design rules, in order:
//!
//! 1. **The scalar kernels in [`gemm`](super::gemm) stay the ground
//!    truth.** The AVX2 `f64` microkernel uses vectorized multiply +
//!    add (never FMA) in exactly the scalar kernel's per-element
//!    reduction order, so `f64` results are *bit-identical* to the
//!    scalar fallback on every machine — property-tested in
//!    `gemm::tests`. Fused multiply-add rounds once instead of twice
//!    and would silently fork trajectories between machines; it is
//!    reserved for the f32 inference path, whose contract is a
//!    relative-error bound rather than bit equality.
//! 2. **Dispatch is resolved once.** [`active`] consults a cached
//!    `is_x86_feature_detected!` probe (AVX2 + FMA), the
//!    `REPRO_FORCE_SCALAR` environment variable (any value other than
//!    `0`/empty forces the scalar fallback — the CI leg that keeps the
//!    fallback green), a process-wide override ([`set_force_scalar`])
//!    used by the `repro bench` parity guard, and the one-way
//!    [`degrade_to_scalar`] latch: a suspected-faulty SIMD kernel
//!    (chaos tier: the `kernel.avx2.fault` failpoint) drops dispatch
//!    to the scalar ground truth for the rest of the process and
//!    training continues — because the f64 kernels are bit-identical
//!    across the two paths, the post-degrade trajectory matches a
//!    scalar run resumed from the same state bit-for-bit.
//! 3. **The vector `tanh` is documented-error, not libm.** The
//!    training epilogue's [`tanh_block`] evaluates tanh as a blend of
//!    an odd Taylor branch (|x| < 1/8) and `(E-1)/(E+1)` with
//!    `E = exp(2|x|)` via Cody-Waite range reduction — measured max
//!    relative error 6.7e-16 vs libm (see
//!    `python/proto_simd_tanh.py`, the executable reference for every
//!    constant below). The scalar fallback keeps calling `f64::tanh`,
//!    so `REPRO_FORCE_SCALAR=1` reproduces pre-SIMD trajectories
//!    bit-for-bit. NaN inputs return a finite value on the vector
//!    path (the hot path treats NaN as already-diverged training).
//! 4. **f32 serving is bounded, cheap, and opt-in.** [`gemm_f32acc`]
//!    takes f32 products (FMA on AVX2) into f32 partial sums over
//!    16-deep k-chunks and accumulates chunk totals in f64; with the
//!    degree-7 [`tanh_fast_f32`] the end-to-end `[2,30,30,30,1]`
//!    forward stays within ~1.3e-6 of the f64 path (budget: 1e-5,
//!    guarded by tests here and in `runtime::infer`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Which GEMM/GEMV/epilogue implementation executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar kernels (`4x8` tile) — the always-compiled,
    /// property-tested ground truth and fallback.
    Scalar,
    /// AVX2 `4x12` f64 microkernel + vector epilogues (x86_64 with
    /// AVX2 and FMA detected at runtime).
    Avx2,
}

/// AVX2 microkernel tile rows (matches the scalar `MR`, so the packed
/// A panels are shared).
pub(crate) const MR_AVX2: usize = 4;
/// AVX2 microkernel tile columns: 3 x `__m256d` accumulator rows — 12
/// accumulators + 3 B loads + 1 broadcast fill the 16 ymm registers.
pub(crate) const NR_AVX2: usize = 12;

#[derive(Debug, Clone, Copy)]
struct Detect {
    avx2: bool,
    fma: bool,
    env_force: bool,
}

static DETECT: OnceLock<Detect> = OnceLock::new();
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);
static DEGRADED: AtomicBool = AtomicBool::new(false);

fn detect() -> Detect {
    *DETECT.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        let (avx2, fma) = (
            is_x86_feature_detected!("avx2"),
            is_x86_feature_detected!("fma"),
        );
        #[cfg(not(target_arch = "x86_64"))]
        let (avx2, fma) = (false, false);
        let env_force = std::env::var("REPRO_FORCE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        Detect { avx2, fma, env_force }
    })
}

/// Whether the CPU reports AVX2 (independent of overrides) — recorded
/// in the bench JSON so perf records are comparable across machines.
pub fn cpu_avx2() -> bool {
    detect().avx2
}

/// Whether the CPU reports FMA (independent of overrides).
pub fn cpu_fma() -> bool {
    detect().fma
}

/// Whether the SIMD kernels are usable: features detected and not
/// disabled via `REPRO_FORCE_SCALAR`.
pub fn simd_available() -> bool {
    let d = detect();
    d.avx2 && d.fma && !d.env_force
}

/// Process-wide override forcing the scalar kernels (the bench
/// harness's simd-vs-scalar parity probe). Relaxed-atomic: set it
/// before spawning worker threads, not concurrently with them.
pub fn set_force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// Permanently degrade dispatch to the scalar ground-truth kernels
/// for the rest of the process — graceful kernel degradation: when a
/// SIMD code path is suspected faulty (in the chaos tier, via the
/// `kernel.avx2.fault` failpoint), the run switches to the portable
/// kernels and keeps training instead of crashing or silently
/// producing wrong numbers. Logs once, on the first call. There is
/// deliberately no un-degrade: a kernel that faulted once is not
/// trusted again within the process.
pub fn degrade_to_scalar(reason: &str) {
    if !DEGRADED.swap(true, Ordering::SeqCst) {
        eprintln!(
            "kernel degradation: dispatch falling back to scalar \
             kernels ({reason})"
        );
        crate::telemetry::emit(
            crate::telemetry::Event::KernelDispatch {
                kernel: kernel_name(),
                degraded: true,
                reason: reason.to_string(),
            },
        );
    }
}

/// Whether [`degrade_to_scalar`] has been tripped.
pub fn degraded() -> bool {
    DEGRADED.load(Ordering::Relaxed)
}

/// The kernel the next `gemm`/`gemv`/epilogue call will run on.
pub fn active() -> Kernel {
    if simd_available()
        && !FORCE_SCALAR.load(Ordering::Relaxed)
        && !DEGRADED.load(Ordering::Relaxed)
    {
        Kernel::Avx2
    } else {
        Kernel::Scalar
    }
}

/// Stable identifier of the active kernel (bench JSON `kernel` field).
pub fn kernel_name() -> &'static str {
    match active() {
        Kernel::Avx2 => "avx2_4x12",
        Kernel::Scalar => "scalar_4x8",
    }
}

// ---------------------------------------------------------------------
// tanh: accurate f64 (training epilogue) and fast f32 (inference)
// ---------------------------------------------------------------------
//
// Shared constants; every value is validated against the numpy
// transliteration in python/proto_simd_tanh.py. The magic-number
// round-to-nearest and the 2^k bit reconstruction assume
// round-to-nearest-even FP mode (the only mode Rust runs in).

/// Cody-Waite high part of ln 2 (top 32 mantissa bits).
const LN2_HI: f64 = 0.693_147_180_369_123_8;
/// Cody-Waite low part: `ln 2 - LN2_HI`.
const LN2_LO: f64 = 1.908_214_929_270_587_7e-10;
/// `1.5 * 2^52`: adding and subtracting rounds to the nearest integer.
const MAGIC: f64 = 6_755_399_441_055_744.0;
/// tanh odd-Taylor coefficients (x^3 … x^13).
const TANH_C: [f64; 6] = [
    -0.333_333_333_333_333_3,
    0.133_333_333_333_333_33,
    -0.053_968_253_968_253_97,
    0.021_869_488_536_155_203,
    -0.008_863_235_529_902_197,
    0.003_592_128_036_572_481,
];
/// exp Taylor coefficients `1/i!` for `i = 0..13`.
const EXP_C: [f64; 14] = [
    1.0,
    1.0,
    0.5,
    0.166_666_666_666_666_66,
    0.041_666_666_666_666_664,
    0.008_333_333_333_333_333,
    0.001_388_888_888_888_889,
    1.984_126_984_126_984e-4,
    2.480_158_730_158_73e-5,
    2.755_731_922_398_589_3e-6,
    2.755_731_922_398_589e-7,
    2.505_210_838_544_172e-8,
    2.087_675_698_786_81e-9,
    1.605_904_383_682_161_3e-10,
];

/// Scalar transliteration of the AVX2 `tanh` lanes — the *same*
/// operation sequence, so remainder elements of a [`tanh_block`] call
/// are bit-identical to vector lanes (values never depend on an
/// element's position within a block). Max relative error vs libm:
/// 6.7e-16 (`python/proto_simd_tanh.py`).
pub fn tanh_accurate(x: f64) -> f64 {
    let ax = x.abs();
    if ax < 0.125 {
        let x2 = x * x;
        let mut p = TANH_C[5];
        for &c in TANH_C[..5].iter().rev() {
            p = p * x2 + c;
        }
        x + x * (x2 * p)
    } else {
        // tanh(|x|) = (E - 1) / (E + 1), E = exp(2|x|); clamped at
        // y = 40 where the quotient already rounds to 1.0.
        let y = (2.0 * ax).min(40.0);
        let kd = (y * std::f64::consts::LOG2_E + MAGIC) - MAGIC;
        let r = (y - kd * LN2_HI) - kd * LN2_LO;
        let mut q = EXP_C[13];
        for &c in EXP_C[..13].iter().rev() {
            q = q * r + c;
        }
        let k = kd as i64;
        let scale = f64::from_bits(((1023 + k) as u64) << 52);
        let e = q * scale;
        ((e - 1.0) / (e + 1.0)).copysign(x)
    }
}

/// In-place tanh over a block, dispatched: AVX2 runs the vector
/// algorithm above (documented ≤1e-15-class relative error); the
/// scalar fallback keeps libm's `f64::tanh`, preserving pre-SIMD
/// trajectories bit-for-bit under `REPRO_FORCE_SCALAR=1`.
pub fn tanh_block(z: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if active() == Kernel::Avx2 {
        // SAFETY: `active()` returned Avx2, so AVX2+FMA are present.
        unsafe { avx2::tanh_block(z) };
        return;
    }
    for v in z {
        *v = v.tanh();
    }
}

/// f32 Cody-Waite ln 2 split (11 exact high bits).
const LN2_HI_F: f32 = 0.693_359_4;
/// f32 Cody-Waite low part.
const LN2_LO_F: f32 = -2.121_944_4e-4;
/// `1.5 * 2^23` — the f32 round-to-nearest magic.
const MAGIC_F: f32 = 12_582_912.0;
/// f32 tanh odd-Taylor coefficients (x^3, x^5, x^7).
const TANH_CF: [f32; 3] = [-0.333_333_34, 0.133_333_34, -0.053_968_254];
/// f32 exp Taylor coefficients `1/i!` for `i = 0..7`.
const EXP_CF: [f32; 8] = [
    1.0,
    1.0,
    0.5,
    0.166_666_67,
    0.041_666_668,
    0.008_333_334,
    0.001_388_888_9,
    1.984_127e-4,
];

/// Fast f32 tanh for the mixed-precision inference path: same blend
/// structure as [`tanh_accurate`] with a degree-7 exp polynomial. Max
/// relative error ~3.1e-7 vs the f64 libm tanh
/// (`python/proto_simd_tanh.py`) — well inside the serve path's 1e-5
/// budget. The AVX2 8-lane version performs the identical operation
/// sequence, so vector and scalar agree bit-for-bit.
pub fn tanh_fast_f32(x: f32) -> f32 {
    let ax = x.abs();
    if ax < 0.125 {
        let x2 = x * x;
        let p = (TANH_CF[2] * x2 + TANH_CF[1]) * x2 + TANH_CF[0];
        x + x * (x2 * p)
    } else {
        let y = (2.0 * ax).min(18.0);
        let kd = (y * std::f32::consts::LOG2_E + MAGIC_F) - MAGIC_F;
        let r = (y - kd * LN2_HI_F) - kd * LN2_LO_F;
        let mut q = EXP_CF[7];
        for &c in EXP_CF[..7].iter().rev() {
            q = q * r + c;
        }
        let k = kd as i32;
        let scale = f32::from_bits(((127 + k) as u32) << 23);
        let e = q * scale;
        ((e - 1.0) / (e + 1.0)).copysign(x)
    }
}

/// In-place [`tanh_fast_f32`] over a block (8-wide on AVX2).
pub fn tanh_block_f32(z: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if active() == Kernel::Avx2 {
        // SAFETY: `active()` returned Avx2.
        unsafe { avx2::tanh_block_f32(z) };
        return;
    }
    for v in z {
        *v = tanh_fast_f32(*v);
    }
}

// ---------------------------------------------------------------------
// f32-compute / f64-accumulate inference GEMM
// ---------------------------------------------------------------------

/// k-chunk depth of the f32 partial sums: products accumulate in f32
/// for at most this many terms before the running total moves to f64.
const KBLK_F32: usize = 16;

/// Pack a row-major `nin x nout` f64 weight matrix (the [`Mlp`]
/// storage layout) into f32 panels of 8 output columns, zero-padded:
/// `wp[blk * nin * 8 + i * 8 + lane] = w[i * nout + blk * 8 + lane]`.
/// Returns `(panels, nout_pad)`. Done once per layer when a serving
/// session switches to f32 precision.
///
/// [`Mlp`]: crate::runtime::backend::native::Mlp
pub fn pack_weights_f32(w: &[f64], nin: usize, nout: usize)
    -> (Vec<f32>, usize) {
    assert!(w.len() >= nin * nout);
    let nout_pad = nout.div_ceil(8) * 8;
    let mut wp = vec![0.0f32; nin * nout_pad];
    for blk in 0..nout_pad / 8 {
        for i in 0..nin {
            for lane in 0..8 {
                let j = blk * 8 + lane;
                if j < nout {
                    wp[blk * nin * 8 + i * 8 + lane] =
                        w[i * nout + j] as f32;
                }
            }
        }
    }
    (wp, nout_pad)
}

/// Mixed-precision layer product: `z[p, o] = sum_i a[p, i] * w[i, o]`
/// with `a` f32 row-major `m x nin`, `wp` the [`pack_weights_f32`]
/// panels, and `z` f64 row-major `m x nout_pad`. Products are f32
/// (FMA on AVX2), partial sums stay f32 within [`KBLK_F32`]-deep
/// k-chunks, and chunk totals accumulate in f64 — the
/// "f32-compute / f64-accumulate" serving scheme. Measured end-to-end
/// error of the f32 serve path: ~1.3e-6 relative (budget 1e-5).
pub fn gemm_f32acc(
    a: &[f32],
    m: usize,
    nin: usize,
    wp: &[f32],
    nout_pad: usize,
    z: &mut [f64],
) {
    assert_eq!(nout_pad % 8, 0, "packed width must be a multiple of 8");
    assert!(a.len() >= m * nin);
    assert!(wp.len() >= nin * nout_pad);
    assert!(z.len() >= m * nout_pad);
    #[cfg(target_arch = "x86_64")]
    if active() == Kernel::Avx2 {
        // SAFETY: `active()` returned Avx2; lengths asserted above.
        unsafe { avx2::gemm_f32acc(a, m, nin, wp, nout_pad, z) };
        return;
    }
    for p in 0..m {
        let arow = &a[p * nin..p * nin + nin];
        for blk in 0..nout_pad / 8 {
            let panel = &wp[blk * nin * 8..(blk + 1) * nin * 8];
            let mut acc = [0.0f64; 8];
            for c0 in (0..nin).step_by(KBLK_F32) {
                let c1 = (c0 + KBLK_F32).min(nin);
                let mut part = [0.0f32; 8];
                for (i, &ai) in arow[c0..c1].iter().enumerate() {
                    let wrow = &panel[(c0 + i) * 8..(c0 + i) * 8 + 8];
                    for (s, &wv) in part.iter_mut().zip(wrow) {
                        *s += ai * wv;
                    }
                }
                for (d, &s) in acc.iter_mut().zip(&part) {
                    *d += s as f64;
                }
            }
            z[p * nout_pad + blk * 8..p * nout_pad + blk * 8 + 8]
                .copy_from_slice(&acc);
        }
    }
}

// ---------------------------------------------------------------------
// AVX2 implementations
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
pub(crate) use avx2::{block_kernel_avx2, gemv_notrans_avx2,
                      gemv_trans_avx2};

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::{EXP_C, EXP_CF, KBLK_F32, LN2_HI, LN2_HI_F, LN2_LO,
                LN2_LO_F, MAGIC, MAGIC_F, MR_AVX2, NR_AVX2, TANH_C,
                TANH_CF};

    /// AVX2 analogue of `gemm::block_kernel`: one packed `mc x kc` A
    /// block against one packed (NR=12) `kc x nc` B block,
    /// accumulating `alpha * product` into `C[ic.., jc..]`. Vectorized
    /// multiply + add only — per-(i,j) the reduction order is exactly
    /// the scalar kernel's, so results are bit-identical (FMA would
    /// round differently; see the module docs).
    ///
    /// # Safety
    /// Requires AVX2. `pa`/`pb` must hold full zero-padded panels
    /// (`pb` 32-byte aligned — guaranteed by `GemmBufs`) and `c` the
    /// `(ic + mc) x ldc` destination.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub(crate) unsafe fn block_kernel_avx2(
        pa: &[f64],
        pb: &[f64],
        mc: usize,
        nc: usize,
        kc: usize,
        alpha: f64,
        c: &mut [f64],
        ic: usize,
        jc: usize,
        ldc: usize,
    ) {
        let alpha_v = _mm256_set1_pd(alpha);
        for jr in (0..nc).step_by(NR_AVX2) {
            let nr = NR_AVX2.min(nc - jr);
            let bpan = pb.as_ptr().add(jr * kc);
            for ir in (0..mc).step_by(MR_AVX2) {
                let mr = MR_AVX2.min(mc - ir);
                let apan = pa.as_ptr().add(ir * kc);
                // 4 x 12 accumulator: 12 ymm + 3 B loads + 1 broadcast
                let mut acc = [[_mm256_setzero_pd(); 3]; MR_AVX2];
                for p in 0..kc {
                    let b0 = _mm256_load_pd(bpan.add(p * NR_AVX2));
                    let b1 = _mm256_load_pd(bpan.add(p * NR_AVX2 + 4));
                    let b2 = _mm256_load_pd(bpan.add(p * NR_AVX2 + 8));
                    for i in 0..MR_AVX2 {
                        let ai = _mm256_broadcast_sd(
                            &*apan.add(p * MR_AVX2 + i));
                        acc[i][0] = _mm256_add_pd(
                            acc[i][0], _mm256_mul_pd(ai, b0));
                        acc[i][1] = _mm256_add_pd(
                            acc[i][1], _mm256_mul_pd(ai, b1));
                        acc[i][2] = _mm256_add_pd(
                            acc[i][2], _mm256_mul_pd(ai, b2));
                    }
                }
                if mr == MR_AVX2 && nr == NR_AVX2 {
                    for i in 0..MR_AVX2 {
                        let row = (ic + ir + i) * ldc + jc + jr;
                        let cp = c.as_mut_ptr().add(row);
                        for v in 0..3 {
                            let cv = _mm256_loadu_pd(cp.add(4 * v));
                            let cv = _mm256_add_pd(
                                cv, _mm256_mul_pd(alpha_v, acc[i][v]));
                            _mm256_storeu_pd(cp.add(4 * v), cv);
                        }
                    }
                } else {
                    // ragged edge: spill the tile, then the scalar
                    // kernel's exact `c += alpha * acc` writes
                    let mut tile = [0.0f64; MR_AVX2 * NR_AVX2];
                    for i in 0..MR_AVX2 {
                        for v in 0..3 {
                            _mm256_storeu_pd(
                                tile.as_mut_ptr()
                                    .add(i * NR_AVX2 + 4 * v),
                                acc[i][v],
                            );
                        }
                    }
                    for i in 0..mr {
                        let row = (ic + ir + i) * ldc + jc + jr;
                        for j in 0..nr {
                            c[row + j] += alpha * tile[i * NR_AVX2 + j];
                        }
                    }
                }
            }
        }
    }

    /// `y[i] += alpha * dot(A[i, :], x)`, 4 rows per accumulator with
    /// one lane per row — each lane performs the scalar loop's exact
    /// serial reduction, so results are bit-identical to it.
    ///
    /// # Safety
    /// Requires AVX2; `a` is `m x n` row-major, `x` len >= n, `y` len
    /// >= m.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn gemv_notrans_avx2(
        m: usize,
        n: usize,
        alpha: f64,
        a: &[f64],
        x: &[f64],
        y: &mut [f64],
    ) {
        let m4 = m - m % 4;
        let ap = a.as_ptr();
        let xp = x.as_ptr();
        for i in (0..m4).step_by(4) {
            let (r0, r1, r2, r3) = (
                ap.add(i * n),
                ap.add((i + 1) * n),
                ap.add((i + 2) * n),
                ap.add((i + 3) * n),
            );
            let mut acc = _mm256_setzero_pd();
            for j in 0..n {
                let av = _mm256_set_pd(
                    *r3.add(j), *r2.add(j), *r1.add(j), *r0.add(j));
                let xv = _mm256_broadcast_sd(&*xp.add(j));
                acc = _mm256_add_pd(acc, _mm256_mul_pd(av, xv));
            }
            let mut t = [0.0f64; 4];
            _mm256_storeu_pd(t.as_mut_ptr(), acc);
            for (yi, &ti) in y[i..i + 4].iter_mut().zip(&t) {
                *yi += alpha * ti;
            }
        }
        for i in m4..m {
            let row = &a[i * n..i * n + n];
            let mut acc = 0.0;
            for (&aj, &xj) in row.iter().zip(&x[..n]) {
                acc += aj * xj;
            }
            y[i] += alpha * acc;
        }
    }

    /// `y[j] += (alpha * x[i]) * A[i, j]` over rows i — vectorized
    /// across the independent outputs j, preserving the scalar loop's
    /// per-element order (and its skip of zero-scaled rows).
    ///
    /// # Safety
    /// Requires AVX2; `a` is `m x n` row-major, `x` len >= m, `y` len
    /// >= n.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn gemv_trans_avx2(
        m: usize,
        n: usize,
        alpha: f64,
        a: &[f64],
        x: &[f64],
        y: &mut [f64],
    ) {
        let n4 = n - n % 4;
        for (i, &xi) in x.iter().enumerate().take(m) {
            let s = alpha * xi;
            if s == 0.0 {
                continue;
            }
            let sv = _mm256_set1_pd(s);
            let row = a.as_ptr().add(i * n);
            let yp = y.as_mut_ptr();
            for j in (0..n4).step_by(4) {
                let yv = _mm256_loadu_pd(yp.add(j));
                let av = _mm256_loadu_pd(row.add(j));
                _mm256_storeu_pd(
                    yp.add(j),
                    _mm256_add_pd(yv, _mm256_mul_pd(sv, av)),
                );
            }
            for j in n4..n {
                y[j] += s * *row.add(j);
            }
        }
    }

    /// 4-lane vector body of [`super::tanh_accurate`] — identical
    /// operation sequence, both branches computed and blended.
    ///
    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn tanh4(x: __m256d) -> __m256d {
        let sign_mask = _mm256_set1_pd(-0.0);
        let ax = _mm256_andnot_pd(sign_mask, x);
        let sgn = _mm256_and_pd(sign_mask, x);
        // small branch: x + x * (x2 * P(x2))
        let x2 = _mm256_mul_pd(x, x);
        let mut p = _mm256_set1_pd(TANH_C[5]);
        for &c in TANH_C[..5].iter().rev() {
            p = _mm256_add_pd(_mm256_mul_pd(p, x2), _mm256_set1_pd(c));
        }
        let small =
            _mm256_add_pd(x, _mm256_mul_pd(x, _mm256_mul_pd(x2, p)));
        // exp branch: E = 2^k * Q(r), tanh = (E - 1) / (E + 1)
        let y = _mm256_min_pd(
            _mm256_mul_pd(_mm256_set1_pd(2.0), ax),
            _mm256_set1_pd(40.0),
        );
        let t0 = _mm256_add_pd(
            _mm256_mul_pd(y, _mm256_set1_pd(std::f64::consts::LOG2_E)),
            _mm256_set1_pd(MAGIC),
        );
        let kd = _mm256_sub_pd(t0, _mm256_set1_pd(MAGIC));
        let r = _mm256_sub_pd(
            _mm256_sub_pd(y, _mm256_mul_pd(kd, _mm256_set1_pd(LN2_HI))),
            _mm256_mul_pd(kd, _mm256_set1_pd(LN2_LO)),
        );
        let mut q = _mm256_set1_pd(EXP_C[13]);
        for &c in EXP_C[..13].iter().rev() {
            q = _mm256_add_pd(_mm256_mul_pd(q, r), _mm256_set1_pd(c));
        }
        // 2^k from the magic-biased mantissa: t0's low bits hold k
        let ki = _mm256_castpd_si256(t0);
        let scale = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(
            _mm256_add_epi64(ki, _mm256_set1_epi64x(1023)),
        ));
        let e = _mm256_mul_pd(q, scale);
        let one = _mm256_set1_pd(1.0);
        let t = _mm256_div_pd(_mm256_sub_pd(e, one),
                              _mm256_add_pd(e, one));
        let big = _mm256_or_pd(t, sgn);
        let mask =
            _mm256_cmp_pd::<_CMP_LT_OQ>(ax, _mm256_set1_pd(0.125));
        _mm256_blendv_pd(big, small, mask)
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn tanh_block(z: &mut [f64]) {
        let n4 = z.len() - z.len() % 4;
        let zp = z.as_mut_ptr();
        for o in (0..n4).step_by(4) {
            let v = _mm256_loadu_pd(zp.add(o));
            _mm256_storeu_pd(zp.add(o), tanh4(v));
        }
        for v in &mut z[n4..] {
            *v = super::tanh_accurate(*v);
        }
    }

    /// 8-lane vector body of [`super::tanh_fast_f32`] — identical
    /// operation sequence (multiply + add, no FMA), so vector and
    /// scalar f32 tanh agree bit-for-bit.
    ///
    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn tanh8_f32(x: __m256) -> __m256 {
        let sign_mask = _mm256_set1_ps(-0.0);
        let ax = _mm256_andnot_ps(sign_mask, x);
        let sgn = _mm256_and_ps(sign_mask, x);
        let x2 = _mm256_mul_ps(x, x);
        let p = _mm256_add_ps(
            _mm256_mul_ps(
                _mm256_add_ps(
                    _mm256_mul_ps(_mm256_set1_ps(TANH_CF[2]), x2),
                    _mm256_set1_ps(TANH_CF[1]),
                ),
                x2,
            ),
            _mm256_set1_ps(TANH_CF[0]),
        );
        let small =
            _mm256_add_ps(x, _mm256_mul_ps(x, _mm256_mul_ps(x2, p)));
        let y = _mm256_min_ps(
            _mm256_mul_ps(_mm256_set1_ps(2.0), ax),
            _mm256_set1_ps(18.0),
        );
        let t0 = _mm256_add_ps(
            _mm256_mul_ps(y, _mm256_set1_ps(std::f32::consts::LOG2_E)),
            _mm256_set1_ps(MAGIC_F),
        );
        let kd = _mm256_sub_ps(t0, _mm256_set1_ps(MAGIC_F));
        let r = _mm256_sub_ps(
            _mm256_sub_ps(
                y, _mm256_mul_ps(kd, _mm256_set1_ps(LN2_HI_F))),
            _mm256_mul_ps(kd, _mm256_set1_ps(LN2_LO_F)),
        );
        let mut q = _mm256_set1_ps(EXP_CF[7]);
        for &c in EXP_CF[..7].iter().rev() {
            q = _mm256_add_ps(_mm256_mul_ps(q, r), _mm256_set1_ps(c));
        }
        let ki = _mm256_castps_si256(t0);
        let scale = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(
            _mm256_add_epi32(ki, _mm256_set1_epi32(127)),
        ));
        let e = _mm256_mul_ps(q, scale);
        let one = _mm256_set1_ps(1.0);
        let t = _mm256_div_ps(_mm256_sub_ps(e, one),
                              _mm256_add_ps(e, one));
        let big = _mm256_or_ps(t, sgn);
        let mask =
            _mm256_cmp_ps::<_CMP_LT_OQ>(ax, _mm256_set1_ps(0.125));
        _mm256_blendv_ps(big, small, mask)
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn tanh_block_f32(z: &mut [f32]) {
        let n8 = z.len() - z.len() % 8;
        let zp = z.as_mut_ptr();
        for o in (0..n8).step_by(8) {
            let v = _mm256_loadu_ps(zp.add(o));
            _mm256_storeu_ps(zp.add(o), tanh8_f32(v));
        }
        for v in &mut z[n8..] {
            *v = super::tanh_fast_f32(*v);
        }
    }

    /// AVX2 body of [`super::gemm_f32acc`]: 8-lane f32 FMA products,
    /// f32 partial sums per 16-deep k-chunk, f64 chunk accumulation.
    ///
    /// # Safety
    /// Requires AVX2 + FMA; slice lengths checked by the dispatcher.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn gemm_f32acc(
        a: &[f32],
        m: usize,
        nin: usize,
        wp: &[f32],
        nout_pad: usize,
        z: &mut [f64],
    ) {
        let ap = a.as_ptr();
        let wpp = wp.as_ptr();
        let zp = z.as_mut_ptr();
        for p in 0..m {
            let arow = ap.add(p * nin);
            for blk in 0..nout_pad / 8 {
                let panel = wpp.add(blk * nin * 8);
                let mut lo = _mm256_setzero_pd();
                let mut hi = _mm256_setzero_pd();
                for c0 in (0..nin).step_by(KBLK_F32) {
                    let c1 = (c0 + KBLK_F32).min(nin);
                    let mut part = _mm256_setzero_ps();
                    for i in c0..c1 {
                        let av = _mm256_set1_ps(*arow.add(i));
                        let wv = _mm256_loadu_ps(panel.add(i * 8));
                        part = _mm256_fmadd_ps(av, wv, part);
                    }
                    lo = _mm256_add_pd(
                        lo,
                        _mm256_cvtps_pd(_mm256_castps256_ps128(part)),
                    );
                    hi = _mm256_add_pd(
                        hi,
                        _mm256_cvtps_pd(
                            _mm256_extractf128_ps::<1>(part)),
                    );
                }
                _mm256_storeu_pd(zp.add(p * nout_pad + blk * 8), lo);
                _mm256_storeu_pd(zp.add(p * nout_pad + blk * 8 + 4),
                                 hi);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn edge_values() -> Vec<f64> {
        vec![
            0.0, -0.0, 1e-300, -1e-300, 0.124999, 0.125, 0.1250001,
            -0.125, 1.0, -1.0, 5.0, -5.0, 18.9, 19.1, -19.1, 40.0,
            700.0, -700.0, 1e308, -1e308,
        ]
    }

    #[test]
    fn accurate_tanh_is_1e15_class_vs_libm() {
        let mut rng = Rng::new(9);
        let mut worst = 0.0f64;
        let mut xs = edge_values();
        for _ in 0..200_000 {
            xs.push(rng.uniform_in(-25.0, 25.0));
            xs.push(rng.uniform_in(-0.2, 0.2));
        }
        for x in xs {
            let got = tanh_accurate(x);
            let want = x.tanh();
            let rel =
                (got - want).abs() / want.abs().max(f64::MIN_POSITIVE);
            assert!(
                rel < 5e-15,
                "tanh_accurate({x}) = {got}, libm {want} (rel {rel:e})"
            );
            worst = worst.max(rel);
        }
        assert!(worst < 5e-15);
    }

    #[test]
    fn fast_f32_tanh_is_within_inference_budget() {
        let mut rng = Rng::new(31);
        for _ in 0..200_000 {
            let x = rng.uniform_in(-12.0, 12.0) as f32;
            let got = tanh_fast_f32(x) as f64;
            let want = (x as f64).tanh();
            let rel = (got - want).abs() / want.abs().max(1e-6);
            assert!(
                rel < 2e-6,
                "tanh_fast_f32({x}) = {got}, want {want} (rel {rel:e})"
            );
        }
    }

    #[test]
    fn vector_tanh_matches_scalar_transliteration_bitwise() {
        if !simd_available() {
            return; // no AVX2 on this machine: nothing to compare
        }
        let mut rng = Rng::new(77);
        // odd length exercises the scalar remainder lane
        let mut xs: Vec<f64> = edge_values();
        for _ in 0..4093 {
            xs.push(rng.uniform_in(-30.0, 30.0));
        }
        let mut v = xs.clone();
        tanh_block(&mut v);
        for (x, got) in xs.iter().zip(&v) {
            let want = tanh_accurate(*x);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "lane diverges from transliteration at x={x}"
            );
        }
        // f32 variant: vector and scalar also agree bit-for-bit
        let xf: Vec<f32> =
            xs.iter().map(|&x| x as f32).collect();
        let mut vf = xf.clone();
        tanh_block_f32(&mut vf);
        for (x, got) in xf.iter().zip(&vf) {
            assert_eq!(got.to_bits(), tanh_fast_f32(*x).to_bits());
        }
    }

    #[test]
    fn scalar_fallback_tanh_block_is_libm() {
        // the fallback must reproduce pre-SIMD trajectories exactly
        if active() != Kernel::Scalar {
            return;
        }
        let xs = [-3.0f64, -0.1, 0.0, 0.7, 11.0];
        let mut v = xs;
        tanh_block(&mut v);
        for (x, got) in xs.iter().zip(&v) {
            assert_eq!(got.to_bits(), x.tanh().to_bits());
        }
    }

    #[test]
    fn mixed_precision_gemm_stays_within_rel_err_bound() {
        let mut rng = Rng::new(55);
        for &(m, nin, nout) in
            &[(1usize, 2usize, 30usize), (17, 30, 30), (64, 30, 1),
              (9, 33, 7)]
        {
            let w: Vec<f64> = (0..nin * nout)
                .map(|_| rng.uniform_in(-0.7, 0.7))
                .collect();
            let a64: Vec<f64> = (0..m * nin)
                .map(|_| rng.uniform_in(-1.0, 1.0))
                .collect();
            let a32: Vec<f32> =
                a64.iter().map(|&v| v as f32).collect();
            let (wp, nout_pad) = pack_weights_f32(&w, nin, nout);
            let mut z = vec![0.0f64; m * nout_pad];
            gemm_f32acc(&a32, m, nin, &wp, nout_pad, &mut z);
            for p in 0..m {
                for j in 0..nout {
                    let mut want = 0.0f64;
                    for i in 0..nin {
                        want += a64[p * nin + i] * w[i * nout + j];
                    }
                    let got = z[p * nout_pad + j];
                    let err = (got - want).abs()
                        / want.abs().max(nin as f64 * 0.5);
                    assert!(
                        err < 1e-5,
                        "z[{p},{j}] = {got}, want {want} ({m}x{nin}\
                         x{nout})"
                    );
                }
                for j in nout..nout_pad {
                    assert_eq!(z[p * nout_pad + j], 0.0,
                               "padding lanes must stay zero");
                }
            }
        }
    }

    #[test]
    fn kernel_name_matches_active_kernel() {
        match active() {
            Kernel::Avx2 => assert_eq!(kernel_name(), "avx2_4x12"),
            Kernel::Scalar => assert_eq!(kernel_name(), "scalar_4x8"),
        }
    }
}
