//! Cache-blocked micro-GEMM kernels for the tensorized training hot
//! path (and anything else that wants dense products).
//!
//! The paper's speedup comes from casting the hp-VPINN residual as
//! dense tensor contractions instead of per-point loops; this module is
//! the CPU kernel those contractions (and the batched MLP
//! forward/backward) run through. Classic BLIS-style structure:
//!
//! - three blocking loops (`NC` columns of B, `KC`-deep panels, `MC`
//!   rows of A) keep the working set cache-resident;
//! - A and B are repacked into contiguous, zero-padded `MR x KC` /
//!   `KC x NR` panels, so the innermost kernel is branch-free and
//!   transposed operands cost nothing extra (the packing routines
//!   absorb the transpose);
//! - an `MR x NR` register microkernel with fixed-bound loops that the
//!   compiler unrolls and vectorizes.
//!
//! All matrices are row-major `f64` slices; `C` always has row stride
//! `n`. Accumulation (`beta = 1`) is exact for the backward pass's
//! `+=` into gradient slices. Everything is deterministic: the
//! floating-point reduction order depends only on the shapes.

/// Microkernel tile rows (accumulator block height).
const MR: usize = 4;
/// Microkernel tile columns (accumulator block width).
const NR: usize = 8;
/// Rows of A per packed block (multiple of `MR`).
const MC: usize = 64;
/// Panel depth (shared k-extent of the packed A/B panels).
const KC: usize = 128;
/// Columns of B per packed block (multiple of `NR`).
const NC: usize = 256;

/// Reusable packing buffers — allocate once per thread, pass to every
/// [`gemm`] call to keep the hot path allocation-free.
#[derive(Debug, Clone)]
pub struct GemmBufs {
    pa: Vec<f64>,
    pb: Vec<f64>,
}

impl GemmBufs {
    /// Allocate the packing panels (one-time, reused across calls).
    pub fn new() -> GemmBufs {
        GemmBufs { pa: vec![0.0; MC * KC], pb: vec![0.0; KC * NC] }
    }
}

impl Default for GemmBufs {
    fn default() -> Self {
        GemmBufs::new()
    }
}

/// `C <- beta*C + alpha * op(A) @ op(B)` with `op(A)` of shape `m x k`
/// and `op(B)` of shape `k x n`, all row-major.
///
/// `ta == false` means `a` is stored `m x k`; `ta == true` means `a` is
/// stored `k x m` and accessed transposed (likewise `tb` for `b`, which
/// is then stored `n x k`). `c` is `m x n` with row stride `n`.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    bufs: &mut GemmBufs,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    ta: bool,
    b: &[f64],
    tb: bool,
    beta: f64,
    c: &mut [f64],
) {
    assert!(a.len() >= m * k, "A too short: {} < {}*{}", a.len(), m, k);
    assert!(b.len() >= k * n, "B too short: {} < {}*{}", b.len(), k, n);
    assert!(c.len() >= m * n, "C too short: {} < {}*{}", c.len(), m, n);
    if m == 0 || n == 0 {
        return;
    }
    if beta == 0.0 {
        c[..m * n].fill(0.0);
    } else if beta != 1.0 {
        for v in &mut c[..m * n] {
            *v *= beta;
        }
    }
    if k == 0 || alpha == 0.0 {
        return;
    }
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            pack_b(b, tb, n, k, pc, jc, kc, nc, &mut bufs.pb);
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                pack_a(a, ta, m, k, ic, pc, mc, kc, &mut bufs.pa);
                block_kernel(&bufs.pa, &bufs.pb, mc, nc, kc, alpha, c,
                             ic, jc, n);
            }
        }
    }
}

/// Pack `op(A)[ic..ic+mc, pc..pc+kc]` into `MR`-row panels, p-major
/// within each panel, zero-padding the ragged last panel so the
/// microkernel never branches on edges.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    a: &[f64],
    ta: bool,
    m: usize,
    k: usize,
    ic: usize,
    pc: usize,
    mc: usize,
    kc: usize,
    pa: &mut [f64],
) {
    let mut w = 0;
    for ip in (0..mc).step_by(MR) {
        for p in 0..kc {
            for ii in 0..MR {
                let i = ip + ii;
                pa[w] = if i < mc {
                    if ta {
                        a[(pc + p) * m + ic + i]
                    } else {
                        a[(ic + i) * k + pc + p]
                    }
                } else {
                    0.0
                };
                w += 1;
            }
        }
    }
}

/// Pack `op(B)[pc..pc+kc, jc..jc+nc]` into `NR`-column panels, p-major
/// within each panel, zero-padded like [`pack_a`].
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: &[f64],
    tb: bool,
    n: usize,
    k: usize,
    pc: usize,
    jc: usize,
    kc: usize,
    nc: usize,
    pb: &mut [f64],
) {
    let mut w = 0;
    for jp in (0..nc).step_by(NR) {
        for p in 0..kc {
            for jj in 0..NR {
                let j = jp + jj;
                pb[w] = if j < nc {
                    if tb {
                        b[(jc + j) * k + pc + p]
                    } else {
                        b[(pc + p) * n + jc + j]
                    }
                } else {
                    0.0
                };
                w += 1;
            }
        }
    }
}

/// Multiply one packed `mc x kc` A block against one packed `kc x nc`
/// B block, accumulating `alpha * product` into `C[ic.., jc..]`.
#[allow(clippy::too_many_arguments)]
fn block_kernel(
    pa: &[f64],
    pb: &[f64],
    mc: usize,
    nc: usize,
    kc: usize,
    alpha: f64,
    c: &mut [f64],
    ic: usize,
    jc: usize,
    ldc: usize,
) {
    for jr in (0..nc).step_by(NR) {
        let nr = NR.min(nc - jr);
        let bpan = &pb[jr * kc..jr * kc + NR * kc];
        for ir in (0..mc).step_by(MR) {
            let mr = MR.min(mc - ir);
            let apan = &pa[ir * kc..ir * kc + MR * kc];
            // MR x NR register accumulator; fixed bounds so the
            // compiler fully unrolls and vectorizes.
            let mut acc = [[0.0f64; NR]; MR];
            for p in 0..kc {
                let av = &apan[p * MR..p * MR + MR];
                let bv = &bpan[p * NR..p * NR + NR];
                for (arow, &ai) in acc.iter_mut().zip(av) {
                    for (aj, &bj) in arow.iter_mut().zip(bv) {
                        *aj += ai * bj;
                    }
                }
            }
            for (i, arow) in acc.iter().enumerate().take(mr) {
                let row = (ic + ir + i) * ldc + jc + jr;
                for (cj, &aj) in c[row..row + nr].iter_mut().zip(arow) {
                    *cj += alpha * aj;
                }
            }
        }
    }
}

/// `y <- beta*y + alpha * op(A) @ x` for a row-major `m x n` matrix.
///
/// `trans == false`: `op(A) = A` (`x` has length `n`, `y` length `m`).
/// `trans == true`: `op(A) = A^T` (`x` has length `m`, `y` length `n`).
/// The blocked residual contraction and its adjoint run through this
/// (per element, the premultiplier slab is an `nt x nq` matrix).
#[allow(clippy::too_many_arguments)]
pub fn gemv(
    m: usize,
    n: usize,
    alpha: f64,
    a: &[f64],
    trans: bool,
    x: &[f64],
    beta: f64,
    y: &mut [f64],
) {
    let (ylen, xlen) = if trans { (n, m) } else { (m, n) };
    assert!(a.len() >= m * n, "A too short: {} < {}*{}", a.len(), m, n);
    assert!(x.len() >= xlen, "x too short: {} < {}", x.len(), xlen);
    assert!(y.len() >= ylen, "y too short: {} < {}", y.len(), ylen);
    if beta == 0.0 {
        y[..ylen].fill(0.0);
    } else if beta != 1.0 {
        for v in &mut y[..ylen] {
            *v *= beta;
        }
    }
    if alpha == 0.0 || m == 0 || n == 0 {
        return;
    }
    if !trans {
        for (i, yi) in y.iter_mut().enumerate().take(m) {
            let row = &a[i * n..i * n + n];
            let mut acc = 0.0;
            for (&aj, &xj) in row.iter().zip(&x[..n]) {
                acc += aj * xj;
            }
            *yi += alpha * acc;
        }
    } else {
        for (i, &xi) in x.iter().enumerate().take(m) {
            let s = alpha * xi;
            if s == 0.0 {
                continue;
            }
            let row = &a[i * n..i * n + n];
            for (yj, &aj) in y[..n].iter_mut().zip(row) {
                *yj += s * aj;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_result;
    use crate::util::rng::Rng;

    /// Naive triple-loop reference — deliberately the dumbest possible
    /// implementation, the ground truth the blocked kernel must match.
    #[allow(clippy::too_many_arguments)]
    fn naive_gemm(
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        a: &[f64],
        ta: bool,
        b: &[f64],
        tb: bool,
        beta: f64,
        c: &mut [f64],
    ) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    let av = if ta { a[p * m + i] } else { a[i * k + p] };
                    let bv = if tb { b[j * k + p] } else { b[p * n + j] };
                    acc += av * bv;
                }
                c[i * n + j] = beta * c[i * n + j] + alpha * acc;
            }
        }
    }

    fn fill(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
    }

    #[derive(Debug, Clone, Copy)]
    struct Case {
        m: usize,
        n: usize,
        k: usize,
        ta: bool,
        tb: bool,
        alpha: f64,
        beta: f64,
    }

    /// Dimension pool biased toward block/tile edges: 1-wide, around
    /// MR/NR, and straddling MC/KC boundaries.
    const DIMS: [usize; 12] = [1, 2, 3, 4, 5, 7, 8, 9, 15, 17, 31, 33];

    fn run_case(rng: &mut Rng, case: &Case) -> Result<(), String> {
        let Case { m, n, k, ta, tb, alpha, beta } = *case;
        let a = fill(rng, m * k);
        let b = fill(rng, k * n);
        let c0 = fill(rng, m * n);
        let mut c_blk = c0.clone();
        let mut c_ref = c0;
        let mut bufs = GemmBufs::new();
        gemm(&mut bufs, m, n, k, alpha, &a, ta, &b, tb, beta, &mut c_blk);
        naive_gemm(m, n, k, alpha, &a, ta, &b, tb, beta, &mut c_ref);
        let tol = 1e-12 * (1.0 + k as f64);
        for (i, (x, y)) in c_blk.iter().zip(&c_ref).enumerate() {
            if (x - y).abs() > tol {
                return Err(format!("C[{i}]: blocked {x} vs naive {y}"));
            }
        }
        Ok(())
    }

    #[test]
    fn gemm_matches_naive_on_odd_shapes() {
        let mut vals = Rng::new(11);
        check_result(
            7,
            60,
            |r| Case {
                m: DIMS[r.below(DIMS.len())],
                n: DIMS[r.below(DIMS.len())],
                k: DIMS[r.below(DIMS.len())],
                ta: r.uniform() < 0.5,
                tb: r.uniform() < 0.5,
                alpha: [1.0, -1.0, 0.5, 0.0][r.below(4)],
                beta: [0.0, 1.0, -0.25][r.below(3)],
            },
            |case| run_case(&mut vals, case),
        );
    }

    #[test]
    fn gemm_crosses_every_blocking_boundary() {
        // m > MC, n > NC, k > KC in one shot, plus ragged edges.
        let mut rng = Rng::new(3);
        for &(m, n, k) in
            &[(MC + 1, NC + 3, KC + 5), (MR + 1, NR + 1, 2 * KC + 1)]
        {
            run_case(
                &mut rng,
                &Case { m, n, k, ta: false, tb: true, alpha: 1.0,
                        beta: 1.0 },
            )
            .unwrap();
        }
    }

    #[test]
    fn gemm_one_wide_layers() {
        // the shapes a [2,1,...,1] network produces
        let mut rng = Rng::new(5);
        for &(m, n, k) in &[(1, 1, 1), (9, 1, 2), (1, 7, 1), (30, 1, 1)] {
            for &(ta, tb) in
                &[(false, false), (true, false), (false, true), (true, true)]
            {
                run_case(
                    &mut rng,
                    &Case { m, n, k, ta, tb, alpha: 1.0, beta: 0.0 },
                )
                .unwrap();
            }
        }
    }

    #[test]
    fn gemm_accumulates_with_beta_one() {
        // the backward pass does C += A^T B three times in a row
        let mut rng = Rng::new(17);
        let (m, n, k) = (6, 5, 40);
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let mut c_blk = vec![0.0; m * n];
        let mut c_ref = vec![0.0; m * n];
        let mut bufs = GemmBufs::new();
        for _ in 0..3 {
            gemm(&mut bufs, m, n, k, 1.0, &a, true, &b, false, 1.0,
                 &mut c_blk);
            naive_gemm(m, n, k, 1.0, &a, true, &b, false, 1.0, &mut c_ref);
        }
        for (x, y) in c_blk.iter().zip(&c_ref) {
            assert!((x - y).abs() < 1e-11, "{x} vs {y}");
        }
    }

    #[test]
    fn gemv_matches_naive_both_orientations() {
        let mut vals = Rng::new(23);
        check_result(
            13,
            60,
            |r| {
                (
                    DIMS[r.below(DIMS.len())],
                    DIMS[r.below(DIMS.len())],
                    r.uniform() < 0.5,
                    [1.0, -0.5, 0.0][r.below(3)],
                    [0.0, 1.0, 2.0][r.below(3)],
                )
            },
            |&(m, n, trans, alpha, beta)| {
                let a = fill(&mut vals, m * n);
                let (xlen, ylen) = if trans { (m, n) } else { (n, m) };
                let x = fill(&mut vals, xlen);
                let y0 = fill(&mut vals, ylen);
                let mut y = y0.clone();
                gemv(m, n, alpha, &a, trans, &x, beta, &mut y);
                for (idx, yi) in y.iter().enumerate() {
                    let mut acc = 0.0;
                    if trans {
                        for p in 0..m {
                            acc += a[p * n + idx] * x[p];
                        }
                    } else {
                        for p in 0..n {
                            acc += a[idx * n + p] * x[p];
                        }
                    }
                    let want = beta * y0[idx] + alpha * acc;
                    if (yi - want).abs() > 1e-12 * (1.0 + m.max(n) as f64) {
                        return Err(format!(
                            "y[{idx}]: blocked {yi} vs naive {want}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
