//! Cache-blocked micro-GEMM kernels for the tensorized training hot
//! path (and anything else that wants dense products).
//!
//! The paper's speedup comes from casting the hp-VPINN residual as
//! dense tensor contractions instead of per-point loops; this module is
//! the CPU kernel those contractions (and the batched MLP
//! forward/backward) run through. Classic BLIS-style structure:
//!
//! - three blocking loops (`NC` columns of B, `KC`-deep panels, `MC`
//!   rows of A) keep the working set cache-resident;
//! - A and B are repacked into contiguous, zero-padded `MR x KC` /
//!   `KC x NR` panels, so the innermost kernel is branch-free and
//!   transposed operands cost nothing extra (the packing routines
//!   absorb the transpose);
//! - an `MR x NR` register microkernel with fixed-bound loops that the
//!   compiler unrolls and vectorizes.
//!
//! All matrices are row-major `f64` slices; `C` always has row stride
//! `n`. Accumulation (`beta = 1`) is exact for the backward pass's
//! `+=` into gradient slices. Everything is deterministic: the
//! floating-point reduction order depends only on the shapes.
//!
//! Two microkernels can execute the packed blocks: the portable scalar
//! `4x8` tile below (the property-tested ground truth) and the AVX2
//! `4x12` tile in [`simd`](super::simd), selected once per call via
//! [`simd::active`]. Because the per-(i, j) reduction order is
//! invariant under the tile width (the packing loops only regroup
//! *independent* output elements, and the AVX2 kernel uses the same
//! multiply-then-add sequence — no FMA), the two kernels produce
//! bit-identical results; the proptests at the bottom enforce that.

use super::simd;

/// Microkernel tile rows (accumulator block height).
const MR: usize = 4;
/// Microkernel tile columns (accumulator block width).
const NR: usize = 8;
/// Rows of A per packed block (multiple of every kernel's `mr`).
const MC: usize = 64;
/// Panel depth (shared k-extent of the packed A/B panels).
const KC: usize = 128;
/// Columns of B per packed block.
const NC: usize = 256;
/// `NC` rounded up to the widest kernel tile (`NR_AVX2 = 12`): the
/// packed-B capacity that serves every kernel without reallocation.
const NC_PAD_MAX: usize = NC.div_ceil(simd::NR_AVX2) * simd::NR_AVX2;

/// 64-byte-aligned, exactly-sized `f64` scratch for the packed
/// panels: cache-line (and thus 32-byte vector-load) aligned so the
/// AVX2 microkernel can use aligned panel loads. Growth via
/// [`AlignedBuf::ensure`] reallocates only when the requested size
/// exceeds the current capacity — steady-state reuse never churns.
struct AlignedBuf {
    ptr: std::ptr::NonNull<f64>,
    cap: usize,
}

impl AlignedBuf {
    const ALIGN: usize = 64;

    fn layout(cap: usize) -> std::alloc::Layout {
        std::alloc::Layout::from_size_align(
            cap * std::mem::size_of::<f64>(),
            Self::ALIGN,
        )
        .expect("panel layout overflows")
    }

    /// Grow to at least `n` f64 slots (exact allocation, zeroed; a
    /// no-op when capacity already suffices). Panel contents are
    /// scratch, so growth need not preserve them.
    fn ensure(&mut self, n: usize) {
        if n <= self.cap {
            return;
        }
        self.release();
        let layout = Self::layout(n);
        // SAFETY: layout has non-zero size (n > cap >= 0).
        let raw = unsafe { std::alloc::alloc_zeroed(layout) };
        let Some(ptr) = std::ptr::NonNull::new(raw.cast::<f64>())
        else {
            std::alloc::handle_alloc_error(layout)
        };
        debug_assert_eq!(
            ptr.as_ptr() as usize % Self::ALIGN,
            0,
            "allocator violated the panel alignment contract"
        );
        self.ptr = ptr;
        self.cap = n;
    }

    fn release(&mut self) {
        if self.cap > 0 {
            // SAFETY: ptr was allocated with exactly this layout.
            unsafe {
                std::alloc::dealloc(
                    self.ptr.as_ptr().cast(),
                    Self::layout(self.cap),
                );
            }
            self.ptr = std::ptr::NonNull::dangling();
            self.cap = 0;
        }
    }

    fn as_mut_slice(&mut self) -> &mut [f64] {
        // SAFETY: ptr is valid for cap f64s (or dangling with cap 0).
        unsafe {
            std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.cap)
        }
    }

    fn as_slice(&self) -> &[f64] {
        // SAFETY: as above.
        unsafe {
            std::slice::from_raw_parts(self.ptr.as_ptr(), self.cap)
        }
    }
}

impl Default for AlignedBuf {
    fn default() -> Self {
        AlignedBuf { ptr: std::ptr::NonNull::dangling(), cap: 0 }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        self.release();
    }
}

impl Clone for AlignedBuf {
    fn clone(&self) -> Self {
        let mut b = AlignedBuf::default();
        b.ensure(self.cap);
        b.as_mut_slice().copy_from_slice(self.as_slice());
        b
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
        -> std::fmt::Result {
        f.debug_struct("AlignedBuf").field("cap", &self.cap).finish()
    }
}

// SAFETY: AlignedBuf uniquely owns its allocation (no interior
// mutability, no aliasing), so moving/sharing it across threads is as
// safe as for Vec<f64>.
unsafe impl Send for AlignedBuf {}
unsafe impl Sync for AlignedBuf {}

/// Reusable packing buffers — allocate once per thread, pass to every
/// [`gemm`] call to keep the hot path allocation-free. `Default`
/// yields empty buffers that the first [`gemm`] call grows (exactly
/// once); [`GemmBufs::new`] pre-allocates the full panel capacity up
/// front.
#[derive(Debug, Clone, Default)]
pub struct GemmBufs {
    pa: AlignedBuf,
    pb: AlignedBuf,
}

impl GemmBufs {
    /// Allocate the packing panels (one-time, reused across calls).
    pub fn new() -> GemmBufs {
        let mut b = GemmBufs::default();
        b.pa.ensure(MC * KC);
        b.pb.ensure(KC * NC_PAD_MAX);
        b
    }
}

/// `C <- beta*C + alpha * op(A) @ op(B)` with `op(A)` of shape `m x k`
/// and `op(B)` of shape `k x n`, all row-major.
///
/// `ta == false` means `a` is stored `m x k`; `ta == true` means `a` is
/// stored `k x m` and accessed transposed (likewise `tb` for `b`, which
/// is then stored `n x k`). `c` is `m x n` with row stride `n`.
///
/// Runs on the kernel selected once per call by
/// [`simd::active`] (AVX2 `4x12` where detected, scalar `4x8`
/// otherwise or under `REPRO_FORCE_SCALAR=1`); both kernels produce
/// bit-identical results.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    bufs: &mut GemmBufs,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    ta: bool,
    b: &[f64],
    tb: bool,
    beta: f64,
    c: &mut [f64],
) {
    gemm_with(simd::active(), bufs, m, n, k, alpha, a, ta, b, tb, beta,
              c);
}

/// [`gemm`] on an explicitly chosen kernel — the bit-for-bit parity
/// proptests compare the kernels directly through this (no racy
/// global toggles).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_with(
    kern: simd::Kernel,
    bufs: &mut GemmBufs,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    ta: bool,
    b: &[f64],
    tb: bool,
    beta: f64,
    c: &mut [f64],
) {
    assert!(a.len() >= m * k, "A too short: {} < {}*{}", a.len(), m, k);
    assert!(b.len() >= k * n, "B too short: {} < {}*{}", b.len(), k, n);
    assert!(c.len() >= m * n, "C too short: {} < {}*{}", c.len(), m, n);
    if m == 0 || n == 0 {
        return;
    }
    if beta == 0.0 {
        c[..m * n].fill(0.0);
    } else if beta != 1.0 {
        for v in &mut c[..m * n] {
            *v *= beta;
        }
    }
    if k == 0 || alpha == 0.0 {
        return;
    }
    let (mr, nr) = match kern {
        simd::Kernel::Scalar => (MR, NR),
        simd::Kernel::Avx2 => (simd::MR_AVX2, simd::NR_AVX2),
    };
    debug_assert_eq!(MC % mr, 0, "MC must be a multiple of the tile");
    bufs.pa.ensure(MC * KC);
    bufs.pb.ensure(KC * NC.div_ceil(nr) * nr);
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            pack_b(b, tb, n, k, pc, jc, kc, nc, nr,
                   bufs.pb.as_mut_slice());
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                pack_a(a, ta, m, k, ic, pc, mc, kc, mr,
                       bufs.pa.as_mut_slice());
                match kern {
                    simd::Kernel::Scalar => block_kernel(
                        bufs.pa.as_slice(), bufs.pb.as_slice(), mc, nc,
                        kc, alpha, c, ic, jc, n),
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: Kernel::Avx2 is only ever produced by
                    // simd::active() after feature detection (or by
                    // tests that checked simd_available()).
                    simd::Kernel::Avx2 => unsafe {
                        simd::block_kernel_avx2(
                            bufs.pa.as_slice(), bufs.pb.as_slice(), mc,
                            nc, kc, alpha, c, ic, jc, n)
                    },
                    #[cfg(not(target_arch = "x86_64"))]
                    simd::Kernel::Avx2 => block_kernel(
                        bufs.pa.as_slice(), bufs.pb.as_slice(), mc, nc,
                        kc, alpha, c, ic, jc, n),
                }
            }
        }
    }
}

/// Pack `op(A)[ic..ic+mc, pc..pc+kc]` into `mr`-row panels, p-major
/// within each panel, zero-padding the ragged last panel so the
/// microkernel never branches on edges. `mr` is the active kernel's
/// tile height.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    a: &[f64],
    ta: bool,
    m: usize,
    k: usize,
    ic: usize,
    pc: usize,
    mc: usize,
    kc: usize,
    mr: usize,
    pa: &mut [f64],
) {
    let mut w = 0;
    for ip in (0..mc).step_by(mr) {
        for p in 0..kc {
            for ii in 0..mr {
                let i = ip + ii;
                pa[w] = if i < mc {
                    if ta {
                        a[(pc + p) * m + ic + i]
                    } else {
                        a[(ic + i) * k + pc + p]
                    }
                } else {
                    0.0
                };
                w += 1;
            }
        }
    }
}

/// Pack `op(B)[pc..pc+kc, jc..jc+nc]` into `nr`-column panels, p-major
/// within each panel, zero-padded like [`pack_a`]. `nr` is the active
/// kernel's tile width.
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: &[f64],
    tb: bool,
    n: usize,
    k: usize,
    pc: usize,
    jc: usize,
    kc: usize,
    nc: usize,
    nr: usize,
    pb: &mut [f64],
) {
    let mut w = 0;
    for jp in (0..nc).step_by(nr) {
        for p in 0..kc {
            for jj in 0..nr {
                let j = jp + jj;
                pb[w] = if j < nc {
                    if tb {
                        b[(jc + j) * k + pc + p]
                    } else {
                        b[(pc + p) * n + jc + j]
                    }
                } else {
                    0.0
                };
                w += 1;
            }
        }
    }
}

/// Multiply one packed `mc x kc` A block against one packed `kc x nc`
/// B block, accumulating `alpha * product` into `C[ic.., jc..]`.
#[allow(clippy::too_many_arguments)]
fn block_kernel(
    pa: &[f64],
    pb: &[f64],
    mc: usize,
    nc: usize,
    kc: usize,
    alpha: f64,
    c: &mut [f64],
    ic: usize,
    jc: usize,
    ldc: usize,
) {
    for jr in (0..nc).step_by(NR) {
        let nr = NR.min(nc - jr);
        let bpan = &pb[jr * kc..jr * kc + NR * kc];
        for ir in (0..mc).step_by(MR) {
            let mr = MR.min(mc - ir);
            let apan = &pa[ir * kc..ir * kc + MR * kc];
            // MR x NR register accumulator; fixed bounds so the
            // compiler fully unrolls and vectorizes.
            let mut acc = [[0.0f64; NR]; MR];
            for p in 0..kc {
                let av = &apan[p * MR..p * MR + MR];
                let bv = &bpan[p * NR..p * NR + NR];
                for (arow, &ai) in acc.iter_mut().zip(av) {
                    for (aj, &bj) in arow.iter_mut().zip(bv) {
                        *aj += ai * bj;
                    }
                }
            }
            for (i, arow) in acc.iter().enumerate().take(mr) {
                let row = (ic + ir + i) * ldc + jc + jr;
                for (cj, &aj) in c[row..row + nr].iter_mut().zip(arow) {
                    *cj += alpha * aj;
                }
            }
        }
    }
}

/// `y <- beta*y + alpha * op(A) @ x` for a row-major `m x n` matrix.
///
/// `trans == false`: `op(A) = A` (`x` has length `n`, `y` length `m`).
/// `trans == true`: `op(A) = A^T` (`x` has length `m`, `y` length `n`).
/// The blocked residual contraction and its adjoint run through this
/// (per element, the premultiplier slab is an `nt x nq` matrix).
///
/// Dispatched like [`gemm`]; the AVX2 variants preserve the scalar
/// loops' per-element reduction order exactly (one lane per output),
/// so both kernels are bit-identical here too.
#[allow(clippy::too_many_arguments)]
pub fn gemv(
    m: usize,
    n: usize,
    alpha: f64,
    a: &[f64],
    trans: bool,
    x: &[f64],
    beta: f64,
    y: &mut [f64],
) {
    gemv_with(simd::active(), m, n, alpha, a, trans, x, beta, y);
}

/// [`gemv`] on an explicitly chosen kernel (parity tests).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemv_with(
    kern: simd::Kernel,
    m: usize,
    n: usize,
    alpha: f64,
    a: &[f64],
    trans: bool,
    x: &[f64],
    beta: f64,
    y: &mut [f64],
) {
    let (ylen, xlen) = if trans { (n, m) } else { (m, n) };
    assert!(a.len() >= m * n, "A too short: {} < {}*{}", a.len(), m, n);
    assert!(x.len() >= xlen, "x too short: {} < {}", x.len(), xlen);
    assert!(y.len() >= ylen, "y too short: {} < {}", y.len(), ylen);
    if beta == 0.0 {
        y[..ylen].fill(0.0);
    } else if beta != 1.0 {
        for v in &mut y[..ylen] {
            *v *= beta;
        }
    }
    if alpha == 0.0 || m == 0 || n == 0 {
        return;
    }
    #[cfg(target_arch = "x86_64")]
    if kern == simd::Kernel::Avx2 {
        // SAFETY: Kernel::Avx2 implies the feature probe passed;
        // slice lengths were asserted above.
        unsafe {
            if trans {
                simd::gemv_trans_avx2(m, n, alpha, a, x, y);
            } else {
                simd::gemv_notrans_avx2(m, n, alpha, a, x, y);
            }
        }
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = kern;
    if !trans {
        for (i, yi) in y.iter_mut().enumerate().take(m) {
            let row = &a[i * n..i * n + n];
            let mut acc = 0.0;
            for (&aj, &xj) in row.iter().zip(&x[..n]) {
                acc += aj * xj;
            }
            *yi += alpha * acc;
        }
    } else {
        for (i, &xi) in x.iter().enumerate().take(m) {
            let s = alpha * xi;
            if s == 0.0 {
                continue;
            }
            let row = &a[i * n..i * n + n];
            for (yj, &aj) in y[..n].iter_mut().zip(row) {
                *yj += s * aj;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_result;
    use crate::util::rng::Rng;

    /// Naive triple-loop reference — deliberately the dumbest possible
    /// implementation, the ground truth the blocked kernel must match.
    #[allow(clippy::too_many_arguments)]
    fn naive_gemm(
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        a: &[f64],
        ta: bool,
        b: &[f64],
        tb: bool,
        beta: f64,
        c: &mut [f64],
    ) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    let av = if ta { a[p * m + i] } else { a[i * k + p] };
                    let bv = if tb { b[j * k + p] } else { b[p * n + j] };
                    acc += av * bv;
                }
                c[i * n + j] = beta * c[i * n + j] + alpha * acc;
            }
        }
    }

    fn fill(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
    }

    #[derive(Debug, Clone, Copy)]
    struct Case {
        m: usize,
        n: usize,
        k: usize,
        ta: bool,
        tb: bool,
        alpha: f64,
        beta: f64,
    }

    /// Dimension pool biased toward block/tile edges: 1-wide, around
    /// MR/NR, and straddling MC/KC boundaries.
    const DIMS: [usize; 12] = [1, 2, 3, 4, 5, 7, 8, 9, 15, 17, 31, 33];

    fn run_case(rng: &mut Rng, case: &Case) -> Result<(), String> {
        let Case { m, n, k, ta, tb, alpha, beta } = *case;
        let a = fill(rng, m * k);
        let b = fill(rng, k * n);
        let c0 = fill(rng, m * n);
        let mut c_blk = c0.clone();
        let mut c_ref = c0;
        let mut bufs = GemmBufs::new();
        gemm(&mut bufs, m, n, k, alpha, &a, ta, &b, tb, beta, &mut c_blk);
        naive_gemm(m, n, k, alpha, &a, ta, &b, tb, beta, &mut c_ref);
        let tol = 1e-12 * (1.0 + k as f64);
        for (i, (x, y)) in c_blk.iter().zip(&c_ref).enumerate() {
            if (x - y).abs() > tol {
                return Err(format!("C[{i}]: blocked {x} vs naive {y}"));
            }
        }
        Ok(())
    }

    #[test]
    fn gemm_matches_naive_on_odd_shapes() {
        let mut vals = Rng::new(11);
        check_result(
            7,
            60,
            |r| Case {
                m: DIMS[r.below(DIMS.len())],
                n: DIMS[r.below(DIMS.len())],
                k: DIMS[r.below(DIMS.len())],
                ta: r.uniform() < 0.5,
                tb: r.uniform() < 0.5,
                alpha: [1.0, -1.0, 0.5, 0.0][r.below(4)],
                beta: [0.0, 1.0, -0.25][r.below(3)],
            },
            |case| run_case(&mut vals, case),
        );
    }

    #[test]
    fn gemm_crosses_every_blocking_boundary() {
        // m > MC, n > NC, k > KC in one shot, plus ragged edges.
        let mut rng = Rng::new(3);
        for &(m, n, k) in
            &[(MC + 1, NC + 3, KC + 5), (MR + 1, NR + 1, 2 * KC + 1)]
        {
            run_case(
                &mut rng,
                &Case { m, n, k, ta: false, tb: true, alpha: 1.0,
                        beta: 1.0 },
            )
            .unwrap();
        }
    }

    #[test]
    fn gemm_one_wide_layers() {
        // the shapes a [2,1,...,1] network produces
        let mut rng = Rng::new(5);
        for &(m, n, k) in &[(1, 1, 1), (9, 1, 2), (1, 7, 1), (30, 1, 1)] {
            for &(ta, tb) in
                &[(false, false), (true, false), (false, true), (true, true)]
            {
                run_case(
                    &mut rng,
                    &Case { m, n, k, ta, tb, alpha: 1.0, beta: 0.0 },
                )
                .unwrap();
            }
        }
    }

    #[test]
    fn gemm_accumulates_with_beta_one() {
        // the backward pass does C += A^T B three times in a row
        let mut rng = Rng::new(17);
        let (m, n, k) = (6, 5, 40);
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let mut c_blk = vec![0.0; m * n];
        let mut c_ref = vec![0.0; m * n];
        let mut bufs = GemmBufs::new();
        for _ in 0..3 {
            gemm(&mut bufs, m, n, k, 1.0, &a, true, &b, false, 1.0,
                 &mut c_blk);
            naive_gemm(m, n, k, 1.0, &a, true, &b, false, 1.0, &mut c_ref);
        }
        for (x, y) in c_blk.iter().zip(&c_ref) {
            assert!((x - y).abs() < 1e-11, "{x} vs {y}");
        }
    }

    #[test]
    fn gemv_matches_naive_both_orientations() {
        let mut vals = Rng::new(23);
        check_result(
            13,
            60,
            |r| {
                (
                    DIMS[r.below(DIMS.len())],
                    DIMS[r.below(DIMS.len())],
                    r.uniform() < 0.5,
                    [1.0, -0.5, 0.0][r.below(3)],
                    [0.0, 1.0, 2.0][r.below(3)],
                )
            },
            |&(m, n, trans, alpha, beta)| {
                let a = fill(&mut vals, m * n);
                let (xlen, ylen) = if trans { (m, n) } else { (n, m) };
                let x = fill(&mut vals, xlen);
                let y0 = fill(&mut vals, ylen);
                let mut y = y0.clone();
                gemv(m, n, alpha, &a, trans, &x, beta, &mut y);
                for (idx, yi) in y.iter().enumerate() {
                    let mut acc = 0.0;
                    if trans {
                        for p in 0..m {
                            acc += a[p * n + idx] * x[p];
                        }
                    } else {
                        for p in 0..n {
                            acc += a[idx * n + p] * x[p];
                        }
                    }
                    let want = beta * y0[idx] + alpha * acc;
                    if (yi - want).abs() > 1e-12 * (1.0 + m.max(n) as f64) {
                        return Err(format!(
                            "y[{idx}]: blocked {yi} vs naive {want}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn simd_gemm_is_bit_identical_to_scalar() {
        if !simd::simd_available() {
            eprintln!("skipping: no AVX2 on this host");
            return;
        }
        let mut vals = Rng::new(29);
        check_result(
            31,
            80,
            |r| Case {
                m: DIMS[r.below(DIMS.len())],
                n: DIMS[r.below(DIMS.len())],
                k: DIMS[r.below(DIMS.len())],
                ta: r.uniform() < 0.5,
                tb: r.uniform() < 0.5,
                alpha: [1.0, -1.0, 0.5][r.below(3)],
                beta: [0.0, 1.0, -0.25][r.below(3)],
            },
            |case| {
                let Case { m, n, k, ta, tb, alpha, beta } = *case;
                let a = fill(&mut vals, m * k);
                let b = fill(&mut vals, k * n);
                let c0 = fill(&mut vals, m * n);
                let mut c_s = c0.clone();
                let mut c_v = c0;
                let mut bufs = GemmBufs::new();
                gemm_with(simd::Kernel::Scalar, &mut bufs, m, n, k,
                          alpha, &a, ta, &b, tb, beta, &mut c_s);
                gemm_with(simd::Kernel::Avx2, &mut bufs, m, n, k,
                          alpha, &a, ta, &b, tb, beta, &mut c_v);
                for (i, (s, v)) in c_s.iter().zip(&c_v).enumerate() {
                    if s.to_bits() != v.to_bits() {
                        return Err(format!(
                            "C[{i}]: scalar {s:?} vs avx2 {v:?} \
                             (bits differ)"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn simd_gemm_bit_identity_across_blocking_boundaries() {
        if !simd::simd_available() {
            eprintln!("skipping: no AVX2 on this host");
            return;
        }
        // Shapes straddling MC/KC/NC and both kernels' ragged tile
        // edges (NR = 8 vs NR_AVX2 = 12).
        let mut rng = Rng::new(41);
        for &(m, n, k) in &[
            (MC + 1, NC + 3, KC + 5),
            (MC, NC, KC),
            (MR + 1, simd::NR_AVX2 + 1, 2 * KC + 1),
            (3, 2 * NC + 11, 7),
        ] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let mut c_s = vec![0.0; m * n];
            let mut c_v = vec![0.0; m * n];
            let mut bufs = GemmBufs::new();
            gemm_with(simd::Kernel::Scalar, &mut bufs, m, n, k, 1.0,
                      &a, false, &b, true, 1.0, &mut c_s);
            gemm_with(simd::Kernel::Avx2, &mut bufs, m, n, k, 1.0,
                      &a, false, &b, true, 1.0, &mut c_v);
            for (s, v) in c_s.iter().zip(&c_v) {
                assert_eq!(
                    s.to_bits(),
                    v.to_bits(),
                    "({m},{n},{k}): scalar {s:?} vs avx2 {v:?}"
                );
            }
        }
    }

    #[test]
    fn simd_gemv_is_bit_identical_to_scalar() {
        if !simd::simd_available() {
            eprintln!("skipping: no AVX2 on this host");
            return;
        }
        let mut vals = Rng::new(37);
        check_result(
            43,
            80,
            |r| {
                (
                    DIMS[r.below(DIMS.len())],
                    DIMS[r.below(DIMS.len())],
                    r.uniform() < 0.5,
                    [1.0, -0.5, 2.0][r.below(3)],
                    [0.0, 1.0, -0.25][r.below(3)],
                )
            },
            |&(m, n, trans, alpha, beta)| {
                let a = fill(&mut vals, m * n);
                let (xlen, ylen) = if trans { (m, n) } else { (n, m) };
                let mut x = fill(&mut vals, xlen);
                // exercise the trans path's `s == 0.0` skip too
                if xlen > 2 {
                    x[1] = 0.0;
                }
                let y0 = fill(&mut vals, ylen);
                let mut y_s = y0.clone();
                let mut y_v = y0;
                gemv_with(simd::Kernel::Scalar, m, n, alpha, &a, trans,
                          &x, beta, &mut y_s);
                gemv_with(simd::Kernel::Avx2, m, n, alpha, &a, trans,
                          &x, beta, &mut y_v);
                for (i, (s, v)) in y_s.iter().zip(&y_v).enumerate() {
                    if s.to_bits() != v.to_bits() {
                        return Err(format!(
                            "y[{i}] (trans={trans}): scalar {s:?} vs \
                             avx2 {v:?} (bits differ)"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gemm_bufs_default_grows_once_and_is_reused() {
        // Default starts empty; the first gemm grows the panels and
        // later (smaller or equal) calls must not reallocate.
        let mut bufs = GemmBufs::default();
        assert_eq!(bufs.pa.cap, 0);
        assert_eq!(bufs.pb.cap, 0);
        let mut rng = Rng::new(53);
        let (m, n, k) = (9, 11, 13);
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let mut c = vec![0.0; m * n];
        gemm(&mut bufs, m, n, k, 1.0, &a, false, &b, false, 0.0, &mut c);
        let (pa_ptr, pb_ptr) =
            (bufs.pa.ptr.as_ptr() as usize, bufs.pb.ptr.as_ptr() as usize);
        assert_eq!(pa_ptr % 64, 0, "packed-A panel not 64-byte aligned");
        assert_eq!(pb_ptr % 64, 0, "packed-B panel not 64-byte aligned");
        assert!(bufs.pa.cap >= MC * KC);
        gemm(&mut bufs, m, n, k, 1.0, &a, false, &b, false, 0.0, &mut c);
        assert_eq!(bufs.pa.ptr.as_ptr() as usize, pa_ptr,
                   "steady-state gemm reallocated the A panel");
        assert_eq!(bufs.pb.ptr.as_ptr() as usize, pb_ptr,
                   "steady-state gemm reallocated the B panel");
        // ensure() with a smaller request is a no-op
        bufs.pa.ensure(1);
        assert_eq!(bufs.pa.ptr.as_ptr() as usize, pa_ptr);
    }
}
