//! Jacobi-preconditioned conjugate gradients for SPD systems (the FEM
//! reference solver's workhorse).

use super::csr::CsrMatrix;

/// Iteration/tolerance knobs for the Krylov solvers.
#[derive(Debug, Clone, Copy)]
pub struct CgOptions {
    /// Iteration cap.
    pub max_iter: usize,
    /// Relative residual tolerance (vs ||b||).
    pub rtol: f64,
    /// Absolute residual tolerance.
    pub atol: f64,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { max_iter: 10_000, rtol: 1e-10, atol: 1e-14 }
    }
}

/// Outcome of a Krylov solve.
#[derive(Debug, Clone)]
pub struct CgResult {
    /// Solution vector.
    pub x: Vec<f64>,
    /// Iterations used.
    pub iterations: usize,
    /// Final residual 2-norm.
    pub residual_norm: f64,
    /// Whether a tolerance was met before the iteration cap.
    pub converged: bool,
}

/// Solve A x = b with Jacobi (diagonal) preconditioning.
pub fn cg_solve(a: &CsrMatrix, b: &[f64], opts: CgOptions) -> CgResult {
    let n = b.len();
    assert_eq!(a.n_rows, n);
    let diag = a.diagonal();
    let minv: Vec<f64> = diag
        .iter()
        .map(|&d| if d.abs() > 1e-300 { 1.0 / d } else { 1.0 })
        .collect();

    let mut x = vec![0.0; n];
    let mut r = b.to_vec(); // r = b - A*0
    let mut z: Vec<f64> = r.iter().zip(&minv).map(|(ri, mi)| ri * mi)
        .collect();
    let mut p = z.clone();
    let mut rz: f64 = dot(&r, &z);
    let b_norm = norm(b).max(1e-300);
    let mut ap = vec![0.0; n];

    let mut iterations = 0;
    for it in 0..opts.max_iter {
        iterations = it;
        let r_norm = norm(&r);
        if r_norm <= opts.rtol * b_norm || r_norm <= opts.atol {
            return CgResult { x, iterations: it, residual_norm: r_norm,
                              converged: true };
        }
        a.matvec(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            // not SPD (or breakdown) — bail with what we have
            return CgResult { x, iterations: it, residual_norm: r_norm,
                              converged: false };
        }
        let alpha = rz / pap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        for i in 0..n {
            z[i] = r[i] * minv[i];
        }
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    let r_norm = norm(&r);
    CgResult { x, iterations, residual_norm: r_norm,
               converged: r_norm <= opts.rtol * b_norm }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::csr::Triplets;
    use crate::util::proptest::check_result;
    use crate::util::rng::Rng;

    fn laplace_1d(n: usize) -> CsrMatrix {
        let mut t = Triplets::new(n, n);
        for i in 0..n {
            t.push(i, i, 2.0);
            if i > 0 {
                t.push(i, i - 1, -1.0);
            }
            if i + 1 < n {
                t.push(i, i + 1, -1.0);
            }
        }
        t.to_csr()
    }

    #[test]
    fn solves_identity() {
        let mut t = Triplets::new(3, 3);
        for i in 0..3 {
            t.push(i, i, 1.0);
        }
        let r = cg_solve(&t.to_csr(), &[1.0, 2.0, 3.0],
                         CgOptions::default());
        assert!(r.converged);
        assert!((r.x[2] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn solves_laplace_1d() {
        let n = 50;
        let a = laplace_1d(n);
        // manufactured: x = i*(n+1-i), b = A x
        let xs: Vec<f64> =
            (1..=n).map(|i| (i * (n + 1 - i)) as f64).collect();
        let b = a.matvec_alloc(&xs);
        let r = cg_solve(&a, &b, CgOptions::default());
        assert!(r.converged, "residual {}", r.residual_norm);
        for (got, want) in r.x.iter().zip(&xs) {
            assert!((got - want).abs() < 1e-6);
        }
    }

    #[test]
    fn preconditioner_helps_scaled_system() {
        // badly scaled diagonal: D_i = 10^(i mod 6)
        let n = 40;
        let mut t = Triplets::new(n, n);
        for i in 0..n {
            let s = 10f64.powi((i % 6) as i32);
            t.push(i, i, 2.0 * s);
            if i > 0 {
                t.push(i, i - 1, -0.5);
                t.push(i - 1, i, -0.5);
            }
        }
        let a = t.to_csr();
        let want: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let b = a.matvec_alloc(&want);
        let r = cg_solve(&a, &b, CgOptions { max_iter: 500,
                                             ..Default::default() });
        assert!(r.converged);
        for (g, w) in r.x.iter().zip(&want) {
            assert!((g - w).abs() < 1e-7);
        }
    }

    #[test]
    fn property_random_spd_systems() {
        check_result(
            9,
            25,
            |r: &mut Rng| {
                let n = 5 + r.below(15);
                // A = B^T B + n I (SPD), dense-ish via triplets
                let bmat: Vec<f64> =
                    (0..n * n).map(|_| r.normal()).collect();
                let x: Vec<f64> = (0..n).map(|_| r.normal()).collect();
                (n, bmat, x)
            },
            |(n, bmat, xs)| {
                let n = *n;
                let mut t = Triplets::new(n, n);
                for i in 0..n {
                    for j in 0..n {
                        let mut acc = 0.0;
                        for k in 0..n {
                            acc += bmat[k * n + i] * bmat[k * n + j];
                        }
                        if i == j {
                            acc += n as f64;
                        }
                        t.push(i, j, acc);
                    }
                }
                let a = t.to_csr();
                let b = a.matvec_alloc(xs);
                let r = cg_solve(&a, &b, CgOptions::default());
                if !r.converged {
                    return Err(format!("no convergence: {}",
                                       r.residual_norm));
                }
                for (g, w) in r.x.iter().zip(xs) {
                    if (g - w).abs() > 1e-6 {
                        return Err(format!("|{g} - {w}| too large"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn non_spd_flagged() {
        let mut t = Triplets::new(2, 2);
        t.push(0, 0, -1.0);
        t.push(1, 1, -1.0);
        let r = cg_solve(&t.to_csr(), &[1.0, 1.0], CgOptions::default());
        assert!(!r.converged);
    }
}
