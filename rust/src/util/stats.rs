//! Timing statistics: the paper reports *median time per epoch*; this
//! module implements that measurement protocol (plus percentiles) for
//! the coordinator and the bench harness.

use std::time::Instant;

/// Accumulates per-step wall-clock samples.
#[derive(Debug, Default, Clone)]
pub struct StepTimer {
    samples_ms: Vec<f64>,
    current: Option<InstantWrap>,
}

#[derive(Debug, Clone)]
struct InstantWrap(Instant);

impl StepTimer {
    /// Empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark the start of a step.
    pub fn start(&mut self) {
        self.current = Some(InstantWrap(Instant::now()));
    }

    /// Mark the end of a step, recording its duration.
    pub fn stop(&mut self) {
        if let Some(InstantWrap(t0)) = self.current.take() {
            self.samples_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
    }

    /// Record an externally measured sample.
    pub fn record_ms(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    /// Recorded sample count.
    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    /// Order statistics over the recorded samples.
    pub fn summary(&self) -> Summary {
        Summary::from(&self.samples_ms)
    }
}

/// Order statistics over a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Smallest sample.
    pub min: f64,
    /// 50th percentile.
    pub median: f64,
    /// 90th percentile.
    pub p90: f64,
    /// Largest sample.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
}

impl Summary {
    /// Summarize a sample set (all zeros when empty).
    pub fn from(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary { n: 0, min: 0.0, median: 0.0, p90: 0.0,
                             max: 0.0, mean: 0.0 };
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: s.len(),
            min: s[0],
            median: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            max: s[s.len() - 1],
            mean: s.iter().sum::<f64>() / s.len() as f64,
        }
    }
}

/// Linear-interpolated percentile of a sorted slice, p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of an unsorted slice.
pub fn median(samples: &[f64]) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentiles() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&s, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 100.0) - 100.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 50.0) - 50.5).abs() < 1e-12);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.mean, 22.0);
        // median robust to the outlier, unlike the mean — exactly why
        // the paper reports median per-epoch time
        assert!(s.median < s.mean);
    }

    #[test]
    fn empty_summary() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn timer_records() {
        let mut t = StepTimer::new();
        for _ in 0..3 {
            t.start();
            std::hint::black_box((0..1000).sum::<u64>());
            t.stop();
        }
        assert_eq!(t.count(), 3);
        assert!(t.summary().min >= 0.0);
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut t = StepTimer::new();
        t.stop();
        assert_eq!(t.count(), 0);
    }
}
