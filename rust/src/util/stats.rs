//! Timing statistics: the paper reports *median time per epoch*; this
//! module implements that measurement protocol (plus percentiles) for
//! the coordinator and the bench harness.

use std::time::Instant;

/// Accumulates per-step wall-clock samples.
#[derive(Debug, Default, Clone)]
pub struct StepTimer {
    samples_ms: Vec<f64>,
    current: Option<InstantWrap>,
}

#[derive(Debug, Clone)]
struct InstantWrap(Instant);

impl StepTimer {
    /// Empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark the start of a step.
    pub fn start(&mut self) {
        self.current = Some(InstantWrap(Instant::now()));
    }

    /// Mark the end of a step, recording its duration.
    pub fn stop(&mut self) {
        if let Some(InstantWrap(t0)) = self.current.take() {
            self.samples_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
    }

    /// Record an externally measured sample.
    pub fn record_ms(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    /// Recorded sample count.
    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    /// Order statistics over the recorded samples.
    pub fn summary(&self) -> Summary {
        Summary::from(&self.samples_ms)
    }
}

/// Order statistics over a sample set.
///
/// Non-finite samples (NaN, ±inf — e.g. a poisoned timer under a
/// `step.stall` failpoint or clock weirdness) are *excluded* from every
/// statistic and counted in [`Summary::dropped`], so one bad sample can
/// neither panic the aggregation nor smear the percentiles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Finite sample count (the statistics cover exactly these).
    pub n: usize,
    /// Smallest sample.
    pub min: f64,
    /// 50th percentile.
    pub median: f64,
    /// 90th percentile.
    pub p90: f64,
    /// 99th percentile (tail latency for the serve metrics).
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Non-finite samples excluded from the statistics.
    pub dropped: usize,
}

impl Summary {
    const EMPTY: Summary = Summary {
        n: 0,
        min: 0.0,
        median: 0.0,
        p90: 0.0,
        p99: 0.0,
        max: 0.0,
        mean: 0.0,
        dropped: 0,
    };

    /// Summarize a sample set (all zeros when empty). Non-finite
    /// samples are dropped, not propagated: sorting uses
    /// `f64::total_cmp` and the count of excluded samples is reported
    /// in `dropped`.
    pub fn from(samples: &[f64]) -> Summary {
        let mut s: Vec<f64> =
            samples.iter().copied().filter(|v| v.is_finite()).collect();
        let dropped = samples.len() - s.len();
        if s.is_empty() {
            return Summary { dropped, ..Summary::EMPTY };
        }
        s.sort_by(f64::total_cmp);
        Summary {
            n: s.len(),
            min: s[0],
            median: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p99: percentile_sorted(&s, 99.0),
            max: s[s.len() - 1],
            mean: s.iter().sum::<f64>() / s.len() as f64,
            dropped,
        }
    }

    /// Merge two summaries into an estimate of the summary of the
    /// concatenated sample sets — how `repro report` combines the
    /// step-time statistics of several metrics files without the raw
    /// samples.
    ///
    /// Exactness contract (property-tested below):
    /// - `n`, `min`, `max`, `dropped`: **exact** (counts add, extrema
    ///   compose).
    /// - `mean`: the count-weighted mean — exact up to float roundoff.
    /// - percentiles: the count-weighted average of the inputs'
    ///   percentiles, which always lies **between** the two input
    ///   values. For the *median* the concatenation's true median
    ///   also lies in that bracket, so the merge error is bounded by
    ///   `|a.median - b.median|`. The tail percentiles (p90/p99) have
    ///   no such bracket — a concatenation's tail can exceed both
    ///   inputs' — and are estimates only.
    ///
    /// An empty side contributes only its `dropped` count.
    pub fn merge(&self, other: &Summary) -> Summary {
        let dropped = self.dropped + other.dropped;
        if self.n == 0 {
            return Summary { dropped, ..*other };
        }
        if other.n == 0 {
            return Summary { dropped, ..*self };
        }
        let n = self.n + other.n;
        let wa = self.n as f64 / n as f64;
        let wb = other.n as f64 / n as f64;
        Summary {
            n,
            min: self.min.min(other.min),
            median: wa * self.median + wb * other.median,
            p90: wa * self.p90 + wb * other.p90,
            p99: wa * self.p99 + wb * other.p99,
            max: self.max.max(other.max),
            mean: wa * self.mean + wb * other.mean,
            dropped,
        }
    }
}

/// Linear-interpolated percentile of a sorted slice, p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of an unsorted slice. Non-finite samples are excluded (see
/// [`Summary`]); an all-non-finite or empty input yields 0.
pub fn median(samples: &[f64]) -> f64 {
    let mut s: Vec<f64> =
        samples.iter().copied().filter(|v| v.is_finite()).collect();
    s.sort_by(f64::total_cmp);
    percentile_sorted(&s, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentiles() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&s, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 100.0) - 100.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 50.0) - 50.5).abs() < 1e-12);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.mean, 22.0);
        // median robust to the outlier, unlike the mean — exactly why
        // the paper reports median per-epoch time
        assert!(s.median < s.mean);
    }

    #[test]
    fn empty_summary() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn timer_records() {
        let mut t = StepTimer::new();
        for _ in 0..3 {
            t.start();
            std::hint::black_box((0..1000).sum::<u64>());
            t.stop();
        }
        assert_eq!(t.count(), 3);
        assert!(t.summary().min >= 0.0);
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut t = StepTimer::new();
        t.stop();
        assert_eq!(t.count(), 0);
    }

    /// Regression: a single NaN sample used to panic the
    /// `partial_cmp(..).unwrap()` sort in `Summary::from` and
    /// `median`. Now NaN/±inf are counted-and-excluded.
    #[test]
    fn non_finite_samples_are_dropped_not_fatal() {
        let s = Summary::from(&[
            2.0,
            f64::NAN,
            1.0,
            f64::INFINITY,
            3.0,
            f64::NEG_INFINITY,
        ]);
        assert_eq!(s.n, 3);
        assert_eq!(s.dropped, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.max, 3.0);
        assert!(s.mean.is_finite() && s.p90.is_finite());
        assert_eq!(median(&[f64::NAN, 5.0, 1.0]), 3.0);
    }

    #[test]
    fn all_non_finite_yields_empty_summary() {
        let s = Summary::from(&[f64::NAN, f64::INFINITY]);
        assert_eq!(s.n, 0);
        assert_eq!(s.dropped, 2);
        assert_eq!(s.median, 0.0);
        assert_eq!(median(&[f64::NAN]), 0.0);
    }

    #[test]
    fn p99_orders_with_the_other_percentiles() {
        let s: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let sm = Summary::from(&s);
        assert!(sm.median <= sm.p90 && sm.p90 <= sm.p99);
        assert!((sm.p99 - 990.01).abs() < 1e-9);
    }

    /// Property: for any sorted finite input, the interpolated
    /// percentile stays within [min, max] and is monotone in p.
    #[test]
    fn prop_percentile_bounds_and_monotonicity() {
        use crate::util::proptest::check_result;
        check_result(
            41,
            300,
            |r| {
                let n = 1 + r.below(40);
                let mut v: Vec<f64> =
                    (0..n).map(|_| r.uniform_in(-1e3, 1e3)).collect();
                v.sort_by(f64::total_cmp);
                let p0 = r.uniform_in(0.0, 100.0);
                let p1 = r.uniform_in(0.0, 100.0);
                (v, p0.min(p1), p0.max(p1))
            },
            |(v, plo, phi)| {
                let lo = percentile_sorted(v, *plo);
                let hi = percentile_sorted(v, *phi);
                if lo < v[0] - 1e-9 || hi > v[v.len() - 1] + 1e-9 {
                    return Err(format!("out of bounds: {lo} {hi}"));
                }
                if lo > hi + 1e-9 {
                    return Err(format!("not monotone: {lo} > {hi}"));
                }
                Ok(())
            },
        );
    }

    /// Property: Summary invariants hold under random contamination
    /// with non-finite samples — dropped counts exactly the non-finite
    /// ones, the order statistics chain min <= median <= p90 <= p99 <=
    /// max holds, and a single-sample set collapses every percentile
    /// onto that sample.
    #[test]
    fn prop_summary_invariants() {
        use crate::util::proptest::check_result;
        check_result(
            43,
            300,
            |r| {
                let n = r.below(30);
                let mut v: Vec<f64> =
                    (0..n).map(|_| r.uniform_in(-10.0, 1e4)).collect();
                let bad = r.below(4);
                for _ in 0..bad {
                    let x = match r.below(3) {
                        0 => f64::NAN,
                        1 => f64::INFINITY,
                        _ => f64::NEG_INFINITY,
                    };
                    v.insert(r.below(v.len() + 1), x);
                }
                (v, bad)
            },
            |(v, bad)| {
                let s = Summary::from(v);
                if s.dropped != *bad {
                    return Err(format!(
                        "dropped {} != injected {bad}",
                        s.dropped
                    ));
                }
                if s.n + s.dropped != v.len() {
                    return Err("n + dropped != len".into());
                }
                if s.n == 0 {
                    return Ok(());
                }
                let eps = 1e-9;
                if !(s.min <= s.median + eps
                    && s.median <= s.p90 + eps
                    && s.p90 <= s.p99 + eps
                    && s.p99 <= s.max + eps)
                {
                    return Err(format!("order chain broken: {s:?}"));
                }
                if s.n == 1
                    && !(s.min == s.max
                        && s.median == s.min
                        && s.p99 == s.min)
                {
                    return Err(format!("single-sample collapse: {s:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn merge_empty_sides_carry_dropped() {
        let a = Summary::from(&[f64::NAN, f64::NAN]);
        let b = Summary::from(&[1.0, 2.0, 3.0, f64::INFINITY]);
        let m = a.merge(&b);
        assert_eq!(m.n, 3);
        assert_eq!(m.dropped, 3);
        assert_eq!(m.min, 1.0);
        assert_eq!(m.max, 3.0);
        // symmetric
        let m2 = b.merge(&a);
        assert_eq!(m2.n, 3);
        assert_eq!(m2.dropped, 3);
        // both empty
        let e = a.merge(&Summary::from(&[]));
        assert_eq!(e.n, 0);
        assert_eq!(e.dropped, 2);
    }

    /// Property: merging two summaries matches the summary of the
    /// concatenated sample sets per the documented contract — exactly
    /// for `n`/`min`/`max`/`dropped`, to fp roundoff for `mean`, with
    /// the median inside the inputs' median bracket of the true value,
    /// and the tail percentiles inside the inputs' own bracket.
    /// Non-finite samples injected on either side land in `dropped`.
    #[test]
    fn prop_merge_matches_concatenation_contract() {
        use crate::util::proptest::check_result;
        check_result(
            47,
            300,
            |r| {
                let gen_side = |r: &mut crate::util::rng::Rng| {
                    let n = r.below(40);
                    let mut v: Vec<f64> =
                        (0..n).map(|_| r.uniform_in(-5.0, 1e3)).collect();
                    for _ in 0..r.below(3) {
                        let x = match r.below(3) {
                            0 => f64::NAN,
                            1 => f64::INFINITY,
                            _ => f64::NEG_INFINITY,
                        };
                        v.insert(r.below(v.len() + 1), x);
                    }
                    v
                };
                let a = gen_side(&mut *r);
                let b = gen_side(&mut *r);
                (a, b)
            },
            |(av, bv)| {
                let a = Summary::from(av);
                let b = Summary::from(bv);
                let m = a.merge(&b);
                let concat: Vec<f64> =
                    av.iter().chain(bv.iter()).copied().collect();
                let c = Summary::from(&concat);
                // exact fields
                if m.n != c.n {
                    return Err(format!("n {} != {}", m.n, c.n));
                }
                if m.dropped != c.dropped {
                    return Err(format!(
                        "dropped {} != {}",
                        m.dropped, c.dropped
                    ));
                }
                if m.n == 0 {
                    return Ok(());
                }
                if m.min != c.min || m.max != c.max {
                    return Err(format!(
                        "extrema ({}, {}) != ({}, {})",
                        m.min, m.max, c.min, c.max
                    ));
                }
                // mean: weighted mean is exact up to roundoff
                let scale = 1.0 + c.mean.abs();
                if (m.mean - c.mean).abs() > 1e-9 * scale {
                    return Err(format!(
                        "mean {} vs {}",
                        m.mean, c.mean
                    ));
                }
                let slack = 1e-9 * (1.0 + c.max.abs());
                if a.n > 0 && b.n > 0 {
                    // median: the concatenation's median lies between
                    // the input medians, so the merge error is bounded
                    // by their spread
                    let spread = (a.median - b.median).abs();
                    if (m.median - c.median).abs() > spread + slack {
                        return Err(format!(
                            "median err {} > spread {spread}",
                            (m.median - c.median).abs()
                        ));
                    }
                    // tails: no concat bracket (documented), but the
                    // weighted average must stay between the inputs
                    for (mv, av_, bv_) in
                        [(m.p90, a.p90, b.p90), (m.p99, a.p99, b.p99)]
                    {
                        let lo = av_.min(bv_);
                        let hi = av_.max(bv_);
                        if mv < lo - slack || mv > hi + slack {
                            return Err(format!(
                                "tail {mv} outside [{lo}, {hi}]"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
