//! Tiny CSV writer (and reader for tests) for experiment outputs under
//! `results/`.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    w: BufWriter<File>,
    n_cols: usize,
}

impl CsvWriter {
    /// Create/truncate `path` (parent dirs included) and write the
    /// header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let f = File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        let mut w = BufWriter::new(f);
        writeln!(w, "{}", header.join(","))?;
        Ok(Self { w, n_cols: header.len() })
    }

    /// Write one row (arity-checked against the header).
    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        anyhow::ensure!(cells.len() == self.n_cols,
                        "row has {} cells, header has {}", cells.len(),
                        self.n_cols);
        writeln!(self.w, "{}", cells.join(","))?;
        Ok(())
    }

    /// Convenience: numeric row.
    pub fn row_f64(&mut self, cells: &[f64]) -> Result<()> {
        self.row(&cells.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }

    /// Flush buffered rows to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Parse a simple (no quoting) CSV back into rows — used by tests.
pub fn read_simple(path: impl AsRef<Path>) -> Result<Vec<Vec<String>>> {
    let text = fs::read_to_string(path)?;
    Ok(text
        .lines()
        .filter(|l| !l.is_empty())
        .map(|l| l.split(',').map(|c| c.to_string()).collect())
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fastvpinns_csv_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn writes_and_reads() {
        let p = tmp("a.csv");
        {
            let mut w = CsvWriter::create(&p, &["x", "y"]).unwrap();
            w.row_f64(&[1.0, 2.5]).unwrap();
            w.row(&["a".into(), "b".into()]).unwrap();
            w.flush().unwrap();
        }
        let rows = read_simple(&p).unwrap();
        assert_eq!(rows[0], vec!["x", "y"]);
        assert_eq!(rows[1], vec!["1", "2.5"]);
        assert_eq!(rows[2], vec!["a", "b"]);
    }

    #[test]
    fn rejects_wrong_arity() {
        let p = tmp("b.csv");
        let mut w = CsvWriter::create(&p, &["x", "y"]).unwrap();
        assert!(w.row_f64(&[1.0]).is_err());
    }
}
