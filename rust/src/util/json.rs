//! Minimal JSON parser/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` is
//! decoded for the BMP). Numbers are f64. Object key order is preserved
//! (artifact manifests are written by python `json.dump` with stable
//! ordering, and round-trip tests rely on it).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// Key order preserved as encountered.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    /// The number, or an error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    /// The non-negative integer, or an error.
    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    /// The string, or an error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    /// The bool, or an error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool, got {self:?}")),
        }
    }

    /// The array elements, or an error.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(anyhow!("expected array, got {self:?}")),
        }
    }

    /// Object field `key`, if present.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field `key`, or a missing-key error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// Convenience: object as a map view.
    pub fn as_map(&self) -> Result<BTreeMap<&str, &Json>> {
        match self {
            Json::Obj(o) => {
                Ok(o.iter().map(|(k, v)| (k.as_str(), v)).collect())
            }
            _ => Err(anyhow!("expected object, got {self:?}")),
        }
    }

    // ---- builders ---------------------------------------------------------

    /// Build an object from (key, value) pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at byte {}", c as char,
                       self.i),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            );
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // Re-walk UTF-8: step back and take the full char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let ch = rest
                        .chars()
                        .next()
                        .ok_or_else(|| anyhow!("unexpected end"))?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_to(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    /// Serialize (compact form) into `out`.
    pub fn write_to(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_to(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Json::Obj(o) = &v {
            let keys: Vec<_> = o.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, vec!["z", "a", "m"]);
        } else {
            panic!();
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"fv","shape":[4,25,400],"f":1.5,"ok":true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("\u{e9}".into())
        );
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(Json::parse("7").unwrap().as_usize().unwrap(), 7);
        assert!(Json::parse("7.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-7").unwrap().as_usize().is_err());
    }

    #[test]
    fn accessor_errors() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert!(v.req("b").is_err());
        assert!(v.req("a").unwrap().as_str().is_err());
    }
}
