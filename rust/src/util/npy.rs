//! NumPy `.npy` v1.0 read/write for f32/f64 arrays (C order).
//!
//! Used for the Rust<->Python assembly cross-validation (`repro
//! dump-tensors` -> pytest) and for persisting trained parameters.

use std::fs::File;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8] = b"\x93NUMPY";

/// Write a C-ordered f64 array.
pub fn write_f64(path: impl AsRef<Path>, data: &[f64], shape: &[usize])
    -> Result<()> {
    write_raw(path, "<f8", shape, bytemuck_f64(data))
}

/// Write a C-ordered f32 array.
pub fn write_f32(path: impl AsRef<Path>, data: &[f32], shape: &[usize])
    -> Result<()> {
    write_raw(path, "<f4", shape, bytemuck_f32(data))
}

fn bytemuck_f64(d: &[f64]) -> Vec<u8> {
    d.iter().flat_map(|v| v.to_le_bytes()).collect()
}

fn bytemuck_f32(d: &[f32]) -> Vec<u8> {
    d.iter().flat_map(|v| v.to_le_bytes()).collect()
}

fn write_raw(path: impl AsRef<Path>, descr: &str, shape: &[usize],
             payload: Vec<u8>) -> Result<()> {
    let n: usize = shape.iter().product::<usize>().max(1);
    let elem = descr[2..].parse::<usize>().unwrap_or(8);
    if payload.len() != n * elem {
        bail!("npy write: payload {} != {}x{}", payload.len(), n, elem);
    }
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|s| s.to_string())
                .collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{descr}', 'fortran_order': False, \
         'shape': {shape_str}, }}"
    );
    // pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64
    let unpadded = MAGIC.len() + 2 + 2 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut f = File::create(path.as_ref())
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    f.write_all(&payload)?;
    Ok(())
}

/// A loaded array: shape + f64 data (f32 sources are widened).
#[derive(Debug, Clone)]
pub struct NpyArray {
    /// Array shape.
    pub shape: Vec<usize>,
    /// Row-major values, widened to f64.
    pub data: Vec<f64>,
    /// original dtype descr, e.g. "<f4"
    pub descr: String,
}

impl NpyArray {
    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the array has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Narrowing f32 copy (runtime boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }
}

/// Read a `.npy` file (supports `<f4`, `<f8`, `<i8`).
pub fn read(path: impl AsRef<Path>) -> Result<NpyArray> {
    let mut buf = Vec::new();
    File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?
        .read_to_end(&mut buf)?;
    if buf.len() < 10 || &buf[..6] != MAGIC {
        bail!("not an npy file: {}", path.as_ref().display());
    }
    let major = buf[6];
    let (hlen, hstart) = match major {
        1 => (u16::from_le_bytes([buf[8], buf[9]]) as usize, 10),
        2 | 3 => (
            u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize,
            12,
        ),
        v => bail!("unsupported npy version {v}"),
    };
    let header = std::str::from_utf8(&buf[hstart..hstart + hlen])?;
    let descr = extract_quoted(header, "descr")?;
    if header.contains("'fortran_order': True") {
        bail!("fortran order unsupported");
    }
    let shape = extract_shape(header)?;
    let n: usize = shape.iter().product::<usize>().max(1);
    let payload = &buf[hstart + hlen..];
    let data: Vec<f64> = match descr.as_str() {
        "<f8" => {
            check_len(payload.len(), n * 8)?;
            payload
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect()
        }
        "<f4" => {
            check_len(payload.len(), n * 4)?;
            payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as f64)
                .collect()
        }
        "<i8" => {
            check_len(payload.len(), n * 8)?;
            payload
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().unwrap()) as f64)
                .collect()
        }
        d => bail!("unsupported dtype {d}"),
    };
    Ok(NpyArray { shape, data, descr })
}

fn check_len(got: usize, want: usize) -> Result<()> {
    if got < want {
        bail!("payload too short: {got} < {want}");
    }
    Ok(())
}

fn extract_quoted(header: &str, key: &str) -> Result<String> {
    let pat = format!("'{key}':");
    let pos = header
        .find(&pat)
        .with_context(|| format!("npy header missing {key}"))?;
    let rest = &header[pos + pat.len()..];
    let q1 = rest.find('\'').context("bad header")?;
    let rest = &rest[q1 + 1..];
    let q2 = rest.find('\'').context("bad header")?;
    Ok(rest[..q2].to_string())
}

fn extract_shape(header: &str) -> Result<Vec<usize>> {
    let pos = header.find("'shape':").context("npy header missing shape")?;
    let rest = &header[pos + 8..];
    let open = rest.find('(').context("bad shape")?;
    let close = rest.find(')').context("bad shape")?;
    let inner = &rest[open + 1..close];
    let mut out = Vec::new();
    for tok in inner.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(tok.parse::<usize>()?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fastvpinns_npy_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_f64_2d() {
        let p = tmp("a.npy");
        let data: Vec<f64> = (0..12).map(|i| i as f64 * 0.5).collect();
        write_f64(&p, &data, &[3, 4]).unwrap();
        let arr = read(&p).unwrap();
        assert_eq!(arr.shape, vec![3, 4]);
        assert_eq!(arr.data, data);
        assert_eq!(arr.descr, "<f8");
    }

    #[test]
    fn roundtrip_f32_3d() {
        let p = tmp("b.npy");
        let data: Vec<f32> = (0..24).map(|i| i as f32).collect();
        write_f32(&p, &data, &[2, 3, 4]).unwrap();
        let arr = read(&p).unwrap();
        assert_eq!(arr.shape, vec![2, 3, 4]);
        assert_eq!(arr.to_f32(), data);
    }

    #[test]
    fn roundtrip_scalar() {
        let p = tmp("c.npy");
        write_f64(&p, &[3.25], &[]).unwrap();
        let arr = read(&p).unwrap();
        assert!(arr.shape.is_empty());
        assert_eq!(arr.data, vec![3.25]);
    }

    #[test]
    fn roundtrip_1d() {
        let p = tmp("d.npy");
        write_f64(&p, &[1.0, 2.0], &[2]).unwrap();
        let arr = read(&p).unwrap();
        assert_eq!(arr.shape, vec![2]);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("e.npy");
        std::fs::write(&p, b"not an npy").unwrap();
        assert!(read(&p).is_err());
    }

    #[test]
    fn python_numpy_can_read_back() {
        // header format sanity: 64-byte aligned, v1.0
        let p = tmp("f.npy");
        write_f32(&p, &[1.0, 2.0, 3.0], &[3]).unwrap();
        let buf = std::fs::read(&p).unwrap();
        assert_eq!(&buf[..6], MAGIC);
        let hlen = u16::from_le_bytes([buf[8], buf[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
    }
}
