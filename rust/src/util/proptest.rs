//! Tiny property-test driver (the proptest crate is unavailable offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` against `cases` random
//! inputs from `gen`; on failure it reports the case index and a Debug
//! dump of the input, so failures are reproducible from the fixed seed.

use super::rng::Rng;

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with context on
/// the first failing case.
pub fn check<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed on case {i}/{cases} (seed {seed}):\n\
                 input = {input:#?}"
            );
        }
    }
}

/// Like `check`, but the property returns Result so failures carry a
/// message.
pub fn check_result<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {i}/{cases} (seed {seed}): {msg}\n\
                 input = {input:#?}"
            );
        }
    }
}

/// Shared random-geometry generators for the mesh/FEM property tests,
/// so `fem::bilinear` and `mesh::gmsh` draw inputs from one vocabulary.
pub mod geom {
    use crate::util::rng::Rng;

    /// A randomized convex CCW quadrilateral: unit-square corners
    /// jittered by up to `amp`, re-drawn until strictly convex (all
    /// four corner cross products positive). `amp <= 0.25` converges
    /// in a couple of draws.
    pub fn convex_quad(r: &mut Rng, amp: f64) -> [[f64; 2]; 4] {
        loop {
            let mut q = [[0.0, 0.0], [1.0, 0.0], [1.0, 1.0], [0.0, 1.0]];
            for v in &mut q {
                v[0] += r.uniform_in(-amp, amp);
                v[1] += r.uniform_in(-amp, amp);
            }
            if is_strictly_convex(&q) {
                return q;
            }
        }
    }

    /// A randomized non-degenerate CCW parallelogram (an *affine*
    /// bilinear map: p2 = p1 + p3 - p0).
    pub fn parallelogram(r: &mut Rng) -> [[f64; 2]; 4] {
        loop {
            let p0 = [r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0)];
            let e1 = [r.uniform_in(0.2, 2.0), r.uniform_in(-0.5, 0.5)];
            let e2 = [r.uniform_in(-0.5, 0.5), r.uniform_in(0.2, 2.0)];
            let cross = e1[0] * e2[1] - e1[1] * e2[0];
            if cross > 0.05 {
                return [
                    p0,
                    [p0[0] + e1[0], p0[1] + e1[1]],
                    [p0[0] + e1[0] + e2[0], p0[1] + e1[1] + e2[1]],
                    [p0[0] + e2[0], p0[1] + e2[1]],
                ];
            }
        }
    }

    fn is_strictly_convex(q: &[[f64; 2]; 4]) -> bool {
        (0..4).all(|i| {
            let a = q[i];
            let b = q[(i + 1) % 4];
            let c = q[(i + 2) % 4];
            (b[0] - a[0]) * (c[1] - b[1]) - (b[1] - a[1]) * (c[0] - b[0])
                > 1e-3
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_quads_satisfy_their_invariants() {
        check(7, 200, |r| geom::convex_quad(r, 0.25), |q| {
            // CCW shoelace area positive
            let a2: f64 = (0..4)
                .map(|i| {
                    let p = q[i];
                    let n = q[(i + 1) % 4];
                    p[0] * n[1] - n[0] * p[1]
                })
                .sum();
            a2 > 0.0
        });
        check(8, 200, |r| geom::parallelogram(r), |q| {
            // opposite edges equal: p2 - p1 == p3 - p0
            ((q[2][0] - q[1][0]) - (q[3][0] - q[0][0])).abs() < 1e-12
                && ((q[2][1] - q[1][1]) - (q[3][1] - q[0][1])).abs()
                    < 1e-12
        });
    }

    #[test]
    fn passes_trivial_property() {
        check(1, 100, |r| r.uniform(), |&u| (0.0..1.0).contains(&u));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_loudly() {
        check(2, 100, |r| r.uniform(), |&u| u < 0.5);
    }

    #[test]
    fn result_variant() {
        check_result(
            3,
            50,
            |r| (r.uniform(), r.uniform()),
            |&(a, b)| {
                if a + b < 2.0 {
                    Ok(())
                } else {
                    Err("sum too large".into())
                }
            },
        );
    }
}
