//! Tiny property-test driver (the proptest crate is unavailable offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` against `cases` random
//! inputs from `gen`; on failure it reports the case index and a Debug
//! dump of the input, so failures are reproducible from the fixed seed.

use super::rng::Rng;

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with context on
/// the first failing case.
pub fn check<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed on case {i}/{cases} (seed {seed}):\n\
                 input = {input:#?}"
            );
        }
    }
}

/// Like `check`, but the property returns Result so failures carry a
/// message.
pub fn check_result<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {i}/{cases} (seed {seed}): {msg}\n\
                 input = {input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(1, 100, |r| r.uniform(), |&u| (0.0..1.0).contains(&u));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_loudly() {
        check(2, 100, |r| r.uniform(), |&u| u < 0.5);
    }

    #[test]
    fn result_variant() {
        check_result(
            3,
            50,
            |r| (r.uniform(), r.uniform()),
            |&(a, b)| {
                if a + b < 2.0 {
                    Ok(())
                } else {
                    Err("sum too large".into())
                }
            },
        );
    }
}
