//! Deterministic RNG (xorshift64*) + parameter initialisation.
//!
//! The `rand` crate is unavailable offline; training reproducibility only
//! needs a seedable generator with decent equidistribution, which
//! xorshift64* provides.

/// xorshift64* PRNG. Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator (any seed, incl. 0, is valid).
    pub fn new(seed: u64) -> Self {
        // splitmix64-style scramble so nearby seeds diverge immediately,
        // and avoid the all-zero fixed point
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        Self { state: (z ^ (z >> 31)) | 1 }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Glorot/Xavier-uniform init for a (n_in, n_out) weight matrix,
    /// row-major — matches the distribution PINN codes typically use.
    pub fn glorot(&mut self, n_in: usize, n_out: usize) -> Vec<f32> {
        let limit = (6.0 / (n_in + n_out) as f64).sqrt();
        (0..n_in * n_out)
            .map(|_| self.uniform_in(-limit, limit) as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn glorot_bounds() {
        let mut r = Rng::new(5);
        let w = r.glorot(30, 30);
        let lim = (6.0f64 / 60.0).sqrt() as f32;
        assert_eq!(w.len(), 900);
        assert!(w.iter().all(|&x| x.abs() <= lim));
        // not degenerate
        let mx = w.iter().cloned().fold(f32::MIN, f32::max);
        assert!(mx > 0.5 * lim);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
