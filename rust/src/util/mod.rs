//! Offline substrates: JSON, npy I/O, CSV, CLI parsing, RNG,
//! statistics and a small property-test driver.
//!
//! The offline crate registry lacks serde/clap/criterion/rand/proptest,
//! so this module provides the minimal, well-tested equivalents the rest
//! of the crate builds on (DESIGN.md SS3).

pub mod cli;
pub mod csv;
pub mod json;
pub mod npy;
pub mod proptest;
pub mod rng;
pub mod stats;
