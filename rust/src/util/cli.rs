//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `repro <subcommand> [--flag value]... [--bool-flag]...`
//! Flags may be given as `--key value` or `--key=value`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare token (e.g. `train`).
    pub subcommand: String,
    /// Bare tokens after the subcommand.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.bools.push(stripped.to_string());
                }
            } else if out.subcommand.is_empty() {
                out.subcommand = tok;
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// The value of `--key value`, if given.
    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Whether `--key` was given (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    /// String flag with a default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }

    /// Required string flag (errors when missing).
    pub fn req_str(&self, key: &str) -> Result<String> {
        self.flag(key)
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    /// Integer flag with a default (errors on non-integers).
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v}")),
        }
    }

    /// Float flag with a default (errors on non-numbers).
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v}")),
        }
    }

    /// Insert (or overwrite) a `--key value` flag.
    pub fn set(&mut self, key: &str, value: &str) {
        self.flags.insert(key.to_string(), value.to_string());
    }

    /// All `--key value` flags as owned pairs (sorted by key) — what
    /// checkpoints persist so `--resume` can rebuild the invocation.
    pub fn flag_pairs(&self) -> Vec<(String, String)> {
        self.flags
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// A copy of these args with `defaults` filled in underneath: any
    /// key already given (as a flag or a bool) wins over its default.
    /// This is how `--resume` merges a checkpoint's persisted flags
    /// with overrides from the current command line.
    pub fn with_defaults(&self, defaults: &[(String, String)]) -> Args {
        let mut out = self.clone();
        for (k, v) in defaults {
            if !out.has(k) {
                out.flags.insert(k.clone(), v.clone());
            }
        }
        out
    }

    /// Error out on unknown flags — catches typos early.
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys().chain(self.bools.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --artifact fv_x --iters 100 --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.flag("artifact"), Some("fv_x"));
        assert_eq!(a.usize_or("iters", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn eq_form() {
        let a = parse("run --lr=0.001 --name=x");
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.001);
        assert_eq!(a.flag("name"), Some("x"));
    }

    #[test]
    fn positional() {
        let a = parse("experiment fig10 fig11");
        assert_eq!(a.positional, vec!["fig10", "fig11"]);
    }

    #[test]
    fn defaults_and_requirements() {
        let a = parse("train");
        assert_eq!(a.usize_or("iters", 7).unwrap(), 7);
        assert!(a.req_str("artifact").is_err());
    }

    #[test]
    fn bad_number() {
        let a = parse("train --iters abc");
        assert!(a.usize_or("iters", 0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("train --iterz 5");
        assert!(a.check_known(&["iters"]).is_err());
        assert!(a.check_known(&["iterz"]).is_ok());
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse("train --force");
        assert!(a.has("force"));
    }

    #[test]
    fn defaults_merge_under_given_flags() {
        let a = parse("train --n 8 --quiet");
        let merged = a.with_defaults(&[
            ("n".into(), "2".into()),
            ("k-pi".into(), "4".into()),
            ("quiet".into(), "x".into()),
        ]);
        assert_eq!(merged.flag("n"), Some("8"), "given flag wins");
        assert_eq!(merged.flag("k-pi"), Some("4"), "default fills in");
        assert!(merged.has("quiet"));
        assert!(merged.flag("quiet").is_none(), "bool blocks the default");
        let pairs = merged.flag_pairs();
        assert!(pairs.contains(&("k-pi".into(), "4".into())));
    }

    #[test]
    fn set_inserts_and_overwrites() {
        let mut a = parse("infer");
        a.set("ckpt", "out.ckpt");
        assert_eq!(a.flag("ckpt"), Some("out.ckpt"));
        a.set("ckpt", "b.ckpt");
        assert_eq!(a.flag("ckpt"), Some("b.ckpt"));
    }
}
