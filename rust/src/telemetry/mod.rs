//! The observability plane: a lock-light structured event stream for
//! training and serving, written as newline-delimited JSON.
//!
//! ## Design
//!
//! Instrumentation sites all over the stack (the coordinator's step
//! loop, the native backend's tick phases, the checkpoint writer, the
//! SIMD dispatch latch, the serve micro-batcher) call [`emit`] with an
//! [`Event`]. When nothing is armed — the default — every one of those
//! calls is **one relaxed atomic load and a branch**, the same
//! discipline as [`crate::runtime::failpoint`]: no lock, no clock
//! read, no allocation rides the hot path of a run that did not ask
//! for metrics.
//!
//! Armed (CLI: `--metrics-out FILE`), [`emit`] stamps a monotonic
//! timestamp and hands the event to a **bounded channel** feeding one
//! dedicated writer thread. The producer side never blocks: a full
//! channel drops the event and counts it (the final `flush` line
//! reports the total), because a slow disk must never stall a training
//! step. The writer serializes each event to a single JSON line and
//! writes it with one `write_all` call — **line-atomic**: a line is
//! one small write(2) to a regular file, so a crash (even the
//! `checkpoint.write.kill` failpoint's `exit(137)`) can kill the
//! stream between lines but not tear one in half. On clean
//! [`shutdown`] the writer appends a `flush` event and fsyncs.
//!
//! ## Zero-perturbation guarantee
//!
//! Telemetry is observation-only. It reads losses, gradients and
//! clocks; it never touches parameters, RNG state, iteration order or
//! the reduction tree. Per-step losses and the final u-hash of a run
//! with `--metrics-out` are **bit-identical** to the same run without
//! it — `rust/tests/telemetry_e2e.rs` proves this, and the `repro
//! bench` telemetry-overhead guard keeps the armed wall-clock cost
//! within 2% of the disarmed step.
//!
//! ## Schema (version 1)
//!
//! Every line is one JSON object with `"v"` ([`SCHEMA_VERSION`]),
//! `"ev"` (the event type) and `"t_ms"` (monotonic milliseconds since
//! arming). Adding fields is backward-compatible; removing or
//! renaming one, or changing a type, bumps `SCHEMA_VERSION`. The
//! catalog (authoritative; `python/proto_telemetry_check.py` is the
//! second, independent implementation):
//!
//! | `ev` | fields | emitted by |
//! |------|--------|------------|
//! | `step` | `step`, `wall_ms`, `assign_ms`/`step_ms`/`reduce_ms`/`sync_ms` (number or null), `loss` (number or null), `grad_norm` (number or null), `lr` | the trainer, once per optimizer step |
//! | `recovery` | `at_step`, `rollback_to`, `reason`, `lr_scale` | the trainer's rollback path |
//! | `checkpoint` | `step`, `path`, `bytes`, `write_ms` | [`Checkpoint::write`](crate::runtime::checkpoint::Checkpoint::write) |
//! | `kernel` | `kernel`, `degraded`, `reason` | arming (the selected kernel) and the dispatch degrade latch |
//! | `queue` | `queued`, `hwm` | a serve worker claiming a micro-batch |
//! | `batch` | `len`, `max` | a serve worker claiming a micro-batch |
//! | `flush` | `dropped` | [`shutdown`] — always the last line of a cleanly closed stream |
//!
//! Phase times are null when the step's backend published none (the
//! XLA executor, or a step raced past arming); `loss`/`grad_norm` are
//! null when non-finite (a poisoned step under `grad.nan` appears in
//! the stream with `loss: null`, immediately before its `recovery`
//! event — JSON has no NaN, and the chaos tier asserts exactly this
//! interleaving).

use std::fs::File;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

/// Version stamped into every emitted line as `"v"`. Bumped when a
/// field is removed, renamed or retyped (additions are compatible).
pub const SCHEMA_VERSION: u32 = 1;

/// Bounded channel capacity between emitters and the writer thread.
/// Full means the disk cannot keep up; events are dropped and counted
/// rather than ever blocking a training step.
const CHANNEL_DEPTH: usize = 4096;

/// One structured telemetry event (serialized as a single JSON line —
/// see the module-level schema table).
#[derive(Debug, Clone)]
pub enum Event {
    /// One optimizer step: wall time, the four coordinator tick phases
    /// (when the backend published them), and the scalars the step
    /// produced.
    StepStats {
        /// 1-based optimizer step id.
        step: u64,
        /// Whole-step wall time (ms) as the trainer saw it.
        wall_ms: f64,
        /// Per-phase wall times `[assign, step, reduce, sync]` (ms)
        /// from the native backend's tick; `None` when unavailable.
        phases_ms: Option<[f64; 4]>,
        /// Step loss (serialized null when non-finite).
        loss: f64,
        /// Gradient L2 norm (serialized null when non-finite).
        grad_norm: f64,
        /// Effective learning rate (schedule x recovery backoff).
        lr: f64,
    },
    /// The trainer rolled back to a snapshot (divergence healing).
    Recovery {
        /// Step the divergence was detected at.
        at_step: u64,
        /// Snapshot step the trainer rolled back to.
        rollback_to: u64,
        /// Human-readable divergence reason.
        reason: String,
        /// Learning-rate backoff scale after this rollback.
        lr_scale: f64,
    },
    /// A checkpoint artifact was written successfully.
    CheckpointWrite {
        /// Step count stored in the artifact.
        step: u64,
        /// Destination path.
        path: String,
        /// Serialized artifact size in bytes.
        bytes: u64,
        /// Wall time of the atomic write (ms).
        write_ms: f64,
    },
    /// Kernel dispatch state: emitted once at arming with the selected
    /// kernel, and again if the degrade latch trips.
    KernelDispatch {
        /// Active kernel name (`avx2_4x12` / `scalar_4x8`).
        kernel: &'static str,
        /// Whether dispatch has degraded to the scalar fallback.
        degraded: bool,
        /// Why this event fired ("arm", or the degrade reason).
        reason: String,
    },
    /// Serve-plane queue pressure, sampled when a worker claims a
    /// micro-batch.
    QueueSample {
        /// Jobs waiting in pool queues right now.
        queued: u64,
        /// Queue-depth high-water mark so far.
        hwm: u64,
    },
    /// One coalesced serve micro-batch was claimed for evaluation.
    BatchFlush {
        /// Requests coalesced into the batch.
        len: u64,
        /// The policy's `max_batch` (fill ratio = len/max).
        max: u64,
    },
}

/// A finite number, or JSON null — `Json::Num(NaN)` would serialize as
/// the invalid token `NaN`, and a poisoned step's loss must still
/// produce a parseable line.
fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::num(v)
    } else {
        Json::Null
    }
}

impl Event {
    /// The `"ev"` tag this event serializes under.
    pub fn tag(&self) -> &'static str {
        match self {
            Event::StepStats { .. } => "step",
            Event::Recovery { .. } => "recovery",
            Event::CheckpointWrite { .. } => "checkpoint",
            Event::KernelDispatch { .. } => "kernel",
            Event::QueueSample { .. } => "queue",
            Event::BatchFlush { .. } => "batch",
        }
    }

    /// Serialize to one JSON line (no trailing newline).
    fn to_json(&self, t_ms: f64) -> Json {
        let mut fields = vec![
            ("v", Json::num(SCHEMA_VERSION as f64)),
            ("ev", Json::str(self.tag())),
            ("t_ms", Json::num(t_ms)),
        ];
        match self {
            Event::StepStats {
                step, wall_ms, phases_ms, loss, grad_norm, lr,
            } => {
                fields.push(("step", Json::num(*step as f64)));
                fields.push(("wall_ms", Json::num(*wall_ms)));
                let p = |i: usize| match phases_ms {
                    Some(ms) => Json::num(ms[i]),
                    None => Json::Null,
                };
                fields.push(("assign_ms", p(0)));
                fields.push(("step_ms", p(1)));
                fields.push(("reduce_ms", p(2)));
                fields.push(("sync_ms", p(3)));
                fields.push(("loss", num_or_null(*loss)));
                fields.push(("grad_norm", num_or_null(*grad_norm)));
                fields.push(("lr", Json::num(*lr)));
            }
            Event::Recovery { at_step, rollback_to, reason, lr_scale } => {
                fields.push(("at_step", Json::num(*at_step as f64)));
                fields.push((
                    "rollback_to",
                    Json::num(*rollback_to as f64),
                ));
                fields.push(("reason", Json::str(reason.clone())));
                fields.push(("lr_scale", Json::num(*lr_scale)));
            }
            Event::CheckpointWrite { step, path, bytes, write_ms } => {
                fields.push(("step", Json::num(*step as f64)));
                fields.push(("path", Json::str(path.clone())));
                fields.push(("bytes", Json::num(*bytes as f64)));
                fields.push(("write_ms", Json::num(*write_ms)));
            }
            Event::KernelDispatch { kernel, degraded, reason } => {
                fields.push(("kernel", Json::str(*kernel)));
                fields.push(("degraded", Json::Bool(*degraded)));
                fields.push(("reason", Json::str(reason.clone())));
            }
            Event::QueueSample { queued, hwm } => {
                fields.push(("queued", Json::num(*queued as f64)));
                fields.push(("hwm", Json::num(*hwm as f64)));
            }
            Event::BatchFlush { len, max } => {
                fields.push(("len", Json::num(*len as f64)));
                fields.push(("max", Json::num(*max as f64)));
            }
        }
        Json::obj(fields)
    }
}

enum Msg {
    Event(Event, f64),
    /// Clean shutdown: write the flush line (with the final dropped
    /// count), fsync, exit.
    Flush(u64),
}

struct Sink {
    tx: SyncSender<Msg>,
    t0: Instant,
    writer: Option<std::thread::JoinHandle<()>>,
}

/// The disarmed fast path: one relaxed load, same as
/// `failpoint::ARMED`.
static ARMED: AtomicBool = AtomicBool::new(false);
/// Events dropped because the writer channel was full.
static DROPPED: AtomicU64 = AtomicU64::new(0);

fn sink() -> &'static Mutex<Option<Sink>> {
    static SINK: OnceLock<Mutex<Option<Sink>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(None))
}

fn lock_sink() -> std::sync::MutexGuard<'static, Option<Sink>> {
    sink().lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Whether a metrics stream is armed (one relaxed load — the disarmed
/// fast path of every instrumentation site).
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Arm the telemetry stream: create/truncate `path`, start the writer
/// thread, and start the monotonic `t_ms` clock. Emits an initial
/// [`Event::KernelDispatch`] recording the selected kernel. Errors if
/// already armed (one stream per process) or the file cannot be
/// created.
pub fn arm(path: impl AsRef<std::path::Path>) -> Result<()> {
    let path = path.as_ref();
    let mut guard = lock_sink();
    ensure!(
        guard.is_none(),
        "telemetry is already armed (one --metrics-out per process)"
    );
    let file = File::create(path).with_context(|| {
        format!("create metrics file {}", path.display())
    })?;
    let (tx, rx) = sync_channel::<Msg>(CHANNEL_DEPTH);
    let writer = std::thread::Builder::new()
        .name("telemetry-writer".into())
        .spawn(move || writer_loop(file, rx))
        .context("spawn telemetry writer thread")?;
    *guard = Some(Sink { tx, t0: Instant::now(), writer: Some(writer) });
    DROPPED.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    drop(guard);
    emit(Event::KernelDispatch {
        kernel: crate::linalg::simd::kernel_name(),
        degraded: crate::linalg::simd::degraded(),
        reason: "arm".to_string(),
    });
    Ok(())
}

/// Record an event. Disarmed: one relaxed atomic load. Armed: stamp
/// the monotonic timestamp and `try_send` to the writer — never
/// blocks; a full channel drops the event and counts it in the final
/// `flush` line.
pub fn emit(ev: Event) {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    let guard = lock_sink();
    if let Some(s) = guard.as_ref() {
        let t_ms = s.t0.elapsed().as_secs_f64() * 1e3;
        match s.tx.try_send(Msg::Event(ev, t_ms)) {
            Ok(()) => {}
            Err(TrySendError::Full(_))
            | Err(TrySendError::Disconnected(_)) => {
                DROPPED.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Disarm and close the stream: the writer drains the channel, appends
/// the `flush` line with the dropped-event count, fsyncs and exits.
/// Idempotent — a no-op when nothing is armed, so the CLI calls it
/// unconditionally on the way out.
pub fn shutdown() {
    ARMED.store(false, Ordering::SeqCst);
    let s = lock_sink().take();
    if let Some(Sink { tx, writer, .. }) = s {
        let _ = tx.send(Msg::Flush(DROPPED.load(Ordering::SeqCst)));
        drop(tx);
        if let Some(h) = writer {
            let _ = h.join();
        }
    }
}

fn writer_loop(mut file: File, rx: Receiver<Msg>) {
    let mut line = String::with_capacity(256);
    while let Ok(msg) = rx.recv() {
        line.clear();
        let done = match msg {
            Msg::Event(ev, t_ms) => {
                line.push_str(&ev.to_json(t_ms).to_string());
                false
            }
            Msg::Flush(dropped) => {
                line.push_str(
                    &Json::obj(vec![
                        ("v", Json::num(SCHEMA_VERSION as f64)),
                        ("ev", Json::str("flush")),
                        ("dropped", Json::num(dropped as f64)),
                    ])
                    .to_string(),
                );
                true
            }
        };
        line.push('\n');
        // one write_all per complete line — the line-atomicity
        // contract: a crash lands between lines, never inside one
        if file.write_all(line.as_bytes()).is_err() {
            break; // disk gone; drain silently, nothing else to do
        }
        if done {
            break;
        }
    }
    let _ = file.sync_all();
}

// ---------------------------------------------------------------- phases

/// Handoff slot for the native backend's per-tick phase times: the
/// backend finishes a [`PhaseClock`] inside `compute_loss_grad`, the
/// trainer collects it via [`take_phase_ms`] when emitting the step's
/// [`Event::StepStats`]. A Mutex<Option<...>> (not part of the Event
/// channel) so the `Backend` trait does not change.
fn phase_slot() -> &'static Mutex<Option<[f64; 4]>> {
    static SLOT: OnceLock<Mutex<Option<[f64; 4]>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Monotonic per-phase timer for one coordinator tick. Disarmed, it is
/// inert: [`PhaseClock::start`] takes the one relaxed load, and every
/// other method is a branch on a plain `Option` — no clock reads.
#[derive(Debug)]
pub struct PhaseClock {
    t: Option<Instant>,
    ms: [f64; 4],
}

impl PhaseClock {
    /// Start timing a tick (inert when telemetry is disarmed).
    pub fn start() -> PhaseClock {
        let t = if armed() { Some(Instant::now()) } else { None };
        PhaseClock { t, ms: [0.0; 4] }
    }

    /// Close phase `idx` (0=AssignShards, 1=Step, 2=Reduce, 3=Sync):
    /// records the time since the previous mark (or start) and begins
    /// the next phase.
    pub fn mark(&mut self, idx: usize) {
        if let Some(t0) = self.t {
            let now = Instant::now();
            if let Some(slot) = self.ms.get_mut(idx) {
                *slot = now.duration_since(t0).as_secs_f64() * 1e3;
            }
            self.t = Some(now);
        }
    }

    /// Publish the four phase times to the trainer's pickup slot.
    pub fn finish(self) {
        if self.t.is_some() {
            *phase_slot()
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner) =
                Some(self.ms);
        }
    }
}

/// Collect (and clear) the phase times the backend published for the
/// step that just ran. `None` when the backend has no tick
/// instrumentation (XLA) or telemetry was disarmed during the step.
pub fn take_phase_ms() -> Option<[f64; 4]> {
    if !armed() {
        return None;
    }
    phase_slot()
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .take()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    // One sequential test owning the process-global sink end to end
    // (the suite runs tests in parallel, and a second arming test
    // would race this one through ARMED) — the failpoint module's
    // test discipline.
    #[test]
    fn arm_emit_shutdown_roundtrip_and_disarmed_noop() {
        // disarmed: emit is a no-op, the clock stays inert
        assert!(!armed());
        emit(Event::QueueSample { queued: 1, hwm: 1 });
        let mut pc = PhaseClock::start();
        pc.mark(0);
        pc.finish();
        assert_eq!(take_phase_ms(), None);

        let path = std::env::temp_dir().join(format!(
            "fastvpinns_telemetry_unit_{}.jsonl",
            std::process::id()
        ));
        arm(&path).unwrap();
        assert!(armed());
        // double-arm is rejected, and the failed arm does not disarm
        assert!(arm(&path).is_err());
        assert!(armed());

        emit(Event::StepStats {
            step: 1,
            wall_ms: 1.5,
            phases_ms: Some([0.1, 1.0, 0.2, 0.2]),
            loss: 0.5,
            grad_norm: f64::NAN, // must serialize as null, not NaN
            lr: 1e-3,
        });
        emit(Event::Recovery {
            at_step: 500,
            rollback_to: 450,
            reason: "non-finite loss NaN".into(),
            lr_scale: 0.5,
        });
        emit(Event::CheckpointWrite {
            step: 100,
            path: "out.ckpt".into(),
            bytes: 1234,
            write_ms: 0.7,
        });
        emit(Event::BatchFlush { len: 3, max: 8 });

        // armed phase clock publishes to the pickup slot
        let mut pc = PhaseClock::start();
        pc.mark(0);
        pc.mark(1);
        pc.mark(2);
        pc.mark(3);
        pc.finish();
        let phases = take_phase_ms().unwrap();
        assert!(phases.iter().all(|p| p.is_finite() && *p >= 0.0));
        assert_eq!(take_phase_ms(), None, "take clears the slot");

        shutdown();
        assert!(!armed());
        shutdown(); // idempotent

        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'), "stream ends with a newline");
        let parsed: Vec<Json> = text
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        // arm's kernel line + 4 events + flush
        assert_eq!(parsed.len(), 6);
        let tags: Vec<&str> = parsed
            .iter()
            .map(|j| j.req("ev").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(
            tags,
            ["kernel", "step", "recovery", "checkpoint", "batch",
             "flush"]
        );
        for j in &parsed {
            assert_eq!(
                j.req("v").unwrap().as_usize().unwrap(),
                SCHEMA_VERSION as usize
            );
        }
        // the NaN grad norm landed as null (valid JSON), the finite
        // loss as a number
        let step = &parsed[1];
        assert!(matches!(step.req("grad_norm").unwrap(), Json::Null));
        assert_eq!(step.req("loss").unwrap().as_f64().unwrap(), 0.5);
        assert!(step.req("t_ms").unwrap().as_f64().unwrap() >= 0.0);
        // timestamps are monotone non-decreasing
        let times: Vec<f64> = parsed[..5]
            .iter()
            .map(|j| j.req("t_ms").unwrap().as_f64().unwrap())
            .collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "{times:?}");
        // clean shutdown reports zero dropped events
        assert_eq!(
            parsed[5].req("dropped").unwrap().as_usize().unwrap(),
            0
        );
        let _ = std::fs::remove_file(&path);
    }
}
