//! Classical Q1 mapped-FEM reference solver for
//! `-div(eps(x) grad u) + b(x) . grad u + c(x) u = f` with Dirichlet
//! BCs — variable diffusion, variable convection and a reaction (mass)
//! term, mirroring the coefficient fields of the
//! [`VariationalForm`](crate::runtime::backend::VariationalForm) layer
//! so every `Problem` the backends train can be cross-validated
//! against an independent discretization ([`solve_problem`]).
//!
//! Plays the role ParMooN plays in the paper: reference solutions for the
//! gear (Fig. 12) and disk-inverse (Fig. 15) experiments, and the FEM
//! side of Table 1 (solve time vs NN prediction time).

use anyhow::{ensure, Result};

use crate::fem::bilinear::BilinearMap;
use crate::fem::quadrature::{self, QuadKind};
use crate::linalg::{bicgstab_solve, cg_solve, CgOptions, CsrMatrix,
                    Triplets};
use crate::mesh::QuadMesh;
use crate::problems::Problem;

/// Variable-coefficient problem definition
/// `-div(eps grad u) + b . grad u + c u = f`, Dirichlet data `g`.
pub struct FemProblem<'a> {
    /// Diffusion coefficient field.
    pub eps: &'a dyn Fn(f64, f64) -> f64,
    /// Convection field; `None` means `b == 0` (keeps the system
    /// symmetric so CG applies).
    pub b: Option<&'a dyn Fn(f64, f64) -> (f64, f64)>,
    /// Reaction coefficient field; `None` means `c == 0`. A negative
    /// `c` (Helmholtz, `c = -k^2`) makes the system indefinite — the
    /// solver switches to BiCGStab.
    pub c: Option<&'a dyn Fn(f64, f64) -> f64>,
    /// Source term.
    pub f: &'a dyn Fn(f64, f64) -> f64,
    /// Dirichlet boundary data.
    pub g: &'a dyn Fn(f64, f64) -> f64,
}

/// Q1 shape functions on the reference square, vertex order matching
/// the mesh/bilinear contract: (-1,-1), (1,-1), (1,1), (-1,1).
fn q1_shape(xi: f64, eta: f64) -> [f64; 4] {
    [
        0.25 * (1.0 - xi) * (1.0 - eta),
        0.25 * (1.0 + xi) * (1.0 - eta),
        0.25 * (1.0 + xi) * (1.0 + eta),
        0.25 * (1.0 - xi) * (1.0 + eta),
    ]
}

fn q1_grad(xi: f64, eta: f64) -> [[f64; 2]; 4] {
    [
        [-0.25 * (1.0 - eta), -0.25 * (1.0 - xi)],
        [0.25 * (1.0 - eta), -0.25 * (1.0 + xi)],
        [0.25 * (1.0 + eta), 0.25 * (1.0 + xi)],
        [-0.25 * (1.0 + eta), 0.25 * (1.0 - xi)],
    ]
}

/// A solved FEM field on a quad mesh (nodal values) with point
/// evaluation via a cell spatial index.
pub struct FemSolution {
    /// The mesh the field lives on.
    pub mesh: QuadMesh,
    /// Nodal solution values.
    pub u: Vec<f64>,
    /// Linear-solver iterations used.
    pub solve_iterations: usize,
    /// Linear-solve wall clock.
    pub solve_seconds: f64,
    index: CellIndex,
}

impl FemSolution {
    /// Evaluate the field at (x, y); None if outside the mesh.
    pub fn eval(&self, x: f64, y: f64) -> Option<f64> {
        let e = self.index.locate(&self.mesh, x, y)?;
        let bm = BilinearMap::new(&self.mesh.cell_vertices(e));
        let r = bm.inverse_map(x, y)?;
        let n = q1_shape(r[0], r[1]);
        let c = self.mesh.cells[e];
        Some((0..4).map(|k| n[k] * self.u[c[k]]).sum())
    }

    /// Nodal values as f64 slice.
    pub fn nodal(&self) -> &[f64] {
        &self.u
    }
}

/// Solve the problem on `mesh`. Uses CG when b == 0 (SPD), BiCGStab
/// otherwise.
pub fn solve(mesh: &QuadMesh, p: &FemProblem, nq1d: usize)
    -> Result<FemSolution> {
    let t0 = std::time::Instant::now();
    let n = mesh.n_points();
    ensure!(n > 0, "empty mesh");
    let rule = quadrature::tensor_rule_2d(nq1d, QuadKind::GaussLegendre);

    // boundary nodes
    let mut is_bd = vec![false; n];
    for e in &mesh.boundary {
        is_bd[e.a] = true;
        is_bd[e.b] = true;
    }
    // free-node numbering
    let mut free_id = vec![usize::MAX; n];
    let mut n_free = 0;
    for i in 0..n {
        if !is_bd[i] {
            free_id[i] = n_free;
            n_free += 1;
        }
    }
    // Dirichlet values
    let gvals: Vec<f64> = (0..n)
        .map(|i| {
            if is_bd[i] {
                (p.g)(mesh.points[i][0], mesh.points[i][1])
            } else {
                0.0
            }
        })
        .collect();

    let mut trip = Triplets::new(n_free, n_free);
    let mut rhs = vec![0.0; n_free];

    for e in 0..mesh.n_cells() {
        let verts = mesh.cell_vertices(e);
        let bm = BilinearMap::new(&verts);
        let c = mesh.cells[e];
        let mut ke = [[0.0f64; 4]; 4];
        let mut fe = [0.0f64; 4];
        for q in 0..rule.w.len() {
            let (xi, eta, wq) = (rule.xi[q], rule.eta[q], rule.w[q]);
            let j = bm.jacobian(xi, eta);
            let adet = j.det.abs();
            let pxy = bm.map(xi, eta);
            let epsq = (p.eps)(pxy[0], pxy[1]);
            let (bxq, byq) = match p.b {
                Some(b) => b(pxy[0], pxy[1]),
                None => (0.0, 0.0),
            };
            let cq = p.c.map(|c| c(pxy[0], pxy[1])).unwrap_or(0.0);
            let fq = (p.f)(pxy[0], pxy[1]);
            let shp = q1_shape(xi, eta);
            let gref = q1_grad(xi, eta);
            // actual-domain gradients of the 4 shape functions
            let mut gact = [[0.0f64; 2]; 4];
            for (k, gk) in gref.iter().enumerate() {
                let g = bm.grad_to_actual(gk[0], gk[1], xi, eta);
                gact[k] = g;
            }
            let wj = wq * adet;
            for a in 0..4 {
                for b_ in 0..4 {
                    let diff = epsq
                        * (gact[a][0] * gact[b_][0]
                            + gact[a][1] * gact[b_][1]);
                    let conv = (bxq * gact[b_][0] + byq * gact[b_][1])
                        * shp[a];
                    let mass = cq * shp[b_] * shp[a];
                    ke[a][b_] += wj * (diff + conv + mass);
                }
                fe[a] += wj * fq * shp[a];
            }
        }
        // scatter with Dirichlet elimination
        for a in 0..4 {
            let ga = c[a];
            if is_bd[ga] {
                continue;
            }
            let ia = free_id[ga];
            rhs[ia] += fe[a];
            for b_ in 0..4 {
                let gb = c[b_];
                if is_bd[gb] {
                    rhs[ia] -= ke[a][b_] * gvals[gb];
                } else {
                    trip.push(ia, free_id[gb], ke[a][b_]);
                }
            }
        }
    }

    let a: CsrMatrix = trip.to_csr();
    let opts = CgOptions { max_iter: 20_000, rtol: 1e-10, atol: 1e-14 };
    // CG needs SPD: convection breaks symmetry, a (possibly negative)
    // reaction can break definiteness — both fall back to BiCGStab
    let symmetric = p.b.is_none() && p.c.is_none();
    let res = if symmetric {
        cg_solve(&a, &rhs, opts)
    } else {
        bicgstab_solve(&a, &rhs, opts)
    };
    ensure!(res.converged,
            "linear solver did not converge (residual {:.3e})",
            res.residual_norm);

    let mut u = gvals;
    for i in 0..n {
        if free_id[i] != usize::MAX {
            u[i] = res.x[free_id[i]];
        }
    }
    let index = CellIndex::build(mesh);
    Ok(FemSolution {
        mesh: mesh.clone(),
        u,
        solve_iterations: res.iterations,
        solve_seconds: t0.elapsed().as_secs_f64(),
        index,
    })
}

/// Solve the PDE described by a [`Problem`] — coefficient fields
/// (`eps_at`/`b_at`/`c_at`), forcing and Dirichlet data — on `mesh`.
/// This is the FEM cross-check entry point for every trainable
/// problem: the same trait object that drives the variational backend
/// drives an independent classical discretization.
pub fn solve_problem(mesh: &QuadMesh, p: &dyn Problem, nq1d: usize)
    -> Result<FemSolution> {
    let var = p.coeff_variability();
    let has_b = var.b || p.b() != (0.0, 0.0);
    let has_c = var.c || p.c() != 0.0;
    let eps = |x: f64, y: f64| p.eps_at(x, y);
    let b = |x: f64, y: f64| p.b_at(x, y);
    let c = |x: f64, y: f64| p.c_at(x, y);
    let f = |x: f64, y: f64| p.forcing(x, y);
    let g = |x: f64, y: f64| p.boundary(x, y);
    solve(
        mesh,
        &FemProblem {
            eps: &eps,
            b: if has_b { Some(&b) } else { None },
            c: if has_c { Some(&c) } else { None },
            f: &f,
            g: &g,
        },
        nq1d,
    )
}

/// Uniform-grid spatial index over cell bounding boxes.
struct CellIndex {
    lo: [f64; 2],
    inv_h: [f64; 2],
    nx: usize,
    ny: usize,
    bins: Vec<Vec<u32>>,
}

impl CellIndex {
    fn build(mesh: &QuadMesh) -> CellIndex {
        let (lo, hi) = mesh.bbox();
        let ncell = mesh.n_cells();
        let nx = (ncell as f64).sqrt().ceil() as usize + 1;
        let ny = nx;
        let hx = ((hi[0] - lo[0]) / nx as f64).max(1e-12);
        let hy = ((hi[1] - lo[1]) / ny as f64).max(1e-12);
        let mut bins = vec![Vec::new(); nx * ny];
        for e in 0..ncell {
            let v = mesh.cell_vertices(e);
            let (mut bx0, mut by0) = (f64::INFINITY, f64::INFINITY);
            let (mut bx1, mut by1) = (f64::NEG_INFINITY, f64::NEG_INFINITY);
            for p in v {
                bx0 = bx0.min(p[0]);
                by0 = by0.min(p[1]);
                bx1 = bx1.max(p[0]);
                by1 = by1.max(p[1]);
            }
            let ix0 = (((bx0 - lo[0]) / hx).floor() as isize).max(0) as usize;
            let iy0 = (((by0 - lo[1]) / hy).floor() as isize).max(0) as usize;
            let ix1 = (((bx1 - lo[0]) / hx).floor() as usize).min(nx - 1);
            let iy1 = (((by1 - lo[1]) / hy).floor() as usize).min(ny - 1);
            for iy in iy0..=iy1 {
                for ix in ix0..=ix1 {
                    bins[iy * nx + ix].push(e as u32);
                }
            }
        }
        CellIndex { lo, inv_h: [1.0 / hx, 1.0 / hy], nx, ny, bins }
    }

    fn locate(&self, mesh: &QuadMesh, x: f64, y: f64) -> Option<usize> {
        let ix = ((x - self.lo[0]) * self.inv_h[0]).floor() as isize;
        let iy = ((y - self.lo[1]) * self.inv_h[1]).floor() as isize;
        if ix < 0 || iy < 0 || ix >= self.nx as isize
            || iy >= self.ny as isize {
            return None;
        }
        let bin = &self.bins[iy as usize * self.nx + ix as usize];
        for &e in bin {
            let bm = BilinearMap::new(&mesh.cell_vertices(e as usize));
            if bm.contains(x, y, 1e-9) {
                return Some(e as usize);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{generators, refine};

    fn l2_err(mesh: &QuadMesh, u: &[f64], exact: impl Fn(f64, f64) -> f64)
        -> f64 {
        let mut acc = 0.0;
        for (i, p) in mesh.points.iter().enumerate() {
            let d = u[i] - exact(p[0], p[1]);
            acc += d * d;
        }
        (acc / mesh.n_points() as f64).sqrt()
    }

    #[test]
    fn poisson_manufactured_convergence() {
        // -lap u = f with u = sin(pi x) sin(pi y); O(h^2) in nodal L2
        let om = std::f64::consts::PI;
        let exact = move |x: f64, y: f64| (om * x).sin() * (om * y).sin();
        let f = move |x: f64, y: f64| {
            2.0 * om * om * (om * x).sin() * (om * y).sin()
        };
        let g = |_: f64, _: f64| 0.0;
        let eps = |_: f64, _: f64| 1.0;
        let mut errs = Vec::new();
        for n in [4usize, 8, 16] {
            let mesh = generators::unit_square(n);
            let sol = solve(&mesh,
                            &FemProblem { eps: &eps, b: None, c: None,
                                          f: &f, g: &g }, 3).unwrap();
            errs.push(l2_err(&mesh, &sol.u, exact));
        }
        // each refinement should cut the error by ~4
        assert!(errs[0] / errs[1] > 3.0, "{errs:?}");
        assert!(errs[1] / errs[2] > 3.0, "{errs:?}");
    }

    #[test]
    fn dirichlet_values_exact_on_boundary() {
        let mesh = generators::unit_square(5);
        let g = |x: f64, y: f64| 1.0 + x + 2.0 * y;
        let sol = solve(&mesh,
                        &FemProblem { eps: &|_, _| 1.0, b: None, c: None,
                                      f: &|_, _| 0.0, g: &g }, 3).unwrap();
        for e in &mesh.boundary {
            for v in [e.a, e.b] {
                let p = mesh.points[v];
                assert!((sol.u[v] - g(p[0], p[1])).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn laplace_linear_solution_exact() {
        // u = 1 + x + 2y is harmonic -> Q1 FEM reproduces it exactly
        let mesh = generators::skewed_square(4, 0.2);
        let g = |x: f64, y: f64| 1.0 + x + 2.0 * y;
        let sol = solve(&mesh,
                        &FemProblem { eps: &|_, _| 1.0, b: None, c: None,
                                      f: &|_, _| 0.0, g: &g }, 4).unwrap();
        for (i, p) in mesh.points.iter().enumerate() {
            assert!((sol.u[i] - g(p[0], p[1])).abs() < 1e-9,
                    "node {i}: {} vs {}", sol.u[i], g(p[0], p[1]));
        }
    }

    #[test]
    fn convection_diffusion_runs_nonsymmetric() {
        let mesh = generators::unit_square(8);
        let sol = solve(&mesh,
                        &FemProblem { eps: &|_, _| 1.0,
                                      b: Some(&|_, _| (1.0, 0.0)), c: None,
                                      f: &|_, _| 1.0, g: &|_, _| 0.0 },
                        3).unwrap();
        // interior values positive and bounded for this problem
        let mx = sol.u.iter().cloned().fold(f64::MIN, f64::max);
        assert!(mx > 0.0 && mx < 1.0);
    }

    #[test]
    fn variable_eps_affects_solution() {
        let mesh = generators::unit_square(8);
        let base = solve(&mesh,
                         &FemProblem { eps: &|_, _| 1.0, b: None, c: None,
                                       f: &|_, _| 1.0, g: &|_, _| 0.0 },
                         3).unwrap();
        let var = solve(&mesh,
                        &FemProblem { eps: &|x, _| 1.0 + 5.0 * x,
                                      b: None, c: None, f: &|_, _| 1.0,
                                      g: &|_, _| 0.0 }, 3).unwrap();
        let d: f64 = base
            .u
            .iter()
            .zip(&var.u)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(d > 1e-3, "variable eps had no effect");
    }

    #[test]
    fn helmholtz_manufactured_convergence() {
        // -lap u - k^2 u = f with u = sin(k x) sin(k y), k = pi (below
        // the first Dirichlet eigenvalue 2 pi^2): O(h^2) in nodal L2
        let k = std::f64::consts::PI;
        let exact = move |x: f64, y: f64| (k * x).sin() * (k * y).sin();
        // -lap u = 2 k^2 u  =>  f = (2 k^2 - k^2) u = k^2 u
        let f = move |x: f64, y: f64| k * k * exact(x, y);
        let c = move |_: f64, _: f64| -k * k;
        let mut errs = Vec::new();
        for n in [4usize, 8, 16] {
            let mesh = generators::unit_square(n);
            let sol = solve(&mesh,
                            &FemProblem { eps: &|_, _| 1.0, b: None,
                                          c: Some(&c), f: &f,
                                          g: &|_, _| 0.0 }, 3).unwrap();
            errs.push(l2_err(&mesh, &sol.u, exact));
        }
        assert!(errs[0] / errs[1] > 3.0, "{errs:?}");
        assert!(errs[1] / errs[2] > 3.0, "{errs:?}");
    }

    #[test]
    fn positive_reaction_damps_the_solution() {
        // adding c > 0 to -lap u + c u = 1 must shrink u everywhere
        let mesh = generators::unit_square(8);
        let base = solve(&mesh,
                         &FemProblem { eps: &|_, _| 1.0, b: None, c: None,
                                       f: &|_, _| 1.0, g: &|_, _| 0.0 },
                         3).unwrap();
        let damped = solve(&mesh,
                           &FemProblem { eps: &|_, _| 1.0, b: None,
                                         c: Some(&|_, _| 50.0),
                                         f: &|_, _| 1.0,
                                         g: &|_, _| 0.0 }, 3).unwrap();
        let mx_base = base.u.iter().cloned().fold(f64::MIN, f64::max);
        let mx_damp = damped.u.iter().cloned().fold(f64::MIN, f64::max);
        assert!(mx_damp < mx_base, "{mx_damp} !< {mx_base}");
        assert!(mx_damp > 0.0);
    }

    #[test]
    fn solve_problem_helmholtz_cross_validates_exact() {
        // the Problem-driven entry point: FEM vs the manufactured
        // Helmholtz solution through the trait's coefficient fields
        use crate::problems::Helmholtz2D;
        let p = Helmholtz2D::new(std::f64::consts::PI);
        let mesh = generators::unit_square(16);
        let sol = solve_problem(&mesh, &p, 3).unwrap();
        let err = l2_err(&mesh, &sol.u,
                         |x, y| p.exact(x, y).unwrap());
        assert!(err < 0.02, "helmholtz FEM vs exact L2 {err}");
    }

    #[test]
    fn solve_problem_cd_var_cross_validates_exact() {
        // variable rotating convection through the trait's b_at field
        use crate::problems::VariableConvectionCd;
        let p = VariableConvectionCd::new();
        let mesh = generators::unit_square(16);
        let sol = solve_problem(&mesh, &p, 3).unwrap();
        let err = l2_err(&mesh, &sol.u,
                         |x, y| p.exact(x, y).unwrap());
        assert!(err < 0.02, "cd_var FEM vs exact L2 {err}");
    }

    #[test]
    fn eval_interpolates() {
        let mesh = generators::unit_square(6);
        let g = |x: f64, y: f64| x + y;
        let sol = solve(&mesh,
                        &FemProblem { eps: &|_, _| 1.0, b: None, c: None,
                                      f: &|_, _| 0.0, g: &g }, 3).unwrap();
        // harmonic linear solution: eval must match anywhere
        for (x, y) in [(0.31, 0.77), (0.5, 0.5), (0.99, 0.01)] {
            let v = sol.eval(x, y).unwrap();
            assert!((v - (x + y)).abs() < 1e-9, "({x},{y}): {v}");
        }
        assert!(sol.eval(2.0, 2.0).is_none());
    }

    #[test]
    fn eval_on_gear_mesh() {
        let mesh = generators::gear(6, 6, 3, 0.4, 0.8, 1.0);
        let sol = solve(&mesh,
                        &FemProblem { eps: &|_, _| 1.0, b: None, c: None,
                                      f: &|_, _| 1.0, g: &|_, _| 0.0 },
                        3).unwrap();
        // a point on the mid annulus must be inside
        let v = sol.eval(0.6, 0.0);
        assert!(v.is_some());
        // hub hole is outside the domain
        assert!(sol.eval(0.0, 0.0).is_none());
    }

    #[test]
    fn convergence_on_refined_disk() {
        // area-converging mesh + harmonic u = x^2 - y^2
        let exact = |x: f64, y: f64| x * x - y * y;
        let mesh = generators::disk(6, 4, 0.0, 0.0, 1.0);
        let fine = refine::refine_uniform(&mesh);
        let prob = FemProblem { eps: &|_, _| 1.0, b: None, c: None,
                                f: &|_, _| 0.0, g: &exact };
        let e1 = {
            let s = solve(&mesh, &prob, 3).unwrap();
            l2_err(&mesh, &s.u, exact)
        };
        let e2 = {
            let s = solve(&fine, &prob, 3).unwrap();
            l2_err(&fine, &s.u, exact)
        };
        assert!(e2 < e1, "no improvement: {e1} -> {e2}");
    }
}
