//! Legacy VTK (ASCII, unstructured grid) writer for solution fields —
//! lets users open predictions/errors in ParaView.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::QuadMesh;

/// Write `mesh` with any number of named point-data scalar fields.
pub fn write_point_fields(
    mesh: &QuadMesh,
    fields: &[(&str, &[f64])],
    path: impl AsRef<Path>,
) -> Result<()> {
    for (name, data) in fields {
        ensure!(data.len() == mesh.n_points(),
                "field '{name}' has {} values for {} points", data.len(),
                mesh.n_points());
    }
    let mut s = String::new();
    s.push_str("# vtk DataFile Version 3.0\nfastvpinns\nASCII\n");
    s.push_str("DATASET UNSTRUCTURED_GRID\n");
    let _ = writeln!(s, "POINTS {} double", mesh.n_points());
    for p in &mesh.points {
        let _ = writeln!(s, "{} {} 0", p[0], p[1]);
    }
    let _ = writeln!(s, "CELLS {} {}", mesh.n_cells(), mesh.n_cells() * 5);
    for c in &mesh.cells {
        let _ = writeln!(s, "4 {} {} {} {}", c[0], c[1], c[2], c[3]);
    }
    let _ = writeln!(s, "CELL_TYPES {}", mesh.n_cells());
    for _ in 0..mesh.n_cells() {
        s.push_str("9\n"); // VTK_QUAD
    }
    if !fields.is_empty() {
        let _ = writeln!(s, "POINT_DATA {}", mesh.n_points());
        for (name, data) in fields {
            let _ = writeln!(s, "SCALARS {name} double 1");
            s.push_str("LOOKUP_TABLE default\n");
            for v in *data {
                let _ = writeln!(s, "{v}");
            }
        }
    }
    fs::write(path.as_ref(), s)
        .with_context(|| format!("write {}", path.as_ref().display()))?;
    Ok(())
}

/// Write a bare point cloud (no mesh connectivity) with named scalar
/// fields as legacy-VTK POLYDATA — the `repro infer` output path for
/// arbitrary query clouds, viewable in ParaView as vertices.
pub fn write_point_cloud(
    points: &[[f64; 2]],
    fields: &[(&str, &[f64])],
    path: impl AsRef<Path>,
) -> Result<()> {
    for (name, data) in fields {
        ensure!(data.len() == points.len(),
                "field '{name}' has {} values for {} points", data.len(),
                points.len());
    }
    let n = points.len();
    let mut s = String::new();
    s.push_str("# vtk DataFile Version 3.0\nfastvpinns\nASCII\n");
    s.push_str("DATASET POLYDATA\n");
    let _ = writeln!(s, "POINTS {n} double");
    for p in points {
        let _ = writeln!(s, "{} {} 0", p[0], p[1]);
    }
    let _ = writeln!(s, "VERTICES {n} {}", 2 * n);
    for i in 0..n {
        let _ = writeln!(s, "1 {i}");
    }
    if !fields.is_empty() {
        let _ = writeln!(s, "POINT_DATA {n}");
        for (name, data) in fields {
            let _ = writeln!(s, "SCALARS {name} double 1");
            s.push_str("LOOKUP_TABLE default\n");
            for v in *data {
                let _ = writeln!(s, "{v}");
            }
        }
    }
    fs::write(path.as_ref(), s)
        .with_context(|| format!("write {}", path.as_ref().display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generators;

    #[test]
    fn writes_valid_header() {
        let m = generators::unit_square(2);
        let u: Vec<f64> = m.points.iter().map(|p| p[0] + p[1]).collect();
        let p = std::env::temp_dir().join("fastvpinns_test.vtk");
        write_point_fields(&m, &[("u", &u)], &p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("# vtk DataFile"));
        assert!(text.contains("POINTS 9 double"));
        assert!(text.contains("CELL_TYPES 4"));
        assert!(text.contains("SCALARS u double 1"));
    }

    #[test]
    fn rejects_wrong_field_length() {
        let m = generators::unit_square(1);
        let bad = vec![0.0; 3];
        let p = std::env::temp_dir().join("fastvpinns_bad.vtk");
        assert!(write_point_fields(&m, &[("u", &bad)], &p).is_err());
    }

    #[test]
    fn point_cloud_polydata() {
        let pts = [[0.0, 0.0], [0.5, 0.25], [1.0, 1.0]];
        let u = vec![1.0, 2.0, 3.0];
        let e = vec![0.1, 0.2, 0.3];
        let p = std::env::temp_dir().join("fastvpinns_cloud.vtk");
        write_point_cloud(&pts, &[("u", &u), ("eps", &e)], &p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("DATASET POLYDATA"));
        assert!(text.contains("POINTS 3 double"));
        assert!(text.contains("VERTICES 3 6"));
        assert!(text.contains("SCALARS eps double 1"));
        assert!(write_point_cloud(&pts, &[("u", &e[..2])], &p).is_err());
    }
}
