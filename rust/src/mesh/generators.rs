//! Mesh generators: structured grids, skewed grids, disk (butterfly),
//! annulus and the parametric spur gear (the paper's Fig. 3 workload).
//!
//! Numbering contracts (cross-validated against python fem_py.mesh):
//! - grids: nodes row-major `iy*(nx+1)+ix`, cells row-major
//!   `[bl, br, tr, tl]`.

use std::collections::HashMap;

use super::QuadMesh;

/// Structured grid on [x0,x1] x [y0,y1] with nx x ny cells.
pub fn rect_grid(nx: usize, ny: usize, x0: f64, y0: f64, x1: f64, y1: f64)
    -> QuadMesh {
    assert!(nx >= 1 && ny >= 1);
    let mut points = Vec::with_capacity((nx + 1) * (ny + 1));
    for iy in 0..=ny {
        for ix in 0..=nx {
            let x = x0 + (x1 - x0) * ix as f64 / nx as f64;
            let y = y0 + (y1 - y0) * iy as f64 / ny as f64;
            points.push([x, y]);
        }
    }
    let mut cells = Vec::with_capacity(nx * ny);
    for cy in 0..ny {
        for cx in 0..nx {
            let bl = cy * (nx + 1) + cx;
            let br = bl + 1;
            let tl = bl + (nx + 1);
            let tr = tl + 1;
            cells.push([bl, br, tr, tl]);
        }
    }
    QuadMesh::new(points, cells).expect("rect_grid is always valid")
}

/// n x n grid on the unit square.
pub fn unit_square(n: usize) -> QuadMesh {
    rect_grid(n, n, 0.0, 0.0, 1.0, 1.0)
}

/// Unit-square grid with interior nodes displaced by an analytic field —
/// genuinely non-constant per-element Jacobians. MUST stay identical to
/// python fem_py.mesh.skewed_square (cross-validation contract).
pub fn skewed_square(n: usize, amp: f64) -> QuadMesh {
    let mut m = unit_square(n);
    let h = 1.0 / n as f64;
    for p in &mut m.points {
        let (x, y) = (p[0], p[1]);
        let interior = x > 1e-12 && x < 1.0 - 1e-12 && y > 1e-12
            && y < 1.0 - 1e-12;
        if interior {
            p[0] = x + amp * h * (9.0 * x + 5.0 * y).sin();
            p[1] = y + amp * h * (7.0 * x - 4.0 * y).cos();
        }
    }
    m.compute_boundary();
    m
}

/// Helper: weld coincident points (tolerance 1e-9) across blocks.
struct Welder {
    points: Vec<[f64; 2]>,
    index: HashMap<(i64, i64), usize>,
}

impl Welder {
    fn new() -> Self {
        Welder { points: vec![], index: HashMap::new() }
    }

    fn key(p: [f64; 2]) -> (i64, i64) {
        ((p[0] * 1e9).round() as i64, (p[1] * 1e9).round() as i64)
    }

    fn add(&mut self, p: [f64; 2]) -> usize {
        let k = Self::key(p);
        *self.index.entry(k).or_insert_with(|| {
            self.points.push(p);
            self.points.len() - 1
        })
    }
}

/// Butterfly ("O-grid") disk mesh of radius `r` centred at `(cx, cy)`:
/// a central n x n square block plus four n x m transition blocks mapped
/// to the circle. Total cells: n^2 + 4 n m (n=16, m=12 -> 1024, the
/// paper's SS4.7.2 disk).
pub fn disk(n: usize, m: usize, cx: f64, cy: f64, r: f64) -> QuadMesh {
    assert!(n >= 1 && m >= 1);
    let s = 0.5 * r; // half-side of the inner square block
    let mut w = Welder::new();
    let mut cells = Vec::new();

    // --- central block: [-s, s]^2
    let mut grid = vec![vec![0usize; n + 1]; n + 1];
    for (iy, row) in grid.iter_mut().enumerate() {
        for (ix, slot) in row.iter_mut().enumerate() {
            let x = -s + 2.0 * s * ix as f64 / n as f64;
            let y = -s + 2.0 * s * iy as f64 / n as f64;
            *slot = w.add([cx + x, cy + y]);
        }
    }
    for iy in 0..n {
        for ix in 0..n {
            cells.push([grid[iy][ix], grid[iy][ix + 1], grid[iy + 1][ix + 1],
                        grid[iy + 1][ix]]);
        }
    }

    // --- four transition blocks: inner edge = square side, outer = arc.
    // Side k covers angles centred on k*90deg - 135deg..-45deg style;
    // parametrise t in [0,1] along the side, v in [0,1] inner->outer.
    for side in 0..4 {
        let mut block = vec![vec![0usize; n + 1]; m + 1];
        for (iv, row) in block.iter_mut().enumerate() {
            let v = iv as f64 / m as f64;
            for (it, slot) in row.iter_mut().enumerate() {
                let t = it as f64 / n as f64;
                // inner square point along this side (CCW)
                let (sx, sy) = match side {
                    0 => (-s + 2.0 * s * t, -s), // bottom
                    1 => (s, -s + 2.0 * s * t),  // right
                    2 => (s - 2.0 * s * t, s),   // top
                    _ => (-s, s - 2.0 * s * t),  // left
                };
                // matching arc point: angle sweeps the quarter circle
                let a0 = match side {
                    0 => -0.75 * std::f64::consts::PI,
                    1 => -0.25 * std::f64::consts::PI,
                    2 => 0.25 * std::f64::consts::PI,
                    _ => 0.75 * std::f64::consts::PI,
                };
                let ang = a0 + t * 0.5 * std::f64::consts::PI;
                let (axp, ayp) = (r * ang.cos(), r * ang.sin());
                let x = sx + v * (axp - sx);
                let y = sy + v * (ayp - sy);
                *slot = w.add([cx + x, cy + y]);
            }
        }
        for iv in 0..m {
            for it in 0..n {
                // orientation: keep CCW (inner->outer on the left)
                cells.push([block[iv][it], block[iv][it + 1],
                            block[iv + 1][it + 1], block[iv + 1][it]]);
            }
        }
    }

    let mut mesh = QuadMesh::new(w.points, cells).expect("disk mesh valid");
    fix_orientation(&mut mesh);
    mesh.compute_boundary();
    mesh
}

/// Annulus (ring) mesh: n_theta x n_r cells between radii r0 < r1.
pub fn annulus(n_theta: usize, n_r: usize, cx: f64, cy: f64, r0: f64,
               r1: f64) -> QuadMesh {
    assert!(n_theta >= 3 && n_r >= 1 && r0 > 0.0 && r1 > r0);
    let mut points = Vec::with_capacity(n_theta * (n_r + 1));
    for ir in 0..=n_r {
        let r = r0 + (r1 - r0) * ir as f64 / n_r as f64;
        for it in 0..n_theta {
            let ang = 2.0 * std::f64::consts::PI * it as f64
                / n_theta as f64;
            points.push([cx + r * ang.cos(), cy + r * ang.sin()]);
        }
    }
    let idx = |ir: usize, it: usize| ir * n_theta + (it % n_theta);
    let mut cells = Vec::with_capacity(n_theta * n_r);
    for ir in 0..n_r {
        for it in 0..n_theta {
            // CCW winding: radially outward is the "up" direction, so
            // traverse inner edge first in +theta, then outer edge back.
            cells.push([idx(ir, it), idx(ir, it + 1), idx(ir + 1, it + 1),
                        idx(ir + 1, it)]);
        }
    }
    let mut mesh = QuadMesh::new(points, cells).expect("annulus valid");
    fix_orientation(&mut mesh);
    mesh.compute_boundary();
    mesh
}

/// Spur-gear radius profile at angle `theta`: a smoothed trapezoid wave
/// between root and tip radius, `teeth` times around the circle. The
/// smoothing (cosine flanks) keeps cells valid while still producing the
/// strongly skewed quads the paper's gear mesh stresses.
pub fn gear_radius(theta: f64, teeth: usize, r_root: f64, r_tip: f64) -> f64 {
    let phase = (theta * teeth as f64 / (2.0 * std::f64::consts::PI))
        .rem_euclid(1.0);
    // tooth occupies [0, 0.45] of the pitch: flanks 0.1 wide each side
    let prof = |p: f64| -> f64 {
        let flank = 0.12;
        let top = 0.45;
        if p < flank {
            0.5 * (1.0 - (std::f64::consts::PI * p / flank).cos())
        } else if p < top - flank {
            1.0
        } else if p < top {
            0.5 * (1.0 + (std::f64::consts::PI * (p - top + flank) / flank)
                .cos())
        } else {
            0.0
        }
    };
    r_root + (r_tip - r_root) * prof(phase)
}

/// Parametric spur gear with a hub bore: `n_theta x n_layers` quads
/// between the hub circle (radius `r_hub`) and the gear outline.
///
/// `gear(20, 44, 16, ..)` -> 880 x 16 = 14,080 cells, the CI stand-in
/// for the paper's 14,192-cell Gmsh mesh (DESIGN.md SS3).
pub fn gear(teeth: usize, pts_per_tooth: usize, n_layers: usize, r_hub: f64,
            r_root: f64, r_tip: f64) -> QuadMesh {
    assert!(teeth >= 3 && pts_per_tooth >= 4 && n_layers >= 2);
    assert!(r_hub < r_root && r_root < r_tip);
    let n_theta = teeth * pts_per_tooth;
    let mut points = Vec::with_capacity(n_theta * (n_layers + 1));
    for il in 0..=n_layers {
        let v = il as f64 / n_layers as f64;
        // grade layers toward the outline so teeth are resolved
        let vv = v.powf(0.8);
        for it in 0..n_theta {
            let ang = 2.0 * std::f64::consts::PI * it as f64
                / n_theta as f64;
            let r_out = gear_radius(ang, teeth, r_root, r_tip);
            let r = r_hub + (r_out - r_hub) * vv;
            points.push([r * ang.cos(), r * ang.sin()]);
        }
    }
    let idx = |il: usize, it: usize| il * n_theta + (it % n_theta);
    let mut cells = Vec::with_capacity(n_theta * n_layers);
    for il in 0..n_layers {
        for it in 0..n_theta {
            cells.push([idx(il, it), idx(il, it + 1), idx(il + 1, it + 1),
                        idx(il + 1, it)]);
        }
    }
    let mut mesh = QuadMesh::new(points, cells).expect("gear valid");
    fix_orientation(&mut mesh);
    mesh.compute_boundary();
    mesh
}

/// The canonical gear workloads from DESIGN.md / specs.py.
pub fn gear_ci() -> QuadMesh {
    // 20 teeth * 11 pts = 220 around, 8 layers -> 1760 cells
    gear(20, 11, 8, 0.35, 0.8, 1.0)
}

/// The paper-scale spur gear: 14,080 cells.
pub fn gear_paper() -> QuadMesh {
    // 20 teeth * 44 pts = 880 around, 16 layers -> 14,080 cells
    gear(20, 44, 16, 0.35, 0.8, 1.0)
}

/// The paper's SS4.7.2 disk: 1024 cells (butterfly 16 + 4x16x12).
pub fn disk_1024() -> QuadMesh {
    disk(16, 12, 0.0, 0.0, 1.0)
}

/// Flip any negatively-oriented cells (shoelace) to CCW.
fn fix_orientation(m: &mut QuadMesh) {
    for c in &mut m.cells {
        let p: Vec<[f64; 2]> = c.iter().map(|&v| m.points[v]).collect();
        let area = 0.5
            * ((p[0][0] * p[1][1] - p[1][0] * p[0][1])
                + (p[1][0] * p[2][1] - p[2][0] * p[1][1])
                + (p[2][0] * p[3][1] - p[3][0] * p[2][1])
                + (p[3][0] * p[0][1] - p[0][0] * p[3][1]));
        if area < 0.0 {
            c.swap(1, 3);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::quality;

    #[test]
    fn rect_grid_matches_python_layout() {
        let m = rect_grid(2, 2, 0.0, 0.0, 1.0, 1.0);
        // node 4 = (iy=1, ix=1) -> (0.5, 0.5)
        assert_eq!(m.points[4], [0.5, 0.5]);
        // cell 0 corners = [0, 1, 4, 3]
        assert_eq!(m.cells[0], [0, 1, 4, 3]);
    }

    #[test]
    fn skewed_square_keeps_boundary_fixed() {
        let m = skewed_square(4, 0.3);
        for p in &m.points {
            let on_bd = p[0].abs() < 1e-9 || (p[0] - 1.0).abs() < 1e-9
                || p[1].abs() < 1e-9 || (p[1] - 1.0).abs() < 1e-9;
            let inside = p[0] > -0.1 && p[0] < 1.1 && p[1] > -0.1
                && p[1] < 1.1;
            assert!(inside);
            let _ = on_bd;
        }
        assert!((m.area() - 1.0).abs() < 1e-10);
        assert!(quality::all_jacobians_positive(&m));
    }

    #[test]
    fn disk_counts_and_area() {
        let m = disk_1024();
        assert_eq!(m.n_cells(), 1024);
        let exact = std::f64::consts::PI;
        assert!((m.area() - exact).abs() / exact < 0.01,
                "area {} vs {}", m.area(), exact);
        assert!(quality::all_jacobians_positive(&m));
    }

    #[test]
    fn disk_boundary_on_circle() {
        let m = disk(8, 6, 1.0, -2.0, 3.0);
        for e in &m.boundary {
            for v in [e.a, e.b] {
                let p = m.points[v];
                let r = ((p[0] - 1.0).powi(2) + (p[1] + 2.0).powi(2)).sqrt();
                assert!((r - 3.0).abs() < 1e-9, "boundary point r={r}");
            }
        }
    }

    #[test]
    fn annulus_counts() {
        let m = annulus(12, 3, 0.0, 0.0, 0.5, 1.0);
        assert_eq!(m.n_cells(), 36);
        assert_eq!(m.n_points(), 12 * 4);
        // two boundary loops: 12 inner + 12 outer edges
        assert_eq!(m.boundary.len(), 24);
        assert!(quality::all_jacobians_positive(&m));
    }

    #[test]
    fn gear_ci_counts() {
        let m = gear_ci();
        assert_eq!(m.n_cells(), 1760);
        assert!(quality::all_jacobians_positive(&m));
        // two boundary loops (hub + outline)
        assert_eq!(m.boundary.len(), 2 * 220);
    }

    #[test]
    fn gear_paper_counts() {
        let m = gear_paper();
        assert_eq!(m.n_cells(), 14_080);
        assert!(quality::all_jacobians_positive(&m));
    }

    #[test]
    fn gear_has_genuinely_skewed_cells() {
        let m = gear_ci();
        let (mn, mx) = quality::jacobian_ratio_extremes(&m);
        // teeth flanks produce strongly varying in-cell Jacobians; no
        // cell of a curved mesh is perfectly affine (ratio < 1)
        assert!(mn < 0.9, "min in-cell |J| ratio {mn}");
        assert!(mx <= 1.0 + 1e-12 && mx > mn);
    }

    #[test]
    fn gear_radius_periodic() {
        for k in 0..5 {
            let t = 0.3 + k as f64 * 2.0 * std::f64::consts::PI / 20.0;
            let a = gear_radius(0.3, 20, 0.8, 1.0);
            let b = gear_radius(t, 20, 0.8, 1.0);
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn gear_radius_bounds() {
        for i in 0..1000 {
            let t = i as f64 * 0.0063;
            let r = gear_radius(t, 14, 0.8, 1.0);
            assert!((0.8..=1.0 + 1e-12).contains(&r));
        }
    }
}
