//! Uniform quad refinement: each cell splits into 4 (edge midpoints +
//! centroid). Used for FEM convergence studies (Table 1 DOF ladder).

use std::collections::HashMap;

use super::QuadMesh;

/// One level of uniform refinement.
pub fn refine_uniform(mesh: &QuadMesh) -> QuadMesh {
    let mut points = mesh.points.clone();
    let mut edge_mid: HashMap<(usize, usize), usize> = HashMap::new();
    let mut cells = Vec::with_capacity(mesh.n_cells() * 4);

    let mut midpoint = |a: usize, b: usize, pts: &mut Vec<[f64; 2]>| {
        let key = (a.min(b), a.max(b));
        *edge_mid.entry(key).or_insert_with(|| {
            let pa = pts[a];
            let pb = pts[b];
            pts.push([(pa[0] + pb[0]) / 2.0, (pa[1] + pb[1]) / 2.0]);
            pts.len() - 1
        })
    };

    for c in &mesh.cells {
        let [v0, v1, v2, v3] = *c;
        let m01 = midpoint(v0, v1, &mut points);
        let m12 = midpoint(v1, v2, &mut points);
        let m23 = midpoint(v2, v3, &mut points);
        let m30 = midpoint(v3, v0, &mut points);
        let p = [
            (points[v0][0] + points[v1][0] + points[v2][0] + points[v3][0])
                / 4.0,
            (points[v0][1] + points[v1][1] + points[v2][1] + points[v3][1])
                / 4.0,
        ];
        points.push(p);
        let ctr = points.len() - 1;
        cells.push([v0, m01, ctr, m30]);
        cells.push([m01, v1, m12, ctr]);
        cells.push([ctr, m12, v2, m23]);
        cells.push([m30, ctr, m23, v3]);
    }

    QuadMesh::new(points, cells).expect("refinement preserves validity")
}

/// `levels` rounds of refinement.
pub fn refine_n(mesh: &QuadMesh, levels: usize) -> QuadMesh {
    let mut m = mesh.clone();
    for _ in 0..levels {
        m = refine_uniform(&m);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{generators, quality};

    #[test]
    fn counts_quadruple() {
        let m = generators::unit_square(2);
        let r = refine_uniform(&m);
        assert_eq!(r.n_cells(), 16);
        // structured grid: refined = 4x4 grid -> 25 points
        assert_eq!(r.n_points(), 25);
    }

    #[test]
    fn area_preserved() {
        let m = generators::skewed_square(3, 0.2);
        let r = refine_uniform(&m);
        assert!((r.area() - m.area()).abs() < 1e-12);
    }

    #[test]
    fn validity_preserved_on_gear() {
        let m = generators::gear(8, 6, 3, 0.4, 0.8, 1.0);
        let r = refine_uniform(&m);
        assert_eq!(r.n_cells(), 4 * m.n_cells());
        assert!(quality::all_jacobians_positive(&r));
    }

    #[test]
    fn refine_n_levels() {
        let m = generators::unit_square(1);
        let r = refine_n(&m, 3);
        assert_eq!(r.n_cells(), 64);
    }

    #[test]
    fn shared_edges_welded() {
        // refined 2x2 grid must not duplicate midpoints on shared edges
        let m = generators::unit_square(2);
        let r = refine_uniform(&m);
        let mut seen = std::collections::HashMap::new();
        for p in &r.points {
            let key = ((p[0] * 1e9) as i64, (p[1] * 1e9) as i64);
            *seen.entry(key).or_insert(0) += 1;
        }
        assert!(seen.values().all(|&c| c == 1), "duplicate points");
    }
}
