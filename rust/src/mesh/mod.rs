//! Quadrilateral meshes: data structure, generators, Gmsh I/O, quality
//! metrics, refinement and VTK export.
//!
//! Cells are counter-clockwise `[v0, v1, v2, v3]`, matching reference
//! corners (-1,-1), (1,-1), (1,1), (-1,1) — the contract shared with
//! `fem::bilinear` and python `fem_py.transforms`.

pub mod generators;
pub mod gmsh;
pub mod quality;
pub mod refine;
pub mod vtk;

use std::collections::HashMap;

use anyhow::{bail, Result};

/// An oriented boundary edge (a -> b in the owning cell's CCW order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoundaryEdge {
    /// Start point index.
    pub a: usize,
    /// End point index.
    pub b: usize,
    /// Physical tag (0 = untagged / default boundary).
    pub tag: u32,
}

/// A 2D all-quad mesh.
#[derive(Debug, Clone, Default)]
pub struct QuadMesh {
    /// Vertex coordinates.
    pub points: Vec<[f64; 2]>,
    /// CCW vertex indices per quad cell.
    pub cells: Vec<[usize; 4]>,
    /// Oriented boundary edges; populated by `compute_boundary` (called
    /// by all constructors in this crate).
    pub boundary: Vec<BoundaryEdge>,
}

impl QuadMesh {
    /// Build a mesh, validating indices/orientation and computing the
    /// boundary.
    pub fn new(points: Vec<[f64; 2]>, cells: Vec<[usize; 4]>) -> Result<Self> {
        let mut m = QuadMesh { points, cells, boundary: vec![] };
        m.validate()?;
        m.compute_boundary();
        Ok(m)
    }

    /// Vertex count.
    pub fn n_points(&self) -> usize {
        self.points.len()
    }

    /// Cell count.
    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    /// The 4 vertex coordinates of cell `e`.
    pub fn cell_vertices(&self, e: usize) -> [[f64; 2]; 4] {
        let c = self.cells[e];
        [self.points[c[0]], self.points[c[1]], self.points[c[2]],
         self.points[c[3]]]
    }

    fn validate(&self) -> Result<()> {
        for (i, c) in self.cells.iter().enumerate() {
            for &v in c {
                if v >= self.points.len() {
                    bail!("cell {i} references missing point {v}");
                }
            }
            let set: std::collections::BTreeSet<_> = c.iter().collect();
            if set.len() != 4 {
                bail!("cell {i} has repeated vertices: {c:?}");
            }
        }
        Ok(())
    }

    /// Find boundary edges: cell edges that occur exactly once.
    pub fn compute_boundary(&mut self) {
        let mut count: HashMap<(usize, usize), (usize, (usize, usize))> =
            HashMap::new();
        for c in &self.cells {
            for k in 0..4 {
                let a = c[k];
                let b = c[(k + 1) % 4];
                let key = (a.min(b), a.max(b));
                let e = count.entry(key).or_insert((0, (a, b)));
                e.0 += 1;
            }
        }
        let mut edges: Vec<BoundaryEdge> = count
            .into_iter()
            .filter(|(_, (n, _))| *n == 1)
            .map(|(_, (_, (a, b)))| BoundaryEdge { a, b, tag: 0 })
            .collect();
        // deterministic order (hash maps are not)
        edges.sort_by_key(|e| (e.a, e.b));
        self.boundary = edges;
    }

    /// Total boundary length.
    pub fn boundary_length(&self) -> f64 {
        self.boundary
            .iter()
            .map(|e| dist(self.points[e.a], self.points[e.b]))
            .sum()
    }

    /// Sample exactly `n` points spread along the boundary proportionally
    /// to edge length (deterministic; used to build the static-shape
    /// Dirichlet inputs of the AOT artifacts).
    pub fn sample_boundary(&self, n: usize) -> Vec<[f64; 2]> {
        assert!(!self.boundary.is_empty(), "mesh has no boundary");
        let total = self.boundary_length();
        let mut out = Vec::with_capacity(n);
        let mut acc = 0.0;
        let mut edge_iter = self.boundary.iter();
        let mut cur = edge_iter.next().unwrap();
        let mut cur_len = dist(self.points[cur.a], self.points[cur.b]);
        for i in 0..n {
            let target = total * i as f64 / n as f64;
            while acc + cur_len < target {
                acc += cur_len;
                match edge_iter.next() {
                    Some(e) => {
                        cur = e;
                        cur_len = dist(self.points[cur.a],
                                       self.points[cur.b]);
                    }
                    None => break,
                }
            }
            let t = if cur_len > 0.0 {
                ((target - acc) / cur_len).clamp(0.0, 1.0)
            } else {
                0.0
            };
            let pa = self.points[cur.a];
            let pb = self.points[cur.b];
            out.push([pa[0] + t * (pb[0] - pa[0]),
                      pa[1] + t * (pb[1] - pa[1])]);
        }
        out
    }

    /// Draw `n` interior sample points: pick a random cell, then a random
    /// reference point, and map it — always inside the domain, even for
    /// non-convex meshes (gear!).
    pub fn sample_interior(&self, n: usize, seed: u64) -> Vec<[f64; 2]> {
        use crate::fem::bilinear::BilinearMap;
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n)
            .map(|_| {
                let e = rng.below(self.n_cells());
                let bm = BilinearMap::new(&self.cell_vertices(e));
                let xi = rng.uniform_in(-1.0, 1.0);
                let eta = rng.uniform_in(-1.0, 1.0);
                bm.map(xi, eta)
            })
            .collect()
    }

    /// Bounding box: ((xmin, ymin), (xmax, ymax)).
    pub fn bbox(&self) -> ([f64; 2], [f64; 2]) {
        let mut lo = [f64::INFINITY; 2];
        let mut hi = [f64::NEG_INFINITY; 2];
        for p in &self.points {
            for d in 0..2 {
                lo[d] = lo[d].min(p[d]);
                hi[d] = hi[d].max(p[d]);
            }
        }
        (lo, hi)
    }

    /// Total mesh area via the shoelace formula per cell.
    pub fn area(&self) -> f64 {
        self.cells
            .iter()
            .map(|c| {
                let p: Vec<[f64; 2]> =
                    c.iter().map(|&v| self.points[v]).collect();
                0.5 * ((p[0][0] * p[1][1] - p[1][0] * p[0][1])
                    + (p[1][0] * p[2][1] - p[2][0] * p[1][1])
                    + (p[2][0] * p[3][1] - p[3][0] * p[2][1])
                    + (p[3][0] * p[0][1] - p[0][0] * p[3][1]))
            })
            .sum()
    }
}

fn dist(a: [f64; 2], b: [f64; 2]) -> f64 {
    ((a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn square() -> QuadMesh {
        generators::unit_square(2)
    }

    #[test]
    fn unit_square_counts() {
        let m = square();
        assert_eq!(m.n_points(), 9);
        assert_eq!(m.n_cells(), 4);
        assert_eq!(m.boundary.len(), 8);
    }

    #[test]
    fn area_is_one() {
        assert!((square().area() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn boundary_length_is_four() {
        assert!((square().boundary_length() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn boundary_samples_on_boundary() {
        let m = square();
        for p in m.sample_boundary(40) {
            let on = p[0].abs() < 1e-12 || (p[0] - 1.0).abs() < 1e-12
                || p[1].abs() < 1e-12 || (p[1] - 1.0).abs() < 1e-12;
            assert!(on, "{p:?} not on boundary");
        }
    }

    #[test]
    fn boundary_sample_count_exact() {
        let m = square();
        for n in [1, 7, 100, 1000] {
            assert_eq!(m.sample_boundary(n).len(), n);
        }
    }

    #[test]
    fn interior_samples_inside_bbox() {
        let m = square();
        for p in m.sample_interior(200, 1) {
            assert!((0.0..=1.0).contains(&p[0]));
            assert!((0.0..=1.0).contains(&p[1]));
        }
    }

    #[test]
    fn rejects_bad_cells() {
        let pts = vec![[0.0, 0.0], [1.0, 0.0], [1.0, 1.0]];
        assert!(QuadMesh::new(pts.clone(), vec![[0, 1, 2, 5]]).is_err());
        assert!(QuadMesh::new(pts, vec![[0, 1, 2, 2]]).is_err());
    }

    #[test]
    fn euler_characteristic_disk_topology() {
        // V - E + F = 1 for a disk-like mesh (counting unique edges)
        let m = generators::unit_square(5);
        let mut edges = std::collections::BTreeSet::new();
        for c in &m.cells {
            for k in 0..4 {
                let a = c[k];
                let b = c[(k + 1) % 4];
                edges.insert((a.min(b), a.max(b)));
            }
        }
        let v = m.n_points() as i64;
        let e = edges.len() as i64;
        let f = m.n_cells() as i64;
        assert_eq!(v - e + f, 1);
    }
}
