//! Mesh quality metrics: Jacobian positivity (validity), in-cell
//! Jacobian variation (skewness proxy), aspect ratio.

use crate::fem::bilinear::BilinearMap;

use super::QuadMesh;

const SAMPLE: [f64; 5] = [-1.0, -0.5, 0.0, 0.5, 1.0];

/// Minimum Jacobian determinant over a 5x5 reference sample of cell `e`.
pub fn min_jacobian(mesh: &QuadMesh, e: usize) -> f64 {
    let bm = BilinearMap::new(&mesh.cell_vertices(e));
    let mut mn = f64::INFINITY;
    for &xi in &SAMPLE {
        for &eta in &SAMPLE {
            mn = mn.min(bm.jacobian(xi, eta).det);
        }
    }
    mn
}

/// Max Jacobian determinant over the same sample.
pub fn max_jacobian(mesh: &QuadMesh, e: usize) -> f64 {
    let bm = BilinearMap::new(&mesh.cell_vertices(e));
    let mut mx = f64::NEG_INFINITY;
    for &xi in &SAMPLE {
        for &eta in &SAMPLE {
            mx = mx.max(bm.jacobian(xi, eta).det);
        }
    }
    mx
}

/// True if every cell has strictly positive Jacobian everywhere sampled
/// (the mesh is valid / non-inverted).
pub fn all_jacobians_positive(mesh: &QuadMesh) -> bool {
    (0..mesh.n_cells()).all(|e| min_jacobian(mesh, e) > 0.0)
}

/// Worst in-cell Jacobian ratio min/max over the mesh: 1.0 for perfectly
/// affine cells, -> 0 for heavily skewed ones. Returns (worst, best).
pub fn jacobian_ratio_extremes(mesh: &QuadMesh) -> (f64, f64) {
    let mut worst = f64::INFINITY;
    let mut best = f64::NEG_INFINITY;
    for e in 0..mesh.n_cells() {
        let mn = min_jacobian(mesh, e);
        let mx = max_jacobian(mesh, e);
        if mx > 0.0 {
            let ratio = mn / mx;
            worst = worst.min(ratio);
            best = best.max(ratio);
        }
    }
    (worst, best)
}

/// Aspect ratio of cell `e`: longest edge / shortest edge.
pub fn aspect_ratio(mesh: &QuadMesh, e: usize) -> f64 {
    let v = mesh.cell_vertices(e);
    let mut lens = [0.0; 4];
    for k in 0..4 {
        let a = v[k];
        let b = v[(k + 1) % 4];
        lens[k] = ((a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2)).sqrt();
    }
    let mx = lens.iter().cloned().fold(f64::MIN, f64::max);
    let mn = lens.iter().cloned().fold(f64::MAX, f64::min);
    mx / mn
}

/// Summary over the whole mesh (printed by `repro mesh`).
#[derive(Debug, Clone, Copy)]
pub struct QualityReport {
    /// Cell count.
    pub n_cells: usize,
    /// Vertex count.
    pub n_points: usize,
    /// Whether every cell has a positive Jacobian everywhere probed.
    pub all_valid: bool,
    /// Smallest Jacobian determinant seen.
    pub min_jac: f64,
    /// Worst max/min in-cell Jacobian ratio (skewness proxy).
    pub worst_ratio: f64,
    /// Largest cell aspect ratio.
    pub max_aspect: f64,
    /// Total mesh area.
    pub area: f64,
}

/// Probe every cell's Jacobian and sizes into a [`QualityReport`].
pub fn report(mesh: &QuadMesh) -> QualityReport {
    let mut min_jac = f64::INFINITY;
    let mut max_aspect: f64 = 0.0;
    for e in 0..mesh.n_cells() {
        min_jac = min_jac.min(min_jacobian(mesh, e));
        max_aspect = max_aspect.max(aspect_ratio(mesh, e));
    }
    let (worst_ratio, _) = jacobian_ratio_extremes(mesh);
    QualityReport {
        n_cells: mesh.n_cells(),
        n_points: mesh.n_points(),
        all_valid: min_jac > 0.0,
        min_jac,
        worst_ratio,
        max_aspect,
        area: mesh.area(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generators;

    #[test]
    fn unit_square_is_perfect() {
        let m = generators::unit_square(3);
        assert!(all_jacobians_positive(&m));
        let (worst, best) = jacobian_ratio_extremes(&m);
        assert!((worst - 1.0).abs() < 1e-12);
        assert!((best - 1.0).abs() < 1e-12);
        assert!((aspect_ratio(&m, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skewed_mesh_valid_but_not_affine() {
        let m = generators::skewed_square(4, 0.3);
        assert!(all_jacobians_positive(&m));
        let (worst, _) = jacobian_ratio_extremes(&m);
        assert!(worst < 1.0 - 1e-6);
    }

    #[test]
    fn inverted_cell_detected() {
        // deliberately build a bow-tie (self-intersecting) quad
        let pts = vec![[0.0, 0.0], [1.0, 0.0], [0.0, 1.0], [1.0, 1.0]];
        let m = QuadMesh::new(pts, vec![[0, 1, 2, 3]]).unwrap();
        assert!(!all_jacobians_positive(&m));
    }

    #[test]
    fn rect_aspect() {
        let m = generators::rect_grid(1, 1, 0.0, 0.0, 4.0, 1.0);
        assert!((aspect_ratio(&m, 0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn report_fields() {
        let m = generators::disk(4, 3, 0.0, 0.0, 1.0);
        let r = report(&m);
        assert_eq!(r.n_cells, m.n_cells());
        assert!(r.all_valid);
        assert!(r.area > 3.0 && r.area < 3.2);
    }
}
