//! Gmsh `.msh` v2.2 ASCII reader/writer (quads + boundary lines).
//!
//! The paper's gear mesh was produced with Gmsh; this module lets users
//! bring their own meshes while the generators cover the built-in
//! workloads.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::QuadMesh;

/// Parse a Gmsh v2.2 ASCII file. Quad elements (type 3) become cells;
/// line elements (type 1) become tagged boundary edges (first tag).
pub fn read(path: impl AsRef<Path>) -> Result<QuadMesh> {
    let text = fs::read_to_string(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    parse(&text)
}

/// Parse Gmsh 2.2 ASCII text into a [`QuadMesh`].
pub fn parse(text: &str) -> Result<QuadMesh> {
    let mut lines = text.lines().peekable();
    let mut node_ids: HashMap<usize, usize> = HashMap::new();
    let mut points: Vec<[f64; 2]> = Vec::new();
    let mut cells: Vec<[usize; 4]> = Vec::new();
    let mut tagged: Vec<(usize, usize, u32)> = Vec::new();

    while let Some(line) = lines.next() {
        match line.trim() {
            "$MeshFormat" => {
                let fmt = lines.next().context("truncated $MeshFormat")?;
                let ver: f64 = fmt
                    .split_whitespace()
                    .next()
                    .context("bad format line")?
                    .parse()?;
                if !(2.0..3.0).contains(&ver) {
                    bail!("only msh v2.x supported, got {ver}");
                }
                expect_end(&mut lines, "$EndMeshFormat")?;
            }
            "$Nodes" => {
                let n: usize =
                    lines.next().context("truncated $Nodes")?.trim()
                        .parse()?;
                for _ in 0..n {
                    let l = lines.next().context("truncated node list")?;
                    let mut it = l.split_whitespace();
                    let id: usize = it.next().context("bad node")?.parse()?;
                    let x: f64 = it.next().context("bad node")?.parse()?;
                    let y: f64 = it.next().context("bad node")?.parse()?;
                    node_ids.insert(id, points.len());
                    points.push([x, y]);
                }
                expect_end(&mut lines, "$EndNodes")?;
            }
            "$Elements" => {
                let n: usize =
                    lines.next().context("truncated $Elements")?.trim()
                        .parse()?;
                for _ in 0..n {
                    let l = lines.next().context("truncated element list")?;
                    let toks: Vec<usize> = l
                        .split_whitespace()
                        .map(|t| t.parse::<usize>())
                        .collect::<std::result::Result<_, _>>()?;
                    if toks.len() < 3 {
                        bail!("bad element line: {l}");
                    }
                    let etype = toks[1];
                    let ntags = toks[2];
                    let conn = &toks[3 + ntags..];
                    let tag = if ntags > 0 { toks[3] as u32 } else { 0 };
                    match etype {
                        3 => {
                            if conn.len() != 4 {
                                bail!("quad with {} nodes", conn.len());
                            }
                            let mut c = [0usize; 4];
                            for (k, id) in conn.iter().enumerate() {
                                c[k] = *node_ids
                                    .get(id)
                                    .with_context(|| format!(
                                        "element references unknown node {id}"
                                    ))?;
                            }
                            cells.push(c);
                        }
                        1 => {
                            let a = *node_ids.get(&conn[0])
                                .context("unknown node")?;
                            let b = *node_ids.get(&conn[1])
                                .context("unknown node")?;
                            tagged.push((a, b, tag));
                        }
                        15 => {} // points: ignore
                        _ => {}  // other element types: ignore
                    }
                }
                expect_end(&mut lines, "$EndElements")?;
            }
            _ => {}
        }
    }

    if cells.is_empty() {
        bail!("no quad elements found");
    }
    let mut mesh = QuadMesh::new(points, cells)?;
    // apply tags from $Elements line entries to computed boundary
    if !tagged.is_empty() {
        let tag_of: HashMap<(usize, usize), u32> = tagged
            .iter()
            .map(|&(a, b, t)| ((a.min(b), a.max(b)), t))
            .collect();
        for e in &mut mesh.boundary {
            if let Some(&t) = tag_of.get(&(e.a.min(e.b), e.a.max(e.b))) {
                e.tag = t;
            }
        }
    }
    Ok(mesh)
}

fn expect_end<'a, I: Iterator<Item = &'a str>>(
    lines: &mut I, end: &str,
) -> Result<()> {
    match lines.next() {
        Some(l) if l.trim() == end => Ok(()),
        other => bail!("expected {end}, got {other:?}"),
    }
}

/// Write a mesh as Gmsh v2.2 ASCII (quads + tagged boundary lines).
pub fn write(mesh: &QuadMesh, path: impl AsRef<Path>) -> Result<()> {
    let mut s = String::new();
    s.push_str("$MeshFormat\n2.2 0 8\n$EndMeshFormat\n$Nodes\n");
    let _ = writeln!(s, "{}", mesh.n_points());
    for (i, p) in mesh.points.iter().enumerate() {
        let _ = writeln!(s, "{} {} {} 0", i + 1, p[0], p[1]);
    }
    s.push_str("$EndNodes\n$Elements\n");
    let _ = writeln!(s, "{}", mesh.n_cells() + mesh.boundary.len());
    let mut eid = 1;
    for e in &mesh.boundary {
        let _ = writeln!(s, "{eid} 1 2 {} 0 {} {}", e.tag, e.a + 1,
                         e.b + 1);
        eid += 1;
    }
    for c in &mesh.cells {
        let _ = writeln!(s, "{eid} 3 2 0 0 {} {} {} {}", c[0] + 1,
                         c[1] + 1, c[2] + 1, c[3] + 1);
        eid += 1;
    }
    s.push_str("$EndElements\n");
    fs::write(path.as_ref(), s)
        .with_context(|| format!("write {}", path.as_ref().display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generators;

    const SAMPLE: &str = "\
$MeshFormat
2.2 0 8
$EndMeshFormat
$Nodes
6
1 0 0 0
2 1 0 0
3 2 0 0
4 0 1 0
5 1 1 0
6 2 1 0
$EndNodes
$Elements
4
1 3 2 0 0 1 2 5 4
2 3 2 0 0 2 3 6 5
3 1 2 7 0 1 2
4 1 2 7 0 2 3
$EndElements
";

    #[test]
    fn parses_sample() {
        let m = parse(SAMPLE).unwrap();
        assert_eq!(m.n_points(), 6);
        assert_eq!(m.n_cells(), 2);
        assert!((m.area() - 2.0).abs() < 1e-12);
        // bottom edges carry tag 7
        let bottom: Vec<_> = m
            .boundary
            .iter()
            .filter(|e| m.points[e.a][1] < 1e-9 && m.points[e.b][1] < 1e-9)
            .collect();
        assert_eq!(bottom.len(), 2);
        assert!(bottom.iter().all(|e| e.tag == 7));
    }

    #[test]
    fn roundtrip_gear() {
        let m = generators::gear(6, 5, 3, 0.4, 0.8, 1.0);
        let p = std::env::temp_dir().join("fastvpinns_gear.msh");
        write(&m, &p).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back.n_cells(), m.n_cells());
        assert_eq!(back.n_points(), m.n_points());
        assert!((back.area() - m.area()).abs() < 1e-9);
    }

    #[test]
    fn property_write_read_roundtrip_random_jittered_meshes() {
        // Random rectangle grids with jittered interior nodes and
        // randomly tagged boundary edges must survive write -> parse
        // exactly: f64 Display output round-trips, node/cell order is
        // preserved, and tags reattach by edge identity.
        use crate::util::proptest::check_result;
        // pid-unique path: concurrent test processes must not collide
        let path = std::env::temp_dir().join(format!(
            "fastvpinns_prop_rt_{}.msh", std::process::id()));
        check_result(
            31,
            40,
            |r| {
                let nx = 1 + r.below(4);
                let ny = 1 + r.below(4);
                let mut m = generators::rect_grid(
                    nx, ny, -1.0, 0.5, 1.0, 2.0);
                let h = 0.2 / nx.max(ny) as f64;
                for p in &mut m.points {
                    let interior = p[0] > -1.0 + 1e-9 && p[0] < 1.0 - 1e-9
                        && p[1] > 0.5 + 1e-9 && p[1] < 2.0 - 1e-9;
                    if interior {
                        p[0] += r.uniform_in(-h, h);
                        p[1] += r.uniform_in(-h, h);
                    }
                }
                for e in &mut m.boundary {
                    e.tag = r.below(5) as u32;
                }
                m
            },
            |m| {
                write(m, &path).map_err(|e| e.to_string())?;
                let back = read(&path).map_err(|e| e.to_string())?;
                if back.points != m.points {
                    return Err("points changed in roundtrip".into());
                }
                if back.cells != m.cells {
                    return Err("cells changed in roundtrip".into());
                }
                if back.boundary != m.boundary {
                    return Err(format!(
                        "boundary changed: {:?} vs {:?}",
                        back.boundary, m.boundary
                    ));
                }
                Ok(())
            },
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_v4() {
        let bad = "$MeshFormat\n4.1 0 8\n$EndMeshFormat\n";
        assert!(parse(bad).is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse("$MeshFormat\n2.2 0 8\n$EndMeshFormat\n").is_err());
    }
}
