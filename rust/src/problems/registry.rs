//! The single problem registry: every CLI-trainable problem family in
//! one table. `repro train --problem <name>` dispatch *and* the USAGE
//! problem list are both derived from [`REGISTRY`], so the help text
//! cannot drift from the supported set.
//!
//! Each entry builds a ready-to-train [`ProblemSetup`] (mesh, problem,
//! native loss mode, sensor count) from CLI flags; the backend derives
//! the [`VariationalForm`](crate::runtime::backend::VariationalForm)
//! coefficient tables from the problem itself, so a new PDE is one
//! `Problem` impl plus one registry line.

use anyhow::Result;

use crate::coordinator::schedule::LrSchedule;
use crate::mesh::{generators, QuadMesh};
use crate::problems::{self, Problem};
use crate::runtime::backend::native::NativeLoss;
use crate::util::cli::Args;

/// Everything `repro train` needs for one named problem family.
pub struct ProblemSetup {
    /// The mesh this family trains on.
    pub mesh: QuadMesh,
    /// The PDE instance (coefficients, forcing, exact solution).
    pub problem: Box<dyn Problem>,
    /// Native loss *mode* (the PDE coefficients live on the problem).
    pub loss: NativeLoss,
    /// Sensor count (inverse modes).
    pub ns: usize,
    /// Default iteration budget for this family (`--iters` overrides);
    /// weak-forcing problems need longer to escape the early
    /// boundary-dominated plateau.
    pub iters: usize,
    /// Default learning-rate schedule (`--lr F` overrides with a
    /// constant rate).
    pub lr: LrSchedule,
    /// Ground-truth eps field for post-training evaluation
    /// (inverse-space problems with a manufactured field).
    pub eps_star: Option<fn(f64, f64) -> f64>,
}

/// One registry row.
pub struct Entry {
    /// CLI name (`--problem <name>`).
    pub name: &'static str,
    /// One-line summary for the CLI help.
    pub summary: &'static str,
    /// Build the ready-to-train setup from CLI flags.
    pub build: fn(&Args) -> Result<ProblemSetup>,
}

/// The registry — the only list of trainable problems in the tree.
pub const REGISTRY: &[Entry] = &[
    Entry {
        name: "poisson_sin",
        summary: "-lap u = f, exact sin(wx)sin(wy) on (0,1)^2 (SS4.6)",
        build: build_poisson_sin,
    },
    Entry {
        name: "cd_gear",
        summary: "convection-diffusion on the 1760-cell spur gear (Fig 12)",
        build: build_cd_gear,
    },
    Entry {
        name: "helmholtz",
        summary: "-lap u - k^2 u = f via the reaction term (c = -k^2)",
        build: build_helmholtz,
    },
    Entry {
        name: "cd_var",
        summary: "rotating convection field b(x,y) via hoisted b tables",
        build: build_cd_var,
    },
    Entry {
        name: "inverse_const",
        summary: "recover the scalar eps = 0.3 from sensors (SS4.7.1)",
        build: build_inverse_const,
    },
    Entry {
        name: "inverse_space",
        summary: "recover the eps(x,y) field with the two-head net (SS4.7.2)",
        build: build_inverse_space,
    },
];

/// Look a problem family up by its CLI name.
pub fn lookup(name: &str) -> Option<&'static Entry> {
    REGISTRY.iter().find(|e| e.name == name)
}

/// `"a|b|c"` — the USAGE string's problem list.
pub fn name_list() -> String {
    REGISTRY
        .iter()
        .map(|e| e.name)
        .collect::<Vec<_>>()
        .join("|")
}

fn build_poisson_sin(args: &Args) -> Result<ProblemSetup> {
    let omega = args.f64_or("omega-pi", 2.0)? * std::f64::consts::PI;
    let n = args.usize_or("n", 4)?;
    Ok(ProblemSetup {
        mesh: generators::unit_square(n.max(1)),
        problem: Box::new(problems::PoissonSin::new(omega)),
        loss: NativeLoss::Forward,
        ns: 0,
        iters: 5000,
        lr: LrSchedule::Constant(5e-3),
        eps_star: None,
    })
}

fn build_cd_gear(_args: &Args) -> Result<ProblemSetup> {
    Ok(ProblemSetup {
        mesh: generators::gear_ci(),
        problem: Box::new(problems::GearCd),
        loss: NativeLoss::Forward,
        ns: 0,
        iters: 5000,
        lr: LrSchedule::Constant(5e-3),
        eps_star: None,
    })
}

fn build_helmholtz(args: &Args) -> Result<ProblemSetup> {
    // default k = 2pi, mirroring poisson_sin's omega default: the
    // forcing scales with k^2, so larger k strengthens the variational
    // signal against the boundary penalty (k = pi trains much slower
    // at this mesh scale; it stays reachable via --k-pi 1)
    let k = args.f64_or("k-pi", 2.0)? * std::f64::consts::PI;
    // coarse 2x2 mesh with high-order tests (the CLI's nt1d=5/nq1d=10):
    // the per-element forcing projections scale with the element
    // measure, so the coarse mesh keeps the variational signal strong
    // against the boundary penalty — on finer meshes the run collapses
    // into the u ~ 0 boundary-satisfying saddle and the (k^2-weak)
    // forcing cannot pull it out within the budget. The decayed-lr
    // 12000-iter default escapes the saddle at full rate, then the
    // tight tail (~3e-4 by the end) damps the late rel-L2 wander that
    // a constant rate shows near the accuracy floor. Exact-Rust-init
    // numpy replicas (RustRng port): rel-L2 6.4e-3 (seed 42), 7.8e-3
    // (seed 1) at 12000 — under the 1e-2 acceptance bar with margin.
    let n = args.usize_or("n", 2)?;
    Ok(ProblemSetup {
        mesh: generators::unit_square(n.max(1)),
        problem: Box::new(problems::Helmholtz2D::new(k)),
        loss: NativeLoss::Forward,
        ns: 0,
        iters: 12_000,
        lr: LrSchedule::ExpDecay { lr0: 5e-3, factor: 0.7, every: 1500 },
        eps_star: None,
    })
}

fn build_cd_var(args: &Args) -> Result<ProblemSetup> {
    let n = args.usize_or("n", 4)?;
    Ok(ProblemSetup {
        mesh: generators::unit_square(n.max(1)),
        problem: Box::new(problems::VariableConvectionCd::new()),
        loss: NativeLoss::Forward,
        ns: 0,
        iters: 5000,
        lr: LrSchedule::Constant(5e-3),
        eps_star: None,
    })
}

fn build_inverse_const(args: &Args) -> Result<ProblemSetup> {
    Ok(ProblemSetup {
        mesh: generators::rect_grid(2, 2, -1.0, -1.0, 1.0, 1.0),
        problem: Box::new(problems::InverseConstPoisson::new()),
        loss: NativeLoss::InverseConst,
        ns: args.usize_or("ns", 50)?,
        iters: 5000,
        lr: LrSchedule::Constant(5e-3),
        eps_star: None,
    })
}

fn build_inverse_space(args: &Args) -> Result<ProblemSetup> {
    let n = args.usize_or("n", 2)?;
    Ok(ProblemSetup {
        mesh: generators::unit_square(n.max(1)),
        problem: Box::new(problems::InverseSpaceSin),
        loss: NativeLoss::InverseSpace,
        ns: args.usize_or("ns", 200)?,
        iters: 5000,
        lr: LrSchedule::Constant(5e-3),
        eps_star: Some(problems::InverseSpaceSin::eps_actual),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_builds_with_default_args() {
        let args = Args::default();
        for e in REGISTRY {
            let setup = (e.build)(&args)
                .unwrap_or_else(|err| panic!("{} failed: {err}", e.name));
            assert!(setup.mesh.n_cells() > 0, "{}: empty mesh", e.name);
            // forcing/boundary must be evaluable on the mesh bbox
            let (lo, _hi) = setup.mesh.bbox();
            let f = setup.problem.forcing(lo[0], lo[1]);
            assert!(f.is_finite(), "{}: non-finite forcing", e.name);
            match setup.loss {
                NativeLoss::InverseConst | NativeLoss::InverseSpace => {
                    assert!(setup.ns > 0, "{}: inverse needs sensors",
                            e.name)
                }
                NativeLoss::Forward => assert_eq!(setup.ns, 0),
            }
        }
    }

    #[test]
    fn lookup_and_name_list_agree_with_the_registry() {
        assert!(lookup("helmholtz").is_some());
        assert!(lookup("cd_var").is_some());
        assert!(lookup("nope").is_none());
        let list = name_list();
        for e in REGISTRY {
            assert!(list.contains(e.name), "{} missing from {list}",
                    e.name);
        }
        // names are unique
        for (i, a) in REGISTRY.iter().enumerate() {
            for b in &REGISTRY[i + 1..] {
                assert_ne!(a.name, b.name);
            }
        }
    }
}
