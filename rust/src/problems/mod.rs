//! Every PDE instance the paper evaluates, as `Problem` trait objects:
//! forcing term, Dirichlet data, exact solution (when analytic) and
//! the coefficient fields of the weak form. Forcing terms for
//! manufactured solutions are derived with the `autodiff` substrate —
//! no hand calculus.
//!
//! A `Problem` fully describes the PDE
//! `-div(eps(x,y) grad u) + b(x,y) . grad u + c(x,y) u = f`:
//! the backend hoists `eps_at`/`b_at`/`c_at` into a
//! [`VariationalForm`](crate::runtime::backend::VariationalForm) once
//! and the same tensor contraction covers Poisson, convection–
//! diffusion, Helmholtz (`c = -k²`) and any coefficient field — adding
//! a PDE is implementing this trait, not forking the hot path.
//! [`registry`] maps CLI `--problem` names to ready-to-train setups.

pub mod registry;

use crate::autodiff::{probe_2d, Dual2};

/// Which coefficients of a problem vary in space. Constant
/// coefficients take the backend's scalar fast path; variable ones are
/// tabulated once per quadrature point (`eps_at`/`b_at`/`c_at`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoeffVariability {
    /// Diffusion eps(x, y) varies in space.
    pub eps: bool,
    /// Convection b(x, y) varies in space.
    pub b: bool,
    /// Reaction c(x, y) varies in space.
    pub c: bool,
}

impl CoeffVariability {
    /// All coefficients spatially constant (the common case).
    pub const CONST: CoeffVariability =
        CoeffVariability { eps: false, b: false, c: false };
}

/// A scalar 2D second-order problem instance
/// `-div(eps grad u) + b . grad u + c u = f` with Dirichlet data.
pub trait Problem {
    /// Stable instance label (may encode parameters, e.g.
    /// `helmholtz_k6.283`).
    fn name(&self) -> &str;
    /// Source term f(x, y).
    fn forcing(&self, x: f64, y: f64) -> f64;
    /// Dirichlet boundary value g(x, y).
    fn boundary(&self, x: f64, y: f64) -> f64;
    /// Analytic solution, when available.
    fn exact(&self, _x: f64, _y: f64) -> Option<f64> {
        None
    }
    /// Diffusion coefficient (constant problems).
    fn eps(&self) -> f64 {
        1.0
    }
    /// Convection velocity (constant problems).
    fn b(&self) -> (f64, f64) {
        (0.0, 0.0)
    }
    /// Reaction coefficient (constant problems; Helmholtz: `-k²`).
    fn c(&self) -> f64 {
        0.0
    }
    /// Diffusion field eps(x, y); defaults to the constant [`Problem::eps`].
    fn eps_at(&self, _x: f64, _y: f64) -> f64 {
        self.eps()
    }
    /// Convection field b(x, y); defaults to the constant [`Problem::b`].
    fn b_at(&self, _x: f64, _y: f64) -> (f64, f64) {
        self.b()
    }
    /// Reaction field c(x, y); defaults to the constant [`Problem::c`].
    fn c_at(&self, _x: f64, _y: f64) -> f64 {
        self.c()
    }
    /// Which coefficient fields vary in space (drives table hoisting).
    fn coeff_variability(&self) -> CoeffVariability {
        CoeffVariability::CONST
    }
}

/// Wrapper overriding which coefficients of `P` take the tabulated
/// (generalized) path even when spatially constant — the bench harness
/// and the contraction regression tests use it to time/compare the
/// table path against the scalar fast path on the *same* PDE.
pub struct ForceVariable<P: Problem> {
    inner: P,
    var: CoeffVariability,
}

impl<P: Problem> ForceVariable<P> {
    /// Force *every* coefficient onto the table path.
    pub fn new(inner: P) -> Self {
        ForceVariable {
            inner,
            var: CoeffVariability { eps: true, b: true, c: true },
        }
    }

    /// Force only the selected coefficients onto the table path.
    pub fn with(inner: P, var: CoeffVariability) -> Self {
        ForceVariable { inner, var }
    }
}

impl<P: Problem> Problem for ForceVariable<P> {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn forcing(&self, x: f64, y: f64) -> f64 {
        self.inner.forcing(x, y)
    }
    fn boundary(&self, x: f64, y: f64) -> f64 {
        self.inner.boundary(x, y)
    }
    fn exact(&self, x: f64, y: f64) -> Option<f64> {
        self.inner.exact(x, y)
    }
    fn eps(&self) -> f64 {
        self.inner.eps()
    }
    fn b(&self) -> (f64, f64) {
        self.inner.b()
    }
    fn c(&self) -> f64 {
        self.inner.c()
    }
    fn eps_at(&self, x: f64, y: f64) -> f64 {
        self.inner.eps_at(x, y)
    }
    fn b_at(&self, x: f64, y: f64) -> (f64, f64) {
        self.inner.b_at(x, y)
    }
    fn c_at(&self, x: f64, y: f64) -> f64 {
        self.inner.c_at(x, y)
    }
    fn coeff_variability(&self) -> CoeffVariability {
        self.var
    }
}

// ---------------------------------------------------------------------
// Poisson sin(omega x) sin(omega y) family (SS4.6)
// ---------------------------------------------------------------------

/// `-lap u = -2 omega^2 sin(omega x) sin(omega y)` on (0,1)^2, exact
/// solution `u = -sin(omega x) sin(omega y)` (paper SS4.6).
pub struct PoissonSin {
    /// Frequency of the manufactured solution.
    pub omega: f64,
    label: String,
}

impl PoissonSin {
    /// The problem at frequency `omega`.
    pub fn new(omega: f64) -> Self {
        PoissonSin { omega, label: format!("poisson_sin_w{omega:.3}") }
    }
}

impl Problem for PoissonSin {
    fn name(&self) -> &str {
        &self.label
    }

    fn forcing(&self, x: f64, y: f64) -> f64 {
        let om = self.omega;
        -2.0 * om * om * (om * x).sin() * (om * y).sin()
    }

    fn boundary(&self, x: f64, y: f64) -> f64 {
        self.exact(x, y).unwrap()
    }

    fn exact(&self, x: f64, y: f64) -> Option<f64> {
        Some(-(self.omega * x).sin() * (self.omega * y).sin())
    }
}

/// Convenience constructor.
pub fn poisson_sin(omega: f64) -> Box<dyn Problem> {
    Box::new(PoissonSin::new(omega))
}

// ---------------------------------------------------------------------
// Gear convection-diffusion (SS4.6.4, Fig. 12)
// ---------------------------------------------------------------------

/// `-eps lap u + b . grad u = 50 sin(x) + cos(x)` on the gear domain,
/// u = 0 on the boundary; eps = 1, b = (0.1, 0). No analytic solution —
/// the FEM solver provides the reference field.
pub struct GearCd;

impl Problem for GearCd {
    fn name(&self) -> &str {
        "gear_cd"
    }

    fn forcing(&self, x: f64, _y: f64) -> f64 {
        50.0 * x.sin() + x.cos()
    }

    fn boundary(&self, _x: f64, _y: f64) -> f64 {
        0.0
    }

    fn b(&self) -> (f64, f64) {
        (0.1, 0.0)
    }
}

// ---------------------------------------------------------------------
// Helmholtz (paper SS4.6: same kernel, reaction term c = -k^2)
// ---------------------------------------------------------------------

/// `-lap u - k^2 u = f` on (0,1)^2 with the manufactured exact solution
/// `u = sin(k x) sin(k y)` — the weak form is the Poisson contraction
/// plus a mass term `c = -k^2` against the same `V` premultiplier.
/// Forcing derived via Dual2 probes. Well-posed for `k^2` away from the
/// Dirichlet Laplacian spectrum `pi^2 (m^2 + n^2)`, coercive below
/// `2 pi^2`.
pub struct Helmholtz2D {
    /// Wavenumber.
    pub k: f64,
    label: String,
}

impl Helmholtz2D {
    /// The problem at wavenumber `k`.
    pub fn new(k: f64) -> Self {
        Helmholtz2D { k, label: format!("helmholtz_k{k:.3}") }
    }

    fn u_dual(&self, x: Dual2, y: Dual2) -> Dual2 {
        (x * self.k).sin() * (y * self.k).sin()
    }
}

impl Problem for Helmholtz2D {
    fn name(&self) -> &str {
        &self.label
    }

    fn forcing(&self, x: f64, y: f64) -> f64 {
        // f = -lap u + c u with c = -k^2
        let p = probe_2d(|a, b| self.u_dual(a, b), x, y);
        -p.lap + self.c() * p.u
    }

    fn boundary(&self, x: f64, y: f64) -> f64 {
        self.exact(x, y).unwrap()
    }

    fn exact(&self, x: f64, y: f64) -> Option<f64> {
        Some((self.k * x).sin() * (self.k * y).sin())
    }

    fn c(&self) -> f64 {
        -self.k * self.k
    }
}

// ---------------------------------------------------------------------
// Variable-convection cd (a b(x,y) field through the same kernel)
// ---------------------------------------------------------------------

/// `-eps lap u + b(x,y) . grad u = f` on (0,1)^2 with the rotating
/// convection field `b = omega_r (y - 1/2, 1/2 - x)` and manufactured
/// exact `u = sin(pi x) sin(pi y)`; forcing via Dual2. The `b` tables
/// are hoisted per quadrature point — no per-step evaluation.
pub struct VariableConvectionCd {
    /// Constant diffusion coefficient.
    pub eps0: f64,
    /// Angular rate of the rotating field.
    pub omega_r: f64,
}

impl VariableConvectionCd {
    /// The standard instance (eps = 1, omega_r = 2).
    pub fn new() -> Self {
        VariableConvectionCd { eps0: 1.0, omega_r: 2.0 }
    }

    fn u_dual(x: Dual2, y: Dual2) -> Dual2 {
        (x * std::f64::consts::PI).sin() * (y * std::f64::consts::PI).sin()
    }
}

impl Default for VariableConvectionCd {
    fn default() -> Self {
        Self::new()
    }
}

impl Problem for VariableConvectionCd {
    fn name(&self) -> &str {
        "cd_var"
    }

    fn forcing(&self, x: f64, y: f64) -> f64 {
        // f = -eps lap u + b(x,y) . grad u
        let u = probe_2d(Self::u_dual, x, y);
        let (bx, by) = self.b_at(x, y);
        -self.eps0 * u.lap + bx * u.dx + by * u.dy
    }

    fn boundary(&self, x: f64, y: f64) -> f64 {
        self.exact(x, y).unwrap()
    }

    fn exact(&self, x: f64, y: f64) -> Option<f64> {
        Some((std::f64::consts::PI * x).sin()
            * (std::f64::consts::PI * y).sin())
    }

    fn eps(&self) -> f64 {
        self.eps0
    }

    fn b_at(&self, x: f64, y: f64) -> (f64, f64) {
        (self.omega_r * (y - 0.5), self.omega_r * (0.5 - x))
    }

    fn coeff_variability(&self) -> CoeffVariability {
        CoeffVariability { eps: false, b: true, c: false }
    }
}

// ---------------------------------------------------------------------
// Inverse: constant diffusion (SS4.7.1, Fig. 14)
// ---------------------------------------------------------------------

/// `-eps lap u = f` on (-1,1)^2 with exact
/// `u = 10 sin(x) tanh(x) exp(-eps_actual x^2)`, eps_actual = 0.3.
/// The forcing is manufactured via Dual2 so the trainable eps must
/// converge to eps_actual.
pub struct InverseConstPoisson {
    /// Ground-truth diffusion constant the run must recover.
    pub eps_actual: f64,
}

impl InverseConstPoisson {
    /// The paper's instance (eps_actual = 0.3).
    pub fn new() -> Self {
        InverseConstPoisson { eps_actual: 0.3 }
    }

    fn u_dual(&self, x: Dual2, _y: Dual2) -> Dual2 {
        let e = self.eps_actual;
        x.sin() * x.tanh() * ((x * x) * (-e)).exp() * 10.0
    }
}

impl Default for InverseConstPoisson {
    fn default() -> Self {
        Self::new()
    }
}

impl Problem for InverseConstPoisson {
    fn name(&self) -> &str {
        "inverse_const_poisson"
    }

    fn forcing(&self, x: f64, y: f64) -> f64 {
        // f = -eps_actual * lap(u_exact)
        let p = probe_2d(|a, b| self.u_dual(a, b), x, y);
        -self.eps_actual * p.lap
    }

    fn boundary(&self, x: f64, y: f64) -> f64 {
        self.exact(x, y).unwrap()
    }

    fn exact(&self, x: f64, _y: f64) -> Option<f64> {
        let e = self.eps_actual;
        Some(10.0 * x.sin() * x.tanh() * (-e * x * x).exp())
    }

    fn eps(&self) -> f64 {
        self.eps_actual
    }
}

// ---------------------------------------------------------------------
// Inverse: space-dependent diffusion (SS4.7.2, Fig. 15)
// ---------------------------------------------------------------------

/// `-div(eps(x,y) grad u) + u_x = 10` on the unit disk, u = 0 on the
/// boundary; eps_actual = 0.5 (sin x + cos y). FEM provides u_ref.
pub struct InverseSpaceCd;

impl InverseSpaceCd {
    /// The paper's ground-truth diffusion field.
    pub fn eps_actual(x: f64, y: f64) -> f64 {
        0.5 * (x.sin() + y.cos())
    }
}

impl Problem for InverseSpaceCd {
    fn name(&self) -> &str {
        "inverse_space_cd"
    }

    fn forcing(&self, _x: f64, _y: f64) -> f64 {
        10.0
    }

    fn boundary(&self, _x: f64, _y: f64) -> f64 {
        0.0
    }

    fn b(&self) -> (f64, f64) {
        (1.0, 0.0)
    }

    // the *true* diffusion field: the inverse-space loss replaces it
    // with the trainable head, but the FEM reference solve and any
    // forward run see the ground truth through the trait
    fn eps_at(&self, x: f64, y: f64) -> f64 {
        Self::eps_actual(x, y)
    }

    fn coeff_variability(&self) -> CoeffVariability {
        CoeffVariability { eps: true, b: false, c: false }
    }
}

// ---------------------------------------------------------------------
// Inverse: space-dependent diffusion, manufactured (native tests/CLI)
// ---------------------------------------------------------------------

/// `-div(eps(x,y) grad u) + u_x = f` on (0,1)^2 with the paper's
/// eps_actual = 0.5 (sin x + cos y) but a manufactured exact solution
/// `u = sin(pi x) sin(pi y)` — the forcing is derived with Dual2
/// probes, so sensors can be fed from `exact` with no FEM solve. This
/// is the CI-scale counterpart of [`InverseSpaceCd`] (whose reference
/// field comes from FEM on the disk).
pub struct InverseSpaceSin;

impl InverseSpaceSin {
    /// The paper's field — delegates to [`InverseSpaceCd::eps_actual`]
    /// so the CI-scale problem cannot drift from the fig15 reference.
    pub fn eps_actual(x: f64, y: f64) -> f64 {
        InverseSpaceCd::eps_actual(x, y)
    }

    fn u_dual(x: Dual2, y: Dual2) -> Dual2 {
        (x * std::f64::consts::PI).sin() * (y * std::f64::consts::PI).sin()
    }

    fn eps_dual(x: Dual2, y: Dual2) -> Dual2 {
        (x.sin() + y.cos()) * 0.5
    }
}

impl Problem for InverseSpaceSin {
    fn name(&self) -> &str {
        "inverse_space_sin"
    }

    fn forcing(&self, x: f64, y: f64) -> f64 {
        // f = -(eps_x u_x + eps_y u_y + eps lap u) + b . grad u
        let u = probe_2d(Self::u_dual, x, y);
        let e = probe_2d(Self::eps_dual, x, y);
        let (bx, by) = self.b();
        -(e.dx * u.dx + e.dy * u.dy + e.u * u.lap) + bx * u.dx + by * u.dy
    }

    fn boundary(&self, x: f64, y: f64) -> f64 {
        self.exact(x, y).unwrap()
    }

    fn exact(&self, x: f64, y: f64) -> Option<f64> {
        Some((std::f64::consts::PI * x).sin()
            * (std::f64::consts::PI * y).sin())
    }

    fn b(&self) -> (f64, f64) {
        (1.0, 0.0)
    }

    // ground truth field (the inverse-space loss trains a head for it)
    fn eps_at(&self, x: f64, y: f64) -> f64 {
        Self::eps_actual(x, y)
    }

    fn coeff_variability(&self) -> CoeffVariability {
        CoeffVariability { eps: true, b: false, c: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_exact_satisfies_pde() {
        // -lap u == f pointwise
        let p = PoissonSin::new(2.0 * std::f64::consts::PI);
        for (x, y) in [(0.3, 0.7), (0.11, 0.95), (0.5, 0.5)] {
            let om = p.omega;
            let lap = 2.0 * om * om * (om * x).sin() * (om * y).sin();
            assert!((-lap - p.forcing(x, y)).abs() < 1e-9);
        }
    }

    #[test]
    fn poisson_boundary_zero_for_harmonic_omegas() {
        let p = PoissonSin::new(2.0 * std::f64::consts::PI);
        for t in [0.0, 0.31, 0.77, 1.0] {
            assert!(p.boundary(t, 0.0).abs() < 1e-9);
            assert!(p.boundary(0.0, t).abs() < 1e-9);
            assert!(p.boundary(t, 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_const_forcing_consistent_with_fd() {
        let p = InverseConstPoisson::new();
        let g = |x: f64| 10.0 * x.sin() * x.tanh() * (-0.3 * x * x).exp();
        let (x, y, h) = (0.4, -0.6, 1e-5);
        let lap_fd = (g(x + h) - 2.0 * g(x) + g(x - h)) / (h * h);
        let want = -0.3 * lap_fd;
        assert!((p.forcing(x, y) - want).abs() < 1e-4,
                "{} vs {}", p.forcing(x, y), want);
    }

    #[test]
    fn inverse_const_exact_matches_boundary() {
        let p = InverseConstPoisson::new();
        assert_eq!(p.exact(0.7, -1.0), Some(p.boundary(0.7, -1.0)));
    }

    #[test]
    fn gear_forcing_formula() {
        let g = GearCd;
        assert!((g.forcing(1.0, 5.0)
            - (50.0 * 1.0f64.sin() + 1.0f64.cos())).abs() < 1e-14);
        assert_eq!(g.b(), (0.1, 0.0));
    }

    #[test]
    fn inverse_space_sin_forcing_consistent_with_fd() {
        // f must equal -div(eps grad u) + u_x of the manufactured pair
        let p = InverseSpaceSin;
        let u = |x: f64, y: f64| {
            (std::f64::consts::PI * x).sin() * (std::f64::consts::PI * y).sin()
        };
        let e = InverseSpaceSin::eps_actual;
        let h = 1e-5;
        for (x, y) in [(0.3, 0.7), (0.52, 0.18), (0.9, 0.4)] {
            // flux divergence via central differences of eps*grad u
            let fx = |x: f64, y: f64| {
                e(x, y) * (u(x + h, y) - u(x - h, y)) / (2.0 * h)
            };
            let fy = |x: f64, y: f64| {
                e(x, y) * (u(x, y + h) - u(x, y - h)) / (2.0 * h)
            };
            let div = (fx(x + h, y) - fx(x - h, y)) / (2.0 * h)
                + (fy(x, y + h) - fy(x, y - h)) / (2.0 * h);
            let ux = (u(x + h, y) - u(x - h, y)) / (2.0 * h);
            let want = -div + ux;
            assert!((p.forcing(x, y) - want).abs() < 1e-4,
                    "({x},{y}): {} vs {}", p.forcing(x, y), want);
        }
    }

    #[test]
    fn inverse_space_sin_exact_on_boundary_and_eps_positive() {
        let p = InverseSpaceSin;
        for t in [0.0, 0.3, 0.77, 1.0] {
            assert!(p.boundary(t, 0.0).abs() < 1e-12);
            assert!(p.boundary(0.0, t).abs() < 1e-12);
        }
        for i in 0..50 {
            let t = i as f64 / 49.0;
            assert!(InverseSpaceSin::eps_actual(t, 1.0 - t) > 0.0);
        }
    }

    #[test]
    fn helmholtz_forcing_consistent_with_fd() {
        // f must equal -lap u - k^2 u of the manufactured solution
        let k = 2.5;
        let p = Helmholtz2D::new(k);
        let u = |x: f64, y: f64| (k * x).sin() * (k * y).sin();
        let h = 1e-5;
        for (x, y) in [(0.3, 0.7), (0.52, 0.18), (0.9, 0.4)] {
            let lap = (u(x + h, y) - 2.0 * u(x, y) + u(x - h, y)) / (h * h)
                + (u(x, y + h) - 2.0 * u(x, y) + u(x, y - h)) / (h * h);
            let want = -lap - k * k * u(x, y);
            assert!((p.forcing(x, y) - want).abs() < 1e-4,
                    "({x},{y}): {} vs {}", p.forcing(x, y), want);
        }
        assert_eq!(p.c(), -k * k);
        assert_eq!(p.coeff_variability(), CoeffVariability::CONST);
    }

    #[test]
    fn helmholtz_pi_has_zero_boundary() {
        let p = Helmholtz2D::new(std::f64::consts::PI);
        for t in [0.0, 0.31, 0.77, 1.0] {
            assert!(p.boundary(t, 0.0).abs() < 1e-12);
            assert!(p.boundary(0.0, t).abs() < 1e-12);
            assert!(p.boundary(t, 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn cd_var_forcing_consistent_with_fd() {
        let p = VariableConvectionCd::new();
        let u = |x: f64, y: f64| {
            (std::f64::consts::PI * x).sin() * (std::f64::consts::PI * y).sin()
        };
        let h = 1e-5;
        for (x, y) in [(0.3, 0.7), (0.52, 0.18), (0.9, 0.4)] {
            let lap = (u(x + h, y) - 2.0 * u(x, y) + u(x - h, y)) / (h * h)
                + (u(x, y + h) - 2.0 * u(x, y) + u(x, y - h)) / (h * h);
            let ux = (u(x + h, y) - u(x - h, y)) / (2.0 * h);
            let uy = (u(x, y + h) - u(x, y - h)) / (2.0 * h);
            let (bx, by) = p.b_at(x, y);
            let want = -p.eps() * lap + bx * ux + by * uy;
            assert!((p.forcing(x, y) - want).abs() < 1e-4,
                    "({x},{y}): {} vs {}", p.forcing(x, y), want);
        }
        assert!(p.coeff_variability().b);
        // the rotating field is divergence-free and vanishes at center
        assert_eq!(p.b_at(0.5, 0.5), (0.0, 0.0));
    }

    #[test]
    fn force_variable_delegates_everything_but_variability() {
        let p = ForceVariable::new(Helmholtz2D::new(2.0));
        let inner = Helmholtz2D::new(2.0);
        assert_eq!(p.forcing(0.3, 0.4), inner.forcing(0.3, 0.4));
        assert_eq!(p.eps_at(0.1, 0.9), inner.eps_at(0.1, 0.9));
        assert_eq!(p.c_at(0.1, 0.9), inner.c_at(0.1, 0.9));
        let v = p.coeff_variability();
        assert!(v.eps && v.b && v.c);
    }

    #[test]
    fn space_eps_range() {
        // on the unit disk, eps stays positive (needed for well-posedness)
        for i in 0..100 {
            let t = i as f64 * 0.0628;
            let (x, y) = (t.cos() * 0.9, t.sin() * 0.9);
            assert!(InverseSpaceCd::eps_actual(x, y) > 0.0);
        }
    }
}
