//! Every PDE instance the paper evaluates, as `Problem` trait objects:
//! forcing term, Dirichlet data, exact solution (when analytic) and
//! coefficients. Forcing terms for manufactured solutions are derived
//! with the `autodiff` substrate — no hand calculus.

use crate::autodiff::{probe_2d, Dual2};

/// A scalar 2D convection-diffusion problem instance.
pub trait Problem {
    fn name(&self) -> &str;
    /// Source term f(x, y).
    fn forcing(&self, x: f64, y: f64) -> f64;
    /// Dirichlet boundary value g(x, y).
    fn boundary(&self, x: f64, y: f64) -> f64;
    /// Analytic solution, when available.
    fn exact(&self, _x: f64, _y: f64) -> Option<f64> {
        None
    }
    /// Diffusion coefficient (constant problems).
    fn eps(&self) -> f64 {
        1.0
    }
    /// Convection velocity.
    fn b(&self) -> (f64, f64) {
        (0.0, 0.0)
    }
}

// ---------------------------------------------------------------------
// Poisson sin(omega x) sin(omega y) family (SS4.6)
// ---------------------------------------------------------------------

/// `-lap u = -2 omega^2 sin(omega x) sin(omega y)` on (0,1)^2, exact
/// solution `u = -sin(omega x) sin(omega y)` (paper SS4.6).
pub struct PoissonSin {
    pub omega: f64,
    label: String,
}

impl PoissonSin {
    pub fn new(omega: f64) -> Self {
        PoissonSin { omega, label: format!("poisson_sin_w{omega:.3}") }
    }
}

impl Problem for PoissonSin {
    fn name(&self) -> &str {
        &self.label
    }

    fn forcing(&self, x: f64, y: f64) -> f64 {
        let om = self.omega;
        -2.0 * om * om * (om * x).sin() * (om * y).sin()
    }

    fn boundary(&self, x: f64, y: f64) -> f64 {
        self.exact(x, y).unwrap()
    }

    fn exact(&self, x: f64, y: f64) -> Option<f64> {
        Some(-(self.omega * x).sin() * (self.omega * y).sin())
    }
}

/// Convenience constructor.
pub fn poisson_sin(omega: f64) -> Box<dyn Problem> {
    Box::new(PoissonSin::new(omega))
}

// ---------------------------------------------------------------------
// Gear convection-diffusion (SS4.6.4, Fig. 12)
// ---------------------------------------------------------------------

/// `-eps lap u + b . grad u = 50 sin(x) + cos(x)` on the gear domain,
/// u = 0 on the boundary; eps = 1, b = (0.1, 0). No analytic solution —
/// the FEM solver provides the reference field.
pub struct GearCd;

impl Problem for GearCd {
    fn name(&self) -> &str {
        "gear_cd"
    }

    fn forcing(&self, x: f64, _y: f64) -> f64 {
        50.0 * x.sin() + x.cos()
    }

    fn boundary(&self, _x: f64, _y: f64) -> f64 {
        0.0
    }

    fn b(&self) -> (f64, f64) {
        (0.1, 0.0)
    }
}

// ---------------------------------------------------------------------
// Inverse: constant diffusion (SS4.7.1, Fig. 14)
// ---------------------------------------------------------------------

/// `-eps lap u = f` on (-1,1)^2 with exact
/// `u = 10 sin(x) tanh(x) exp(-eps_actual x^2)`, eps_actual = 0.3.
/// The forcing is manufactured via Dual2 so the trainable eps must
/// converge to eps_actual.
pub struct InverseConstPoisson {
    pub eps_actual: f64,
}

impl InverseConstPoisson {
    pub fn new() -> Self {
        InverseConstPoisson { eps_actual: 0.3 }
    }

    fn u_dual(&self, x: Dual2, _y: Dual2) -> Dual2 {
        let e = self.eps_actual;
        x.sin() * x.tanh() * ((x * x) * (-e)).exp() * 10.0
    }
}

impl Default for InverseConstPoisson {
    fn default() -> Self {
        Self::new()
    }
}

impl Problem for InverseConstPoisson {
    fn name(&self) -> &str {
        "inverse_const_poisson"
    }

    fn forcing(&self, x: f64, y: f64) -> f64 {
        // f = -eps_actual * lap(u_exact)
        let p = probe_2d(|a, b| self.u_dual(a, b), x, y);
        -self.eps_actual * p.lap
    }

    fn boundary(&self, x: f64, y: f64) -> f64 {
        self.exact(x, y).unwrap()
    }

    fn exact(&self, x: f64, _y: f64) -> Option<f64> {
        let e = self.eps_actual;
        Some(10.0 * x.sin() * x.tanh() * (-e * x * x).exp())
    }

    fn eps(&self) -> f64 {
        self.eps_actual
    }
}

// ---------------------------------------------------------------------
// Inverse: space-dependent diffusion (SS4.7.2, Fig. 15)
// ---------------------------------------------------------------------

/// `-div(eps(x,y) grad u) + u_x = 10` on the unit disk, u = 0 on the
/// boundary; eps_actual = 0.5 (sin x + cos y). FEM provides u_ref.
pub struct InverseSpaceCd;

impl InverseSpaceCd {
    pub fn eps_actual(x: f64, y: f64) -> f64 {
        0.5 * (x.sin() + y.cos())
    }
}

impl Problem for InverseSpaceCd {
    fn name(&self) -> &str {
        "inverse_space_cd"
    }

    fn forcing(&self, _x: f64, _y: f64) -> f64 {
        10.0
    }

    fn boundary(&self, _x: f64, _y: f64) -> f64 {
        0.0
    }

    fn b(&self) -> (f64, f64) {
        (1.0, 0.0)
    }
}

// ---------------------------------------------------------------------
// Inverse: space-dependent diffusion, manufactured (native tests/CLI)
// ---------------------------------------------------------------------

/// `-div(eps(x,y) grad u) + u_x = f` on (0,1)^2 with the paper's
/// eps_actual = 0.5 (sin x + cos y) but a manufactured exact solution
/// `u = sin(pi x) sin(pi y)` — the forcing is derived with Dual2
/// probes, so sensors can be fed from `exact` with no FEM solve. This
/// is the CI-scale counterpart of [`InverseSpaceCd`] (whose reference
/// field comes from FEM on the disk).
pub struct InverseSpaceSin;

impl InverseSpaceSin {
    /// The paper's field — delegates to [`InverseSpaceCd::eps_actual`]
    /// so the CI-scale problem cannot drift from the fig15 reference.
    pub fn eps_actual(x: f64, y: f64) -> f64 {
        InverseSpaceCd::eps_actual(x, y)
    }

    fn u_dual(x: Dual2, y: Dual2) -> Dual2 {
        (x * std::f64::consts::PI).sin() * (y * std::f64::consts::PI).sin()
    }

    fn eps_dual(x: Dual2, y: Dual2) -> Dual2 {
        (x.sin() + y.cos()) * 0.5
    }
}

impl Problem for InverseSpaceSin {
    fn name(&self) -> &str {
        "inverse_space_sin"
    }

    fn forcing(&self, x: f64, y: f64) -> f64 {
        // f = -(eps_x u_x + eps_y u_y + eps lap u) + b . grad u
        let u = probe_2d(Self::u_dual, x, y);
        let e = probe_2d(Self::eps_dual, x, y);
        let (bx, by) = self.b();
        -(e.dx * u.dx + e.dy * u.dy + e.u * u.lap) + bx * u.dx + by * u.dy
    }

    fn boundary(&self, x: f64, y: f64) -> f64 {
        self.exact(x, y).unwrap()
    }

    fn exact(&self, x: f64, y: f64) -> Option<f64> {
        Some((std::f64::consts::PI * x).sin()
            * (std::f64::consts::PI * y).sin())
    }

    fn b(&self) -> (f64, f64) {
        (1.0, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_exact_satisfies_pde() {
        // -lap u == f pointwise
        let p = PoissonSin::new(2.0 * std::f64::consts::PI);
        for (x, y) in [(0.3, 0.7), (0.11, 0.95), (0.5, 0.5)] {
            let om = p.omega;
            let lap = 2.0 * om * om * (om * x).sin() * (om * y).sin();
            assert!((-lap - p.forcing(x, y)).abs() < 1e-9);
        }
    }

    #[test]
    fn poisson_boundary_zero_for_harmonic_omegas() {
        let p = PoissonSin::new(2.0 * std::f64::consts::PI);
        for t in [0.0, 0.31, 0.77, 1.0] {
            assert!(p.boundary(t, 0.0).abs() < 1e-9);
            assert!(p.boundary(0.0, t).abs() < 1e-9);
            assert!(p.boundary(t, 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_const_forcing_consistent_with_fd() {
        let p = InverseConstPoisson::new();
        let g = |x: f64| 10.0 * x.sin() * x.tanh() * (-0.3 * x * x).exp();
        let (x, y, h) = (0.4, -0.6, 1e-5);
        let lap_fd = (g(x + h) - 2.0 * g(x) + g(x - h)) / (h * h);
        let want = -0.3 * lap_fd;
        assert!((p.forcing(x, y) - want).abs() < 1e-4,
                "{} vs {}", p.forcing(x, y), want);
    }

    #[test]
    fn inverse_const_exact_matches_boundary() {
        let p = InverseConstPoisson::new();
        assert_eq!(p.exact(0.7, -1.0), Some(p.boundary(0.7, -1.0)));
    }

    #[test]
    fn gear_forcing_formula() {
        let g = GearCd;
        assert!((g.forcing(1.0, 5.0)
            - (50.0 * 1.0f64.sin() + 1.0f64.cos())).abs() < 1e-14);
        assert_eq!(g.b(), (0.1, 0.0));
    }

    #[test]
    fn inverse_space_sin_forcing_consistent_with_fd() {
        // f must equal -div(eps grad u) + u_x of the manufactured pair
        let p = InverseSpaceSin;
        let u = |x: f64, y: f64| {
            (std::f64::consts::PI * x).sin() * (std::f64::consts::PI * y).sin()
        };
        let e = InverseSpaceSin::eps_actual;
        let h = 1e-5;
        for (x, y) in [(0.3, 0.7), (0.52, 0.18), (0.9, 0.4)] {
            // flux divergence via central differences of eps*grad u
            let fx = |x: f64, y: f64| {
                e(x, y) * (u(x + h, y) - u(x - h, y)) / (2.0 * h)
            };
            let fy = |x: f64, y: f64| {
                e(x, y) * (u(x, y + h) - u(x, y - h)) / (2.0 * h)
            };
            let div = (fx(x + h, y) - fx(x - h, y)) / (2.0 * h)
                + (fy(x, y + h) - fy(x, y - h)) / (2.0 * h);
            let ux = (u(x + h, y) - u(x - h, y)) / (2.0 * h);
            let want = -div + ux;
            assert!((p.forcing(x, y) - want).abs() < 1e-4,
                    "({x},{y}): {} vs {}", p.forcing(x, y), want);
        }
    }

    #[test]
    fn inverse_space_sin_exact_on_boundary_and_eps_positive() {
        let p = InverseSpaceSin;
        for t in [0.0, 0.3, 0.77, 1.0] {
            assert!(p.boundary(t, 0.0).abs() < 1e-12);
            assert!(p.boundary(0.0, t).abs() < 1e-12);
        }
        for i in 0..50 {
            let t = i as f64 / 49.0;
            assert!(InverseSpaceSin::eps_actual(t, 1.0 - t) > 0.0);
        }
    }

    #[test]
    fn space_eps_range() {
        // on the unit disk, eps stays positive (needed for well-posedness)
        for i in 0..100 {
            let t = i as f64 * 0.0628;
            let (x, y) = (t.cos() * 0.9, t.sin() * 0.9);
            assert!(InverseSpaceCd::eps_actual(x, y) > 0.0);
        }
    }
}
