//! # FastVPINNs — tensor-driven hp-Variational PINNs
//!
//! Rust reproduction of *FastVPINNs: Tensor-Driven Acceleration of VPINNs
//! for Complex Geometries* (Anandh, Ghose, Jain, Ganesan, 2024), built
//! around a runtime-polymorphic [`runtime::backend::Backend`]:
//!
//! - **Native backend** (default) — the whole FastVPINNs train step in
//!   pure Rust, fully tensorized: quadrature points are batched into
//!   element blocks and the tanh-MLP forward (carrying spatial
//!   tangents), the variational residual against the precomputed
//!   premultiplier tensors `G_x`/`G_y`/`V`, and the hand-written
//!   reverse-mode backprop all run as cache-blocked micro-GEMMs
//!   ([`linalg::gemm`]), plus Dirichlet/sensor penalties and Adam.
//!   The *PDE* is decoupled from that hot path by the
//!   [`runtime::backend::VariationalForm`] layer: a problem's
//!   coefficient fields — diffusion `eps(x,y)`, convection `b(x,y)`,
//!   reaction `c(x,y)` (Helmholtz is `c = -k²`) — are hoisted once
//!   into scalars or per-quadrature-point tables and threaded through
//!   the same contraction,
//!   `r[e,j] = Σ_q eps_q (G_x ∂u/∂x + G_y ∂u/∂y) + Σ_q V (b_q·∇u +
//!   c_q u) − F`, so Poisson, convection–diffusion, Helmholtz and
//!   variable-coefficient scenarios all train on one kernel and a new
//!   PDE is a ~50-line [`problems::Problem`] impl plus a registry
//!   line. `NativeLoss` is just the *mode*: `Forward` (fixed
//!   coefficients), `InverseConst` (trainable scalar eps + sensors),
//!   `InverseSpace` (the two-head eps *field* from the network's
//!   softplus'd second head, entering the contraction per quadrature
//!   point). Element shards run on a persistent worker pool
//!   ([`coordinator::pool`]) with per-worker workspaces allocated
//!   once, so the step hot path spawns no threads and allocates
//!   nothing — and the fixed-order shard reduce keeps results
//!   bit-identical at any worker count. Trains offline with no
//!   Python, no artifacts and no XLA in the build graph (`repro
//!   bench` tracks its step time, tagged per PDE).
//! - **XLA backend** (`--features xla`) — executes AOT train steps
//!   (HLO + JSON manifest, produced once by `make artifacts` from the
//!   JAX/Pallas definitions under `python/compile`) on the PJRT CPU
//!   client. Same [`coordinator::trainer::Trainer`], same losses — the
//!   accelerated path.
//!
//! The rest of the stack is backend-agnostic: quad meshes and
//! generators, the mapped-FEM assembly of the premultiplier tensors, a
//! classical Q1 FEM reference solver, the training coordinator, and the
//! experiment/bench harness that regenerates every table and figure of
//! the paper.
//!
//! Trained models are not train-and-discard: any backend can export a
//! versioned on-disk artifact ([`runtime::checkpoint`]) carrying the
//! network weights (raw `f64` bits — reloaded predictions are
//! bit-identical), the Adam state for warm restart, the hoisted
//! weak-form coefficients and a domain fingerprint; the coordinator
//! writes them periodically with best-by-validation tracking, `repro
//! train --resume` continues the loss trajectory exactly, and
//! [`runtime::infer::InferenceSession`] (CLI: `repro infer`) serves
//! batched point-cloud queries from the artifact alone — the paper's
//! amortized-inference payoff (`repro bench` tracks points/sec). On
//! top of that sits [`serve`] (CLI: `repro serve`): a long-running
//! multi-model inference server that micro-batches concurrent TCP
//! queries onto the same blocked eval path, with LRU model caching,
//! `/metrics`-style stats and graceful SIGTERM drain.
//!
//! ## Quick tour (native backend — runs with zero setup)
//!
//! ```
//! use fastvpinns::prelude::*;
//!
//! // 1. mesh + premultiplier tensor assembly (pure Rust)
//! let mesh = generators::unit_square(2);
//! let domain = assembly::assemble(&mesh, 3, 5, QuadKind::GaussLegendre);
//!
//! // 2. pick a PDE: the Problem carries the weak form's coefficient
//! //    fields (eps/b/c); the backend hoists them into a
//! //    VariationalForm once — Helmholtz is just c = -k^2, no
//! //    backend-specific code anywhere
//! let problem = problems::Helmholtz2D::new(std::f64::consts::PI);
//! let form = VariationalForm::from_problem(&problem, &domain);
//! assert!(form.has_reaction());
//!
//! // 3. data source + native backend (no artifacts!); the loss is
//! //    only the *mode* — the PDE came from the problem
//! let src = DataSource { mesh: &mesh, domain: Some(&domain),
//!                        problem: &problem, sensor_values: None };
//! let cfg = TrainConfig { iters: 50, ..TrainConfig::default() };
//! let ncfg = NativeConfig {
//!     layers: vec![2, 8, 8, 1],
//!     loss: NativeLoss::Forward,
//!     nb: 40,
//!     ns: 0,
//! };
//! let backend =
//!     NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
//!
//! // 4. train through the backend-agnostic coordinator
//! let mut trainer = Trainer::new(Box::new(backend), &cfg);
//! assert_eq!(trainer.loss_kind(), "helmholtz");
//! let report = trainer.run().unwrap();
//! assert!(report.final_loss.is_finite());
//! let u = trainer.predict(&[[0.5, 0.5]]).unwrap();
//! assert_eq!(u.len(), 1);
//!
//! // 5. persist the trained model and serve it through the batched
//! //    inference engine: raw f64 weights + the same blocked-GEMM
//! //    forward path make the reloaded predictions bit-identical
//! let ck = trainer.checkpoint().unwrap();
//! let path = std::env::temp_dir().join("fastvpinns_tour.ckpt");
//! ck.write(&path).unwrap();
//! let mut sess = InferenceSession::open(&path).unwrap();
//! let (u2, eps2) = sess.eval(&[[0.5, 0.5]]);
//! assert_eq!(u2, u);
//! assert!(eps2.is_none()); // single-head forward network
//! std::fs::remove_file(&path).ok();
//! ```
//!
//! With `--features xla`, swap `NativeBackend::new(...)` for
//! `XlaBackend::new(&engine, "fv_poisson_ne4_nt5_nq20", ...)` — the
//! `Trainer` code does not change. On the CLI the same registry that
//! builds these problems drives `repro train --problem
//! poisson_sin|cd_gear|helmholtz|cd_var|inverse_const|inverse_space`
//! (and the help text is generated from it).

#![warn(missing_docs)]

pub mod autodiff;
pub mod coordinator;
pub mod experiments;
pub mod fem;
pub mod fem_solver;
pub mod linalg;
pub mod mesh;
pub mod problems;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::coordinator::metrics::ErrorNorms;
    pub use crate::coordinator::trainer::{
        CheckpointPolicy, DataSource, RecoveryEvent, RecoveryPolicy,
        TrainConfig, TrainReport, Trainer,
    };
    pub use crate::fem::assembly::{self, AssembledDomain};
    pub use crate::fem::quadrature::QuadKind;
    pub use crate::fem_solver::{FemProblem, FemSolution};
    pub use crate::mesh::{generators, QuadMesh};
    pub use crate::problems;
    pub use crate::runtime::backend::native::{
        Mlp, NativeBackend, NativeConfig, NativeLoss,
    };
    pub use crate::runtime::backend::{
        Backend, BackendOpts, Coeff, StepStats, VariationalForm,
    };
    pub use crate::runtime::checkpoint::{
        Checkpoint, DomainFingerprint, TrainHyper,
    };
    pub use crate::runtime::infer::InferenceSession;
    pub use crate::serve::{
        ServeClient, ServeConfig, Server, ServerHandle,
    };
    #[cfg(feature = "xla")]
    pub use crate::runtime::backend::xla::XlaBackend;
    #[cfg(feature = "xla")]
    pub use crate::runtime::engine::Engine;
    pub use crate::runtime::manifest::Manifest;
    pub use crate::runtime::tensor::TensorData;
}
