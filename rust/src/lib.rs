//! # FastVPINNs — tensor-driven hp-Variational PINNs
//!
//! Rust reproduction of *FastVPINNs: Tensor-Driven Acceleration of VPINNs
//! for Complex Geometries* (Anandh, Ghose, Jain, Ganesan, 2024), built
//! around a runtime-polymorphic [`runtime::backend::Backend`]:
//!
//! - **Native backend** (default) — the whole FastVPINNs train step in
//!   pure Rust, fully tensorized: quadrature points are batched into
//!   element blocks and the tanh-MLP forward (carrying spatial
//!   tangents), the variational residual against the precomputed
//!   premultiplier tensors `G_x`/`G_y`/`V`, and the hand-written
//!   reverse-mode backprop all run as cache-blocked micro-GEMMs
//!   ([`linalg::gemm`]), plus Dirichlet/sensor penalties and Adam.
//!   Every paper loss trains natively — forward Poisson /
//!   convection-diffusion, the scalar inverse problem, and the
//!   two-head inverse-space problem (`NativeLoss::InverseSpace`: a
//!   shared trunk with u and softplus'd eps heads, the eps *field*
//!   entering the residual contraction per quadrature point).
//!   Per-thread workspaces are allocated once and reused, so the step
//!   hot path is allocation-free. Trains offline with no Python, no
//!   artifacts and no XLA in the build graph (`repro bench` tracks its
//!   step time).
//! - **XLA backend** (`--features xla`) — executes AOT train steps
//!   (HLO + JSON manifest, produced once by `make artifacts` from the
//!   JAX/Pallas definitions under `python/compile`) on the PJRT CPU
//!   client. Same [`coordinator::trainer::Trainer`], same losses — the
//!   accelerated path.
//!
//! The rest of the stack is backend-agnostic: quad meshes and
//! generators, the mapped-FEM assembly of the premultiplier tensors, a
//! classical Q1 FEM reference solver, the training coordinator, and the
//! experiment/bench harness that regenerates every table and figure of
//! the paper.
//!
//! ## Quick tour (native backend — runs with zero setup)
//!
//! ```
//! use fastvpinns::prelude::*;
//!
//! // 1. mesh + premultiplier tensor assembly (pure Rust)
//! let mesh = generators::unit_square(2);
//! let domain = assembly::assemble(&mesh, 3, 5, QuadKind::GaussLegendre);
//!
//! // 2. problem + data source + native backend (no artifacts!)
//! let problem = problems::poisson_sin(std::f64::consts::PI);
//! let src = DataSource { mesh: &mesh, domain: Some(&domain),
//!                        problem: &*problem, sensor_values: None };
//! let cfg = TrainConfig { iters: 50, ..TrainConfig::default() };
//! let ncfg = NativeConfig {
//!     layers: vec![2, 8, 8, 1],
//!     loss: NativeLoss::Forward { eps: 1.0, bx: 0.0, by: 0.0 },
//!     nb: 40,
//!     ns: 0,
//! };
//! let backend =
//!     NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
//!
//! // 3. train through the backend-agnostic coordinator
//! let mut trainer = Trainer::new(Box::new(backend), &cfg);
//! let report = trainer.run().unwrap();
//! assert!(report.final_loss.is_finite());
//! let u = trainer.predict(&[[0.5, 0.5]]).unwrap();
//! assert_eq!(u.len(), 1);
//! ```
//!
//! With `--features xla`, swap `NativeBackend::new(...)` for
//! `XlaBackend::new(&engine, "fv_poisson_ne4_nt5_nq20", ...)` — the
//! `Trainer` code does not change.

pub mod autodiff;
pub mod coordinator;
pub mod experiments;
pub mod fem;
pub mod fem_solver;
pub mod linalg;
pub mod mesh;
pub mod problems;
pub mod runtime;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::coordinator::metrics::ErrorNorms;
    pub use crate::coordinator::trainer::{
        DataSource, TrainConfig, TrainReport, Trainer,
    };
    pub use crate::fem::assembly::{self, AssembledDomain};
    pub use crate::fem::quadrature::QuadKind;
    pub use crate::fem_solver::{FemProblem, FemSolution};
    pub use crate::mesh::{generators, QuadMesh};
    pub use crate::problems;
    pub use crate::runtime::backend::native::{
        Mlp, NativeBackend, NativeConfig, NativeLoss,
    };
    pub use crate::runtime::backend::{Backend, BackendOpts, StepStats};
    #[cfg(feature = "xla")]
    pub use crate::runtime::backend::xla::XlaBackend;
    #[cfg(feature = "xla")]
    pub use crate::runtime::engine::Engine;
    pub use crate::runtime::manifest::Manifest;
    pub use crate::runtime::tensor::TensorData;
}
