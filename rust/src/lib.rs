//! # FastVPINNs — tensor-driven hp-Variational PINNs
//!
//! Rust reproduction of *FastVPINNs: Tensor-Driven Acceleration of VPINNs
//! for Complex Geometries* (Anandh, Ghose, Jain, Ganesan, 2024) as a
//! three-layer stack:
//!
//! - **L3 (this crate)** owns everything at run time: quad meshes and
//!   generators, the mapped-FEM assembly of the FastVPINNs premultiplier
//!   tensors, a classical Q1 FEM reference solver, the PJRT runtime that
//!   executes AOT-compiled training artifacts, the training coordinator,
//!   and the experiment/bench harness that regenerates every table and
//!   figure of the paper.
//! - **L2 (python/compile, build-time only)** defines the JAX model and
//!   losses and lowers whole train steps (network + autodiff + Adam) to
//!   HLO text.
//! - **L1 (python/compile/kernels)** is the Pallas residual-contraction
//!   kernel the losses call into.
//!
//! Python never runs on the training path: `make artifacts` once, then
//! the `repro` binary is self-contained.
//!
//! ## Quick tour
//!
//! ```no_run
//! use fastvpinns::prelude::*;
//! use fastvpinns::coordinator::trainer::DataSource;
//!
//! // 1. mesh + assembly (pure Rust)
//! let mesh = generators::unit_square(2);
//! let domain = assembly::assemble(&mesh, 5, 20, QuadKind::GaussLegendre);
//!
//! // 2. runtime + data source
//! let engine = Engine::new("artifacts").unwrap();
//! let problem = problems::poisson_sin(2.0 * std::f64::consts::PI);
//! let src = DataSource { mesh: &mesh, domain: Some(&domain),
//!                        problem: &*problem, sensor_values: None };
//!
//! // 3. train the AOT-compiled step
//! let cfg = TrainConfig { iters: 2000, ..TrainConfig::default() };
//! let mut trainer =
//!     Trainer::new(&engine, "fv_poisson_ne4_nt5_nq20", &src, &cfg)
//!         .unwrap();
//! let report = trainer.run().unwrap();
//! println!("final loss {:.3e}", report.final_loss);
//! ```

pub mod autodiff;
pub mod coordinator;
pub mod experiments;
pub mod fem;
pub mod fem_solver;
pub mod linalg;
pub mod mesh;
pub mod problems;
pub mod runtime;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::coordinator::metrics::ErrorNorms;
    pub use crate::coordinator::trainer::{TrainConfig, TrainReport, Trainer};
    pub use crate::fem::assembly::{self, AssembledDomain};
    pub use crate::fem::quadrature::QuadKind;
    pub use crate::fem_solver::{FemProblem, FemSolution};
    pub use crate::mesh::{generators, QuadMesh};
    pub use crate::problems;
    pub use crate::runtime::engine::Engine;
    pub use crate::runtime::manifest::Manifest;
    pub use crate::runtime::tensor::TensorData;
}
