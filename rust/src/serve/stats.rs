//! Server-side metrics, aggregated panic-free.
//!
//! Counters are atomics; latency samples live in a fixed-capacity ring
//! (steady-state traffic overwrites the oldest sample instead of
//! growing without bound). The snapshot computes percentiles through
//! [`Summary`], whose non-finite handling (count-and-drop, sort by
//! `total_cmp`) is exactly what makes this path safe: one poisoned
//! timer sample must never take the metrics endpoint — or the server —
//! down.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;

use super::protocol::finite_num;

/// Latency samples kept for percentile estimation.
const LATENCY_RING: usize = 4096;

/// Shared serve-side metrics. One instance per server, updated by
/// connection threads and workers, snapshotted by the `stats` op.
pub struct ServeStats {
    start: Instant,
    requests: AtomicU64,
    errors: AtomicU64,
    points: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    /// Jobs sitting in model-pool queues right now (submitted but not
    /// yet claimed by a worker), across every live pool.
    queued: AtomicU64,
    /// High-water mark of `queued` over the server's lifetime — how
    /// deep the backpressure queues actually got under load.
    queue_hwm: AtomicU64,
    latencies_ms: Mutex<LatencyRing>,
    model_hits: Mutex<Vec<(String, u64)>>,
}

struct LatencyRing {
    samples: Vec<f64>,
    next: usize,
}

impl Default for ServeStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeStats {
    /// Fresh metrics (uptime starts now).
    pub fn new() -> ServeStats {
        ServeStats {
            start: Instant::now(),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            points: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            queued: AtomicU64::new(0),
            queue_hwm: AtomicU64::new(0),
            latencies_ms: Mutex::new(LatencyRing {
                samples: Vec::with_capacity(LATENCY_RING),
                next: 0,
            }),
            model_hits: Mutex::new(Vec::new()),
        }
    }

    /// Record one answered eval request.
    pub fn record_eval(&self, model: &str, n_points: u64, ms: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.points.fetch_add(n_points, Ordering::Relaxed);
        let mut ring = lock(&self.latencies_ms);
        if ring.samples.len() < LATENCY_RING {
            ring.samples.push(ms);
        } else {
            let i = ring.next;
            ring.samples[i] = ms;
        }
        ring.next = (ring.next + 1) % LATENCY_RING;
        drop(ring);
        let mut hits = lock(&self.model_hits);
        match hits.iter_mut().find(|(n, _)| n == model) {
            Some((_, c)) => *c += 1,
            None => hits.push((model.to_string(), 1)),
        }
    }

    /// Record one failed request (parse error, unknown model, ...).
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one coalesced micro-batch of `n_requests` requests.
    pub fn record_batch(&self, n_requests: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(n_requests as u64, Ordering::Relaxed);
    }

    /// Record one job entering a model-pool queue, pushing the
    /// high-water mark up when this is the deepest the queues have
    /// been.
    pub fn record_enqueue(&self) {
        let depth = self.queued.fetch_add(1, Ordering::Relaxed) + 1;
        self.queue_hwm.fetch_max(depth, Ordering::Relaxed);
    }

    /// Record `n` jobs leaving the queues (claimed into a micro-batch,
    /// or a failed submit rolling its increment back).
    pub fn record_dequeue(&self, n: usize) {
        self.queued.fetch_sub(n as u64, Ordering::Relaxed);
    }

    /// Jobs sitting in pool queues right now (submitted, not yet
    /// claimed into a micro-batch) — the telemetry `queue` events
    /// sample this gauge.
    pub fn queued(&self) -> u64 {
        self.queued.load(Ordering::Relaxed)
    }

    /// Deepest the pool queues have been since the server started.
    pub fn queue_hwm(&self) -> u64 {
        self.queue_hwm.load(Ordering::Relaxed)
    }

    /// Answered request count so far.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Mean coalesced batch size over `max_batch` — 1.0 means every
    /// batch was full, 1/max_batch means no coalescing happened.
    pub fn batch_fill(&self, max_batch: usize) -> f64 {
        let batches = self.batches.load(Ordering::Relaxed);
        if batches == 0 || max_batch == 0 {
            return 0.0;
        }
        let coalesced = self.batched_requests.load(Ordering::Relaxed);
        coalesced as f64 / (batches * max_batch as u64) as f64
    }

    /// Order statistics over the retained latency samples.
    pub fn latency_summary(&self) -> Summary {
        Summary::from(&lock(&self.latencies_ms).samples)
    }

    /// The `/metrics`-style stats reply.
    pub fn snapshot(&self, max_batch: usize) -> Json {
        let uptime = self.start.elapsed().as_secs_f64();
        let req = self.requests.load(Ordering::Relaxed);
        let rps = if uptime > 0.0 { req as f64 / uptime } else { 0.0 };
        let lat = self.latency_summary();
        let hits = lock(&self.model_hits)
            .iter()
            .map(|(n, c)| (n.clone(), Json::num(*c as f64)))
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("uptime_s", finite_num(uptime)),
            ("requests", Json::num(req as f64)),
            (
                "errors",
                Json::num(self.errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "points",
                Json::num(self.points.load(Ordering::Relaxed) as f64),
            ),
            ("requests_per_sec", finite_num(rps)),
            (
                "latency_ms",
                Json::obj(vec![
                    ("n", Json::num(lat.n as f64)),
                    ("p50", finite_num(lat.median)),
                    ("p90", finite_num(lat.p90)),
                    ("p99", finite_num(lat.p99)),
                    ("max", finite_num(lat.max)),
                    ("mean", finite_num(lat.mean)),
                    ("dropped", Json::num(lat.dropped as f64)),
                ]),
            ),
            (
                "batch",
                Json::obj(vec![
                    (
                        "batches",
                        Json::num(
                            self.batches.load(Ordering::Relaxed) as f64,
                        ),
                    ),
                    ("max_batch", Json::num(max_batch as f64)),
                    ("fill", finite_num(self.batch_fill(max_batch))),
                    (
                        "queued",
                        Json::num(
                            self.queued.load(Ordering::Relaxed) as f64,
                        ),
                    ),
                    (
                        "queue_hwm",
                        Json::num(self.queue_hwm() as f64),
                    ),
                ]),
            ),
            ("models", Json::Obj(hits)),
        ])
    }
}

/// Lock a mutex, riding through poisoning: a worker that panicked
/// while holding a stats lock must not cascade into every later
/// metrics call (the data is monotone counters and samples — safe to
/// read regardless).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_counts_and_stays_finite() {
        let s = ServeStats::new();
        s.record_eval("a", 100, 1.5);
        s.record_eval("a", 50, 2.5);
        s.record_eval("b", 10, f64::NAN); // poisoned sample
        s.record_error();
        s.record_batch(3);
        s.record_batch(1);
        let j = s.snapshot(8);
        assert_eq!(j.req("requests").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("errors").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.req("points").unwrap().as_usize().unwrap(), 160);
        let lat = j.req("latency_ms").unwrap();
        assert_eq!(lat.req("n").unwrap().as_usize().unwrap(), 2);
        assert_eq!(lat.req("dropped").unwrap().as_usize().unwrap(), 1);
        assert!(lat.req("p50").unwrap().as_f64().unwrap().is_finite());
        assert!(lat.req("p99").unwrap().as_f64().unwrap().is_finite());
        let batch = j.req("batch").unwrap();
        // (3 + 1) requests over 2 batches of cap 8 -> fill 0.25
        assert!((batch.req("fill").unwrap().as_f64().unwrap() - 0.25)
            .abs()
            < 1e-12);
        let hits = j.req("models").unwrap();
        assert_eq!(hits.req("a").unwrap().as_usize().unwrap(), 2);
        assert_eq!(hits.req("b").unwrap().as_usize().unwrap(), 1);
        // and the whole reply serializes to parseable JSON even with
        // the NaN sample recorded
        let text = j.to_string();
        assert!(Json::parse(&text).is_ok(), "{text}");
    }

    #[test]
    fn queue_high_water_mark_tracks_the_peak_not_the_present() {
        let s = ServeStats::new();
        assert_eq!(s.queue_hwm(), 0);
        s.record_enqueue();
        s.record_enqueue();
        s.record_enqueue();
        s.record_dequeue(2); // a worker drained a 2-job batch
        s.record_enqueue();
        // depth went 1,2,3 -> 1 -> 2: the mark stays at the peak
        assert_eq!(s.queue_hwm(), 3);
        let j = s.snapshot(8);
        let batch = j.req("batch").unwrap();
        assert_eq!(
            batch.req("queued").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            batch.req("queue_hwm").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn latency_ring_overwrites_oldest() {
        let s = ServeStats::new();
        for i in 0..(LATENCY_RING + 10) {
            s.record_eval("m", 1, i as f64);
        }
        let sum = s.latency_summary();
        assert_eq!(sum.n, LATENCY_RING);
        // the 10 oldest samples (0..9) were overwritten
        assert!(sum.min >= 10.0, "min {}", sum.min);
    }
}
