//! Serve throughput benchmarking: an in-process server hammered by
//! concurrent clients over real TCP, reported as `loss: "serve"` rows
//! in the `repro bench` record.
//!
//! Also home to [`synthetic_checkpoint`], the shared fixture builder
//! for serve tests and benches: a Glorot-initialized network wrapped
//! in a well-formed checkpoint artifact, no training run required.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::runtime::backend::native::Mlp;
use crate::runtime::backend::{Coeff, VariationalForm};
use crate::runtime::checkpoint::{
    Checkpoint, DomainFingerprint, TrainHyper,
};
use crate::runtime::infer::Precision;

use super::client::ServeClient;
use super::server::{ServeConfig, Server};

/// A well-formed checkpoint around an untrained Glorot-initialized
/// network — enough for [`InferenceSession`] to load and serve it.
/// The serve path only cares about parameter bits, not training
/// history, so benches and tests can skip the training run entirely.
///
/// [`InferenceSession`]: crate::runtime::infer::InferenceSession
pub fn synthetic_checkpoint(
    layers: &[usize],
    two_head: bool,
    seed: u64,
) -> Result<Checkpoint> {
    let net = if two_head {
        Mlp::glorot_two_head(layers, seed)?
    } else {
        Mlp::glorot(layers, seed)?
    };
    let n = net.theta.len();
    Ok(Checkpoint {
        problem: "synthetic".into(),
        problem_label: format!("synthetic_seed{seed}"),
        loss_mode: "forward".into(),
        loss_kind: "poisson".into(),
        cli: Vec::new(),
        layers: layers.to_vec(),
        two_head,
        step: 0,
        best_metric: None,
        theta: net.theta,
        eps: 0.0,
        adam_m: vec![0.0; n],
        adam_v: vec![0.0; n],
        form: VariationalForm {
            eps: Coeff::Const(1.0),
            bx: Coeff::Const(0.0),
            by: Coeff::Const(0.0),
            c: Coeff::Const(0.0),
        },
        fingerprint: DomainFingerprint {
            ne: 1,
            nt: 1,
            nq: 1,
            n_points: 4,
            n_cells: 1,
            bbox: [0.0, 0.0, 1.0, 1.0],
            quad_hash: 0,
        },
        hyper: TrainHyper {
            tau: 10.0,
            gamma: 10.0,
            seed,
            eps_init: 1.0,
            nb: 0,
            ns: 0,
        },
    })
}

/// The model name the bench registry serves.
pub const BENCH_MODEL: &str = "bench";

/// Write the bench registry: one synthetic model with the standard
/// bench network shape, into `dir`.
pub fn prepare_bench_registry(
    dir: &Path,
    layers: &[usize],
) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| {
        format!("create bench registry {}", dir.display())
    })?;
    let ck = synthetic_checkpoint(layers, false, 42)?;
    ck.write(dir.join(format!("{BENCH_MODEL}.ckpt")))
}

/// One measured serve-throughput datapoint.
pub struct ServeBenchCase {
    /// Concurrent client connections.
    pub clients: usize,
    /// Precision every request asked for.
    pub precision: Precision,
    /// Points per eval request.
    pub points_per_req: usize,
    /// Total timed requests (all clients).
    pub requests: usize,
    /// Aggregate throughput over the timed window.
    pub points_per_sec: f64,
    /// Server-side median request latency.
    pub p50_ms: f64,
    /// Server-side p99 request latency.
    pub p99_ms: f64,
    /// Mean coalesced batch size over `max_batch`.
    pub batch_fill: f64,
    /// The coalescing cap the server ran with.
    pub max_batch: usize,
}

/// Spin up a fresh in-process server over `registry`, drive it with
/// `clients` concurrent TCP connections issuing `reqs_per_client`
/// eval requests each, and report aggregate throughput plus the
/// server's own latency percentiles and batch-fill ratio.
pub fn serve_bench_case(
    registry: &Path,
    clients: usize,
    points_per_req: usize,
    reqs_per_client: usize,
    precision: Precision,
) -> Result<ServeBenchCase> {
    let clients = clients.max(1);
    let mut config = ServeConfig::new("127.0.0.1:0", registry);
    config.workers_per_model = clients.clamp(1, 4);
    let handle = Server::spawn(config.clone())?;
    let addr = handle.addr();

    // Warm up: load the model and touch both eval paths once so the
    // timed window measures serving, not artifact parsing or the
    // one-time f32 weight packing.
    let mut warm = ServeClient::connect(addr)?;
    warm.eval(BENCH_MODEL, &query(0, 0, 16), Some(precision))?;
    let warm_stats = handle.stats();
    let warmup_requests = warm_stats.requests();

    let t0 = Instant::now();
    let joins: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || -> Result<()> {
                let mut client = ServeClient::connect(addr)?;
                for r in 0..reqs_per_client {
                    let q = query(c, r, points_per_req);
                    let (u, _) = client.eval(
                        BENCH_MODEL,
                        &q,
                        Some(precision),
                    )?;
                    if u.len() != points_per_req {
                        return Err(anyhow!(
                            "short reply: {} of {points_per_req}",
                            u.len()
                        ));
                    }
                }
                Ok(())
            })
        })
        .collect();
    for j in joins {
        j.join()
            .map_err(|_| anyhow!("bench client panicked"))??;
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);

    let stats = handle.stats();
    let lat = stats.latency_summary();
    let fill = stats.batch_fill(config.policy.max_batch);
    let timed_requests =
        stats.requests().saturating_sub(warmup_requests) as usize;
    handle.shutdown()?;

    let total_points = (timed_requests * points_per_req) as f64;
    Ok(ServeBenchCase {
        clients,
        precision,
        points_per_req,
        requests: timed_requests,
        points_per_sec: total_points / elapsed,
        p50_ms: lat.median,
        p99_ms: lat.p99,
        batch_fill: fill,
        max_batch: config.policy.max_batch,
    })
}

/// Deterministic per-(client, request) query cloud in the unit square.
fn query(client: usize, req: usize, n: usize) -> Vec<[f64; 2]> {
    let salt = 0.17 * client as f64 + 0.031 * req as f64;
    (0..n)
        .map(|i| {
            let t = (i as f64 + 0.5) / n as f64;
            [(t + salt).fract(), (t * 1.618 + salt).fract()]
        })
        .collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::runtime::checkpoint::expected_n_params;
    use crate::runtime::infer::InferenceSession;

    #[test]
    fn synthetic_checkpoint_loads_and_roundtrips() {
        let ck = synthetic_checkpoint(&[2, 5, 1], false, 3).unwrap();
        assert_eq!(
            ck.theta.len(),
            expected_n_params(&[2, 5, 1], false)
        );
        let mut sess = InferenceSession::from_checkpoint(&ck).unwrap();
        let (u, eps) = sess.eval(&[[0.5, 0.5]]);
        assert_eq!(u.len(), 1);
        assert!(eps.is_none());
        // two-head variant exposes the eps head
        let ck2 = synthetic_checkpoint(&[2, 5, 1], true, 3).unwrap();
        let mut sess2 =
            InferenceSession::from_checkpoint(&ck2).unwrap();
        let (_, eps2) = sess2.eval(&[[0.5, 0.5]]);
        assert_eq!(eps2.unwrap().len(), 1);
    }

    #[test]
    fn queries_are_deterministic_and_in_the_unit_square() {
        let a = query(2, 7, 32);
        let b = query(2, 7, 32);
        assert_eq!(a, b);
        assert!(a
            .iter()
            .all(|p| (0.0..1.0).contains(&p[0])
                && (0.0..1.0).contains(&p[1])));
        assert_ne!(query(0, 0, 8), query(1, 0, 8));
    }
}
