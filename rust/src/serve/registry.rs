//! Model registry: a directory of checkpoint artifacts, plus the LRU
//! cache of live worker pools the server serves from.
//!
//! A registry is just `<dir>/<name>.ckpt` files — the same artifacts
//! `repro train --checkpoint` writes, generation rings
//! (`.g0`/`.g1`/`.best` siblings) and all. Models load lazily on first
//! query through [`Checkpoint::read_salvage`], so a torn primary falls
//! back to its generation ring exactly like `--resume` does.
//!
//! The cache is keyed by **artifact fingerprint** (FNV-1a over the
//! serialized checkpoint bytes), not by name: two registry entries
//! that are byte-identical share one worker pool. Capacity eviction
//! drops the coldest pool — dropping joins its workers, so an evicted
//! model costs nothing until it is queried again.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::runtime::checkpoint::{scan_registry, Checkpoint};
use crate::runtime::infer::InferenceSession;

use super::pool::{BatchPolicy, ModelPool};
use super::stats::ServeStats;

/// A directory of servable checkpoint artifacts.
pub struct Registry {
    dir: PathBuf,
}

impl Registry {
    /// Open a registry directory (must exist).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Registry> {
        let dir = dir.into();
        if !dir.is_dir() {
            bail!("model registry {} is not a directory", dir.display());
        }
        Ok(Registry { dir })
    }

    /// The registry directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Servable model names (primary `<name>.ckpt` files, sorted).
    pub fn models(&self) -> Result<Vec<String>> {
        Ok(scan_registry(&self.dir)?
            .into_iter()
            .map(|(name, _)| name)
            .collect())
    }

    /// Path of a named model's primary artifact. Model names are plain
    /// file stems — anything that looks like path traversal is
    /// rejected before touching the filesystem.
    pub fn path_of(&self, name: &str) -> Result<PathBuf> {
        if name.is_empty()
            || name == "."
            || name == ".."
            || name.contains(['/', '\\'])
        {
            bail!("invalid model name {name:?}");
        }
        Ok(self.dir.join(format!("{name}.ckpt")))
    }
}

/// One live cache entry: a worker pool plus the fingerprint of the
/// artifact it was built from.
struct CacheEntry {
    fingerprint: u64,
    pool: Arc<ModelPool>,
}

struct CacheInner {
    /// LRU order: front is coldest, back is hottest.
    pools: Vec<CacheEntry>,
    /// `name -> fingerprint` aliases (several names may share a pool).
    names: Vec<(String, u64)>,
}

/// LRU cache of loaded [`ModelPool`]s, keyed by artifact fingerprint.
pub struct ModelCache {
    capacity: usize,
    workers_per_model: usize,
    policy: BatchPolicy,
    stats: Arc<ServeStats>,
    inner: Mutex<CacheInner>,
}

impl ModelCache {
    /// A cache holding at most `capacity` live pools, each running
    /// `workers_per_model` workers under `policy`.
    pub fn new(
        capacity: usize,
        workers_per_model: usize,
        policy: BatchPolicy,
        stats: Arc<ServeStats>,
    ) -> ModelCache {
        ModelCache {
            capacity: capacity.max(1),
            workers_per_model,
            policy,
            stats,
            inner: Mutex::new(CacheInner {
                pools: Vec::new(),
                names: Vec::new(),
            }),
        }
    }

    /// Number of live pools.
    pub fn len(&self) -> usize {
        lock(&self.inner).pools.len()
    }

    /// Whether no pool is loaded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The pool serving `name`, loading the artifact on a miss.
    ///
    /// The cache lock is held across the load (checkpoint read +
    /// session build + worker spawn, milliseconds for real models) —
    /// that serializes *loads*, which keeps a thundering herd on one
    /// cold model from building the same pool N times. Queries against
    /// already-cached models queue behind a load only for the lock's
    /// duration, and evaluation itself never runs under this lock.
    ///
    /// A failed load (torn artifact past salvage, `io.read.err`
    /// failpoint, fingerprint mismatch, ...) caches **nothing**: the
    /// error goes to the one requesting client and any stale alias for
    /// the name is dropped, so the next request retries from disk.
    pub fn get(
        &self,
        registry: &Registry,
        name: &str,
    ) -> Result<Arc<ModelPool>> {
        let path = registry.path_of(name)?;
        let mut inner = lock(&self.inner);
        if let Some(fp) = alias_of(&inner.names, name) {
            if let Some(pool) = touch(&mut inner.pools, fp) {
                return Ok(pool);
            }
            // alias survived its pool's eviction: fall through and
            // reload from disk
        }
        match self.load(&mut inner, &path, name) {
            Ok(pool) => Ok(pool),
            Err(e) => {
                inner.names.retain(|(n, _)| n != name);
                // Rescan-on-miss: artifacts dropped into the registry
                // after startup must be servable without a restart.
                // One fresh directory scan decides between a retry
                // (the file landed since the failed read) and an
                // unknown-model error enriched with what the registry
                // *does* serve right now. `path_of` already rejected
                // traversal names above, so no request-controlled
                // path reaches the scan.
                let fresh =
                    scan_registry(registry.dir()).unwrap_or_default();
                if fresh.iter().any(|(n, _)| n == name) {
                    return match self.load(&mut inner, &path, name) {
                        Ok(pool) => Ok(pool),
                        Err(e2) => {
                            inner.names.retain(|(n, _)| n != name);
                            Err(e2)
                        }
                    };
                }
                let known: Vec<String> =
                    fresh.into_iter().map(|(n, _)| n).collect();
                Err(e.context(format!(
                    "unknown model {name:?} after registry rescan \
                     (servable: [{}])",
                    known.join(", ")
                )))
            }
        }
    }

    fn load(
        &self,
        inner: &mut CacheInner,
        path: &Path,
        name: &str,
    ) -> Result<Arc<ModelPool>> {
        let (ck, loaded_from) = Checkpoint::read_salvage(path)
            .with_context(|| format!("loading model {name:?}"))?;
        if loaded_from != path {
            eprintln!(
                "serve: model {name:?} salvaged from {}",
                loaded_from.display()
            );
        }
        let fp = ck.artifact_fingerprint();
        inner.names.retain(|(n, _)| n != name);
        inner.names.push((name.to_string(), fp));
        if let Some(pool) = touch(&mut inner.pools, fp) {
            // byte-identical artifact already serving under another
            // name — share its pool
            return Ok(pool);
        }
        let session = InferenceSession::from_checkpoint(&ck)
            .with_context(|| format!("model {name:?} does not load"))?;
        let pool = Arc::new(ModelPool::start(
            &session,
            self.workers_per_model,
            self.policy,
            Arc::clone(&self.stats),
        )?);
        inner.pools.push(CacheEntry {
            fingerprint: fp,
            pool: Arc::clone(&pool),
        });
        while inner.pools.len() > self.capacity {
            let evicted = inner.pools.remove(0);
            inner
                .names
                .retain(|(_, f)| *f != evicted.fingerprint);
            // dropping the entry joins the pool's workers once the
            // last in-flight Arc clone goes away
        }
        Ok(pool)
    }

    /// Drop the pool serving `name` (and every alias of the same
    /// artifact). Returns whether anything was evicted.
    pub fn evict(&self, name: &str) -> bool {
        let mut inner = lock(&self.inner);
        let Some(fp) = alias_of(&inner.names, name) else {
            return false;
        };
        inner.names.retain(|(_, f)| *f != fp);
        let before = inner.pools.len();
        inner.pools.retain(|e| e.fingerprint != fp);
        before != inner.pools.len()
    }

    /// Drop every pool, joining all worker threads (drain path).
    pub fn clear(&self) {
        let mut inner = lock(&self.inner);
        inner.names.clear();
        inner.pools.clear();
    }
}

fn alias_of(names: &[(String, u64)], name: &str) -> Option<u64> {
    names.iter().find(|(n, _)| n == name).map(|(_, fp)| *fp)
}

/// Find a pool by fingerprint and move it to the hot end.
fn touch(
    pools: &mut Vec<CacheEntry>,
    fp: u64,
) -> Option<Arc<ModelPool>> {
    let i = pools.iter().position(|e| e.fingerprint == fp)?;
    let entry = pools.remove(i);
    let pool = Arc::clone(&entry.pool);
    pools.push(entry);
    Some(pool)
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::runtime::infer::Precision;
    use crate::serve::bench::synthetic_checkpoint;

    fn tmp_registry(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fastvpinns_registry_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_model(dir: &Path, name: &str, seed: u64) {
        let ck = synthetic_checkpoint(&[2, 6, 1], false, seed).unwrap();
        ck.write(dir.join(format!("{name}.ckpt"))).unwrap();
    }

    fn cache(capacity: usize) -> ModelCache {
        ModelCache::new(
            capacity,
            1,
            BatchPolicy::default(),
            Arc::new(ServeStats::new()),
        )
    }

    #[test]
    fn traversal_names_are_rejected() {
        let dir = tmp_registry("traversal");
        let reg = Registry::open(&dir).unwrap();
        for bad in ["", ".", "..", "a/b", "a\\b", "../escape"] {
            assert!(reg.path_of(bad).is_err(), "{bad:?}");
        }
        assert!(reg.path_of("model-1.v2").is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_evicts_coldest_and_reloads_on_demand() {
        let dir = tmp_registry("lru");
        for (name, seed) in [("a", 1), ("b", 2), ("c", 3)] {
            write_model(&dir, name, seed);
        }
        let reg = Registry::open(&dir).unwrap();
        assert_eq!(reg.models().unwrap(), ["a", "b", "c"]);
        let cache = cache(2);
        cache.get(&reg, "a").unwrap();
        cache.get(&reg, "b").unwrap();
        cache.get(&reg, "a").unwrap(); // refresh a: b is now coldest
        cache.get(&reg, "c").unwrap(); // evicts b
        assert_eq!(cache.len(), 2);
        // b reloads transparently; the pool still answers
        let pool = cache.get(&reg, "b").unwrap();
        let out = pool
            .submit(vec![[0.3, 0.4]], Precision::F64)
            .unwrap();
        assert_eq!(out.0.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn identical_artifacts_share_one_pool() {
        let dir = tmp_registry("dedup");
        write_model(&dir, "x", 9);
        std::fs::copy(
            dir.join("x.ckpt"),
            dir.join("x_copy.ckpt"),
        )
        .unwrap();
        let reg = Registry::open(&dir).unwrap();
        let cache = cache(4);
        let p1 = cache.get(&reg, "x").unwrap();
        let p2 = cache.get(&reg, "x_copy").unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(cache.len(), 1);
        // evicting either name drops the shared pool
        assert!(cache.evict("x"));
        assert!(cache.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Rescan-on-miss: an artifact dropped into the registry *after*
    /// the cache/server exist becomes servable on the next query —
    /// and a prior failed lookup of the same name must not have
    /// negatively cached anything.
    #[test]
    fn artifact_written_post_spawn_becomes_servable() {
        let dir = tmp_registry("post_spawn");
        write_model(&dir, "present", 5);
        let reg = Registry::open(&dir).unwrap();
        let cache = cache(2);
        // the model does not exist yet: the error mentions the rescan
        // and lists what the registry serves right now
        let err = cache.get(&reg, "late").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("registry rescan"), "{msg}");
        assert!(msg.contains("present"), "{msg}");
        assert!(cache.is_empty());
        // drop the artifact in post-spawn; the very next get serves it
        write_model(&dir, "late", 11);
        let pool = cache.get(&reg, "late").unwrap();
        let out =
            pool.submit(vec![[0.2, 0.7]], Precision::F64).unwrap();
        assert_eq!(out.0.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Traversal-safety regression on the rescan path: names rejected
    /// by `path_of` must error *before* any filesystem access — the
    /// rescan retry must not open a request-controlled path.
    #[test]
    fn rescan_path_never_reaches_traversal_names() {
        let dir = tmp_registry("rescan_traversal");
        let reg = Registry::open(&dir).unwrap();
        let cache = cache(2);
        for bad in ["", ".", "..", "a/b", "a\\b", "../escape"] {
            let err = cache.get(&reg, bad).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("invalid model name"),
                "{bad:?} must fail name validation, not the \
                 load/rescan path: {msg}"
            );
            assert!(
                !msg.contains("registry rescan"),
                "{bad:?} reached the rescan path: {msg}"
            );
        }
        assert!(cache.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_or_torn_models_error_without_caching() {
        let dir = tmp_registry("missing");
        let reg = Registry::open(&dir).unwrap();
        let cache = cache(2);
        assert!(cache.get(&reg, "ghost").is_err());
        assert!(cache.is_empty());
        // a torn artifact with no salvage generation also fails clean
        std::fs::write(dir.join("torn.ckpt"), b"FVPCHKPT garbage")
            .unwrap();
        assert!(cache.get(&reg, "torn").is_err());
        assert!(cache.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
