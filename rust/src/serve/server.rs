//! The serve loop: TCP accept, per-connection request handling, and
//! graceful drain.
//!
//! One thread per connection reads frames and submits eval jobs into
//! the model cache's worker pools; the accept loop itself only hands
//! off sockets. Shutdown (SIGTERM, SIGINT or a `shutdown` op) flips a
//! flag: the accept loop stops, idle connections close at their next
//! poll tick, in-flight frames finish and are answered, worker pools
//! join, and a final stats line is printed. Nothing on this path is
//! allowed to panic — a bad request, a torn artifact or a poisoned
//! latency sample is always an error *reply*, never a dead server.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::runtime::infer::Precision;
use crate::util::json::Json;

use super::pool::BatchPolicy;
use super::protocol::{
    error_response, eval_response, parse_request, read_frame_polled,
    write_frame, Request,
};
use super::registry::{ModelCache, Registry};
use super::stats::ServeStats;

/// How often idle loops poll the shutdown flag.
const POLL: Duration = Duration::from_millis(25);

/// Set by the SIGTERM/SIGINT handler; checked by every poll loop.
static SIGNALLED: AtomicBool = AtomicBool::new(false);

/// Server configuration (CLI flags map 1:1 onto these fields).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address, e.g. `127.0.0.1:7077` (port 0 picks a free one).
    pub addr: String,
    /// Model registry directory (`<name>.ckpt` artifacts).
    pub registry: PathBuf,
    /// Max models resident at once (LRU beyond this).
    pub cache_capacity: usize,
    /// Worker threads (= private forked sessions) per model.
    pub workers_per_model: usize,
    /// Micro-batch coalescing policy.
    pub policy: BatchPolicy,
    /// How long drain waits for in-flight connections before exiting
    /// anyway.
    pub drain_timeout: Duration,
}

impl ServeConfig {
    /// A config with default pooling/batching knobs.
    pub fn new(
        addr: impl Into<String>,
        registry: impl Into<PathBuf>,
    ) -> ServeConfig {
        ServeConfig {
            addr: addr.into(),
            registry: registry.into(),
            cache_capacity: 4,
            workers_per_model: 2,
            policy: BatchPolicy::default(),
            drain_timeout: Duration::from_secs(10),
        }
    }
}

/// State shared by the accept loop and every connection thread.
struct Shared {
    registry: Registry,
    cache: ModelCache,
    stats: Arc<ServeStats>,
    policy: BatchPolicy,
    stop: AtomicBool,
    active: AtomicUsize,
}

impl Shared {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
            || SIGNALLED.load(Ordering::SeqCst)
    }
}

/// The serve runtime. Build with [`Server::new`], then either
/// [`run`](Server::run) on the current thread (the CLI path) or
/// [`spawn`](Server::spawn) for an in-process server (tests, bench).
pub struct Server {
    config: ServeConfig,
    shared: Arc<Shared>,
}

impl Server {
    /// Open the registry and build the serve runtime.
    pub fn new(config: ServeConfig) -> Result<Server> {
        let registry = Registry::open(config.registry.clone())?;
        let stats = Arc::new(ServeStats::new());
        let cache = ModelCache::new(
            config.cache_capacity,
            config.workers_per_model,
            config.policy,
            Arc::clone(&stats),
        );
        Ok(Server {
            shared: Arc::new(Shared {
                registry,
                cache,
                stats,
                policy: config.policy,
                stop: AtomicBool::new(false),
                active: AtomicUsize::new(0),
            }),
            config,
        })
    }

    /// Serve on the current thread until shutdown; installs the
    /// SIGTERM/SIGINT handler. This is what `repro serve` runs.
    pub fn run(self) -> Result<()> {
        install_signal_handler();
        let listener = self.bind()?;
        let addr = listener
            .local_addr()
            .context("resolve listen address")?;
        let models = self.shared.registry.models().unwrap_or_default();
        println!(
            "serve: listening on {addr} ({} models in {}, cache {} \
             x {} workers, batch {} within {:?})",
            models.len(),
            self.config.registry.display(),
            self.config.cache_capacity,
            self.config.workers_per_model,
            self.config.policy.max_batch,
            self.config.policy.max_wait,
        );
        serve_on(&self.shared, listener, self.config.drain_timeout);
        println!(
            "serve: drained. final stats: {}",
            self.shared.stats.snapshot(self.shared.policy.max_batch)
        );
        Ok(())
    }

    /// Serve on a background thread; returns once the listener is
    /// bound, so `handle.addr()` is immediately connectable. Used by
    /// the e2e tests and the serve bench. Does **not** install signal
    /// handlers — in-process servers stop via [`ServerHandle::stop`].
    pub fn spawn(config: ServeConfig) -> Result<ServerHandle> {
        let server = Server::new(config)?;
        let listener = server.bind()?;
        let addr = listener
            .local_addr()
            .context("resolve listen address")?;
        let shared = Arc::clone(&server.shared);
        let drain = server.config.drain_timeout;
        let join = std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || serve_on(&server.shared, listener, drain))
            .context("spawn serve accept thread")?;
        Ok(ServerHandle { addr, shared, join: Some(join) })
    }

    fn bind(&self) -> Result<TcpListener> {
        let listener = TcpListener::bind(&self.config.addr)
            .with_context(|| {
                format!("bind serve listener on {}", self.config.addr)
            })?;
        listener
            .set_nonblocking(true)
            .context("set listener nonblocking")?;
        Ok(listener)
    }
}

/// Handle to an in-process [`Server::spawn`] instance.
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    join: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound listen address (with the real port when 0 was asked).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The server's metrics (shared with the serve loop).
    pub fn stats(&self) -> Arc<ServeStats> {
        Arc::clone(&self.shared.stats)
    }

    /// Ask the server to drain (idempotent, non-blocking).
    pub fn stop(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
    }

    /// Stop and wait for the drain to complete.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop();
        match self.join.take() {
            Some(j) => j
                .join()
                .map_err(|_| anyhow!("serve accept thread panicked")),
            None => Ok(()),
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The accept loop: non-blocking accept polled against the stop flag,
/// one detached thread per connection, then drain.
fn serve_on(
    shared: &Arc<Shared>,
    listener: TcpListener,
    drain_timeout: Duration,
) {
    while !shared.stopping() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let conn = Arc::clone(shared);
                shared.active.fetch_add(1, Ordering::SeqCst);
                let spawned = std::thread::Builder::new()
                    .name("serve-conn".into())
                    .spawn(move || {
                        handle_conn(&conn, stream);
                        conn.active.fetch_sub(1, Ordering::SeqCst);
                    });
                if spawned.is_err() {
                    // the thread never existed; give back its slot so
                    // drain does not wait on a ghost connection
                    shared.active.fetch_sub(1, Ordering::SeqCst);
                    eprintln!("serve: connection thread spawn failed");
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                std::thread::sleep(POLL);
            }
            Err(e) => {
                eprintln!("serve: accept error: {e}");
                std::thread::sleep(POLL);
            }
        }
    }
    // Drain: connection threads see the stop flag at their next poll
    // tick; in-flight frames finish and are answered first.
    let deadline = Instant::now() + drain_timeout;
    while shared.active.load(Ordering::SeqCst) > 0
        && Instant::now() < deadline
    {
        std::thread::sleep(POLL);
    }
    let leftover = shared.active.load(Ordering::SeqCst);
    if leftover > 0 {
        eprintln!(
            "serve: drain timeout with {leftover} connection(s) still \
             open"
        );
    }
    // Joining the worker pools happens here, not in some signal
    // context: dropping each pool closes its queue and joins threads.
    shared.cache.clear();
}

/// One connection: frames in, replies out, until EOF / stop / error.
fn handle_conn(shared: &Arc<Shared>, mut stream: TcpStream) {
    // Short read timeouts make the stop flag responsive between
    // frames; write timeouts keep a dead peer from pinning the thread.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_nodelay(true);
    loop {
        let msg = match read_frame_polled(&mut stream, || {
            shared.stopping()
        }) {
            Ok(Some(msg)) => msg,
            Ok(None) => return, // clean EOF or drain
            Err(e) => {
                // a torn frame is not answerable on a framed stream;
                // drop the connection (the error is still counted)
                shared.stats.record_error();
                eprintln!("serve: dropping connection: {e:#}");
                return;
            }
        };
        let (reply, shutdown) = handle_request(shared, &msg);
        if write_frame(&mut stream, &reply).is_err() {
            // peer went away mid-reply; nothing left to do here
            return;
        }
        if shutdown {
            shared.stop.store(true, Ordering::SeqCst);
            return;
        }
    }
}

/// Answer one request. Returns the reply and whether the server should
/// begin draining afterwards. Never panics: every failure mode is an
/// `ok: false` reply.
fn handle_request(shared: &Arc<Shared>, msg: &Json) -> (Json, bool) {
    let req = match parse_request(msg) {
        Ok(r) => r,
        Err(e) => {
            shared.stats.record_error();
            return (error_response(&format!("{e:#}")), false);
        }
    };
    match req {
        Request::Eval { model, points, precision } => {
            let t0 = Instant::now();
            let n = points.len();
            let precision = precision.unwrap_or(Precision::F64);
            let result = shared
                .cache
                .get(&shared.registry, &model)
                .and_then(|pool| pool.submit(points, precision));
            match result {
                Ok((u, eps)) => {
                    shared.stats.record_eval(
                        &model,
                        n as u64,
                        t0.elapsed().as_secs_f64() * 1e3,
                    );
                    (
                        eval_response(
                            &model,
                            precision,
                            &u,
                            eps.as_deref(),
                        ),
                        false,
                    )
                }
                Err(e) => {
                    // a failed load leaves nothing cached (the cache
                    // dropped any stale alias); make double sure a
                    // half-dead pool cannot linger either
                    shared.cache.evict(&model);
                    shared.stats.record_error();
                    (error_response(&format!("{e:#}")), false)
                }
            }
        }
        Request::Stats => {
            (shared.stats.snapshot(shared.policy.max_batch), false)
        }
        Request::Models => match shared.registry.models() {
            Ok(models) => (
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    (
                        "models",
                        Json::Arr(
                            models
                                .iter()
                                .map(|m| Json::str(m.clone()))
                                .collect(),
                        ),
                    ),
                    (
                        "loaded",
                        Json::num(shared.cache.len() as f64),
                    ),
                ]),
                false,
            ),
            Err(e) => {
                shared.stats.record_error();
                (error_response(&format!("{e:#}")), false)
            }
        },
        Request::Ping => (
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::str("pong")),
            ]),
            false,
        ),
        Request::Shutdown => (
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("draining", Json::Bool(true)),
            ]),
            true,
        ),
    }
}

/// Route SIGTERM/SIGINT to the shutdown flag so `kill -TERM` drains
/// instead of killing mid-request. Uses the raw libc `signal(2)`
/// symbol directly (the crate has no libc dependency); the handler
/// only stores to an atomic, which is async-signal-safe.
#[cfg(unix)]
fn install_signal_handler() {
    extern "C" fn on_signal(_sig: i32) {
        SIGNALLED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    let handler = on_signal as extern "C" fn(i32) as usize;
    unsafe {
        signal(SIGTERM, handler);
        signal(SIGINT, handler);
    }
}

/// Non-unix builds rely on in-process [`ServerHandle::stop`] / the
/// `shutdown` op only.
#[cfg(not(unix))]
fn install_signal_handler() {}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_sane() {
        let c = ServeConfig::new("127.0.0.1:0", "/tmp/registry");
        assert!(c.cache_capacity >= 1);
        assert!(c.workers_per_model >= 1);
        assert!(c.policy.max_batch >= 1);
    }

    #[test]
    fn opening_a_missing_registry_fails_before_binding() {
        let c = ServeConfig::new(
            "127.0.0.1:0",
            "/nonexistent/fastvpinns/registry",
        );
        assert!(Server::new(c).is_err());
    }
}
