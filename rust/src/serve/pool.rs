//! Per-model worker pool with micro-batch coalescing.
//!
//! [`InferenceSession::eval`] needs `&mut self` (it reuses scratch
//! buffers), so a pool is N workers each owning a private
//! [`fork`](InferenceSession::fork) of one loaded session — identical
//! parameter bits, private scratch. Connection threads submit jobs
//! into one bounded queue per model; a free worker drains it into a
//! micro-batch under the [`BatchPolicy`] (take up to `max_batch` jobs,
//! waiting at most `max_wait` for stragglers after the first), runs
//! *one* concatenated blocked-GEMM eval per precision present, and
//! splits the outputs back at request boundaries.
//!
//! Coalescing is bit-transparent at f64: the blocked eval path computes
//! each point independently of its batch neighbours, so a request's
//! outputs do not depend on which jobs it shared a batch with.

use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::runtime::infer::{InferenceSession, Precision};

use super::stats::ServeStats;

/// Micro-batch coalescing knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Most requests coalesced into one eval call.
    pub max_batch: usize,
    /// How long a worker waits for stragglers after the first job of a
    /// batch arrives.
    pub max_wait: Duration,
    /// Bound on queued-but-unclaimed jobs per model; submitters block
    /// (backpressure) when the queue is full.
    pub queue_depth: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_depth: 64,
        }
    }
}

/// Model outputs for one request: primary head, plus the eps head for
/// two-head networks.
pub type EvalOutput = (Vec<f32>, Option<Vec<f32>>);

/// One queued point-cloud query.
struct EvalJob {
    points: Vec<[f64; 2]>,
    precision: Precision,
    reply: SyncSender<EvalOutput>,
}

/// A pool of worker threads serving one loaded model.
///
/// Dropping the pool closes the queue and joins every worker.
pub struct ModelPool {
    tx: Option<SyncSender<EvalJob>>,
    workers: Vec<JoinHandle<()>>,
    policy: BatchPolicy,
    two_head: bool,
    stats: Arc<ServeStats>,
}

impl ModelPool {
    /// Spawn `n_workers` threads, each with a private fork of
    /// `session`. Fails only if the OS refuses to spawn any thread.
    pub fn start(
        session: &InferenceSession,
        n_workers: usize,
        policy: BatchPolicy,
        stats: Arc<ServeStats>,
    ) -> Result<ModelPool> {
        let n_workers = n_workers.max(1);
        let (tx, rx) =
            sync_channel::<EvalJob>(policy.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let mut sess = session.fork();
            let rx = Arc::clone(&rx);
            let stats = Arc::clone(&stats);
            let handle = std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || {
                    worker_loop(&mut sess, &rx, policy, &stats)
                })
                .context("spawning serve worker thread")?;
            workers.push(handle);
        }
        Ok(ModelPool {
            tx: Some(tx),
            workers,
            policy,
            two_head: session.two_head(),
            stats,
        })
    }

    /// Whether the served model has an eps head.
    pub fn two_head(&self) -> bool {
        self.two_head
    }

    /// The coalescing policy this pool runs under.
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a query and block until its micro-batch is evaluated.
    pub fn submit(
        &self,
        points: Vec<[f64; 2]>,
        precision: Precision,
    ) -> Result<EvalOutput> {
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("model pool is shut down"))?;
        let (reply_tx, reply_rx) = sync_channel::<EvalOutput>(1);
        // count the job as queued before the (blocking, backpressured)
        // send so the gauge covers the time spent waiting for a slot;
        // a failed send rolls the increment back
        self.stats.record_enqueue();
        if tx
            .send(EvalJob { points, precision, reply: reply_tx })
            .is_err()
        {
            self.stats.record_dequeue(1);
            return Err(anyhow!("model pool workers are gone"));
        }
        reply_rx
            .recv()
            .map_err(|_| anyhow!("model pool dropped the request"))
    }

    /// Close the queue (subsequent [`submit`](ModelPool::submit) calls
    /// error); workers exit once the backlog drains.
    pub fn close(&mut self) {
        self.tx = None;
    }
}

impl Drop for ModelPool {
    fn drop(&mut self) {
        // Closing the channel wakes every worker out of recv().
        self.tx = None;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Claim one micro-batch from the shared queue. Holds the queue lock
/// only while *collecting* jobs — evaluation happens outside, so other
/// workers can start coalescing the next batch immediately.
fn next_batch(
    rx: &Mutex<Receiver<EvalJob>>,
    policy: BatchPolicy,
) -> Option<Vec<EvalJob>> {
    let queue = match rx.lock() {
        Ok(q) => q,
        // Workers do not panic while holding this lock; if one somehow
        // did, the receiver underneath is still perfectly usable.
        Err(poisoned) => poisoned.into_inner(),
    };
    let first = queue.recv().ok()?; // closed queue: pool is draining
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let left = deadline.saturating_duration_since(Instant::now());
        match queue.recv_timeout(left) {
            Ok(job) => batch.push(job),
            Err(RecvTimeoutError::Timeout)
            | Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

fn worker_loop(
    sess: &mut InferenceSession,
    rx: &Mutex<Receiver<EvalJob>>,
    policy: BatchPolicy,
    stats: &ServeStats,
) {
    while let Some(batch) = next_batch(rx, policy) {
        stats.record_dequeue(batch.len());
        stats.record_batch(batch.len());
        if crate::telemetry::armed() {
            // queue pressure + batch fill, sampled at the moment a
            // worker claims a coalesced batch (the natural clock of
            // the serve plane)
            crate::telemetry::emit(
                crate::telemetry::Event::QueueSample {
                    queued: stats.queued(),
                    hwm: stats.queue_hwm(),
                },
            );
            crate::telemetry::emit(
                crate::telemetry::Event::BatchFlush {
                    len: batch.len() as u64,
                    max: policy.max_batch as u64,
                },
            );
        }
        eval_batch(sess, &batch);
    }
}

/// Run one coalesced batch: group jobs by precision (at most two
/// groups), concatenate each group's points into a single eval call,
/// then split the outputs back at request boundaries and reply.
fn eval_batch(sess: &mut InferenceSession, batch: &[EvalJob]) {
    for want in [Precision::F64, Precision::F32] {
        let group: Vec<&EvalJob> =
            batch.iter().filter(|j| j.precision == want).collect();
        if group.is_empty() {
            continue;
        }
        let total: usize = group.iter().map(|j| j.points.len()).sum();
        let mut points = Vec::with_capacity(total);
        for job in &group {
            points.extend_from_slice(&job.points);
        }
        sess.set_precision(want);
        let (u, eps) = sess.eval(&points);
        let mut off = 0usize;
        for job in &group {
            let n = job.points.len();
            let u_part = u[off..off + n].to_vec();
            let eps_part =
                eps.as_ref().map(|e| e[off..off + n].to_vec());
            off += n;
            // The submitter may have given up (connection dropped);
            // a dead reply channel is not the worker's problem.
            let _ = job.reply.send((u_part, eps_part));
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::serve::bench::synthetic_checkpoint;

    fn tiny_session(two_head: bool) -> InferenceSession {
        let ck =
            synthetic_checkpoint(&[2, 8, 1], two_head, 7).unwrap();
        InferenceSession::from_checkpoint(&ck).unwrap()
    }

    fn grid(n: usize, salt: f64) -> Vec<[f64; 2]> {
        (0..n)
            .map(|i| {
                let t = i as f64 / n as f64;
                [t, (t + salt).fract()]
            })
            .collect()
    }

    #[test]
    fn coalesced_results_match_a_lone_session_bitwise() {
        let mut lone = tiny_session(false);
        let pool = ModelPool::start(
            &lone,
            3,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(20),
                queue_depth: 16,
            },
            Arc::new(ServeStats::new()),
        )
        .unwrap();
        for i in 0..12 {
            let q = grid(5 + i % 3, i as f64 * 0.13);
            let (u, eps) =
                pool.submit(q.clone(), Precision::F64).unwrap();
            let (lu, leps) = lone.eval(&q);
            assert_eq!(u, lu);
            assert_eq!(eps, leps);
        }
    }

    #[test]
    fn two_head_outputs_split_correctly_across_a_batch() {
        let mut lone = tiny_session(true);
        let pool = ModelPool::start(
            &lone,
            2,
            BatchPolicy::default(),
            Arc::new(ServeStats::new()),
        )
        .unwrap();
        assert!(pool.two_head());
        for i in 0..6 {
            let q = grid(4 + i, 0.31 * i as f64);
            let (u, eps) =
                pool.submit(q.clone(), Precision::F64).unwrap();
            let (lu, leps) = lone.eval(&q);
            assert_eq!(u, lu);
            assert_eq!(eps.unwrap(), leps.unwrap());
        }
    }

    #[test]
    fn concurrent_mixed_precision_submissions_all_answer() {
        let lone = tiny_session(false);
        let stats = Arc::new(ServeStats::new());
        let pool = Arc::new(
            ModelPool::start(
                &lone,
                2,
                BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(10),
                    queue_depth: 16,
                },
                Arc::clone(&stats),
            )
            .unwrap(),
        );
        let mut joins = Vec::new();
        for i in 0..8u32 {
            let pool = Arc::clone(&pool);
            let prec = if i % 2 == 0 {
                Precision::F64
            } else {
                Precision::F32
            };
            joins.push(std::thread::spawn(move || {
                let q = grid(6, 0.05 * f64::from(i));
                pool.submit(q, prec).unwrap().0.len()
            }));
        }
        for j in joins {
            assert_eq!(j.join().unwrap(), 6);
        }
        // the pool recorded its coalesced batches
        let fill = stats.batch_fill(8);
        assert!(fill > 0.0 && fill <= 1.0, "fill {fill}");
        // every submit passed through the queue gauge: the high-water
        // mark saw at least one job, and everything drained back out
        let hwm = stats.queue_hwm();
        assert!((1..=8).contains(&hwm), "queue hwm {hwm}");
        let j = stats.snapshot(8);
        let batch = j.req("batch").unwrap();
        assert_eq!(
            batch.req("queued").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn submit_after_close_is_an_error_not_a_hang() {
        let lone = tiny_session(false);
        let mut pool = ModelPool::start(
            &lone,
            1,
            BatchPolicy::default(),
            Arc::new(ServeStats::new()),
        )
        .unwrap();
        pool.close();
        assert!(pool.submit(grid(3, 0.0), Precision::F64).is_err());
    }
}
