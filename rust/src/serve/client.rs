//! A small blocking client for the serve protocol — used by the
//! `repro serve-probe` CLI, the e2e tests and the serve bench. Any
//! language can speak the protocol (4-byte LE length + JSON); this is
//! merely the in-repo reference implementation.

use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::runtime::infer::Precision;
use crate::util::json::Json;

use super::protocol::{
    decode_f32s, read_frame, write_frame,
};

/// One connection to a running serve instance.
pub struct ServeClient {
    stream: TcpStream,
}

impl ServeClient {
    /// Connect to a server.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<ServeClient> {
        let mut last: Option<std::io::Error> = None;
        for a in addr
            .to_socket_addrs()
            .context("resolve serve address")?
        {
            match TcpStream::connect_timeout(
                &a,
                Duration::from_secs(5),
            ) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    return Ok(ServeClient { stream });
                }
                Err(e) => last = Some(e),
            }
        }
        match last {
            Some(e) => {
                Err(e).context("connect to serve instance")
            }
            None => bail!("serve address resolved to nothing"),
        }
    }

    /// Send one request object, wait for its reply.
    pub fn request(&mut self, msg: &Json) -> Result<Json> {
        write_frame(&mut self.stream, msg)?;
        match read_frame(&mut self.stream)? {
            Some(reply) => Ok(reply),
            None => bail!("server closed the connection mid-request"),
        }
    }

    /// Send a request and insist on `ok: true`, surfacing the server's
    /// error message otherwise.
    fn request_ok(&mut self, msg: &Json) -> Result<Json> {
        let reply = self.request(msg)?;
        let ok = reply
            .req("ok")
            .and_then(Json::as_bool)
            .unwrap_or(false);
        if !ok {
            let why = reply
                .get("error")
                .and_then(|e| e.as_str().ok())
                .unwrap_or("server reported failure without a message");
            bail!("serve error: {why}");
        }
        Ok(reply)
    }

    /// Evaluate `model` over `points`; `precision: None` uses the
    /// server default (f64).
    pub fn eval(
        &mut self,
        model: &str,
        points: &[[f64; 2]],
        precision: Option<Precision>,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>)> {
        let mut flat = Vec::with_capacity(points.len() * 2);
        for p in points {
            flat.push(Json::num(p[0]));
            flat.push(Json::num(p[1]));
        }
        let mut fields = vec![
            ("op", Json::str("eval")),
            ("model", Json::str(model)),
            ("points", Json::Arr(flat)),
        ];
        if let Some(p) = precision {
            fields.push(("precision", Json::str(p.to_string())));
        }
        let reply = self.request_ok(&Json::obj(fields))?;
        let u = decode_f32s(reply.req("u")?)
            .context("decode u outputs")?;
        let eps = match reply.get("eps") {
            Some(e) => {
                Some(decode_f32s(e).context("decode eps outputs")?)
            }
            None => None,
        };
        Ok((u, eps))
    }

    /// Fetch the metrics snapshot.
    pub fn stats(&mut self) -> Result<Json> {
        self.request_ok(&Json::obj(vec![("op", Json::str("stats"))]))
    }

    /// List servable model names.
    pub fn models(&mut self) -> Result<Vec<String>> {
        let reply = self
            .request_ok(&Json::obj(vec![("op", Json::str("models"))]))?;
        reply
            .req("models")?
            .as_arr()?
            .iter()
            .map(|m| Ok(m.as_str()?.to_string()))
            .collect()
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        self.request_ok(&Json::obj(vec![("op", Json::str("ping"))]))?;
        Ok(())
    }

    /// Ask the server to drain and exit.
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.request_ok(&Json::obj(vec![(
            "op",
            Json::str("shutdown"),
        )]))?;
        Ok(())
    }
}
