//! The wire protocol: length-prefixed JSON frames and the typed
//! request/response vocabulary.
//!
//! A frame is a 4-byte little-endian `u32` payload length followed by
//! exactly that many bytes of UTF-8 JSON. Requests are objects with an
//! `"op"` key; responses always carry `"ok": true|false` (with an
//! `"error"` message when false). Query points travel as a flat
//! interleaved array `[x0, y0, x1, y1, ...]`; outputs come back as
//! arrays of numbers. `f32` outputs are serialized through their exact
//! `f64` value and Rust's shortest-roundtrip formatting, so the bits a
//! client decodes equal the bits the session computed.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::runtime::infer::Precision;
use crate::util::json::Json;

/// Hard per-frame size limit (bytes of JSON payload). Large enough for
/// ~1M-point queries, small enough that a garbage length prefix cannot
/// OOM the server.
pub const MAX_FRAME: usize = 64 << 20;

/// Serialize `msg` and write it as one frame.
pub fn write_frame(w: &mut impl Write, msg: &Json) -> Result<()> {
    let body = msg.to_string();
    if body.len() > MAX_FRAME {
        bail!(
            "frame of {} bytes exceeds the {MAX_FRAME}-byte limit",
            body.len()
        );
    }
    w.write_all(&(body.len() as u32).to_le_bytes())
        .context("write frame header")?;
    w.write_all(body.as_bytes()).context("write frame body")?;
    w.flush().context("flush frame")?;
    Ok(())
}

/// Read one frame from a blocking stream. `Ok(None)` on a clean EOF
/// (the peer closed between frames); an EOF mid-frame is an error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Json>> {
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(e) => return Err(e).context("read frame header"),
        }
    }
    finish_frame(r, first[0], || false)
}

/// Read one frame from a stream with a read timeout set, polling
/// `stop` between timeouts while waiting for the frame to *start*.
/// Returns `Ok(None)` on clean EOF or when `stop()` turns true before
/// a frame begins; once the first byte has arrived the frame is read
/// to completion regardless of `stop` (drain semantics: an in-flight
/// request finishes).
pub fn read_frame_polled(
    r: &mut impl Read,
    stop: impl Fn() -> bool,
) -> Result<Option<Json>> {
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if is_timeout(&e) => {
                if stop() {
                    return Ok(None);
                }
            }
            Err(e) => return Err(e).context("read frame header"),
        }
    }
    finish_frame(r, first[0], stop)
}

/// Read the rest of a frame whose first header byte is `b0`.
fn finish_frame(
    r: &mut impl Read,
    b0: u8,
    stop: impl Fn() -> bool,
) -> Result<Option<Json>> {
    let mut hdr = [0u8; 3];
    read_exact_retry(r, &mut hdr, &stop).context("read frame header")?;
    let len =
        u32::from_le_bytes([b0, hdr[0], hdr[1], hdr[2]]) as usize;
    if len > MAX_FRAME {
        bail!("frame of {len} bytes exceeds the {MAX_FRAME}-byte limit");
    }
    let mut body = vec![0u8; len];
    read_exact_retry(r, &mut body, &stop).context("read frame body")?;
    let text =
        std::str::from_utf8(&body).context("frame is not UTF-8")?;
    Ok(Some(Json::parse(text).context("frame is not valid JSON")?))
}

/// `read_exact` that rides through read timeouts and interrupts (the
/// server polls its shutdown flag via short read timeouts, which must
/// not tear a frame that is mid-flight on a slow link). A mid-frame
/// EOF is an error. Gives up after ~30s of timeout retries so a peer
/// that stalls mid-frame cannot pin the connection thread forever.
fn read_exact_retry(
    r: &mut impl Read,
    buf: &mut [u8],
    _stop: &impl Fn() -> bool,
) -> Result<()> {
    let mut got = 0;
    let mut stalls = 0u32;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => bail!("connection closed mid-frame"),
            Ok(n) => {
                got += n;
                stalls = 0;
            }
            Err(e) if is_timeout(&e) => {
                stalls += 1;
                if stalls > 300 {
                    bail!("peer stalled mid-frame");
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::Interrupted
    )
}

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Evaluate `model` over a point cloud.
    Eval {
        /// Registry model name (file stem of the artifact).
        model: String,
        /// Query points.
        points: Vec<[f64; 2]>,
        /// Per-request precision override (server default when None).
        precision: Option<Precision>,
    },
    /// Metrics snapshot.
    Stats,
    /// List registry models and their load state.
    Models,
    /// Liveness probe.
    Ping,
    /// Ask the server to drain and exit (same path as SIGTERM).
    Shutdown,
}

/// Parse a request object. Every malformation is a recoverable error
/// (the server answers `{"ok": false, ...}` and keeps the connection).
pub fn parse_request(j: &Json) -> Result<Request> {
    let op = j.req("op")?.as_str()?;
    match op {
        "eval" => {
            let model = j.req("model")?.as_str()?.to_string();
            let flat = j.req("points")?.as_arr()?;
            if flat.is_empty() {
                bail!("points is empty");
            }
            if flat.len() % 2 != 0 {
                bail!(
                    "points must be a flat [x0,y0,x1,y1,...] array \
                     (got odd length {})",
                    flat.len()
                );
            }
            let mut points = Vec::with_capacity(flat.len() / 2);
            for pair in flat.chunks_exact(2) {
                let x = pair[0].as_f64()?;
                let y = pair[1].as_f64()?;
                if !x.is_finite() || !y.is_finite() {
                    bail!("non-finite query point ({x}, {y})");
                }
                points.push([x, y]);
            }
            let precision = match j.get("precision") {
                Some(p) => Some(p.as_str()?.parse()?),
                None => None,
            };
            Ok(Request::Eval { model, points, precision })
        }
        "stats" => Ok(Request::Stats),
        "models" => Ok(Request::Models),
        "ping" => Ok(Request::Ping),
        "shutdown" => Ok(Request::Shutdown),
        other => bail!(
            "unknown op {other:?} \
             (expected eval|stats|models|ping|shutdown)"
        ),
    }
}

/// A number that is guaranteed to serialize as valid JSON: non-finite
/// values (which the writer would emit as the invalid tokens `NaN` /
/// `inf`) become `null`. Clients decode `null` back to NaN.
pub fn finite_num(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

/// Encode f32 outputs: each value through its exact f64 widening, so
/// shortest-roundtrip f64 text reproduces the f32 bits on decode.
fn f32_array(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| finite_num(x as f64)).collect())
}

/// Successful eval response.
pub fn eval_response(
    model: &str,
    precision: Precision,
    u: &[f32],
    eps: Option<&[f32]>,
) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("model", Json::str(model)),
        ("precision", Json::str(precision.to_string())),
        ("n", Json::num(u.len() as f64)),
        ("u", f32_array(u)),
    ];
    if let Some(e) = eps {
        fields.push(("eps", f32_array(e)));
    }
    Json::obj(fields)
}

/// Error response (`ok: false`).
pub fn error_response(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
}

/// Decode an output array written by [`eval_response`] back to f32
/// (`null` → NaN, the encoding of a non-finite output).
pub fn decode_f32s(j: &Json) -> Result<Vec<f32>> {
    j.as_arr()?
        .iter()
        .map(|v| match v {
            Json::Null => Ok(f32::NAN),
            other => other.as_f64().map(|x| x as f32),
        })
        .collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let msg = Json::obj(vec![
            ("op", Json::str("eval")),
            ("model", Json::str("m")),
            ("points", Json::Arr(vec![Json::num(0.5), Json::num(0.25)])),
        ]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg).unwrap();
        let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
        assert_eq!(len as usize, buf.len() - 4, "length prefix");
        let back = read_frame(&mut &buf[..]).unwrap().unwrap();
        assert_eq!(back, msg);
        // two frames back to back
        let mut twice = buf.clone();
        twice.extend_from_slice(&buf);
        let mut r = &twice[..];
        assert!(read_frame(&mut r).unwrap().is_some());
        assert!(read_frame(&mut r).unwrap().is_some());
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn oversized_and_torn_frames_are_errors() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(b"xxxx");
        let err = read_frame(&mut &buf[..]).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
        // header promises more bytes than arrive
        let mut torn = Vec::new();
        torn.extend_from_slice(&8u32.to_le_bytes());
        torn.extend_from_slice(b"tru");
        assert!(read_frame(&mut &torn[..]).is_err());
    }

    #[test]
    fn requests_parse_and_reject() {
        let j = Json::parse(
            r#"{"op":"eval","model":"poisson",
                "points":[0.1,0.2,0.3,0.4],"precision":"f32"}"#,
        )
        .unwrap();
        match parse_request(&j).unwrap() {
            Request::Eval { model, points, precision } => {
                assert_eq!(model, "poisson");
                assert_eq!(points, vec![[0.1, 0.2], [0.3, 0.4]]);
                assert_eq!(precision, Some(Precision::F32));
            }
            other => panic!("wrong request: {other:?}"),
        }
        for (txt, needle) in [
            (r#"{"op":"warp"}"#, "unknown op"),
            (r#"{"op":"eval","model":"m","points":[1.0]}"#, "odd"),
            (r#"{"op":"eval","model":"m","points":[]}"#, "empty"),
            (r#"{"points":[1,2]}"#, "op"),
        ] {
            let j = Json::parse(txt).unwrap();
            let err = parse_request(&j).unwrap_err().to_string();
            assert!(err.contains(needle), "{txt} -> {err}");
        }
        assert_eq!(parse_request(&Json::parse(r#"{"op":"stats"}"#)
            .unwrap()).unwrap(), Request::Stats);
    }

    #[test]
    fn f32_outputs_roundtrip_bitwise() {
        // shortest-f64 text of the exact widening reproduces the bits
        let vals: Vec<f32> = vec![
            0.1,
            -1.5e-7,
            std::f32::consts::PI,
            f32::MIN_POSITIVE,
            1.0e30,
            -0.0,
        ];
        let resp = eval_response("m", Precision::F64, &vals, None);
        let mut buf = Vec::new();
        write_frame(&mut buf, &resp).unwrap();
        let back = read_frame(&mut &buf[..]).unwrap().unwrap();
        let dec = decode_f32s(back.req("u").unwrap()).unwrap();
        for (a, b) in vals.iter().zip(&dec) {
            // -0.0 flattens to 0 through the writer's integer form;
            // IEEE equality (not bits) is the contract at zero
            if *a == 0.0 {
                assert!(*a == *b);
            } else {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
            }
        }
        // non-finite encodes as null, decodes as NaN
        let resp =
            eval_response("m", Precision::F64, &[f32::NAN], None);
        let mut buf = Vec::new();
        write_frame(&mut buf, &resp).unwrap();
        let back = read_frame(&mut &buf[..]).unwrap().unwrap();
        let dec = decode_f32s(back.req("u").unwrap()).unwrap();
        assert!(dec[0].is_nan());
    }
}
