//! `repro serve` — a long-running, micro-batching inference server on
//! top of [`InferenceSession`](crate::runtime::infer::InferenceSession):
//! the amortized-inference payoff of the paper as a *system*. Training
//! a FastVPINN is the expensive part; once trained, answering a point
//! query is a few small GEMMs — this module keeps trained models
//! resident and turns concurrent query traffic into the large batches
//! the blocked-GEMM eval path is fastest at.
//!
//! ## Data flow
//!
//! ```text
//! client ──TCP frame──▶ connection thread ──EvalJob──▶ per-model queue
//!                                                        │ (bounded)
//!                             worker pool (one forked session each)
//!                               │  coalesce ≤ max_batch jobs, wait
//!                               │  ≤ max_wait for stragglers
//!                               ▼
//!                        one blocked-GEMM eval over the
//!                        concatenated point cloud, split back
//!                        per request ──reply──▶ connection thread
//! ```
//!
//! - **Protocol** ([`protocol`]): length-prefixed JSON frames over TCP
//!   — a 4-byte little-endian length, then one UTF-8 JSON object. No
//!   heavy dependencies, `nc`/any language can speak it.
//! - **Registry** ([`registry`]): a directory of `<name>.ckpt`
//!   artifacts. Models load lazily on first query (salvage-aware:
//!   a torn primary falls back to its generation ring) and live in an
//!   LRU cache keyed by *artifact fingerprint*, so two names pointing
//!   at byte-identical artifacts share one worker pool. A load failure
//!   (e.g. the `io.read.err` failpoint) is an error reply to that one
//!   client — never a server crash, and nothing broken is cached.
//! - **Micro-batching** ([`pool`]): each model runs a pool of worker
//!   threads, each owning a private forked session (`eval` needs `&mut
//!   self`). Workers drain the model's bounded queue into micro-batches
//!   under a max-batch/max-wait policy. At f64 the coalesced results
//!   are bit-identical to a lone single-threaded session: per-point
//!   outputs are independent of batch composition on the blocked eval
//!   path, and every fork shares the exact parameter bits.
//! - **Stats** ([`stats`]): a `/metrics`-style reply — requests/sec,
//!   p50/p90/p99 latency via [`Summary`](crate::util::stats::Summary)
//!   (non-finite samples counted-and-dropped, never a panic),
//!   batch-fill ratio, per-model hit counts.
//! - **Drain** ([`server`]): SIGTERM (or a `shutdown` op) stops the
//!   accept loop, lets in-flight requests finish, joins the worker
//!   pools and prints a final stats line — `kill -TERM` is a clean
//!   exit, tested by the CI `serve-smoke` job.

// The serve loop must never take the whole server down on one bad
// request, sample or artifact: panics are forbidden on this path.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod bench;
pub mod client;
pub mod pool;
pub mod protocol;
pub mod registry;
pub mod server;
pub mod stats;

pub use client::ServeClient;
pub use pool::{BatchPolicy, ModelPool};
pub use registry::{ModelCache, Registry};
pub use server::{ServeConfig, Server, ServerHandle};
pub use stats::ServeStats;
