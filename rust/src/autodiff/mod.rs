//! Scalar forward-mode automatic differentiation to second order.
//!
//! Used to manufacture forcing terms `f = -eps*lap(u) + b.grad(u)` from
//! exact solutions without hand-derived calculus (problems.rs): a
//! `Dual2` carries (value, d/dt, d2/dt2) along a 1D probe direction, so
//! the 2D Laplacian is two axis probes.

use std::ops::{Add, Div, Mul, Neg, Sub};

/// Second-order dual number: value, first and second derivative with
/// respect to a single scalar parameter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dual2 {
    /// Value.
    pub v: f64,
    /// First derivative along the probe direction.
    pub d1: f64,
    /// Second derivative along the probe direction.
    pub d2: f64,
}

impl Dual2 {
    /// The active variable: value x, dx/dx = 1.
    pub fn var(x: f64) -> Dual2 {
        Dual2 { v: x, d1: 1.0, d2: 0.0 }
    }

    /// A constant.
    pub fn con(c: f64) -> Dual2 {
        Dual2 { v: c, d1: 0.0, d2: 0.0 }
    }

    /// `sin`, propagating both derivatives.
    pub fn sin(self) -> Dual2 {
        let (s, c) = (self.v.sin(), self.v.cos());
        Dual2 {
            v: s,
            d1: c * self.d1,
            d2: c * self.d2 - s * self.d1 * self.d1,
        }
    }

    /// `cos`, propagating both derivatives.
    pub fn cos(self) -> Dual2 {
        let (s, c) = (self.v.sin(), self.v.cos());
        Dual2 {
            v: c,
            d1: -s * self.d1,
            d2: -s * self.d2 - c * self.d1 * self.d1,
        }
    }

    /// `exp`, propagating both derivatives.
    pub fn exp(self) -> Dual2 {
        let e = self.v.exp();
        Dual2 {
            v: e,
            d1: e * self.d1,
            d2: e * (self.d2 + self.d1 * self.d1),
        }
    }

    /// `tanh`, propagating both derivatives.
    pub fn tanh(self) -> Dual2 {
        let t = self.v.tanh();
        let sech2 = 1.0 - t * t;
        Dual2 {
            v: t,
            d1: sech2 * self.d1,
            d2: sech2 * self.d2 - 2.0 * t * sech2 * self.d1 * self.d1,
        }
    }

    /// Integer power (`n >= 2`), propagating both derivatives.
    pub fn powi(self, n: i32) -> Dual2 {
        let vp = self.v.powi(n - 2);
        let n_ = n as f64;
        Dual2 {
            v: vp * self.v * self.v,
            d1: n_ * vp * self.v * self.d1,
            d2: n_ * vp * self.v * self.d2
                + n_ * (n_ - 1.0) * vp * self.d1 * self.d1,
        }
    }

    /// Natural log, propagating both derivatives.
    pub fn ln(self) -> Dual2 {
        let d1 = self.d1 / self.v;
        Dual2 {
            v: self.v.ln(),
            d1,
            d2: self.d2 / self.v - d1 * d1,
        }
    }

    /// Square root, propagating both derivatives.
    pub fn sqrt(self) -> Dual2 {
        let s = self.v.sqrt();
        Dual2 {
            v: s,
            d1: 0.5 / s * self.d1,
            d2: 0.5 / s * self.d2 - 0.25 / (s * self.v) * self.d1 * self.d1,
        }
    }
}

impl Add for Dual2 {
    type Output = Dual2;
    fn add(self, o: Dual2) -> Dual2 {
        Dual2 { v: self.v + o.v, d1: self.d1 + o.d1, d2: self.d2 + o.d2 }
    }
}

impl Sub for Dual2 {
    type Output = Dual2;
    fn sub(self, o: Dual2) -> Dual2 {
        Dual2 { v: self.v - o.v, d1: self.d1 - o.d1, d2: self.d2 - o.d2 }
    }
}

impl Mul for Dual2 {
    type Output = Dual2;
    fn mul(self, o: Dual2) -> Dual2 {
        Dual2 {
            v: self.v * o.v,
            d1: self.d1 * o.v + self.v * o.d1,
            d2: self.d2 * o.v + 2.0 * self.d1 * o.d1 + self.v * o.d2,
        }
    }
}

impl Div for Dual2 {
    type Output = Dual2;
    fn div(self, o: Dual2) -> Dual2 {
        let w = self.v / o.v;
        let d1 = (self.d1 - w * o.d1) / o.v;
        let d2 = (self.d2 - 2.0 * d1 * o.d1 - w * o.d2) / o.v;
        Dual2 { v: w, d1, d2 }
    }
}

impl Neg for Dual2 {
    type Output = Dual2;
    fn neg(self) -> Dual2 {
        Dual2 { v: -self.v, d1: -self.d1, d2: -self.d2 }
    }
}

impl Mul<f64> for Dual2 {
    type Output = Dual2;
    fn mul(self, s: f64) -> Dual2 {
        Dual2 { v: self.v * s, d1: self.d1 * s, d2: self.d2 * s }
    }
}

/// Evaluate (u, du/dx, du/dy, lap u) of a bivariate scalar function given
/// as a Dual2 closure, probing each axis.
pub fn probe_2d(
    u: impl Fn(Dual2, Dual2) -> Dual2,
    x: f64,
    y: f64,
) -> Probe2d {
    let ux = u(Dual2::var(x), Dual2::con(y));
    let uy = u(Dual2::con(x), Dual2::var(y));
    Probe2d {
        u: ux.v,
        dx: ux.d1,
        dy: uy.d1,
        lap: ux.d2 + uy.d2,
    }
}

/// Result of probing a 2D function with axis-aligned [`Dual2`]
/// variables: value, gradient and Laplacian at one point.
#[derive(Debug, Clone, Copy)]
pub struct Probe2d {
    /// u(x, y).
    pub u: f64,
    /// du/dx.
    pub dx: f64,
    /// du/dy.
    pub dy: f64,
    /// lap u = u_xx + u_yy.
    pub lap: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn polynomial_derivatives() {
        // f(x) = x^3 - 2x: f' = 3x^2-2, f'' = 6x
        let x = Dual2::var(1.7);
        let f = x.powi(3) - x * 2.0;
        assert!(close(f.v, 1.7f64.powi(3) - 3.4, 1e-14));
        assert!(close(f.d1, 3.0 * 1.7 * 1.7 - 2.0, 1e-14));
        assert!(close(f.d2, 6.0 * 1.7, 1e-14));
    }

    #[test]
    fn trig_derivatives() {
        let x = Dual2::var(0.8);
        let f = x.sin();
        assert!(close(f.d1, 0.8f64.cos(), 1e-14));
        assert!(close(f.d2, -0.8f64.sin(), 1e-14));
        let g = x.cos();
        assert!(close(g.d2, -0.8f64.cos(), 1e-14));
    }

    #[test]
    fn chain_rule_second_order() {
        // f = sin(x^2): f'' = 2cos(x^2) - 4x^2 sin(x^2)
        let xv = 0.6;
        let f = (Dual2::var(xv) * Dual2::var(xv)).sin();
        let want = 2.0 * (xv * xv).cos() - 4.0 * xv * xv * (xv * xv).sin();
        assert!(close(f.d2, want, 1e-13));
    }

    #[test]
    fn exp_tanh() {
        let xv = -0.4;
        let f = Dual2::var(xv).exp();
        assert!(close(f.d2, xv.exp(), 1e-14));
        let t = Dual2::var(xv).tanh();
        let tv = xv.tanh();
        assert!(close(t.d1, 1.0 - tv * tv, 1e-14));
        // (tanh)'' = -2 tanh sech^2
        assert!(close(t.d2, -2.0 * tv * (1.0 - tv * tv), 1e-13));
    }

    #[test]
    fn ln_derivatives() {
        // f = ln(x): f' = 1/x, f'' = -1/x^2
        let x = Dual2::var(1.3);
        let f = x.ln();
        assert!(close(f.v, 1.3f64.ln(), 1e-14));
        assert!(close(f.d1, 1.0 / 1.3, 1e-14));
        assert!(close(f.d2, -1.0 / (1.3 * 1.3), 1e-14));
        // chain: ln(1 + e^z) has d1 = sigmoid(z)
        let z = Dual2::var(-0.7);
        let sp = (z.exp() + Dual2::con(1.0)).ln();
        let sig = 1.0 / (1.0 + 0.7f64.exp());
        assert!(close(sp.d1, sig, 1e-14));
    }

    #[test]
    fn division() {
        // f = 1/(1+x^2): check against finite differences
        let xv = 0.9;
        let f = Dual2::con(1.0) / (Dual2::con(1.0)
            + Dual2::var(xv) * Dual2::var(xv));
        let h = 1e-5;
        let g = |x: f64| 1.0 / (1.0 + x * x);
        let fd1 = (g(xv + h) - g(xv - h)) / (2.0 * h);
        let fd2 = (g(xv + h) - 2.0 * g(xv) + g(xv - h)) / (h * h);
        assert!(close(f.d1, fd1, 1e-8));
        assert!(close(f.d2, fd2, 1e-4));
    }

    #[test]
    fn laplacian_of_sinsin() {
        // u = sin(ax) sin(ay): lap u = -2a^2 u
        let a = 2.0 * std::f64::consts::PI;
        let p = probe_2d(
            |x, y| (x * a).sin() * (y * a).sin(),
            0.3, 0.7,
        );
        let u = (a * 0.3f64).sin() * (a * 0.7f64).sin();
        assert!(close(p.u, u, 1e-14));
        assert!(close(p.lap, -2.0 * a * a * u, 1e-11));
    }

    #[test]
    fn inverse_problem_exact_solution() {
        // u = 10 sin(x) tanh(x) exp(-eps x^2), eps = 0.3 (paper SS4.7.1):
        // cross-check the Dual2 laplacian against finite differences
        let eps = 0.3;
        let u = |x: Dual2, _y: Dual2| {
            x.sin() * x.tanh() * ((x * x) * (-eps)).exp() * 10.0
        };
        let (xv, yv) = (0.45, -0.2);
        let p = probe_2d(u, xv, yv);
        let g = |x: f64| {
            10.0 * x.sin() * x.tanh() * (-eps * x * x).exp()
        };
        let h = 1e-5;
        let fd2 = (g(xv + h) - 2.0 * g(xv) + g(xv - h)) / (h * h);
        assert!(close(p.lap, fd2, 1e-4), "{} vs {}", p.lap, fd2);
        assert!(close(p.dy, 0.0, 1e-14));
    }
}
