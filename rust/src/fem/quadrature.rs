//! Gauss quadrature on [-1,1] and the tensor-product 2D rule (mirrors
//! python fem_py.quadrature, same Newton iterations and ordering).

use anyhow::{bail, Result};

use super::jacobi;

/// Which 1D rule to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuadKind {
    /// Interior Gauss-Legendre points (exact to degree 2n-1).
    GaussLegendre,
    /// Gauss-Lobatto points incl. the endpoints.
    GaussLobatto,
}

impl QuadKind {
    /// Parse a CLI quadrature name ("gauss-legendre"/"gl", ...).
    pub fn parse(s: &str) -> Result<QuadKind> {
        match s {
            "gauss-legendre" | "gl" => Ok(QuadKind::GaussLegendre),
            "gauss-lobatto" | "gll" | "lobatto" => Ok(QuadKind::GaussLobatto),
            _ => bail!("unknown quadrature kind '{s}'"),
        }
    }
}

/// n-point Gauss-Legendre rule (exact to degree 2n-1), ascending points.
pub fn gauss_legendre(n: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n >= 1);
    if n == 1 {
        return (vec![0.0], vec![2.0]);
    }
    let mut x: Vec<f64> = (1..=n)
        .map(|k| {
            -((std::f64::consts::PI * (k as f64 - 0.25)
                / (n as f64 + 0.5))
                .cos())
        })
        .collect();
    for xi in &mut x {
        for _ in 0..100 {
            let p = jacobi::legendre(n, *xi);
            let dp = jacobi::legendre_deriv(n, *xi);
            let dx = p / dp;
            *xi -= dx;
            if dx.abs() < 1e-15 {
                break;
            }
        }
    }
    let w: Vec<f64> = x
        .iter()
        .map(|&xi| {
            let dp = jacobi::legendre_deriv(n, xi);
            2.0 / ((1.0 - xi * xi) * dp * dp)
        })
        .collect();
    (x, w)
}

/// n-point Gauss-Lobatto-Legendre rule (endpoints included, exact to
/// degree 2n-3).
pub fn gauss_lobatto(n: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n >= 2, "Lobatto rules need n >= 2");
    if n == 2 {
        return (vec![-1.0, 1.0], vec![1.0, 1.0]);
    }
    let m = n - 1;
    let mut interior: Vec<f64> = (1..m)
        .map(|k| -((std::f64::consts::PI * k as f64 / m as f64).cos()))
        .collect();
    for xi in &mut interior {
        for _ in 0..100 {
            let p = jacobi::legendre(m, *xi);
            let dp = jacobi::legendre_deriv(m, *xi);
            let d2p = (2.0 * *xi * dp - (m * (m + 1)) as f64 * p)
                / (1.0 - *xi * *xi);
            let dx = dp / d2p;
            *xi -= dx;
            if dx.abs() < 1e-15 {
                break;
            }
        }
    }
    let mut x = Vec::with_capacity(n);
    x.push(-1.0);
    x.extend(interior);
    x.push(1.0);
    let w: Vec<f64> = x
        .iter()
        .map(|&xi| {
            let pm = jacobi::legendre(m, xi);
            2.0 / ((m * (m + 1)) as f64 * pm * pm)
        })
        .collect();
    (x, w)
}

/// The n-point 1D rule on [-1, 1]: (points, weights).
pub fn rule_1d(n: usize, kind: QuadKind) -> (Vec<f64>, Vec<f64>) {
    match kind {
        QuadKind::GaussLegendre => gauss_legendre(n),
        QuadKind::GaussLobatto => gauss_lobatto(n),
    }
}

/// Tensor-product rule on [-1,1]^2: q = i*n1d + j, xi_q = x[i],
/// eta_q = x[j]. Ordering is the cross-layer contract with
/// fem_py.quadrature.tensor_rule_2d.
pub struct TensorRule2d {
    /// xi coordinate per 2D point.
    pub xi: Vec<f64>,
    /// eta coordinate per 2D point.
    pub eta: Vec<f64>,
    /// Weight per 2D point.
    pub w: Vec<f64>,
}

/// The `n1d x n1d` tensor-product rule on the reference square.
pub fn tensor_rule_2d(n1d: usize, kind: QuadKind) -> TensorRule2d {
    let (x, w1) = rule_1d(n1d, kind);
    let nq = n1d * n1d;
    let mut xi = Vec::with_capacity(nq);
    let mut eta = Vec::with_capacity(nq);
    let mut w = Vec::with_capacity(nq);
    for i in 0..n1d {
        for j in 0..n1d {
            xi.push(x[i]);
            eta.push(x[j]);
            w.push(w1[i] * w1[j]);
        }
    }
    TensorRule2d { xi, eta, w }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poly_val(c: &[f64], x: f64) -> f64 {
        c.iter().rev().fold(0.0, |acc, &ci| acc * x + ci)
    }

    fn poly_integral(c: &[f64]) -> f64 {
        c.iter()
            .enumerate()
            .map(|(i, &ci)| {
                ci * (1.0 - (-1.0f64).powi(i as i32 + 1)) / (i as f64 + 1.0)
            })
            .sum()
    }

    #[test]
    fn gl_weights_sum_two() {
        for n in 1..16 {
            let (_, w) = gauss_legendre(n);
            assert!((w.iter().sum::<f64>() - 2.0).abs() < 1e-13, "n={n}");
        }
    }

    #[test]
    fn gl_exactness() {
        let mut rng = crate::util::rng::Rng::new(1);
        for n in 1..12 {
            let (x, w) = gauss_legendre(n);
            let c: Vec<f64> =
                (0..2 * n).map(|_| rng.normal()).collect();
            let got: f64 = x
                .iter()
                .zip(&w)
                .map(|(&xi, &wi)| wi * poly_val(&c, xi))
                .sum();
            assert!((got - poly_integral(&c)).abs() < 1e-10, "n={n}");
        }
    }

    #[test]
    fn gl_known_3point() {
        let (x, w) = gauss_legendre(3);
        let s = (0.6f64).sqrt();
        assert!((x[0] + s).abs() < 1e-14);
        assert!(x[1].abs() < 1e-14);
        assert!((x[2] - s).abs() < 1e-14);
        assert!((w[0] - 5.0 / 9.0).abs() < 1e-14);
        assert!((w[1] - 8.0 / 9.0).abs() < 1e-14);
    }

    #[test]
    fn lobatto_endpoints_and_exactness() {
        let mut rng = crate::util::rng::Rng::new(2);
        for n in 2..12 {
            let (x, w) = gauss_lobatto(n);
            assert!((x[0] + 1.0).abs() < 1e-14);
            assert!((x[n - 1] - 1.0).abs() < 1e-14);
            assert!((w.iter().sum::<f64>() - 2.0).abs() < 1e-12);
            let c: Vec<f64> = (0..2 * n - 2).map(|_| rng.normal()).collect();
            let got: f64 = x
                .iter()
                .zip(&w)
                .map(|(&xi, &wi)| wi * poly_val(&c, xi))
                .sum();
            assert!((got - poly_integral(&c)).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn lobatto_known_5point() {
        let (x, w) = gauss_lobatto(5);
        let s = (3.0f64 / 7.0).sqrt();
        assert!((x[1] + s).abs() < 1e-13);
        assert!((w[0] - 0.1).abs() < 1e-13);
        assert!((w[2] - 32.0 / 45.0).abs() < 1e-13);
    }

    #[test]
    fn tensor_ordering_contract() {
        let (x, _) = gauss_legendre(3);
        let r = tensor_rule_2d(3, QuadKind::GaussLegendre);
        for i in 0..3 {
            for j in 0..3 {
                let q = i * 3 + j;
                assert!((r.xi[q] - x[i]).abs() < 1e-15);
                assert!((r.eta[q] - x[j]).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn tensor_integrates_monomials() {
        let r = tensor_rule_2d(5, QuadKind::GaussLegendre);
        for p in [0usize, 2, 4, 6] {
            for q in [0usize, 2, 4] {
                let got: f64 = (0..r.w.len())
                    .map(|k| {
                        r.w[k] * r.xi[k].powi(p as i32)
                            * r.eta[k].powi(q as i32)
                    })
                    .sum();
                let want = (2.0 / (p as f64 + 1.0)) * (2.0 / (q as f64 + 1.0));
                assert!((got - want).abs() < 1e-12, "x^{p} y^{q}");
            }
        }
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(QuadKind::parse("gl").unwrap(), QuadKind::GaussLegendre);
        assert_eq!(QuadKind::parse("lobatto").unwrap(),
                   QuadKind::GaussLobatto);
        assert!(QuadKind::parse("mc").is_err());
    }
}
