//! The mapped-FEM substrate: Jacobi/Legendre test bases, Gauss
//! quadrature, bilinear reference->actual transforms and the FastVPINNs
//! premultiplier tensor assembly (the paper's SS4.1-4.4 data layout).

pub mod assembly;
pub mod bilinear;
pub mod jacobi;
pub mod quadrature;
