//! Bilinear reference->actual element transformation (paper Appendix A.1).
//!
//! Mirrors python `fem_py.transforms.BilinearMap` — the Jacobian is
//! evaluated *pointwise*, which is what makes skewed quadrilaterals work
//! in FastVPINNs where the original hp-VPINNs assumed it constant.

/// Bilinear map for one quadrilateral (vertices CCW, matching reference
/// corners (-1,-1), (1,-1), (1,1), (-1,1)).
#[derive(Debug, Clone, Copy)]
pub struct BilinearMap {
    xc: [f64; 4],
    yc: [f64; 4],
}

/// Pointwise Jacobian: j11 = dx/dxi, j12 = dx/deta, j21 = dy/dxi,
/// j22 = dy/deta, det = j11*j22 - j12*j21.
#[derive(Debug, Clone, Copy)]
pub struct Jacobian {
    /// dx/dxi.
    pub j11: f64,
    /// dx/deta.
    pub j12: f64,
    /// dy/dxi.
    pub j21: f64,
    /// dy/deta.
    pub j22: f64,
    /// j11*j22 - j12*j21.
    pub det: f64,
}

impl BilinearMap {
    /// Map for one quad cell from its vertices in mesh order.
    pub fn new(verts: &[[f64; 2]; 4]) -> Self {
        let [p0, p1, p2, p3] = *verts;
        let (x0, x1, x2, x3) = (p0[0], p1[0], p2[0], p3[0]);
        let (y0, y1, y2, y3) = (p0[1], p1[1], p2[1], p3[1]);
        BilinearMap {
            xc: [
                (x0 + x1 + x2 + x3) / 4.0,
                (-x0 + x1 + x2 - x3) / 4.0,
                (-x0 - x1 + x2 + x3) / 4.0,
                (x0 - x1 + x2 - x3) / 4.0,
            ],
            yc: [
                (y0 + y1 + y2 + y3) / 4.0,
                (-y0 + y1 + y2 - y3) / 4.0,
                (-y0 - y1 + y2 + y3) / 4.0,
                (y0 - y1 + y2 - y3) / 4.0,
            ],
        }
    }

    /// Reference (xi, eta) -> actual (x, y).
    pub fn map(&self, xi: f64, eta: f64) -> [f64; 2] {
        [
            self.xc[0] + self.xc[1] * xi + self.xc[2] * eta
                + self.xc[3] * xi * eta,
            self.yc[0] + self.yc[1] * xi + self.yc[2] * eta
                + self.yc[3] * xi * eta,
        ]
    }

    /// The Jacobian of the map at reference point (xi, eta).
    pub fn jacobian(&self, xi: f64, eta: f64) -> Jacobian {
        let j11 = self.xc[1] + self.xc[3] * eta;
        let j12 = self.xc[2] + self.xc[3] * xi;
        let j21 = self.yc[1] + self.yc[3] * eta;
        let j22 = self.yc[2] + self.yc[3] * xi;
        Jacobian { j11, j12, j21, j22, det: j11 * j22 - j12 * j21 }
    }

    /// Transform reference gradients (d/dxi, d/deta) to actual (d/dx, d/dy):
    ///
    /// [du/dx]   1  [ j22  -j21] [du/dxi ]
    /// [du/dy] = -  [-j12   j11] [du/deta]
    ///           D
    pub fn grad_to_actual(&self, dxi: f64, deta: f64, xi: f64, eta: f64)
        -> [f64; 2] {
        let j = self.jacobian(xi, eta);
        [
            (j.j22 * dxi - j.j21 * deta) / j.det,
            (-j.j12 * dxi + j.j11 * deta) / j.det,
        ]
    }

    /// Actual (x, y) -> reference (xi, eta) via Newton; returns None if
    /// it fails to converge (point far outside the element).
    pub fn inverse_map(&self, x: f64, y: f64) -> Option<[f64; 2]> {
        let (mut xi, mut eta) = (0.0f64, 0.0f64);
        for _ in 0..60 {
            let p = self.map(xi, eta);
            let (rx, ry) = (p[0] - x, p[1] - y);
            let j = self.jacobian(xi, eta);
            if j.det.abs() < 1e-300 {
                return None;
            }
            let dxi = (j.j22 * rx - j.j12 * ry) / j.det;
            let deta = (-j.j21 * rx + j.j11 * ry) / j.det;
            xi -= dxi;
            eta -= deta;
            if dxi.abs() < 1e-13 && deta.abs() < 1e-13 {
                return Some([xi, eta]);
            }
            if !xi.is_finite() || !eta.is_finite() || xi.abs() > 1e3
                || eta.abs() > 1e3 {
                return None;
            }
        }
        Some([xi, eta])
    }

    /// True if (x, y) lies inside this element (with tolerance).
    pub fn contains(&self, x: f64, y: f64, tol: f64) -> bool {
        match self.inverse_map(x, y) {
            Some([xi, eta]) => {
                xi.abs() <= 1.0 + tol && eta.abs() <= 1.0 + tol
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check_result, geom};

    const SKEWED: [[f64; 2]; 4] =
        [[0.0, 0.0], [2.0, 0.3], [1.7, 1.9], [-0.2, 1.2]];

    #[test]
    fn maps_corners() {
        let bm = BilinearMap::new(&SKEWED);
        let refc = [[-1.0, -1.0], [1.0, -1.0], [1.0, 1.0], [-1.0, 1.0]];
        for (r, v) in refc.iter().zip(SKEWED.iter()) {
            let p = bm.map(r[0], r[1]);
            assert!((p[0] - v[0]).abs() < 1e-14);
            assert!((p[1] - v[1]).abs() < 1e-14);
        }
    }

    #[test]
    fn affine_constant_jacobian() {
        let unit = [[0.0, 0.0], [1.0, 0.0], [1.0, 1.0], [0.0, 1.0]];
        let bm = BilinearMap::new(&unit);
        for (xi, eta) in [(0.0, 0.0), (0.7, -0.3), (-0.9, 0.9)] {
            assert!((bm.jacobian(xi, eta).det - 0.25).abs() < 1e-15);
        }
    }

    #[test]
    fn skewed_jacobian_varies() {
        let bm = BilinearMap::new(&SKEWED);
        let d1 = bm.jacobian(-0.9, -0.9).det;
        let d2 = bm.jacobian(0.9, 0.9).det;
        assert!((d1 - d2).abs() > 1e-3);
    }

    #[test]
    fn jacobian_finite_difference() {
        let bm = BilinearMap::new(&SKEWED);
        let (xi, eta, h) = (0.37, -0.21, 1e-7);
        let j = bm.jacobian(xi, eta);
        let px = bm.map(xi + h, eta);
        let mx = bm.map(xi - h, eta);
        assert!((j.j11 - (px[0] - mx[0]) / (2.0 * h)).abs() < 1e-6);
        assert!((j.j21 - (px[1] - mx[1]) / (2.0 * h)).abs() < 1e-6);
        let pe = bm.map(xi, eta + h);
        let me = bm.map(xi, eta - h);
        assert!((j.j12 - (pe[0] - me[0]) / (2.0 * h)).abs() < 1e-6);
        assert!((j.j22 - (pe[1] - me[1]) / (2.0 * h)).abs() < 1e-6);
    }

    #[test]
    fn grad_chain_rule_on_known_function() {
        // u(x,y) = x^2 + 3xy -> du/dx = 2x+3y, du/dy = 3x
        let bm = BilinearMap::new(&SKEWED);
        let h = 1e-7;
        for (xi, eta) in [(0.2, -0.5), (-0.8, 0.3), (0.0, 0.0)] {
            let u = |a: f64, b: f64| {
                let p = bm.map(a, b);
                p[0] * p[0] + 3.0 * p[0] * p[1]
            };
            let dxi = (u(xi + h, eta) - u(xi - h, eta)) / (2.0 * h);
            let deta = (u(xi, eta + h) - u(xi, eta - h)) / (2.0 * h);
            let g = bm.grad_to_actual(dxi, deta, xi, eta);
            let p = bm.map(xi, eta);
            assert!((g[0] - (2.0 * p[0] + 3.0 * p[1])).abs() < 1e-5);
            assert!((g[1] - 3.0 * p[0]).abs() < 1e-5);
        }
    }

    #[test]
    fn property_positive_det_on_random_convex_quads() {
        // det(J) is bilinear in (xi, eta), so its minimum over the
        // reference square sits at a corner: positive at the four
        // corners (<=> strict convexity, CCW) implies positive
        // everywhere — checked here on corners plus random interiors.
        check_result(11, 300, |r| {
            let q = geom::convex_quad(r, 0.25);
            let xi = r.uniform_in(-1.0, 1.0);
            let eta = r.uniform_in(-1.0, 1.0);
            (q, xi, eta)
        }, |&(q, xi, eta)| {
            let bm = BilinearMap::new(&q);
            for (cx, cy) in
                [(-1.0, -1.0), (1.0, -1.0), (1.0, 1.0), (-1.0, 1.0)]
            {
                let d = bm.jacobian(cx, cy).det;
                if d <= 0.0 {
                    return Err(format!("corner det {d} <= 0"));
                }
            }
            let d = bm.jacobian(xi, eta).det;
            if d <= 0.0 {
                return Err(format!("interior det {d} <= 0 at \
                                    ({xi},{eta})"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_affine_maps_have_constant_jacobian() {
        // parallelograms are the affine bilinear maps: J must not vary
        // with (xi, eta) and det * 4 must equal the shoelace area
        check_result(12, 300, |r| {
            let q = geom::parallelogram(r);
            let xi = r.uniform_in(-1.0, 1.0);
            let eta = r.uniform_in(-1.0, 1.0);
            (q, xi, eta)
        }, |&(q, xi, eta)| {
            let bm = BilinearMap::new(&q);
            let j0 = bm.jacobian(0.0, 0.0);
            let j = bm.jacobian(xi, eta);
            let tol = 1e-13 * (1.0 + j0.det.abs());
            for (a, b) in [(j.j11, j0.j11), (j.j12, j0.j12),
                           (j.j21, j0.j21), (j.j22, j0.j22),
                           (j.det, j0.det)]
            {
                if (a - b).abs() > tol {
                    return Err(format!("J varies on an affine map: \
                                        {a} vs {b}"));
                }
            }
            let area: f64 = (0..4)
                .map(|i| {
                    let p = q[i];
                    let n = q[(i + 1) % 4];
                    p[0] * n[1] - n[0] * p[1]
                })
                .sum::<f64>()
                / 2.0;
            if (4.0 * j0.det - area).abs() > 1e-12 * (1.0 + area) {
                return Err(format!("4 det = {} vs area {area}",
                                   4.0 * j0.det));
            }
            Ok(())
        });
    }

    #[test]
    fn property_inverse_roundtrip_random_convex_quads() {
        check_result(
            42,
            200,
            |r| {
                let verts = geom::convex_quad(r, 0.25);
                let xi = r.uniform_in(-0.95, 0.95);
                let eta = r.uniform_in(-0.95, 0.95);
                (verts, xi, eta)
            },
            |&(verts, xi, eta)| {
                let bm = BilinearMap::new(&verts);
                let p = bm.map(xi, eta);
                match bm.inverse_map(p[0], p[1]) {
                    Some([xi2, eta2]) => {
                        if (xi2 - xi).abs() < 1e-9 && (eta2 - eta).abs() < 1e-9
                        {
                            Ok(())
                        } else {
                            Err(format!("roundtrip ({xi},{eta}) -> \
                                         ({xi2},{eta2})"))
                        }
                    }
                    None => Err("inverse_map diverged".into()),
                }
            },
        );
    }

    #[test]
    fn contains_logic() {
        let bm = BilinearMap::new(&SKEWED);
        let inside = bm.map(0.1, -0.4);
        assert!(bm.contains(inside[0], inside[1], 1e-9));
        assert!(!bm.contains(10.0, 10.0, 1e-9));
    }
}
