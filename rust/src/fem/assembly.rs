//! FastVPINNs premultiplier tensor assembly (paper SS4.2-4.4) — the Rust
//! runtime twin of python fem_py.assembly (cross-validated via
//! `repro dump-tensors` + pytest).
//!
//! For every element e, test function j, quadrature point q:
//!
//! ```text
//! G_x[e,j,q] = w_q * |J_e(q)| * dv_j/dx (x_{e,q})
//! G_y[e,j,q] = w_q * |J_e(q)| * dv_j/dy (x_{e,q})
//! V  [e,j,q] = w_q * |J_e(q)| *  v_j    (xi_q, eta_q)
//! F  [e,j]   = sum_q V[e,j,q] * f(x_{e,q})
//! ```
//!
//! The assembly is embarrassingly parallel over elements and runs on all
//! cores: each scoped thread owns one contiguous, evenly-split chunk of
//! elements (lock-free — no work-stealing counter, no per-element
//! mutexes; rayon is unavailable offline). Output is bit-reproducible
//! regardless of thread count because every element writes only its own
//! slice.

use crate::fem::bilinear::BilinearMap;
use crate::fem::jacobi;
use crate::fem::quadrature::{self, QuadKind};
use crate::linalg::gemv;
use crate::mesh::QuadMesh;

/// Everything a FastVPINNs train step needs, in f64 (cast to f32 at the
/// runtime boundary).
#[derive(Debug, Clone)]
pub struct AssembledDomain {
    /// Element count.
    pub ne: usize,
    /// Test functions per element (`nt1d`^2).
    pub nt: usize,
    /// Quadrature points per element (`nq1d`^2).
    pub nq: usize,
    /// 1D test-function order.
    pub nt1d: usize,
    /// 1D quadrature order.
    pub nq1d: usize,
    /// (ne*nq, 2) row-major, element-major point order.
    pub quad_xy: Vec<f64>,
    /// (ne, nt, nq) row-major: `w |J| dv_j/dx`.
    pub gx: Vec<f64>,
    /// (ne, nt, nq) row-major: `w |J| dv_j/dy`.
    pub gy: Vec<f64>,
    /// (ne, nt, nq) row-major: `w |J| v_j`.
    pub v: Vec<f64>,
    /// (ne, nq) |J| at each quadrature point.
    pub jdet: Vec<f64>,
    /// Reference-rule xi coordinates (length nq).
    pub xi: Vec<f64>,
    /// Reference-rule eta coordinates (length nq).
    pub eta: Vec<f64>,
    /// Reference-rule weights (length nq).
    pub w: Vec<f64>,
}

impl AssembledDomain {
    /// F[e,j] = sum_q V[e,j,q] * f(x_q, y_q).
    pub fn force_matrix(&self, f: impl Fn(f64, f64) -> f64)
        -> Vec<f64> {
        let (ne, nt, nq) = (self.ne, self.nt, self.nq);
        // f at all quadrature points, element-major
        let fq: Vec<f64> = (0..ne * nq)
            .map(|i| f(self.quad_xy[2 * i], self.quad_xy[2 * i + 1]))
            .collect();
        let mut out = vec![0.0; ne * nt];
        // per element, F[e,:] = V[e] @ f[e] is a blocked (nt x nq)
        // matrix-vector product against the premultiplier slab
        for e in 0..ne {
            gemv(nt, nq, 1.0, &self.v[e * nt * nq..(e + 1) * nt * nq],
                 false, &fq[e * nq..(e + 1) * nq], 0.0,
                 &mut out[e * nt..(e + 1) * nt]);
        }
        out
    }

    /// Sample a pointwise coefficient field at every quadrature point:
    /// `(ne * nq)` element-major — the hoisted table a
    /// [`VariationalForm`](crate::runtime::backend::VariationalForm)
    /// threads through the residual contraction. Evaluated once per
    /// backend construction, never on the step hot path.
    pub fn coeff_table(&self, f: impl Fn(f64, f64) -> f64) -> Vec<f64> {
        (0..self.ne * self.nq)
            .map(|i| f(self.quad_xy[2 * i], self.quad_xy[2 * i + 1]))
            .collect()
    }

    /// Total integration measure sum_{e,q} w_q |J| (= mesh area).
    pub fn total_measure(&self) -> f64 {
        let mut acc = 0.0;
        for e in 0..self.ne {
            for q in 0..self.nq {
                acc += self.w[q] * self.jdet[e * self.nq + q];
            }
        }
        acc
    }

    /// Quadrature coordinates of element e (x then y per point).
    pub fn elem_quad_xy(&self, e: usize) -> &[f64] {
        &self.quad_xy[2 * e * self.nq..2 * (e + 1) * self.nq]
    }

    /// f32 copies for the runtime boundary.
    pub fn quad_xy_f32(&self) -> Vec<f32> {
        self.quad_xy.iter().map(|&v| v as f32).collect()
    }

    /// f32 copy of `gx` for the runtime boundary.
    pub fn gx_f32(&self) -> Vec<f32> {
        self.gx.iter().map(|&v| v as f32).collect()
    }

    /// f32 copy of `gy` for the runtime boundary.
    pub fn gy_f32(&self) -> Vec<f32> {
        self.gy.iter().map(|&v| v as f32).collect()
    }

    /// f32 copy of `v` for the runtime boundary.
    pub fn v_f32(&self) -> Vec<f32> {
        self.v.iter().map(|&v| v as f32).collect()
    }
}

/// Assemble the premultiplier tensors for every element of `mesh`.
pub fn assemble(mesh: &QuadMesh, nt1d: usize, nq1d: usize, kind: QuadKind)
    -> AssembledDomain {
    let ne = mesh.n_cells();
    let nt = nt1d * nt1d;
    let nq = nq1d * nq1d;
    let rule = quadrature::tensor_rule_2d(nq1d, kind);
    // reference test values/gradients: (nt, nq) row-major, shared
    let (v_ref, dxi_ref, deta_ref) =
        jacobi::test_fn_2d(nt1d, &rule.xi, &rule.eta);

    let mut quad_xy = vec![0.0; ne * nq * 2];
    let mut gx = vec![0.0; ne * nt * nq];
    let mut gy = vec![0.0; ne * nt * nq];
    let mut v = vec![0.0; ne * nt * nq];
    let mut jdet = vec![0.0; ne * nq];

    let n_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(ne.max(1));
    // Even contiguous split: thread t owns elements [t*per, (t+1)*per).
    // Each thread gets disjoint &mut slices of the output buffers, so no
    // synchronization at all is needed.
    let per = if ne == 0 { 1 } else { ne.div_ceil(n_threads) };
    {
        let (xi, eta, w) = (&rule.xi, &rule.eta, &rule.w);
        let (v_ref, dxi_ref, deta_ref) = (&v_ref, &dxi_ref, &deta_ref);
        std::thread::scope(|s| {
            let chunks = quad_xy
                .chunks_mut(per * nq * 2)
                .zip(gx.chunks_mut(per * nt * nq))
                .zip(gy.chunks_mut(per * nt * nq))
                .zip(v.chunks_mut(per * nt * nq))
                .zip(jdet.chunks_mut(per * nq))
                .enumerate();
            for (t, ((((qc, gxc), gyc), vc), jc)) in chunks {
                let e0 = t * per;
                s.spawn(move || {
                    let elems = qc
                        .chunks_mut(nq * 2)
                        .zip(gxc.chunks_mut(nt * nq))
                        .zip(gyc.chunks_mut(nt * nq))
                        .zip(vc.chunks_mut(nt * nq))
                        .zip(jc.chunks_mut(nq))
                        .enumerate();
                    for (k, ((((q, gx), gy), v), jd)) in elems {
                        assemble_element(
                            mesh, e0 + k, nt, nq, xi, eta, w, v_ref,
                            dxi_ref, deta_ref,
                            ElemOut { quad: q, gx, gy, v, jd },
                        );
                    }
                });
            }
        });
    }

    AssembledDomain {
        ne, nt, nq, nt1d, nq1d,
        quad_xy, gx, gy, v, jdet,
        xi: rule.xi, eta: rule.eta, w: rule.w,
    }
}

struct ElemOut<'a> {
    quad: &'a mut [f64],
    gx: &'a mut [f64],
    gy: &'a mut [f64],
    v: &'a mut [f64],
    jd: &'a mut [f64],
}

#[allow(clippy::too_many_arguments)]
fn assemble_element(
    mesh: &QuadMesh, e: usize, nt: usize, nq: usize, xi: &[f64],
    eta: &[f64], w: &[f64], v_ref: &[f64], dxi_ref: &[f64],
    deta_ref: &[f64], out: ElemOut<'_>,
) {
    let bm = BilinearMap::new(&mesh.cell_vertices(e));
    // per-point jacobian data
    let mut inv = vec![0.0; nq * 4]; // j22/det, -j21/det, -j12/det, j11/det
    for q in 0..nq {
        let p = bm.map(xi[q], eta[q]);
        out.quad[2 * q] = p[0];
        out.quad[2 * q + 1] = p[1];
        let j = bm.jacobian(xi[q], eta[q]);
        let adet = j.det.abs();
        out.jd[q] = adet;
        inv[4 * q] = j.j22 / j.det;
        inv[4 * q + 1] = -j.j21 / j.det;
        inv[4 * q + 2] = -j.j12 / j.det;
        inv[4 * q + 3] = j.j11 / j.det;
    }
    for j in 0..nt {
        let row = j * nq;
        for q in 0..nq {
            let wj = w[q] * out.jd[q];
            let dxi = dxi_ref[row + q];
            let deta = deta_ref[row + q];
            // dv/dx = ( j22*dxi - j21*deta)/det etc.
            let dvx = inv[4 * q] * dxi + inv[4 * q + 1] * deta;
            let dvy = inv[4 * q + 2] * dxi + inv[4 * q + 3] * deta;
            out.gx[row + q] = wj * dvx;
            out.gy[row + q] = wj * dvy;
            out.v[row + q] = wj * v_ref[row + q];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generators;

    fn sinsin_grad(om: f64, x: f64, y: f64) -> (f64, f64) {
        (om * (om * x).cos() * (om * y).sin(),
         om * (om * x).sin() * (om * y).cos())
    }

    #[test]
    fn shapes() {
        let m = generators::unit_square(3);
        let d = assemble(&m, 4, 6, QuadKind::GaussLegendre);
        assert_eq!(d.gx.len(), 9 * 16 * 36);
        assert_eq!(d.quad_xy.len(), 9 * 36 * 2);
        assert_eq!(d.jdet.len(), 9 * 36);
    }

    #[test]
    fn total_measure_is_area() {
        let m = generators::skewed_square(4, 0.3);
        let d = assemble(&m, 2, 8, QuadKind::GaussLegendre);
        assert!((d.total_measure() - 1.0).abs() < 1e-10);
        let g = generators::disk(8, 6, 0.0, 0.0, 1.0);
        let dg = assemble(&g, 2, 6, QuadKind::GaussLegendre);
        assert!((dg.total_measure() - g.area()).abs() < 1e-9);
    }

    #[test]
    fn residual_of_exact_solution_vanishes() {
        // int (grad u . grad v - f v) -> 0 for u exact, v vanishing on
        // element boundaries (integration by parts) — the key Galerkin
        // identity the whole method rests on.
        let om = 2.0 * std::f64::consts::PI;
        let m = generators::unit_square(2);
        let d = assemble(&m, 4, 30, QuadKind::GaussLegendre);
        let f = d.force_matrix(|x, y| {
            2.0 * om * om * (om * x).sin() * (om * y).sin()
        });
        let mut max_res: f64 = 0.0;
        for e in 0..d.ne {
            for j in 0..d.nt {
                let base = (e * d.nt + j) * d.nq;
                let mut acc = 0.0;
                for q in 0..d.nq {
                    let x = d.quad_xy[2 * (e * d.nq + q)];
                    let y = d.quad_xy[2 * (e * d.nq + q) + 1];
                    let (ux, uy) = sinsin_grad(om, x, y);
                    acc += d.gx[base + q] * ux + d.gy[base + q] * uy;
                }
                max_res = max_res.max((acc - f[e * d.nt + j]).abs());
            }
        }
        assert!(max_res < 1e-8, "max residual {max_res}");
    }

    #[test]
    fn residual_vanishes_on_skewed_mesh() {
        let om = std::f64::consts::PI;
        let m = generators::skewed_square(3, 0.25);
        let d = assemble(&m, 3, 40, QuadKind::GaussLegendre);
        let f = d.force_matrix(|x, y| {
            2.0 * om * om * (om * x).sin() * (om * y).sin()
        });
        let mut max_res: f64 = 0.0;
        for e in 0..d.ne {
            for j in 0..d.nt {
                let base = (e * d.nt + j) * d.nq;
                let mut acc = 0.0;
                for q in 0..d.nq {
                    let x = d.quad_xy[2 * (e * d.nq + q)];
                    let y = d.quad_xy[2 * (e * d.nq + q) + 1];
                    let (ux, uy) = sinsin_grad(om, x, y);
                    acc += d.gx[base + q] * ux + d.gy[base + q] * uy;
                }
                max_res = max_res.max((acc - f[e * d.nt + j]).abs());
            }
        }
        assert!(max_res < 1e-6, "max residual {max_res}");
    }

    #[test]
    fn residual_with_reaction_and_convection_vanishes() {
        // the generalized Galerkin identity for
        // -eps lap u + b . grad u + c u = f:
        // int (eps grad u . grad v + (b . grad u + c u) v - f v) -> 0
        // for exact u and v vanishing on element boundaries — the
        // identity the Helmholtz / variable-convection scenarios rest
        // on, evaluated straight from the Gx/Gy/V premultipliers.
        let om = std::f64::consts::PI;
        let k2 = 6.25; // Helmholtz-style reaction c = -k^2
        let (eps, bx, by) = (0.7, 0.4, -0.3);
        let u = move |x: f64, y: f64| (om * x).sin() * (om * y).sin();
        let m = generators::skewed_square(2, 0.2);
        let d = assemble(&m, 3, 30, QuadKind::GaussLegendre);
        let f = d.force_matrix(|x, y| {
            let lap = -2.0 * om * om * u(x, y);
            let (ux, uy) = sinsin_grad(om, x, y);
            -eps * lap + bx * ux + by * uy - k2 * u(x, y)
        });
        let ctab = d.coeff_table(|_, _| -k2);
        let mut max_res: f64 = 0.0;
        for e in 0..d.ne {
            for j in 0..d.nt {
                let base = (e * d.nt + j) * d.nq;
                let mut acc = 0.0;
                for q in 0..d.nq {
                    let gp = e * d.nq + q;
                    let x = d.quad_xy[2 * gp];
                    let y = d.quad_xy[2 * gp + 1];
                    let (ux, uy) = sinsin_grad(om, x, y);
                    acc += eps * (d.gx[base + q] * ux
                        + d.gy[base + q] * uy)
                        + d.v[base + q]
                            * (bx * ux + by * uy + ctab[gp] * u(x, y));
                }
                max_res = max_res.max((acc - f[e * d.nt + j]).abs());
            }
        }
        assert!(max_res < 1e-6, "max residual {max_res}");
    }

    #[test]
    fn coeff_table_samples_quadrature_points() {
        let m = generators::unit_square(2);
        let d = assemble(&m, 2, 4, QuadKind::GaussLegendre);
        let t = d.coeff_table(|x, y| 2.0 * x - y);
        assert_eq!(t.len(), d.ne * d.nq);
        for i in 0..t.len() {
            let want = 2.0 * d.quad_xy[2 * i] - d.quad_xy[2 * i + 1];
            assert_eq!(t[i], want);
        }
    }

    #[test]
    fn force_matrix_linear() {
        let m = generators::unit_square(2);
        let d = assemble(&m, 3, 8, QuadKind::GaussLegendre);
        let f1 = d.force_matrix(|x, _| x);
        let f2 = d.force_matrix(|x, _| 2.0 * x);
        for (a, b) in f1.iter().zip(&f2) {
            assert!((2.0 * a - b).abs() < 1e-14);
        }
    }

    #[test]
    fn lobatto_vs_legendre_agree() {
        let m = generators::unit_square(2);
        let d1 = assemble(&m, 3, 12, QuadKind::GaussLegendre);
        let d2 = assemble(&m, 3, 12, QuadKind::GaussLobatto);
        let f1 = d1.force_matrix(|x, y| (x).sin() * y);
        let f2 = d2.force_matrix(|x, y| (x).sin() * y);
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // the work-stealing parallel assembly must be bit-reproducible
        let m = generators::skewed_square(5, 0.2);
        let d1 = assemble(&m, 3, 5, QuadKind::GaussLegendre);
        let d2 = assemble(&m, 3, 5, QuadKind::GaussLegendre);
        assert_eq!(d1.gx, d2.gx);
        assert_eq!(d1.quad_xy, d2.quad_xy);
    }
}
