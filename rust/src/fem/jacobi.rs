//! Legendre/Jacobi polynomials and the hp-VPINNs test basis
//! `t_j(x) = P_{j+1}(x) - P_{j-1}(x)` (mirrors python fem_py.jacobi /
//! fem_py.basis; same recurrences, f64 throughout).

/// P_n(x) by the Bonnet recurrence.
pub fn legendre(n: usize, x: f64) -> f64 {
    match n {
        0 => 1.0,
        1 => x,
        _ => {
            let (mut p0, mut p1) = (1.0, x);
            for k in 1..n {
                let k_ = k as f64;
                let p2 = ((2.0 * k_ + 1.0) * x * p1 - k_ * p0) / (k_ + 1.0);
                p0 = p1;
                p1 = p2;
            }
            p1
        }
    }
}

/// P'_n(x) via the derivative recurrence (stable at x = +-1).
pub fn legendre_deriv(n: usize, x: f64) -> f64 {
    match n {
        0 => 0.0,
        1 => 1.0,
        _ => {
            let (mut p0, mut p1) = (1.0, x);
            let (mut d0, mut d1) = (0.0, 1.0);
            for k in 1..n {
                let k_ = k as f64;
                let p2 = ((2.0 * k_ + 1.0) * x * p1 - k_ * p0) / (k_ + 1.0);
                let d2 = (2.0 * k_ + 1.0) * p1 + d0;
                p0 = p1;
                p1 = p2;
                d0 = d1;
                d1 = d2;
            }
            d1
        }
    }
}

/// Values [P_0..P_n] at x.
pub fn legendre_all(n: usize, x: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(n + 1);
    out.push(1.0);
    if n >= 1 {
        out.push(x);
    }
    for k in 1..n {
        let k_ = k as f64;
        let next = ((2.0 * k_ + 1.0) * x * out[k] - k_ * out[k - 1])
            / (k_ + 1.0);
        out.push(next);
    }
    out
}

/// Derivatives [P'_0..P'_n] at x.
pub fn legendre_deriv_all(n: usize, x: f64) -> Vec<f64> {
    let p = legendre_all(n, x);
    let mut d = vec![0.0; n + 1];
    if n >= 1 {
        d[1] = 1.0;
    }
    for k in 1..n {
        d[k + 1] = (2.0 * k as f64 + 1.0) * p[k] + d[k - 1];
    }
    d
}

/// General Jacobi polynomial P_n^{(a,b)}(x).
pub fn jacobi(n: usize, a: f64, b: f64, x: f64) -> f64 {
    if n == 0 {
        return 1.0;
    }
    let mut p0 = 1.0;
    let mut p1 = 0.5 * (a - b + (a + b + 2.0) * x);
    for k in 1..n {
        let k_ = k as f64;
        let c = 2.0 * k_ + a + b;
        let a1 = 2.0 * (k_ + 1.0) * (k_ + a + b + 1.0) * c;
        let a2 = (c + 1.0) * (a * a - b * b);
        let a3 = c * (c + 1.0) * (c + 2.0);
        let a4 = 2.0 * (k_ + a) * (k_ + b) * (c + 2.0);
        let p2 = ((a2 + a3 * x) * p1 - a4 * p0) / a1;
        p0 = p1;
        p1 = p2;
    }
    p1
}

/// d/dx P_n^{(a,b)} = (n+a+b+1)/2 * P_{n-1}^{(a+1,b+1)}.
pub fn jacobi_deriv(n: usize, a: f64, b: f64, x: f64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    0.5 * (n as f64 + a + b + 1.0) * jacobi(n - 1, a + 1.0, b + 1.0, x)
}

/// 1D test-basis values t_1..t_n1d at each of the given points.
/// Returns row-major (n1d, xs.len()).
pub fn test_fn_1d(n1d: usize, xs: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; n1d * xs.len()];
    for (qi, &x) in xs.iter().enumerate() {
        let p = legendre_all(n1d + 1, x);
        for j in 1..=n1d {
            out[(j - 1) * xs.len() + qi] = p[j + 1] - p[j - 1];
        }
    }
    out
}

/// 1D test-basis derivatives t'_1..t'_n1d. Row-major (n1d, xs.len()).
pub fn test_grad_1d(n1d: usize, xs: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; n1d * xs.len()];
    for (qi, &x) in xs.iter().enumerate() {
        let d = legendre_deriv_all(n1d + 1, x);
        for j in 1..=n1d {
            out[(j - 1) * xs.len() + qi] = d[j + 1] - d[j - 1];
        }
    }
    out
}

/// 2D tensor-product test basis at reference points (xi_q, eta_q):
/// returns (v, dxi, deta), each row-major (n1d*n1d, nq), flattening
/// J = a*n1d + b — the contract shared with fem_py.basis.test_fn_2d.
pub fn test_fn_2d(n1d: usize, xi: &[f64], eta: &[f64])
    -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    assert_eq!(xi.len(), eta.len());
    let nq = xi.len();
    let txi = test_fn_1d(n1d, xi);
    let teta = test_fn_1d(n1d, eta);
    let dtxi = test_grad_1d(n1d, xi);
    let dteta = test_grad_1d(n1d, eta);
    let nt = n1d * n1d;
    let mut v = vec![0.0; nt * nq];
    let mut dxi = vec![0.0; nt * nq];
    let mut deta = vec![0.0; nt * nq];
    for a in 0..n1d {
        for b in 0..n1d {
            let j = a * n1d + b;
            for q in 0..nq {
                v[j * nq + q] = txi[a * nq + q] * teta[b * nq + q];
                dxi[j * nq + q] = dtxi[a * nq + q] * teta[b * nq + q];
                deta[j * nq + q] = txi[a * nq + q] * dteta[b * nq + q];
            }
        }
    }
    (v, dxi, deta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_forms() {
        for &x in &[-1.0, -0.3, 0.0, 0.7, 1.0] {
            assert!((legendre(2, x) - 0.5 * (3.0 * x * x - 1.0)).abs()
                < 1e-14);
            assert!((legendre(3, x) - 0.5 * (5.0 * x * x * x - 3.0 * x))
                .abs() < 1e-14);
        }
    }

    #[test]
    fn endpoint_values() {
        for n in 0..12 {
            assert!((legendre(n, 1.0) - 1.0).abs() < 1e-13);
            let sign = if n % 2 == 0 { 1.0 } else { -1.0 };
            assert!((legendre(n, -1.0) - sign).abs() < 1e-13);
        }
    }

    #[test]
    fn deriv_at_one() {
        for n in 1..12 {
            let expect = n as f64 * (n as f64 + 1.0) / 2.0;
            assert!((legendre_deriv(n, 1.0) - expect).abs() < 1e-10,
                    "n={n}");
        }
    }

    #[test]
    fn deriv_finite_difference() {
        let h = 1e-7;
        for n in 1..10 {
            for &x in &[-0.8, -0.1, 0.5, 0.93] {
                let fd = (legendre(n, x + h) - legendre(n, x - h))
                    / (2.0 * h);
                assert!((legendre_deriv(n, x) - fd).abs() < 1e-5,
                        "n={n} x={x}");
            }
        }
    }

    #[test]
    fn all_variants_match() {
        let x = 0.37;
        let p = legendre_all(9, x);
        let d = legendre_deriv_all(9, x);
        for n in 0..=9 {
            assert!((p[n] - legendre(n, x)).abs() < 1e-14);
            assert!((d[n] - legendre_deriv(n, x)).abs() < 1e-12);
        }
    }

    #[test]
    fn jacobi_reduces_to_legendre() {
        for n in 0..8 {
            for &x in &[-0.9, 0.0, 0.4, 1.0] {
                assert!((jacobi(n, 0.0, 0.0, x) - legendre(n, x)).abs()
                    < 1e-13);
            }
        }
    }

    #[test]
    fn jacobi_deriv_finite_difference() {
        let h = 1e-7;
        for n in 1..6 {
            let x = 0.3;
            let fd = (jacobi(n, 1.0, 1.0, x + h) - jacobi(n, 1.0, 1.0, x - h))
                / (2.0 * h);
            assert!((jacobi_deriv(n, 1.0, 1.0, x) - fd).abs() < 1e-5);
        }
    }

    #[test]
    fn test_basis_vanishes_at_endpoints() {
        let t = test_fn_1d(8, &[-1.0, 1.0]);
        for v in t {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn test_basis_definition() {
        let xs = [-0.6, 0.2, 0.9];
        let t = test_fn_1d(4, &xs);
        for j in 1..=4usize {
            for (qi, &x) in xs.iter().enumerate() {
                let expect = legendre(j + 1, x) - legendre(j - 1, x);
                assert!((t[(j - 1) * 3 + qi] - expect).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn test_grad_finite_difference() {
        let xs = [-0.5, 0.0, 0.77];
        let h = 1e-7;
        let g = test_grad_1d(5, &xs);
        let tp = test_fn_1d(5, &xs.map(|x| x + h));
        let tm = test_fn_1d(5, &xs.map(|x| x - h));
        for i in 0..g.len() {
            let fd = (tp[i] - tm[i]) / (2.0 * h);
            assert!((g[i] - fd).abs() < 1e-5);
        }
    }

    #[test]
    fn test_2d_tensor_structure() {
        let xi = [-0.3, 0.1, 0.8];
        let eta = [0.5, -0.7, 0.2];
        let (v, _, _) = test_fn_2d(3, &xi, &eta);
        let txi = test_fn_1d(3, &xi);
        let teta = test_fn_1d(3, &eta);
        for a in 0..3 {
            for b in 0..3 {
                for q in 0..3 {
                    let got = v[(a * 3 + b) * 3 + q];
                    let want = txi[a * 3 + q] * teta[b * 3 + q];
                    assert!((got - want).abs() < 1e-14);
                }
            }
        }
    }
}
