//! The L3 training coordinator: drives a runtime backend (native pure
//! Rust, or AOT/PJRT with `--features xla`) through an optimizer run,
//! applies LR schedules, tracks timing (median per epoch — the paper's
//! protocol), computes error norms and logs history. The in-process
//! coordinator plane lives here too: [`pool`] holds the persistent
//! fork-join worker pool and [`shard`] the tick state machine plus the
//! cost-aware, worker-count-independent shard plan the native backend
//! steps through.

pub mod history;
pub mod metrics;
pub mod pool;
pub mod schedule;
pub mod shard;
pub mod trainer;
