//! The L3 training coordinator: owns parameter/optimizer state as XLA
//! literals, drives the AOT train-step executable, applies LR schedules,
//! tracks timing (median per epoch — the paper's protocol), computes
//! error norms and logs history.

pub mod history;
pub mod metrics;
pub mod schedule;
pub mod trainer;
