//! The L3 training coordinator: drives a runtime backend (native pure
//! Rust, or AOT/PJRT with `--features xla`) through an optimizer run,
//! applies LR schedules, tracks timing (median per epoch — the paper's
//! protocol), computes error norms and logs history.

pub mod history;
pub mod metrics;
pub mod schedule;
pub mod trainer;
