//! The training coordinator: owns parameter + Adam state as XLA
//! literals, assembles the data inputs demanded by an artifact's
//! manifest, and drives the train-step executable.
//!
//! The hot loop is pure Rust + PJRT — python is not involved.

use std::rc::Rc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::history::{HistoryRow, TrainHistory};
use crate::coordinator::metrics::ErrorNorms;
use crate::coordinator::schedule::LrSchedule;
use crate::fem::assembly::AssembledDomain;
use crate::mesh::QuadMesh;
use crate::problems::Problem;
use crate::runtime::engine::{Artifact, Engine};
use crate::runtime::tensor::TensorData;
use crate::util::rng::Rng;
use crate::util::stats::StepTimer;

/// Training hyper-parameters (paper defaults where applicable).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub iters: usize,
    pub lr: LrSchedule,
    /// Dirichlet penalty (paper's tau).
    pub tau: f64,
    /// Sensor penalty for inverse problems (paper's gamma).
    pub gamma: f64,
    pub seed: u64,
    /// Record a history row every `log_every` steps (1 = all).
    pub log_every: usize,
    /// Initial guess for the trainable eps (inverse_const; paper: 2.0).
    pub eps_init: f64,
    /// Early stop when |eps - target| < tol (inverse_const).
    pub eps_converge: Option<(f64, f64)>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 2000,
            lr: LrSchedule::Constant(1e-3),
            tau: 10.0,
            gamma: 10.0,
            seed: 42,
            log_every: 1,
            eps_init: 2.0,
            eps_converge: None,
        }
    }
}

/// Where the trainer gets its mesh/problem data from.
pub struct DataSource<'a> {
    pub mesh: &'a QuadMesh,
    /// Assembled premultiplier tensors (not needed for PINN artifacts).
    pub domain: Option<&'a AssembledDomain>,
    pub problem: &'a dyn Problem,
    /// Sensor ground truth override (defaults to `problem.exact`).
    pub sensor_values: Option<&'a dyn Fn(f64, f64) -> f64>,
}

/// Summary returned by `Trainer::run`.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub final_loss: f64,
    pub final_var_loss: f64,
    pub final_bd_loss: f64,
    pub median_step_ms: f64,
    pub total_seconds: f64,
    /// Final trainable eps (inverse_const only).
    pub eps_final: Option<f64>,
    pub converged_early: bool,
}

pub struct Trainer<'a> {
    engine: &'a Engine,
    art: Rc<Artifact>,
    /// p/m/v literals in manifest order (3 * n_param_arrays).
    state: Vec<xla::Literal>,
    /// Data-segment inputs in manifest order (after step, lr),
    /// uploaded to the device ONCE — they are step-invariant, and at
    /// paper scale the premultiplier tensors are hundreds of MB.
    data: Vec<xla::PjRtBuffer>,
    /// Host sources of `data`. PJRT CPU uploads are asynchronous: the
    /// source literal MUST outlive the buffer's first use, so we pin
    /// them here (dropping them early is a use-after-free that
    /// manifests as a `literal.size_bytes() == b->size()` CHECK crash).
    _data_src: Vec<xla::Literal>,
    cfg: TrainConfig,
    pub history: TrainHistory,
    step: usize,
    n_params: usize,
}

impl<'a> Trainer<'a> {
    pub fn new(
        engine: &'a Engine,
        artifact: &str,
        src: &DataSource<'_>,
        cfg: &TrainConfig,
    ) -> Result<Trainer<'a>> {
        let art = engine.load(artifact)?;
        ensure!(art.manifest.kind == "train",
                "{artifact} is not a train artifact");
        let m = &art.manifest;
        let n_params = m.n_param_arrays();

        // ---- initial state: glorot weights, zero biases and moments
        let mut rng = Rng::new(cfg.seed);
        let mut state: Vec<xla::Literal> = Vec::with_capacity(3 * n_params);
        for i in 0..n_params {
            let shape = &m.inputs[i].shape;
            let t = match shape.len() {
                2 => TensorData::new(shape.clone(),
                                     rng.glorot(shape[0], shape[1]))?,
                1 => TensorData::zeros(shape),
                0 => TensorData::scalar(cfg.eps_init as f32),
                _ => bail!("unexpected param rank {shape:?}"),
            };
            state.push(t.to_literal()?);
        }
        // m and v moments: zeros of the same shapes
        for i in 0..2 * n_params {
            let shape = &m.inputs[n_params + i].shape;
            state.push(TensorData::zeros(shape).to_literal()?);
        }

        // ---- sanity: step/lr slots where aot.signature puts them
        ensure!(m.inputs[3 * n_params].name == "step"
                    && m.inputs[3 * n_params + 1].name == "lr",
                "manifest layout unexpected: {:?}",
                &m.inputs[3 * n_params].name);

        // ---- data segment in manifest order, resident on device
        let mut data = Vec::new();
        let mut data_src = Vec::new();
        for spec in &m.inputs[3 * n_params + 2..] {
            let lit = build_data_input(m, spec, src, cfg)
                .with_context(|| format!("building input '{}'",
                                         spec.name))?;
            data.push(engine.to_buffer(&lit)?);
            data_src.push(lit);
        }

        let extra_label = match m.loss.as_str() {
            "inverse_const" => "eps".to_string(),
            "inverse_space" => "sensor_loss".to_string(),
            _ => String::new(),
        };

        Ok(Trainer {
            engine,
            art,
            state,
            data,
            _data_src: data_src,
            cfg: cfg.clone(),
            history: TrainHistory { rows: vec![], extra_label },
            step: 0,
            n_params,
        })
    }

    pub fn manifest(&self) -> &crate::runtime::manifest::Manifest {
        &self.art.manifest
    }

    /// Current trainable eps (inverse_const artifacts).
    pub fn current_eps(&self) -> Result<f64> {
        ensure!(self.art.manifest.loss == "inverse_const",
                "no trainable eps in {}", self.art.manifest.name);
        let lit = &self.state[self.n_params - 1];
        Ok(lit.to_vec::<f32>()?[0] as f64)
    }

    /// Network parameter literals (excludes the eps scalar), for predict.
    pub fn network_params(&self) -> &[xla::Literal] {
        &self.state[..self.art.manifest.n_network_arrays()]
    }

    /// One optimizer step; returns (loss, var_loss, bd_loss, extra).
    pub fn step_once(&mut self) -> Result<(f64, f64, f64, f64)> {
        self.step += 1;
        let lr = self.cfg.lr.at(self.step - 1) as f32;
        let step_lit = xla::Literal::scalar(self.step as f32);
        let lr_lit = xla::Literal::scalar(lr);

        // upload the (small) mutable state; the big data segment is
        // already device-resident
        let state_bufs: Vec<xla::PjRtBuffer> = self
            .state
            .iter()
            .map(|l| self.engine.to_buffer(l))
            .collect::<Result<_>>()?;
        let step_buf = self.engine.to_buffer(&step_lit)?;
        let lr_buf = self.engine.to_buffer(&lr_lit)?;
        let mut inputs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.art.manifest.inputs.len());
        inputs.extend(state_bufs.iter());
        inputs.push(&step_buf);
        inputs.push(&lr_buf);
        inputs.extend(self.data.iter());

        let outputs = self.art.execute_buffers(&inputs)?;
        let n_state = 3 * self.n_params;
        let mut it = outputs.into_iter();
        let mut new_state = Vec::with_capacity(n_state);
        for _ in 0..n_state {
            new_state.push(it.next().ok_or_else(|| anyhow!("short output"))?);
        }
        let rest: Vec<xla::Literal> = it.collect();
        self.state = new_state;

        let scalar = |l: &xla::Literal| -> Result<f64> {
            Ok(l.to_vec::<f32>()?[0] as f64)
        };
        let loss = scalar(&rest[0])?;
        let var_loss = if rest.len() > 1 { scalar(&rest[1])? } else { 0.0 };
        let bd_loss = if rest.len() > 2 { scalar(&rest[2])? } else { 0.0 };
        let extra = match self.art.manifest.loss.as_str() {
            "inverse_const" => self.current_eps()?,
            _ if rest.len() > 3 => scalar(&rest[3])?,
            _ => 0.0,
        };
        Ok((loss, var_loss, bd_loss, extra))
    }

    /// Train for `cfg.iters` steps (or until eps convergence).
    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let mut timer = StepTimer::new();
        let mut last = (f64::NAN, f64::NAN, f64::NAN, 0.0);
        let mut converged_early = false;
        for i in 0..self.cfg.iters {
            timer.start();
            last = self.step_once()?;
            timer.stop();
            if !last.0.is_finite() {
                bail!("loss diverged to {} at step {}", last.0, self.step);
            }
            let log = self.cfg.log_every.max(1);
            if i % log == 0 || i + 1 == self.cfg.iters {
                self.history.push(HistoryRow {
                    step: self.step,
                    loss: last.0,
                    var_loss: last.1,
                    bd_loss: last.2,
                    extra: last.3,
                    step_ms: timer.summary().median,
                });
            }
            if let Some((target, tol)) = self.cfg.eps_converge {
                if self.art.manifest.loss == "inverse_const"
                    && (last.3 - target).abs() < tol
                {
                    converged_early = true;
                    break;
                }
            }
        }
        Ok(TrainReport {
            steps: self.step,
            final_loss: last.0,
            final_var_loss: last.1,
            final_bd_loss: last.2,
            median_step_ms: timer.summary().median,
            total_seconds: t0.elapsed().as_secs_f64(),
            eps_final: if self.art.manifest.loss == "inverse_const" {
                Some(last.3)
            } else {
                None
            },
            converged_early,
        })
    }

    /// Predict at points via the matching predict artifact, head 0.
    pub fn predict(&self, predict_name: &str, points: &[[f64; 2]])
        -> Result<Vec<f32>> {
        let outs = self.engine.predict(predict_name,
                                       self.network_params(), points)?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Predict all heads (u, eps for two-head inverse networks).
    pub fn predict_heads(&self, predict_name: &str, points: &[[f64; 2]])
        -> Result<Vec<Vec<f32>>> {
        self.engine.predict(predict_name, self.network_params(), points)
    }

    /// Evaluate error norms against a reference on given points.
    pub fn evaluate(
        &self,
        predict_name: &str,
        points: &[[f64; 2]],
        reference: &[f64],
    ) -> Result<ErrorNorms> {
        let pred = self.predict(predict_name, points)?;
        Ok(ErrorNorms::compute_f32(&pred, reference))
    }
}

/// Build one data-segment literal according to its manifest name.
fn build_data_input(
    m: &crate::runtime::manifest::Manifest,
    spec: &crate::runtime::manifest::IoSpec,
    src: &DataSource<'_>,
    cfg: &TrainConfig,
) -> Result<xla::Literal> {
    let domain = || -> Result<&AssembledDomain> {
        src.domain.ok_or_else(|| anyhow!(
            "artifact {} needs assembled tensors but DataSource.domain \
             is None", m.name))
    };
    let lit = match spec.name.as_str() {
        "quad_xy" => {
            let d = domain()?;
            TensorData::new(spec.shape.clone(), d.quad_xy_f32())?
        }
        "gx" => TensorData::new(spec.shape.clone(), domain()?.gx_f32())?,
        "gy" => TensorData::new(spec.shape.clone(), domain()?.gy_f32())?,
        "v" => TensorData::new(spec.shape.clone(), domain()?.v_f32())?,
        "f" => {
            let d = domain()?;
            let f = d.force_matrix(|x, y| src.problem.forcing(x, y));
            TensorData::from_f64(spec.shape.clone(), &f)?
        }
        "bd_xy" => {
            let pts = src.mesh.sample_boundary(m.config.nb);
            let flat: Vec<f32> = pts
                .iter()
                .flat_map(|p| [p[0] as f32, p[1] as f32])
                .collect();
            TensorData::new(spec.shape.clone(), flat)?
        }
        "bd_u" => {
            let pts = src.mesh.sample_boundary(m.config.nb);
            let vals: Vec<f32> = pts
                .iter()
                .map(|p| src.problem.boundary(p[0], p[1]) as f32)
                .collect();
            TensorData::new(spec.shape.clone(), vals)?
        }
        "sensor_xy" => {
            let pts = src.mesh.sample_interior(m.config.ns, cfg.seed + 1);
            let flat: Vec<f32> = pts
                .iter()
                .flat_map(|p| [p[0] as f32, p[1] as f32])
                .collect();
            TensorData::new(spec.shape.clone(), flat)?
        }
        "sensor_u" => {
            let pts = src.mesh.sample_interior(m.config.ns, cfg.seed + 1);
            let vals: Vec<f32> = pts
                .iter()
                .map(|p| sensor_value(src, p[0], p[1]))
                .collect::<Result<_>>()?;
            TensorData::new(spec.shape.clone(), vals)?
        }
        "coll_xy" => {
            let pts = src.mesh.sample_interior(m.config.n_coll, cfg.seed);
            let flat: Vec<f32> = pts
                .iter()
                .flat_map(|p| [p[0] as f32, p[1] as f32])
                .collect();
            TensorData::new(spec.shape.clone(), flat)?
        }
        "f_vals" => {
            let pts = src.mesh.sample_interior(m.config.n_coll, cfg.seed);
            let vals: Vec<f32> = pts
                .iter()
                .map(|p| src.problem.forcing(p[0], p[1]) as f32)
                .collect();
            TensorData::new(spec.shape.clone(), vals)?
        }
        "tau" => TensorData::scalar(cfg.tau as f32),
        "gamma" => TensorData::scalar(cfg.gamma as f32),
        other => bail!("unknown manifest input '{other}'"),
    };
    lit.to_literal()
}

fn sensor_value(src: &DataSource<'_>, x: f64, y: f64) -> Result<f32> {
    if let Some(f) = src.sensor_values {
        return Ok(f(x, y) as f32);
    }
    src.problem
        .exact(x, y)
        .map(|v| v as f32)
        .ok_or_else(|| anyhow!(
            "problem '{}' has no exact solution; provide \
             DataSource.sensor_values", src.problem.name()))
}

#[cfg(test)]
mod tests {
    //! Full Trainer tests need compiled artifacts; they live in
    //! rust/tests/integration.rs. Here: config defaults only.
    use super::*;

    #[test]
    fn config_defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!(c.eps_init, 2.0); // paper SS4.7.1 initial guess
        assert!(matches!(c.lr, LrSchedule::Constant(lr) if lr == 1e-3));
    }
}
