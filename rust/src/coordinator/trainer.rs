//! The training coordinator: drives any [`Backend`] through an optimizer
//! run — applies the LR schedule, tracks timing (median per epoch — the
//! paper's protocol), logs history, checks convergence and computes
//! error norms.
//!
//! The coordinator is backend-agnostic: the same loop trains the pure
//! Rust native backend and (with `--features xla`) the AOT/PJRT
//! artifacts. No `xla::` type appears in any signature here.

use anyhow::{bail, Result};

use crate::coordinator::history::{HistoryRow, TrainHistory};
use crate::coordinator::metrics::ErrorNorms;
use crate::coordinator::schedule::LrSchedule;
use crate::runtime::backend::BackendOpts;
pub use crate::runtime::backend::{Backend, DataSource, StepStats};
use crate::util::stats::StepTimer;

/// Training hyper-parameters (paper defaults where applicable).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub iters: usize,
    pub lr: LrSchedule,
    /// Dirichlet penalty (paper's tau).
    pub tau: f64,
    /// Sensor penalty for inverse problems (paper's gamma).
    pub gamma: f64,
    pub seed: u64,
    /// Record a history row every `log_every` steps (1 = all).
    pub log_every: usize,
    /// Initial guess for the trainable eps (inverse_const; paper: 2.0).
    pub eps_init: f64,
    /// Early stop when |eps - target| < tol (inverse_const).
    pub eps_converge: Option<(f64, f64)>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 2000,
            lr: LrSchedule::Constant(1e-3),
            tau: 10.0,
            gamma: 10.0,
            seed: 42,
            log_every: 1,
            eps_init: 2.0,
            eps_converge: None,
        }
    }
}

impl From<&TrainConfig> for BackendOpts {
    fn from(c: &TrainConfig) -> BackendOpts {
        BackendOpts {
            tau: c.tau,
            gamma: c.gamma,
            seed: c.seed,
            eps_init: c.eps_init,
        }
    }
}

/// Summary returned by `Trainer::run`.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub final_loss: f64,
    pub final_var_loss: f64,
    pub final_bd_loss: f64,
    pub median_step_ms: f64,
    pub total_seconds: f64,
    /// Final trainable eps (inverse_const only).
    pub eps_final: Option<f64>,
    pub converged_early: bool,
}

pub struct Trainer<'a> {
    backend: Box<dyn Backend + 'a>,
    cfg: TrainConfig,
    pub history: TrainHistory,
    step: usize,
}

impl<'a> Trainer<'a> {
    /// Wrap a backend. Backend selection is runtime-polymorphic: pass a
    /// boxed [`crate::runtime::backend::native::NativeBackend`] or (with
    /// `--features xla`) an `XlaBackend`.
    pub fn new(backend: Box<dyn Backend + 'a>, cfg: &TrainConfig)
        -> Trainer<'a> {
        let extra_label = match backend.loss_kind() {
            "inverse_const" => "eps".to_string(),
            "inverse_space" => "sensor_loss".to_string(),
            _ => String::new(),
        };
        Trainer {
            backend,
            cfg: cfg.clone(),
            history: TrainHistory { rows: vec![], extra_label },
            step: 0,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn loss_kind(&self) -> &str {
        self.backend.loss_kind()
    }

    /// Current trainable eps (inverse losses).
    pub fn current_eps(&self) -> Result<f64> {
        self.backend.current_eps().ok_or_else(|| anyhow::anyhow!(
            "no trainable eps in this {} backend ({})",
            self.backend.name(), self.backend.loss_kind()))
    }

    /// One optimizer step; returns (loss, var_loss, bd_loss, extra).
    pub fn step_once(&mut self) -> Result<(f64, f64, f64, f64)> {
        self.step += 1;
        let lr = self.cfg.lr.at(self.step - 1);
        let s = self.backend.step(self.step, lr)?;
        Ok((s.loss, s.var_loss, s.bd_loss, s.extra))
    }

    /// Train for `cfg.iters` steps (or until eps convergence).
    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let mut timer = StepTimer::new();
        let mut last = (f64::NAN, f64::NAN, f64::NAN, 0.0);
        let mut converged_early = false;
        let inverse = self.backend.loss_kind() == "inverse_const";
        for i in 0..self.cfg.iters {
            timer.start();
            last = self.step_once()?;
            timer.stop();
            if !last.0.is_finite() {
                bail!("loss diverged to {} at step {}", last.0, self.step);
            }
            let log = self.cfg.log_every.max(1);
            if i % log == 0 || i + 1 == self.cfg.iters {
                self.history.push(HistoryRow {
                    step: self.step,
                    loss: last.0,
                    var_loss: last.1,
                    bd_loss: last.2,
                    extra: last.3,
                    step_ms: timer.summary().median,
                });
            }
            if let Some((target, tol)) = self.cfg.eps_converge {
                if inverse && (last.3 - target).abs() < tol {
                    converged_early = true;
                    break;
                }
            }
        }
        Ok(TrainReport {
            steps: self.step,
            final_loss: last.0,
            final_var_loss: last.1,
            final_bd_loss: last.2,
            median_step_ms: timer.summary().median,
            total_seconds: t0.elapsed().as_secs_f64(),
            eps_final: if inverse { Some(last.3) } else { None },
            converged_early,
        })
    }

    /// Predict u (head 0) at arbitrary points.
    pub fn predict(&self, points: &[[f64; 2]]) -> Result<Vec<f32>> {
        let mut heads = self.backend.predict(points)?;
        anyhow::ensure!(!heads.is_empty(), "backend returned no heads");
        Ok(heads.swap_remove(0))
    }

    /// Predict all heads (u, eps for two-head inverse networks).
    pub fn predict_heads(&self, points: &[[f64; 2]])
        -> Result<Vec<Vec<f32>>> {
        self.backend.predict(points)
    }

    /// Predict the trainable eps *field* (two-head inverse-space
    /// networks). Prefers the backend's dedicated
    /// [`Backend::predict_eps_field`]; falls back to head 1 of
    /// `predict` for backends that only expose the field as a second
    /// output head (AOT two-head artifacts).
    pub fn predict_eps_field(&self, points: &[[f64; 2]])
        -> Result<Vec<f32>> {
        if let Some(eps) = self.backend.predict_eps_field(points)? {
            return Ok(eps);
        }
        let mut heads = self.backend.predict(points)?;
        anyhow::ensure!(
            heads.len() >= 2,
            "backend {} ({}) has no eps field head",
            self.backend.name(), self.backend.loss_kind()
        );
        Ok(heads.swap_remove(1))
    }

    /// Evaluate error norms against a reference on given points.
    pub fn evaluate(&self, points: &[[f64; 2]], reference: &[f64])
        -> Result<ErrorNorms> {
        let pred = self.predict(points)?;
        Ok(ErrorNorms::compute_f32(&pred, reference))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fem::assembly;
    use crate::fem::quadrature::QuadKind;
    use crate::mesh::generators;
    use crate::problems::PoissonSin;
    use crate::runtime::backend::native::{
        NativeBackend, NativeConfig, NativeLoss,
    };

    #[test]
    fn config_defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!(c.eps_init, 2.0); // paper SS4.7.1 initial guess
        assert!(matches!(c.lr, LrSchedule::Constant(lr) if lr == 1e-3));
    }

    #[test]
    fn trainer_drives_native_backend_and_logs_history() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = PoissonSin::new(std::f64::consts::PI);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = TrainConfig {
            iters: 25,
            log_every: 5,
            ..TrainConfig::default()
        };
        let ncfg = NativeConfig {
            layers: vec![2, 8, 1],
            loss: NativeLoss::Forward,
            nb: 16,
            ns: 0,
        };
        let backend = NativeBackend::new(
            &ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
        let mut t = Trainer::new(Box::new(backend), &cfg);
        assert_eq!(t.backend_name(), "native");
        let report = t.run().unwrap();
        assert_eq!(report.steps, 25);
        assert!(report.final_loss.is_finite());
        assert!(!t.history.rows.is_empty());
        assert!(t.current_eps().is_err()); // forward problem: no eps
        let pred = t.predict(&[[0.5, 0.5]]).unwrap();
        assert_eq!(pred.len(), 1);
    }

    #[test]
    fn trainer_drives_two_head_inverse_space_backend() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = PoissonSin::new(std::f64::consts::PI);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = TrainConfig { iters: 5, ..TrainConfig::default() };
        let ncfg = NativeConfig {
            layers: vec![2, 8, 1],
            loss: NativeLoss::InverseSpace,
            nb: 16,
            ns: 8,
        };
        let backend = NativeBackend::new(
            &ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
        let mut t = Trainer::new(Box::new(backend), &cfg);
        assert_eq!(t.loss_kind(), "inverse_space");
        assert_eq!(t.history.extra_label, "sensor_loss");
        t.run().unwrap();
        assert!(t.current_eps().is_err()); // field, not a scalar
        let pts = [[0.5, 0.5], [0.2, 0.8]];
        let heads = t.predict_heads(&pts).unwrap();
        assert_eq!(heads.len(), 2, "u and eps heads");
        let eps = t.predict_eps_field(&pts).unwrap();
        assert_eq!(eps.len(), 2);
        assert_eq!(eps, heads[1]);
        assert!(eps.iter().all(|&e| e > 0.0), "softplus positivity");
    }
}
