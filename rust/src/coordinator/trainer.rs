//! The training coordinator: drives any [`Backend`] through an optimizer
//! run — applies the LR schedule, tracks timing (median per epoch — the
//! paper's protocol), logs history, checks convergence and computes
//! error norms.
//!
//! The coordinator is backend-agnostic: the same loop trains the pure
//! Rust native backend and (with `--features xla`) the AOT/PJRT
//! artifacts. No `xla::` type appears in any signature here.
//!
//! It also owns run-level persistence: a [`CheckpointPolicy`] makes
//! [`Trainer::run`] write a versioned
//! [`Checkpoint`](crate::runtime::checkpoint::Checkpoint) artifact
//! periodically and at the end of the run, tracking the best model so
//! far (by validation rel-L2 when a validation set is attached, by
//! total loss otherwise) at `<path>.best`; and
//! [`Trainer::resume_from_step`] continues a warm-restarted run at the
//! persisted step count, so the LR schedule and Adam bias correction
//! pick up exactly where the interrupted run left off.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::coordinator::history::{HistoryRow, TrainHistory};
use crate::coordinator::metrics::ErrorNorms;
use crate::coordinator::schedule::LrSchedule;
use crate::runtime::backend::BackendOpts;
pub use crate::runtime::backend::{Backend, DataSource, StepStats};
use crate::runtime::checkpoint::Checkpoint;
use crate::util::stats::StepTimer;

/// Training hyper-parameters (paper defaults where applicable).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Optimizer step budget for one `run()`.
    pub iters: usize,
    /// Learning-rate schedule.
    pub lr: LrSchedule,
    /// Dirichlet penalty (paper's tau).
    pub tau: f64,
    /// Sensor penalty for inverse problems (paper's gamma).
    pub gamma: f64,
    /// RNG seed (weight init + boundary/sensor sampling).
    pub seed: u64,
    /// Record a history row every `log_every` steps (1 = all).
    pub log_every: usize,
    /// Initial guess for the trainable eps (inverse_const; paper: 2.0).
    pub eps_init: f64,
    /// Early stop when |eps - target| < tol (inverse_const).
    pub eps_converge: Option<(f64, f64)>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 2000,
            lr: LrSchedule::Constant(1e-3),
            tau: 10.0,
            gamma: 10.0,
            seed: 42,
            log_every: 1,
            eps_init: 2.0,
            eps_converge: None,
        }
    }
}

impl From<&TrainConfig> for BackendOpts {
    fn from(c: &TrainConfig) -> BackendOpts {
        BackendOpts {
            tau: c.tau,
            gamma: c.gamma,
            seed: c.seed,
            eps_init: c.eps_init,
        }
    }
}

/// When and where [`Trainer::run`] persists checkpoints.
#[derive(Debug, Clone)]
pub struct CheckpointPolicy {
    /// Artifact path; overwritten on every save. The best model so far
    /// additionally lands at `<path>.best`.
    pub path: PathBuf,
    /// Save every `every` steps (0 = only at the end of the run).
    pub every: usize,
    /// Registry problem id persisted into the artifact (what
    /// `--resume` looks up).
    pub problem: String,
    /// CLI flags persisted into the artifact so a resumed run can
    /// rebuild the identical setup.
    pub cli: Vec<(String, String)>,
}

/// Summary returned by `Trainer::run`.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Optimizer steps taken in total (incl. a resumed prefix).
    pub steps: usize,
    /// Total objective after the last step.
    pub final_loss: f64,
    /// Variational component of the final loss.
    pub final_var_loss: f64,
    /// Dirichlet-penalty component of the final loss.
    pub final_bd_loss: f64,
    /// Median wall-clock per step (the paper's protocol).
    pub median_step_ms: f64,
    /// Total wall-clock of the run.
    pub total_seconds: f64,
    /// Final trainable eps (inverse_const only).
    pub eps_final: Option<f64>,
    /// Whether the eps-convergence early stop fired.
    pub converged_early: bool,
    /// Best checkpoint metric seen (validation rel-L2 when a
    /// validation set is attached, total loss otherwise); `None`
    /// without a [`CheckpointPolicy`].
    pub best_metric: Option<f64>,
}

/// Drives a boxed [`Backend`] through a training run; see the module
/// docs for responsibilities.
pub struct Trainer<'a> {
    backend: Box<dyn Backend + 'a>,
    cfg: TrainConfig,
    /// Per-step loss/timing log (CSV-dumpable).
    pub history: TrainHistory,
    step: usize,
    ckpt: Option<CheckpointPolicy>,
    /// Validation set for best-model tracking: points + reference.
    validation: Option<(Vec<[f64; 2]>, Vec<f64>)>,
    best_metric: f64,
}

impl<'a> Trainer<'a> {
    /// Wrap a backend. Backend selection is runtime-polymorphic: pass a
    /// boxed [`crate::runtime::backend::native::NativeBackend`] or (with
    /// `--features xla`) an `XlaBackend`.
    pub fn new(backend: Box<dyn Backend + 'a>, cfg: &TrainConfig)
        -> Trainer<'a> {
        let extra_label = match backend.loss_kind() {
            "inverse_const" => "eps".to_string(),
            "inverse_space" => "sensor_loss".to_string(),
            _ => String::new(),
        };
        Trainer {
            backend,
            cfg: cfg.clone(),
            history: TrainHistory { rows: vec![], extra_label },
            step: 0,
            ckpt: None,
            validation: None,
            best_metric: f64::INFINITY,
        }
    }

    /// Enable checkpointing for the next [`Trainer::run`] (see
    /// [`CheckpointPolicy`]).
    pub fn set_checkpoint_policy(&mut self, policy: CheckpointPolicy) {
        self.ckpt = Some(policy);
    }

    /// Attach a validation set: with one, best-model tracking ranks
    /// checkpoints by rel-L2 of head 0 against `reference` on `points`
    /// instead of by total loss.
    pub fn set_validation(
        &mut self,
        points: Vec<[f64; 2]>,
        reference: Vec<f64>,
    ) {
        self.validation = Some((points, reference));
    }

    /// Continue a warm-restarted run at `step` (the checkpoint's
    /// persisted count): the LR schedule position and the 1-based Adam
    /// step the backend sees both pick up from there, so the resumed
    /// trajectory matches the uninterrupted one.
    pub fn resume_from_step(&mut self, step: usize) {
        self.step = step;
    }

    /// Seed best-model tracking from a prior run's persisted
    /// [`Checkpoint::best_metric`] (warm restart): the resumed run
    /// then only overwrites `<path>.best` when it actually beats the
    /// original run's best, instead of restarting the comparison from
    /// scratch.
    pub fn resume_best_metric(&mut self, metric: f64) {
        self.best_metric = metric;
    }

    /// Export the backend's state as a [`Checkpoint`] with the
    /// trainer's current step count stamped in — the manual
    /// counterpart of a [`CheckpointPolicy`]-driven save (run-level
    /// metadata like the registry problem id is the caller's to fill).
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        let mut ck = self.backend.export_checkpoint()?;
        ck.step = self.step;
        if self.best_metric.is_finite() {
            ck.best_metric = Some(self.best_metric);
        }
        Ok(ck)
    }

    /// Write a policy-driven checkpoint: stamp step + run metadata,
    /// save to the policy path, and — if this is the best model so far
    /// by the current metric — to `<path>.best` as well.
    fn save_checkpoint(&mut self, last_loss: f64) -> Result<()> {
        let metric = match &self.validation {
            Some((pts, reference)) => {
                let mut heads = self.backend.predict(pts)?;
                anyhow::ensure!(
                    !heads.is_empty(),
                    "backend returned no heads for validation"
                );
                ErrorNorms::compute_f32(&heads.swap_remove(0), reference)
                    .rel_l2
            }
            None => last_loss,
        };
        let improved = metric < self.best_metric;
        if improved {
            self.best_metric = metric;
        }
        let policy = self.ckpt.as_ref().expect("save without policy");
        let mut ck = self.backend.export_checkpoint()?;
        ck.step = self.step;
        if self.best_metric.is_finite() {
            ck.best_metric = Some(self.best_metric);
        }
        ck.problem = policy.problem.clone();
        ck.cli = policy.cli.clone();
        ck.write(&policy.path)?;
        if improved {
            let mut best = policy.path.clone().into_os_string();
            best.push(".best");
            ck.write(PathBuf::from(best))?;
        }
        Ok(())
    }

    /// The wrapped backend's id ("native", "xla").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The wrapped backend's loss family ("poisson", "helmholtz", ...).
    pub fn loss_kind(&self) -> &str {
        self.backend.loss_kind()
    }

    /// Current trainable eps (inverse losses).
    pub fn current_eps(&self) -> Result<f64> {
        self.backend.current_eps().ok_or_else(|| anyhow::anyhow!(
            "no trainable eps in this {} backend ({})",
            self.backend.name(), self.backend.loss_kind()))
    }

    /// One optimizer step; returns (loss, var_loss, bd_loss, extra).
    pub fn step_once(&mut self) -> Result<(f64, f64, f64, f64)> {
        self.step += 1;
        let lr = self.cfg.lr.at(self.step - 1);
        let s = self.backend.step(self.step, lr)?;
        Ok((s.loss, s.var_loss, s.bd_loss, s.extra))
    }

    /// Train for `cfg.iters` steps (or until eps convergence).
    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let mut timer = StepTimer::new();
        let mut last = (f64::NAN, f64::NAN, f64::NAN, 0.0);
        let mut converged_early = false;
        let mut saved_at = None;
        let inverse = self.backend.loss_kind() == "inverse_const";
        for i in 0..self.cfg.iters {
            timer.start();
            last = self.step_once()?;
            timer.stop();
            if !last.0.is_finite() {
                bail!("loss diverged to {} at step {}", last.0, self.step);
            }
            let log = self.cfg.log_every.max(1);
            if i % log == 0 || i + 1 == self.cfg.iters {
                self.history.push(HistoryRow {
                    step: self.step,
                    loss: last.0,
                    var_loss: last.1,
                    bd_loss: last.2,
                    extra: last.3,
                    step_ms: timer.summary().median,
                });
            }
            let every = self.ckpt.as_ref().map_or(0, |p| p.every);
            if every > 0 && self.step % every == 0 {
                self.save_checkpoint(last.0)?;
                saved_at = Some(self.step);
            }
            if let Some((target, tol)) = self.cfg.eps_converge {
                if inverse && (last.3 - target).abs() < tol {
                    converged_early = true;
                    break;
                }
            }
        }
        // final save, unless the last periodic save already covered
        // this exact step
        if self.ckpt.is_some() && saved_at != Some(self.step) {
            self.save_checkpoint(last.0)?;
        }
        Ok(TrainReport {
            steps: self.step,
            final_loss: last.0,
            final_var_loss: last.1,
            final_bd_loss: last.2,
            median_step_ms: timer.summary().median,
            total_seconds: t0.elapsed().as_secs_f64(),
            eps_final: if inverse { Some(last.3) } else { None },
            converged_early,
            best_metric: if self.ckpt.is_some()
                && self.best_metric.is_finite()
            {
                Some(self.best_metric)
            } else {
                None
            },
        })
    }

    /// Predict u (head 0) at arbitrary points.
    pub fn predict(&self, points: &[[f64; 2]]) -> Result<Vec<f32>> {
        let mut heads = self.backend.predict(points)?;
        anyhow::ensure!(!heads.is_empty(), "backend returned no heads");
        Ok(heads.swap_remove(0))
    }

    /// Predict all heads (u, eps for two-head inverse networks).
    pub fn predict_heads(&self, points: &[[f64; 2]])
        -> Result<Vec<Vec<f32>>> {
        self.backend.predict(points)
    }

    /// Predict the trainable eps *field* (two-head inverse-space
    /// networks). Prefers the backend's dedicated
    /// [`Backend::predict_eps_field`]; falls back to head 1 of
    /// `predict` for backends that only expose the field as a second
    /// output head (AOT two-head artifacts).
    pub fn predict_eps_field(&self, points: &[[f64; 2]])
        -> Result<Vec<f32>> {
        if let Some(eps) = self.backend.predict_eps_field(points)? {
            return Ok(eps);
        }
        let mut heads = self.backend.predict(points)?;
        anyhow::ensure!(
            heads.len() >= 2,
            "backend {} ({}) has no eps field head",
            self.backend.name(), self.backend.loss_kind()
        );
        Ok(heads.swap_remove(1))
    }

    /// Evaluate error norms against a reference on given points.
    pub fn evaluate(&self, points: &[[f64; 2]], reference: &[f64])
        -> Result<ErrorNorms> {
        let pred = self.predict(points)?;
        Ok(ErrorNorms::compute_f32(&pred, reference))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fem::assembly;
    use crate::fem::quadrature::QuadKind;
    use crate::mesh::generators;
    use crate::problems::PoissonSin;
    use crate::runtime::backend::native::{
        NativeBackend, NativeConfig, NativeLoss,
    };

    #[test]
    fn config_defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!(c.eps_init, 2.0); // paper SS4.7.1 initial guess
        assert!(matches!(c.lr, LrSchedule::Constant(lr) if lr == 1e-3));
    }

    #[test]
    fn trainer_drives_native_backend_and_logs_history() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = PoissonSin::new(std::f64::consts::PI);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = TrainConfig {
            iters: 25,
            log_every: 5,
            ..TrainConfig::default()
        };
        let ncfg = NativeConfig {
            layers: vec![2, 8, 1],
            loss: NativeLoss::Forward,
            nb: 16,
            ns: 0,
        };
        let backend = NativeBackend::new(
            &ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
        let mut t = Trainer::new(Box::new(backend), &cfg);
        assert_eq!(t.backend_name(), "native");
        let report = t.run().unwrap();
        assert_eq!(report.steps, 25);
        assert!(report.final_loss.is_finite());
        assert!(!t.history.rows.is_empty());
        assert!(t.current_eps().is_err()); // forward problem: no eps
        let pred = t.predict(&[[0.5, 0.5]]).unwrap();
        assert_eq!(pred.len(), 1);
    }

    #[test]
    fn trainer_drives_two_head_inverse_space_backend() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = PoissonSin::new(std::f64::consts::PI);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = TrainConfig { iters: 5, ..TrainConfig::default() };
        let ncfg = NativeConfig {
            layers: vec![2, 8, 1],
            loss: NativeLoss::InverseSpace,
            nb: 16,
            ns: 8,
        };
        let backend = NativeBackend::new(
            &ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
        let mut t = Trainer::new(Box::new(backend), &cfg);
        assert_eq!(t.loss_kind(), "inverse_space");
        assert_eq!(t.history.extra_label, "sensor_loss");
        t.run().unwrap();
        assert!(t.current_eps().is_err()); // field, not a scalar
        let pts = [[0.5, 0.5], [0.2, 0.8]];
        let heads = t.predict_heads(&pts).unwrap();
        assert_eq!(heads.len(), 2, "u and eps heads");
        let eps = t.predict_eps_field(&pts).unwrap();
        assert_eq!(eps.len(), 2);
        assert_eq!(eps, heads[1]);
        assert!(eps.iter().all(|&e| e > 0.0), "softplus positivity");
    }

    #[test]
    fn checkpoint_policy_writes_periodic_final_and_best() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = PoissonSin::new(std::f64::consts::PI);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = TrainConfig { iters: 25, ..TrainConfig::default() };
        let ncfg = NativeConfig {
            layers: vec![2, 8, 1],
            loss: NativeLoss::Forward,
            nb: 16,
            ns: 0,
        };
        let backend = NativeBackend::new(
            &ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
        let mut t = Trainer::new(Box::new(backend), &cfg);
        let path = std::env::temp_dir().join(format!(
            "fastvpinns_trainer_policy_{}.ckpt",
            std::process::id()
        ));
        let best = {
            let mut b = path.clone().into_os_string();
            b.push(".best");
            std::path::PathBuf::from(b)
        };
        t.set_checkpoint_policy(CheckpointPolicy {
            path: path.clone(),
            every: 10,
            problem: "poisson_sin".into(),
            cli: vec![("n".into(), "1".into())],
        });
        let pts = vec![[0.25, 0.25], [0.5, 0.75], [0.9, 0.1]];
        let exact: Vec<f64> = pts
            .iter()
            .map(|p| problem.exact(p[0], p[1]).unwrap())
            .collect();
        t.set_validation(pts.clone(), exact);
        let report = t.run().unwrap();
        assert!(report.best_metric.is_some(), "validation metric tracked");
        let ck = Checkpoint::read(&path).unwrap();
        assert_eq!(ck.step, 25, "final save carries the step count");
        assert_eq!(ck.problem, "poisson_sin");
        assert_eq!(ck.cli, vec![("n".to_string(), "1".to_string())]);
        // the final artifact reproduces the live backend bit-for-bit
        let net = crate::runtime::backend::native::Mlp::from_theta(
            &ck.layers, ck.two_head, ck.theta.clone()).unwrap();
        assert_eq!(net.eval(&pts), t.predict(&pts).unwrap());
        let bk = Checkpoint::read(&best).unwrap();
        assert_eq!(bk.layers, ck.layers);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&best).ok();
    }
}
