//! The training coordinator: drives any [`Backend`] through an optimizer
//! run — applies the LR schedule, tracks timing (median per epoch — the
//! paper's protocol), logs history, checks convergence and computes
//! error norms.
//!
//! The coordinator is backend-agnostic: the same loop trains the pure
//! Rust native backend and (with `--features xla`) the AOT/PJRT
//! artifacts. No `xla::` type appears in any signature here.
//!
//! It also owns run-level persistence: a [`CheckpointPolicy`] makes
//! [`Trainer::run`] write a versioned
//! [`Checkpoint`](crate::runtime::checkpoint::Checkpoint) artifact
//! periodically and at the end of the run, tracking the best model so
//! far (by validation rel-L2 when a validation set is attached, by
//! total loss otherwise) at `<path>.best`; and
//! [`Trainer::resume_from_step`] continues a warm-restarted run at the
//! persisted step count, so the LR schedule and Adam bias correction
//! pick up exactly where the interrupted run left off.
//!
//! ## Self-healing
//!
//! [`Trainer::run`] is crash-averse by default: a [`RecoveryPolicy`]
//! keeps an in-memory snapshot of the backend state every
//! `snapshot_every` clean steps, and a divergence sentinel checks every
//! step's loss and gradient norm. When a step goes non-finite (or the
//! grad norm explodes past `grad_norm_limit`), the loop rolls the
//! backend back to the snapshot, resets the Adam moments (they were
//! computed on the doomed trajectory), scales the learning rate down by
//! `lr_backoff`, and replays — up to `max_recoveries` times per run,
//! after which the divergence is surfaced as an error. The backoff is
//! a *transient* response: after `lr_restore_after` consecutive clean
//! steps the scale is annealed back to 1.0, so a one-off divergence
//! does not leave the whole tail of the run training at a reduced
//! rate (offline sizing in `python/proto_selfheal.py` shows the
//! annealed recovery lands inside the clean-run accuracy family,
//! while a permanent backoff erodes the acceptance-bar margin).
//! Every rollback is recorded as a [`RecoveryEvent`] in
//! [`TrainReport::recoveries`].
//! A warn-only watchdog thread (`watchdog_ms > 0`) flags steps that
//! exceed a wall-clock limit without ever killing the run. Backends
//! that cannot export their state (no
//! [`Backend::export_checkpoint`]) silently fall back to the legacy
//! abort-on-divergence behavior.

#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Result};

use crate::coordinator::history::{HistoryRow, TrainHistory};
use crate::coordinator::metrics::ErrorNorms;
use crate::coordinator::schedule::LrSchedule;
use crate::runtime::backend::BackendOpts;
pub use crate::runtime::backend::{Backend, DataSource, StepStats};
use crate::runtime::checkpoint::Checkpoint;
use crate::runtime::failpoint;
use crate::util::stats::StepTimer;

/// Training hyper-parameters (paper defaults where applicable).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Optimizer step budget for one `run()`.
    pub iters: usize,
    /// Learning-rate schedule.
    pub lr: LrSchedule,
    /// Dirichlet penalty (paper's tau).
    pub tau: f64,
    /// Sensor penalty for inverse problems (paper's gamma).
    pub gamma: f64,
    /// RNG seed (weight init + boundary/sensor sampling).
    pub seed: u64,
    /// Record a history row every `log_every` steps (1 = all).
    pub log_every: usize,
    /// Initial guess for the trainable eps (inverse_const; paper: 2.0).
    pub eps_init: f64,
    /// Early stop when |eps - target| < tol (inverse_const).
    pub eps_converge: Option<(f64, f64)>,
    /// Worker threads for the native backend's persistent pool
    /// (`--workers`; `None` = env alias, then machine parallelism).
    /// Wall-clock only — never changes a result bit.
    pub workers: Option<usize>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 2000,
            lr: LrSchedule::Constant(1e-3),
            tau: 10.0,
            gamma: 10.0,
            seed: 42,
            log_every: 1,
            eps_init: 2.0,
            eps_converge: None,
            workers: None,
        }
    }
}

impl From<&TrainConfig> for BackendOpts {
    fn from(c: &TrainConfig) -> BackendOpts {
        BackendOpts {
            tau: c.tau,
            gamma: c.gamma,
            seed: c.seed,
            eps_init: c.eps_init,
            workers: c.workers,
        }
    }
}

/// When and where [`Trainer::run`] persists checkpoints.
#[derive(Debug, Clone)]
pub struct CheckpointPolicy {
    /// Artifact path; overwritten on every save. The best model so far
    /// additionally lands at `<path>.best`.
    pub path: PathBuf,
    /// Save every `every` steps (0 = only at the end of the run).
    pub every: usize,
    /// Registry problem id persisted into the artifact (what
    /// `--resume` looks up).
    pub problem: String,
    /// CLI flags persisted into the artifact so a resumed run can
    /// rebuild the identical setup.
    pub cli: Vec<(String, String)>,
}

/// How [`Trainer::run`] reacts to divergence and stalls — the
/// self-healing knobs (see the module docs for the protocol).
#[derive(Debug, Clone, Copy)]
pub struct RecoveryPolicy {
    /// Snapshot the backend state in memory every this many clean
    /// steps (0 disables self-healing: divergence aborts the run like
    /// a plain training loop).
    pub snapshot_every: usize,
    /// Rollbacks allowed per `run()` before the divergence is
    /// surfaced as an error.
    pub max_recoveries: usize,
    /// Learning-rate multiplier applied on every rollback
    /// (compounding: two recoveries at 0.5 leave the LR at 0.25x).
    pub lr_backoff: f64,
    /// Consecutive clean steps after the most recent rollback before
    /// the backoff is annealed away (scale restored to 1.0). 0 keeps
    /// the reduced rate for the rest of the run.
    pub lr_restore_after: usize,
    /// Gradient-norm explosion threshold (0 disables the norm check;
    /// a non-finite loss or grad norm always counts as divergence).
    pub grad_norm_limit: f64,
    /// Warn when a single step exceeds this wall clock, in
    /// milliseconds (0 disables the watchdog thread).
    pub watchdog_ms: u64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            snapshot_every: 50,
            max_recoveries: 3,
            lr_backoff: 0.5,
            lr_restore_after: 500,
            grad_norm_limit: 1e12,
            watchdog_ms: 0,
        }
    }
}

/// One rollback performed by the self-healing loop, recorded in
/// [`TrainReport::recoveries`].
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryEvent {
    /// Step whose stats tripped the divergence sentinel.
    pub at_step: usize,
    /// Snapshot step the run was rolled back to.
    pub rollback_to: usize,
    /// What the sentinel saw (e.g. `"non-finite loss NaN"`).
    pub reason: String,
    /// Learning-rate scale in effect after this backoff.
    pub lr_scale: f64,
}

/// Summary returned by `Trainer::run`.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Optimizer steps taken in total (incl. a resumed prefix).
    pub steps: usize,
    /// Total objective after the last step.
    pub final_loss: f64,
    /// Variational component of the final loss.
    pub final_var_loss: f64,
    /// Dirichlet-penalty component of the final loss.
    pub final_bd_loss: f64,
    /// Median wall-clock per step (the paper's protocol).
    pub median_step_ms: f64,
    /// Total wall-clock of the run.
    pub total_seconds: f64,
    /// Final trainable eps (inverse_const only).
    pub eps_final: Option<f64>,
    /// Whether the eps-convergence early stop fired.
    pub converged_early: bool,
    /// Best checkpoint metric seen (validation rel-L2 when a
    /// validation set is attached, total loss otherwise); `None`
    /// without a [`CheckpointPolicy`].
    pub best_metric: Option<f64>,
    /// Every divergence rollback the self-healing loop performed, in
    /// order (empty on a clean run).
    pub recoveries: Vec<RecoveryEvent>,
    /// Steps the watchdog flagged as stalled (warn-only; 0 with the
    /// watchdog disabled).
    pub stalls: usize,
}

/// Drives a boxed [`Backend`] through a training run; see the module
/// docs for responsibilities.
pub struct Trainer<'a> {
    backend: Box<dyn Backend + 'a>,
    cfg: TrainConfig,
    /// Per-step loss/timing log (CSV-dumpable).
    pub history: TrainHistory,
    step: usize,
    ckpt: Option<CheckpointPolicy>,
    /// Validation set for best-model tracking: points + reference.
    validation: Option<(Vec<[f64; 2]>, Vec<f64>)>,
    best_metric: f64,
    recovery: RecoveryPolicy,
    /// Compounded LR backoff from recoveries (1.0 until one fires).
    lr_scale: f64,
}

impl<'a> Trainer<'a> {
    /// Wrap a backend. Backend selection is runtime-polymorphic: pass a
    /// boxed [`crate::runtime::backend::native::NativeBackend`] or (with
    /// `--features xla`) an `XlaBackend`.
    pub fn new(backend: Box<dyn Backend + 'a>, cfg: &TrainConfig)
        -> Trainer<'a> {
        let extra_label = match backend.loss_kind() {
            "inverse_const" => "eps".to_string(),
            "inverse_space" => "sensor_loss".to_string(),
            _ => String::new(),
        };
        Trainer {
            backend,
            cfg: cfg.clone(),
            history: TrainHistory { rows: vec![], extra_label },
            step: 0,
            ckpt: None,
            validation: None,
            best_metric: f64::INFINITY,
            recovery: RecoveryPolicy::default(),
            lr_scale: 1.0,
        }
    }

    /// Enable checkpointing for the next [`Trainer::run`] (see
    /// [`CheckpointPolicy`]).
    pub fn set_checkpoint_policy(&mut self, policy: CheckpointPolicy) {
        self.ckpt = Some(policy);
    }

    /// Override the self-healing policy for the next [`Trainer::run`]
    /// (see [`RecoveryPolicy`]; healing is on by default).
    pub fn set_recovery_policy(&mut self, policy: RecoveryPolicy) {
        self.recovery = policy;
    }

    /// Current learning-rate backoff scale (1.0 until a recovery
    /// fires, then multiplied by [`RecoveryPolicy::lr_backoff`] per
    /// rollback and annealed back to 1.0 after
    /// [`RecoveryPolicy::lr_restore_after`] clean steps).
    pub fn lr_scale(&self) -> f64 {
        self.lr_scale
    }

    /// Attach a validation set: with one, best-model tracking ranks
    /// checkpoints by rel-L2 of head 0 against `reference` on `points`
    /// instead of by total loss.
    pub fn set_validation(
        &mut self,
        points: Vec<[f64; 2]>,
        reference: Vec<f64>,
    ) {
        self.validation = Some((points, reference));
    }

    /// Continue a warm-restarted run at `step` (the checkpoint's
    /// persisted count): the LR schedule position and the 1-based Adam
    /// step the backend sees both pick up from there, so the resumed
    /// trajectory matches the uninterrupted one.
    pub fn resume_from_step(&mut self, step: usize) {
        self.step = step;
    }

    /// Seed best-model tracking from a prior run's persisted
    /// [`Checkpoint::best_metric`] (warm restart): the resumed run
    /// then only overwrites `<path>.best` when it actually beats the
    /// original run's best, instead of restarting the comparison from
    /// scratch.
    pub fn resume_best_metric(&mut self, metric: f64) {
        self.best_metric = metric;
    }

    /// Export the backend's state as a [`Checkpoint`] with the
    /// trainer's current step count stamped in — the manual
    /// counterpart of a [`CheckpointPolicy`]-driven save (run-level
    /// metadata like the registry problem id is the caller's to fill).
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        let mut ck = self.backend.export_checkpoint()?;
        ck.step = self.step;
        if self.best_metric.is_finite() {
            ck.best_metric = Some(self.best_metric);
        }
        Ok(ck)
    }

    /// Write a policy-driven checkpoint: stamp step + run metadata,
    /// save to the policy path, and — if this is the best model so far
    /// by the current metric — to `<path>.best` as well.
    fn save_checkpoint(&mut self, last_loss: f64) -> Result<()> {
        let metric = match &self.validation {
            Some((pts, reference)) => {
                let mut heads = self.backend.predict(pts)?;
                anyhow::ensure!(
                    !heads.is_empty(),
                    "backend returned no heads for validation"
                );
                ErrorNorms::compute_f32(&heads.swap_remove(0), reference)?
                    .rel_l2
            }
            None => last_loss,
        };
        let improved = metric < self.best_metric;
        if improved {
            self.best_metric = metric;
        }
        let policy = self.ckpt.as_ref().ok_or_else(|| {
            anyhow!("save_checkpoint called without a checkpoint policy")
        })?;
        let mut ck = self.backend.export_checkpoint()?;
        ck.step = self.step;
        if self.best_metric.is_finite() {
            ck.best_metric = Some(self.best_metric);
        }
        ck.problem = policy.problem.clone();
        ck.cli = policy.cli.clone();
        ck.write_generation(&policy.path)?;
        if improved {
            let mut best = policy.path.clone().into_os_string();
            best.push(".best");
            ck.write(PathBuf::from(best))?;
        }
        Ok(())
    }

    /// The wrapped backend's id ("native", "xla").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The wrapped backend's loss family ("poisson", "helmholtz", ...).
    pub fn loss_kind(&self) -> &str {
        self.backend.loss_kind()
    }

    /// Current trainable eps (inverse losses).
    pub fn current_eps(&self) -> Result<f64> {
        self.backend.current_eps().ok_or_else(|| anyhow::anyhow!(
            "no trainable eps in this {} backend ({})",
            self.backend.name(), self.backend.loss_kind()))
    }

    /// One optimizer step under the current LR schedule and recovery
    /// backoff scale.
    pub fn step_once(&mut self) -> Result<StepStats> {
        self.step += 1;
        // chaos site: hold the step long enough to trip the watchdog
        if let Some(v) = failpoint::fire("step.stall") {
            let ms = if v.is_finite() && v >= 0.0 { v } else { 2000.0 };
            std::thread::sleep(std::time::Duration::from_millis(
                ms as u64));
        }
        let lr = self.cfg.lr.at(self.step - 1) * self.lr_scale;
        self.backend.step(self.step, lr)
    }

    /// Train for `cfg.iters` steps (or until eps convergence), healing
    /// divergence along the way per the [`RecoveryPolicy`] (module
    /// docs describe the rollback protocol).
    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let mut timer = StepTimer::new();
        let mut last = StepStats {
            loss: f64::NAN,
            var_loss: f64::NAN,
            bd_loss: f64::NAN,
            extra: 0.0,
            grad_norm: 0.0,
        };
        let mut converged_early = false;
        let mut saved_at = None;
        let inverse = self.backend.loss_kind() == "inverse_const";
        let start = self.step;
        let target = start + self.cfg.iters;
        let mut recoveries: Vec<RecoveryEvent> = Vec::new();
        // Step the latest rollback landed on; drives the backoff
        // anneal (lr_restore_after clean steps -> scale back to 1.0).
        let mut last_rollback: Option<usize> = None;
        // In-memory rollback point. Healing needs a backend that can
        // snapshot itself; ones that can't (export_checkpoint errors)
        // keep the legacy abort-on-divergence behavior.
        let mut snapshot = if self.recovery.snapshot_every > 0 {
            self.checkpoint().ok()
        } else {
            None
        };
        let heal = snapshot.is_some();
        let watchdog = match self.recovery.watchdog_ms {
            0 => None,
            ms => Some(Watchdog::spawn(ms)),
        };
        while self.step < target {
            // telemetry wall clock: a separate Instant captured only
            // when armed (StepTimer keeps its samples private), so the
            // disarmed loop pays one relaxed load per step and nothing
            // else
            let t_ev = crate::telemetry::armed()
                .then(std::time::Instant::now);
            timer.start();
            if let Some(w) = &watchdog {
                w.begin(self.step as u64 + 1);
            }
            last = self.step_once()?;
            if let Some(w) = &watchdog {
                w.end();
            }
            timer.stop();
            if let Some(t0) = t_ev {
                // emitted before the divergence sentinel so a poisoned
                // step appears in the stream (loss: null) immediately
                // ahead of its recovery event
                crate::telemetry::emit(
                    crate::telemetry::Event::StepStats {
                        step: self.step as u64,
                        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                        phases_ms: crate::telemetry::take_phase_ms(),
                        loss: last.loss,
                        grad_norm: last.grad_norm,
                        lr: self.cfg.lr.at(self.step - 1)
                            * self.lr_scale,
                    },
                );
            }

            // ---- divergence sentinel
            let limit = self.recovery.grad_norm_limit;
            let trouble = if !last.loss.is_finite() {
                Some(format!("non-finite loss {}", last.loss))
            } else if heal && !last.grad_norm.is_finite() {
                Some(format!("non-finite grad norm {}", last.grad_norm))
            } else if heal && limit > 0.0 && last.grad_norm > limit {
                Some(format!("grad norm {:.3e} above limit {:.3e}",
                             last.grad_norm, limit))
            } else {
                None
            };
            if let Some(reason) = trouble {
                if !heal {
                    bail!("loss diverged to {} at step {}",
                          last.loss, self.step);
                }
                let snap = snapshot.as_ref().ok_or_else(|| {
                    anyhow!("healing enabled without a snapshot")
                })?;
                ensure!(
                    recoveries.len() < self.recovery.max_recoveries,
                    "training diverged ({reason}) at step {} and the \
                     recovery budget ({}) is exhausted",
                    self.step,
                    self.recovery.max_recoveries
                );
                // Roll back: restore parameters from the snapshot but
                // RESET the Adam moments — they were accumulated on
                // the doomed trajectory, and replaying with them warm
                // invites the same blow-up.
                let mut restore = snap.clone();
                restore.adam_m.fill(0.0);
                restore.adam_v.fill(0.0);
                self.backend.restore_checkpoint(&restore)?;
                self.lr_scale *= self.recovery.lr_backoff;
                eprintln!(
                    "recovery[{}/{}]: {} at step {} -> rolled back to \
                     step {}, Adam moments reset, lr scale {:.3e}",
                    recoveries.len() + 1,
                    self.recovery.max_recoveries,
                    reason,
                    self.step,
                    snap.step,
                    self.lr_scale
                );
                crate::telemetry::emit(
                    crate::telemetry::Event::Recovery {
                        at_step: self.step as u64,
                        rollback_to: snap.step as u64,
                        reason: reason.clone(),
                        lr_scale: self.lr_scale,
                    },
                );
                recoveries.push(RecoveryEvent {
                    at_step: self.step,
                    rollback_to: snap.step,
                    reason,
                    lr_scale: self.lr_scale,
                });
                self.step = snap.step;
                last_rollback = Some(snap.step);
                continue;
            }
            // The backoff is transient: enough clean steps since the
            // rollback and the divergence is judged a one-off — the
            // tail of the run should train at the designed rate.
            if let Some(rb) = last_rollback {
                let after = self.recovery.lr_restore_after;
                if after > 0 && self.lr_scale < 1.0
                    && self.step - rb >= after
                {
                    self.lr_scale = 1.0;
                    last_rollback = None;
                    eprintln!(
                        "recovery: {after} clean steps since the \
                         rollback — lr scale restored to 1.0"
                    );
                }
            }

            let i = self.step - start - 1;
            let log = self.cfg.log_every.max(1);
            if i % log == 0 || self.step == target {
                self.history.push(HistoryRow {
                    step: self.step,
                    loss: last.loss,
                    var_loss: last.var_loss,
                    bd_loss: last.bd_loss,
                    extra: last.extra,
                    step_ms: timer.summary().median,
                });
            }
            let every = self.ckpt.as_ref().map_or(0, |p| p.every);
            if every > 0 && self.step % every == 0 {
                self.save_checkpoint(last.loss)?;
                saved_at = Some(self.step);
            }
            if heal && self.step % self.recovery.snapshot_every == 0 {
                snapshot = Some(self.checkpoint()?);
            }
            if let Some((tgt, tol)) = self.cfg.eps_converge {
                if inverse && (last.extra - tgt).abs() < tol {
                    converged_early = true;
                    break;
                }
            }
        }
        let stalls = watchdog.as_ref().map_or(0, |w| w.stalls());
        drop(watchdog); // joins the monitor thread
        // final save, unless the last periodic save already covered
        // this exact step
        if self.ckpt.is_some() && saved_at != Some(self.step) {
            self.save_checkpoint(last.loss)?;
        }
        Ok(TrainReport {
            steps: self.step,
            final_loss: last.loss,
            final_var_loss: last.var_loss,
            final_bd_loss: last.bd_loss,
            median_step_ms: timer.summary().median,
            total_seconds: t0.elapsed().as_secs_f64(),
            eps_final: if inverse { Some(last.extra) } else { None },
            converged_early,
            best_metric: if self.ckpt.is_some()
                && self.best_metric.is_finite()
            {
                Some(self.best_metric)
            } else {
                None
            },
            recoveries,
            stalls,
        })
    }

    /// Predict u (head 0) at arbitrary points.
    pub fn predict(&self, points: &[[f64; 2]]) -> Result<Vec<f32>> {
        let mut heads = self.backend.predict(points)?;
        anyhow::ensure!(!heads.is_empty(), "backend returned no heads");
        Ok(heads.swap_remove(0))
    }

    /// Predict all heads (u, eps for two-head inverse networks).
    pub fn predict_heads(&self, points: &[[f64; 2]])
        -> Result<Vec<Vec<f32>>> {
        self.backend.predict(points)
    }

    /// Predict the trainable eps *field* (two-head inverse-space
    /// networks). Prefers the backend's dedicated
    /// [`Backend::predict_eps_field`]; falls back to head 1 of
    /// `predict` for backends that only expose the field as a second
    /// output head (AOT two-head artifacts).
    pub fn predict_eps_field(&self, points: &[[f64; 2]])
        -> Result<Vec<f32>> {
        if let Some(eps) = self.backend.predict_eps_field(points)? {
            return Ok(eps);
        }
        let mut heads = self.backend.predict(points)?;
        anyhow::ensure!(
            heads.len() >= 2,
            "backend {} ({}) has no eps field head",
            self.backend.name(), self.backend.loss_kind()
        );
        Ok(heads.swap_remove(1))
    }

    /// Evaluate error norms against a reference on given points.
    pub fn evaluate(&self, points: &[[f64; 2]], reference: &[f64])
        -> Result<ErrorNorms> {
        let pred = self.predict(points)?;
        ErrorNorms::compute_f32(&pred, reference)
    }
}

/// Warn-only stall monitor: a background thread watching the step the
/// coordinator is currently executing and shouting (once per step)
/// when it exceeds the configured wall-clock limit. It never kills
/// anything — a stalled step may be a slow allocator, a swapping
/// machine, or the `step.stall` failpoint — it just makes the stall
/// visible and countable in [`TrainReport::stalls`].
struct Watchdog {
    shared: Arc<WatchdogShared>,
    t0: std::time::Instant,
    handle: Option<std::thread::JoinHandle<()>>,
}

#[derive(Default)]
struct WatchdogShared {
    /// Step currently executing (0 = coordinator is between steps).
    seq: AtomicU64,
    /// Milliseconds since watchdog start when that step began.
    began_ms: AtomicU64,
    /// Steps that exceeded the limit.
    stalls: AtomicU64,
    stop: AtomicBool,
}

impl Watchdog {
    fn spawn(limit_ms: u64) -> Watchdog {
        let shared = Arc::new(WatchdogShared::default());
        let t0 = std::time::Instant::now();
        let s = Arc::clone(&shared);
        let handle = std::thread::spawn(move || {
            let poll = std::time::Duration::from_millis(
                (limit_ms / 4).clamp(5, 250));
            let mut warned = 0u64;
            while !s.stop.load(Ordering::Relaxed) {
                std::thread::sleep(poll);
                let seq = s.seq.load(Ordering::Relaxed);
                if seq == 0 || seq == warned {
                    continue;
                }
                let began = s.began_ms.load(Ordering::Relaxed);
                let now = t0.elapsed().as_millis() as u64;
                if now.saturating_sub(began) > limit_ms {
                    warned = seq;
                    s.stalls.fetch_add(1, Ordering::Relaxed);
                    eprintln!(
                        "watchdog: step {} has been running {} ms \
                         (limit {} ms)",
                        seq, now - began, limit_ms);
                }
            }
        });
        Watchdog { shared, t0, handle: Some(handle) }
    }

    fn begin(&self, step: u64) {
        self.shared.began_ms.store(
            self.t0.elapsed().as_millis() as u64, Ordering::Relaxed);
        self.shared.seq.store(step, Ordering::Relaxed);
    }

    fn end(&self) {
        self.shared.seq.store(0, Ordering::Relaxed);
    }

    fn stalls(&self) -> usize {
        self.shared.stalls.load(Ordering::Relaxed) as usize
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::fem::assembly;
    use crate::fem::quadrature::QuadKind;
    use crate::mesh::generators;
    use crate::problems::PoissonSin;
    use crate::runtime::backend::native::{
        NativeBackend, NativeConfig, NativeLoss,
    };

    #[test]
    fn config_defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!(c.eps_init, 2.0); // paper SS4.7.1 initial guess
        assert!(matches!(c.lr, LrSchedule::Constant(lr) if lr == 1e-3));
    }

    #[test]
    fn trainer_drives_native_backend_and_logs_history() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = PoissonSin::new(std::f64::consts::PI);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = TrainConfig {
            iters: 25,
            log_every: 5,
            ..TrainConfig::default()
        };
        let ncfg = NativeConfig {
            layers: vec![2, 8, 1],
            loss: NativeLoss::Forward,
            nb: 16,
            ns: 0,
        };
        let backend = NativeBackend::new(
            &ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
        let mut t = Trainer::new(Box::new(backend), &cfg);
        assert_eq!(t.backend_name(), "native");
        let report = t.run().unwrap();
        assert_eq!(report.steps, 25);
        assert!(report.final_loss.is_finite());
        assert!(!t.history.rows.is_empty());
        assert!(t.current_eps().is_err()); // forward problem: no eps
        let pred = t.predict(&[[0.5, 0.5]]).unwrap();
        assert_eq!(pred.len(), 1);
    }

    #[test]
    fn trainer_drives_two_head_inverse_space_backend() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = PoissonSin::new(std::f64::consts::PI);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = TrainConfig { iters: 5, ..TrainConfig::default() };
        let ncfg = NativeConfig {
            layers: vec![2, 8, 1],
            loss: NativeLoss::InverseSpace,
            nb: 16,
            ns: 8,
        };
        let backend = NativeBackend::new(
            &ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
        let mut t = Trainer::new(Box::new(backend), &cfg);
        assert_eq!(t.loss_kind(), "inverse_space");
        assert_eq!(t.history.extra_label, "sensor_loss");
        t.run().unwrap();
        assert!(t.current_eps().is_err()); // field, not a scalar
        let pts = [[0.5, 0.5], [0.2, 0.8]];
        let heads = t.predict_heads(&pts).unwrap();
        assert_eq!(heads.len(), 2, "u and eps heads");
        let eps = t.predict_eps_field(&pts).unwrap();
        assert_eq!(eps.len(), 2);
        assert_eq!(eps, heads[1]);
        assert!(eps.iter().all(|&e| e > 0.0), "softplus positivity");
    }

    #[test]
    fn checkpoint_policy_writes_periodic_final_and_best() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = PoissonSin::new(std::f64::consts::PI);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = TrainConfig { iters: 25, ..TrainConfig::default() };
        let ncfg = NativeConfig {
            layers: vec![2, 8, 1],
            loss: NativeLoss::Forward,
            nb: 16,
            ns: 0,
        };
        let backend = NativeBackend::new(
            &ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
        let mut t = Trainer::new(Box::new(backend), &cfg);
        let path = std::env::temp_dir().join(format!(
            "fastvpinns_trainer_policy_{}.ckpt",
            std::process::id()
        ));
        let best = {
            let mut b = path.clone().into_os_string();
            b.push(".best");
            std::path::PathBuf::from(b)
        };
        t.set_checkpoint_policy(CheckpointPolicy {
            path: path.clone(),
            every: 10,
            problem: "poisson_sin".into(),
            cli: vec![("n".into(), "1".into())],
        });
        let pts = vec![[0.25, 0.25], [0.5, 0.75], [0.9, 0.1]];
        let exact: Vec<f64> = pts
            .iter()
            .map(|p| problem.exact(p[0], p[1]).unwrap())
            .collect();
        t.set_validation(pts.clone(), exact);
        let report = t.run().unwrap();
        assert!(report.best_metric.is_some(), "validation metric tracked");
        let ck = Checkpoint::read(&path).unwrap();
        assert_eq!(ck.step, 25, "final save carries the step count");
        assert_eq!(ck.problem, "poisson_sin");
        assert_eq!(ck.cli, vec![("n".to_string(), "1".to_string())]);
        // the final artifact reproduces the live backend bit-for-bit
        let net = crate::runtime::backend::native::Mlp::from_theta(
            &ck.layers, ck.two_head, ck.theta.clone()).unwrap();
        assert_eq!(net.eval(&pts), t.predict(&pts).unwrap());
        let bk = Checkpoint::read(&best).unwrap();
        assert_eq!(bk.layers, ck.layers);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&best).ok();
        for i in 0..crate::runtime::checkpoint::GENERATIONS {
            std::fs::remove_file(
                crate::runtime::checkpoint::generation_path(&path, i),
            )
            .ok();
        }
    }

    /// Delegates to a real native backend but poisons the reported
    /// stats from a chosen step until the coordinator restores a
    /// snapshot — a deterministic divergence that doesn't touch the
    /// process-global failpoint table (another test owns that).
    struct Flaky {
        inner: NativeBackend,
        /// Coordinator step to start poisoning at (`None` = done).
        fail_at: Option<usize>,
        /// Re-arm `fail_at` on restore instead of healing — models a
        /// divergence that rollback cannot fix (budget-exhaustion
        /// path).
        sticky: bool,
        corrupted: bool,
    }

    impl Backend for Flaky {
        fn name(&self) -> &'static str {
            "flaky"
        }
        fn loss_kind(&self) -> &str {
            self.inner.loss_kind()
        }
        fn step(&mut self, step: usize, lr: f64)
            -> Result<StepStats> {
            let mut s = self.inner.step(step, lr)?;
            if self.fail_at == Some(step) {
                self.corrupted = true;
                if !self.sticky {
                    self.fail_at = None;
                }
            }
            if self.corrupted {
                s.loss = f64::NAN;
                s.grad_norm = f64::NAN;
            }
            Ok(s)
        }
        fn predict(&self, points: &[[f64; 2]])
            -> Result<Vec<Vec<f32>>> {
            self.inner.predict(points)
        }
        fn export_checkpoint(&self) -> Result<Checkpoint> {
            self.inner.export_checkpoint()
        }
        fn restore_checkpoint(&mut self, ck: &Checkpoint)
            -> Result<()> {
            self.corrupted = false;
            self.inner.restore_checkpoint(ck)
        }
    }

    fn flaky_backend(fail_at: usize, sticky: bool) -> Flaky {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = PoissonSin::new(std::f64::consts::PI);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let ncfg = NativeConfig {
            layers: vec![2, 8, 1],
            loss: NativeLoss::Forward,
            nb: 16,
            ns: 0,
        };
        let inner = NativeBackend::new(
            &ncfg, &src, &BackendOpts::default()).unwrap();
        Flaky { inner, fail_at: Some(fail_at), sticky, corrupted: false }
    }

    #[test]
    fn divergence_rolls_back_and_run_completes() {
        let cfg = TrainConfig { iters: 30, ..TrainConfig::default() };
        let mut t = Trainer::new(Box::new(flaky_backend(17, false)), &cfg);
        t.set_recovery_policy(RecoveryPolicy {
            snapshot_every: 10,
            ..RecoveryPolicy::default()
        });
        let report = t.run().unwrap();
        assert_eq!(report.steps, 30, "run replays through the fault");
        assert!(report.final_loss.is_finite());
        assert_eq!(report.recoveries.len(), 1);
        let ev = &report.recoveries[0];
        assert_eq!(ev.at_step, 17);
        assert_eq!(ev.rollback_to, 10, "last clean snapshot");
        assert!(ev.reason.contains("non-finite loss"));
        assert!((ev.lr_scale - 0.5).abs() < 1e-15, "one backoff");
        assert!((t.lr_scale() - 0.5).abs() < 1e-15);
        // the rolled-back span is replayed, so steps 11..17 appear
        // twice in the history — an honest trace of what happened
        let n17 = t.history.rows.iter()
            .filter(|r| r.step == 17).count();
        assert_eq!(n17, 2);
    }

    #[test]
    fn lr_backoff_anneals_back_after_sustained_health() {
        let cfg = TrainConfig { iters: 30, ..TrainConfig::default() };
        let mut t = Trainer::new(Box::new(flaky_backend(17, false)), &cfg);
        t.set_recovery_policy(RecoveryPolicy {
            snapshot_every: 10,
            lr_restore_after: 5,
            ..RecoveryPolicy::default()
        });
        let report = t.run().unwrap();
        assert_eq!(report.steps, 30);
        assert_eq!(report.recoveries.len(), 1);
        // the event records the backed-off scale that was in effect
        assert!((report.recoveries[0].lr_scale - 0.5).abs() < 1e-15);
        // rollback lands on step 10 and the replay is clean, so the
        // 5th clean step (15) anneals the scale back to 1.0 and it
        // stays there through the end of the run
        assert!((t.lr_scale() - 1.0).abs() < 1e-15,
                "backoff not annealed: {}", t.lr_scale());
    }

    #[test]
    fn unfixable_divergence_exhausts_the_recovery_budget() {
        let cfg = TrainConfig { iters: 30, ..TrainConfig::default() };
        let mut t = Trainer::new(Box::new(flaky_backend(17, true)), &cfg);
        t.set_recovery_policy(RecoveryPolicy {
            snapshot_every: 10,
            max_recoveries: 2,
            ..RecoveryPolicy::default()
        });
        let err = t.run().unwrap_err().to_string();
        assert!(err.contains("recovery budget (2) is exhausted"),
                "got: {err}");
    }

    #[test]
    fn healing_disabled_keeps_the_legacy_abort() {
        let cfg = TrainConfig { iters: 30, ..TrainConfig::default() };
        let mut t = Trainer::new(Box::new(flaky_backend(17, false)), &cfg);
        t.set_recovery_policy(RecoveryPolicy {
            snapshot_every: 0,
            ..RecoveryPolicy::default()
        });
        let err = t.run().unwrap_err().to_string();
        assert!(err.contains("loss diverged"), "got: {err}");
    }

    #[test]
    fn watchdog_counts_a_stalled_step() {
        struct Slow {
            inner: Flaky,
        }
        impl Backend for Slow {
            fn name(&self) -> &'static str {
                "slow"
            }
            fn loss_kind(&self) -> &str {
                self.inner.loss_kind()
            }
            fn step(&mut self, step: usize, lr: f64)
                -> Result<StepStats> {
                if step == 2 {
                    std::thread::sleep(
                        std::time::Duration::from_millis(120));
                }
                self.inner.step(step, lr)
            }
            fn predict(&self, points: &[[f64; 2]])
                -> Result<Vec<Vec<f32>>> {
                self.inner.predict(points)
            }
            fn export_checkpoint(&self) -> Result<Checkpoint> {
                self.inner.export_checkpoint()
            }
            fn restore_checkpoint(&mut self, ck: &Checkpoint)
                -> Result<()> {
                self.inner.restore_checkpoint(ck)
            }
        }
        let cfg = TrainConfig { iters: 4, ..TrainConfig::default() };
        let slow = Slow { inner: flaky_backend(usize::MAX, false) };
        let mut t = Trainer::new(Box::new(slow), &cfg);
        t.set_recovery_policy(RecoveryPolicy {
            watchdog_ms: 40,
            ..RecoveryPolicy::default()
        });
        let report = t.run().unwrap();
        assert_eq!(report.stalls, 1, "exactly the slow step flagged");
    }
}
