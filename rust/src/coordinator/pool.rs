//! A persistent fork-join worker pool: threads are spawned once per
//! backend, parked on a condvar between ticks, and woken for each
//! phase — replacing the per-step `std::thread::scope` spawns the
//! training loop used to pay (a thread spawn + join per worker per
//! step, ~10–50 µs each, pure overhead at small step times).
//!
//! The pool runs *borrowed* jobs: [`WorkerPool::run`] takes
//! `&(dyn Fn(usize) + Sync)`, publishes the pointer to the workers,
//! and blocks until every worker has finished, which is what makes the
//! lifetime erasure sound (the closure provably outlives every use).
//! A panicking job is caught on the worker, counted, and surfaced as
//! an `Err` from `run` — the pool itself stays usable, which the
//! recovery path (rollback + replay) depends on.

use anyhow::{bail, Context, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

/// Type-erased pointer to the borrowed job closure. Send because the
/// pointee is `Sync` (shared-call only) and `run` guarantees it stays
/// alive while any worker can reach it.
struct JobPtr(*const (dyn Fn(usize) + Sync + 'static));

// SAFETY: the pointer is only dereferenced by workers between the
// epoch publish and the final `remaining` decrement, and `run` blocks
// the owning thread for exactly that window, keeping the borrowed
// closure alive. The closure itself is `Sync`, so concurrent `&self`
// calls from many workers are fine.
unsafe impl Send for JobPtr {}

struct PoolState {
    job: Option<JobPtr>,
    /// Bumped per `run` call; workers use it to detect fresh work.
    epoch: u64,
    /// Workers that have not yet finished the current job.
    remaining: usize,
    /// Jobs that panicked during the current run.
    panics: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Caller -> workers: a new job (or shutdown) was published.
    work: Condvar,
    /// Workers -> caller: `remaining` reached zero.
    done: Condvar,
}

/// Lock, riding mutex poisoning: a worker panic is already surfaced
/// through the `panics` counter, and the state machine's fields stay
/// consistent under it (every mutation is a single store).
fn ride<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Persistent fork-join pool over `n` named worker threads. Created
/// once (per [`crate::runtime::backend::native::NativeBackend`]);
/// dropped pools signal shutdown and join their threads.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers.max(1)` parked worker threads.
    pub fn new(workers: usize) -> Result<WorkerPool> {
        let n = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                job: None,
                epoch: 0,
                remaining: 0,
                panics: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(n);
        for wid in 0..n {
            let sh = Arc::clone(&shared);
            let h = std::thread::Builder::new()
                .name(format!("fvp-worker-{wid}"))
                .spawn(move || worker_loop(wid, &sh))
                .with_context(|| format!("spawn pool worker {wid}"))?;
            handles.push(h);
        }
        Ok(WorkerPool { shared, handles })
    }

    /// Worker thread count.
    pub fn n_workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `f(worker_id)` once on every worker and block until all of
    /// them return. Intended for one logical caller (the training
    /// loop); errors if any worker's job panicked.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) -> Result<()> {
        let ptr = f as *const (dyn Fn(usize) + Sync);
        // SAFETY: only the lifetime bound is erased — layout and
        // vtable are untouched. Soundness argument at `JobPtr`: this
        // function does not return until `remaining == 0`, i.e. until
        // no worker can still call through the pointer.
        let ptr: *const (dyn Fn(usize) + Sync + 'static) =
            unsafe { std::mem::transmute(ptr) };
        let mut st = ride(&self.shared.state);
        st.job = Some(JobPtr(ptr));
        st.epoch = st.epoch.wrapping_add(1);
        st.remaining = self.handles.len();
        st.panics = 0;
        self.shared.work.notify_all();
        while st.remaining > 0 {
            st = wait(&self.shared.done, st);
        }
        st.job = None;
        let panics = st.panics;
        drop(st);
        if panics > 0 {
            bail!("{panics} pool worker(s) panicked during a tick");
        }
        Ok(())
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = ride(&self.shared.state);
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            // a worker that somehow unwound is already accounted for;
            // nothing useful to do with the join result at drop time
            let _ = h.join();
        }
    }
}

fn worker_loop(wid: usize, sh: &Shared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = ride(&sh.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    if let Some(j) = &st.job {
                        seen = st.epoch;
                        break j.0;
                    }
                }
                st = wait(&sh.work, st);
            }
        };
        // SAFETY: `run` blocks until this worker (and every other)
        // decrements `remaining` below, so the borrowed closure behind
        // `job` is still alive here.
        let result =
            catch_unwind(AssertUnwindSafe(|| unsafe { (*job)(wid) }));
        let mut st = ride(&sh.state);
        if result.is_err() {
            st.panics += 1;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            sh.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_worker_runs_each_job_exactly_once() {
        let pool = WorkerPool::new(4).unwrap();
        let count = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(&|_w| {
                count.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        assert_eq!(count.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn workers_see_distinct_ids_and_borrowed_data() {
        let pool = WorkerPool::new(3).unwrap();
        let data = [3usize, 5, 7]; // borrowed stack data
        let hits: Vec<AtomicUsize> =
            (0..3).map(|_| AtomicUsize::new(0)).collect();
        pool.run(&|w| {
            hits[w].fetch_add(data[w], Ordering::Relaxed);
        })
        .unwrap();
        let got: Vec<usize> =
            hits.iter().map(|h| h.load(Ordering::Relaxed)).collect();
        assert_eq!(got, vec![3, 5, 7]);
    }

    #[test]
    fn a_panicking_job_errors_and_the_pool_survives() {
        let pool = WorkerPool::new(2).unwrap();
        let err = pool
            .run(&|w| {
                if w == 0 {
                    panic!("injected test panic");
                }
            })
            .unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        // the pool keeps working after a failed tick
        let count = AtomicUsize::new(0);
        pool.run(&|_w| {
            count.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(count.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn cursor_claim_pattern_covers_every_shard_once() {
        // the exact shape the backend's Step/Reduce phases use
        let pool = WorkerPool::new(4).unwrap();
        let hits: Vec<AtomicUsize> =
            (0..33).map(|_| AtomicUsize::new(0)).collect();
        let cursor = AtomicUsize::new(0);
        pool.run(&|_w| loop {
            let s = cursor.fetch_add(1, Ordering::Relaxed);
            if s >= hits.len() {
                break;
            }
            hits[s].fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "shard {i}");
        }
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let pool = WorkerPool::new(0).unwrap();
        assert_eq!(pool.n_workers(), 1);
        pool.run(&|w| assert_eq!(w, 0)).unwrap();
    }
}
