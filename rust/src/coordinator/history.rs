//! Training event log: per-step losses and timings, dumped as CSV.

use std::path::Path;

use anyhow::Result;

use crate::util::csv::CsvWriter;

/// One logged training step.
#[derive(Debug, Clone, Copy)]
pub struct HistoryRow {
    /// 1-based optimizer step.
    pub step: usize,
    /// Total objective.
    pub loss: f64,
    /// Variational component.
    pub var_loss: f64,
    /// Dirichlet-penalty component.
    pub bd_loss: f64,
    /// Sensor loss or eps, experiment-dependent.
    pub extra: f64,
    /// Median step wall-clock so far (ms).
    pub step_ms: f64,
}

/// The per-run step log, dumped as CSV by `--history`.
#[derive(Debug, Default, Clone)]
pub struct TrainHistory {
    /// Logged rows, in step order.
    pub rows: Vec<HistoryRow>,
    /// semantic label of `extra` ("", "sensor_loss", "eps", ...)
    pub extra_label: String,
}

impl TrainHistory {
    /// Append a row.
    pub fn push(&mut self, row: HistoryRow) {
        self.rows.push(row);
    }

    /// Total loss of the most recent row.
    pub fn last_loss(&self) -> Option<f64> {
        self.rows.last().map(|r| r.loss)
    }

    /// Dump all rows as CSV (header derived from the extra label).
    pub fn to_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let extra = if self.extra_label.is_empty() {
            "extra"
        } else {
            &self.extra_label
        };
        let mut w = CsvWriter::create(
            path,
            &["step", "loss", "var_loss", "bd_loss", extra, "step_ms"],
        )?;
        for r in &self.rows {
            w.row_f64(&[r.step as f64, r.loss, r.var_loss, r.bd_loss,
                        r.extra, r.step_ms])?;
        }
        w.flush()
    }

    /// Median step time over the recorded rows (paper protocol).
    pub fn median_step_ms(&self) -> f64 {
        crate::util::stats::median(
            &self.rows.iter().map(|r| r.step_ms).collect::<Vec<_>>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let mut h = TrainHistory { extra_label: "eps".into(),
                                   ..Default::default() };
        h.push(HistoryRow { step: 1, loss: 10.0, var_loss: 9.0,
                            bd_loss: 1.0, extra: 2.0, step_ms: 1.5 });
        h.push(HistoryRow { step: 2, loss: 5.0, var_loss: 4.5,
                            bd_loss: 0.5, extra: 1.5, step_ms: 1.4 });
        let p = std::env::temp_dir().join("fastvpinns_hist.csv");
        h.to_csv(&p).unwrap();
        let rows = crate::util::csv::read_simple(&p).unwrap();
        assert_eq!(rows[0][4], "eps");
        assert_eq!(rows.len(), 3);
        assert_eq!(h.last_loss(), Some(5.0));
        assert!((h.median_step_ms() - 1.45).abs() < 1e-12);
    }
}
