//! The tick-based coordinator state machine and the explicit,
//! cost-aware shard plan for the native training step.
//!
//! Modeled on Psyche's Coordinator loop: every optimizer step is one
//! *tick* through four phases — `AssignShards → Step → Reduce → Sync`.
//! [`Tick`] enforces the phase order; [`ShardPlan`] decides *what* each
//! phase operates on.
//!
//! The determinism keystone: the plan is derived **only** from the
//! element count, the quadrature order and the block size — never from
//! the worker count. Workers claim shards off a cursor, but results
//! are keyed by shard, and the [`n_pairs`]/[`pair`] tree reduce merges
//! the per-shard partials along a binary tree whose shape depends only
//! on the shard count. Floating-point addition is not associative, so
//! "same summation tree" is exactly the property that makes per-step
//! losses bit-identical for *any* `--workers` value.

use anyhow::{ensure, Result};

/// Upper bound on shards per plan. Small enough that the per-shard
/// gradient accumulators stay cache-friendly (64 × n_params doubles),
/// large enough to feed every realistic worker count with several
/// shards of work for load balancing.
pub const MAX_SHARDS: usize = 64;

/// One phase of a coordinator tick, in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Reset the per-shard accumulators the workers will claim.
    AssignShards,
    /// Workers pull shards off a shared cursor and compute partials.
    Step,
    /// Pairwise tree reduce of the per-shard partials into shard 0.
    Reduce,
    /// Fold the root into the flat gradient; penalties + step stats.
    Sync,
}

impl Phase {
    /// The phase that must follow `self` (`Sync` wraps to
    /// `AssignShards`, starting the next tick).
    pub fn next(self) -> Phase {
        match self {
            Phase::AssignShards => Phase::Step,
            Phase::Step => Phase::Reduce,
            Phase::Reduce => Phase::Sync,
            Phase::Sync => Phase::AssignShards,
        }
    }
}

impl Default for Phase {
    fn default() -> Phase {
        Phase::AssignShards
    }
}

/// Phase-order guard for the coordinator loop: each phase must be
/// entered via [`Tick::begin`] in the fixed order, and a completed
/// `Sync` increments the tick counter. A skipped or repeated phase is
/// a coordinator bug and errors instead of silently corrupting the
/// reduction.
#[derive(Debug, Clone, Copy, Default)]
pub struct Tick {
    phase: Phase,
    ticks: u64,
}

impl Tick {
    /// Enter phase `p`. Errors unless `p` is the expected next phase.
    pub fn begin(&mut self, p: Phase) -> Result<()> {
        ensure!(
            p == self.phase,
            "coordinator tick out of order: expected {:?}, got {:?}",
            self.phase,
            p
        );
        self.phase = p.next();
        if p == Phase::Sync {
            self.ticks += 1;
        }
        Ok(())
    }

    /// The phase the next [`Tick::begin`] must name.
    pub fn expected(&self) -> Phase {
        self.phase
    }

    /// Completed ticks (== optimizer steps driven through the plan).
    pub fn ticks(&self) -> u64 {
        self.ticks
    }
}

/// One contiguous run of elements, aligned to the global block grid
/// (`lo % block_elems == 0`), with its quadrature-point cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// First element (inclusive).
    pub lo: usize,
    /// Last element (exclusive).
    pub hi: usize,
    /// Cost weight: quadrature points in the shard.
    pub weight: usize,
}

/// A step-invariant partition of the element range into up to
/// [`MAX_SHARDS`] contiguous, block-aligned shards, weight-balanced by
/// quadrature-point count (the ragged tail block is genuinely
/// lighter). Built once at backend construction; a function of
/// `(ne, nq, block_elems)` and nothing else.
#[derive(Debug, Clone, Default)]
pub struct ShardPlan {
    shards: Vec<Shard>,
}

impl ShardPlan {
    /// Partition `ne` elements (each carrying `nq` quadrature points,
    /// tiled into blocks of `block_elems`) into weight-balanced
    /// shards. Greedy over blocks: each shard takes whole blocks until
    /// it reaches `ceil(remaining_weight / remaining_shards)`, while
    /// always leaving at least one block per remaining shard.
    pub fn build(ne: usize, nq: usize, block_elems: usize) -> ShardPlan {
        let be = block_elems.max(1);
        let nq = nq.max(1);
        let n_blocks = ne.div_ceil(be);
        let n_shards = n_blocks.min(MAX_SHARDS);
        let block_w = |b: usize| -> usize {
            let lo = b * be;
            let hi = ((b + 1) * be).min(ne);
            (hi - lo) * nq
        };
        let mut remaining: usize = ne * nq;
        let mut shards = Vec::with_capacity(n_shards);
        let mut b = 0;
        for s in 0..n_shards {
            let left = n_shards - s;
            let target = remaining.div_ceil(left);
            let max_b = n_blocks - (left - 1);
            let lo_blk = b;
            let mut w = 0;
            while b < max_b && w < target {
                w += block_w(b);
                b += 1;
            }
            remaining -= w;
            shards.push(Shard {
                lo: lo_blk * be,
                hi: (b * be).min(ne),
                weight: w,
            });
        }
        ShardPlan { shards }
    }

    /// Number of shards in the plan (0 only for an empty domain).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard `s` by plan order.
    pub fn shard(&self, s: usize) -> Shard {
        self.shards[s]
    }

    /// All shards, in plan (= element) order.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }
}

/// Number of merge pairs at tree level `stride` for `n` shards. The
/// levels run `stride = 1, 2, 4, ...` while `stride < n`; within a
/// level, pair `k` merges shard `2*stride*k + stride` into shard
/// `2*stride*k`. Pairs within a level touch disjoint shards, so
/// workers may process them in any order without changing a bit; the
/// tree shape depends only on `n`.
pub fn n_pairs(n: usize, stride: usize) -> usize {
    if n > stride {
        (n - 1 - stride) / (2 * stride) + 1
    } else {
        0
    }
}

/// The (destination, source) shard indices of pair `k` at level
/// `stride` — see [`n_pairs`] for the tree layout.
pub fn pair(stride: usize, k: usize) -> (usize, usize) {
    (2 * stride * k, 2 * stride * k + stride)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tick_enforces_the_phase_order() {
        let mut t = Tick::default();
        assert_eq!(t.expected(), Phase::AssignShards);
        assert!(t.begin(Phase::Step).is_err());
        t.begin(Phase::AssignShards).unwrap();
        assert!(t.begin(Phase::Sync).is_err());
        t.begin(Phase::Step).unwrap();
        t.begin(Phase::Reduce).unwrap();
        assert_eq!(t.ticks(), 0);
        t.begin(Phase::Sync).unwrap();
        assert_eq!(t.ticks(), 1);
        // the next tick starts over
        assert_eq!(t.expected(), Phase::AssignShards);
        t.begin(Phase::AssignShards).unwrap();
    }

    fn check_plan(ne: usize, nq: usize, be: usize) {
        let plan = ShardPlan::build(ne, nq, be);
        let n_blocks = ne.div_ceil(be.max(1));
        assert_eq!(plan.n_shards(), n_blocks.min(MAX_SHARDS),
                   "ne={ne} nq={nq} be={be}");
        // contiguous cover of [0, ne), block-aligned starts, weights
        // that sum to the total quadrature cost
        let mut next = 0;
        let mut total_w = 0;
        for sh in plan.shards() {
            assert_eq!(sh.lo, next, "gap/overlap at {}", sh.lo);
            assert!(sh.hi > sh.lo, "empty shard");
            assert_eq!(sh.lo % be.max(1), 0, "unaligned shard start");
            assert_eq!(sh.weight, (sh.hi - sh.lo) * nq.max(1));
            next = sh.hi;
            total_w += sh.weight;
        }
        assert_eq!(next, ne);
        assert_eq!(total_w, ne * nq.max(1));
        // balanced: a shard stops taking blocks the moment it reaches
        // its running target, so no shard exceeds the ideal mean by a
        // full block's weight (at most one block minus one point of
        // overshoot; the min side is unbounded by design — the tail
        // shard takes whatever is left). Verified over ~16k shapes in
        // python/proto_shard_plan.py.
        if plan.n_shards() > 0 {
            let ideal = (ne * nq.max(1)).div_ceil(plan.n_shards());
            let max = plan.shards().iter().map(|s| s.weight).max();
            assert!(
                max.unwrap() <= ideal + be.max(1) * nq.max(1) - 1,
                "unbalanced plan ne={ne} nq={nq} be={be}: {plan:?}"
            );
        }
    }

    #[test]
    fn plans_cover_balance_and_align_across_shapes() {
        for ne in [1, 2, 3, 5, 9, 64, 65, 100, 4096, 100_000] {
            for be in [1, 2, 7, 28, 256] {
                for nq in [1, 9, 100] {
                    check_plan(ne, nq, be);
                }
            }
        }
    }

    #[test]
    fn ragged_tail_block_is_lighter() {
        // ne=9, be=2: blocks of 2,2,2,2,1 elements — the plan sees the
        // true quadrature cost, so the last shard carries the light
        // tail
        let plan = ShardPlan::build(9, 4, 2);
        assert_eq!(plan.n_shards(), 5);
        let w: Vec<usize> =
            plan.shards().iter().map(|s| s.weight).collect();
        assert_eq!(w, vec![8, 8, 8, 8, 4]);
    }

    #[test]
    fn empty_domain_yields_an_empty_plan() {
        assert_eq!(ShardPlan::build(0, 9, 4).n_shards(), 0);
    }

    #[test]
    fn tree_reduce_covers_every_shard_exactly_once() {
        for n in 1..=70usize {
            let mut parts: Vec<u64> = (0..n as u64).map(|i| i + 1).collect();
            let want: u64 = parts.iter().sum();
            let mut stride = 1;
            while stride < n {
                let mut seen = vec![false; n];
                for k in 0..n_pairs(n, stride) {
                    let (a, b) = pair(stride, k);
                    assert!(a < b && b < n, "bad pair ({a},{b}) n={n}");
                    // disjoint within the level: any worker
                    // interleaving is safe
                    assert!(!seen[a] && !seen[b], "overlap at n={n}");
                    seen[a] = true;
                    seen[b] = true;
                    parts[a] += parts[b];
                    parts[b] = 0;
                }
                stride *= 2;
            }
            assert_eq!(parts[0], want, "tree reduce lost shards at n={n}");
        }
    }
}
