//! Learning-rate schedules. The paper uses a constant rate for the
//! square benchmarks and an exponential decay (x0.99 every 1000 iters)
//! for the gear run (SS4.6.4).

/// A learning-rate schedule, evaluated per optimizer step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// Fixed rate.
    Constant(f64),
    /// lr0 * factor^(step / every)
    ExpDecay {
        /// Initial rate.
        lr0: f64,
        /// Multiplicative decay applied every `every` steps.
        factor: f64,
        /// Decay interval in steps.
        every: usize,
    },
}

impl LrSchedule {
    /// The rate at 0-based step `step`.
    pub fn at(&self, step: usize) -> f64 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::ExpDecay { lr0, factor, every } => {
                lr0 * factor.powi((step / every.max(1)) as i32)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant() {
        let s = LrSchedule::Constant(1e-3);
        assert_eq!(s.at(0), 1e-3);
        assert_eq!(s.at(100_000), 1e-3);
    }

    #[test]
    fn exp_decay_paper_gear() {
        // x0.99 every 1000 iterations from 0.005
        let s = LrSchedule::ExpDecay { lr0: 5e-3, factor: 0.99,
                                       every: 1000 };
        assert!((s.at(0) - 5e-3).abs() < 1e-12);
        assert!((s.at(999) - 5e-3).abs() < 1e-12);
        assert!((s.at(1000) - 5e-3 * 0.99).abs() < 1e-12);
        assert!((s.at(10_000) - 5e-3 * 0.99f64.powi(10)).abs() < 1e-12);
    }

    #[test]
    fn decay_monotone() {
        let s = LrSchedule::ExpDecay { lr0: 1.0, factor: 0.9, every: 10 };
        let mut last = f64::INFINITY;
        for step in (0..100).step_by(10) {
            let lr = s.at(step);
            assert!(lr <= last);
            last = lr;
        }
    }
}
