//! Error norms between predictions and references (exact or FEM).

use anyhow::{bail, Result};

/// Standard error norms over a point set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorNorms {
    /// Mean absolute error.
    pub mae: f64,
    /// Root-mean-square error.
    pub rmse: f64,
    /// Max absolute error.
    pub linf: f64,
    /// ||pred - ref||_2 / ||ref||_2 — see [`ErrorNorms::compute`] for
    /// the identically-zero-reference degradation.
    pub rel_l2: f64,
    /// Point count.
    pub n: usize,
}

impl ErrorNorms {
    /// All norms of `pred - reference` over a point set.
    ///
    /// Errors (instead of panicking — this is CLI-reachable through
    /// `--expect-rel-l2` and the serve stats path) when the slices
    /// disagree in length.
    ///
    /// Degenerate reference: when `reference` is identically zero,
    /// `||ref||_2 = 0` and the relative norm is undefined, so `rel_l2`
    /// degrades to the **absolute** L2 norm `||pred - ref||_2`
    /// (unnormalized, not divided by n). Callers comparing against a
    /// rel-L2 bar should make sure their reference is nonzero.
    pub fn compute(pred: &[f64], reference: &[f64]) -> Result<ErrorNorms> {
        if pred.len() != reference.len() {
            bail!(
                "error-norm length mismatch: {} predictions vs {} \
                 reference values",
                pred.len(),
                reference.len()
            );
        }
        let n = pred.len();
        if n == 0 {
            return Ok(ErrorNorms { mae: 0.0, rmse: 0.0, linf: 0.0,
                                   rel_l2: 0.0, n: 0 });
        }
        let mut abs_sum = 0.0;
        let mut sq_sum = 0.0;
        let mut linf: f64 = 0.0;
        let mut ref_sq = 0.0;
        for (p, r) in pred.iter().zip(reference) {
            let d = p - r;
            abs_sum += d.abs();
            sq_sum += d * d;
            linf = linf.max(d.abs());
            ref_sq += r * r;
        }
        Ok(ErrorNorms {
            mae: abs_sum / n as f64,
            rmse: (sq_sum / n as f64).sqrt(),
            linf,
            // zero reference: fall back to the absolute L2 norm (the
            // relative norm would be 0/0) — documented on `compute`
            rel_l2: if ref_sq > 0.0 {
                (sq_sum / ref_sq).sqrt()
            } else {
                sq_sum.sqrt()
            },
            n,
        })
    }

    /// [`ErrorNorms::compute`] for f32 predictions (runtime outputs).
    pub fn compute_f32(pred: &[f32], reference: &[f64])
        -> Result<ErrorNorms> {
        let p: Vec<f64> = pred.iter().map(|&v| v as f64).collect();
        Self::compute(&p, reference)
    }
}

/// A uniform evaluation grid over a rectangle (the paper's 100x100 test
/// grid for the square problems).
pub fn eval_grid(nx: usize, ny: usize, x0: f64, y0: f64, x1: f64, y1: f64)
    -> Vec<[f64; 2]> {
    let mut out = Vec::with_capacity(nx * ny);
    for iy in 0..ny {
        for ix in 0..nx {
            out.push([
                x0 + (x1 - x0) * ix as f64 / (nx - 1).max(1) as f64,
                y0 + (y1 - y0) * iy as f64 / (ny - 1).max(1) as f64,
            ]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_error() {
        let v = vec![1.0, 2.0, 3.0];
        let e = ErrorNorms::compute(&v, &v).unwrap();
        assert_eq!(e.mae, 0.0);
        assert_eq!(e.rel_l2, 0.0);
        assert_eq!(e.linf, 0.0);
    }

    #[test]
    fn known_values() {
        let e = ErrorNorms::compute(&[1.0, 3.0], &[0.0, 0.0]).unwrap();
        assert_eq!(e.mae, 2.0);
        assert!((e.rmse - (5.0f64).sqrt()).abs() < 1e-12);
        assert_eq!(e.linf, 3.0);
    }

    /// Regression: a length mismatch used to `assert_eq!`-panic (and
    /// was CLI-reachable through `--expect-rel-l2`); it is now a
    /// recoverable error naming both lengths.
    #[test]
    fn length_mismatch_is_an_error_not_a_panic() {
        let err = ErrorNorms::compute(&[1.0, 2.0], &[1.0])
            .unwrap_err()
            .to_string();
        assert!(err.contains("2 predictions"), "{err}");
        assert!(err.contains("1 reference"), "{err}");
        assert!(ErrorNorms::compute_f32(&[1.0f32], &[]).is_err());
    }

    /// Documented degradation: with an identically-zero reference the
    /// relative norm is undefined, so `rel_l2` falls back to the
    /// *absolute* L2 norm ||pred||_2 (unnormalized).
    #[test]
    fn rel_l2_degrades_to_absolute_l2_on_zero_reference() {
        let e = ErrorNorms::compute(&[3.0, 4.0], &[0.0, 0.0]).unwrap();
        assert_eq!(e.rel_l2, 5.0); // sqrt(3^2 + 4^2), not /sqrt(n)
        assert_eq!(e.rmse, (12.5f64).sqrt());
    }

    #[test]
    fn rel_l2_scale_invariance() {
        let p = vec![1.1, 2.2, 3.3];
        let r = vec![1.0, 2.0, 3.0];
        let e1 = ErrorNorms::compute(&p, &r).unwrap();
        let p10: Vec<f64> = p.iter().map(|v| v * 10.0).collect();
        let r10: Vec<f64> = r.iter().map(|v| v * 10.0).collect();
        let e2 = ErrorNorms::compute(&p10, &r10).unwrap();
        assert!((e1.rel_l2 - e2.rel_l2).abs() < 1e-12);
    }

    #[test]
    fn grid_corners() {
        let g = eval_grid(3, 3, 0.0, 0.0, 1.0, 1.0);
        assert_eq!(g.len(), 9);
        assert_eq!(g[0], [0.0, 0.0]);
        assert_eq!(g[8], [1.0, 1.0]);
        assert_eq!(g[4], [0.5, 0.5]);
    }
}
