//! Error norms between predictions and references (exact or FEM).

/// Standard error norms over a point set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorNorms {
    /// Mean absolute error.
    pub mae: f64,
    /// Root-mean-square error.
    pub rmse: f64,
    /// Max absolute error.
    pub linf: f64,
    /// ||pred - ref||_2 / ||ref||_2
    pub rel_l2: f64,
    /// Point count.
    pub n: usize,
}

impl ErrorNorms {
    /// All norms of `pred - reference` over a point set.
    pub fn compute(pred: &[f64], reference: &[f64]) -> ErrorNorms {
        assert_eq!(pred.len(), reference.len());
        let n = pred.len();
        if n == 0 {
            return ErrorNorms { mae: 0.0, rmse: 0.0, linf: 0.0,
                                rel_l2: 0.0, n: 0 };
        }
        let mut abs_sum = 0.0;
        let mut sq_sum = 0.0;
        let mut linf: f64 = 0.0;
        let mut ref_sq = 0.0;
        for (p, r) in pred.iter().zip(reference) {
            let d = p - r;
            abs_sum += d.abs();
            sq_sum += d * d;
            linf = linf.max(d.abs());
            ref_sq += r * r;
        }
        ErrorNorms {
            mae: abs_sum / n as f64,
            rmse: (sq_sum / n as f64).sqrt(),
            linf,
            rel_l2: if ref_sq > 0.0 {
                (sq_sum / ref_sq).sqrt()
            } else {
                sq_sum.sqrt()
            },
            n,
        }
    }

    /// [`ErrorNorms::compute`] for f32 predictions (runtime outputs).
    pub fn compute_f32(pred: &[f32], reference: &[f64]) -> ErrorNorms {
        let p: Vec<f64> = pred.iter().map(|&v| v as f64).collect();
        Self::compute(&p, reference)
    }
}

/// A uniform evaluation grid over a rectangle (the paper's 100x100 test
/// grid for the square problems).
pub fn eval_grid(nx: usize, ny: usize, x0: f64, y0: f64, x1: f64, y1: f64)
    -> Vec<[f64; 2]> {
    let mut out = Vec::with_capacity(nx * ny);
    for iy in 0..ny {
        for ix in 0..nx {
            out.push([
                x0 + (x1 - x0) * ix as f64 / (nx - 1).max(1) as f64,
                y0 + (y1 - y0) * iy as f64 / (ny - 1).max(1) as f64,
            ]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_error() {
        let v = vec![1.0, 2.0, 3.0];
        let e = ErrorNorms::compute(&v, &v);
        assert_eq!(e.mae, 0.0);
        assert_eq!(e.rel_l2, 0.0);
        assert_eq!(e.linf, 0.0);
    }

    #[test]
    fn known_values() {
        let e = ErrorNorms::compute(&[1.0, 3.0], &[0.0, 0.0]);
        assert_eq!(e.mae, 2.0);
        assert!((e.rmse - (5.0f64).sqrt()).abs() < 1e-12);
        assert_eq!(e.linf, 3.0);
    }

    #[test]
    fn rel_l2_scale_invariance() {
        let p = vec![1.1, 2.2, 3.3];
        let r = vec![1.0, 2.0, 3.0];
        let e1 = ErrorNorms::compute(&p, &r);
        let p10: Vec<f64> = p.iter().map(|v| v * 10.0).collect();
        let r10: Vec<f64> = r.iter().map(|v| v * 10.0).collect();
        let e2 = ErrorNorms::compute(&p10, &r10);
        assert!((e1.rel_l2 - e2.rel_l2).abs() < 1e-12);
    }

    #[test]
    fn grid_corners() {
        let g = eval_grid(3, 3, 0.0, 0.0, 1.0, 1.0);
        assert_eq!(g.len(), 9);
        assert_eq!(g[0], [0.0, 0.0]);
        assert_eq!(g[8], [1.0, 1.0]);
        assert_eq!(g[4], [0.5, 0.5]);
    }
}
