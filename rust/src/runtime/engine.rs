//! The PJRT engine: a CPU client plus a cache of compiled executables,
//! keyed by artifact name.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;
use super::tensor::TensorData;

/// A loaded + compiled artifact.
pub struct Artifact {
    /// The JSON sidecar describing the executable's I/O.
    pub manifest: Manifest,
    /// The compiled PJRT executable.
    pub exe: xla::PjRtLoadedExecutable,
    /// Wall-clock spent compiling the HLO.
    pub compile_seconds: f64,
}

impl Artifact {
    /// Execute with the given ordered inputs; returns the decomposed
    /// output tuple as literals.
    pub fn execute(&self, inputs: &[&xla::Literal])
        -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.manifest.inputs.len(),
            "artifact {} expects {} inputs, got {}",
            self.manifest.name, self.manifest.inputs.len(), inputs.len()
        );
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.manifest.name))?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.manifest.outputs.len(),
            "artifact {} returned {} outputs, manifest says {}",
            self.manifest.name, parts.len(), self.manifest.outputs.len()
        );
        Ok(parts)
    }

    /// Execute with device-resident inputs (`PjRtBuffer`s). Avoids
    /// re-uploading step-invariant tensors (the premultiplier tensors
    /// can be hundreds of MB at paper scale) on every training step —
    /// see EXPERIMENTS.md SSPerf.
    pub fn execute_buffers(&self, inputs: &[&xla::PjRtBuffer])
        -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.manifest.inputs.len(),
            "artifact {} expects {} inputs, got {}",
            self.manifest.name, self.manifest.inputs.len(), inputs.len()
        );
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .with_context(|| format!("execute_b {}", self.manifest.name))?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.manifest.outputs.len(),
            "artifact {} returned {} outputs, manifest says {}",
            self.manifest.name, parts.len(), self.manifest.outputs.len()
        );
        Ok(parts)
    }

    /// Validate that host tensors match the manifest signature.
    pub fn check_inputs(&self, tensors: &[TensorData]) -> Result<()> {
        for (spec, t) in self.manifest.inputs.iter().zip(tensors) {
            if spec.shape != t.shape {
                bail!("input '{}' shape mismatch: manifest {:?}, got {:?}",
                      spec.name, spec.shape, t.shape);
            }
        }
        Ok(())
    }
}

/// PJRT CPU client + executable cache + artifact directory.
pub struct Engine {
    /// The PJRT CPU client artifacts execute on.
    pub client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Artifact>>>,
}

impl Engine {
    /// Create a CPU PJRT client reading artifacts from `artifact_dir`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Engine { client, dir, cache: RefCell::new(HashMap::new()) })
    }

    /// The PJRT platform name ("cpu" offline).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload a host literal to the device once (for step-invariant
    /// inputs reused across thousands of `execute_buffers` calls).
    pub fn to_buffer(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow::anyhow!("host->device upload: {e:?}"))
    }

    /// The directory artifacts are loaded from.
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Names of all artifacts present in the directory (manifest files).
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("read {}", self.dir.display()))? {
            let p = entry?.path();
            if p.extension().map(|e| e == "json").unwrap_or(false) {
                if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                    if stem != "index" {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// Load (and compile) an artifact; cached by name.
    pub fn load(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let man_path = self.dir.join(format!("{name}.json"));
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        if !man_path.exists() || !hlo_path.exists() {
            bail!(
                "artifact '{name}' not found under {} — run `make \
                 artifacts` (or `python -m compile.aot --name {name}`)",
                self.dir.display()
            );
        }
        let manifest = Manifest::load(&man_path)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| anyhow::anyhow!("parse {name} HLO: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        let art = Rc::new(Artifact {
            manifest,
            exe,
            compile_seconds: t0.elapsed().as_secs_f64(),
        });
        self.cache.borrow_mut().insert(name.to_string(), art.clone());
        Ok(art)
    }

    /// Evaluate a predict artifact at arbitrary points: pads/chunks to the
    /// artifact's static n_eval and returns one Vec<f32> per output head.
    pub fn predict(
        &self,
        predict_name: &str,
        params: &[xla::Literal],
        points: &[[f64; 2]],
    ) -> Result<Vec<Vec<f32>>> {
        let art = self.load(predict_name)?;
        anyhow::ensure!(art.manifest.kind == "predict",
                        "{predict_name} is not a predict artifact");
        let n_eval = art.manifest.config.n_eval;
        let heads = art.manifest.config.heads.max(1);
        let n_params = art.manifest.inputs.len() - 1;
        anyhow::ensure!(params.len() >= n_params,
                        "predict needs {n_params} param arrays");
        let mut outs: Vec<Vec<f32>> =
            (0..heads).map(|_| Vec::with_capacity(points.len())).collect();
        for chunk in points.chunks(n_eval) {
            let mut xy = vec![0.0f32; n_eval * 2];
            for (i, p) in chunk.iter().enumerate() {
                xy[2 * i] = p[0] as f32;
                xy[2 * i + 1] = p[1] as f32;
            }
            let xy_lit = TensorData::new(vec![n_eval, 2], xy)?.to_literal()?;
            let mut inputs: Vec<&xla::Literal> =
                params[..n_params].iter().collect();
            inputs.push(&xy_lit);
            let result = art.execute(&inputs)?;
            for h in 0..heads {
                let vals = result[h].to_vec::<f32>()?;
                outs[h].extend_from_slice(&vals[..chunk.len()]);
            }
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests that need real artifacts live in
    //! rust/tests/integration.rs (skipped when artifacts/ is absent);
    //! here we only test the filesystem surface.
    use super::*;

    #[test]
    fn missing_artifact_is_helpful_error() {
        let dir = std::env::temp_dir().join("fastvpinns_empty_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let eng = Engine::new(&dir).unwrap();
        let err = match eng.load("nope") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn list_empty_dir() {
        let dir = std::env::temp_dir().join("fastvpinns_empty_artifacts2");
        std::fs::create_dir_all(&dir).unwrap();
        let eng = Engine::new(&dir).unwrap();
        assert!(eng.list().unwrap().is_empty());
    }

    #[test]
    fn cpu_client_boots() {
        let dir = std::env::temp_dir();
        let eng = Engine::new(dir).unwrap();
        assert!(!eng.platform().is_empty());
    }
}
