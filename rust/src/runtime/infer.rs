//! Batched inference over persisted checkpoints: the serve-trained-
//! models half of the amortized-inference story. Training a VPINN is
//! the expensive part; once trained, evaluating it at arbitrary points
//! is a few small GEMMs per batch — this module makes that a
//! first-class path (`repro infer` on the CLI) instead of something
//! only the training process could do.
//!
//! An [`InferenceSession`] rebuilds the network (both heads of a
//! two-head inverse-space model) from a
//! [`Checkpoint`](super::checkpoint::Checkpoint) and answers
//! point-cloud queries through the *same* blocked-GEMM forward path
//! training uses ([`Mlp::eval_heads_with`]) — points are batched into
//! blocks and each layer is one cache-blocked GEMM plus a fused
//! bias/tanh epilogue, never a per-point scalar loop. Because the
//! checkpoint stores raw `f64` parameter bits, a session's predictions
//! are bit-identical to the exporting backend's.
//!
//! The session owns a reusable scratch allocation, so steady-state
//! query traffic performs no per-batch setup beyond the output
//! vectors. `repro bench` tracks the resulting throughput (points/sec
//! at batch sizes 1, 256 and 4096).

use std::path::Path;

use anyhow::{Context, Result};

use super::backend::native::{EvalScratch, Mlp};
use super::checkpoint::Checkpoint;

/// A loaded model ready to answer batched point queries. Build with
/// [`InferenceSession::open`] (from a file) or
/// [`InferenceSession::from_checkpoint`] (from a parsed artifact).
pub struct InferenceSession {
    net: Mlp,
    scratch: EvalScratch,
    /// Registry problem id from the artifact ("" for manual exports).
    pub problem: String,
    /// Problem instance label (e.g. `helmholtz_k6.283`).
    pub problem_label: String,
    /// Loss family the model was trained on.
    pub loss_kind: String,
    /// Optimizer step count at export.
    pub step: usize,
    /// Training-domain bounding box `[x0, y0, x1, y1]` — the region
    /// the model was fit on (useful for building query grids; the
    /// network extrapolates beyond it at the caller's own risk).
    pub bbox: [f64; 4],
}

impl InferenceSession {
    /// Build a session from a parsed artifact.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<InferenceSession> {
        let net =
            Mlp::from_theta(&ck.layers, ck.two_head, ck.theta.clone())
                .context("checkpoint network does not reconstruct")?;
        let scratch = EvalScratch::new(&net);
        Ok(InferenceSession {
            net,
            scratch,
            problem: ck.problem.clone(),
            problem_label: ck.problem_label.clone(),
            loss_kind: ck.loss_kind.clone(),
            step: ck.step,
            bbox: ck.fingerprint.bbox,
        })
    }

    /// Read an artifact from disk and build a session from it.
    pub fn open(path: impl AsRef<Path>) -> Result<InferenceSession> {
        InferenceSession::from_checkpoint(&Checkpoint::read(path)?)
    }

    /// Whether the model carries an eps field head (two-head
    /// inverse-space networks).
    pub fn two_head(&self) -> bool {
        self.net.two_head()
    }

    /// The reconstructed network (e.g. for custom evaluation drivers).
    pub fn network(&self) -> &Mlp {
        &self.net
    }

    /// Evaluate the model over a query point cloud: `(u, eps)` with
    /// `eps = Some(field)` for two-head models. Batched through the
    /// blocked-GEMM forward path; reuses the session's scratch, so
    /// repeated calls allocate only the output vectors.
    pub fn eval(&mut self, points: &[[f64; 2]])
        -> (Vec<f32>, Option<Vec<f32>>) {
        self.net.eval_heads_with(points, &mut self.scratch)
    }

    /// [`InferenceSession::eval`], u head only.
    pub fn eval_u(&mut self, points: &[[f64; 2]]) -> Vec<f32> {
        self.eval(points).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::{DataSource, TrainConfig, Trainer};
    use crate::fem::assembly;
    use crate::fem::quadrature::QuadKind;
    use crate::mesh::generators;
    use crate::problems::InverseSpaceSin;
    use crate::runtime::backend::native::{
        NativeBackend, NativeConfig, NativeLoss,
    };
    use crate::runtime::backend::BackendOpts;

    #[test]
    fn session_reproduces_trained_two_head_backend_bitwise() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = InverseSpaceSin;
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = TrainConfig { iters: 12, ..TrainConfig::default() };
        let ncfg = NativeConfig {
            layers: vec![2, 6, 1],
            loss: NativeLoss::InverseSpace,
            nb: 16,
            ns: 8,
        };
        let backend = NativeBackend::new(
            &ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
        let mut t = Trainer::new(Box::new(backend), &cfg);
        t.run().unwrap();
        let ck = t.checkpoint().unwrap();
        // through the on-disk bytes, not just the in-memory struct
        let ck = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        let mut sess = InferenceSession::from_checkpoint(&ck).unwrap();
        assert!(sess.two_head());
        assert_eq!(sess.step, 12);
        let pts: Vec<[f64; 2]> = (0..137)
            .map(|i| {
                let s = i as f64 / 136.0;
                [s, (1.7 * s).fract()]
            })
            .collect();
        let (u, eps) = sess.eval(&pts);
        let heads = t.predict_heads(&pts).unwrap();
        assert_eq!(u, heads[0], "u head must be bit-identical");
        assert_eq!(eps.as_deref(), Some(&heads[1][..]),
                   "eps head must be bit-identical");
        // repeated queries reuse the scratch and stay identical
        let (u2, _) = sess.eval(&pts);
        assert_eq!(u, u2);
    }
}
