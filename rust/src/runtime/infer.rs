//! Batched inference over persisted checkpoints: the serve-trained-
//! models half of the amortized-inference story. Training a VPINN is
//! the expensive part; once trained, evaluating it at arbitrary points
//! is a few small GEMMs per batch — this module makes that a
//! first-class path (`repro infer` on the CLI) instead of something
//! only the training process could do.
//!
//! An [`InferenceSession`] rebuilds the network (both heads of a
//! two-head inverse-space model) from a
//! [`Checkpoint`](super::checkpoint::Checkpoint) and answers
//! point-cloud queries through the *same* blocked-GEMM forward path
//! training uses ([`Mlp::eval_heads_with`]) — points are batched into
//! blocks and each layer is one cache-blocked GEMM plus a fused
//! bias/tanh epilogue, never a per-point scalar loop. Because the
//! checkpoint stores raw `f64` parameter bits, a session's predictions
//! are bit-identical to the exporting backend's.
//!
//! The session owns a reusable scratch allocation, so steady-state
//! query traffic performs no per-batch setup beyond the output
//! vectors. `repro bench` tracks the resulting throughput (points/sec
//! at batch sizes 1, 256 and 4096).
//!
//! Sessions serve at two precisions ([`Precision`]): the default f64
//! path above, and an opt-in f32-compute / f64-accumulate path
//! (`--precision f32` on the CLI) that packs the checkpoint's f64
//! weights once into f32 panels and runs blocks through
//! [`simd::gemm_f32acc`] + the fast f32 tanh. The checkpoint itself
//! always stays f64; the f32 path trades bit identity for throughput
//! under a tested relative-error budget of `1e-5` on the u head.
//!
//! [`read_points_csv`] parses the `--points` query-cloud format with
//! line-numbered errors — a malformed row rejects the file instead of
//! silently truncating the cloud.

// Serving paths are CLI-reachable: failures must travel as errors,
// never as panics in the user's terminal.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::backend::native::{softplus, EvalScratch, Mlp};
use super::checkpoint::Checkpoint;
use crate::linalg::simd;

/// Serving precision of an [`InferenceSession`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f64 forward — bit-identical to the exporting backend.
    #[default]
    F64,
    /// f32-compute / f64-accumulate forward: f32 weight panels, FMA
    /// products, f64 chunk accumulation, fast f32 tanh. Max relative
    /// error vs the f64 path is budgeted (and tested) at `1e-5` on a
    /// 4096-point cloud.
    F32,
}

impl std::str::FromStr for Precision {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Precision> {
        match s {
            "f64" => Ok(Precision::F64),
            "f32" => Ok(Precision::F32),
            _ => Err(anyhow!(
                "unknown precision {s:?} (expected \"f64\" or \"f32\")"
            )),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        })
    }
}

/// Points per mixed-precision forward block (same order as the f64
/// path's eval block: activations stay cache-resident).
const F32_BLOCK: usize = 512;

/// One packed weight stage of the mixed-precision forward.
struct F32Stage {
    nin: usize,
    nout: usize,
    nout_pad: usize,
    /// [`simd::pack_weights_f32`] panels of 8 output columns.
    wp: Vec<f32>,
    /// Bias stays f64: added to the f64-accumulated pre-activation
    /// before the cast back to f32.
    bias: Vec<f64>,
}

/// The f32-compute / f64-accumulate forward evaluator: an [`Mlp`]'s
/// weights packed once into f32 panels, plus reusable f32 activation
/// and f64 pre-activation scratch. Built lazily on the first
/// [`InferenceSession::set_precision`]`(F32)`.
pub struct F32Evaluator {
    stages: Vec<F32Stage>,
    /// `(panels, nout_pad, bias)` of the eps head, when two-head.
    eps: Option<(Vec<f32>, usize, f64)>,
    a: Vec<f32>,
    nxt: Vec<f32>,
    z: Vec<f64>,
}

impl F32Evaluator {
    /// Pack a network's weights for mixed-precision serving (one-time
    /// cost; the source network stays f64 and untouched).
    pub fn from_mlp(net: &Mlp) -> F32Evaluator {
        let n_stages = net.layers.len() - 1;
        let mut stages = Vec::with_capacity(n_stages);
        let mut pad_max = 8;
        for l in 0..n_stages {
            let (nin, nout) = (net.layers[l], net.layers[l + 1]);
            let (w, b) = net.stage_params(l);
            let (wp, nout_pad) = simd::pack_weights_f32(w, nin, nout);
            pad_max = pad_max.max(nout_pad);
            stages.push(F32Stage {
                nin,
                nout,
                nout_pad,
                wp,
                bias: b.to_vec(),
            });
        }
        let eps = net.eps_params().map(|(we, be)| {
            let nin = net.layers[n_stages - 1];
            let (wp, nout_pad) = simd::pack_weights_f32(we, nin, 1);
            (wp, nout_pad, be)
        });
        let wmax = net.layers.iter().copied().max().unwrap_or(2).max(2);
        F32Evaluator {
            stages,
            eps,
            a: vec![0.0; F32_BLOCK * wmax],
            nxt: vec![0.0; F32_BLOCK * wmax],
            z: vec![0.0; F32_BLOCK * pad_max],
        }
    }

    /// Mixed-precision analogue of [`Mlp::eval_heads`]: `(u, eps)`
    /// with `eps = Some(field)` for two-head networks. The eps head
    /// applies the same f64 softplus as training, on the
    /// f64-accumulated pre-activation.
    pub fn eval_heads(&mut self, points: &[[f64; 2]])
        -> (Vec<f32>, Option<Vec<f32>>) {
        let last = self.stages.len() - 1;
        let mut out = Vec::with_capacity(points.len());
        let mut out_eps =
            self.eps.as_ref().map(|_| Vec::with_capacity(points.len()));
        for chunk in points.chunks(F32_BLOCK) {
            let n = chunk.len();
            for (p, pt) in chunk.iter().enumerate() {
                self.a[2 * p] = pt[0] as f32;
                self.a[2 * p + 1] = pt[1] as f32;
            }
            for st in &self.stages[..last] {
                simd::gemm_f32acc(&self.a[..n * st.nin], n, st.nin,
                                  &st.wp, st.nout_pad, &mut self.z);
                for p in 0..n {
                    for (j, &bj) in st.bias.iter().enumerate() {
                        self.nxt[p * st.nout + j] =
                            (self.z[p * st.nout_pad + j] + bj) as f32;
                    }
                }
                simd::tanh_block_f32(&mut self.nxt[..n * st.nout]);
                std::mem::swap(&mut self.a, &mut self.nxt);
            }
            let st = &self.stages[last];
            simd::gemm_f32acc(&self.a[..n * st.nin], n, st.nin, &st.wp,
                              st.nout_pad, &mut self.z);
            let bu = st.bias[0];
            out.extend(
                (0..n).map(|p| (self.z[p * st.nout_pad] + bu) as f32));
            if let (Some((wp, pad, be)), Some(oe)) =
                (self.eps.as_ref(), out_eps.as_mut())
            {
                simd::gemm_f32acc(&self.a[..n * st.nin], n, st.nin, wp,
                                  *pad, &mut self.z);
                oe.extend((0..n).map(|p| {
                    softplus(self.z[p * pad] + be) as f32
                }));
            }
        }
        (out, out_eps)
    }
}

/// A loaded model ready to answer batched point queries. Build with
/// [`InferenceSession::open`] (from a file) or
/// [`InferenceSession::from_checkpoint`] (from a parsed artifact).
pub struct InferenceSession {
    net: Mlp,
    scratch: EvalScratch,
    precision: Precision,
    /// Packed mixed-precision evaluator, built on first use.
    f32eval: Option<F32Evaluator>,
    /// Registry problem id from the artifact ("" for manual exports).
    pub problem: String,
    /// Problem instance label (e.g. `helmholtz_k6.283`).
    pub problem_label: String,
    /// Loss family the model was trained on.
    pub loss_kind: String,
    /// Optimizer step count at export.
    pub step: usize,
    /// Training-domain bounding box `[x0, y0, x1, y1]` — the region
    /// the model was fit on (useful for building query grids; the
    /// network extrapolates beyond it at the caller's own risk).
    pub bbox: [f64; 4],
}

impl InferenceSession {
    /// Build a session from a parsed artifact.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<InferenceSession> {
        let net =
            Mlp::from_theta(&ck.layers, ck.two_head, ck.theta.clone())
                .context("checkpoint network does not reconstruct")?;
        let scratch = EvalScratch::new(&net);
        Ok(InferenceSession {
            net,
            scratch,
            precision: Precision::F64,
            f32eval: None,
            problem: ck.problem.clone(),
            problem_label: ck.problem_label.clone(),
            loss_kind: ck.loss_kind.clone(),
            step: ck.step,
            bbox: ck.fingerprint.bbox,
        })
    }

    /// Read an artifact from disk and build a session from it.
    pub fn open(path: impl AsRef<Path>) -> Result<InferenceSession> {
        InferenceSession::from_checkpoint(&Checkpoint::read(path)?)
    }

    /// Whether the model carries an eps field head (two-head
    /// inverse-space networks).
    pub fn two_head(&self) -> bool {
        self.net.two_head()
    }

    /// The reconstructed network (e.g. for custom evaluation drivers).
    pub fn network(&self) -> &Mlp {
        &self.net
    }

    /// The serving precision currently in effect.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Switch serving precision. The first switch to [`Precision::F32`]
    /// packs the f64 weights into f32 panels (one-time cost, kept for
    /// the session's lifetime); switching back to [`Precision::F64`]
    /// restores the bit-identical path. The checkpoint parameters are
    /// never modified.
    pub fn set_precision(&mut self, p: Precision) {
        if p == Precision::F32 && self.f32eval.is_none() {
            self.f32eval = Some(F32Evaluator::from_mlp(&self.net));
        }
        self.precision = p;
    }

    /// Evaluate the model over a query point cloud: `(u, eps)` with
    /// `eps = Some(field)` for two-head models. Batched through the
    /// blocked-GEMM forward path; reuses the session's scratch, so
    /// repeated calls allocate only the output vectors.
    pub fn eval(&mut self, points: &[[f64; 2]])
        -> (Vec<f32>, Option<Vec<f32>>) {
        match self.precision {
            Precision::F64 => {
                self.net.eval_heads_with(points, &mut self.scratch)
            }
            Precision::F32 => {
                // set_precision(F32) packs the evaluator up front, but
                // pack here too rather than trust every future caller
                if self.f32eval.is_none() {
                    self.f32eval =
                        Some(F32Evaluator::from_mlp(&self.net));
                }
                match self.f32eval.as_mut() {
                    Some(ev) => ev.eval_heads(points),
                    None => unreachable!(),
                }
            }
        }
    }

    /// [`InferenceSession::eval`], u head only.
    pub fn eval_u(&mut self, points: &[[f64; 2]]) -> Vec<f32> {
        self.eval(points).0
    }

    /// Clone the model into an independent session with its own
    /// scratch (and, when this session serves f32, its own packed f32
    /// evaluator). `eval` takes `&mut self`, so a serve worker pool
    /// needs one session per worker — `fork` gives each worker a
    /// private copy without re-reading or re-parsing the artifact.
    /// Both forks answer f64 queries bit-identically: they share the
    /// exact parameter bits and the eval path is deterministic.
    pub fn fork(&self) -> InferenceSession {
        let net = self.net.clone();
        let scratch = EvalScratch::new(&net);
        let mut sess = InferenceSession {
            net,
            scratch,
            precision: Precision::F64,
            f32eval: None,
            problem: self.problem.clone(),
            problem_label: self.problem_label.clone(),
            loss_kind: self.loss_kind.clone(),
            step: self.step,
            bbox: self.bbox,
        };
        sess.set_precision(self.precision);
        sess
    }
}

// Send audit: serve worker pools move one forked session into each
// worker thread, so `InferenceSession` must be `Send`. It is — the
// only non-trivially-owned state is the aligned GEMM scratch
// (`AlignedBuf`), which declares `Send` itself — and this assertion
// turns any future regression (e.g. an Rc or raw-pointer cache slipped
// into the eval path) into a compile error right here instead of a
// type error at the far-away spawn site.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<InferenceSession>();
    assert_send::<F32Evaluator>();
    assert_send::<Precision>();
};

/// Parse a query point cloud from a CSV of `x,y` rows (the CLI's
/// `--points` format).
///
/// The first non-blank row may be a header — it is skipped only when
/// *every* field on it is non-numeric. Blank lines and surrounding
/// whitespace are fine. Anything else — a truncated row, a field that
/// does not parse, a non-finite coordinate — rejects the whole file
/// with a line-numbered error naming the offending content, instead of
/// silently truncating the cloud.
pub fn read_points_csv(path: &str) -> Result<Vec<[f64; 2]>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read points file {path}"))?;
    let mut out = Vec::new();
    let mut first_row = true;
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let header_candidate = first_row;
        first_row = false;
        let fields: Vec<&str> =
            line.split(',').map(str::trim).collect();
        if fields.len() != 2 {
            if header_candidate
                && fields.iter().all(|f| f.parse::<f64>().is_err())
            {
                continue; // header row (e.g. a stray "x" or "x,y,u")
            }
            bail!(
                "{path}:{}: expected 2 comma-separated fields 'x,y', \
                 got {} in '{line}'",
                ln + 1, fields.len()
            );
        }
        match (fields[0].parse::<f64>(), fields[1].parse::<f64>()) {
            (Ok(x), Ok(y)) => {
                ensure!(
                    x.is_finite() && y.is_finite(),
                    "{path}:{}: non-finite coordinate in '{line}'",
                    ln + 1
                );
                out.push([x, y]);
            }
            _ if header_candidate
                && fields.iter().all(|f| f.parse::<f64>().is_err()) =>
            {
                // header row ("x,y"); a later non-numeric row is data
                // gone bad and falls through to the errors below
            }
            (Err(_), _) => bail!(
                "{path}:{}: cannot parse x field '{}' as a number \
                 (row '{line}')",
                ln + 1, fields[0]
            ),
            (_, Err(_)) => bail!(
                "{path}:{}: cannot parse y field '{}' as a number \
                 (row '{line}')",
                ln + 1, fields[1]
            ),
        }
    }
    ensure!(
        !out.is_empty(),
        "{path}: no data rows (expected lines of 'x,y')"
    );
    Ok(out)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::{DataSource, TrainConfig, Trainer};
    use crate::fem::assembly;
    use crate::fem::quadrature::QuadKind;
    use crate::mesh::generators;
    use crate::problems::InverseSpaceSin;
    use crate::runtime::backend::native::{
        NativeBackend, NativeConfig, NativeLoss,
    };
    use crate::runtime::backend::BackendOpts;

    #[test]
    fn session_reproduces_trained_two_head_backend_bitwise() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let problem = InverseSpaceSin;
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = TrainConfig { iters: 12, ..TrainConfig::default() };
        let ncfg = NativeConfig {
            layers: vec![2, 6, 1],
            loss: NativeLoss::InverseSpace,
            nb: 16,
            ns: 8,
        };
        let backend = NativeBackend::new(
            &ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
        let mut t = Trainer::new(Box::new(backend), &cfg);
        t.run().unwrap();
        let ck = t.checkpoint().unwrap();
        // through the on-disk bytes, not just the in-memory struct
        let ck = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        let mut sess = InferenceSession::from_checkpoint(&ck).unwrap();
        assert!(sess.two_head());
        assert_eq!(sess.step, 12);
        let pts: Vec<[f64; 2]> = (0..137)
            .map(|i| {
                let s = i as f64 / 136.0;
                [s, (1.7 * s).fract()]
            })
            .collect();
        let (u, eps) = sess.eval(&pts);
        let heads = t.predict_heads(&pts).unwrap();
        assert_eq!(u, heads[0], "u head must be bit-identical");
        assert_eq!(eps.as_deref(), Some(&heads[1][..]),
                   "eps head must be bit-identical");
        // a forked session (the serve worker-pool path) shares the
        // exact parameter bits: same answers, bit for bit
        let mut forked = sess.fork();
        let (uf, epsf) = forked.eval(&pts);
        assert_eq!(u, uf, "forked session u head drifted");
        assert_eq!(eps, epsf, "forked session eps head drifted");
        // repeated queries reuse the scratch and stay identical
        let (u2, _) = sess.eval(&pts);
        assert_eq!(u, u2);
        // f32 serving: bounded drift on both heads, then switching
        // back to f64 restores bit identity
        sess.set_precision(Precision::F32);
        assert_eq!(sess.precision(), Precision::F32);
        let (u32v, eps32) = sess.eval(&pts);
        let eps = eps.unwrap();
        let eps32 = eps32.unwrap();
        let scale_u = u
            .iter()
            .fold(0.0f64, |m, &v| m.max((v as f64).abs()))
            .max(1e-12);
        let scale_e = eps
            .iter()
            .fold(0.0f64, |m, &v| m.max((v as f64).abs()))
            .max(1e-12);
        for (a, b) in u.iter().zip(&u32v) {
            let err = ((*a as f64) - (*b as f64)).abs() / scale_u;
            assert!(err < 1e-5, "u drift {err:e} over budget");
        }
        for (a, b) in eps.iter().zip(&eps32) {
            let err = ((*a as f64) - (*b as f64)).abs() / scale_e;
            assert!(err < 1e-5, "eps drift {err:e} over budget");
        }
        sess.set_precision(Precision::F64);
        let (u3, _) = sess.eval(&pts);
        assert_eq!(u, u3, "f64 path must stay bit-identical");
    }

    #[test]
    fn f32_path_stays_within_rel_err_budget_on_std_net() {
        // The acceptance-criteria bound: max rel err < 1e-5 on a
        // 4096-point cloud through the paper's standard [2,30,30,30,1]
        // network (prototype-measured ~1.3e-6; see
        // python/proto_simd_tanh.py).
        let net = Mlp::glorot(&[2, 30, 30, 30, 1], 42).unwrap();
        let mut ev = F32Evaluator::from_mlp(&net);
        let pts: Vec<[f64; 2]> = (0..4096)
            .map(|i| {
                let s = i as f64 / 4095.0;
                [s, (0.7 + 2.3 * s).fract()]
            })
            .collect();
        let (u_ref, _) = net.eval_heads(&pts);
        let (u_32, none) = ev.eval_heads(&pts);
        assert!(none.is_none(), "single-head net grew an eps head");
        let scale = u_ref
            .iter()
            .fold(0.0f64, |m, &v| m.max((v as f64).abs()))
            .max(1e-12);
        let mut worst = 0.0f64;
        for (a, b) in u_ref.iter().zip(&u_32) {
            worst = worst.max(((*a as f64) - (*b as f64)).abs() / scale);
        }
        assert!(worst < 1e-5, "max rel err {worst:e} over the budget");
    }

    #[test]
    fn precision_parses_and_displays() {
        assert_eq!("f64".parse::<Precision>().unwrap(), Precision::F64);
        assert_eq!("f32".parse::<Precision>().unwrap(), Precision::F32);
        assert!("f16".parse::<Precision>().is_err());
        assert_eq!(Precision::F32.to_string(), "f32");
        assert_eq!(Precision::default(), Precision::F64);
    }

    /// Write `content` to a unique temp CSV and parse it.
    fn parse_csv(tag: &str, content: &str) -> Result<Vec<[f64; 2]>> {
        let path = std::env::temp_dir().join(format!(
            "fastvpinns_points_{tag}_{}.csv",
            std::process::id()
        ));
        std::fs::write(&path, content).unwrap();
        let r = read_points_csv(&path.to_string_lossy());
        std::fs::remove_file(&path).ok();
        r
    }

    #[test]
    fn points_csv_parses_with_and_without_header() {
        let pts = parse_csv("hdr", "x,y\n0.5, 0.25\n1,2\n").unwrap();
        assert_eq!(pts, vec![[0.5, 0.25], [1.0, 2.0]]);
        let pts = parse_csv("nohdr", "0.5,0.25\n\n 1 , 2 \n").unwrap();
        assert_eq!(pts, vec![[0.5, 0.25], [1.0, 2.0]]);
        // blank lines before the header are fine
        let pts = parse_csv("blank_hdr", "\n\nx,y\n3,4\n").unwrap();
        assert_eq!(pts, vec![[3.0, 4.0]]);
    }

    #[test]
    fn points_csv_rejects_truncated_row_with_line_number() {
        let err = parse_csv("trunc", "0.1,0.2\n0.3\n0.5,0.6\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains(":2:"), "line number missing: {err}");
        assert!(err.contains("expected 2 comma-separated fields"),
                "got: {err}");
    }

    #[test]
    fn points_csv_rejects_garbage_fields_with_line_number() {
        let err = parse_csv("garb_x", "x,y\n0.1,0.2\nbanana,0.4\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains(":3:"), "line number missing: {err}");
        assert!(err.contains("x field 'banana'"), "got: {err}");
        let err = parse_csv("garb_y", "0.1,0.2\n0.3,0.4.5\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains(":2:"), "line number missing: {err}");
        assert!(err.contains("y field '0.4.5'"), "got: {err}");
        // a half-numeric first row is data gone bad, not a header
        let err = parse_csv("half_hdr", "x,1.0\n0.3,0.4\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains(":1:"), "got: {err}");
    }

    #[test]
    fn points_csv_rejects_non_finite_and_empty() {
        let err = parse_csv("nan", "0.1,0.2\nnan,0.4\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("non-finite"), "got: {err}");
        let err = parse_csv("empty", "x,y\n").unwrap_err().to_string();
        assert!(err.contains("no data rows"), "got: {err}");
    }
}
