//! Artifact manifests: the JSON sidecar written by `compile/aot.py`
//! describing the exact ordered input/output signature of each HLO
//! executable. The Rust side trusts only this file — never positional
//! conventions baked into code.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape.iter().product()
        }
    }
}

/// Static configuration of an artifact (mirrors specs.Spec).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArtifactConfig {
    pub layers: Vec<usize>,
    pub ne: usize,
    pub nt1d: usize,
    pub nq1d: usize,
    pub nt: usize,
    pub nq: usize,
    pub nb: usize,
    pub ns: usize,
    pub n_coll: usize,
    pub n_eval: usize,
    pub kernel: String,
    pub heads: usize,
    pub eps: Option<f64>,
    pub bx: Option<f64>,
    pub by: Option<f64>,
    pub paper_scale: bool,
    pub note: String,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub name: String,
    /// "train" | "predict"
    pub kind: String,
    /// poisson | cd | inverse_const | inverse_space | pinn | hp_loop | ""
    pub loss: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
    pub config: ArtifactConfig,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let name = j.req("name")?.as_str()?.to_string();
        let kind = j.req("kind")?.as_str()?.to_string();
        let loss = j.req("loss")?.as_str()?.to_string();
        let mut inputs = Vec::new();
        for item in j.req("inputs")?.as_arr()? {
            let shape = item
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            if item.req("dtype")?.as_str()? != "f32" {
                bail!("only f32 inputs supported");
            }
            inputs.push(IoSpec {
                name: item.req("name")?.as_str()?.to_string(),
                shape,
            });
        }
        let outputs = j
            .req("outputs")?
            .as_arr()?
            .iter()
            .map(|o| o.as_str().map(|s| s.to_string()))
            .collect::<Result<Vec<_>>>()?;

        let c = j.req("config")?;
        let get = |k: &str| -> Result<usize> {
            c.req(k)?.as_usize()
        };
        let cf = c.req("const")?;
        let fopt = |k: &str| -> Option<f64> {
            cf.get(k).and_then(|v| v.as_f64().ok())
        };
        let config = ArtifactConfig {
            layers: c
                .req("layers")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?,
            ne: get("ne")?,
            nt1d: get("nt1d")?,
            nq1d: get("nq1d")?,
            nt: get("nt")?,
            nq: get("nq")?,
            nb: get("nb")?,
            ns: get("ns")?,
            n_coll: get("n_coll")?,
            n_eval: get("n_eval")?,
            kernel: c.req("kernel")?.as_str()?.to_string(),
            heads: get("heads")?,
            eps: fopt("eps"),
            bx: fopt("bx"),
            by: fopt("by"),
            paper_scale: c.req("paper_scale")?.as_bool()?,
            note: c.req("note")?.as_str()?.to_string(),
        };
        Ok(Manifest { name, kind, loss, inputs, outputs, config })
    }

    /// Number of parameter arrays (p0..p{n-1}) in the signature.
    pub fn n_param_arrays(&self) -> usize {
        self.inputs
            .iter()
            .take_while(|s| s.name.starts_with('p'))
            .count()
    }

    /// Number of *network* parameter arrays: 2 per layer transition
    /// (excludes the trainable eps scalar of inverse_const).
    pub fn n_network_arrays(&self) -> usize {
        2 * (self.config.layers.len() - 1)
    }

    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s == name)
    }

    /// Shapes of the parameter/optimizer state arrays in order.
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        (0..self.n_param_arrays())
            .map(|i| self.inputs[i].shape.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "fv_poisson_test",
      "kind": "train",
      "loss": "poisson",
      "inputs": [
        {"name": "p0", "shape": [2, 4], "dtype": "f32"},
        {"name": "p1", "shape": [4], "dtype": "f32"},
        {"name": "m0", "shape": [2, 4], "dtype": "f32"},
        {"name": "m1", "shape": [4], "dtype": "f32"},
        {"name": "v0", "shape": [2, 4], "dtype": "f32"},
        {"name": "v1", "shape": [4], "dtype": "f32"},
        {"name": "step", "shape": [], "dtype": "f32"},
        {"name": "lr", "shape": [], "dtype": "f32"},
        {"name": "quad_xy", "shape": [36, 2], "dtype": "f32"},
        {"name": "gx", "shape": [4, 4, 9], "dtype": "f32"},
        {"name": "gy", "shape": [4, 4, 9], "dtype": "f32"},
        {"name": "f", "shape": [4, 4], "dtype": "f32"},
        {"name": "bd_xy", "shape": [8, 2], "dtype": "f32"},
        {"name": "bd_u", "shape": [8], "dtype": "f32"},
        {"name": "tau", "shape": [], "dtype": "f32"}
      ],
      "outputs": ["p0", "p1", "m0", "m1", "v0", "v1",
                  "loss", "var_loss", "bd_loss"],
      "config": {
        "layers": [2, 4, 1],
        "ne": 4, "nt1d": 2, "nq1d": 3, "nt": 4, "nq": 9,
        "nb": 8, "ns": 0, "n_coll": 0, "n_eval": 0,
        "kernel": "pallas", "heads": 1,
        "const": {"eps": 1.0},
        "paper_scale": false, "note": "test",
        "param_order": "..."
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "fv_poisson_test");
        assert_eq!(m.inputs.len(), 15);
        assert_eq!(m.n_param_arrays(), 2);
        assert_eq!(m.config.ne, 4);
        assert_eq!(m.config.eps, Some(1.0));
        assert_eq!(m.config.bx, None);
        assert_eq!(m.input_index("gx"), Some(9));
        assert_eq!(m.output_index("loss"), Some(6));
    }

    #[test]
    fn numel() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.inputs[m.input_index("gx").unwrap()].numel(), 144);
        assert_eq!(m.inputs[m.input_index("tau").unwrap()].numel(), 1);
    }

    #[test]
    fn rejects_non_f32() {
        let bad = SAMPLE.replace("\"dtype\": \"f32\"",
                                 "\"dtype\": \"f64\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_keys() {
        assert!(Manifest::parse("{}").is_err());
    }
}
