//! Artifact manifests: the JSON sidecar written by `compile/aot.py`
//! describing the exact ordered input/output signature of each HLO
//! executable. The Rust side trusts only this file — never positional
//! conventions baked into code.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One named input/output buffer of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    /// Buffer name (matches the python spec).
    pub name: String,
    /// Buffer shape (empty = scalar).
    pub shape: Vec<usize>,
}

impl IoSpec {
    /// Element count (1 for scalars).
    pub fn numel(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape.iter().product()
        }
    }
}

/// Static configuration of an artifact (mirrors specs.Spec).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArtifactConfig {
    /// MLP layer widths.
    pub layers: Vec<usize>,
    /// Element count.
    pub ne: usize,
    /// 1D test-function order.
    pub nt1d: usize,
    /// 1D quadrature order.
    pub nq1d: usize,
    /// Test functions per element.
    pub nt: usize,
    /// Quadrature points per element.
    pub nq: usize,
    /// Boundary sample count.
    pub nb: usize,
    /// Sensor count.
    pub ns: usize,
    /// Collocation point count (PINN baselines).
    pub n_coll: usize,
    /// Prediction batch size (predict artifacts).
    pub n_eval: usize,
    /// Which residual kernel was lowered ("tensor", "loop", ...).
    pub kernel: String,
    /// Output head count.
    pub heads: usize,
    /// Baked-in diffusion constant, when the loss has one.
    pub eps: Option<f64>,
    /// Baked-in convection x component.
    pub bx: Option<f64>,
    /// Baked-in convection y component.
    pub by: Option<f64>,
    /// Whether this is a paper-scale (vs CI-scale) config.
    pub paper_scale: bool,
    /// Free-form provenance note.
    pub note: String,
}

/// The JSON sidecar describing one AOT artifact (name, kind, loss and
/// I/O buffer layout) — written by `python -m compile.aot`.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Artifact name (file stem).
    pub name: String,
    /// "train" | "predict"
    pub kind: String,
    /// poisson | cd | inverse_const | inverse_space | pinn | hp_loop | ""
    pub loss: String,
    /// Input buffers, in call order.
    pub inputs: Vec<IoSpec>,
    /// Output buffer names, in result order.
    pub outputs: Vec<String>,
    /// Static shape/hyper-parameter record.
    pub config: ArtifactConfig,
}

impl Manifest {
    /// Read and parse a manifest file.
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let name = j.req("name")?.as_str()?.to_string();
        let kind = j.req("kind")?.as_str()?.to_string();
        let loss = j.req("loss")?.as_str()?.to_string();
        let mut inputs = Vec::new();
        for item in j.req("inputs")?.as_arr()? {
            let shape = item
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            if item.req("dtype")?.as_str()? != "f32" {
                bail!("only f32 inputs supported");
            }
            inputs.push(IoSpec {
                name: item.req("name")?.as_str()?.to_string(),
                shape,
            });
        }
        let outputs = j
            .req("outputs")?
            .as_arr()?
            .iter()
            .map(|o| o.as_str().map(|s| s.to_string()))
            .collect::<Result<Vec<_>>>()?;

        let c = j.req("config")?;
        let get = |k: &str| -> Result<usize> {
            c.req(k)?.as_usize()
        };
        let cf = c.req("const")?;
        let fopt = |k: &str| -> Option<f64> {
            cf.get(k).and_then(|v| v.as_f64().ok())
        };
        let config = ArtifactConfig {
            layers: c
                .req("layers")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?,
            ne: get("ne")?,
            nt1d: get("nt1d")?,
            nq1d: get("nq1d")?,
            nt: get("nt")?,
            nq: get("nq")?,
            nb: get("nb")?,
            ns: get("ns")?,
            n_coll: get("n_coll")?,
            n_eval: get("n_eval")?,
            kernel: c.req("kernel")?.as_str()?.to_string(),
            heads: get("heads")?,
            eps: fopt("eps"),
            bx: fopt("bx"),
            by: fopt("by"),
            paper_scale: c.req("paper_scale")?.as_bool()?,
            note: c.req("note")?.as_str()?.to_string(),
        };
        Ok(Manifest { name, kind, loss, inputs, outputs, config })
    }

    /// Number of parameter arrays (p0..p{n-1}) in the signature.
    ///
    /// Only names matching the exact `p<digits>` convention count — a
    /// plain `starts_with('p')` would misclassify future non-param
    /// inputs like `points` or `pred_xy` as parameter arrays.
    pub fn n_param_arrays(&self) -> usize {
        self.inputs
            .iter()
            .take_while(|s| is_param_array_name(&s.name))
            .count()
    }

    /// Number of *network* parameter arrays: 2 per layer transition
    /// (excludes the trainable eps scalar of inverse_const).
    pub fn n_network_arrays(&self) -> usize {
        2 * (self.config.layers.len() - 1)
    }

    /// Position of input buffer `name`, if declared.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }

    /// Position of output buffer `name`, if declared.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s == name)
    }

    /// Shapes of the parameter/optimizer state arrays in order.
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        (0..self.n_param_arrays())
            .map(|i| self.inputs[i].shape.clone())
            .collect()
    }
}

/// True for the `p<digits>` parameter-array naming convention
/// (`p0`, `p1`, ..., `p12`) and nothing else.
fn is_param_array_name(name: &str) -> bool {
    let rest = match name.strip_prefix('p') {
        Some(r) => r,
        None => return false,
    };
    !rest.is_empty() && rest.bytes().all(|b| b.is_ascii_digit())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "fv_poisson_test",
      "kind": "train",
      "loss": "poisson",
      "inputs": [
        {"name": "p0", "shape": [2, 4], "dtype": "f32"},
        {"name": "p1", "shape": [4], "dtype": "f32"},
        {"name": "m0", "shape": [2, 4], "dtype": "f32"},
        {"name": "m1", "shape": [4], "dtype": "f32"},
        {"name": "v0", "shape": [2, 4], "dtype": "f32"},
        {"name": "v1", "shape": [4], "dtype": "f32"},
        {"name": "step", "shape": [], "dtype": "f32"},
        {"name": "lr", "shape": [], "dtype": "f32"},
        {"name": "quad_xy", "shape": [36, 2], "dtype": "f32"},
        {"name": "gx", "shape": [4, 4, 9], "dtype": "f32"},
        {"name": "gy", "shape": [4, 4, 9], "dtype": "f32"},
        {"name": "f", "shape": [4, 4], "dtype": "f32"},
        {"name": "bd_xy", "shape": [8, 2], "dtype": "f32"},
        {"name": "bd_u", "shape": [8], "dtype": "f32"},
        {"name": "tau", "shape": [], "dtype": "f32"}
      ],
      "outputs": ["p0", "p1", "m0", "m1", "v0", "v1",
                  "loss", "var_loss", "bd_loss"],
      "config": {
        "layers": [2, 4, 1],
        "ne": 4, "nt1d": 2, "nq1d": 3, "nt": 4, "nq": 9,
        "nb": 8, "ns": 0, "n_coll": 0, "n_eval": 0,
        "kernel": "pallas", "heads": 1,
        "const": {"eps": 1.0},
        "paper_scale": false, "note": "test",
        "param_order": "..."
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "fv_poisson_test");
        assert_eq!(m.inputs.len(), 15);
        assert_eq!(m.n_param_arrays(), 2);
        assert_eq!(m.config.ne, 4);
        assert_eq!(m.config.eps, Some(1.0));
        assert_eq!(m.config.bx, None);
        assert_eq!(m.input_index("gx"), Some(9));
        assert_eq!(m.output_index("loss"), Some(6));
    }

    #[test]
    fn numel() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.inputs[m.input_index("gx").unwrap()].numel(), 144);
        assert_eq!(m.inputs[m.input_index("tau").unwrap()].numel(), 1);
    }

    /// Regression: an adversarial leading input named `points` (or
    /// `pred_xy`) merely *starts with* 'p' — the old
    /// `starts_with('p')` check counted it as a parameter array and
    /// shifted every downstream buffer index by one.
    #[test]
    fn adversarial_p_prefixed_input_is_not_a_param_array() {
        let adversarial = SAMPLE
            .replace(
                r#"{"name": "m0", "shape": [2, 4], "dtype": "f32"}"#,
                r#"{"name": "points", "shape": [2, 4], "dtype": "f32"}"#,
            )
            .replace(
                r#"{"name": "m1", "shape": [4], "dtype": "f32"}"#,
                r#"{"name": "pred_xy", "shape": [4], "dtype": "f32"}"#,
            );
        let m = Manifest::parse(&adversarial).unwrap();
        // p0, p1 count; the run stops at "points"/"pred_xy"
        assert_eq!(m.n_param_arrays(), 2);
        assert_eq!(m.param_shapes(),
                   vec![vec![2, 4], vec![4]]);
    }

    #[test]
    fn param_name_convention_is_exact() {
        assert!(is_param_array_name("p0"));
        assert!(is_param_array_name("p17"));
        assert!(!is_param_array_name("p"));
        assert!(!is_param_array_name("points"));
        assert!(!is_param_array_name("pred_xy"));
        assert!(!is_param_array_name("p1x"));
        assert!(!is_param_array_name("q0"));
    }

    #[test]
    fn rejects_non_f32() {
        let bad = SAMPLE.replace("\"dtype\": \"f32\"",
                                 "\"dtype\": \"f64\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_keys() {
        assert!(Manifest::parse("{}").is_err());
    }
}
