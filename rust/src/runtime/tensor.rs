//! Host-side tensor data; conversion to/from `xla::Literal` lives
//! behind the `xla` feature so the native backend and host tensors
//! compile without PJRT.

use anyhow::{ensure, Result};

/// A host f32 tensor (C order) with shape. The empty shape is a scalar
/// (one element), matching XLA semantics.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorData {
    /// Dimension sizes (empty = scalar).
    pub shape: Vec<usize>,
    /// Row-major (C order) elements.
    pub data: Vec<f32>,
}

/// Element count implied by a shape (empty product = 1 = scalar).
fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl TensorData {
    /// Wrap `data` with `shape`, validating the element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let expect = numel(&shape);
        ensure!(data.len() == expect,
                "shape {shape:?} wants {expect} elements, got {}",
                data.len());
        Ok(TensorData { shape, data })
    }

    /// A rank-0 scalar.
    pub fn scalar(v: f32) -> Self {
        TensorData { shape: vec![], data: vec![v] }
    }

    /// An all-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        TensorData { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    /// Narrowing f64 -> f32 constructor (the runtime boundary).
    pub fn from_f64(shape: Vec<usize>, data: &[f64]) -> Result<Self> {
        Self::new(shape, data.iter().map(|&v| v as f32).collect())
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Convert to an XLA literal (f32).
    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        if self.shape.is_empty() {
            return Ok(xla::Literal::scalar(self.data[0]));
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Read back from an XLA literal.
    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(TensorData { shape: dims, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_validation() {
        assert!(TensorData::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(TensorData::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(TensorData::new(vec![], vec![1.0]).is_ok());
        assert!(TensorData::new(vec![], vec![]).is_err());
        assert!(TensorData::new(vec![0, 3], vec![]).is_ok());
    }

    #[test]
    fn zeros_and_scalar() {
        assert_eq!(TensorData::zeros(&[2, 2]).len(), 4);
        assert_eq!(TensorData::zeros(&[]).len(), 1);
        assert_eq!(TensorData::scalar(3.0).shape.len(), 0);
    }

    #[test]
    fn from_f64_casts() {
        let t = TensorData::from_f64(vec![2], &[1.5, -2.5]).unwrap();
        assert_eq!(t.data, vec![1.5f32, -2.5f32]);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip() {
        let t = TensorData::new(vec![2, 3],
                                (0..6).map(|i| i as f32).collect())
            .unwrap();
        let lit = t.to_literal().unwrap();
        let back = TensorData::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn scalar_literal_roundtrip() {
        let t = TensorData::scalar(2.5);
        let lit = t.to_literal().unwrap();
        let back = TensorData::from_literal(&lit).unwrap();
        assert_eq!(back.data, vec![2.5]);
        assert!(back.shape.is_empty());
    }
}
