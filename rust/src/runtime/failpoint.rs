//! Deterministic fault injection: named failure sites compiled into
//! the runtime, armed at process start, and hit-counted — the honest
//! way to test the crash-safe checkpoint ring and the self-healing
//! training loop, because the faults fire inside the real code paths
//! (mid-write, mid-step, mid-read) instead of in a mock.
//!
//! ## Arming
//!
//! A spec is a comma-separated list of `site[@N][=V]` terms:
//!
//! - `site` — fire on **every** hit of the site,
//! - `site@N` — fire exactly on the `N`-th hit (1-based), once,
//! - `site=V` — attach a numeric payload the site interprets (e.g. a
//!   stall duration in milliseconds).
//!
//! Arm via the `REPRO_FAILPOINTS` environment variable (read once by
//! [`arm_from_env`], which the CLI calls at startup) or the
//! `--failpoints` train flag. Unknown site names are rejected at
//! arming time, so a typo cannot silently disarm a chaos test.
//!
//! ## Site catalog
//!
//! | site | fires where | effect |
//! |------|-------------|--------|
//! | `checkpoint.write.truncate` | [`Checkpoint::write`] | writes a torn half-artifact to the final path and *reports success* — silent corruption the salvage path must discover at load |
//! | `checkpoint.write.kill`     | [`Checkpoint::write`] | writes a torn half-artifact to the final path, then kills the process (exit 137) — a crash mid-save |
//! | `io.read.err`               | [`Checkpoint::read`]  | returns an injected I/O error |
//! | `grad.nan`                  | native backend step   | poisons the gradient with NaN before the Adam update (use `@N` for "diverge at step N") |
//! | `step.stall`                | [`Trainer::step_once`] | sleeps `=V` milliseconds (default 2000) inside the step, tripping the watchdog |
//! | `kernel.avx2.fault`         | native backend step   | simulates an AVX2 kernel fault: dispatch degrades to the scalar ground-truth kernels for the rest of the process |
//!
//! [`Checkpoint::write`]: crate::runtime::checkpoint::Checkpoint::write
//! [`Checkpoint::read`]: crate::runtime::checkpoint::Checkpoint::read
//! [`Trainer::step_once`]: crate::coordinator::trainer::Trainer::step_once
//!
//! ## Cost when disarmed
//!
//! [`fire`] first checks one process-wide relaxed [`AtomicBool`]; with
//! nothing armed (the default) every site is a single atomic load and
//! a branch — nothing is locked, parsed or allocated on the hot path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use anyhow::{bail, Context, Result};

/// Every failpoint site compiled into this build (see the module-level
/// catalog). [`arm_from_spec`] validates names against this list.
pub const SITES: &[&str] = &[
    "checkpoint.write.truncate",
    "checkpoint.write.kill",
    "io.read.err",
    "grad.nan",
    "step.stall",
    "kernel.avx2.fault",
];

/// One armed site.
#[derive(Debug, Clone, Copy)]
struct Arm {
    /// `Some(n)`: fire only on the n-th hit (1-based); `None`: always.
    on_hit: Option<u64>,
    /// Optional `=V` payload.
    value: Option<f64>,
    /// Times the site was evaluated.
    hits: u64,
    /// Times the site actually fired.
    fired: u64,
}

static ARMED: AtomicBool = AtomicBool::new(false);

fn table() -> &'static Mutex<HashMap<&'static str, Arm>> {
    static TABLE: OnceLock<Mutex<HashMap<&'static str, Arm>>> =
        OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(HashMap::new()))
}

fn canonical(site: &str) -> Option<&'static str> {
    SITES.iter().find(|&&s| s == site).copied()
}

/// Arm failpoints from a `site[@N][=V],...` spec (see module docs).
/// Terms accumulate onto whatever is already armed; re-arming a site
/// replaces its term and resets its counters. Unknown sites and
/// malformed terms are errors.
pub fn arm_from_spec(spec: &str) -> Result<()> {
    let mut parsed: Vec<(&'static str, Arm)> = Vec::new();
    for term in spec.split(',') {
        let term = term.trim();
        if term.is_empty() {
            continue;
        }
        let (head, value) = match term.split_once('=') {
            Some((h, v)) => (
                h,
                Some(v.trim().parse::<f64>().with_context(|| {
                    format!("failpoint term '{term}': bad value '{v}'")
                })?),
            ),
            None => (term, None),
        };
        let (name, on_hit) = match head.split_once('@') {
            Some((n, h)) => (
                n.trim(),
                Some(h.trim().parse::<u64>().with_context(|| {
                    format!("failpoint term '{term}': bad hit index '{h}'")
                })?),
            ),
            None => (head.trim(), None),
        };
        if on_hit == Some(0) {
            bail!("failpoint term '{term}': hit indices are 1-based");
        }
        let site = canonical(name).with_context(|| {
            format!(
                "unknown failpoint site '{name}' (known: {})",
                SITES.join(", ")
            )
        })?;
        parsed.push((site, Arm { on_hit, value, hits: 0, fired: 0 }));
    }
    if parsed.is_empty() {
        return Ok(());
    }
    let mut t = table().lock().unwrap_or_else(|e| e.into_inner());
    for (site, arm) in parsed {
        t.insert(site, arm);
    }
    ARMED.store(true, Ordering::SeqCst);
    Ok(())
}

/// Arm from the `REPRO_FAILPOINTS` environment variable when set —
/// called once at CLI startup so the chaos tier can inject faults into
/// any subcommand without a dedicated flag.
pub fn arm_from_env() -> Result<()> {
    match std::env::var("REPRO_FAILPOINTS") {
        Ok(spec) if !spec.is_empty() => arm_from_spec(&spec)
            .context("parse REPRO_FAILPOINTS"),
        _ => Ok(()),
    }
}

/// Disarm everything and reset all counters (test isolation).
pub fn disarm_all() {
    ARMED.store(false, Ordering::SeqCst);
    table().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Whether any site is armed (one relaxed load — the disarmed fast
/// path of every site check).
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Evaluate a site: count the hit and return `Some(payload)` when the
/// site fires now, `None` otherwise. The payload is the `=V` value,
/// or NaN when the term carried none — each site supplies its own
/// default for the NaN case. With nothing armed this is a single
/// atomic load.
pub fn fire(site: &str) -> Option<f64> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut t = table().lock().unwrap_or_else(|e| e.into_inner());
    let arm = t.get_mut(site)?;
    arm.hits += 1;
    let firing = match arm.on_hit {
        Some(n) => arm.hits == n,
        None => true,
    };
    if !firing {
        return None;
    }
    arm.fired += 1;
    Some(arm.value.unwrap_or(f64::NAN))
}

/// [`fire`] without the payload — for sites whose effect needs no
/// parameter.
pub fn fired(site: &str) -> bool {
    fire(site).is_some()
}

/// How many times a site has been evaluated since arming (0 when the
/// site is not armed) — chaos tests assert on this to prove a fault
/// was actually reached.
pub fn hits(site: &str) -> u64 {
    let t = table().lock().unwrap_or_else(|e| e.into_inner());
    t.get(site).map_or(0, |a| a.hits)
}

/// How many times a site has actually fired since arming.
pub fn fired_count(site: &str) -> u64 {
    let t = table().lock().unwrap_or_else(|e| e.into_inner());
    t.get(site).map_or(0, |a| a.fired)
}

#[cfg(test)]
mod tests {
    use super::*;

    // One sequential test owning the process-global table end to end:
    // the suite runs tests in parallel and a second failpoint test
    // would race this one through the shared ARMED flag.
    #[test]
    fn spec_parsing_hit_counting_and_disarm() {
        disarm_all();
        assert!(!armed());
        // disarmed: every site is silent and costs one atomic load
        assert_eq!(fire("grad.nan"), None);
        assert_eq!(hits("grad.nan"), 0);

        // unknown sites and malformed terms are rejected up front
        assert!(arm_from_spec("grad.none@3").is_err());
        assert!(arm_from_spec("grad.nan@x").is_err());
        assert!(arm_from_spec("grad.nan@0").is_err());
        assert!(arm_from_spec("step.stall=abc").is_err());
        assert!(!armed(), "failed arming must not half-arm");

        // an empty spec is a no-op, not an error
        arm_from_spec("").unwrap();
        assert!(!armed());

        arm_from_spec("grad.nan@3, step.stall=250").unwrap();
        assert!(armed());

        // @3: fires exactly on the third hit, once; no =V payload
        // means the NaN sentinel (the site picks its own default)
        assert_eq!(fire("grad.nan"), None);
        assert_eq!(fire("grad.nan"), None);
        assert!(fire("grad.nan").is_some_and(|v| v.is_nan()));
        assert_eq!(fire("grad.nan"), None);
        assert_eq!(hits("grad.nan"), 4);
        assert_eq!(fired_count("grad.nan"), 1);

        // =V: fires every hit, carrying the payload
        assert_eq!(fire("step.stall"), Some(250.0));
        assert_eq!(fire("step.stall"), Some(250.0));
        assert_eq!(fired_count("step.stall"), 2);

        // a site in the catalog but not in the spec stays silent
        assert!(!fired("io.read.err"));

        // re-arming a site resets its counters
        arm_from_spec("grad.nan@1").unwrap();
        assert!(fire("grad.nan").is_some_and(|v| v.is_nan()));
        assert_eq!(fired_count("grad.nan"), 1);

        disarm_all();
        assert!(!armed());
        assert_eq!(fire("step.stall"), None);
    }
}
