//! The variational-form layer: the weak form of a second-order scalar
//! PDE as per-quadrature-point coefficient fields, decoupled from the
//! backend hot path.
//!
//! The paper's central claim is that the tensorized residual
//! contraction is *PDE-agnostic*: Poisson, convection–diffusion and
//! Helmholtz all run on the same kernel. This module makes that true in
//! code. A [`VariationalForm`] describes
//!
//! ```text
//! r[e,j] = Σ_q eps_q (G_x[e,j,q] ∂u/∂x + G_y[e,j,q] ∂u/∂y)
//!        + Σ_q V[e,j,q] (b_q · ∇u + c_q u) − F[e,j]
//! ```
//!
//! where each coefficient is a [`Coeff`]: either a spatial **constant**
//! (the scalar fast path — a GEMV `alpha` or a single multiply, exactly
//! the pre-refactor closed form) or a **table** of per-quadrature-point
//! values, hoisted *once* at backend construction from the
//! [`Problem`](crate::problems::Problem)'s coefficient fields
//! (`eps_at`/`b_at`/`c_at`) and threaded through the blocked GEMM/GEMV
//! contraction every step with no re-evaluation. Helmholtz is nothing
//! but `c = -k²`; a rotating-convection problem is nothing but a `b`
//! table — no backend fork, no new adjoint code.
//!
//! The trainable-eps losses compose with the form: `inverse_const`
//! replaces the form's diffusion with the trainable scalar,
//! `inverse_space` with the network's softplus'd eps head; convection
//! and reaction still come from the form.

use crate::fem::assembly::AssembledDomain;
use crate::problems::Problem;

/// One coefficient of the weak form, hoisted to step-invariant data.
#[derive(Debug, Clone, PartialEq)]
pub enum Coeff {
    /// Spatially constant — the backend keeps the pre-refactor scalar
    /// fast path (fold into a GEMV `alpha` / one multiply).
    Const(f64),
    /// Per-quadrature-point values, `ne * nq` element-major — sampled
    /// once at construction, never re-evaluated on the hot path.
    Table(Vec<f64>),
}

impl Coeff {
    /// Value at global quadrature-point index `p` (= `e * nq + q`).
    #[inline]
    pub fn at(&self, p: usize) -> f64 {
        match self {
            Coeff::Const(v) => *v,
            Coeff::Table(t) => t[p],
        }
    }

    /// The constant value, when this coefficient is one.
    pub fn constant(&self) -> Option<f64> {
        match self {
            Coeff::Const(v) => Some(*v),
            Coeff::Table(_) => None,
        }
    }

    /// Whether the coefficient is identically zero (`Const(0.0)`): the
    /// backend skips the corresponding term entirely.
    pub fn is_zero(&self) -> bool {
        matches!(self, Coeff::Const(v) if *v == 0.0)
    }
}

/// The weak form `-div(eps grad u) + b . grad u + c u = f` as hoisted
/// coefficient data. Built once per backend from the problem's
/// coefficient fields; the step loop only ever indexes it.
#[derive(Debug, Clone, PartialEq)]
pub struct VariationalForm {
    /// Diffusion `eps(x, y)`.
    pub eps: Coeff,
    /// Convection `b_x(x, y)`.
    pub bx: Coeff,
    /// Convection `b_y(x, y)`.
    pub by: Coeff,
    /// Reaction `c(x, y)` (Helmholtz: `c = -k²`).
    pub c: Coeff,
}

impl VariationalForm {
    /// Hoist the problem's coefficient fields over the assembled
    /// domain's quadrature points: constants stay scalars (the fast
    /// path), spatially varying coefficients are tabulated once.
    pub fn from_problem(p: &dyn Problem, dom: &AssembledDomain)
        -> VariationalForm {
        let var = p.coeff_variability();
        // the variability flags must agree with the pointwise
        // overrides: a Problem that overrides eps_at/b_at/c_at but
        // leaves the matching flag unset would silently train the
        // wrong PDE (the constant would be hoisted instead of the
        // field). Probe a few quadrature points at construction —
        // off the step hot path — and fail loudly; setting the flag
        // (tabulating is always correct) resolves any false positive.
        for gp in [0, dom.ne * dom.nq / 2, dom.ne * dom.nq - 1] {
            let (x, y) = (dom.quad_xy[2 * gp], dom.quad_xy[2 * gp + 1]);
            assert!(
                var.eps || p.eps_at(x, y) == p.eps(),
                "problem '{}' overrides eps_at but \
                 coeff_variability().eps is false", p.name());
            assert!(
                var.b || p.b_at(x, y) == p.b(),
                "problem '{}' overrides b_at but \
                 coeff_variability().b is false", p.name());
            assert!(
                var.c || p.c_at(x, y) == p.c(),
                "problem '{}' overrides c_at but \
                 coeff_variability().c is false", p.name());
        }
        let eps = if var.eps {
            Coeff::Table(dom.coeff_table(|x, y| p.eps_at(x, y)))
        } else {
            Coeff::Const(p.eps())
        };
        let (bx, by) = if var.b {
            (Coeff::Table(dom.coeff_table(|x, y| p.b_at(x, y).0)),
             Coeff::Table(dom.coeff_table(|x, y| p.b_at(x, y).1)))
        } else {
            let (bx, by) = p.b();
            (Coeff::Const(bx), Coeff::Const(by))
        };
        let c = if var.c {
            Coeff::Table(dom.coeff_table(|x, y| p.c_at(x, y)))
        } else {
            Coeff::Const(p.c())
        };
        VariationalForm { eps, bx, by, c }
    }

    /// Whether the form carries a convection term at all.
    pub fn has_convection(&self) -> bool {
        !self.bx.is_zero() || !self.by.is_zero()
    }

    /// Whether the form carries a reaction (mass) term.
    pub fn has_reaction(&self) -> bool {
        !self.c.is_zero()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fem::assembly;
    use crate::fem::quadrature::QuadKind;
    use crate::mesh::generators;
    use crate::problems::{
        ForceVariable, Helmholtz2D, PoissonSin, VariableConvectionCd,
    };

    #[test]
    fn constant_problem_stays_on_the_scalar_path() {
        let mesh = generators::unit_square(2);
        let dom = assembly::assemble(&mesh, 2, 3, QuadKind::GaussLegendre);
        let p = PoissonSin::new(std::f64::consts::PI);
        let f = VariationalForm::from_problem(&p, &dom);
        assert_eq!(f.eps.constant(), Some(1.0));
        assert!(f.bx.is_zero() && f.by.is_zero() && f.c.is_zero());
        assert!(!f.has_convection() && !f.has_reaction());
    }

    #[test]
    fn helmholtz_reaction_is_minus_k_squared() {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 3, QuadKind::GaussLegendre);
        let k = 2.5;
        let f = VariationalForm::from_problem(&Helmholtz2D::new(k), &dom);
        assert_eq!(f.c.constant(), Some(-k * k));
        assert!(f.has_reaction() && !f.has_convection());
    }

    #[test]
    fn variable_coefficients_are_tabulated_at_quadrature_points() {
        let mesh = generators::skewed_square(2, 0.15);
        let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
        let p = VariableConvectionCd::new();
        let f = VariationalForm::from_problem(&p, &dom);
        assert!(f.eps.constant().is_some(), "eps is constant for cd_var");
        let (bxt, byt) = match (&f.bx, &f.by) {
            (Coeff::Table(a), Coeff::Table(b)) => (a, b),
            other => panic!("b must be tabulated, got {other:?}"),
        };
        assert_eq!(bxt.len(), dom.ne * dom.nq);
        for gp in 0..dom.ne * dom.nq {
            let (x, y) = (dom.quad_xy[2 * gp], dom.quad_xy[2 * gp + 1]);
            let (bx, by) = p.b_at(x, y);
            assert_eq!(bxt[gp], bx);
            assert_eq!(byt[gp], by);
            assert_eq!(f.bx.at(gp), bx);
            assert_eq!(f.by.at(gp), by);
        }
    }

    #[test]
    fn force_variable_tabulates_constants_faithfully() {
        let mesh = generators::unit_square(2);
        let dom = assembly::assemble(&mesh, 2, 3, QuadKind::GaussLegendre);
        let p = ForceVariable::new(PoissonSin::new(std::f64::consts::PI));
        let f = VariationalForm::from_problem(&p, &dom);
        for coeff in [&f.eps, &f.bx, &f.by, &f.c] {
            assert!(coeff.constant().is_none(), "must be a table");
        }
        for gp in 0..dom.ne * dom.nq {
            assert_eq!(f.eps.at(gp), 1.0);
            assert_eq!(f.c.at(gp), 0.0);
        }
        // zero tables are *not* Const(0): has_* answers by value class
        assert!(f.has_convection() && f.has_reaction());
    }
}
