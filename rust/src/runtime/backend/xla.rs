//! The XLA/PJRT training backend (`--features xla`): executes
//! AOT-compiled train-step artifacts produced by `python -m compile.aot`
//! (`make artifacts`). This is the accelerated path of the paper
//! reproduction; the logic here used to live inside `Trainer` before the
//! backend abstraction.

use std::rc::Rc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::{Backend, BackendOpts, DataSource, StepStats};
use crate::runtime::engine::{Artifact, Engine};
use crate::runtime::tensor::TensorData;
use crate::util::rng::Rng;

/// Executes AOT-compiled train/predict artifacts on the PJRT client —
/// the accelerated implementation of [`Backend`].
pub struct XlaBackend<'a> {
    engine: &'a Engine,
    art: Rc<Artifact>,
    /// Predict artifact driven by `Backend::predict` (optional: training
    /// without evaluation needs none).
    predict_name: Option<String>,
    /// p/m/v literals in manifest order (3 * n_param_arrays).
    state: Vec<xla::Literal>,
    /// Data-segment inputs in manifest order (after step, lr),
    /// uploaded to the device ONCE — they are step-invariant, and at
    /// paper scale the premultiplier tensors are hundreds of MB.
    data: Vec<xla::PjRtBuffer>,
    /// Host sources of `data`. PJRT CPU uploads are asynchronous: the
    /// source literal MUST outlive the buffer's first use, so we pin
    /// them here (dropping them early is a use-after-free that
    /// manifests as a `literal.size_bytes() == b->size()` CHECK crash).
    _data_src: Vec<xla::Literal>,
    n_params: usize,
}

impl<'a> XlaBackend<'a> {
    /// Load `artifact` (and optionally a predict artifact), upload the
    /// step-invariant data tensors once and initialize the parameter
    /// state on device.
    pub fn new(
        engine: &'a Engine,
        artifact: &str,
        predict_name: Option<&str>,
        src: &DataSource<'_>,
        opts: &BackendOpts,
    ) -> Result<XlaBackend<'a>> {
        let art = engine.load(artifact)?;
        ensure!(art.manifest.kind == "train",
                "{artifact} is not a train artifact");
        let m = &art.manifest;
        let n_params = m.n_param_arrays();

        // ---- initial state: glorot weights, zero biases and moments
        let mut rng = Rng::new(opts.seed);
        let mut state: Vec<xla::Literal> = Vec::with_capacity(3 * n_params);
        for i in 0..n_params {
            let shape = &m.inputs[i].shape;
            let t = match shape.len() {
                2 => TensorData::new(shape.clone(),
                                     rng.glorot(shape[0], shape[1]))?,
                1 => TensorData::zeros(shape),
                0 => TensorData::scalar(opts.eps_init as f32),
                _ => bail!("unexpected param rank {shape:?}"),
            };
            state.push(t.to_literal()?);
        }
        // m and v moments: zeros of the same shapes
        for i in 0..2 * n_params {
            let shape = &m.inputs[n_params + i].shape;
            state.push(TensorData::zeros(shape).to_literal()?);
        }

        // ---- sanity: step/lr slots where aot.signature puts them
        ensure!(m.inputs[3 * n_params].name == "step"
                    && m.inputs[3 * n_params + 1].name == "lr",
                "manifest layout unexpected: {:?}",
                &m.inputs[3 * n_params].name);

        // ---- data segment in manifest order, resident on device
        let mut data = Vec::new();
        let mut data_src = Vec::new();
        for spec in &m.inputs[3 * n_params + 2..] {
            let lit = build_data_input(m, spec, src, opts)
                .with_context(|| format!("building input '{}'",
                                         spec.name))?;
            data.push(engine.to_buffer(&lit)?);
            data_src.push(lit);
        }

        Ok(XlaBackend {
            engine,
            art,
            predict_name: predict_name.map(|s| s.to_string()),
            state,
            data,
            _data_src: data_src,
            n_params,
        })
    }

    /// The loaded train artifact's manifest.
    pub fn manifest(&self) -> &crate::runtime::manifest::Manifest {
        &self.art.manifest
    }

    /// Network parameter literals (excludes the eps scalar), for predict.
    pub fn network_params(&self) -> &[xla::Literal] {
        &self.state[..self.art.manifest.n_network_arrays()]
    }

    fn eps_from_state(&self) -> Result<f64> {
        ensure!(self.art.manifest.loss == "inverse_const",
                "no trainable eps in {}", self.art.manifest.name);
        let lit = &self.state[self.n_params - 1];
        Ok(lit.to_vec::<f32>()?[0] as f64)
    }
}

impl Backend for XlaBackend<'_> {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn loss_kind(&self) -> &str {
        &self.art.manifest.loss
    }

    fn step(&mut self, step: usize, lr: f64) -> Result<StepStats> {
        let step_lit = xla::Literal::scalar(step as f32);
        let lr_lit = xla::Literal::scalar(lr as f32);

        // upload the (small) mutable state; the big data segment is
        // already device-resident
        let state_bufs: Vec<xla::PjRtBuffer> = self
            .state
            .iter()
            .map(|l| self.engine.to_buffer(l))
            .collect::<Result<_>>()?;
        let step_buf = self.engine.to_buffer(&step_lit)?;
        let lr_buf = self.engine.to_buffer(&lr_lit)?;
        let mut inputs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.art.manifest.inputs.len());
        inputs.extend(state_bufs.iter());
        inputs.push(&step_buf);
        inputs.push(&lr_buf);
        inputs.extend(self.data.iter());

        let outputs = self.art.execute_buffers(&inputs)?;
        let n_state = 3 * self.n_params;
        let mut it = outputs.into_iter();
        let mut new_state = Vec::with_capacity(n_state);
        for _ in 0..n_state {
            new_state.push(it.next().ok_or_else(|| anyhow!("short output"))?);
        }
        let rest: Vec<xla::Literal> = it.collect();
        self.state = new_state;

        let scalar = |l: &xla::Literal| -> Result<f64> {
            Ok(l.to_vec::<f32>()?[0] as f64)
        };
        let loss = scalar(&rest[0])?;
        let var_loss = if rest.len() > 1 { scalar(&rest[1])? } else { 0.0 };
        let bd_loss = if rest.len() > 2 { scalar(&rest[2])? } else { 0.0 };
        let extra = match self.art.manifest.loss.as_str() {
            "inverse_const" => self.eps_from_state()?,
            _ if rest.len() > 3 => scalar(&rest[3])?,
            _ => 0.0,
        };
        // gradient stays device-resident on the AOT path; 0.0 tells
        // the coordinator's sentinel to judge by the loss alone
        Ok(StepStats { loss, var_loss, bd_loss, extra, grad_norm: 0.0 })
    }

    fn predict(&self, points: &[[f64; 2]]) -> Result<Vec<Vec<f32>>> {
        let name = self.predict_name.as_deref().ok_or_else(|| anyhow!(
            "XlaBackend for {} was built without a predict artifact",
            self.art.manifest.name
        ))?;
        self.engine.predict(name, self.network_params(), points)
    }

    fn current_eps(&self) -> Option<f64> {
        if self.art.manifest.loss == "inverse_const" {
            self.eps_from_state().ok()
        } else {
            None
        }
    }
}

/// Build one data-segment literal according to its manifest name.
fn build_data_input(
    m: &crate::runtime::manifest::Manifest,
    spec: &crate::runtime::manifest::IoSpec,
    src: &DataSource<'_>,
    opts: &BackendOpts,
) -> Result<xla::Literal> {
    let domain = || -> Result<&crate::fem::assembly::AssembledDomain> {
        src.domain.ok_or_else(|| anyhow!(
            "artifact {} needs assembled tensors but DataSource.domain \
             is None", m.name))
    };
    let lit = match spec.name.as_str() {
        "quad_xy" => {
            let d = domain()?;
            TensorData::new(spec.shape.clone(), d.quad_xy_f32())?
        }
        "gx" => TensorData::new(spec.shape.clone(), domain()?.gx_f32())?,
        "gy" => TensorData::new(spec.shape.clone(), domain()?.gy_f32())?,
        "v" => TensorData::new(spec.shape.clone(), domain()?.v_f32())?,
        "f" => {
            let d = domain()?;
            let f = d.force_matrix(|x, y| src.problem.forcing(x, y));
            TensorData::from_f64(spec.shape.clone(), &f)?
        }
        "bd_xy" => {
            let pts = src.mesh.sample_boundary(m.config.nb);
            let flat: Vec<f32> = pts
                .iter()
                .flat_map(|p| [p[0] as f32, p[1] as f32])
                .collect();
            TensorData::new(spec.shape.clone(), flat)?
        }
        "bd_u" => {
            let pts = src.mesh.sample_boundary(m.config.nb);
            let vals: Vec<f32> = pts
                .iter()
                .map(|p| src.problem.boundary(p[0], p[1]) as f32)
                .collect();
            TensorData::new(spec.shape.clone(), vals)?
        }
        "sensor_xy" => {
            let pts = src.mesh.sample_interior(m.config.ns, opts.seed + 1);
            let flat: Vec<f32> = pts
                .iter()
                .flat_map(|p| [p[0] as f32, p[1] as f32])
                .collect();
            TensorData::new(spec.shape.clone(), flat)?
        }
        "sensor_u" => {
            let pts = src.mesh.sample_interior(m.config.ns, opts.seed + 1);
            let vals: Vec<f32> = pts
                .iter()
                .map(|p| sensor_value(src, p[0], p[1]))
                .collect::<Result<_>>()?;
            TensorData::new(spec.shape.clone(), vals)?
        }
        "coll_xy" => {
            let pts = src.mesh.sample_interior(m.config.n_coll, opts.seed);
            let flat: Vec<f32> = pts
                .iter()
                .flat_map(|p| [p[0] as f32, p[1] as f32])
                .collect();
            TensorData::new(spec.shape.clone(), flat)?
        }
        "f_vals" => {
            let pts = src.mesh.sample_interior(m.config.n_coll, opts.seed);
            let vals: Vec<f32> = pts
                .iter()
                .map(|p| src.problem.forcing(p[0], p[1]) as f32)
                .collect();
            TensorData::new(spec.shape.clone(), vals)?
        }
        "tau" => TensorData::scalar(opts.tau as f32),
        "gamma" => TensorData::scalar(opts.gamma as f32),
        other => bail!("unknown manifest input '{other}'"),
    };
    lit.to_literal()
}

fn sensor_value(src: &DataSource<'_>, x: f64, y: f64) -> Result<f32> {
    if let Some(f) = src.sensor_values {
        return Ok(f(x, y) as f32);
    }
    src.problem
        .exact(x, y)
        .map(|v| v as f32)
        .ok_or_else(|| anyhow!(
            "problem '{}' has no exact solution; provide \
             DataSource.sensor_values", src.problem.name()))
}
