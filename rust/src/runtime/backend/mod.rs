//! Training backends: the train-step contract the coordinator drives.
//!
//! A [`Backend`] owns everything a training run needs — network
//! parameters, optimizer state, and the step-invariant data tensors —
//! and exposes four operations: advance one optimizer step, predict at
//! arbitrary points, evaluate the trainable eps *field* (two-head
//! inverse-space networks), and report the trainable scalar eps
//! (inverse_const). The coordinator ([`crate::coordinator::trainer::Trainer`])
//! is backend-agnostic: it drives `&dyn Backend`, applies LR schedules,
//! logs history and computes error norms.
//!
//! The *PDE itself* is decoupled from the backends by the
//! [`form::VariationalForm`] layer: a problem's coefficient fields
//! (diffusion `eps(x,y)`, convection `b(x,y)`, reaction `c(x,y)` —
//! Helmholtz is `c = -k²`) are hoisted once into scalars/per-
//! quadrature-point tables and threaded through the blocked
//! contraction, so every PDE — Poisson, convection-diffusion,
//! Helmholtz, variable-coefficient fields — runs on the same kernel.
//!
//! Two implementations:
//! - [`native::NativeBackend`] — the whole FastVPINNs step in pure Rust
//!   (tanh-MLP forward with input tangents, tensor-contraction residual,
//!   hand-written reverse-mode backprop, Adam). Always available; no
//!   artifacts, no Python, no XLA in the build graph.
//! - [`xla::XlaBackend`] (`--features xla`) — executes AOT-compiled
//!   train-step artifacts on the PJRT client, the accelerated path.

pub mod form;
pub mod native;
#[cfg(feature = "xla")]
pub mod xla;

pub use form::{Coeff, VariationalForm};

use anyhow::Result;

use crate::fem::assembly::AssembledDomain;
use crate::mesh::QuadMesh;
use crate::problems::Problem;

/// Where a backend gets its mesh/problem data from.
pub struct DataSource<'a> {
    /// The training mesh.
    pub mesh: &'a QuadMesh,
    /// Assembled premultiplier tensors (not needed for PINN artifacts).
    pub domain: Option<&'a AssembledDomain>,
    /// The PDE instance being solved.
    pub problem: &'a dyn Problem,
    /// Sensor ground truth override (defaults to `problem.exact`).
    pub sensor_values: Option<&'a dyn Fn(f64, f64) -> f64>,
}

/// Scalar penalties + init knobs shared by all backends (a subset of
/// `TrainConfig`; `From<&TrainConfig>` is implemented in the coordinator).
#[derive(Debug, Clone, Copy)]
pub struct BackendOpts {
    /// Dirichlet penalty (paper's tau).
    pub tau: f64,
    /// Sensor penalty for inverse problems (paper's gamma).
    pub gamma: f64,
    /// RNG seed (weight init + boundary/sensor sampling).
    pub seed: u64,
    /// Initial guess for the trainable eps (inverse_const; paper: 2.0).
    pub eps_init: f64,
    /// Worker threads for the persistent pool (`--workers`). `None`
    /// defers to the `FASTVPINNS_THREADS` env alias, then the
    /// machine's available parallelism; always clamped to the element
    /// count. Never changes results — the shard plan and reduction
    /// order are worker-count-independent — only wall-clock.
    pub workers: Option<usize>,
}

impl Default for BackendOpts {
    fn default() -> Self {
        BackendOpts {
            tau: 10.0,
            gamma: 10.0,
            seed: 42,
            eps_init: 2.0,
            workers: None,
        }
    }
}

/// Loss components of one optimizer step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    /// Total objective (var + tau*bd [+ gamma*sensor]).
    pub loss: f64,
    /// Variational component.
    pub var_loss: f64,
    /// Dirichlet-penalty component.
    pub bd_loss: f64,
    /// Loss-dependent extra: eps (inverse_const), sensor loss
    /// (inverse_space), else 0.
    pub extra: f64,
    /// L2 norm of the full flat gradient this step was taken with —
    /// the coordinator's divergence sentinel (non-finite or exploding
    /// norms trigger rollback). Backends that cannot read the gradient
    /// back (device-resident state) report `0.0`, which the sentinel
    /// ignores.
    pub grad_norm: f64,
}

/// The train-step contract.
pub trait Backend {
    /// Short backend id ("native", "xla").
    fn name(&self) -> &'static str;

    /// Loss family being optimized ("poisson", "cd", "inverse_const",
    /// "inverse_space", "pinn", ...).
    fn loss_kind(&self) -> &str;

    /// Run one optimizer step. `step` is 1-based (Adam bias correction),
    /// `lr` the current learning rate.
    fn step(&mut self, step: usize, lr: f64) -> Result<StepStats>;

    /// Evaluate the network at arbitrary points; one `Vec<f32>` per
    /// output head (head 0 is always u).
    fn predict(&self, points: &[[f64; 2]]) -> Result<Vec<Vec<f32>>>;

    /// Evaluate the trainable diffusion *field* `eps(x, y)` at
    /// arbitrary points (two-head inverse-space networks). `None` when
    /// the loss has no eps field head — callers may still find the
    /// field as head 1 of [`Backend::predict`] (AOT two-head
    /// artifacts).
    fn predict_eps_field(&self, _points: &[[f64; 2]])
        -> Result<Option<Vec<f32>>> {
        Ok(None)
    }

    /// Current trainable diffusion coefficient, when the loss has one.
    fn current_eps(&self) -> Option<f64> {
        None
    }

    /// Export the backend's full training state as a versioned
    /// [`Checkpoint`](crate::runtime::checkpoint::Checkpoint) artifact:
    /// network parameters (both heads), trainable scalar eps, Adam
    /// state, the hoisted weak form and the domain fingerprint. The
    /// coordinator fills in run-level metadata (registry problem id,
    /// CLI flags, step count) before writing. Backends without
    /// persistence support return an error (the default).
    fn export_checkpoint(&self)
        -> Result<crate::runtime::checkpoint::Checkpoint> {
        anyhow::bail!(
            "backend '{}' does not support checkpointing", self.name())
    }

    /// Restore parameters, trainable eps and optimizer state from a
    /// checkpoint previously produced by
    /// [`Backend::export_checkpoint`] on an identically-configured
    /// backend — the in-memory rollback primitive behind the
    /// coordinator's divergence recovery (the checkpoint never needs
    /// to touch disk). Implementations must verify the artifact
    /// describes this backend and error on any mismatch. Backends
    /// without persistence support return an error (the default).
    fn restore_checkpoint(
        &mut self,
        _ck: &crate::runtime::checkpoint::Checkpoint,
    ) -> Result<()> {
        anyhow::bail!(
            "backend '{}' does not support state restore", self.name())
    }
}

/// Parse a `--backend` CLI value, erroring helpfully when the XLA path
/// was not compiled in.
pub fn check_backend_name(name: &str) -> Result<()> {
    match name {
        "native" => Ok(()),
        #[cfg(feature = "xla")]
        "xla" => Ok(()),
        #[cfg(not(feature = "xla"))]
        "xla" => anyhow::bail!(
            "backend 'xla' was not compiled in — rebuild with `cargo \
             build --features xla` (and run `make artifacts` for the \
             AOT train steps)"
        ),
        other => anyhow::bail!(
            "unknown backend '{other}' (known: native, xla)"
        ),
    }
}
